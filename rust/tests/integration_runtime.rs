//! Integration tests for the real compute path (L1/L2 artifacts -> L3
//! PJRT execution). Requires `make artifacts` (the Makefile's `test`
//! target guarantees ordering).

use std::path::{Path, PathBuf};

use ipumm::experiments::e2e;
use ipumm::runtime::{ArtifactKind, BlockMmExecutor, Manifest, RuntimeClient};
use ipumm::util::matrix::Matrix;

fn artifacts_dir() -> PathBuf {
    // tests run from the crate root
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn require_artifacts() -> PathBuf {
    let dir = artifacts_dir();
    assert!(
        dir.join("manifest.tsv").exists(),
        "artifacts missing — run `make artifacts` before `cargo test`"
    );
    dir
}

#[test]
fn manifest_lists_expected_artifacts() {
    let m = Manifest::load(&require_artifacts()).unwrap();
    assert!(m.blocks().count() >= 3, "expected >= 3 block sizes");
    assert!(m.by_name("mm_block_128").is_some());
    assert!(m
        .artifacts
        .iter()
        .any(|a| a.kind == ArtifactKind::Full));
}

#[test]
fn client_compiles_every_artifact() {
    let c = RuntimeClient::load(&require_artifacts()).unwrap();
    assert_eq!(c.platform(), "cpu");
    assert!(c.artifact_names().len() >= 4);
}

#[test]
fn block_artifact_accumulates() {
    // out = c + a@b: with a = I, out must equal c + b
    let mut c = RuntimeClient::load(&require_artifacts()).unwrap();
    let n = 64;
    let mut ident = Matrix::zeros(n, n);
    for i in 0..n {
        ident.set(i, i, 1.0);
    }
    let b = Matrix::random(n, n, 5);
    let acc = Matrix::random(n, n, 6);
    let out = c
        .execute_block("mm_block_64", &ident.data, &b.data, &acc.data)
        .unwrap();
    let got = Matrix::from_vec(n, n, out);
    let mut want = acc.clone();
    for i in 0..n * n {
        want.data[i] += b.data[i];
    }
    assert!(got.allclose(&want, 1e-5), "max err {}", got.max_abs_diff(&want));
}

#[test]
fn full_artifact_matches_oracle() {
    let mut c = RuntimeClient::load(&require_artifacts()).unwrap();
    for name in ["mm_full_32", "mm_full_96"] {
        let spec = c.spec(name).unwrap().clone();
        let a = Matrix::random(spec.m, spec.n, 7);
        let b = Matrix::random(spec.n, spec.k, 8);
        let out = c.execute_full(name, &a.data, &b.data).unwrap();
        let got = Matrix::from_vec(spec.m, spec.k, out);
        let want = a.matmul_oracle(&b);
        assert!(got.allclose(&want, 1e-4), "{name}: err {}", got.max_abs_diff(&want));
    }
}

#[test]
fn executing_full_as_block_is_rejected() {
    let mut c = RuntimeClient::load(&require_artifacts()).unwrap();
    let a = vec![0.0f32; 64 * 64];
    let err = c.execute_full("mm_block_64", &a, &a).unwrap_err();
    assert!(err.to_string().contains("not a full-matmul"));
}

#[test]
fn block_executor_handles_exact_multiples() {
    let mut ex = BlockMmExecutor::load(&require_artifacts(), 128).unwrap();
    let a = Matrix::random(256, 384, 11);
    let b = Matrix::random(384, 128, 12);
    let (_c, stats, err) = ex.mm_verified(&a, &b).unwrap();
    assert_eq!(stats.block_calls, 2 * 1 * 3);
    assert!(err < 1e-4);
}

#[test]
fn block_executor_pads_ragged_shapes() {
    let mut ex = BlockMmExecutor::load(&require_artifacts(), 64).unwrap();
    let a = Matrix::random(65, 130, 13);
    let b = Matrix::random(130, 1, 14);
    let (c, stats, err) = ex.mm_verified(&a, &b).unwrap();
    assert_eq!((c.rows, c.cols), (65, 1));
    assert_eq!(stats.padded_m, 128);
    assert_eq!(stats.padded_k, 64);
    assert!(err < 1e-4);
}

#[test]
fn block_executor_accumulation_depth() {
    // deep reduction (right-skew shape): many accumulating steps per block
    let mut ex = BlockMmExecutor::load(&require_artifacts(), 64).unwrap();
    let a = Matrix::random(64, 640, 15);
    let b = Matrix::random(640, 64, 16);
    let (_c, stats, err) = ex.mm_verified(&a, &b).unwrap();
    assert_eq!(stats.block_calls, 10);
    assert!(err < 1e-3);
}

#[test]
fn block_sizes_agree_with_each_other() {
    let dir = require_artifacts();
    let a = Matrix::random(200, 100, 17);
    let b = Matrix::random(100, 160, 18);
    let mut results = Vec::new();
    for cap in [64usize, 128, 256] {
        let mut ex = BlockMmExecutor::load(&dir, cap).unwrap();
        let (c, _s) = ex.mm(&a, &b).unwrap();
        results.push(c);
    }
    for w in results.windows(2) {
        assert!(
            w[0].allclose(&w[1], 1e-4),
            "block sizes disagree: {}",
            w[0].max_abs_diff(&w[1])
        );
    }
}

#[test]
fn e2e_driver_runs_and_verifies() {
    let r = e2e::run(&require_artifacts(), &e2e::default_trace(), 128).unwrap();
    assert_eq!(r.rows.len(), e2e::default_trace().len());
    for row in &r.rows {
        assert!(row.real_max_err < 1e-3, "{}: err {}", row.label, row.real_max_err);
        assert!(row.gpu_tflops > 0.0);
    }
    // paper headline: IPU wins wherever it fits
    assert!(r.geomean_speedup > 1.0, "geomean {}", r.geomean_speedup);
    assert!(r.total_block_calls > 100);
}

#[test]
fn e2e_table_renders_all_rows() {
    let r = e2e::run(&require_artifacts(), &e2e::default_trace()[..2], 128).unwrap();
    let ascii = e2e::to_table(&r).to_ascii();
    assert!(ascii.contains("geomean"));
    assert!(ascii.contains("squared-256"));
}
