//! Property-based invariants over the whole substrate, driven by the
//! in-tree prop framework (rust/src/util/prop.rs): sized random cases
//! with replayable seeds.

use ipumm::arch::IpuArch;
use ipumm::bsp::scheduler::BspEngine;
use ipumm::exchange::fabric::ExchangeFabric;
use ipumm::exchange::plan::{ExchangePattern, ExchangePlan};
use ipumm::gpu::cublas_model::GpuModel;
use ipumm::arch::GpuArch;
use ipumm::memory::mapping::{grid_2d_mapping, linear_balanced_mapping};
use ipumm::graph::tensor::{DType, Tensor, TensorId};
use ipumm::coordinator::runner::ThreadBudget;
use ipumm::coordinator::trace::TraceSpec;
use ipumm::fault::chaos::{describe_minimal, shrink_failing, ChaosRequest};
use ipumm::fault::{
    BreakerConfig, FaultPlan, FaultPolicy, FaultProfile, RequestOutcome, RetryPolicy,
};
use ipumm::obs::window::{windowed, MetricEvent, WindowSpec};
use ipumm::obs::{QuantileSketch, Recorder};
use ipumm::planner::cost::{CostConfig, CostModel, PlanCost};
use ipumm::planner::partition::{MmShape, Partition};
use ipumm::planner::search::{for_each_candidate, search, search_fits, search_with_workers};
use ipumm::prop_assert;
use ipumm::serve::{BucketLadder, DispatchPolicy, MmService, PlanCache, ServiceConfig};
use ipumm::sim::engine::SimEngine;
use ipumm::sparse::csr::BlockCsr;
use ipumm::sparse::pattern::{BlockPattern, PatternKind, SparsitySpec, BLOCK_SIZES};
use ipumm::sparse::planner::{
    sparse_max_fitting_square, sparse_max_fitting_square_linear, sparse_search,
    sparse_search_fits, sparse_search_past_dense_wall_with_workers, sparse_search_spec,
};
use ipumm::util::prop::{check, check_default, PropConfig, Size};
use ipumm::util::rng::Rng;
use ipumm::util::stats::Summary;
use std::sync::Mutex;

/// Serializes every test that toggles the process-global trace recorder
/// (`ipumm::obs::enable`/`disable`/`take`). Cargo runs this binary's
/// tests on parallel threads; without the gate two toggling tests could
/// interleave enable/disable/drain and read each other's data.
static OBS_GATE: Mutex<()> = Mutex::new(());

fn random_shape(rng: &mut Rng, size: Size) -> MmShape {
    let hi = size.scale(64, 4096);
    MmShape::new(
        rng.gen_usize(1, hi),
        rng.gen_usize(1, hi),
        rng.gen_usize(1, hi),
    )
}

#[test]
fn prop_plans_fit_tile_memory_or_error() {
    let arch = IpuArch::gc200();
    check_default("plan fits or OOM", |rng, size| {
        let shape = random_shape(rng, size);
        match search(&arch, shape) {
            Ok(plan) => {
                prop_assert!(
                    plan.cost.fits && plan.cost.tile_bytes_total <= arch.tile_sram_bytes,
                    "plan claims fit but max tile {} > {} for {shape:?}",
                    plan.cost.tile_bytes_total,
                    arch.tile_sram_bytes
                );
                prop_assert!(
                    plan.partition().is_valid(shape, arch.tiles),
                    "invalid partition {:?}",
                    plan.partition()
                );
            }
            Err(_) => {} // OOM is a legal outcome
        }
        Ok(())
    });
}

#[test]
fn prop_plan_efficiency_bounded() {
    let arch = IpuArch::gc200();
    check_default("efficiency in (0, 1]", |rng, size| {
        let shape = random_shape(rng, size);
        if let Ok(plan) = search(&arch, shape) {
            let eff = plan.cost.efficiency();
            prop_assert!(eff > 0.0 && eff <= 1.0, "efficiency {eff} for {shape:?}");
            let tf = plan.tflops(&arch);
            prop_assert!(
                tf <= arch.peak_fp32_tflops(),
                "tflops {tf} above peak for {shape:?}"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_cost_model_census_consistency() {
    let arch = IpuArch::gc200();
    let model = CostModel::new(&arch);
    check_default("census = 4/tile + reduce", |rng, size| {
        let shape = random_shape(rng, size);
        let pm = rng.gen_usize(1, 32.min(shape.m));
        let pk = rng.gen_usize(1, 32.min(shape.k));
        let pn = 1 << rng.gen_usize(0, 3);
        let cn = 16 * rng.gen_usize(1, size.scale(2, 32));
        let part = Partition { pm, pn, pk, cn };
        if !part.is_valid(shape, arch.tiles) {
            return Ok(());
        }
        let cost = model.evaluate(shape, part);
        prop_assert!(
            cost.compute_vertices == 4 * part.tiles_used(),
            "compute vertices {} != 4*{}",
            cost.compute_vertices,
            part.tiles_used()
        );
        prop_assert!(
            (pn == 1) == (cost.reduce_vertices == 0),
            "reduce vertices {} inconsistent with pn={pn}",
            cost.reduce_vertices
        );
        prop_assert!(
            cost.total_cycles == cost.compute_cycles + cost.exchange_cycles + cost.sync_cycles,
            "cycle sum mismatch"
        );
        Ok(())
    });
}

#[test]
fn prop_exchange_plans_conserve_bytes() {
    let arch = IpuArch::gc200();
    let fabric = ExchangeFabric::new(&arch);
    check_default("exchange conservation", |rng, size| {
        let mut plan = ExchangePlan::new("prop", ExchangePattern::AllToAll);
        let transfers = size.scale(1, 200);
        for _ in 0..transfers {
            let src = rng.gen_usize(0, arch.tiles - 1);
            let dst = rng.gen_usize(0, arch.tiles - 1);
            plan.add(src, dst, rng.gen_range(0, 1 << 16));
        }
        plan.validate(arch.tiles).map_err(|e| e.to_string())?;
        let sent: u64 = plan.sent_per_tile(arch.tiles).iter().sum();
        let recv: u64 = plan.recv_per_tile(arch.tiles).iter().sum();
        prop_assert!(sent == recv, "sent {sent} != recv {recv}");
        prop_assert!(sent == plan.total_bytes(), "sent {sent} != total");

        let cost = fabric.cost(&plan);
        let max_tile = plan
            .sent_per_tile(arch.tiles)
            .into_iter()
            .chain(plan.recv_per_tile(arch.tiles))
            .max()
            .unwrap_or(0);
        prop_assert!(cost.max_tile_bytes == max_tile, "bottleneck mismatch");
        if plan.transfers.is_empty() {
            prop_assert!(cost.cycles == 0, "empty plan should be free");
        } else {
            prop_assert!(cost.cycles >= fabric.setup_cycles, "missing setup cost");
        }
        Ok(())
    });
}

#[test]
fn prop_mappings_partition_tensors() {
    check_default("mappings partition", |rng, size| {
        let numel = rng.gen_usize(1, size.scale(16, 1 << 20));
        let tiles = rng.gen_usize(1, 1472);
        let mapping = linear_balanced_mapping(numel, tiles);
        let t = Tensor {
            id: TensorId(0),
            name: "prop".into(),
            shape: vec![numel],
            dtype: DType::F32,
            mapping: Some(mapping),
        };
        t.validate_mapping().map_err(|e| e.to_string())?;

        let rows = rng.gen_usize(1, size.scale(4, 512));
        let cols = rng.gen_usize(1, size.scale(4, 512));
        let pr = rng.gen_usize(1, rows.min(32));
        let pc = rng.gen_usize(1, cols.min(32));
        let tiles2 = pr * pc;
        let g = grid_2d_mapping(rows, cols, pr, pc, tiles2, |i, j| i * pc + j);
        let t2 = Tensor {
            id: TensorId(1),
            name: "grid".into(),
            shape: vec![rows, cols],
            dtype: DType::F32,
            mapping: Some(g),
        };
        t2.validate_mapping().map_err(|e| e.to_string())?;
        Ok(())
    });
}

#[test]
fn prop_sim_trace_phases_partition_total() {
    let arch = IpuArch::gc200();
    let engine = SimEngine::new(arch);
    check_default("trace phases partition", |rng, size| {
        let hi = size.scale(128, 2048);
        let shape = MmShape::new(
            rng.gen_usize(32, hi),
            rng.gen_usize(32, hi),
            rng.gen_usize(32, hi),
        );
        if let Ok(report) = engine.simulate_mm(shape) {
            let (c, s, e) = report.trace.phase_fractions();
            prop_assert!(
                (c + s + e - 1.0).abs() < 1e-9,
                "fractions sum {} for {shape:?}",
                c + s + e
            );
            let util = report.trace.tile_utilization();
            prop_assert!((0.0..=1.0).contains(&util), "utilization {util}");
            prop_assert!(
                report.memory.fits(),
                "graph memory overflow despite fitting plan: {shape:?}"
            );
            prop_assert!(
                report.total_vertices == report.plan.cost.total_vertices(),
                "graph census {} != planner census {}",
                report.total_vertices,
                report.plan.cost.total_vertices()
            );
        }
        Ok(())
    });
}

#[test]
fn prop_bsp_engine_deterministic() {
    let arch = IpuArch::gc200();
    let engine = SimEngine::new(arch.clone());
    check_default("bsp deterministic", |rng, size| {
        let hi = size.scale(64, 1024);
        let shape = MmShape::new(
            rng.gen_usize(16, hi),
            rng.gen_usize(16, hi),
            rng.gen_usize(16, hi),
        );
        if let Ok(plan) = search(&arch, shape) {
            let g = engine.build_graph(shape, &plan);
            let bsp = BspEngine::new(&arch);
            let t1 = bsp.run(&g).total_cycles();
            let t2 = bsp.run(&g).total_cycles();
            prop_assert!(t1 == t2, "nondeterministic trace {t1} vs {t2}");
        }
        Ok(())
    });
}

#[test]
fn prop_gpu_model_bounded_and_monotone_in_peak() {
    let a30 = GpuModel::new(GpuArch::a30());
    let v100 = GpuModel::new(GpuArch::v100());
    check_default("gpu model bounded", |rng, size| {
        let shape = random_shape(rng, size);
        let r = a30.simulate_mm(shape);
        prop_assert!(r.tflops > 0.0, "non-positive tflops for {shape:?}");
        prop_assert!(
            r.efficiency <= 1.0,
            "efficiency {} above 1 for {shape:?}",
            r.efficiency
        );
        // a strictly faster part should never be slower on big shapes
        if shape.flops() > 1_000_000_000 {
            let rv = v100.simulate_mm(shape);
            prop_assert!(
                rv.tflops >= 0.9 * r.tflops,
                "V100 {} slower than A30 {} for {shape:?}",
                rv.tflops,
                r.tflops
            );
        }
        Ok(())
    });
}

#[test]
fn prop_matrix_block_roundtrip() {
    check_default("block roundtrip", |rng, size| {
        let rows = rng.gen_usize(1, size.scale(2, 64));
        let cols = rng.gen_usize(1, size.scale(2, 64));
        let m = ipumm::util::matrix::Matrix::random(rows, cols, rng.next_u64());
        let br = rng.gen_usize(1, 80);
        let bc = rng.gen_usize(1, 80);
        let r0 = rng.gen_usize(0, rows.saturating_sub(1));
        let c0 = rng.gen_usize(0, cols.saturating_sub(1));
        let block = m.block_padded(r0, c0, br, bc);
        // in-range elements match, out-of-range are zero
        for r in 0..br {
            for c in 0..bc {
                let v = block.at(r, c);
                if r0 + r < rows && c0 + c < cols {
                    prop_assert!(v == m.at(r0 + r, c0 + c), "copy mismatch");
                } else {
                    prop_assert!(v == 0.0, "padding not zero");
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_cache_hit_identical_to_fresh_search() {
    // serving-layer contract: a memoized plan must be indistinguishable
    // from re-running the planner — same partition, same cost, same
    // search statistics; a cached OOM verdict must match a fresh OOM
    let arch = IpuArch::gc200();
    let cache = PlanCache::new(512);
    check_default("cache hit == fresh search", |rng, size| {
        let shape = random_shape(rng, size);
        let cached = cache.get_or_plan(&arch, shape);
        let hit = cache.get_or_plan(&arch, shape);
        let fresh = search(&arch, shape);
        match (hit, fresh, cached) {
            (Ok(h), Ok(f), Ok(_)) => {
                prop_assert!(
                    h.cost.partition == f.cost.partition,
                    "partition {:?} != fresh {:?} for {shape:?}",
                    h.cost.partition,
                    f.cost.partition
                );
                prop_assert!(
                    h.cost.total_cycles == f.cost.total_cycles,
                    "cycles {} != fresh {} for {shape:?}",
                    h.cost.total_cycles,
                    f.cost.total_cycles
                );
                prop_assert!(
                    h.candidates_evaluated == f.candidates_evaluated,
                    "search stats diverge for {shape:?}"
                );
            }
            (Err(_), Err(_), Err(_)) => {}
            _ => prop_assert!(false, "cache and fresh search disagree for {shape:?}"),
        }
        Ok(())
    });
}

#[test]
fn prop_bucket_never_smaller_than_request() {
    let ladders = [
        BucketLadder::default(),
        BucketLadder::geometric(32, 2048),
        BucketLadder::block_aligned(128, 8192),
    ];
    check_default("bucket >= request, idempotent", |rng, size| {
        let hi = size.scale(64, 32_768);
        let shape = MmShape::new(
            rng.gen_usize(1, hi),
            rng.gen_usize(1, hi),
            rng.gen_usize(1, hi),
        );
        for ladder in &ladders {
            let b = ladder.bucket(shape);
            prop_assert!(
                b.m >= shape.m && b.n >= shape.n && b.k >= shape.k,
                "bucket {b:?} smaller than request {shape:?}"
            );
            prop_assert!(
                ladder.bucket(b) == b,
                "bucketing not idempotent: {b:?} -> {:?}",
                ladder.bucket(b)
            );
            prop_assert!(
                BucketLadder::overprovision(shape, b) >= 1.0,
                "overprovision below 1 for {shape:?}"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_sparse_density_one_reproduces_dense_cost() {
    // the sparse wrapper's anchor: a fully dense pattern must plan and
    // price exactly like the dense planner, for every generator kind
    let arch = IpuArch::gc200();
    check_default("sparse density 1.0 == dense", |rng, size| {
        let hi = size.scale(64, 2048);
        let shape = MmShape::new(
            rng.gen_usize(8, hi),
            rng.gen_usize(8, hi),
            rng.gen_usize(8, hi),
        );
        let kind = *rng.choose(&PatternKind::all());
        let block = *rng.choose(&BLOCK_SIZES);
        let spec = SparsitySpec::new(kind, block, 1.0, rng.next_u64());
        match (sparse_search_spec(&arch, shape, spec), search(&arch, shape)) {
            (Ok(sparse), Ok(dense)) => {
                prop_assert!(
                    sparse.cost.total_cycles == dense.cost.total_cycles,
                    "sparse {} != dense {} for {shape:?} ({kind:?}, b{block})",
                    sparse.cost.total_cycles,
                    dense.cost.total_cycles
                );
                prop_assert!(
                    sparse.partition() == dense.partition(),
                    "partitions diverge for {shape:?}"
                );
                prop_assert!(
                    sparse.effective_flops() == shape.flops(),
                    "dense pattern must count all flops for {shape:?}"
                );
            }
            (Err(_), Err(_)) => {} // dense wall hits both paths alike
            _ => prop_assert!(false, "OOM verdicts diverge for {shape:?}"),
        }
        Ok(())
    });
}

#[test]
fn prop_sparse_cost_monotone_in_density() {
    // nested generators (random, banded): lowering the density never
    // raises the modeled cost, and every sparse plan beats-or-matches
    // the dense plan it refined from
    let arch = IpuArch::gc200();
    check_default("sparse cost monotone in density", |rng, size| {
        let hi = size.scale(96, 1536);
        let shape = MmShape::new(
            rng.gen_usize(16, hi),
            rng.gen_usize(16, hi),
            rng.gen_usize(16, hi),
        );
        let kind = *rng.choose(&[PatternKind::Random, PatternKind::Banded]);
        let block = *rng.choose(&BLOCK_SIZES);
        let seed = rng.next_u64();
        let mut prev: Option<u64> = None;
        for density in [0.1, 0.3, 0.6, 1.0] {
            let spec = SparsitySpec::new(kind, block, density, seed);
            match sparse_search_spec(&arch, shape, spec) {
                Ok(plan) => {
                    if let Some(speedup) = plan.speedup_vs_dense() {
                        prop_assert!(
                            speedup >= 1.0 - 1e-12,
                            "sparsity slowed {shape:?} down at d={density}"
                        );
                    }
                    if let Some(prev) = prev {
                        prop_assert!(
                            prev <= plan.cost.total_cycles,
                            "cost fell from {prev} to {} as density rose to \
                             {density} for {shape:?} ({kind:?}, b{block})",
                            plan.cost.total_cycles
                        );
                    }
                    prev = Some(plan.cost.total_cycles);
                }
                Err(_) => return Ok(()), // dense wall: whole ladder OOMs
            }
        }
        Ok(())
    });
}

#[test]
fn prop_sparse_admission_monotone_in_density() {
    // the CSR-aware wall's core invariants: (1) anything fitting dense
    // fits at every density (the dense layout is a legal fallback, so
    // the sparse bill never exceeds the dense bill); (2) for nested
    // generators, once a shape fits at some density it keeps fitting at
    // every lower density; (3) the fits-only probe agrees with the full
    // sparse search's verdict
    let arch = IpuArch::gc200();
    check_default("sparse admission monotone", |rng, size| {
        let hi = size.scale(256, 4352); // ramps across the dense wall
        let shape = MmShape::new(
            rng.gen_usize(16, hi),
            rng.gen_usize(16, hi),
            rng.gen_usize(16, hi),
        );
        let kind = *rng.choose(&[PatternKind::Random, PatternKind::Banded]);
        let block = *rng.choose(&BLOCK_SIZES);
        let seed = rng.next_u64();
        let dense_fits = search_fits(&arch, shape);
        let mut seen_fit = false;
        for density in [1.0, 0.6, 0.3, 0.1] {
            let spec = SparsitySpec::new(kind, block, density, seed);
            let fits = sparse_search_fits(&arch, shape, spec);
            if dense_fits {
                prop_assert!(
                    fits,
                    "dense fits but sparse d={density} does not for {shape:?} ({kind:?} b{block})"
                );
            }
            if seen_fit {
                prop_assert!(
                    fits,
                    "fit lost as density fell to {density} for {shape:?} ({kind:?} b{block})"
                );
            }
            seen_fit = seen_fit || fits;
            prop_assert!(
                fits == sparse_search_spec(&arch, shape, spec).is_ok(),
                "fits probe disagrees with the search verdict at d={density} for {shape:?}"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_sparse_wall_bisection_matches_linear() {
    // the bisected density-dependent wall equals the linear-scan
    // reference on both paper architectures, for arbitrary specs and
    // step resolutions (few cases: each probes several squares)
    let archs = [IpuArch::gc200(), IpuArch::gc2()];
    let config = PropConfig { cases: 12, base_seed: 0x5EED };
    check("sparse wall bisection == linear", config, |rng, _size| {
        let arch = &archs[rng.gen_usize(0, 1)];
        let kind = *rng.choose(&PatternKind::all());
        let block = *rng.choose(&BLOCK_SIZES);
        let density = 0.05 + 0.95 * rng.next_f64();
        let spec = SparsitySpec::new(kind, block, density, rng.next_u64());
        let step = *rng.choose(&[384usize, 512, 768]);
        let limit = 5120;
        let b = sparse_max_fitting_square(arch, spec, step, limit);
        let l = sparse_max_fitting_square_linear(arch, spec, step, limit);
        prop_assert!(
            b == l,
            "bisect {b} != linear {l} for {spec:?} step {step} on {}",
            arch.name
        );
        Ok(())
    });
}

#[test]
fn prop_sparse_planner_bill_matches_graph_residency() {
    // the planner's sparse A home share (BlockCsr::residency_per_tile)
    // must equal, tile for tile, what the built sparse graph holds in
    // its CSR tensors — the equality that pins the sparse memory model
    // to the simulated layout
    let arch = IpuArch::gc200();
    let engine = SimEngine::new(arch.clone());
    check_default("sparse bill == graph residency", |rng, size| {
        let hi = size.scale(64, 1536);
        let shape = MmShape::new(
            rng.gen_usize(8, hi),
            rng.gen_usize(8, hi),
            rng.gen_usize(8, hi),
        );
        let spec = SparsitySpec::new(
            *rng.choose(&PatternKind::all()),
            *rng.choose(&BLOCK_SIZES),
            0.05 + 0.95 * rng.next_f64(),
            rng.next_u64(),
        );
        let pattern = BlockPattern::for_shape(spec, shape);
        let Ok(plan) = sparse_search(&arch, shape, &pattern) else {
            return Ok(()); // past even the sparse wall
        };
        let g = engine.build_sparse_graph(shape, &plan, &pattern);
        let csr = BlockCsr::from_pattern(&pattern);
        let a_on_tile = |tile: usize| -> u64 {
            g.tensors()
                .iter()
                .filter(|t| t.name.starts_with("A_"))
                .map(|t| t.bytes_on_tile(tile) as u64)
                .sum()
        };
        // the layout choice the builder and the bill share: CSR only
        // when it beats the dense home share
        let dense_home_a = 4 * (shape.m as u64 * shape.n as u64) / arch.tiles as u64;
        let csr_resident = csr.max_tile_residency(arch.tiles, 4);
        let billed_a = dense_home_a.min(csr_resident); // the bill's home_a substitution
        if csr_resident <= dense_home_a {
            // CSR branch: byte-for-byte equality per tile
            let expected = csr.residency_per_tile(arch.tiles, 4);
            for (tile, want) in expected.iter().enumerate() {
                let got = a_on_tile(tile);
                prop_assert!(
                    got == *want,
                    "tile {tile}: graph holds {got} B, planner bills {want} B for {shape:?} {spec:?}"
                );
            }
        } else {
            // dense-fallback branch: the graph maps A densely; its
            // heaviest tile exceeds the bill's floor-divided share by at
            // most one balanced-mapping remainder element
            prop_assert!(
                g.tensors().iter().all(|t| !t.name.starts_with("A_csr")),
                "dense fallback must not map CSR index tensors for {shape:?} {spec:?}"
            );
            let max_a = (0..arch.tiles).map(a_on_tile).max().unwrap_or(0);
            prop_assert!(
                max_a <= billed_a + 8,
                "dense-fallback A {max_a} B exceeds billed {billed_a} B (+8 slack) for {shape:?} {spec:?}"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_sparse_cache_hits_require_equal_fingerprints() {
    // serving contract: one cache entry per sparsity fingerprint; a hit
    // returns the memoized plan bit-for-bit, and any spec difference
    // (kind, block, density, seed) is a distinct entry
    let arch = IpuArch::gc200();
    let cache = PlanCache::new(512);
    check_default("sparse cache keyed by fingerprint", |rng, size| {
        let hi = size.scale(64, 1024);
        let shape = MmShape::new(
            rng.gen_usize(8, hi),
            rng.gen_usize(8, hi),
            rng.gen_usize(8, hi),
        );
        let spec = SparsitySpec::new(
            *rng.choose(&PatternKind::all()),
            *rng.choose(&BLOCK_SIZES),
            0.05 + 0.95 * rng.next_f64(),
            rng.gen_range(0, 3),
        );
        let before = cache.stats();
        let cold = cache.get_or_plan_sparse(&arch, shape, spec);
        let warm = cache.get_or_plan_sparse(&arch, shape, spec);
        let after = cache.stats();
        prop_assert!(
            after.hits >= before.hits + 1,
            "second identical lookup must hit for {shape:?} {spec:?}"
        );
        match (cold, warm) {
            (Ok(c), Ok(w)) => {
                prop_assert!(
                    c.cost.total_cycles == w.cost.total_cycles
                        && c.partition() == w.partition(),
                    "hit returned a different plan for {shape:?} {spec:?}"
                );
            }
            (Err(_), Err(_)) => {}
            _ => prop_assert!(false, "hit and cold verdicts diverge for {shape:?}"),
        }
        // a different seed is a different fingerprint: must not hit
        let other = SparsitySpec { seed: spec.seed + 17, ..spec };
        prop_assert!(
            spec.fingerprint() != other.fingerprint(),
            "fingerprint ignored the seed"
        );
        let misses_before = cache.stats().misses;
        let _ = cache.get_or_plan_sparse(&arch, shape, other);
        prop_assert!(
            cache.stats().misses == misses_before + 1,
            "different fingerprint must miss for {shape:?}"
        );
        Ok(())
    });
}

#[test]
fn prop_oracle_matches_block_decomposition_in_pure_rust() {
    // the runtime's decomposition logic, replayed without PJRT: splitting
    // the reduction and accumulating must equal the direct oracle
    check_default("oracle decomposition", |rng, size| {
        let m = rng.gen_usize(1, size.scale(2, 24));
        let n = rng.gen_usize(2, size.scale(2, 24).max(2));
        let k = rng.gen_usize(1, size.scale(2, 24));
        let a = ipumm::util::matrix::Matrix::random(m, n, rng.next_u64());
        let b = ipumm::util::matrix::Matrix::random(n, k, rng.next_u64());
        let whole = a.matmul_oracle(&b);

        let split = rng.gen_usize(1, n - 1);
        let a1 = a.block_padded(0, 0, m, split);
        let a2 = a.block_padded(0, split, m, n - split);
        let b1 = b.block_padded(0, 0, split, k);
        let b2 = b.block_padded(split, 0, n - split, k);
        let mut acc = a1.matmul_oracle(&b1);
        let part2 = a2.matmul_oracle(&b2);
        for i in 0..acc.data.len() {
            acc.data[i] += part2.data[i];
        }
        prop_assert!(
            acc.allclose(&whole, 1e-4 * n as f32),
            "decomposition err {}",
            acc.max_abs_diff(&whole)
        );
        Ok(())
    });
}

/// Reference "full evaluator" search: walk the exact candidate
/// enumeration the planner uses, admit by the memory bill, price every
/// survivor with the **full** `CostModel::evaluate`, first-found-wins on
/// ties — the pre-staged algorithm the staged search must reproduce
/// bit-for-bit (winner, PlanCost, and the search statistic).
fn reference_full_search(arch: &IpuArch, shape: MmShape) -> (Option<PlanCost>, usize) {
    let model = CostModel::new(arch);
    let mut best: Option<PlanCost> = None;
    let mut valid = 0usize;
    for_each_candidate(shape, arch.tiles, |part| {
        valid += 1;
        if model.tile_bytes(shape, part) <= arch.tile_sram_bytes {
            let cost = model.evaluate(shape, part);
            let better = match &best {
                None => true,
                Some(b) => cost.total_cycles < b.total_cycles,
            };
            if better {
                best = Some(cost);
            }
        }
        false
    });
    (best, valid)
}

#[test]
fn prop_staged_search_matches_full_evaluate_winner() {
    // tentpole acceptance: the staged (cycles-only, early-exit,
    // winner-materialized-last) search returns the same Plan AND the
    // same full PlanCost as pricing every candidate with the full
    // evaluator — on both paper architectures
    for arch in [IpuArch::gc200(), IpuArch::gc2()] {
        check("staged == full evaluator", PropConfig { cases: 12, base_seed: 0x57A6ED }, |rng, size| {
            let hi = size.scale(96, 3800);
            let shape = MmShape::new(
                rng.gen_usize(1, hi),
                rng.gen_usize(1, hi),
                rng.gen_usize(1, hi),
            );
            let (reference, valid) = reference_full_search(&arch, shape);
            match (search(&arch, shape), reference) {
                (Ok(plan), Some(want)) => {
                    prop_assert!(
                        plan.cost == want,
                        "staged PlanCost diverges for {shape:?} on {}: {:?} vs {:?}",
                        arch.name,
                        plan.cost,
                        want
                    );
                    prop_assert!(
                        plan.candidates_evaluated == valid,
                        "search statistic {} != enumeration count {valid}",
                        plan.candidates_evaluated
                    );
                }
                (Err(_), None) => {}
                (got, want) => prop_assert!(
                    false,
                    "verdicts diverge for {shape:?} on {}: search {:?} vs reference {:?}",
                    arch.name,
                    got.map(|p| p.cost.partition),
                    want.map(|c| c.partition)
                ),
            }
            Ok(())
        });
    }
}

#[test]
fn prop_search_workers_bit_identical_incl_budget_exhausted() {
    // determinism under the governor: workers {1, 2, 7} and a
    // budget-exhausted request (every permit held elsewhere, so the
    // grant degrades to 1) all return bit-identical plans on 24 random
    // shapes spanning small to past-the-wall
    let arch = IpuArch::gc200();
    let mut rng = Rng::new(0x60E63);
    for case in 0..24usize {
        let hi = 64 + 160 * case;
        let shape = MmShape::new(
            rng.gen_usize(1, hi),
            rng.gen_usize(1, hi),
            rng.gen_usize(1, hi),
        );
        let config = CostConfig::default();
        let serial = search_with_workers(&arch, shape, config, 1);
        let mut variants = vec![
            search_with_workers(&arch, shape, config, 2),
            search_with_workers(&arch, shape, config, 7),
        ];
        {
            let _hog = ThreadBudget::global().acquire(usize::MAX - 1);
            variants.push(search_with_workers(&arch, shape, config, 7));
        }
        for (vi, variant) in variants.iter().enumerate() {
            match (&serial, variant) {
                (Ok(s), Ok(v)) => {
                    assert_eq!(s.cost, v.cost, "{shape:?} variant {vi}");
                    assert_eq!(
                        s.candidates_evaluated, v.candidates_evaluated,
                        "{shape:?} variant {vi}"
                    );
                }
                (Err(a), Err(b)) => assert_eq!(a, b, "{shape:?} variant {vi}"),
                _ => panic!("verdicts diverge for {shape:?} variant {vi}"),
            }
        }
    }
}

#[test]
fn prop_search_bit_identical_with_recorder_enabled() {
    // observability neutrality: instrumentation is write-only, so the
    // dense staged search and the sparse past-the-wall search must
    // return bit-identical plans (or identical OOM statistics) with the
    // global trace recorder enabled vs disabled, at workers {1, 4}.
    // This test shares the process-global toggle with the serve
    // neutrality test below through OBS_GATE: lib unit tests only ever
    // exercise the disabled path, and this binary's other tests are
    // neutrality-safe by the very property proven here.
    let _gate = OBS_GATE.lock().unwrap_or_else(|e| e.into_inner());
    let arch = IpuArch::gc200();
    let config = CostConfig::default();
    let mut rng = Rng::new(0x0B5E);
    for case in 0..6usize {
        let hi = 64 + 520 * case; // small squares up to past-the-wall
        let shape = MmShape::new(
            rng.gen_usize(1, hi),
            rng.gen_usize(1, hi),
            rng.gen_usize(1, hi),
        );
        for workers in [1usize, 4] {
            ipumm::obs::disable();
            let plain = search_with_workers(&arch, shape, config, workers);
            ipumm::obs::enable();
            let traced = search_with_workers(&arch, shape, config, workers);
            ipumm::obs::disable();
            let data = ipumm::obs::take();
            match (&plain, &traced) {
                (Ok(p), Ok(t)) => {
                    assert_eq!(p.cost, t.cost, "{shape:?} workers {workers}");
                    assert_eq!(
                        p.candidates_evaluated, t.candidates_evaluated,
                        "{shape:?} workers {workers}"
                    );
                }
                (Err(a), Err(b)) => assert_eq!(a, b, "{shape:?} workers {workers}"),
                _ => panic!("traced and plain verdicts diverge for {shape:?}"),
            }
            // the traced run must actually have recorded planner spans
            // (whole-search span on track "planner", per-stripe spans on
            // planner/wN) and the whole-search counters
            assert!(
                data.spans.iter().any(|s| s.track.starts_with("planner")),
                "no planner spans recorded for {shape:?} workers {workers}"
            );
            assert!(
                data.counters.contains_key("planner.candidates.enumerated"),
                "no planner counters recorded for {shape:?} workers {workers}"
            );
        }
    }
    let mut rng = Rng::new(0x0B5E5);
    for case in 0..4usize {
        let shape = MmShape::new(
            3600 + rng.gen_usize(0, 1800),
            3600 + rng.gen_usize(0, 1800),
            3600 + rng.gen_usize(0, 1800),
        );
        let density = [0.1, 0.2][case % 2];
        let kind = PatternKind::all()[case % 3];
        let pattern =
            BlockPattern::for_shape(SparsitySpec::new(kind, 8, density, case as u64), shape);
        for workers in [1usize, 4] {
            ipumm::obs::disable();
            let plain =
                sparse_search_past_dense_wall_with_workers(&arch, shape, &pattern, config, workers);
            ipumm::obs::enable();
            let traced =
                sparse_search_past_dense_wall_with_workers(&arch, shape, &pattern, config, workers);
            ipumm::obs::disable();
            let data = ipumm::obs::take();
            match (&plain, &traced) {
                (Ok(p), Ok(t)) => {
                    assert_eq!(p.partition(), t.partition(), "{shape:?} workers {workers}");
                    assert_eq!(
                        p.cost.total_cycles, t.cost.total_cycles,
                        "{shape:?} workers {workers}"
                    );
                    assert_eq!(
                        p.candidates_evaluated, t.candidates_evaluated,
                        "{shape:?} workers {workers}"
                    );
                    assert_eq!(p.nnz_elems, t.nnz_elems, "{shape:?} workers {workers}");
                }
                (Err(a), Err(b)) => assert_eq!(a, b, "{shape:?} workers {workers}"),
                _ => panic!("traced and plain sparse verdicts diverge for {shape:?}"),
            }
            assert!(
                data.spans
                    .iter()
                    .any(|s| s.track == "planner" || s.track.starts_with("sparse")),
                "no sparse-search spans recorded for {shape:?} workers {workers}"
            );
        }
    }
    // leave the global recorder off and drained for any test that follows
    ipumm::obs::disable();
    let _ = ipumm::obs::take();
}

#[test]
fn prop_sparse_past_wall_workers_bit_identical_incl_budget_exhausted() {
    // the sharded past-the-wall sparse search: workers {1, 2, 7,
    // budget-exhausted} return bit-identical SparsePlans (or identical
    // OOM statistics) on 12 random past-the-dense-wall shapes
    let arch = IpuArch::gc200();
    let mut rng = Rng::new(0x5BA23E);
    let config = CostConfig::default();
    for case in 0..12usize {
        // >3584-class squares and skews, randomly densified low enough
        // that many (not all) plan under the CSR bill
        let m = 3600 + rng.gen_usize(0, 1800);
        let n = 3600 + rng.gen_usize(0, 1800);
        let k = if case % 3 == 0 { rng.gen_usize(512, 2048) } else { 3600 + rng.gen_usize(0, 1800) };
        let shape = MmShape::new(m, n, k);
        let density = [0.1, 0.2, 0.3][case % 3];
        let kind = PatternKind::all()[case % 3];
        let pattern = BlockPattern::for_shape(SparsitySpec::new(kind, 8, density, case as u64), shape);
        let serial =
            sparse_search_past_dense_wall_with_workers(&arch, shape, &pattern, config, 1);
        let mut variants = vec![
            sparse_search_past_dense_wall_with_workers(&arch, shape, &pattern, config, 2),
            sparse_search_past_dense_wall_with_workers(&arch, shape, &pattern, config, 7),
        ];
        {
            let _hog = ThreadBudget::global().acquire(usize::MAX - 1);
            variants.push(sparse_search_past_dense_wall_with_workers(
                &arch, shape, &pattern, config, 7,
            ));
        }
        for (vi, variant) in variants.iter().enumerate() {
            match (&serial, variant) {
                (Ok(s), Ok(v)) => {
                    assert_eq!(s.partition(), v.partition(), "{shape:?} variant {vi}");
                    assert_eq!(s.cost.total_cycles, v.cost.total_cycles, "{shape:?} v{vi}");
                    assert_eq!(s.cost.compute_cycles, v.cost.compute_cycles, "{shape:?} v{vi}");
                    assert_eq!(s.cost.exchange_cycles, v.cost.exchange_cycles, "{shape:?} v{vi}");
                    assert_eq!(
                        s.cost.sparse_tile_bytes, v.cost.sparse_tile_bytes,
                        "{shape:?} v{vi}"
                    );
                    assert_eq!(
                        s.candidates_evaluated, v.candidates_evaluated,
                        "{shape:?} v{vi}"
                    );
                    assert_eq!(s.nnz_elems, v.nnz_elems, "{shape:?} v{vi}");
                }
                (Err(a), Err(b)) => assert_eq!(a, b, "{shape:?} variant {vi}"),
                _ => panic!("sparse verdicts diverge for {shape:?} variant {vi}"),
            }
        }
    }
}

#[test]
fn prop_served_trace_bit_identical_with_metrics_enabled() {
    // streaming-metrics acceptance: the sketch/window/export pipeline is
    // write-only end to end — a served trace returns identical
    // service-visible outcomes (request ids, buckets, backends, OOM
    // verdicts, model device seconds, plan-cache population) with the
    // global recorder enabled vs disabled, at workers 1 and 4.
    // Wall-clock fields (queue_seconds, batch composition) are
    // timing-dependent at workers > 1 and excluded by design.
    let _gate = OBS_GATE.lock().unwrap_or_else(|e| e.into_inner());
    let shapes: Vec<MmShape> = TraceSpec::paper_mix(48, 7)
        .jobs
        .into_iter()
        .map(|(_, s)| s)
        .collect();
    for workers in [1usize, 4] {
        let config = ServiceConfig { workers: Some(workers), ..ServiceConfig::default() };
        ipumm::obs::disable();
        let _ = ipumm::obs::take();
        let plain_svc = MmService::new(config.clone());
        let plain = plain_svc.serve_trace(&shapes);
        ipumm::obs::enable();
        let traced_svc = MmService::new(config);
        let traced = traced_svc.serve_trace(&shapes);
        ipumm::obs::disable();
        let data = ipumm::obs::take();
        assert_eq!(plain.requests.len(), traced.requests.len(), "workers {workers}");
        for (p, t) in plain.requests.iter().zip(&traced.requests) {
            assert_eq!(p.id, t.id, "workers {workers}");
            assert_eq!(p.bucket, t.bucket, "req {} workers {workers}", p.id);
            assert_eq!(p.backend, t.backend, "req {} workers {workers}", p.id);
            assert_eq!(p.oom, t.oom, "req {} workers {workers}", p.id);
            assert_eq!(
                p.device_seconds.to_bits(),
                t.device_seconds.to_bits(),
                "req {} workers {workers}",
                p.id
            );
        }
        assert_eq!(
            plain_svc.cache().len(),
            traced_svc.cache().len(),
            "cache population diverges at workers {workers}"
        );
        // the traced run really streamed into the global sketches: every
        // served request folded one latency sample into the merged
        // per-worker sketches
        let streamed = data
            .histograms
            .get("serve.latency_seconds")
            .map(|s| s.count())
            .unwrap_or(0);
        assert_eq!(
            streamed,
            traced.requests.len() as u64,
            "global latency sketch short at workers {workers}"
        );
    }
    // leave the global recorder off and drained for any test that follows
    ipumm::obs::disable();
    let _ = ipumm::obs::take();
}

#[test]
fn prop_recorder_histogram_memory_is_bounded_by_buckets() {
    // acceptance: recorder histogram memory is O(buckets), not
    // O(samples) — a 120k-sample stream spanning nine decades folds into
    // a few tens of KiB of sketch, and the overhead report counts every
    // sample. Uses a local Recorder, so no global-toggle gate is needed.
    let rec = Recorder::new();
    let mut rng = Rng::new(0x51C7);
    let samples = 120_000usize;
    for _ in 0..samples {
        // log-uniform across 1ns..1s — worst case for bucket spread
        rec.observe("lat", 1e-9 * (20.7 * rng.next_f64()).exp());
    }
    let data = rec.take();
    let sketch = &data.histograms["lat"];
    assert_eq!(sketch.count(), samples as u64);
    let overhead = data.overhead();
    assert_eq!(overhead.histogram_samples, samples as u64);
    assert_eq!(overhead.sketch_bytes, sketch.memory_bytes());
    // raw retention would be 8 B x 120k = 960 KB; the sketch stays under
    // 64 KiB no matter how long the stream runs (bucket count depends on
    // the value range, never on the sample count)
    assert!(
        sketch.memory_bytes() < 64 * 1024,
        "sketch grew to {} B for {samples} samples",
        sketch.memory_bytes()
    );
    let buckets_before = sketch.buckets();
    let mut more = sketch.clone();
    let mut rng = Rng::new(0x51C8);
    for _ in 0..samples {
        more.observe(1e-9 * (20.7 * rng.next_f64()).exp());
    }
    assert_eq!(
        more.buckets(),
        buckets_before,
        "bucket count must saturate once the value range is covered"
    );
}

#[test]
fn prop_windowed_sketches_recombine_to_the_exact_summary() {
    // satellite cross-check: per-window sketches merged back over every
    // window must (1) agree bit-for-bit with a single sketch fed the
    // whole stream — merge is bucket-count addition, and quantiles
    // depend only on counts/min/max — and (2) agree with the exact
    // sorted-sample `Summary` within the sketch's documented relative
    // error. Constant, bimodal, and seeded log-uniform streams cover
    // degenerate, clustered, and spread distributions.
    let streams: [(&str, Vec<f64>); 3] = [
        ("constant", vec![0.5; 10_000]),
        (
            "bimodal",
            (0..10_000)
                .map(|i| if i % 5 == 0 { 1.0 } else { 1e-3 })
                .collect(),
        ),
        ("log-uniform", {
            let mut rng = Rng::new(0xD15C);
            (0..10_000).map(|_| 1e-6 * (13.8 * rng.next_f64()).exp()).collect()
        }),
    ];
    for (label, latencies) in &streams {
        let events: Vec<MetricEvent> = latencies
            .iter()
            .enumerate()
            .map(|(i, &v)| MetricEvent {
                pos: i as u64,
                class: if i % 2 == 0 { "a" } else { "b" }.to_string(),
                latency_s: v,
                cache_lookup: false,
                cache_hit: false,
                queue_depth: 0,
                oom: false,
            })
            .collect();
        // width 997 does not divide 10_000: the last window is ragged
        let windows = windowed(&events, WindowSpec::tumbling(997));
        assert_eq!(windows.len(), 11, "{label}");
        let mut merged = QuantileSketch::new();
        for w in &windows {
            merged.merge(&w.merged_latency());
        }
        let mut direct = QuantileSketch::new();
        for &v in latencies.iter() {
            direct.observe(v);
        }
        // (1) recombination is lossless on everything quantiles read
        assert_eq!(merged.count(), direct.count(), "{label}");
        assert_eq!(merged.min().to_bits(), direct.min().to_bits(), "{label}");
        assert_eq!(merged.max().to_bits(), direct.max().to_bits(), "{label}");
        for q in [0.0, 0.25, 0.5, 0.9, 0.95, 0.99, 0.999, 1.0] {
            assert_eq!(
                merged.quantile(q).to_bits(),
                direct.quantile(q).to_bits(),
                "{label} q={q}"
            );
        }
        // (2) the sketch tracks the exact whole-run Summary within its
        // documented relative error (1.05 slack covers the bucket
        // representative sitting anywhere inside the bucket)
        let exact = Summary::of(latencies);
        let tol = |v: f64| merged.relative_error() * 1.05 * v.abs() + 1e-12;
        for (q, want) in [
            (0.5, exact.median),
            (0.95, exact.p95),
            (0.99, exact.p99),
            (0.999, exact.p999),
        ] {
            let got = merged.quantile(q);
            assert!(
                (got - want).abs() <= tol(want),
                "{label} q={q}: sketch {got} vs exact {want}"
            );
        }
        assert_eq!(merged.count(), exact.n as u64, "{label}");
        assert!(
            (merged.mean() - exact.mean).abs() <= 1e-9 * exact.mean.abs() + 1e-15,
            "{label}: sketch mean {} vs exact {}",
            merged.mean(),
            exact.mean
        );
    }
}

fn paper_shapes() -> Vec<MmShape> {
    TraceSpec::paper_mix(48, 7).jobs.into_iter().map(|(_, s)| s).collect()
}

#[test]
fn prop_fault_layer_off_is_bit_identical_to_passthrough() {
    // fault-tolerance acceptance (crown jewel): `FaultPlan::none()` plus
    // an *active* policy (deadline, retries, breaker armed) must leave
    // the served trace bit-identical to the passthrough path — same ids,
    // buckets, backends, OOM verdicts, device-second bits, and plan-cache
    // population — at workers 1 and 4. The guard rails only change
    // behavior when a fault actually fires.
    let shapes = paper_shapes();
    for workers in [1usize, 4] {
        let plain_svc =
            MmService::new(ServiceConfig { workers: Some(workers), ..ServiceConfig::default() });
        let plain = plain_svc.serve_trace(&shapes);
        let guarded_svc = MmService::new(ServiceConfig {
            workers: Some(workers),
            faults: FaultPlan::none(),
            fault_policy: FaultPolicy {
                deadline_s: Some(600.0),
                retry: RetryPolicy::standard(3),
                breaker: BreakerConfig::standard(),
            },
            ..ServiceConfig::default()
        });
        let guarded = guarded_svc.serve_trace(&shapes);
        assert_eq!(plain.requests.len(), guarded.requests.len(), "workers {workers}");
        for (p, g) in plain.requests.iter().zip(&guarded.requests) {
            assert_eq!(p.id, g.id, "workers {workers}");
            assert_eq!(p.bucket, g.bucket, "req {} workers {workers}", p.id);
            assert_eq!(p.backend, g.backend, "req {} workers {workers}", p.id);
            assert_eq!(p.oom, g.oom, "req {} workers {workers}", p.id);
            assert_eq!(
                p.device_seconds.to_bits(),
                g.device_seconds.to_bits(),
                "req {} workers {workers}",
                p.id
            );
            assert!(g.outcome.is_served(), "req {} workers {workers}", p.id);
            assert_eq!(g.attempts, 1, "req {} workers {workers}", p.id);
            assert_eq!(g.retry_seconds.to_bits(), 0.0f64.to_bits(), "req {}", p.id);
        }
        assert_eq!(
            plain_svc.cache().len(),
            guarded_svc.cache().len(),
            "cache population diverges at workers {workers}"
        );
        assert!(guarded.breaker_transitions.is_empty(), "workers {workers}");
        assert_eq!(guarded.injected_faults, 0, "workers {workers}");
    }
}

#[test]
fn prop_fault_outcomes_identical_across_runs_and_worker_counts() {
    // determinism under faults: the same seed + profile produces the
    // same outcome, backend, attempt count, and retry/device-second bits
    // for every request — across repeated runs AND across worker counts.
    // Faults are resolved in request-id order before workers fan out, so
    // thread scheduling cannot reach them.
    let shapes = paper_shapes();
    let profile = FaultProfile::by_name("mixed").expect("known profile");
    let mut baseline: Option<Vec<(u64, RequestOutcome, String, u32, u64, u64, bool)>> = None;
    for workers in [1usize, 4] {
        for rep in 0..2 {
            let svc = MmService::new(ServiceConfig {
                workers: Some(workers),
                faults: FaultPlan::seeded(0xC0FFEE, profile.clone()),
                fault_policy: FaultPolicy::standard().with_deadline(0.5),
                ..ServiceConfig::default()
            });
            let report = svc.serve_trace(&shapes);
            let stats = report.fault_stats();
            assert_eq!(
                stats.served + stats.degraded + stats.shed + stats.panicked,
                shapes.len(),
                "outcome accounting must balance (workers {workers} rep {rep})"
            );
            let got: Vec<_> = report
                .requests
                .iter()
                .map(|r| {
                    (
                        r.id,
                        r.outcome,
                        r.backend.clone(),
                        r.attempts,
                        r.retry_seconds.to_bits(),
                        r.device_seconds.to_bits(),
                        r.oom,
                    )
                })
                .collect();
            match &baseline {
                None => baseline = Some(got),
                Some(want) => {
                    assert_eq!(want, &got, "outcomes diverged at workers {workers} rep {rep}")
                }
            }
        }
    }
}

#[test]
fn prop_retried_successes_carry_first_try_bits() {
    // a request that fails transiently and then succeeds must return the
    // exact answer bits of a fault-free run: retries re-run the same
    // deterministic model, they never perturb the result.
    let reqs: Vec<ChaosRequest> = TraceSpec::paper_mix(48, 7)
        .jobs
        .into_iter()
        .enumerate()
        .map(|(i, (_, s))| (i as u64, s, None))
        .collect();
    let clean_svc = MmService::new(ServiceConfig {
        workers: Some(1),
        faults: FaultPlan::none(),
        fault_policy: FaultPolicy::standard(),
        ..ServiceConfig::default()
    });
    let (clean, _) = clean_svc.resolve_requests(&reqs);
    let faulty_svc = MmService::new(ServiceConfig {
        workers: Some(1),
        faults: FaultPlan::seeded(5, FaultProfile::transient(300)),
        fault_policy: FaultPolicy::standard(),
        ..ServiceConfig::default()
    });
    let (faulty, _) = faulty_svc.resolve_requests(&reqs);
    assert_eq!(clean.len(), faulty.len());
    let tflops_bits = |run: &Option<ipumm::coordinator::device::RunOutcome>| {
        run.as_ref().and_then(|r| r.tflops()).map(f64::to_bits)
    };
    let mut retried_successes = 0usize;
    for (c, f) in clean.iter().zip(&faulty) {
        assert_eq!(c.id, f.id);
        if f.outcome.is_served() && f.backend == c.backend {
            assert_eq!(
                c.device_seconds.to_bits(),
                f.device_seconds.to_bits(),
                "req {}: a retried success must carry first-try seconds",
                c.id
            );
            assert_eq!(tflops_bits(&c.run), tflops_bits(&f.run), "req {}", c.id);
            assert_eq!(c.oom, f.oom, "req {}", c.id);
            retried_successes += (f.attempts > 1) as usize;
        }
    }
    assert!(
        retried_successes > 0,
        "a 30% transient profile over 48 requests must retry-and-recover at least once"
    );
}

#[test]
fn prop_fault_counters_are_write_only_and_zero_cost_when_off() {
    // the role-8/9 neutrality invariant extended to the fault layer: the
    // retry/shed/degraded counters and the retry-backoff histogram are
    // write-only — a faulted trace returns identical outcome bits with
    // the global recorder on or off, and the counters only materialize
    // while it is on.
    let _gate = OBS_GATE.lock().unwrap_or_else(|e| e.into_inner());
    let shapes = paper_shapes();
    let config = || ServiceConfig {
        workers: Some(2),
        faults: FaultPlan::seeded(11, FaultProfile::transient(250)),
        fault_policy: FaultPolicy::standard().with_deadline(600.0),
        ..ServiceConfig::default()
    };
    ipumm::obs::disable();
    let _ = ipumm::obs::take();
    let plain = MmService::new(config()).serve_trace(&shapes);
    ipumm::obs::enable();
    let traced = MmService::new(config()).serve_trace(&shapes);
    ipumm::obs::disable();
    let data = ipumm::obs::take();
    assert_eq!(plain.requests.len(), traced.requests.len());
    for (p, t) in plain.requests.iter().zip(&traced.requests) {
        assert_eq!(p.id, t.id);
        assert_eq!(p.outcome, t.outcome, "req {}", p.id);
        assert_eq!(p.attempts, t.attempts, "req {}", p.id);
        assert_eq!(p.retry_seconds.to_bits(), t.retry_seconds.to_bits(), "req {}", p.id);
        assert_eq!(p.device_seconds.to_bits(), t.device_seconds.to_bits(), "req {}", p.id);
    }
    let stats = traced.fault_stats();
    assert!(stats.retries > 0, "a 25% transient profile must retry");
    // the traced run streamed the fault counters: one `serve.retries`
    // tick and one backoff histogram sample per backoff taken (a
    // degraded request's final failed attempt backs off nowhere, so the
    // counter is bounded by — not equal to — total extra attempts)
    let retry_counter = data.counters.get("serve.retries").copied().unwrap_or(0);
    assert!(retry_counter > 0, "retries must stream into the global counter");
    assert!(retry_counter <= stats.retries, "backoffs cannot exceed extra attempts");
    let backoffs = data
        .histograms
        .get("serve.retry_backoff_seconds")
        .map(|s| s.count())
        .unwrap_or(0);
    assert_eq!(backoffs, retry_counter, "every counted retry observed one backoff sample");
    // leave the global recorder off and drained for any test that follows
    ipumm::obs::disable();
    let _ = ipumm::obs::take();
}

#[test]
fn prop_shrinker_reduces_a_failing_trace_to_the_culprit_request() {
    // seeded fault-scenario generation + shrinking (ROADMAP §5): the IPU
    // is dark exactly for request id 7; an IPU-only policy with no
    // retries must shed it. The ddmin shrinker has to reduce the
    // 48-request trace to exactly that (request, fault) pair — original
    // id and shape preserved, because fault draws are id-keyed and
    // independent, so removing requests never perturbs the survivors.
    let reqs: Vec<ChaosRequest> = TraceSpec::paper_mix(48, 7)
        .jobs
        .into_iter()
        .enumerate()
        .map(|(i, (_, s))| (i as u64, s, None))
        .collect();
    let profile = FaultProfile { ipu_outages: vec![(7, 8)], ..FaultProfile::none() };
    let plan = FaultPlan::seeded(3, profile);
    let svc = MmService::new(ServiceConfig {
        workers: Some(1),
        policy: DispatchPolicy::IpuOnly,
        faults: plan.clone(),
        fault_policy: FaultPolicy {
            deadline_s: None,
            retry: RetryPolicy::none(),
            breaker: BreakerConfig::disabled(),
        },
        ..ServiceConfig::default()
    });
    let fails = |subset: &[ChaosRequest]| {
        let (res, _) = svc.resolve_requests(subset);
        res.iter().any(|r| r.outcome.is_shed())
    };
    assert!(fails(&reqs), "the full trace must exhibit the failure");
    let minimal = shrink_failing(&reqs, &fails);
    assert_eq!(minimal.len(), 1, "exactly one culprit request");
    assert_eq!(minimal[0].0, 7, "the culprit keeps its original id through shrinking");
    assert_eq!(minimal[0].1, reqs[7].1, "the culprit keeps its original shape");
    let label = describe_minimal(&plan, &minimal[0]);
    assert!(
        label.contains("request 7") && label.contains("unavailable"),
        "describe_minimal must name the (request, fault) pair: {label}"
    );
}
