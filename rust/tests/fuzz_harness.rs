//! Integration suite for the generative fuzz harness (crate role 12):
//! the seeded `analysis::mutate` trip-wire must be found and shrunk to a
//! 1-minimal counterexample, replay lines must be byte-stable across
//! runs and worker counts, and the obs-identity invariant (the one fuzz
//! path that toggles the process-global recorder) is exercised here, in
//! its own binary, so lib unit tests keep the recorder disabled.

use std::process::Command;

use ipumm::analysis::mutate::MutationClass;
use ipumm::fuzz::{
    check_scenario, fuzz, mutation_probe_scenario, scenario_fails, shrink_candidates,
    HarnessConfig, Scenario,
};

fn mutate_cfg(class: MutationClass) -> HarnessConfig {
    HarnessConfig { mutate: Some((class, 1)) }
}

const ONLY: Option<&str> = Some("verify-clean");

/// For every mutation class: the harness finds the seeded break, shrinks
/// it, and the result is 1-minimal — no single structural shrink step
/// (trace removal, shape halve/decrement, spec drop, policy/worker/arch
/// simplification) still reproduces the failure. Golden structural pins
/// keep the minimal counterexample's shape class stable, and the replay
/// line reproduces the failure deterministically.
#[test]
fn every_mutate_class_is_found_shrunk_to_one_minimal_and_replayable() {
    for class in MutationClass::ALL {
        let cfg = mutate_cfg(class);
        let report = fuzz(1, 1, ONLY, &cfg);
        let f = report
            .failure
            .unwrap_or_else(|| panic!("[{}] must be found by the probe", class.name()));
        assert_eq!(f.invariant, "verify-clean", "[{}]", class.name());

        // 1-minimality: every remaining shrink candidate passes
        assert!(scenario_fails(&f.minimal, &cfg, ONLY), "[{}] minimal must still fail", class.name());
        for cand in shrink_candidates(&f.minimal) {
            assert!(
                !scenario_fails(&cand, &cfg, ONLY),
                "[{}] not 1-minimal: candidate {} still fails",
                class.name(),
                cand.to_line(),
            );
        }

        // golden structural pins: a single dense request on the canonical
        // unperturbed GC200, no faults, no policy, serial workers
        let m = &f.minimal;
        assert_eq!(m.trace.len(), 1, "[{}] {}", class.name(), f.replay);
        assert!(m.trace[0].2.is_none(), "[{}] stays dense", class.name());
        assert_eq!(m.profile, "none", "[{}]", class.name());
        assert_eq!((m.plan_workers, m.serve_workers), (1, 1), "[{}]", class.name());
        assert_eq!(m.arch_perturb, 0, "[{}]", class.name());
        assert_eq!(m.deadline_us, None, "[{}]", class.name());
        assert_eq!(m.retries, 0, "[{}]", class.name());
        let prefix = "v1;arch=gc200~0;pw=1;sw=1;prof=none;fseed=0;dl=none;retry=0;trace=0:";
        assert!(f.replay.starts_with(prefix), "[{}] replay: {}", class.name(), f.replay);
        let dims = &f.replay[prefix.len()..];
        assert_eq!(dims.matches('x').count(), 2, "[{}] replay: {}", class.name(), f.replay);
        assert!(
            dims.chars().all(|c| c.is_ascii_digit() || c == 'x'),
            "[{}] replay: {}",
            class.name(),
            f.replay
        );

        // the culprit report names the class and its expected rule
        assert!(f.minimal_detail.contains(class.name()), "[{}] {}", class.name(), f.minimal_detail);
        assert!(
            f.minimal_detail.contains(class.expected_rule()),
            "[{}] detail must name rule '{}': {}",
            class.name(),
            class.expected_rule(),
            f.minimal_detail
        );

        // the replay line alone reproduces the failure
        let replayed = Scenario::parse(&f.replay).expect("replay line parses");
        let rf = check_scenario(&replayed, &cfg, ONLY)
            .unwrap_or_else(|| panic!("[{}] replay must reproduce", class.name()));
        assert_eq!(rf.detail, f.minimal_detail, "[{}] replay is deterministic", class.name());
    }
}

/// Identical `--seed`/`--iters` produce byte-identical replay specs (and
/// whole JSON reports) across independent runs.
#[test]
fn identical_seeds_produce_byte_identical_replay_specs() {
    let cfg = mutate_cfg(MutationClass::OverlapSpan);
    let a = fuzz(9, 1, ONLY, &cfg);
    let b = fuzz(9, 1, ONLY, &cfg);
    let (fa, fb) = (a.failure.as_ref().unwrap(), b.failure.as_ref().unwrap());
    assert_eq!(fa.replay, fb.replay);
    assert_eq!(fa.shrink_steps, fb.shrink_steps);
    assert_eq!(a.to_json().render(), b.to_json().render(), "whole report is byte-stable");
}

/// Shrinking converges to the same minimal replay line regardless of the
/// starting scenario's worker counts: the failure predicate is
/// worker-independent (that is the plan-identity story), so the shape
/// trajectory is identical and the worker axes shrink to 1.
#[test]
fn shrinking_is_worker_count_independent() {
    let cfg = mutate_cfg(MutationClass::DropExchange);
    let serial = mutation_probe_scenario();
    let mut wide = mutation_probe_scenario();
    wide.plan_workers = 3;
    wide.serve_workers = 2;
    let (min_serial, _) = ipumm::fuzz::shrink_scenario(&serial, &cfg, "verify-clean");
    let (min_wide, _) = ipumm::fuzz::shrink_scenario(&wide, &cfg, "verify-clean");
    assert_eq!(min_serial.to_line(), min_wide.to_line());
}

/// The obs-identity invariant holds on a clean scenario. Runs here (its
/// own test binary) because it flips the process-global recorder; lib
/// unit tests only ever exercise the disabled path.
#[test]
fn obs_identity_holds_on_clean_scenario() {
    let sc = Scenario::parse(
        "v1;arch=gc200~0;pw=2;sw=2;prof=transient;fseed=7;dl=none;retry=2;trace=0:64x64x64,1:96x32x48:r8.500.3",
    )
    .unwrap();
    let f = check_scenario(&sc, &HarnessConfig::default(), Some("obs-identity"));
    assert!(f.is_none(), "{:?}", f.map(|x| x.detail));
    assert!(!ipumm::obs::enabled(), "invariant restores the disabled recorder");
}

// ---- CLI end-to-end -------------------------------------------------------

fn run(args: &[&str]) -> (String, String, bool) {
    let out = Command::new(env!("CARGO_BIN_EXE_ipumm"))
        .args(args)
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .expect("binary runs");
    (
        String::from_utf8_lossy(&out.stdout).to_string(),
        String::from_utf8_lossy(&out.stderr).to_string(),
        out.status.success(),
    )
}

#[test]
fn fuzz_cli_clean_run_exits_zero_and_writes_json() {
    let json_path = std::env::temp_dir().join("ipumm_fuzz_smoke.json");
    let _ = std::fs::remove_file(&json_path);
    let (out, err, ok) = run(&[
        "fuzz", "--seed", "7", "--iters", "3", "--invariant", "plan-identity",
        "--json", json_path.to_str().unwrap(),
    ]);
    assert!(ok, "stdout: {out}\nstderr: {err}");
    assert!(out.contains("clean"), "stdout: {out}");
    let doc = ipumm::util::json::Json::parse(&std::fs::read_to_string(&json_path).unwrap())
        .expect("fuzz JSON parses");
    assert_eq!(doc.get("clean"), Some(&ipumm::util::json::Json::Bool(true)));
    assert_eq!(doc.get("completed").and_then(|j| j.as_f64()), Some(3.0));
    let _ = std::fs::remove_file(&json_path);
}

#[test]
fn fuzz_cli_mutate_trips_and_prints_replay_line() {
    let (out, err, ok) = run(&["fuzz", "--mutate", "overlap-span", "--seed", "1", "--iters", "1"]);
    assert!(!ok, "trip-wire must exit nonzero when the mutation is found");
    assert!(out.contains("replay: ipumm fuzz --replay"), "stdout: {out}");
    assert!(out.contains("race-write-write"), "stdout: {out}");
    assert!(err.contains("trip-wire armed"), "stderr: {err}");

    // the printed replay line reproduces the failure through the CLI
    let line = out
        .lines()
        .find(|l| l.starts_with("replay: "))
        .and_then(|l| l.split('\'').nth(1))
        .expect("replay line present");
    let (rout, _, rok) =
        run(&["fuzz", "--replay", line, "--mutate", "overlap-span", "--seed", "1"]);
    assert!(!rok, "replay must reproduce the violation: {rout}");
    assert!(rout.contains("race-write-write"), "stdout: {rout}");
}

#[test]
fn fuzz_cli_rejects_bad_inputs() {
    let (_, err, ok) = run(&["fuzz", "--replay", "v1;arch=gc9~0;trace=0:8x8x8"]);
    assert!(!ok);
    assert!(err.contains("unknown arch base"), "stderr: {err}");

    let (_, err, ok) = run(&["fuzz", "--invariant", "bogus", "--iters", "1"]);
    assert!(!ok);
    assert!(err.contains("unknown invariant"), "stderr: {err}");

    let (_, err, ok) = run(&["fuzz", "--mutate", "bogus", "--iters", "1"]);
    assert!(!ok);
    assert!(err.contains("unknown mutation class"), "stderr: {err}");
}

#[test]
fn fuzz_cli_clean_replay_exits_zero() {
    let (out, _, ok) = run(&["fuzz", "--replay", "v1;arch=gc200~0;trace=0:64x64x64"]);
    assert!(ok, "a clean scenario replays clean: {out}");
    assert!(out.contains("replay clean"), "stdout: {out}");
}
