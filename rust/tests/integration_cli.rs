//! CLI integration: run the `ipumm` binary end-to-end per subcommand and
//! assert the key lines of each paper artifact appear.

use std::process::Command;

fn run(args: &[&str]) -> (String, String, bool) {
    let out = Command::new(env!("CARGO_BIN_EXE_ipumm"))
        .args(args)
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .expect("binary runs");
    (
        String::from_utf8_lossy(&out.stdout).to_string(),
        String::from_utf8_lossy(&out.stderr).to_string(),
        out.status.success(),
    )
}

#[test]
fn no_args_prints_usage_and_fails() {
    let (_, err, ok) = run(&[]);
    assert!(!ok);
    assert!(err.contains("usage: ipumm"));
}

#[test]
fn unknown_command_fails_cleanly() {
    let (_, err, ok) = run(&["frobnicate"]);
    assert!(!ok);
    assert!(err.contains("unknown command"));
}

#[test]
fn unknown_option_reports_valid_set() {
    let (_, err, ok) = run(&["fig4", "--bogus", "1"]);
    assert!(!ok);
    assert!(err.contains("unknown option --bogus"));
}

#[test]
fn table1_prints_specs() {
    let (out, _, ok) = run(&["table1"]);
    assert!(ok);
    assert!(out.contains("1472"));
    assert!(out.contains("62.6 TFlop/s"));
}

#[test]
fn fig4_small_sweep() {
    let (out, _, ok) = run(&["fig4", "--max-size", "1024", "--workers", "2"]);
    assert!(ok);
    assert!(out.contains("best/peak"));
    assert!(out.contains("IPU best"));
}

#[test]
fn vertices_prints_census() {
    let (out, _, ok) = run(&["vertices"]);
    assert!(ok);
    assert!(out.contains("31743")); // paper column
    assert!(out.contains("right-skewed"));
}

#[test]
fn plan_shows_partition_and_oom() {
    let (out, _, ok) = run(&["plan", "1024", "1024", "1024"]);
    assert!(ok);
    assert!(out.contains("pm="));
    assert!(out.contains("thread budget:"), "plan must print the effective budget");
    let (out, _, ok) = run(&["plan", "8192", "8192", "8192"]);
    assert!(ok);
    assert!(out.contains("memory wall"));
}

#[test]
fn plan_workers_request_is_deterministic() {
    // --workers is a request against the thread budget; any value must
    // print the same plan (the governed pools are bit-deterministic)
    let (w1, _, ok1) = run(&["plan", "2048", "2048", "2048", "--workers", "1"]);
    let (w4, _, ok4) = run(&["plan", "2048", "2048", "2048", "--workers", "4"]);
    assert!(ok1 && ok4);
    assert!(w1.contains("--workers request: 1"));
    assert!(w4.contains("--workers request: 4"));
    let plan_line = |s: &str| {
        s.lines()
            .find(|l| l.contains("pm="))
            .map(str::to_string)
            .expect("plan line present")
    };
    assert_eq!(plan_line(&w1), plan_line(&w4), "worker count changed the plan");
}

#[test]
fn bench_check_gates_regressions() {
    let dir = std::env::temp_dir().join("ipumm_bench_check_test");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let dir_arg = dir.to_str().unwrap();

    // missing artifacts are advisory, never a gate failure: a perf gate
    // must only go red on a confirmed regression
    let (_, err, ok) = run(&["bench-check", "--dir", dir_arg]);
    assert!(ok, "missing artifacts must not fail the gate: {err}");
    assert!(err.contains("BENCH_planner.json"), "stderr: {err}");
    assert!(err.contains("nothing gated"), "stderr: {err}");

    // malformed artifact (a crashed bench run leaves half a file): the
    // gate diagnoses and continues instead of erroring out
    std::fs::write(dir.join("BENCH_planner.json"), "{\"group\": \"planner\", \"resu").unwrap();
    let (_, err, ok) = run(&["bench-check", "--dir", dir_arg]);
    assert!(ok, "malformed artifact must not fail the gate: {err}");
    assert!(err.contains("malformed JSON"), "stderr: {err}");

    // structurally-valid JSON that is not a bench artifact: same story
    std::fs::write(dir.join("BENCH_planner.json"), "[1, 2, 3]").unwrap();
    let (_, err, ok) = run(&["bench-check", "--dir", dir_arg]);
    assert!(ok, "unusable artifact must not fail the gate: {err}");
    assert!(err.contains("skipping"), "stderr: {err}");

    // passing file: current row at parity with its frozen baseline
    let passing = r#"{"group": "planner", "results": [
        {"name": "search_baseline", "mean_s": 0.01},
        {"name": "search", "mean_s": 0.005}
    ]}"#;
    std::fs::write(dir.join("BENCH_planner.json"), passing).unwrap();
    let (out, _, ok) = run(&["bench-check", "--dir", dir_arg]);
    assert!(ok);
    assert!(out.contains("0 regressions"));
    assert!(out.contains("search"));

    // regressed file: >20% slower than the baseline fails the gate
    let regressed = r#"{"group": "sparse", "results": [
        {"name": "past_wall_baseline", "mean_s": 0.01},
        {"name": "past_wall", "mean_s": 0.013}
    ]}"#;
    std::fs::write(dir.join("BENCH_sparse.json"), regressed).unwrap();
    let (out, err, ok) = run(&["bench-check", "--dir", dir_arg]);
    assert!(!ok, "a 1.3x regression must fail the 20% gate");
    assert!(out.contains("FAIL"), "stdout: {out}");
    assert!(err.contains("regressed"), "stderr: {err}");

    // a looser tolerance admits the same file
    let (_, _, ok) = run(&["bench-check", "--dir", dir_arg, "--tolerance", "50"]);
    assert!(ok);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn serve_metrics_out_writes_prometheus_and_json() {
    let prom_path = std::env::temp_dir().join("ipumm_cli_metrics.prom");
    let prom_arg = prom_path.to_str().unwrap();
    let json_path = format!("{prom_arg}.json");
    let (out, err, ok) = run(&[
        "serve", "--jobs", "40", "--workers", "2", "--seed", "3",
        "--metrics-out", prom_arg, "--window", "10", "--slo", "p99<600s@99%",
    ]);
    assert!(ok, "stderr: {err}");
    assert!(out.contains("SLO p99<600s@99%"), "stdout: {out}");
    assert!(out.contains("metrics ->"), "stdout: {out}");

    let prom = std::fs::read_to_string(&prom_path).unwrap();
    assert!(prom.contains("# TYPE ipumm_serve_requests_total counter"));
    assert!(prom.contains("ipumm_serve_latency_seconds{"), "missing summary family");
    assert!(prom.contains("quantile=\"0.99\""));
    assert!(prom.contains("ipumm_slo_compliance"));

    // the snapshot must round-trip through the crate's own JSON parser
    // and carry the per-window timeline
    use ipumm::util::json::Json;
    let text = std::fs::read_to_string(&json_path).unwrap();
    let doc = Json::parse(&text).expect("snapshot parses");
    let timeline = doc.get("timeline").and_then(Json::items).expect("timeline array");
    assert!(!timeline.is_empty(), "no windows in snapshot");
    let w0 = &timeline[0];
    let classes = w0.get("classes").and_then(Json::items).expect("classes array");
    assert!(classes.iter().all(|c| c.get("p50").is_some() && c.get("p99").is_some()));
    let slos = doc.get("slos").and_then(Json::items).expect("slos array");
    assert_eq!(slos.len(), 1);

    let _ = std::fs::remove_file(&prom_path);
    let _ = std::fs::remove_file(&json_path);
}

#[test]
fn serve_slo_violation_exits_nonzero_but_still_exports() {
    let prom_path = std::env::temp_dir().join("ipumm_cli_metrics_violated.prom");
    let prom_arg = prom_path.to_str().unwrap();
    let json_path = format!("{prom_arg}.json");
    // no serve completes in under a nanosecond: guaranteed violation
    let (out, err, ok) = run(&[
        "serve", "--jobs", "20", "--seed", "3",
        "--metrics-out", prom_arg, "--slo", "p50<1ns@50%",
    ]);
    assert!(!ok, "an impossible SLO must fail the serve run");
    assert!(err.contains("SLO violated"), "stderr: {err}");
    assert!(out.contains("VIOLATED") || out.contains("violated"), "stdout: {out}");

    // the export happened before the gate tripped, so the snapshot can
    // feed `slo-check --snapshot` on its own
    let (out2, err2, ok2) = run(&["slo-check", "--snapshot", &json_path]);
    assert!(!ok2, "violated snapshot must fail slo-check");
    assert!(out2.contains("FAIL"), "stdout: {out2}");
    assert!(err2.contains("violated"), "stderr: {err2}");

    let _ = std::fs::remove_file(&prom_path);
    let _ = std::fs::remove_file(&json_path);
}

#[test]
fn slo_check_gates_the_demo_trace() {
    let (out, err, ok) = run(&[
        "slo-check", "--slo", "p99<600s@99%", "--jobs", "40", "--workers", "2",
    ]);
    assert!(ok, "stderr: {err}");
    assert!(out.contains("slo-check: all 1 SLO(s) met"), "stdout: {out}");

    let (_, err, ok) = run(&[
        "slo-check", "--slo", "p50<1ns@50%", "--jobs", "40", "--workers", "2",
    ]);
    assert!(!ok, "impossible SLO must exit nonzero");
    assert!(err.contains("SLO violated"), "stderr: {err}");

    // a passing snapshot gates clean through --snapshot too
    let prom_path = std::env::temp_dir().join("ipumm_cli_slo_ok.prom");
    let prom_arg = prom_path.to_str().unwrap();
    let (_, err, ok) = run(&[
        "serve", "--jobs", "20", "--seed", "3",
        "--metrics-out", prom_arg, "--slo", "p99<600s@99%",
    ]);
    assert!(ok, "stderr: {err}");
    let json_path = format!("{prom_arg}.json");
    let (out, err, ok) = run(&["slo-check", "--snapshot", &json_path]);
    assert!(ok, "stderr: {err}");
    assert!(out.contains("ok"), "stdout: {out}");
    let _ = std::fs::remove_file(&prom_path);
    let _ = std::fs::remove_file(&json_path);
}

#[test]
fn bench_check_against_gates_cross_run_drift() {
    let cur = std::env::temp_dir().join("ipumm_trend_cur");
    let prev = std::env::temp_dir().join("ipumm_trend_prev");
    for d in [&cur, &prev] {
        let _ = std::fs::remove_dir_all(d);
        std::fs::create_dir_all(d).unwrap();
    }
    let cur_arg = cur.to_str().unwrap();
    let prev_arg = prev.to_str().unwrap();

    // previous run: baseline 10ms, search 5ms (0.5x of baseline)
    std::fs::write(
        prev.join("BENCH_planner.json"),
        r#"{"group": "planner", "results": [
            {"name": "search_baseline", "mean_s": 0.01},
            {"name": "search", "mean_s": 0.005}
        ]}"#,
    )
    .unwrap();

    // current run on a 2x slower machine, same normalized ratio: the
    // raw 2x drift must NOT gate — only baseline-normalized drift does
    std::fs::write(
        cur.join("BENCH_planner.json"),
        r#"{"group": "planner", "results": [
            {"name": "search_baseline", "mean_s": 0.02},
            {"name": "search", "mean_s": 0.010}
        ]}"#,
    )
    .unwrap();
    let (out, err, ok) = run(&["bench-check", "--dir", cur_arg, "--against", prev_arg]);
    assert!(ok, "machine-speed drift must not gate; stderr: {err}");
    assert!(out.contains("baseline-normalized"), "stdout: {out}");
    assert!(out.contains("0 cross-run regressions"), "stdout: {out}");

    // genuine regression: baseline parity with prev but search 1.6x
    // slower relative to it -> the trend gate fails
    std::fs::write(
        cur.join("BENCH_planner.json"),
        r#"{"group": "planner", "results": [
            {"name": "search_baseline", "mean_s": 0.01},
            {"name": "search", "mean_s": 0.008}
        ]}"#,
    )
    .unwrap();
    let (out, err, ok) = run(&["bench-check", "--dir", cur_arg, "--against", prev_arg]);
    assert!(!ok, "1.6x normalized drift must fail the 20% trend gate");
    assert!(out.contains("FAIL"), "stdout: {out}");
    assert!(err.contains("drifted"), "stderr: {err}");

    // a looser tolerance admits the same pair
    let (_, _, ok) = run(&[
        "bench-check", "--dir", cur_arg, "--against", prev_arg, "--tolerance", "80",
    ]);
    assert!(ok);

    for d in [&cur, &prev] {
        let _ = std::fs::remove_dir_all(d);
    }
}

#[test]
fn profile_writes_json() {
    let json_path = std::env::temp_dir().join("ipumm_cli_profile.json");
    let json_arg = json_path.to_str().unwrap();
    let (out, _, ok) = run(&["profile", "512", "512", "512", "--json", json_arg]);
    assert!(ok);
    assert!(out.contains("PopVision-style profile"));
    assert!(out.contains("liveness peak"));
    let json = std::fs::read_to_string(&json_path).unwrap();
    assert!(json.contains("\"vertex_census\""));
    let _ = std::fs::remove_file(&json_path);
}

/// Real PJRT execution needs the `xla` feature and `make artifacts`.
#[cfg(feature = "xla")]
#[test]
fn run_with_real_path_verifies() {
    let (out, err, ok) = run(&["run", "200", "300", "100", "--real"]);
    assert!(ok, "stderr: {err}");
    assert!(out.contains("ipu-sim/GC200"));
    assert!(out.contains("verified"));
}

/// Without the feature, `--real` must fail fast with a pointer to it —
/// the model backends still print first.
#[cfg(not(feature = "xla"))]
#[test]
fn run_real_flag_reports_missing_feature() {
    let (out, err, ok) = run(&["run", "200", "300", "100", "--real"]);
    assert!(!ok);
    assert!(out.contains("ipu-sim/GC200"));
    assert!(err.contains("--features xla"), "stderr: {err}");
}

#[test]
fn serve_reports_cache_and_buckets() {
    let (out, _, ok) = run(&["serve", "--jobs", "60", "--workers", "2", "--seed", "3"]);
    assert!(ok);
    assert!(out.contains("hit rate"));
    assert!(out.contains("per-bucket"));
    assert!(out.contains("steady state"));
}

#[test]
fn serve_with_fault_seed_reports_fault_accounting() {
    let (out, err, ok) = run(&[
        "serve", "--jobs", "60", "--workers", "2", "--seed", "3",
        "--fault-seed", "11", "--deadline-ms", "500", "--retries", "3",
    ]);
    assert!(ok, "a 10%-transient profile must still serve cleanly; stderr: {err}");
    assert!(out.contains("fault injection: seed 11"), "stdout: {out}");
    assert!(out.contains("faults:"), "summary must carry the fault line; stdout: {out}");
    assert!(out.contains("hit rate"));
}

#[test]
fn chaos_matrix_reports_recovery_and_writes_json() {
    let json_path = std::env::temp_dir().join("ipumm_cli_chaos.json");
    let json_arg = json_path.to_str().unwrap();
    let (out, err, ok) = run(&[
        "chaos", "--jobs", "80", "--seed", "42", "--workers", "2",
        "--profiles", "transient,breaker-trip", "--json", json_arg,
    ]);
    assert!(ok, "stderr: {err}");
    assert!(out.contains("Chaos matrix"), "stdout: {out}");
    assert!(out.contains("zero lost"), "stdout: {out}");

    // the report must round-trip through the crate's own JSON parser
    use ipumm::util::json::Json;
    let text = std::fs::read_to_string(&json_path).unwrap();
    let doc = Json::parse(&text).expect("chaos report parses");
    let scenarios = doc.get("scenarios").and_then(Json::items).expect("scenarios array");
    assert_eq!(scenarios.len(), 2);
    for s in scenarios {
        assert_eq!(
            s.get("lost").and_then(Json::as_f64),
            Some(0.0),
            "no scenario may lose requests"
        );
    }
    // the breaker-trip scenario records an open->closed recovery cycle
    let trip = scenarios
        .iter()
        .find(|s| s.get("name").and_then(Json::as_str) == Some("breaker-trip"))
        .expect("breaker-trip scenario present");
    let events = trip.get("breaker").and_then(Json::items).expect("breaker events");
    assert!(
        events.iter().any(|e| e.get("to").and_then(Json::as_str) == Some("open")),
        "breaker must open during the outage"
    );
    assert_eq!(
        events.last().and_then(|e| e.get("to")).and_then(Json::as_str),
        Some("closed"),
        "breaker must re-close after the outage"
    );
    let _ = std::fs::remove_file(&json_path);
}

#[test]
fn chaos_rejects_unknown_profile() {
    let (_, err, ok) = run(&["chaos", "--jobs", "10", "--profiles", "glitchstorm"]);
    assert!(!ok);
    assert!(err.contains("unknown fault profile"), "stderr: {err}");
}

#[test]
fn sparse_prints_both_throughput_conventions() {
    let csv_path = std::env::temp_dir().join("ipumm_cli_sparse.csv");
    let csv_arg = csv_path.to_str().unwrap();
    let (out, _, ok) = run(&[
        "sparse", "--k", "1024", "--densities", "1.0,0.25", "--block", "8", "--csv", csv_arg,
    ]);
    assert!(ok);
    assert!(out.contains("thread budget:"), "sparse must print the effective budget");
    assert!(out.contains("dense-equiv"));
    assert!(out.contains("effective"));
    assert!(out.contains("density 0.25"));
    let csv = std::fs::read_to_string(&csv_path).unwrap();
    assert!(csv.starts_with("label,m,n,k,"));
    assert!(csv.lines().count() > 10);
    let _ = std::fs::remove_file(&csv_path);
}

#[test]
fn sparse_rejects_bad_block() {
    let (_, err, ok) = run(&["sparse", "--block", "32"]);
    assert!(!ok);
    assert!(err.contains("--block"), "stderr: {err}");
}

#[test]
fn ablation_lists_mechanisms() {
    let (out, _, ok) = run(&["ablation"]);
    assert!(ok);
    assert!(out.contains("full model"));
    assert!(out.contains("exchange-code-scaling"));
}

#[test]
fn trace_reports_percentiles() {
    let (out, _, ok) = run(&["trace", "--jobs", "30", "--workers", "2"]);
    assert!(ok);
    assert!(out.contains("p95"));
    assert!(out.contains("squared"));
}

#[test]
fn gc2_arch_flag_is_honored() {
    let (out, _, ok) = run(&["table1", "--arch", "gc2", "--gpu", "v100"]);
    assert!(ok);
    assert!(out.contains("GC2"));
    assert!(out.contains("V100"));
}

#[test]
fn fig5_csv_export_works() {
    let csv_path = std::env::temp_dir().join("ipumm_cli_fig5.csv");
    let csv_arg = csv_path.to_str().unwrap();
    let (_, _, ok) = run(&["fig5", "--ks", "1024", "--workers", "2", "--csv", csv_arg]);
    assert!(ok);
    let csv = std::fs::read_to_string(&csv_path).unwrap();
    assert!(csv.starts_with("backend,label"));
    assert!(csv.lines().count() > 10);
    let _ = std::fs::remove_file(&csv_path);
}
