//! Cross-module integration: each paper artifact's *shape* must hold when
//! regenerated through the full experiment drivers (DESIGN.md §4 bands).

use ipumm::arch::ipu::paper;
use ipumm::arch::{GpuArch, IpuArch};
use ipumm::coordinator::device::Backend;
use ipumm::experiments::{
    fig4, fig5, memory_study, multi_ipu_x, phases, sparse_sweep, streaming, table1, vertices,
};
use ipumm::planner::partition::MmShape;
use ipumm::sparse::pattern::PatternKind;

// ---- T1 -------------------------------------------------------------

#[test]
fn t1_table_reports_paper_specs() {
    let ascii = table1::table1(&IpuArch::gc200(), &GpuArch::a30()).to_ascii();
    for anchor in ["1472", "3584", "8832", "229376", "10.3", "150 W", "165 W"] {
        assert!(ascii.contains(anchor), "Table 1 missing '{anchor}':\n{ascii}");
    }
}

// ---- F4 -------------------------------------------------------------

#[test]
fn f4_full_reproduction_bands() {
    let r = fig4::run(&IpuArch::gc200(), &GpuArch::a30(), 6144, Some(4));

    // paper: max square 3584
    assert_eq!(r.ipu_max_square, paper::GC200_MAX_SQUARE);

    // paper: 44.2 TFlop/s best IPU (we match within 5%)
    let err = (r.ipu_best_tflops - paper::GC200_ACHIEVED_TFLOPS).abs()
        / paper::GC200_ACHIEVED_TFLOPS;
    assert!(err < 0.05, "IPU best {} vs paper 44.2", r.ipu_best_tflops);

    // paper: GPU ~9.7 (within 5%)
    assert!((r.gpu_best_tflops - 9.7).abs() / 9.7 < 0.05, "{}", r.gpu_best_tflops);

    // who-wins: IPU above GPU at every fitting size >= 512
    let ipu = Backend::IpuSim(IpuArch::gc200()).name();
    let gpu = Backend::GpuModel(GpuArch::a30()).name();
    for rec in r.metrics.for_backend(&ipu) {
        let size: usize = rec.label.parse().unwrap();
        if size < 512 {
            continue;
        }
        if let Some(ipu_t) = rec.outcome.tflops() {
            let gpu_t = r
                .metrics
                .for_backend(&gpu)
                .iter()
                .find(|g| g.label == rec.label)
                .unwrap()
                .outcome
                .tflops()
                .unwrap();
            assert!(ipu_t > gpu_t, "size {size}: {ipu_t} <= {gpu_t}");
        }
    }

    // monotone-ish rise to the wall: best is at the wall size
    let at_wall = r
        .metrics
        .for_backend(&ipu)
        .iter()
        .find(|x| x.label == "3584")
        .unwrap()
        .outcome
        .tflops()
        .unwrap();
    assert!((at_wall - r.ipu_best_tflops).abs() < 1.0);
}

#[test]
fn f4_gc2_reproduces_jia_numbers() {
    // §2.4: GC2 peaks 18.9 of 31.1 TFlop/s at 2944^2
    let r = fig4::run(&IpuArch::gc2(), &GpuArch::v100(), 4096, Some(4));
    assert!(
        (2688..=3200).contains(&r.ipu_max_square),
        "GC2 wall {}",
        r.ipu_max_square
    );
    let eff = r.ipu_best_tflops / r.ipu_peak;
    assert!((0.5..=0.78).contains(&eff), "GC2 best/peak {eff}");
}

// ---- F5 -------------------------------------------------------------

#[test]
fn f5_multiple_k_series_keep_the_pattern() {
    let r = fig5::run(&IpuArch::gc200(), &GpuArch::a30(), 22, 4, &[1024, 2048, 4096], Some(4));
    let ipu = Backend::IpuSim(IpuArch::gc200()).name();
    for k in [1024usize, 2048, 4096] {
        let (left, right) = fig5::drops(&r, &ipu, k, Some(4)).unwrap();
        assert!(
            right > left,
            "k={k}: right drop {right} should exceed left {left}"
        );
    }
}

// ---- V1 -------------------------------------------------------------

#[test]
fn v1_census_within_10pct_of_paper() {
    let rows = vertices::run(&IpuArch::gc200());
    let pairs = [
        (rows[0].vertices, paper::VERTICES_LEFT),
        (rows[1].vertices, paper::VERTICES_SQUARED),
        (rows[2].vertices, paper::VERTICES_RIGHT),
    ];
    for (ours, theirs) in pairs {
        let err = (ours as f64 - theirs as f64).abs() / theirs as f64;
        assert!(err < 0.10, "census {ours} vs paper {theirs} ({err:.2})");
    }
}

// ---- M1 -------------------------------------------------------------

#[test]
fn m1_memory_walls_and_fractions() {
    let rows = memory_study::run(&memory_study::default_archs(), Some(2));
    let gc200 = &rows[0];
    let gc2 = &rows[1];
    // paper: 17% / 35% tensor occupancy at the wall (±5 points)
    assert!((gc200.tensor_fraction - 0.17).abs() < 0.05, "{}", gc200.tensor_fraction);
    assert!((gc2.tensor_fraction - 0.35).abs() < 0.07, "{}", gc2.tensor_fraction);
    // the wall is overhead-bound: heaviest tile nearly full on both
    assert!(gc200.max_tile_fraction > 0.9);
    assert!(gc2.max_tile_fraction > 0.9);
}

// ---- P1 -------------------------------------------------------------

#[test]
fn p1_phase_profile_shape() {
    let rows = phases::run(&IpuArch::gc200(), &phases::default_shapes());
    for (row, sim) in &rows {
        // Fig. 3 has all three phases present
        assert!(row.compute > 0.0 && row.sync > 0.0 && row.exchange > 0.0);
        assert!(sim.trace.superstep_count() >= 1);
    }
    // larger squared problems have proportionally more compute
    assert!(rows[0].0.compute > rows[1].0.compute);
}

// ---- X1 / X2 ----------------------------------------------------------

#[test]
fn x1_streaming_covers_the_oom_region() {
    let rows = streaming::run(&IpuArch::gc200(), &streaming::default_sizes());
    let oom_but_streamed = rows
        .iter()
        .filter(|r| r.resident_tflops.is_none() && r.streamed.is_some())
        .count();
    assert!(oom_but_streamed >= 3, "streaming should cover the OOM region");
}

#[test]
fn x2_pod_scaling_table() {
    let rows = multi_ipu_x::run(&IpuArch::gc200(), MmShape::square(3584), &[1, 2, 4]);
    let tf: Vec<f64> = rows
        .iter()
        .map(|r| r.report.as_ref().unwrap().tflops)
        .collect();
    assert!(tf[1] > tf[0] && tf[2] > tf[1], "{tf:?}");
}

// ---- S1 -------------------------------------------------------------

#[test]
fn s1_skew_advantage_only_degrades_gracefully_under_sparsity() {
    // the question neither source paper answers alone: crossing the
    // paper's skew axis with PopSparse's density axis. Gates: density
    // 1.0 equals the dense path everywhere it fits, sparsity always
    // speeds the model up, and the memory wall is density-dependent
    // *in one direction only*: sparsity can admit shapes the dense bill
    // rejects, never the reverse
    let rows = sparse_sweep::run(
        &IpuArch::gc200(),
        22,
        4,
        2048,
        8,
        &[1.0, 0.25],
        PatternKind::Random,
        42,
        Some(2),
    );
    assert_eq!(rows.len(), 9 * 2);
    // rows come out point-major (both densities of one shape adjacent),
    // so the dense-wall cross-check needs one search per shape, not row
    for pair in rows.chunks(2) {
        assert_eq!(pair[0].shape, pair[1].shape, "rows are point-major");
        let dense_fits = ipumm::planner::search::search(&IpuArch::gc200(), pair[0].shape).is_ok();
        for r in pair {
            if r.spec.is_dense() {
                if let Some(s) = r.speedup_vs_dense {
                    assert!((s - 1.0).abs() < 1e-12, "{}: dense speedup {s}", r.label);
                }
            } else if let (Some(s), Some(eff), Some(deq)) =
                (r.speedup_vs_dense, r.effective_tflops, r.dense_equiv_tflops)
            {
                assert!(s >= 1.0, "{}: sparsity slowed the model down", r.label);
                assert!(eff <= deq + 1e-9, "{}: effective above dense-equiv", r.label);
            }
            // the wall only ever moves outward with sparsity: a fully
            // dense row mirrors the dense verdict exactly, and anything
            // fitting dense must fit at every density (CSR admission is
            // capped at the dense bill)
            if r.spec.is_dense() {
                assert_eq!(
                    dense_fits,
                    r.dense_equiv_tflops.is_some(),
                    "{}: density 1.0 must reproduce the dense verdict",
                    r.label
                );
            } else if dense_fits {
                assert!(
                    r.dense_equiv_tflops.is_some(),
                    "{}: fits dense but OOMs at lower density",
                    r.label
                );
            }
        }
    }
}

// ---- cross-cutting ----------------------------------------------------

#[test]
fn bow_outperforms_gc200_at_same_shape() {
    // the §2.1 Bow generation: same layout, higher clock
    let r200 = fig4::run(&IpuArch::gc200(), &GpuArch::a30(), 2048, Some(2));
    let rbow = fig4::run(&IpuArch::bow2000(), &GpuArch::a30(), 2048, Some(2));
    assert!(rbow.ipu_best_tflops > r200.ipu_best_tflops);
}

#[test]
fn per_watt_comparison_favors_ipu() {
    // Finding 1 corollary: at the comparison point the IPU also wins on
    // throughput/W (150 W vs 165 W, Table 1)
    let ipu = IpuArch::gc200();
    let gpu = GpuArch::a30();
    let r = fig4::run(&ipu, &gpu, 3584, Some(4));
    let ipu_per_w = r.ipu_best_tflops / ipu.power_w;
    let gpu_per_w = r.gpu_best_tflops / gpu.power_w;
    assert!(ipu_per_w > gpu_per_w);
}
