//! Static-analysis gate tests (ISSUE 9): the IR verifier must accept
//! every scheduler-produced graph, and the seeded mutation corpus must
//! be caught — each class by its expected rule id.
//!
//! The property tests drive the same `util::prop` framework as
//! prop_invariants.rs: sized random (arch, shape, sparsity, worker
//! count) samples with replayable seeds, `IPUMM_PROP_CASES` to deepen.

use ipumm::analysis::mutate::{apply, MutationClass};
use ipumm::analysis::verify::{rules, verify_dense, verify_graph, verify_sparse};
use ipumm::analysis::{lint, report_json};
use ipumm::arch::IpuArch;
use ipumm::planner::cost::CostConfig;
use ipumm::planner::partition::MmShape;
use ipumm::planner::search::{search, search_with_workers};
use ipumm::prop_assert;
use ipumm::sim::engine::SimEngine;
use ipumm::sparse::pattern::{BlockPattern, PatternKind, SparsitySpec, BLOCK_SIZES};
use ipumm::sparse::planner::sparse_search;
use ipumm::util::json::Json;
use ipumm::util::prop::{check, check_default, PropConfig, Size};
use ipumm::util::rng::Rng;

fn random_shape(rng: &mut Rng, size: Size) -> MmShape {
    let hi = size.scale(64, 4096);
    MmShape::new(
        rng.gen_usize(16, hi),
        rng.gen_usize(16, hi),
        rng.gen_usize(16, hi),
    )
}

/// Every plan the dense planner emits — any architecture, any worker
/// count — materializes into a graph the verifier accepts with zero
/// diagnostics: no races, ordered barriers, live reads, no dead
/// exchange phases, and a per-tile residency that matches the
/// planner's memory bill.
#[test]
fn prop_verifier_accepts_every_dense_planner_graph() {
    let archs = [IpuArch::gc200(), IpuArch::gc2()];
    check_default("verifier accepts dense planner graphs", |rng, size| {
        let arch = &archs[rng.gen_usize(0, 1)];
        let shape = random_shape(rng, size);
        let workers = rng.gen_usize(1, 4);
        let plan = match search_with_workers(arch, shape, CostConfig::default(), workers) {
            Ok(p) => p,
            Err(_) => return Ok(()), // OOM shapes have no graph to verify
        };
        let g = SimEngine::new(arch.clone()).build_graph(shape, &plan);
        let ds = verify_dense(arch, shape, &plan, &g);
        prop_assert!(
            ds.is_empty(),
            "verifier rejected planner graph for {shape:?} on {} ({workers} workers): {:?}",
            arch.name,
            ds
        );
        Ok(())
    });
}

/// Same acceptance property for the sparse branch: seeded sparsity
/// specs (kind x block x density), both the block-CSR A layout and the
/// dense-A fallback must verify clean — including the byte-for-byte
/// CSR residency cross-check.
#[test]
fn prop_verifier_accepts_every_sparse_planner_graph() {
    let arch = IpuArch::gc200();
    let config = PropConfig { cases: 24, ..PropConfig::default() };
    check("verifier accepts sparse planner graphs", config, |rng, size| {
        let hi = size.scale(64, 2048);
        let shape = MmShape::new(
            rng.gen_usize(16, hi),
            rng.gen_usize(16, hi),
            rng.gen_usize(16, hi),
        );
        let kind = *rng.choose(&PatternKind::all());
        let block = *rng.choose(&BLOCK_SIZES);
        let density = 0.05 + 0.95 * rng.next_f64();
        let spec = SparsitySpec::new(kind, block, density, rng.next_u64());
        let pattern = BlockPattern::for_shape(spec, shape);
        let plan = match sparse_search(&arch, shape, &pattern) {
            Ok(p) => p,
            Err(_) => return Ok(()),
        };
        let g = SimEngine::new(arch.clone()).build_sparse_graph(shape, &plan, &pattern);
        let ds = verify_sparse(&arch, shape, &plan, &pattern, &g);
        prop_assert!(
            ds.is_empty(),
            "verifier rejected sparse graph for {shape:?} ({kind:?} b{block} d{density:.2}): {:?}",
            ds
        );
        Ok(())
    });
}

/// The mutation corpus end-to-end: for every class and several seeds,
/// a mutated dense graph is flagged with exactly the rule the class
/// advertises — and the *unmutated* twin stays clean, so the catch is
/// attributable to the mutation, not ambient noise.
#[test]
fn mutation_corpus_each_class_caught_by_expected_rule() {
    let arch = IpuArch::gc200();
    let engine = SimEngine::new(arch.clone());
    for shape in [MmShape::square(512), MmShape::new(512, 1536, 768)] {
        let plan = search(&arch, shape).unwrap();
        let clean = engine.build_graph(shape, &plan);
        assert!(
            verify_dense(&arch, shape, &plan, &clean).is_empty(),
            "baseline graph for {shape:?} must verify clean"
        );
        for class in MutationClass::ALL {
            for seed in 0..3u64 {
                let mut g = engine.build_graph(shape, &plan);
                let edit = apply(&mut g, class, seed);
                assert!(
                    edit.is_some(),
                    "{}: no eligible site in {shape:?} graph",
                    class.name()
                );
                let ds = verify_dense(&arch, shape, &plan, &g);
                assert!(
                    ds.iter().any(|d| d.rule == class.expected_rule()),
                    "{} (seed {seed}, {shape:?}) not caught by {}: {:?}",
                    class.name(),
                    class.expected_rule(),
                    ds
                );
            }
        }
    }
}

/// Skewing a block-CSR residency tensor trips the sparse bill
/// cross-check: the per-tile A_bsr/A_csr_* byte totals are pinned to
/// `BlockCsr::residency_per_tile`, so a single moved interval shows up
/// as `memory-bill-mismatch`.
#[test]
fn sparse_residency_skew_is_caught() {
    let arch = IpuArch::gc200();
    let shape = MmShape::new(1000, 1536, 700);
    let spec = SparsitySpec::new(PatternKind::Random, 8, 0.3, 11);
    let pattern = BlockPattern::for_shape(spec, shape);
    let plan = sparse_search(&arch, shape, &pattern).unwrap();
    let engine = SimEngine::new(arch.clone());

    let clean = engine.build_sparse_graph(shape, &plan, &pattern);
    assert!(verify_sparse(&arch, shape, &plan, &pattern, &clean).is_empty());

    let mut g = engine.build_sparse_graph(shape, &plan, &pattern);
    let edit = apply(&mut g, MutationClass::SkewResidency, 0);
    assert!(edit.is_some(), "sparse graph has no skewable home tensor");
    let ds = verify_sparse(&arch, shape, &plan, &pattern, &g);
    assert!(
        ds.iter().any(|d| d.rule == rules::MEMORY_BILL_MISMATCH),
        "sparse skew not caught: {ds:?}"
    );
}

/// `verify_graph` alone (no plan bill) also accepts planner graphs —
/// the structural/schedule half is independent of the bill cross-check.
#[test]
fn verify_graph_half_accepts_planner_graph() {
    let arch = IpuArch::gc200();
    let shape = MmShape::square(1024);
    let plan = search(&arch, shape).unwrap();
    let g = SimEngine::new(arch.clone()).build_graph(shape, &plan);
    assert!(verify_graph(&arch, &g).is_empty());
}

/// The lint gate over the real tree is clean, and the JSON report has
/// the shape CI's validator expects (`count`, `clean`, `diagnostics`).
#[test]
fn repo_lint_gate_is_clean_and_json_shape_stable() {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("rust")
        .join("src");
    let ds = lint::lint_dir(&root).expect("lint walk failed");
    assert!(ds.is_empty(), "lint gate dirty: {ds:?}");

    let report = report_json(&ds);
    let parsed = Json::parse(&report.render()).expect("report JSON must parse");
    assert_eq!(parsed.get("count").and_then(Json::as_f64), Some(0.0));
    assert_eq!(parsed.get("clean").and_then(|v| match v {
        Json::Bool(b) => Some(*b),
        _ => None,
    }), Some(true));
    assert!(matches!(parsed.get("diagnostics"), Some(Json::Arr(_))));
}
