//! Control programs — the Poplar `program::Sequence` analogue.
//!
//! A program is a tree whose leaves are the three BSP phases the paper's
//! Fig. 3 shows in the PopVision timeline: Execute (compute, red), Sync
//! (blue), and Exchange (data movement, yellow). The BSP engine walks the
//! flattened step list.

use crate::graph::vertex::ComputeSetId;

/// Identifier into the graph's exchange-plan table.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct ExchangeId(pub u32);

#[derive(Clone, Debug, PartialEq)]
pub enum Program {
    /// Run children in order.
    Sequence(Vec<Program>),
    /// Execute one compute set (BSP local-compute phase).
    Execute(ComputeSetId),
    /// Run a pre-compiled exchange (BSP data-exchange phase).
    Exchange(ExchangeId),
    /// Global cross-tile synchronisation.
    Sync,
    /// Repeat the body `n` times.
    Repeat(usize, Box<Program>),
}

/// One flattened execution step.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ProgramStep {
    Execute(ComputeSetId),
    Exchange(ExchangeId),
    Sync,
}

impl Program {
    /// Flatten the control tree into the linear BSP step sequence.
    pub fn steps(&self) -> Vec<ProgramStep> {
        let mut out = Vec::new();
        self.collect(&mut out);
        out
    }

    fn collect(&self, out: &mut Vec<ProgramStep>) {
        match self {
            Program::Sequence(children) => {
                for c in children {
                    c.collect(out);
                }
            }
            Program::Execute(cs) => out.push(ProgramStep::Execute(*cs)),
            Program::Exchange(ex) => out.push(ProgramStep::Exchange(*ex)),
            Program::Sync => out.push(ProgramStep::Sync),
            Program::Repeat(n, body) => {
                for _ in 0..*n {
                    body.collect(out);
                }
            }
        }
    }

    /// Number of BSP supersteps (compute phases) in the program.
    pub fn superstep_count(&self) -> usize {
        self.steps()
            .iter()
            .filter(|s| matches!(s, ProgramStep::Execute(_)))
            .count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cs(i: u32) -> Program {
        Program::Execute(ComputeSetId(i))
    }

    #[test]
    fn sequence_flattens_in_order() {
        let p = Program::Sequence(vec![cs(0), Program::Sync, Program::Exchange(ExchangeId(1))]);
        assert_eq!(
            p.steps(),
            vec![
                ProgramStep::Execute(ComputeSetId(0)),
                ProgramStep::Sync,
                ProgramStep::Exchange(ExchangeId(1)),
            ]
        );
    }

    #[test]
    fn repeat_unrolls() {
        let p = Program::Repeat(3, Box::new(Program::Sequence(vec![cs(7), Program::Sync])));
        let steps = p.steps();
        assert_eq!(steps.len(), 6);
        assert_eq!(steps[0], ProgramStep::Execute(ComputeSetId(7)));
        assert_eq!(steps[5], ProgramStep::Sync);
    }

    #[test]
    fn nested_sequences() {
        let p = Program::Sequence(vec![
            Program::Sequence(vec![cs(1), cs(2)]),
            Program::Repeat(2, Box::new(cs(3))),
        ]);
        let ids: Vec<u32> = p
            .steps()
            .iter()
            .filter_map(|s| match s {
                ProgramStep::Execute(ComputeSetId(i)) => Some(*i),
                _ => None,
            })
            .collect();
        assert_eq!(ids, vec![1, 2, 3, 3]);
    }

    #[test]
    fn superstep_count_counts_executes_only() {
        let p = Program::Sequence(vec![cs(0), Program::Sync, cs(1), Program::Exchange(ExchangeId(0))]);
        assert_eq!(p.superstep_count(), 2);
    }

    #[test]
    fn empty_program_has_no_steps() {
        assert!(Program::Sequence(vec![]).steps().is_empty());
        assert_eq!(Program::Repeat(0, Box::new(cs(1))).steps().len(), 0);
    }
}
