//! Vertices (codelets bound to tiles) and compute sets.
//!
//! The vertex census is a first-class output of this reproduction: the
//! paper's Finding 2 attributes the right-skewed performance collapse to
//! the planner emitting ~5.5x more vertices (5542 / 5762 / 31743). Every
//! vertex here carries a cycle-cost and state-size model so the BSP engine
//! and memory accountant can price it.

use crate::graph::tensor::TensorId;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct VertexId(pub u32);

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ComputeSetId(pub u32);

/// Codelet types emitted by the MM planner — the same families PopVision
/// shows for a PopLin matmul.
#[derive(Clone, Debug, PartialEq)]
pub enum VertexKind {
    /// AMP matmul worklist unit: a supervisor vertex driving the tile's AMP
    /// pipeline over an (rows x cols x acc) sub-block.
    AmpMacc { rows: usize, cols: usize, acc: usize },
    /// Partial-sum reduction over `inputs` partials of `width` elements.
    Reduce { inputs: usize, width: usize },
    /// Block-sparse AMP matmul supervisor (PopSparse static block-CSR):
    /// walks `nz_blocks` nonzero `block^3` sub-products on this tile.
    BlockSparseMm { block: usize, nz_blocks: usize },
    /// Pre-arrangement copy of `bytes` into AMP-friendly layout.
    Rearrange { bytes: usize },
    /// Cast between dtypes (fp16 partials -> fp32, etc.).
    Cast { elems: usize },
    /// Zero-initialise `elems` accumulator elements.
    Zero { elems: usize },
}

impl VertexKind {
    pub fn family(&self) -> &'static str {
        match self {
            VertexKind::AmpMacc { .. } => "AmpMacc",
            VertexKind::BlockSparseMm { .. } => "BlockSparseMm",
            VertexKind::Reduce { .. } => "Reduce",
            VertexKind::Rearrange { .. } => "Rearrange",
            VertexKind::Cast { .. } => "Cast",
            VertexKind::Zero { .. } => "Zero",
        }
    }

    /// Estimated execution cycles on one tile, given the tile's AMP MAC
    /// throughput. Fixed overheads reflect supervisor-thread dispatch and
    /// worklist setup (Jia et al. measure O(tens..hundreds) of cycles per
    /// vertex launch) — this is what makes vertex count a performance
    /// driver and not just a statistic.
    pub fn cycles(&self, fp32_macs_per_cycle: u32) -> u64 {
        const VERTEX_OVERHEAD: u64 = 120; // dispatch + worklist decode
        match self {
            VertexKind::AmpMacc { rows, cols, acc } => {
                // AMP quantization: the pipeline processes output rows in
                // groups of 4 and the reduction in vectors of 16; partial
                // groups still occupy full passes
                let ru = |v: usize, q: usize| v.div_ceil(q) * q;
                let macs = (ru(*rows, 4) * ru(*cols, 4) * ru(*acc, 16)) as u64;
                VERTEX_OVERHEAD + macs / fp32_macs_per_cycle.max(1) as u64
            }
            VertexKind::BlockSparseMm { block, nz_blocks } => {
                // each nonzero block is one AMP-quantized block^3 product
                // plus a worklist-entry decode (PopSparse walks block
                // coordinates from CSR metadata between AMP passes)
                const BLOCK_DECODE_CYCLES: u64 = 8;
                let ru = |v: usize, q: usize| v.div_ceil(q) * q;
                let per_block = (ru(*block, 4) * ru(*block, 4) * ru(*block, 16)) as u64
                    / fp32_macs_per_cycle.max(1) as u64;
                VERTEX_OVERHEAD + *nz_blocks as u64 * (BLOCK_DECODE_CYCLES + per_block)
            }
            VertexKind::Reduce { inputs, width } => {
                // ~1 cycle per input element per 2 lanes (64-bit loads)
                VERTEX_OVERHEAD + ((inputs * width) as u64) / 2
            }
            VertexKind::Rearrange { bytes } => VERTEX_OVERHEAD + (*bytes as u64) / 8,
            VertexKind::Cast { elems } => VERTEX_OVERHEAD + (*elems as u64) / 4,
            VertexKind::Zero { elems } => VERTEX_OVERHEAD / 2 + (*elems as u64) / 8,
        }
    }

    /// Vertex state bytes (descriptors, worklists, edge pointers) resident
    /// in tile memory — the overhead the paper's memory finding highlights.
    pub fn state_bytes(&self) -> usize {
        const BASE: usize = 64; // vertex descriptor + edge pointers
        match self {
            VertexKind::AmpMacc { rows, .. } => BASE + 8 * rows.div_ceil(4), // worklists
            // 8 B worklist entry + 4 B block-column index per nonzero block
            VertexKind::BlockSparseMm { nz_blocks, .. } => BASE + 12 * nz_blocks,
            VertexKind::Reduce { inputs, .. } => BASE + 8 * inputs,
            _ => BASE,
        }
    }
}

/// A vertex instance placed on a tile with its tensor connections.
#[derive(Clone, Debug)]
pub struct Vertex {
    pub id: VertexId,
    pub kind: VertexKind,
    pub tile: usize,
    pub inputs: Vec<TensorId>,
    pub outputs: Vec<TensorId>,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct VertexGroupId(pub u32);

/// Tiles a replicated vertex group spans: a contiguous range for the
/// planner's dense compute sets, or an explicit list for scattered
/// placements (reducer tiles).
#[derive(Clone, Debug, PartialEq)]
pub enum TileSpan {
    /// Tiles `start..end` (half-open).
    Range { start: usize, end: usize },
    /// Explicit tiles, in placement order.
    List(Vec<usize>),
}

impl TileSpan {
    pub fn range(start: usize, end: usize) -> TileSpan {
        debug_assert!(start <= end, "inverted tile range {start}..{end}");
        TileSpan::Range { start, end }
    }

    pub fn len(&self) -> usize {
        match self {
            TileSpan::Range { start, end } => end.saturating_sub(*start),
            TileSpan::List(tiles) => tiles.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Largest tile index spanned (bounds checks in `Graph::validate`).
    pub fn max_tile(&self) -> Option<usize> {
        match self {
            TileSpan::Range { start, end } => {
                if end > start {
                    Some(end - 1)
                } else {
                    None
                }
            }
            TileSpan::List(tiles) => tiles.iter().copied().max(),
        }
    }

    pub fn iter(&self) -> TileSpanIter<'_> {
        match self {
            TileSpan::Range { start, end } => TileSpanIter::Range(*start..*end),
            TileSpan::List(tiles) => TileSpanIter::List(tiles.iter()),
        }
    }
}

/// Iterator over a [`TileSpan`]'s tiles (no allocation for ranges).
pub enum TileSpanIter<'a> {
    Range(std::ops::Range<usize>),
    List(std::slice::Iter<'a, usize>),
}

impl Iterator for TileSpanIter<'_> {
    type Item = usize;

    fn next(&mut self) -> Option<usize> {
        match self {
            TileSpanIter::Range(r) => r.next(),
            TileSpanIter::List(it) => it.next().copied(),
        }
    }
}

/// A replicated vertex group: one record standing for
/// `span.len() * per_tile` identical vertices. §Perf: graph
/// materialization allocates O(groups), not O(tiles x vertices); the
/// census, BSP pricing, and memory accounting expand the replication
/// arithmetically (every spanned tile carries `per_tile` copies of
/// `kind`), so grouped and per-vertex graphs price bit-identically.
#[derive(Clone, Debug)]
pub struct VertexGroup {
    pub id: VertexGroupId,
    pub kind: VertexKind,
    pub span: TileSpan,
    /// Identical vertices per spanned tile.
    pub per_tile: usize,
    pub inputs: Vec<TensorId>,
    pub outputs: Vec<TensorId>,
}

impl VertexGroup {
    /// Vertices this group stands for.
    pub fn count(&self) -> usize {
        self.span.len() * self.per_tile
    }
}

/// Vertices that execute together in one BSP compute phase.
#[derive(Clone, Debug)]
pub struct ComputeSet {
    pub id: ComputeSetId,
    pub name: String,
    pub vertices: Vec<VertexId>,
    /// Replicated vertex groups executing in this phase.
    pub groups: Vec<VertexGroupId>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn families() {
        assert_eq!(VertexKind::AmpMacc { rows: 1, cols: 1, acc: 1 }.family(), "AmpMacc");
        assert_eq!(VertexKind::Reduce { inputs: 2, width: 4 }.family(), "Reduce");
    }

    #[test]
    fn amp_cycles_scale_with_macs() {
        let small = VertexKind::AmpMacc { rows: 16, cols: 16, acc: 16 }.cycles(16);
        let big = VertexKind::AmpMacc { rows: 32, cols: 32, acc: 32 }.cycles(16);
        assert!(big > small);
        // 32^3 macs at 16/cycle = 2048 cycles + overhead
        assert_eq!(big, 120 + 2048);
    }

    #[test]
    fn overhead_dominates_tiny_vertices() {
        // a tiny vertex is almost all overhead — the mechanism behind the
        // right-skew collapse
        let tiny = VertexKind::AmpMacc { rows: 4, cols: 4, acc: 4 }.cycles(16);
        // acc quantizes 4 -> 16: 4*4*16/16 = 16 useful-equivalent cycles
        assert_eq!(tiny, 120 + 16);
    }

    #[test]
    fn block_sparse_cycles_scale_with_nonzeros() {
        let sparse = VertexKind::BlockSparseMm { block: 16, nz_blocks: 10 }.cycles(16);
        let denser = VertexKind::BlockSparseMm { block: 16, nz_blocks: 40 }.cycles(16);
        assert!(denser > sparse);
        // 16^3 macs at 16/cycle = 256 cycles + 8 decode, per block
        assert_eq!(sparse, 120 + 10 * (8 + 256));
        // empty worklist is pure overhead
        assert_eq!(VertexKind::BlockSparseMm { block: 8, nz_blocks: 0 }.cycles(16), 120);
    }

    #[test]
    fn block_sparse_quantizes_small_blocks() {
        // block 4: acc rounds 4 -> 16, rows/cols stay 4: 4*4*16/16 = 16
        let v = VertexKind::BlockSparseMm { block: 4, nz_blocks: 1 }.cycles(16);
        assert_eq!(v, 120 + 8 + 16);
    }

    #[test]
    fn block_sparse_state_tracks_worklist() {
        let v = VertexKind::BlockSparseMm { block: 8, nz_blocks: 5 };
        assert_eq!(v.state_bytes(), 64 + 60);
        assert_eq!(v.family(), "BlockSparseMm");
    }

    #[test]
    fn reduce_cycles_scale_with_fanin() {
        let r2 = VertexKind::Reduce { inputs: 2, width: 128 }.cycles(16);
        let r8 = VertexKind::Reduce { inputs: 8, width: 128 }.cycles(16);
        assert!(r8 > r2);
    }

    #[test]
    fn state_bytes_nonzero() {
        assert!(VertexKind::Zero { elems: 10 }.state_bytes() >= 64);
        let r = VertexKind::Reduce { inputs: 16, width: 4 }.state_bytes();
        assert_eq!(r, 64 + 128);
    }

    #[test]
    fn zero_is_cheapest() {
        let z = VertexKind::Zero { elems: 64 }.cycles(16);
        let c = VertexKind::Cast { elems: 64 }.cycles(16);
        assert!(z < c);
    }

    #[test]
    fn tile_span_range_and_list_agree() {
        let r = TileSpan::range(3, 7);
        assert_eq!(r.len(), 4);
        assert_eq!(r.iter().collect::<Vec<_>>(), vec![3, 4, 5, 6]);
        assert_eq!(r.max_tile(), Some(6));
        let l = TileSpan::List(vec![9, 2, 5]);
        assert_eq!(l.len(), 3);
        assert_eq!(l.iter().collect::<Vec<_>>(), vec![9, 2, 5]);
        assert_eq!(l.max_tile(), Some(9));
        let empty = TileSpan::range(4, 4);
        assert!(empty.is_empty());
        assert_eq!(empty.max_tile(), None);
    }

    #[test]
    fn group_count_is_span_times_replication() {
        let g = VertexGroup {
            id: VertexGroupId(0),
            kind: VertexKind::Zero { elems: 1 },
            span: TileSpan::range(0, 10),
            per_tile: 3,
            inputs: vec![],
            outputs: vec![],
        };
        assert_eq!(g.count(), 30);
    }
}
