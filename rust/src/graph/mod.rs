//! Poplar-analogue computational dataflow graph (paper §2.2, Fig. 1).
//!
//! IPU programs are graphs of `Tensor`s (data), `Vertex`s (codelets bound
//! to tiles), `ComputeSet`s (vertices executed in one BSP compute phase),
//! and a control `Program` (Sequence / Execute / Exchange / Sync / Repeat).
//! The `sim` engine builds one of these graphs per matrix multiplication
//! from the planner's chosen partition, then the `bsp` engine executes it
//! against the cycle models. The profiler's vertex census and the memory
//! accountant both walk this structure — it is the load-bearing substrate,
//! not decoration.

pub mod builder;
pub mod program;
pub mod tensor;
pub mod vertex;

pub use builder::Graph;
pub use program::{Program, ProgramStep};
pub use tensor::{DType, Interval, Tensor, TensorId, TileMapping};
pub use vertex::{ComputeSet, ComputeSetId, Vertex, VertexId, VertexKind};
