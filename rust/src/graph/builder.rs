//! The graph container and builder API (Poplar `Graph` analogue).

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::analysis::Diagnostic;
use crate::exchange::plan::ExchangePlan;
use crate::graph::program::{ExchangeId, Program, ProgramStep};
use crate::graph::tensor::{DType, Tensor, TensorId, TileMapping};
use crate::graph::vertex::{
    ComputeSet, ComputeSetId, TileSpan, Vertex, VertexGroup, VertexGroupId, VertexId, VertexKind,
};

/// A complete IPU program graph: data, codelets, exchanges, control.
///
/// Vertices come in two forms: individual [`Vertex`] records (irregular
/// placements, tests) and replicated [`VertexGroup`]s — one record plus a
/// count per `(kind, span)` class, the §Perf representation the matmul
/// builders emit so materialization allocates O(supersteps), not
/// O(tiles x vertices). Census, validation, BSP pricing, and memory
/// accounting treat both forms identically.
#[derive(Clone, Debug)]
pub struct Graph {
    pub tiles: usize,
    tensors: Vec<Tensor>,
    vertices: Vec<Vertex>,
    groups: Vec<VertexGroup>,
    compute_sets: Vec<ComputeSet>,
    exchanges: Vec<ExchangePlan>,
    pub program: Program,
}

impl Graph {
    pub fn new(tiles: usize) -> Graph {
        Graph {
            tiles,
            tensors: Vec::new(),
            vertices: Vec::new(),
            groups: Vec::new(),
            compute_sets: Vec::new(),
            exchanges: Vec::new(),
            program: Program::Sequence(vec![]),
        }
    }

    // ---- construction ----------------------------------------------------

    pub fn add_tensor(&mut self, name: &str, shape: &[usize], dtype: DType) -> TensorId {
        let id = TensorId(self.tensors.len() as u32);
        self.tensors.push(Tensor {
            id,
            name: name.to_string(),
            shape: shape.to_vec(),
            dtype,
            mapping: None,
        });
        id
    }

    pub fn set_tile_mapping(&mut self, t: TensorId, mapping: TileMapping) {
        self.tensors[t.0 as usize].mapping = Some(mapping);
    }

    pub fn add_compute_set(&mut self, name: &str) -> ComputeSetId {
        let id = ComputeSetId(self.compute_sets.len() as u32);
        self.compute_sets.push(ComputeSet {
            id,
            name: name.to_string(),
            vertices: vec![],
            groups: vec![],
        });
        id
    }

    pub fn add_vertex(
        &mut self,
        cs: ComputeSetId,
        kind: VertexKind,
        tile: usize,
        inputs: Vec<TensorId>,
        outputs: Vec<TensorId>,
    ) -> VertexId {
        let id = VertexId(self.vertices.len() as u32);
        self.vertices.push(Vertex { id, kind, tile, inputs, outputs });
        self.compute_sets[cs.0 as usize].vertices.push(id);
        id
    }

    /// Add `span.len() * per_tile` identical vertices as one replicated
    /// record (§Perf: O(1) allocation instead of a per-tile loop).
    pub fn add_vertex_group(
        &mut self,
        cs: ComputeSetId,
        kind: VertexKind,
        span: TileSpan,
        per_tile: usize,
        inputs: Vec<TensorId>,
        outputs: Vec<TensorId>,
    ) -> VertexGroupId {
        debug_assert!(per_tile >= 1, "vertex group with zero replication");
        let id = VertexGroupId(self.groups.len() as u32);
        self.groups.push(VertexGroup { id, kind, span, per_tile, inputs, outputs });
        self.compute_sets[cs.0 as usize].groups.push(id);
        id
    }

    pub fn add_exchange(&mut self, plan: ExchangePlan) -> ExchangeId {
        let id = ExchangeId(self.exchanges.len() as u32);
        self.exchanges.push(plan);
        id
    }

    pub fn set_program(&mut self, program: Program) {
        self.program = program;
    }

    // ---- accessors ---------------------------------------------------------

    pub fn tensor(&self, id: TensorId) -> &Tensor {
        &self.tensors[id.0 as usize]
    }

    pub fn tensors(&self) -> &[Tensor] {
        &self.tensors
    }

    pub fn vertex(&self, id: VertexId) -> &Vertex {
        &self.vertices[id.0 as usize]
    }

    pub fn vertices(&self) -> &[Vertex] {
        &self.vertices
    }

    pub fn group(&self, id: VertexGroupId) -> &VertexGroup {
        &self.groups[id.0 as usize]
    }

    pub fn groups(&self) -> &[VertexGroup] {
        &self.groups
    }

    pub fn compute_set(&self, id: ComputeSetId) -> &ComputeSet {
        &self.compute_sets[id.0 as usize]
    }

    pub fn compute_sets(&self) -> &[ComputeSet] {
        &self.compute_sets
    }

    pub fn exchange(&self, id: ExchangeId) -> &ExchangePlan {
        &self.exchanges[id.0 as usize]
    }

    pub fn exchanges(&self) -> &[ExchangePlan] {
        &self.exchanges
    }

    /// Total vertex count, expanding replicated groups.
    pub fn n_vertices(&self) -> usize {
        self.vertices.len() + self.groups.iter().map(|g| g.count()).sum::<usize>()
    }

    /// Vertex census by codelet family — the PopVision statistic behind
    /// the paper's Finding 2. Replicated groups expand arithmetically.
    pub fn vertex_census(&self) -> BTreeMap<&'static str, usize> {
        let mut census = BTreeMap::new();
        for v in &self.vertices {
            *census.entry(v.kind.family()).or_insert(0) += 1;
        }
        for g in &self.groups {
            *census.entry(g.kind.family()).or_insert(0) += g.count();
        }
        census
    }

    /// *Individual* vertices resident on a tile (replicated groups are
    /// not expanded here — use `groups()` for the grouped form).
    pub fn vertices_on_tile(&self, tile: usize) -> impl Iterator<Item = &Vertex> {
        self.vertices.iter().filter(move |v| v.tile == tile)
    }

    // ---- validation --------------------------------------------------------

    /// Whole-graph consistency as a *full* structured diagnostic list —
    /// mappings partition tensors, vertices sit on real tiles and
    /// reference real tensors, program references are valid, exchanges
    /// validate against the tile count. Unlike the [`Self::validate`]
    /// wrapper this never bails early: every violation in the graph is
    /// reported, each under a stable `graph-*` rule id, so `ipumm check`
    /// and the IR verifier can gate on the complete picture.
    pub fn validate_diagnostics(&self) -> Vec<Diagnostic> {
        let mut ds = Vec::new();
        for t in &self.tensors {
            if let Err(e) = t.validate_mapping() {
                ds.push(
                    Diagnostic::error("graph-tensor-mapping", format!("tensor '{}': {}", t.name, e))
                        .on_tensor(&t.name),
                );
            }
            if let Some(m) = &t.mapping {
                if m.len() > self.tiles {
                    ds.push(
                        Diagnostic::error(
                            "graph-tensor-mapping",
                            format!(
                                "tensor '{}' mapping spans {} tiles > {}",
                                t.name,
                                m.len(),
                                self.tiles
                            ),
                        )
                        .on_tensor(&t.name),
                    );
                }
            }
        }
        for v in &self.vertices {
            if v.tile >= self.tiles {
                ds.push(
                    Diagnostic::error(
                        "graph-vertex-tile",
                        format!("vertex {:?} on tile {} >= {}", v.id, v.tile, self.tiles),
                    )
                    .at_tile(v.tile),
                );
            }
            for t in v.inputs.iter().chain(&v.outputs) {
                if t.0 as usize >= self.tensors.len() {
                    ds.push(Diagnostic::error(
                        "graph-missing-tensor",
                        format!("vertex {:?} references missing tensor {:?}", v.id, t),
                    ));
                }
            }
        }
        for g in &self.groups {
            if let Some(max) = g.span.max_tile() {
                if max >= self.tiles {
                    ds.push(
                        Diagnostic::error(
                            "graph-group-span",
                            format!("group {:?} spans tile {} >= {}", g.id, max, self.tiles),
                        )
                        .at_tile(max),
                    );
                }
            }
            if g.per_tile == 0 {
                ds.push(Diagnostic::error(
                    "graph-group-replication",
                    format!("group {:?} has zero replication", g.id),
                ));
            }
            for t in g.inputs.iter().chain(&g.outputs) {
                if t.0 as usize >= self.tensors.len() {
                    ds.push(Diagnostic::error(
                        "graph-missing-tensor",
                        format!("group {:?} references missing tensor {:?}", g.id, t),
                    ));
                }
            }
        }
        for ex in &self.exchanges {
            if let Err(e) = ex.validate(self.tiles) {
                ds.push(Diagnostic::error(
                    "graph-exchange",
                    format!("exchange '{}': {}", ex.name, e),
                ));
            }
        }
        for step in self.program.steps() {
            match step {
                ProgramStep::Execute(cs) => {
                    if cs.0 as usize >= self.compute_sets.len() {
                        ds.push(Diagnostic::error(
                            "graph-program-ref",
                            format!("program references missing compute set {:?}", cs),
                        ));
                    }
                }
                ProgramStep::Exchange(ex) => {
                    if ex.0 as usize >= self.exchanges.len() {
                        ds.push(Diagnostic::error(
                            "graph-program-ref",
                            format!("program references missing exchange {:?}", ex),
                        ));
                    }
                }
                ProgramStep::Sync => {}
            }
        }
        ds
    }

    /// `Result` wrapper over [`Self::validate_diagnostics`] for callers
    /// that just need pass/fail: Ok iff the graph is clean, otherwise all
    /// violations joined into one error message.
    pub fn validate(&self) -> Result<()> {
        let ds = self.validate_diagnostics();
        if ds.is_empty() {
            return Ok(());
        }
        let msgs: Vec<&str> = ds.iter().map(|d| d.message.as_str()).collect();
        bail!("{}", msgs.join("; "));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exchange::plan::ExchangePattern;
    use crate::graph::tensor::Interval;

    fn tiny_graph() -> Graph {
        let mut g = Graph::new(4);
        let a = g.add_tensor("a", &[2, 2], DType::F32);
        g.set_tile_mapping(a, vec![vec![Interval::new(0, 4)]]);
        let cs = g.add_compute_set("mm");
        g.add_vertex(cs, VertexKind::AmpMacc { rows: 2, cols: 2, acc: 2 }, 0, vec![a], vec![a]);
        let mut plan = ExchangePlan::new("x", ExchangePattern::AllToAll);
        plan.add(0, 1, 16);
        let ex = g.add_exchange(plan);
        g.set_program(Program::Sequence(vec![
            Program::Execute(cs),
            Program::Sync,
            Program::Exchange(ex),
        ]));
        g
    }

    #[test]
    fn builds_and_validates() {
        tiny_graph().validate().unwrap();
    }

    #[test]
    fn census_counts_families() {
        let g = tiny_graph();
        assert_eq!(g.vertex_census().get("AmpMacc"), Some(&1));
        assert_eq!(g.n_vertices(), 1);
    }

    #[test]
    fn census_counts_block_sparse_family() {
        let mut g = tiny_graph();
        let cs = g.add_compute_set("bsmm");
        for t in 0..3 {
            g.add_vertex(
                cs,
                VertexKind::BlockSparseMm { block: 8, nz_blocks: 4 },
                t,
                vec![],
                vec![],
            );
        }
        assert_eq!(g.vertex_census().get("BlockSparseMm"), Some(&3));
        g.validate().unwrap();
    }

    #[test]
    fn invalid_tile_rejected() {
        let mut g = tiny_graph();
        let cs = g.add_compute_set("bad");
        g.add_vertex(cs, VertexKind::Zero { elems: 1 }, 99, vec![], vec![]);
        assert!(g.validate().unwrap_err().to_string().contains("tile 99"));
    }

    #[test]
    fn missing_tensor_reference_rejected() {
        let mut g = tiny_graph();
        let cs = g.add_compute_set("bad");
        g.add_vertex(cs, VertexKind::Zero { elems: 1 }, 0, vec![TensorId(42)], vec![]);
        assert!(g.validate().is_err());
    }

    #[test]
    fn dangling_program_reference_rejected() {
        let mut g = tiny_graph();
        g.set_program(Program::Execute(ComputeSetId(9)));
        assert!(g.validate().is_err());
    }

    #[test]
    fn unmapped_tensor_rejected() {
        let mut g = tiny_graph();
        g.add_tensor("loose", &[4], DType::F32);
        assert!(g.validate().is_err());
    }

    #[test]
    fn vertices_on_tile_filters() {
        let g = tiny_graph();
        assert_eq!(g.vertices_on_tile(0).count(), 1);
        assert_eq!(g.vertices_on_tile(1).count(), 0);
    }

    #[test]
    fn vertex_groups_expand_in_census_and_counts() {
        let mut g = tiny_graph();
        let cs = g.add_compute_set("grouped");
        g.add_vertex_group(
            cs,
            VertexKind::Zero { elems: 4 },
            TileSpan::range(0, 3),
            2,
            vec![],
            vec![],
        );
        g.add_vertex_group(
            cs,
            VertexKind::Reduce { inputs: 2, width: 8 },
            TileSpan::List(vec![1, 3]),
            5,
            vec![],
            vec![],
        );
        // 1 individual AmpMacc + 3*2 Zero + 2*5 Reduce
        assert_eq!(g.n_vertices(), 1 + 6 + 10);
        let census = g.vertex_census();
        assert_eq!(census.get("Zero"), Some(&6));
        assert_eq!(census.get("Reduce"), Some(&10));
        assert_eq!(g.compute_set(cs).groups.len(), 2);
        g.validate().unwrap();
    }

    #[test]
    fn group_on_invalid_tile_rejected() {
        let mut g = tiny_graph();
        let cs = g.add_compute_set("bad");
        g.add_vertex_group(
            cs,
            VertexKind::Zero { elems: 1 },
            TileSpan::range(2, 99),
            1,
            vec![],
            vec![],
        );
        assert!(g.validate().unwrap_err().to_string().contains("spans tile 98"));
    }

    #[test]
    fn validate_diagnostics_reports_all_violations() {
        // two independent violations — the diagnostic list carries both,
        // and the Result wrapper joins both messages
        let mut g = tiny_graph();
        let cs = g.add_compute_set("bad");
        g.add_vertex(cs, VertexKind::Zero { elems: 1 }, 99, vec![], vec![]);
        g.add_vertex(cs, VertexKind::Zero { elems: 1 }, 0, vec![TensorId(42)], vec![]);
        let ds = g.validate_diagnostics();
        let rules: Vec<_> = ds.iter().map(|d| d.rule).collect();
        assert_eq!(rules, vec!["graph-vertex-tile", "graph-missing-tensor"]);
        assert_eq!(ds[0].tile, Some(99));
        let err = g.validate().unwrap_err().to_string();
        assert!(err.contains("tile 99") && err.contains("TensorId(42)"), "{err}");
    }

    #[test]
    fn group_with_missing_tensor_rejected() {
        let mut g = tiny_graph();
        let cs = g.add_compute_set("bad");
        g.add_vertex_group(
            cs,
            VertexKind::Zero { elems: 1 },
            TileSpan::range(0, 1),
            1,
            vec![TensorId(42)],
            vec![],
        );
        assert!(g.validate().is_err());
    }
}
