//! Tensors and their tile mappings.
//!
//! Poplar tensors carry an explicit mapping of element intervals to tiles;
//! how a tensor is laid out across In-Processor memory determines both the
//! per-tile memory bill and the exchange traffic (paper §2.3: "all data
//! required for a computational step must reside in the In-Processor
//! Memory of each tile").

use anyhow::{bail, Result};

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TensorId(pub u32);

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    F16,
    U32,
}

impl DType {
    pub fn size_bytes(&self) -> usize {
        match self {
            DType::F32 | DType::U32 => 4,
            DType::F16 => 2,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::F16 => "f16",
            DType::U32 => "u32",
        }
    }
}

/// Half-open element interval `[begin, end)` in a tensor's flattened order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Interval {
    pub begin: usize,
    pub end: usize,
}

impl Interval {
    pub fn new(begin: usize, end: usize) -> Interval {
        assert!(begin <= end, "interval [{begin}, {end})");
        Interval { begin, end }
    }

    pub fn len(&self) -> usize {
        self.end - self.begin
    }

    pub fn is_empty(&self) -> bool {
        self.begin == self.end
    }
}

/// Per-tile interval lists: `mapping[tile]` = intervals resident on `tile`.
pub type TileMapping = Vec<Vec<Interval>>;

/// A named, shaped, mapped tensor.
#[derive(Clone, Debug)]
pub struct Tensor {
    pub id: TensorId,
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
    pub mapping: Option<TileMapping>,
}

impl Tensor {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn bytes(&self) -> usize {
        self.numel() * self.dtype.size_bytes()
    }

    /// Bytes resident on `tile` under the current mapping (0 if unmapped).
    pub fn bytes_on_tile(&self, tile: usize) -> usize {
        match &self.mapping {
            None => 0,
            Some(m) => m
                .get(tile)
                .map(|ivs| ivs.iter().map(Interval::len).sum::<usize>())
                .unwrap_or(0)
                * self.dtype.size_bytes(),
        }
    }

    /// Validate that a mapping exactly partitions the element range:
    /// every element mapped once, no overlap, no out-of-range intervals.
    pub fn validate_mapping(&self) -> Result<()> {
        let Some(mapping) = &self.mapping else {
            bail!("tensor '{}' has no tile mapping", self.name);
        };
        let mut all: Vec<Interval> = mapping.iter().flatten().copied().collect();
        all.retain(|iv| !iv.is_empty());
        all.sort_by_key(|iv| iv.begin);
        let mut covered = 0usize;
        for iv in &all {
            if iv.begin != covered {
                bail!(
                    "tensor '{}': mapping gap/overlap at element {} (interval starts at {})",
                    self.name,
                    covered,
                    iv.begin
                );
            }
            covered = iv.end;
        }
        if covered != self.numel() {
            bail!(
                "tensor '{}': mapping covers {} of {} elements",
                self.name,
                covered,
                self.numel()
            );
        }
        Ok(())
    }

    /// Tiles with at least one resident element.
    pub fn tiles_used(&self) -> usize {
        match &self.mapping {
            None => 0,
            Some(m) => m
                .iter()
                .filter(|ivs| ivs.iter().any(|iv| !iv.is_empty()))
                .count(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(shape: Vec<usize>, mapping: Option<TileMapping>) -> Tensor {
        Tensor { id: TensorId(0), name: "t".into(), shape, dtype: DType::F32, mapping }
    }

    #[test]
    fn sizes() {
        let x = t(vec![4, 8], None);
        assert_eq!(x.numel(), 32);
        assert_eq!(x.bytes(), 128);
        assert_eq!(DType::F16.size_bytes(), 2);
    }

    #[test]
    fn valid_partition_mapping() {
        let x = t(
            vec![2, 4],
            Some(vec![vec![Interval::new(0, 5)], vec![Interval::new(5, 8)]]),
        );
        x.validate_mapping().unwrap();
        assert_eq!(x.bytes_on_tile(0), 20);
        assert_eq!(x.bytes_on_tile(1), 12);
        assert_eq!(x.bytes_on_tile(99), 0);
        assert_eq!(x.tiles_used(), 2);
    }

    #[test]
    fn gap_is_rejected() {
        let x = t(vec![8], Some(vec![vec![Interval::new(0, 3)], vec![Interval::new(4, 8)]]));
        let e = x.validate_mapping().unwrap_err();
        assert!(e.to_string().contains("gap/overlap"));
    }

    #[test]
    fn overlap_is_rejected() {
        let x = t(vec![8], Some(vec![vec![Interval::new(0, 5)], vec![Interval::new(4, 8)]]));
        assert!(x.validate_mapping().is_err());
    }

    #[test]
    fn short_coverage_is_rejected() {
        let x = t(vec![8], Some(vec![vec![Interval::new(0, 6)]]));
        let e = x.validate_mapping().unwrap_err();
        assert!(e.to_string().contains("covers 6 of 8"));
    }

    #[test]
    fn unmapped_is_rejected() {
        assert!(t(vec![4], None).validate_mapping().is_err());
    }

    #[test]
    fn empty_intervals_ignored() {
        let x = t(
            vec![4],
            Some(vec![vec![Interval::new(0, 0), Interval::new(0, 4)], vec![]]),
        );
        x.validate_mapping().unwrap();
        assert_eq!(x.tiles_used(), 1);
    }
}
