//! IPU-specific extensions beyond the single-chip SRAM-resident model:
//! the paper's §6 future-work directions, built as first-class features.

pub mod streaming;

pub use streaming::{StreamingMm, StreamingReport};
