//! Streaming-memory matmul (paper §6 future work, X1 in DESIGN.md).
//!
//! Past the In-Processor wall (3584^2 on GC200) the M2000's Streaming
//! Memory (256 GB DRAM at 20 GB/s, Table 1) can stage panels: C is
//! computed panel-by-panel, with A/B panels streamed in via remote
//! buffers while resident panels compute (double-buffered overlap —
//! "offering the possibility to overlap communication and computation",
//! §6). Throughput is the max of compute time and stream time per panel,
//! so large problems converge to the 20 GB/s roofline.

use crate::arch::IpuArch;
use crate::planner::partition::MmShape;
use crate::planner::search::{search, PlannerError};

#[derive(Clone, Copy, Debug)]
pub struct StreamingReport {
    pub shape: MmShape,
    /// Panel edge chosen for the on-chip sub-problems.
    pub panel: usize,
    pub panels_total: usize,
    pub seconds: f64,
    pub tflops: f64,
    /// Fraction of wall time the stream (not compute) was critical.
    pub stream_bound_fraction: f64,
    /// On-chip throughput of the panel sub-problem.
    pub panel_tflops: f64,
}

pub struct StreamingMm {
    pub arch: IpuArch,
}

impl StreamingMm {
    pub fn new(arch: IpuArch) -> StreamingMm {
        StreamingMm { arch }
    }

    /// Does the whole problem fit Streaming Memory?
    pub fn fits(&self, shape: MmShape) -> bool {
        shape.tensor_bytes() <= self.arch.streaming_bytes
    }

    /// Largest on-chip square panel (multiple of 512) the planner accepts.
    fn best_panel(&self, cap: usize) -> Result<usize, PlannerError> {
        let mut best = Err(PlannerError::OutOfMemory { candidates_evaluated: 0 });
        let mut p = 512;
        while p <= cap {
            if search(&self.arch, MmShape::square(p)).is_ok() {
                best = Ok(p);
            } else {
                break;
            }
            p += 512;
        }
        best
    }

    /// Simulate a DRAM-staged matmul of `shape`.
    pub fn simulate_mm(&self, shape: MmShape) -> Result<StreamingReport, PlannerError> {
        if !self.fits(shape) {
            return Err(PlannerError::OutOfMemory { candidates_evaluated: 0 });
        }
        let max_dim = shape.m.max(shape.n).max(shape.k);
        let panel = self.best_panel(max_dim.min(4096))?;

        // panel grid over (m, k) with reduction over n panels
        let gm = shape.m.div_ceil(panel);
        let gn = shape.n.div_ceil(panel);
        let gk = shape.k.div_ceil(panel);
        let panels_total = gm * gn * gk;

        // on-chip sub-problem throughput from the calibrated simulator
        let sub = search(&self.arch, MmShape::square(panel))?;
        let panel_secs = self.arch.cycles_to_secs(sub.cost.total_cycles);
        let panel_tflops = sub.tflops(&self.arch);

        // stream per panel step: fetch an A panel and a B panel (C stays
        // resident per (i,j) while the reduction runs)
        let panel_bytes = (panel * panel * 4) as f64;
        let stream_secs = 2.0 * panel_bytes / self.arch.streaming_bw_bytes_per_s;

        // double-buffered overlap: each step costs max(compute, stream);
        // first fetch is exposed
        let step = panel_secs.max(stream_secs);
        let seconds = stream_secs + step * panels_total as f64;
        let tflops = shape.flops() as f64 / seconds / 1e12;
        Ok(StreamingReport {
            shape,
            panel,
            panels_total,
            seconds,
            tflops,
            stream_bound_fraction: if stream_secs > panel_secs { 1.0 } else { 0.0 },
            panel_tflops,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s() -> StreamingMm {
        StreamingMm::new(IpuArch::gc200())
    }

    #[test]
    fn extends_past_the_sram_wall() {
        // 8192^2 is far past the 3584 wall but streams fine
        let r = s().simulate_mm(MmShape::square(8192)).unwrap();
        assert!(r.tflops > 0.0);
        assert!(r.panels_total > 1);
    }

    #[test]
    fn stream_bandwidth_is_the_bottleneck() {
        // panel compute at ~40 TF needs ~TB/s of feed; 20 GB/s can't keep
        // up, so big streamed MMs are stream-bound (the §6 caveat)
        let r = s().simulate_mm(MmShape::square(16384)).unwrap();
        assert!(r.stream_bound_fraction > 0.5);
        assert!(r.tflops < 20.0, "{}", r.tflops); // well under the ~43 resident TFlop/s
    }

    #[test]
    fn streamed_is_slower_than_resident() {
        let resident = search(&IpuArch::gc200(), MmShape::square(3584)).unwrap();
        let streamed = s().simulate_mm(MmShape::square(4096)).unwrap();
        assert!(streamed.tflops < resident.tflops(&IpuArch::gc200()));
    }

    #[test]
    fn dram_capacity_still_bounds() {
        // 256 GB streaming memory: a 200k^2 f32 problem (480 GB) is out
        assert!(s().simulate_mm(MmShape::square(200_000)).is_err());
    }

    #[test]
    fn gc2_has_no_streaming_memory() {
        let gc2 = StreamingMm::new(IpuArch::gc2());
        assert!(gc2.simulate_mm(MmShape::square(4096)).is_err());
    }
}
