//! Exchange plans: the set of tile-to-tile transfers for one BSP exchange
//! phase, plus builders for the patterns a distributed matmul uses
//! (block scatter, row/column broadcast, partial-sum gather).

use anyhow::{bail, Result};

/// One point-to-point transfer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Transfer {
    pub src_tile: usize,
    pub dst_tile: usize,
    pub bytes: u64,
}

/// Pattern tag, used by the profiler and the congestion model.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExchangePattern {
    /// Host/initial scatter of operand blocks to their home tiles.
    Scatter,
    /// Broadcast of operand blocks along a partition axis.
    Broadcast,
    /// Gather of partial sums to reducer tiles.
    ReduceGather,
    /// General rearrangement.
    AllToAll,
}

#[derive(Clone, Debug)]
pub struct ExchangePlan {
    pub name: String,
    pub pattern: ExchangePattern,
    pub transfers: Vec<Transfer>,
}

impl ExchangePlan {
    pub fn new(name: &str, pattern: ExchangePattern) -> ExchangePlan {
        ExchangePlan { name: name.to_string(), pattern, transfers: Vec::new() }
    }

    pub fn add(&mut self, src_tile: usize, dst_tile: usize, bytes: u64) {
        // self-transfers are free on an IPU (data already resident);
        // plans never include them so the fabric cost is honest
        if src_tile != dst_tile && bytes > 0 {
            self.transfers.push(Transfer { src_tile, dst_tile, bytes });
        }
    }

    pub fn total_bytes(&self) -> u64 {
        self.transfers.iter().map(|t| t.bytes).sum()
    }

    /// Bytes leaving each tile (index = tile id, sized to `tiles`).
    pub fn sent_per_tile(&self, tiles: usize) -> Vec<u64> {
        let mut out = vec![0u64; tiles];
        for t in &self.transfers {
            out[t.src_tile] += t.bytes;
        }
        out
    }

    /// Bytes arriving at each tile.
    pub fn recv_per_tile(&self, tiles: usize) -> Vec<u64> {
        let mut out = vec![0u64; tiles];
        for t in &self.transfers {
            out[t.dst_tile] += t.bytes;
        }
        out
    }

    /// Conservation + bounds check: every byte sent is received, and all
    /// endpoints are valid tiles. (The proptest suite leans on this.)
    pub fn validate(&self, tiles: usize) -> Result<()> {
        for t in &self.transfers {
            if t.src_tile >= tiles || t.dst_tile >= tiles {
                bail!(
                    "plan '{}': transfer {}->{} outside tile range 0..{}",
                    self.name,
                    t.src_tile,
                    t.dst_tile,
                    tiles
                );
            }
            if t.src_tile == t.dst_tile {
                bail!("plan '{}': self-transfer on tile {}", self.name, t.src_tile);
            }
        }
        let sent: u64 = self.sent_per_tile(tiles).iter().sum();
        let recv: u64 = self.recv_per_tile(tiles).iter().sum();
        if sent != recv {
            bail!("plan '{}': sent {} != received {}", self.name, sent, recv);
        }
        Ok(())
    }

    /// Number of distinct tiles participating (as sender or receiver).
    pub fn participants(&self) -> usize {
        let mut tiles: Vec<usize> = self
            .transfers
            .iter()
            .flat_map(|t| [t.src_tile, t.dst_tile])
            .collect();
        tiles.sort_unstable();
        tiles.dedup();
        tiles.len()
    }

    // ---- builders for the matmul patterns -------------------------------

    /// Scatter `block_bytes` from a source tile (host gateway tile 0 in our
    /// model) to each tile in `dst_tiles`.
    pub fn scatter(name: &str, src: usize, dst_tiles: &[usize], block_bytes: u64) -> ExchangePlan {
        let mut p = ExchangePlan::new(name, ExchangePattern::Scatter);
        for &d in dst_tiles {
            p.add(src, d, block_bytes);
        }
        p
    }

    /// Broadcast: each tile in `src_tiles` sends its block to `fanout`
    /// sibling tiles computed by the caller-provided mapping.
    pub fn broadcast(
        name: &str,
        src_tiles: &[usize],
        dsts_of: impl Fn(usize) -> Vec<usize>,
        block_bytes: u64,
    ) -> ExchangePlan {
        let mut p = ExchangePlan::new(name, ExchangePattern::Broadcast);
        for &s in src_tiles {
            for d in dsts_of(s) {
                p.add(s, d, block_bytes);
            }
        }
        p
    }

    /// Reduce-gather: each group of `srcs` sends a partial block to its
    /// reducer tile.
    pub fn reduce_gather(
        name: &str,
        groups: &[(usize, Vec<usize>)], // (reducer, partial-holders)
        block_bytes: u64,
    ) -> ExchangePlan {
        let mut p = ExchangePlan::new(name, ExchangePattern::ReduceGather);
        for (reducer, srcs) in groups {
            for &s in srcs {
                p.add(s, *reducer, block_bytes);
            }
        }
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_skips_self_and_empty() {
        let mut p = ExchangePlan::new("t", ExchangePattern::AllToAll);
        p.add(1, 1, 100);
        p.add(1, 2, 0);
        p.add(1, 2, 10);
        assert_eq!(p.transfers.len(), 1);
        assert_eq!(p.total_bytes(), 10);
    }

    #[test]
    fn per_tile_accounting() {
        let mut p = ExchangePlan::new("t", ExchangePattern::AllToAll);
        p.add(0, 1, 5);
        p.add(0, 2, 7);
        p.add(2, 1, 3);
        assert_eq!(p.sent_per_tile(3), vec![12, 0, 3]);
        assert_eq!(p.recv_per_tile(3), vec![0, 8, 7]);
        assert_eq!(p.participants(), 3);
    }

    #[test]
    fn validate_catches_out_of_range() {
        let mut p = ExchangePlan::new("t", ExchangePattern::AllToAll);
        p.add(0, 9, 1);
        assert!(p.validate(4).is_err());
        assert!(p.validate(10).is_ok());
    }

    #[test]
    fn scatter_builder() {
        let p = ExchangePlan::scatter("s", 0, &[1, 2, 3], 64);
        assert_eq!(p.transfers.len(), 3);
        assert_eq!(p.total_bytes(), 192);
        p.validate(4).unwrap();
    }

    #[test]
    fn scatter_to_self_tile_is_free() {
        let p = ExchangePlan::scatter("s", 0, &[0, 1], 64);
        assert_eq!(p.transfers.len(), 1); // 0->0 dropped
    }

    #[test]
    fn broadcast_builder() {
        // tiles 0,1 each broadcast to the two tiles "above" them
        let p = ExchangePlan::broadcast("b", &[0, 1], |s| vec![s + 2, s + 4], 32);
        assert_eq!(p.transfers.len(), 4);
        assert_eq!(p.total_bytes(), 128);
        p.validate(6).unwrap();
    }

    #[test]
    fn reduce_gather_builder() {
        let groups = vec![(0usize, vec![1, 2, 3]), (4usize, vec![5, 6])];
        let p = ExchangePlan::reduce_gather("r", &groups, 16);
        assert_eq!(p.transfers.len(), 5);
        assert_eq!(p.recv_per_tile(7)[0], 48);
        assert_eq!(p.recv_per_tile(7)[4], 32);
        p.validate(7).unwrap();
    }
}
