//! Exchange fabric — the IPU's all-to-all interconnect between tiles.
//!
//! BSP phase 3 (paper Fig. 3, yellow): after a sync, tiles exchange data
//! over the fabric. `plan` describes *what* moves (transfers, with builders
//! for the broadcast/reduce patterns a matmul needs); `fabric` prices *how
//! long* it takes on a given [`crate::arch::IpuArch`].

pub mod fabric;
pub mod plan;

pub use fabric::ExchangeFabric;
pub use plan::{ExchangePattern, ExchangePlan, Transfer};
