//! Exchange fabric timing model.
//!
//! The IPU exchange is a non-blocking all-to-all, but each tile has a fixed
//! send/receive port width (GC200: 8 B/cycle receive). A BSP exchange phase
//! therefore takes at least `max_tile_bytes / port_bytes_per_cycle` cycles,
//! plus a congestion factor when many tiles contend (Jia et al. measure
//! ~70% of ideal under full-chip congestion) and a fixed setup cost for
//! loading the exchange program.

use crate::arch::IpuArch;
use crate::exchange::plan::ExchangePlan;

/// Timing results for one exchange phase.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ExchangeCost {
    pub cycles: u64,
    pub total_bytes: u64,
    /// Bottleneck tile's byte count (the critical path).
    pub max_tile_bytes: u64,
    /// Effective fraction of ideal port bandwidth after congestion.
    pub efficiency: f64,
}

#[derive(Clone, Debug)]
pub struct ExchangeFabric {
    arch: IpuArch,
    /// Fixed cycles to launch an exchange program.
    pub setup_cycles: u64,
    /// Bandwidth derating at full participation (measured ~0.7 on GC2/GC200).
    pub congestion_floor: f64,
}

impl ExchangeFabric {
    pub fn new(arch: &IpuArch) -> ExchangeFabric {
        ExchangeFabric { arch: arch.clone(), setup_cycles: 40, congestion_floor: 0.7 }
    }

    /// Congestion efficiency as a function of participating-tile fraction:
    /// 1.0 for a handful of tiles, easing towards `congestion_floor` at
    /// full participation.
    pub fn congestion_efficiency(&self, participants: usize) -> f64 {
        let frac = (participants as f64 / self.arch.tiles as f64).clamp(0.0, 1.0);
        1.0 - (1.0 - self.congestion_floor) * frac
    }

    /// Cycles for one exchange phase of `plan`.
    pub fn cost(&self, plan: &ExchangePlan) -> ExchangeCost {
        if plan.transfers.is_empty() {
            return ExchangeCost { cycles: 0, total_bytes: 0, max_tile_bytes: 0, efficiency: 1.0 };
        }
        let sent = plan.sent_per_tile(self.arch.tiles);
        let recv = plan.recv_per_tile(self.arch.tiles);
        // the bottleneck is whichever port (in or out) of whichever tile
        // carries the most bytes
        let max_tile_bytes = sent
            .iter()
            .chain(recv.iter())
            .copied()
            .max()
            .unwrap_or(0);
        let efficiency = self.congestion_efficiency(plan.participants());
        let port = self.arch.exchange_bytes_per_tile_cycle * efficiency;
        let cycles = self.setup_cycles + (max_tile_bytes as f64 / port).ceil() as u64;
        ExchangeCost {
            cycles,
            total_bytes: plan.total_bytes(),
            max_tile_bytes,
            efficiency,
        }
    }

    /// Seconds for one exchange phase.
    pub fn cost_secs(&self, plan: &ExchangePlan) -> f64 {
        self.arch.cycles_to_secs(self.cost(plan).cycles)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exchange::plan::ExchangePattern;

    fn fabric() -> ExchangeFabric {
        ExchangeFabric::new(&IpuArch::gc200())
    }

    #[test]
    fn empty_plan_is_free() {
        let p = ExchangePlan::new("e", ExchangePattern::AllToAll);
        let c = fabric().cost(&p);
        assert_eq!(c.cycles, 0);
        assert_eq!(c.total_bytes, 0);
    }

    #[test]
    fn single_transfer_cost() {
        let mut p = ExchangePlan::new("one", ExchangePattern::AllToAll);
        p.add(0, 1, 8_000);
        let f = fabric();
        let c = f.cost(&p);
        // 2 participants of 1472 -> efficiency ~1.0; 8000 B / 8 B/cy = 1000
        assert!(c.efficiency > 0.99);
        assert!(c.cycles >= 1000 && c.cycles < 1100, "{}", c.cycles);
    }

    #[test]
    fn bottleneck_is_max_port_not_total() {
        // tile 0 fans out to 4 tiles: its send port is the bottleneck
        let p = ExchangePlan::scatter("s", 0, &[1, 2, 3, 4], 1000);
        let c = fabric().cost(&p);
        assert_eq!(c.max_tile_bytes, 4000);
        assert_eq!(c.total_bytes, 4000);

        // 4 disjoint pairs move the same total with no shared bottleneck
        let mut q = ExchangePlan::new("p", ExchangePattern::AllToAll);
        for i in 0..4 {
            q.add(2 * i, 2 * i + 1, 1000);
        }
        let cq = fabric().cost(&q);
        assert_eq!(cq.max_tile_bytes, 1000);
        assert!(cq.cycles < c.cycles);
    }

    #[test]
    fn congestion_reduces_efficiency() {
        let f = fabric();
        assert!(f.congestion_efficiency(2) > f.congestion_efficiency(1472));
        assert!((f.congestion_efficiency(1472) - 0.7).abs() < 1e-9);
    }

    #[test]
    fn full_chip_broadcast_is_derated() {
        let tiles: Vec<usize> = (1..1472).collect();
        let p = ExchangePlan::scatter("all", 0, &tiles, 100);
        let c = fabric().cost(&p);
        assert!(c.efficiency < 0.75);
    }

    #[test]
    fn setup_cost_floors_small_exchanges() {
        let mut p = ExchangePlan::new("tiny", ExchangePattern::AllToAll);
        p.add(0, 1, 8);
        let c = fabric().cost(&p);
        assert!(c.cycles >= 40, "{}", c.cycles);
    }
}
