//! The generative robustness harness: a registered invariant suite over
//! the whole plan→graph→verify→simulate→serve pipeline, a seeded fuzz
//! loop that grows scenarios against it, and the full-tuple shrinker
//! that turns any failure into a one-line deterministic repro.
//!
//! Every invariant is a pure function of a [`Scenario`]: it rebuilds the
//! pipeline state it needs from the scenario fields alone, so a failure
//! found at iteration 173 of a fuzz run reproduces from its replay line
//! (`ipumm fuzz --replay <spec>`) on any machine and worker count.
//!
//! The `analysis::mutate` corpus doubles as the harness's own trip-wire
//! ([`HarnessConfig::mutate`]): a seeded graph mutation must be *found*
//! by the `verify-clean` invariant and *shrunk* to a 1-minimal
//! counterexample, proving the fuzzer catches what the static verifier
//! catches — a blind harness exits clean and CI's expect-failure wrapper
//! fails the build.

use std::sync::Mutex;

use crate::analysis::mutate::MutationClass;
use crate::analysis::{mutate, verify};
use crate::arch::GpuArch;
use crate::fault::chaos;
use crate::fuzz::generate::{grow_scenario, shrink_candidates, Scenario};
use crate::planner::cost::{CostConfig, CostModel};
use crate::planner::partition::MmShape;
use crate::planner::search::search_with_workers;
use crate::serve::service::{MmService, ServiceConfig};
use crate::serve::telemetry::ServeReport;
use crate::sim::engine::SimEngine;
use crate::sparse::pattern::{BlockPattern, SparsitySpec};
use crate::sparse::planner::sparse_search;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Harness-wide knobs. `mutate` arms the trip-wire: the named seeded
/// mutation is applied to every dense graph before verification, and the
/// `verify-clean` invariant *fails* exactly when the verifier catches it
/// with its expected rule — the failure the harness must find and shrink.
#[derive(Clone, Copy, Debug, Default)]
pub struct HarnessConfig {
    pub mutate: Option<(MutationClass, u64)>,
}

/// One registered pipeline invariant.
pub struct Invariant {
    pub name: &'static str,
    /// One-line description (the README invariant table row).
    pub what: &'static str,
    pub check: fn(&Scenario, &HarnessConfig) -> Option<String>,
}

/// The registered suite, in evaluation order (cheap planner-level
/// invariants first, serve-level ones last).
pub const INVARIANTS: &[Invariant] = &[
    Invariant {
        name: "plan-identity",
        what: "dense search returns a bit-identical plan for any worker count",
        check: inv_plan_identity,
    },
    Invariant {
        name: "staged-pricing",
        what: "staged cycles-only pricing picks the same fully-priced winner as full evaluation",
        check: inv_staged_pricing,
    },
    Invariant {
        name: "dense-identity",
        what: "density-1.0 sparse search reproduces the dense plan bit-for-bit",
        check: inv_dense_identity,
    },
    Invariant {
        name: "verify-clean",
        what: "analysis::verify is clean on every built graph (trip-wire hook)",
        check: inv_verify_clean,
    },
    Invariant {
        name: "serve-accounting",
        what: "served+degraded+shed+panicked == requests, zero lost, deadlines respected",
        check: inv_serve_accounting,
    },
    Invariant {
        name: "serve-identity",
        what: "serve outcomes are bit-identical across worker counts",
        check: inv_serve_identity,
    },
    Invariant {
        name: "obs-identity",
        what: "serve outcomes are bit-identical with the metrics recorder on vs off",
        check: inv_obs_identity,
    },
];

pub fn invariant_names() -> Vec<&'static str> {
    INVARIANTS.iter().map(|i| i.name).collect()
}

/// A scenario that violated an invariant.
#[derive(Clone, Debug)]
pub struct Failure {
    pub invariant: &'static str,
    pub detail: String,
}

/// Run the suite (or the single `only`-named invariant) over a scenario.
pub fn check_scenario(
    sc: &Scenario,
    cfg: &HarnessConfig,
    only: Option<&str>,
) -> Option<Failure> {
    for inv in INVARIANTS {
        if only.is_some_and(|name| name != inv.name) {
            continue;
        }
        if let Some(detail) = (inv.check)(sc, cfg) {
            return Some(Failure { invariant: inv.name, detail });
        }
    }
    None
}

/// Predicate form of [`check_scenario`] (the shrinker's `fails`).
pub fn scenario_fails(sc: &Scenario, cfg: &HarnessConfig, only: Option<&str>) -> bool {
    check_scenario(sc, cfg, only).is_some()
}

// ---- invariants -----------------------------------------------------------

fn fmt_shape(s: &MmShape) -> String {
    format!("{}x{}x{}", s.m, s.n, s.k)
}

fn inv_plan_identity(sc: &Scenario, _cfg: &HarnessConfig) -> Option<String> {
    let arch = sc.arch();
    let config = CostConfig::default();
    for (shape, _) in sc.unique_jobs() {
        let wide_workers = sc.plan_workers.max(2);
        let serial = search_with_workers(&arch, shape, config, 1);
        let wide = search_with_workers(&arch, shape, config, wide_workers);
        match (serial, wide) {
            (Ok(a), Ok(b)) => {
                if a.cost != b.cost || a.candidates_evaluated != b.candidates_evaluated {
                    return Some(format!(
                        "plan for {} differs between workers 1 and {wide_workers}: \
                         {:?} ({} candidates) vs {:?} ({} candidates)",
                        fmt_shape(&shape),
                        a.partition(),
                        a.candidates_evaluated,
                        b.partition(),
                        b.candidates_evaluated,
                    ));
                }
            }
            (Err(a), Err(b)) if a == b => {}
            (a, b) => {
                return Some(format!(
                    "feasibility verdict for {} differs between workers 1 and {wide_workers}: \
                     {} vs {}",
                    fmt_shape(&shape),
                    verdict(&a),
                    verdict(&b),
                ));
            }
        }
    }
    None
}

fn verdict<T>(r: &Result<T, crate::planner::search::PlannerError>) -> String {
    match r {
        Ok(_) => "plans".to_string(),
        Err(e) => format!("errs ({e})"),
    }
}

fn inv_staged_pricing(sc: &Scenario, _cfg: &HarnessConfig) -> Option<String> {
    let arch = sc.arch();
    let config = CostConfig::default();
    for (shape, _) in sc.unique_jobs() {
        let Ok(plan) = search_with_workers(&arch, shape, config, sc.plan_workers) else {
            continue; // OOM is a verdict, not a pricing question
        };
        let full = CostModel::with_config(&arch, config).evaluate(shape, plan.partition());
        if full != plan.cost {
            return Some(format!(
                "staged winner for {} prices differently under full evaluation: \
                 staged {} cycles vs full {} cycles at {:?}",
                fmt_shape(&shape),
                plan.cost.total_cycles,
                full.total_cycles,
                plan.partition(),
            ));
        }
    }
    None
}

fn inv_dense_identity(sc: &Scenario, _cfg: &HarnessConfig) -> Option<String> {
    let arch = sc.arch();
    let config = CostConfig::default();
    for (shape, _) in sc.unique_jobs() {
        let spec = SparsitySpec::dense(8);
        let pattern = BlockPattern::for_shape(spec, shape);
        match (search_with_workers(&arch, shape, config, 1), sparse_search(&arch, shape, &pattern)) {
            (Ok(dense), Ok(sparse)) => {
                if sparse.partition() != dense.partition() {
                    return Some(format!(
                        "density-1.0 sparse plan for {} picks {:?}, dense picks {:?}",
                        fmt_shape(&shape),
                        sparse.partition(),
                        dense.partition(),
                    ));
                }
                if sparse.dense_plan.as_ref().map(|p| p.cost) != Some(dense.cost) {
                    return Some(format!(
                        "density-1.0 sparse plan for {} does not carry the dense cost bit-for-bit",
                        fmt_shape(&shape),
                    ));
                }
            }
            (Err(_), Err(_)) => {} // both hit the wall: verdicts agree
            (dense, sparse) => {
                return Some(format!(
                    "density-1.0 feasibility for {} differs: dense {} vs sparse {}",
                    fmt_shape(&shape),
                    verdict(&dense),
                    verdict(&sparse),
                ));
            }
        }
    }
    None
}

fn inv_verify_clean(sc: &Scenario, cfg: &HarnessConfig) -> Option<String> {
    let arch = sc.arch();
    let config = CostConfig::default();
    let engine = SimEngine::new(arch.clone());
    for (shape, spec) in sc.unique_jobs() {
        match spec {
            None => {
                let Ok(plan) = search_with_workers(&arch, shape, config, sc.plan_workers) else {
                    continue;
                };
                let mut g = engine.build_graph(shape, &plan);
                let mut edit = None;
                if let Some((class, mseed)) = cfg.mutate {
                    edit = mutate::apply(&mut g, class, mseed);
                    if edit.is_none() {
                        continue; // no eligible mutation site at this shape
                    }
                }
                let ds = verify::verify_dense(&arch, shape, &plan, &g);
                match cfg.mutate {
                    Some((class, _)) => {
                        // trip-wire mode: "failure" = the seeded break was
                        // caught with its expected rule, which is what the
                        // harness must find and shrink
                        if ds.iter().any(|d| d.rule == class.expected_rule()) {
                            return Some(format!(
                                "seeded mutation [{}] on dense {} ({}) caught by rule '{}' \
                                 ({} diagnostic(s))",
                                class.name(),
                                fmt_shape(&shape),
                                edit.unwrap_or_default(),
                                class.expected_rule(),
                                ds.len(),
                            ));
                        }
                    }
                    None => {
                        if !ds.is_empty() {
                            return Some(format!(
                                "verifier found {} diagnostic(s) on clean dense {}: first rule '{}'",
                                ds.len(),
                                fmt_shape(&shape),
                                ds[0].rule,
                            ));
                        }
                    }
                }
            }
            Some(sp) => {
                if cfg.mutate.is_some() {
                    continue; // the mutation corpus targets dense graphs
                }
                let pattern = BlockPattern::for_shape(sp, shape);
                let Ok(plan) = sparse_search(&arch, shape, &pattern) else {
                    continue;
                };
                let g = engine.build_sparse_graph(shape, &plan, &pattern);
                let ds = verify::verify_sparse(&arch, shape, &plan, &pattern, &g);
                if !ds.is_empty() {
                    return Some(format!(
                        "verifier found {} diagnostic(s) on clean sparse {} ({}): first rule '{}'",
                        ds.len(),
                        fmt_shape(&shape),
                        sp.label(),
                        ds[0].rule,
                    ));
                }
            }
        }
    }
    None
}

fn service_for(sc: &Scenario, workers: usize) -> MmService {
    MmService::new(ServiceConfig {
        arch: sc.arch(),
        gpu: GpuArch::a30(),
        workers: Some(workers),
        faults: sc.fault_plan(),
        fault_policy: sc.policy(),
        ..ServiceConfig::default()
    })
}

fn serve_jobs(sc: &Scenario) -> Vec<(MmShape, Option<SparsitySpec>)> {
    sc.trace.iter().map(|(_, s, sp)| (*s, *sp)).collect()
}

fn inv_serve_accounting(sc: &Scenario, _cfg: &HarnessConfig) -> Option<String> {
    let jobs = serve_jobs(sc);
    let report = service_for(sc, sc.serve_workers).serve_trace_mixed(&jobs);
    let folded = chaos::ScenarioReport::from_serve(&sc.profile, jobs.len(), &report);
    let mut v = chaos::invariant_violations(&folded);
    v.extend(chaos::record_violations(&report, &sc.policy()));
    if v.is_empty() {
        None
    } else {
        Some(v.join("; "))
    }
}

/// The per-request outcome signature the identity invariants compare:
/// only model-time, worker-independent fields (wall-clock fields like
/// `plan_seconds` and batch composition legitimately vary with workers).
fn outcome_sig(report: &ServeReport) -> Vec<(u64, &'static str, String, u32, bool, u64, u64)> {
    let mut rows: Vec<_> = report
        .requests
        .iter()
        .map(|r| {
            (
                r.id,
                r.outcome.label(),
                r.backend.clone(),
                r.attempts,
                r.oom,
                r.device_seconds.to_bits(),
                r.retry_seconds.to_bits(),
            )
        })
        .collect();
    rows.sort();
    rows
}

fn first_sig_diff(
    a: &[(u64, &'static str, String, u32, bool, u64, u64)],
    b: &[(u64, &'static str, String, u32, bool, u64, u64)],
) -> String {
    if a.len() != b.len() {
        return format!("{} vs {} records", a.len(), b.len());
    }
    for (ra, rb) in a.iter().zip(b) {
        if ra != rb {
            return format!("request {}: {:?} vs {:?}", ra.0, ra, rb);
        }
    }
    "identical".to_string()
}

fn inv_serve_identity(sc: &Scenario, _cfg: &HarnessConfig) -> Option<String> {
    let jobs = serve_jobs(sc);
    let wide_workers = sc.serve_workers.max(2);
    let serial = outcome_sig(&service_for(sc, 1).serve_trace_mixed(&jobs));
    let wide = outcome_sig(&service_for(sc, wide_workers).serve_trace_mixed(&jobs));
    if serial != wide {
        return Some(format!(
            "serve outcomes differ between workers 1 and {wide_workers}: {}",
            first_sig_diff(&serial, &wide),
        ));
    }
    None
}

/// Serializes the process-global recorder toggle: the obs invariant is
/// the only fuzz path that flips it, and concurrent harness runs (e.g.
/// parallel tests) must not observe each other's enable window.
static OBS_TOGGLE: Mutex<()> = Mutex::new(());

fn inv_obs_identity(sc: &Scenario, _cfg: &HarnessConfig) -> Option<String> {
    let _gate = OBS_TOGGLE.lock().unwrap_or_else(|e| e.into_inner());
    let jobs = serve_jobs(sc);
    let was_enabled = crate::obs::enabled();
    crate::obs::disable();
    let off = outcome_sig(&service_for(sc, sc.serve_workers).serve_trace_mixed(&jobs));
    crate::obs::enable();
    let on = outcome_sig(&service_for(sc, sc.serve_workers).serve_trace_mixed(&jobs));
    crate::obs::disable();
    let _ = crate::obs::take(); // drain spans recorded during the window
    if was_enabled {
        crate::obs::enable();
    }
    if off != on {
        return Some(format!(
            "serve outcomes differ with metrics on vs off: {}",
            first_sig_diff(&off, &on),
        ));
    }
    None
}

// ---- fuzz loop + shrinker -------------------------------------------------

/// A found-and-shrunk invariant violation.
#[derive(Clone, Debug)]
pub struct FuzzFailure {
    pub invariant: &'static str,
    /// The scenario the fuzz loop first tripped on.
    pub original: Scenario,
    pub original_detail: String,
    /// The 1-minimal counterexample the shrinker converged to.
    pub minimal: Scenario,
    pub minimal_detail: String,
    /// Successful shrink steps taken (each one a strictly smaller
    /// still-failing scenario).
    pub shrink_steps: usize,
    /// `minimal.to_line()` — the deterministic one-line repro.
    pub replay: String,
    /// `describe_minimal`-style culprit report for the minimal scenario.
    pub culprit: String,
}

/// Outcome of a fuzz run.
#[derive(Clone, Debug)]
pub struct FuzzReport {
    pub seed: u64,
    pub iters: usize,
    /// Iterations that completed clean (== `iters` when no failure).
    pub completed: usize,
    pub failure: Option<FuzzFailure>,
}

impl FuzzReport {
    pub fn to_json(&self) -> Json {
        let mut doc = Json::obj();
        doc.set("seed", Json::Int(self.seed as i64));
        doc.set("iters", Json::Int(self.iters as i64));
        doc.set("completed", Json::Int(self.completed as i64));
        doc.set("clean", Json::Bool(self.failure.is_none()));
        match &self.failure {
            None => {
                doc.set("failure", Json::Null);
            }
            Some(f) => {
                let mut o = Json::obj();
                o.set("invariant", Json::Str(f.invariant.to_string()));
                o.set("original", Json::Str(f.original.to_line()));
                o.set("original_detail", Json::Str(f.original_detail.clone()));
                o.set("replay", Json::Str(f.replay.clone()));
                o.set("detail", Json::Str(f.minimal_detail.clone()));
                o.set("shrink_steps", Json::Int(f.shrink_steps as i64));
                o.set("culprit", Json::Str(f.culprit.clone()));
                doc.set("failure", o);
            }
        }
        doc
    }
}

/// The canonical trip-wire scenario: the same 1024² dense square `ipumm
/// check --mutate` uses, so every mutation class has an eligible site.
/// In mutate mode the fuzz loop tests it at iteration 0, making the
/// find deterministic; the shrinker then earns its keep reducing it.
pub fn mutation_probe_scenario() -> Scenario {
    Scenario::parse("v1;arch=gc200~0;pw=1;sw=1;prof=none;fseed=0;dl=none;retry=0;trace=0:1024x1024x1024")
        .expect("canonical probe line parses")
}

/// Shrink a failing scenario to a 1-minimal counterexample: repeatedly
/// take the first structural shrink candidate that still fails, until
/// none does. At exit no single candidate (trace-element removal, shape
/// halve/decrement, spec drop, density halve, policy/worker/arch
/// simplification) reproduces the failure — the bigcheck/ddmin loop
/// generalized from `fault::chaos::shrink_failing` to the full tuple.
pub fn shrink_scenario(
    sc: &Scenario,
    cfg: &HarnessConfig,
    invariant: &str,
) -> (Scenario, usize) {
    let mut cur = sc.clone();
    if !scenario_fails(&cur, cfg, Some(invariant)) {
        return (cur, 0);
    }
    let mut steps = 0usize;
    loop {
        let mut progressed = false;
        for cand in shrink_candidates(&cur) {
            if scenario_fails(&cand, cfg, Some(invariant)) {
                cur = cand;
                steps += 1;
                progressed = true;
                break;
            }
        }
        if !progressed {
            break;
        }
    }
    (cur, steps)
}

/// `describe_minimal`-style culprit report for a (minimal) scenario.
pub fn culprit_report(sc: &Scenario, invariant: &str, detail: &str) -> String {
    let mut lines = vec![format!("invariant '{invariant}': {detail}")];
    let plan = sc.fault_plan();
    for req in &sc.trace {
        lines.push(format!("  {}", chaos::describe_minimal(&plan, req)));
    }
    lines.push(format!(
        "  scenario: arch {}~{}, plan workers {}, serve workers {}, profile {}, \
         retries {}, deadline {}, {} request(s)",
        sc.arch_base.name(),
        sc.arch_perturb,
        sc.plan_workers,
        sc.serve_workers,
        sc.profile,
        sc.retries,
        sc.deadline_us.map_or("none".to_string(), |us| format!("{us}us")),
        sc.trace.len(),
    ));
    lines.join("\n")
}

/// The fuzz loop: grow `iters` scenarios from the seed ladder (sizes
/// ramping 0→1, bigcheck-style), check each against the suite (or the
/// single `only` invariant), and on the first failure shrink it and
/// return. In mutate mode iteration 0 tests [`mutation_probe_scenario`]
/// so the trip-wire find is deterministic for any seed.
pub fn fuzz(seed: u64, iters: usize, only: Option<&str>, cfg: &HarnessConfig) -> FuzzReport {
    for i in 0..iters {
        let sc = if i == 0 && cfg.mutate.is_some() {
            mutation_probe_scenario()
        } else {
            let case_seed = seed.wrapping_mul(0x9E37_79B9_7F4A_7C15).wrapping_add(i as u64);
            let size = if iters <= 1 { 1.0 } else { i as f64 / (iters - 1) as f64 };
            grow_scenario(&mut Rng::new(case_seed), size)
        };
        if let Some(f) = check_scenario(&sc, cfg, only) {
            let (minimal, shrink_steps) = shrink_scenario(&sc, cfg, f.invariant);
            let minimal_detail = check_scenario(&minimal, cfg, Some(f.invariant))
                .map(|x| x.detail)
                .unwrap_or_else(|| f.detail.clone());
            let replay = minimal.to_line();
            let culprit = culprit_report(&minimal, f.invariant, &minimal_detail);
            return FuzzReport {
                seed,
                iters,
                completed: i,
                failure: Some(FuzzFailure {
                    invariant: f.invariant,
                    original: sc,
                    original_detail: f.detail,
                    minimal,
                    minimal_detail,
                    shrink_steps,
                    replay,
                    culprit,
                }),
            };
        }
    }
    FuzzReport { seed, iters, completed: iters, failure: None }
}

#[cfg(test)]
mod tests {
    use super::*;

    // NOTE: no lib unit test here runs `obs-identity` — lib unit tests
    // only ever exercise the disabled-recorder path (the enable/disable
    // window is exercised by the fuzz_harness integration binary).

    fn tiny_clean_scenario() -> Scenario {
        Scenario::parse("v1;arch=gc200~0;pw=2;sw=2;prof=transient;fseed=7;dl=none;retry=2;trace=0:64x64x64,1:96x32x48:r8.500.3")
            .expect("tiny scenario parses")
    }

    #[test]
    fn registry_names_are_unique_and_stable() {
        let names = invariant_names();
        assert_eq!(names.len(), INVARIANTS.len());
        let mut sorted = names.clone();
        sorted.sort();
        sorted.dedup();
        assert_eq!(sorted.len(), names.len(), "duplicate invariant name");
        assert!(names.contains(&"verify-clean") && names.contains(&"serve-accounting"));
    }

    #[test]
    fn clean_scenario_passes_planner_level_invariants() {
        let sc = tiny_clean_scenario();
        let cfg = HarnessConfig::default();
        for name in ["plan-identity", "staged-pricing", "dense-identity", "verify-clean"] {
            let f = check_scenario(&sc, &cfg, Some(name));
            assert!(f.is_none(), "{name}: {:?}", f.map(|x| x.detail));
        }
    }

    #[test]
    fn clean_scenario_passes_serve_accounting_and_identity() {
        let sc = tiny_clean_scenario();
        let cfg = HarnessConfig::default();
        for name in ["serve-accounting", "serve-identity"] {
            let f = check_scenario(&sc, &cfg, Some(name));
            assert!(f.is_none(), "{name}: {:?}", f.map(|x| x.detail));
        }
    }

    #[test]
    fn mutation_probe_is_caught_by_verify_clean() {
        let cfg = HarnessConfig { mutate: Some((MutationClass::OverlapSpan, 1)) };
        let sc = mutation_probe_scenario();
        let f = check_scenario(&sc, &cfg, Some("verify-clean"))
            .expect("seeded overlap-span mutation must be caught");
        assert_eq!(f.invariant, "verify-clean");
        assert!(f.detail.contains("overlap-span"), "{}", f.detail);
        assert!(f.detail.contains("race-write-write"), "{}", f.detail);
    }

    #[test]
    fn shrink_returns_input_when_nothing_fails() {
        let sc = tiny_clean_scenario();
        let cfg = HarnessConfig::default();
        let (min, steps) = shrink_scenario(&sc, &cfg, "plan-identity");
        assert_eq!(steps, 0);
        assert_eq!(min, sc);
    }

    #[test]
    fn fuzz_report_json_shape() {
        let rep = FuzzReport { seed: 7, iters: 3, completed: 3, failure: None };
        let doc = Json::parse(&rep.to_json().render()).unwrap();
        assert_eq!(doc.get("clean"), Some(&Json::Bool(true)));
        assert_eq!(doc.get("completed"), Some(&Json::Int(3)));
        assert_eq!(doc.get("failure"), Some(&Json::Null));
    }
}
