//! Seeded, size-bounded generators for the whole-pipeline fuzz scenario
//! tuple, plus the one-line replay spec that makes every failure a
//! deterministic repro.
//!
//! The design is bigcheck-style (SNIPPETS.md): every axis of a
//! [`Scenario`] is grown from a seeded [`Rng`] under a size knob in
//! [0, 1] (early iterations draw small shapes and short traces, later
//! ones the full range), and shrunk *structurally* — each axis offers a
//! finite list of strictly-smaller candidate scenarios
//! ([`shrink_candidates`]) that the harness's ddmin loop re-tests until
//! no single step still fails. That generalizes PR 8's
//! `fault::chaos::shrink_failing` from (request, fault) traces to the
//! full tuple: shape axes shrink toward 1, density toward the failing
//! boundary, the trace toward one request, workers toward 1, the
//! perturbed architecture toward the canonical GC200.
//!
//! Trace ids are **positional** (0..len): the serve path ids requests by
//! position, so the shrinker renumbers after every removal and the
//! failure predicate is re-evaluated on the renumbered candidate — ddmin
//! stays sound without assuming fault draws survive removal.

use crate::arch::IpuArch;
use crate::fault::chaos::ChaosRequest;
use crate::fault::plan::{FaultPlan, FaultProfile};
use crate::fault::retry::{FaultPolicy, RetryPolicy};
use crate::planner::partition::MmShape;
use crate::sparse::pattern::{PatternKind, SparsitySpec, BLOCK_SIZES};
use crate::util::rng::Rng;

/// Canonical architecture a scenario perturbs from. `IpuArch::name` is a
/// `&'static str`, so a perturbed variant keeps its base name — the
/// perturbation seed travels in the replay spec (`arch=gc200~7`) and the
/// perturbed fields land in `IpuArch::fingerprint`, which is what cache
/// keys and plan identity actually read.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArchBase {
    Gc200,
    Gc2,
    /// Not grown by the generator (the paper's square/skew findings are
    /// GC200/GC2), but replayable so `ipumm chaos --arch bow --shrink`
    /// scenarios round-trip through the spec line.
    Bow,
}

impl ArchBase {
    pub fn name(&self) -> &'static str {
        match self {
            ArchBase::Gc200 => "gc200",
            ArchBase::Gc2 => "gc2",
            ArchBase::Bow => "bow",
        }
    }

    pub fn by_name(name: &str) -> Option<ArchBase> {
        match name {
            "gc200" => Some(ArchBase::Gc200),
            "gc2" => Some(ArchBase::Gc2),
            "bow" => Some(ArchBase::Bow),
            _ => None,
        }
    }

    pub fn arch(&self) -> IpuArch {
        match self {
            ArchBase::Gc200 => IpuArch::gc200(),
            ArchBase::Gc2 => IpuArch::gc2(),
            ArchBase::Bow => IpuArch::bow2000(),
        }
    }
}

/// SplitMix64 finalizer — the same integer-only mixer the fault plan
/// uses for its draws; perturbation draws stay float-free.
fn splitmix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// The complete generated scenario: everything the pipeline invariants
/// need, and nothing drawn outside the seed — two scenarios with equal
/// fields behave identically on any machine and worker count.
#[derive(Clone, Debug, PartialEq)]
pub struct Scenario {
    pub arch_base: ArchBase,
    /// 0 = the canonical device; otherwise a deterministic perturbation
    /// of tiles / SRAM / sync cost (see [`Scenario::arch`]).
    pub arch_perturb: u64,
    /// Worker request for planner searches (compared against 1 by the
    /// plan-identity invariant).
    pub plan_workers: usize,
    /// Worker request for the serve layer (a request against the
    /// process-wide `ThreadBudget`, like `--workers`).
    pub serve_workers: usize,
    /// Fault profile name (see `FaultProfile::names`).
    pub profile: String,
    pub fault_seed: u64,
    /// Model-time deadline in microseconds (`None` = no deadline).
    pub deadline_us: Option<u64>,
    pub retries: u32,
    /// Positional-id trace; each request optionally carries a sparsity
    /// spec. Ids are always 0..len (see module docs).
    pub trace: Vec<ChaosRequest>,
}

impl Scenario {
    /// Materialize the (possibly perturbed) device. Perturbation is
    /// integer-only and bounded: tiles shrink by up to 1/8, per-tile
    /// SRAM by up to 1/4, sync cost grows by up to 64 cycles — enough to
    /// move plan choices and the memory wall without leaving the space
    /// of plausible devices.
    pub fn arch(&self) -> IpuArch {
        let mut arch = self.arch_base.arch();
        if self.arch_perturb != 0 {
            let d0 = splitmix64(self.arch_perturb);
            let d1 = splitmix64(d0);
            let d2 = splitmix64(d1);
            let tile_cut = (d0 % (arch.tiles as u64 / 8 + 1)) as usize;
            arch.tiles = (arch.tiles - tile_cut).max(4);
            let sram_cut = d1 % (arch.tile_sram_bytes / 4 + 1);
            arch.tile_sram_bytes = (arch.tile_sram_bytes - sram_cut).max(64 * 1024);
            arch.sync_cycles += d2 % 65;
        }
        arch
    }

    pub fn profile(&self) -> FaultProfile {
        // the generator and parser only emit known names
        FaultProfile::by_name(&self.profile).expect("scenario carries a known profile name")
    }

    pub fn fault_plan(&self) -> FaultPlan {
        if self.profile == "none" {
            FaultPlan::none()
        } else {
            FaultPlan::seeded(self.fault_seed, self.profile())
        }
    }

    pub fn policy(&self) -> FaultPolicy {
        FaultPolicy {
            deadline_s: self.deadline_us.map(|us| us as f64 / 1e6),
            retry: RetryPolicy::standard(self.retries),
            breaker: crate::fault::breaker::BreakerConfig::standard(),
        }
    }

    /// The unique `(shape, spec)` pairs in trace order — the working set
    /// the per-plan invariants (identity, pricing, verify) iterate.
    pub fn unique_jobs(&self) -> Vec<(MmShape, Option<SparsitySpec>)> {
        let mut seen: Vec<(MmShape, Option<SparsitySpec>)> = Vec::new();
        for (_, shape, spec) in &self.trace {
            if !seen.iter().any(|(s, sp)| s == shape && sp == spec) {
                seen.push((*shape, *spec));
            }
        }
        seen
    }

    /// A rough structural size (for shrink-progress reporting).
    pub fn weight(&self) -> u64 {
        let dims: u64 = self
            .trace
            .iter()
            .map(|(_, s, sp)| (s.m + s.n + s.k) as u64 + sp.map_or(0, |x| x.density_permille as u64))
            .sum();
        dims + self.trace.len() as u64 * 1000
            + self.plan_workers as u64
            + self.serve_workers as u64
            + (self.arch_perturb != 0) as u64
            + (self.profile != "none") as u64
            + self.retries as u64
            + self.deadline_us.is_some() as u64
    }

    /// Encode as the one-line replay spec `ipumm fuzz --replay` accepts.
    /// Fixed key order and integer-only values make the line a
    /// byte-stable artifact: equal scenarios render equal lines.
    pub fn to_line(&self) -> String {
        let mut parts = vec![
            "v1".to_string(),
            format!("arch={}~{}", self.arch_base.name(), self.arch_perturb),
            format!("pw={}", self.plan_workers),
            format!("sw={}", self.serve_workers),
            format!("prof={}", self.profile),
            format!("fseed={}", self.fault_seed),
            match self.deadline_us {
                Some(us) => format!("dl={us}"),
                None => "dl=none".to_string(),
            },
            format!("retry={}", self.retries),
        ];
        let trace: Vec<String> = self
            .trace
            .iter()
            .map(|(id, shape, spec)| {
                let mut s = format!("{id}:{}x{}x{}", shape.m, shape.n, shape.k);
                if let Some(sp) = spec {
                    let kind = match sp.kind {
                        PatternKind::Random => 'r',
                        PatternKind::Banded => 'b',
                        PatternKind::BlockDiagonal => 'd',
                    };
                    s.push_str(&format!(
                        ":{kind}{}.{}.{}",
                        sp.block, sp.density_permille, sp.seed
                    ));
                }
                s
            })
            .collect();
        parts.push(format!("trace={}", trace.join(",")));
        parts.join(";")
    }

    /// Parse a replay line back into a scenario. Inverse of
    /// [`Scenario::to_line`]: `parse(sc.to_line()) == sc` for every
    /// scenario the generator can emit.
    pub fn parse(line: &str) -> Result<Scenario, String> {
        let mut fields = line.trim().split(';');
        if fields.next() != Some("v1") {
            return Err("replay spec must start with 'v1;'".to_string());
        }
        let mut arch_base = None;
        let mut arch_perturb = 0u64;
        let mut plan_workers = 1usize;
        let mut serve_workers = 1usize;
        let mut profile = "none".to_string();
        let mut fault_seed = 0u64;
        let mut deadline_us = None;
        let mut retries = 0u32;
        let mut trace = Vec::new();
        for field in fields {
            let (key, val) = field
                .split_once('=')
                .ok_or_else(|| format!("bad replay field '{field}' (want key=value)"))?;
            match key {
                "arch" => {
                    let (base, perturb) = val
                        .split_once('~')
                        .ok_or_else(|| format!("bad arch '{val}' (want base~perturb)"))?;
                    arch_base = Some(
                        ArchBase::by_name(base)
                            .ok_or_else(|| format!("unknown arch base '{base}'"))?,
                    );
                    arch_perturb =
                        perturb.parse().map_err(|_| format!("bad arch perturb '{perturb}'"))?;
                }
                "pw" => {
                    plan_workers = val.parse().map_err(|_| format!("bad pw '{val}'"))?;
                }
                "sw" => {
                    serve_workers = val.parse().map_err(|_| format!("bad sw '{val}'"))?;
                }
                "prof" => {
                    if FaultProfile::by_name(val).is_none() {
                        return Err(format!(
                            "unknown fault profile '{val}' (known: {})",
                            FaultProfile::names().join(", ")
                        ));
                    }
                    profile = val.to_string();
                }
                "fseed" => {
                    fault_seed = val.parse().map_err(|_| format!("bad fseed '{val}'"))?;
                }
                "dl" => {
                    deadline_us = if val == "none" {
                        None
                    } else {
                        Some(val.parse().map_err(|_| format!("bad dl '{val}'"))?)
                    };
                }
                "retry" => {
                    retries = val.parse().map_err(|_| format!("bad retry '{val}'"))?;
                }
                "trace" => {
                    for item in val.split(',').filter(|s| !s.is_empty()) {
                        trace.push(parse_request(item)?);
                    }
                }
                other => return Err(format!("unknown replay field '{other}'")),
            }
        }
        let arch_base = arch_base.ok_or("replay spec missing 'arch='")?;
        if trace.is_empty() {
            return Err("replay spec has an empty trace".to_string());
        }
        if plan_workers == 0 || serve_workers == 0 {
            return Err("worker counts must be >= 1".to_string());
        }
        Ok(Scenario {
            arch_base,
            arch_perturb,
            plan_workers,
            serve_workers,
            profile,
            fault_seed,
            deadline_us,
            retries,
            trace,
        })
    }
}

fn parse_request(item: &str) -> Result<ChaosRequest, String> {
    let mut cols = item.split(':');
    let id: u64 = cols
        .next()
        .and_then(|c| c.parse().ok())
        .ok_or_else(|| format!("bad trace item '{item}' (want id:MxNxK[:spec])"))?;
    let dims = cols.next().ok_or_else(|| format!("trace item '{item}' missing shape"))?;
    let mut d = dims.split('x');
    let (m, n, k) = match (d.next(), d.next(), d.next(), d.next()) {
        (Some(m), Some(n), Some(k), None) => (
            m.parse().map_err(|_| format!("bad m in '{dims}'"))?,
            n.parse().map_err(|_| format!("bad n in '{dims}'"))?,
            k.parse().map_err(|_| format!("bad k in '{dims}'"))?,
        ),
        _ => return Err(format!("bad shape '{dims}' (want MxNxK)")),
    };
    if m == 0 || n == 0 || k == 0 {
        return Err(format!("degenerate shape '{dims}' (dims must be >= 1)"));
    }
    let spec = match cols.next() {
        None => None,
        Some(sp) => Some(parse_spec(sp)?),
    };
    if cols.next().is_some() {
        return Err(format!("trailing columns in trace item '{item}'"));
    }
    Ok((id, MmShape::new(m, n, k), spec))
}

fn parse_spec(sp: &str) -> Result<SparsitySpec, String> {
    let mut chars = sp.chars();
    let kind = match chars.next() {
        Some('r') => PatternKind::Random,
        Some('b') => PatternKind::Banded,
        Some('d') => PatternKind::BlockDiagonal,
        other => return Err(format!("bad spec kind '{other:?}' in '{sp}' (r|b|d)")),
    };
    let rest: String = chars.collect();
    let mut nums = rest.split('.');
    let (block, permille, seed) = match (nums.next(), nums.next(), nums.next(), nums.next()) {
        (Some(b), Some(p), Some(s), None) => (
            b.parse::<usize>().map_err(|_| format!("bad block in '{sp}'"))?,
            p.parse::<u32>().map_err(|_| format!("bad permille in '{sp}'"))?,
            s.parse::<u64>().map_err(|_| format!("bad seed in '{sp}'"))?,
        ),
        _ => return Err(format!("bad spec '{sp}' (want kB.P.S)")),
    };
    if !BLOCK_SIZES.contains(&block) {
        return Err(format!("block {block} not in supported sizes {BLOCK_SIZES:?}"));
    }
    if permille == 0 || permille > 1000 {
        return Err(format!("density permille {permille} out of [1, 1000]"));
    }
    Ok(SparsitySpec { kind, block, density_permille: permille, seed })
}

/// Largest shape dimension the generator emits at full size. Bounded so
/// a CI-sized fuzz run prices hundreds of scenarios in seconds — the
/// determinism invariants are dimension-uniform, so small shapes probe
/// the same code paths the 4096² mysteries would.
pub const MAX_DIM: usize = 384;

/// Longest trace at full size.
pub const MAX_TRACE: usize = 6;

fn grow_dim(rng: &mut Rng, size: f64) -> usize {
    let hi = 8 + ((MAX_DIM - 8) as f64 * size) as usize;
    rng.gen_usize(1, hi.max(1))
}

fn grow_shape(rng: &mut Rng, size: f64) -> MmShape {
    match rng.gen_usize(0, 9) {
        // squared (the paper's Fig. 4 axis)
        0..=3 => MmShape::square(grow_dim(rng, size).max(2)),
        // skewed (Fig. 5): independent dims
        4..=7 => MmShape::new(grow_dim(rng, size), grow_dim(rng, size), grow_dim(rng, size)),
        // degenerate: one axis collapsed to 1 (vector / outer products)
        _ => {
            let mut dims = [grow_dim(rng, size), grow_dim(rng, size), grow_dim(rng, size)];
            dims[rng.gen_usize(0, 2)] = 1;
            MmShape::new(dims[0], dims[1], dims[2])
        }
    }
}

fn grow_spec(rng: &mut Rng, size: f64) -> Option<SparsitySpec> {
    if rng.gen_bool(0.6) {
        return None;
    }
    let kind = *rng.choose(&PatternKind::all());
    let block = *rng.choose(&BLOCK_SIZES);
    let lo = 1000 - (950.0 * size) as u32; // small sizes stay near-dense
    let permille = rng.gen_range(lo as u64, 1000) as u32;
    let seed = rng.gen_range(0, 0xFFFF);
    Some(SparsitySpec { kind, block, density_permille: permille, seed })
}

/// Grow one scenario at the given size in [0, 1].
pub fn grow_scenario(rng: &mut Rng, size: f64) -> Scenario {
    let size = size.clamp(0.0, 1.0);
    let arch_base = if rng.gen_bool(0.75) { ArchBase::Gc200 } else { ArchBase::Gc2 };
    let arch_perturb = if rng.gen_bool(0.3) { rng.gen_range(1, 0xFFFF) } else { 0 };
    let max_workers = 1 + (3.0 * size) as usize;
    let plan_workers = rng.gen_usize(1, max_workers);
    let serve_workers = rng.gen_usize(1, max_workers);
    let profile = (*rng.choose(FaultProfile::names())).to_string();
    let fault_seed = rng.gen_range(0, 0xFFFF);
    let deadline_us = if rng.gen_bool(0.35) {
        Some(*rng.choose(&[500u64, 1_000, 5_000, 20_000]))
    } else {
        None
    };
    let retries = rng.gen_range(0, 3) as u32;
    let len = rng.gen_usize(1, 1 + ((MAX_TRACE - 1) as f64 * size) as usize);
    let trace = (0..len as u64)
        .map(|id| (id, grow_shape(rng, size), grow_spec(rng, size)))
        .collect();
    Scenario {
        arch_base,
        arch_perturb,
        plan_workers,
        serve_workers,
        profile,
        fault_seed,
        deadline_us,
        retries,
        trace,
    }
}

/// Renumber trace ids positionally (the serve path ids by position, so
/// every shrink candidate is renumbered before re-testing).
fn renumber(trace: &mut [ChaosRequest]) {
    for (i, req) in trace.iter_mut().enumerate() {
        req.0 = i as u64;
    }
}

/// Structurally-smaller neighbors of `sc`, biggest reductions first:
/// trace chunk removals (halves down to single requests, ddmin-style),
/// then per-request shape halving/decrement toward 1, sparsity-spec
/// drops and density halving toward the failing boundary, policy axes
/// (profile → none, deadline → off, retries → 0), worker counts toward
/// 1, and the perturbed arch toward canonical GC200.
///
/// The harness's shrink loop ([`crate::fuzz::harness::shrink_scenario`])
/// takes the first candidate that still fails and restarts; a scenario
/// on which *no* candidate fails is 1-minimal by construction.
pub fn shrink_candidates(sc: &Scenario) -> Vec<Scenario> {
    let mut out = Vec::new();
    // 1. trace removals: ddmin chunk ladder, larger chunks first
    let len = sc.trace.len();
    if len > 1 {
        let mut chunk = len.div_ceil(2);
        loop {
            let mut start = 0;
            while start < len {
                let end = (start + chunk).min(len);
                if end - start < len {
                    let mut c = sc.clone();
                    c.trace.drain(start..end);
                    renumber(&mut c.trace);
                    out.push(c);
                }
                start += chunk;
            }
            if chunk == 1 {
                break;
            }
            chunk = chunk.div_ceil(2).max(1);
        }
    }
    // 2. shape axes: halve, then decrement, toward 1
    for (i, (_, shape, _)) in sc.trace.iter().enumerate() {
        for axis in 0..3usize {
            let dim = [shape.m, shape.n, shape.k][axis];
            for smaller in [dim / 2, dim - 1] {
                if smaller >= 1 && smaller < dim {
                    let mut c = sc.clone();
                    let s = &mut c.trace[i].1;
                    match axis {
                        0 => s.m = smaller,
                        1 => s.n = smaller,
                        _ => s.k = smaller,
                    }
                    if !out.contains(&c) {
                        out.push(c);
                    }
                }
            }
        }
    }
    // 3. sparsity: drop the spec, then halve density toward the boundary
    for (i, (_, _, spec)) in sc.trace.iter().enumerate() {
        if let Some(sp) = spec {
            let mut c = sc.clone();
            c.trace[i].2 = None;
            out.push(c);
            if sp.density_permille > 1 {
                let mut c = sc.clone();
                c.trace[i].2 =
                    Some(SparsitySpec { density_permille: sp.density_permille / 2, ..*sp });
                out.push(c);
            }
        }
    }
    // 4. fault/policy axes
    if sc.profile != "none" {
        let mut c = sc.clone();
        c.profile = "none".to_string();
        out.push(c);
    }
    if sc.deadline_us.is_some() {
        let mut c = sc.clone();
        c.deadline_us = None;
        out.push(c);
    }
    if sc.retries > 0 {
        let mut c = sc.clone();
        c.retries = 0;
        out.push(c);
    }
    // 5. workers toward 1
    if sc.plan_workers > 1 {
        let mut c = sc.clone();
        c.plan_workers = 1;
        out.push(c);
    }
    if sc.serve_workers > 1 {
        let mut c = sc.clone();
        c.serve_workers = 1;
        out.push(c);
    }
    // 6. arch toward the canonical paper device
    if sc.arch_perturb != 0 {
        let mut c = sc.clone();
        c.arch_perturb = 0;
        out.push(c);
    }
    if sc.arch_base != ArchBase::Gc200 {
        let mut c = sc.clone();
        c.arch_base = ArchBase::Gc200;
        out.push(c);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn replay_line_round_trips_every_generated_scenario() {
        let mut rng = Rng::new(0xF022);
        for case in 0..64 {
            let size = case as f64 / 63.0;
            let sc = grow_scenario(&mut rng, size);
            let line = sc.to_line();
            let back = Scenario::parse(&line).unwrap_or_else(|e| panic!("{line}: {e}"));
            assert_eq!(back, sc, "round trip through {line}");
            assert_eq!(back.to_line(), line, "re-render is byte-identical");
        }
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        for bad in [
            "",
            "v2;arch=gc200~0;trace=0:8x8x8",
            "v1;arch=gc200~0",                              // no trace
            "v1;arch=gc3~0;trace=0:8x8x8",                  // unknown base
            "v1;arch=gc200~0;prof=meteor;trace=0:8x8x8",    // unknown profile
            "v1;arch=gc200~0;trace=0:8x8",                  // 2-d shape
            "v1;arch=gc200~0;trace=0:0x8x8",                // zero dim
            "v1;arch=gc200~0;trace=0:8x8x8:z8.100.1",       // bad kind
            "v1;arch=gc200~0;trace=0:8x8x8:r7.100.1",       // bad block
            "v1;arch=gc200~0;trace=0:8x8x8:r8.2000.1",      // bad permille
            "v1;arch=gc200~0;pw=0;trace=0:8x8x8",           // zero workers
            "v1;bogus=1;arch=gc200~0;trace=0:8x8x8",        // unknown field
        ] {
            assert!(Scenario::parse(bad).is_err(), "accepted: {bad}");
        }
    }

    #[test]
    fn grow_is_deterministic_for_a_seed() {
        let a = grow_scenario(&mut Rng::new(42), 0.5);
        let b = grow_scenario(&mut Rng::new(42), 0.5);
        assert_eq!(a, b);
        assert_eq!(a.to_line(), b.to_line());
    }

    #[test]
    fn grow_respects_size_bounds() {
        let mut rng = Rng::new(7);
        for _ in 0..32 {
            let sc = grow_scenario(&mut rng, 0.0);
            assert_eq!(sc.trace.len(), 1, "size 0 grows single-request traces");
            for (_, shape, _) in &sc.trace {
                assert!(shape.m <= 8 && shape.n <= 8 && shape.k <= 8, "{shape:?}");
            }
        }
        let sc = grow_scenario(&mut rng, 1.0);
        for (_, shape, _) in &sc.trace {
            assert!(shape.m <= MAX_DIM && shape.n <= MAX_DIM && shape.k <= MAX_DIM);
        }
    }

    #[test]
    fn shrink_candidates_are_strictly_smaller_and_renumbered() {
        let mut rng = Rng::new(0x51AB);
        let sc = grow_scenario(&mut rng, 1.0);
        for c in shrink_candidates(&sc) {
            assert!(c.weight() < sc.weight(), "candidate not smaller: {}", c.to_line());
            for (i, (id, ..)) in c.trace.iter().enumerate() {
                assert_eq!(*id, i as u64, "ids stay positional");
            }
            assert!(!c.trace.is_empty(), "never shrinks to an empty trace");
        }
    }

    #[test]
    fn perturbed_arch_changes_fingerprint_but_keeps_base_name() {
        let canonical = Scenario::parse("v1;arch=gc200~0;trace=0:8x8x8").unwrap();
        let perturbed = Scenario::parse("v1;arch=gc200~7;trace=0:8x8x8").unwrap();
        let (a, b) = (canonical.arch(), perturbed.arch());
        assert_eq!(a.name, b.name);
        assert_ne!(a.fingerprint(), b.fingerprint());
        assert!(b.tiles >= 4 && b.tile_sram_bytes >= 64 * 1024);
        // same perturbation seed → same device, every time
        assert_eq!(perturbed.arch().fingerprint(), b.fingerprint());
    }
}
