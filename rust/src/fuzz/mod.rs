//! Generative chaos harness: whole-pipeline fuzzing with automatic
//! shrinking to minimal counterexamples (crate role 12; ROADMAP §5's
//! dynamic half).
//!
//! [`generate`] grows the complete scenario tuple — perturbed
//! architecture, square/skewed/degenerate shapes, sparsity specs, a
//! request trace, fault profile + policy, worker counts — from a seeded
//! RNG under a bigcheck-style size knob, and offers structural shrink
//! candidates per axis. [`harness`] registers the pipeline invariant
//! suite (plan worker-count bit-identity, staged == full pricing,
//! density-1.0 dense identity, verifier cleanliness, serve accounting
//! exactness, serve and metrics bit-identity), drives the fuzz loop,
//! and shrinks any failure to a 1-minimal scenario with a deterministic
//! one-line replay (`ipumm fuzz --replay <spec>`).

pub mod generate;
pub mod harness;

pub use generate::{grow_scenario, shrink_candidates, ArchBase, Scenario};
pub use harness::{
    check_scenario, culprit_report, fuzz, invariant_names, mutation_probe_scenario,
    scenario_fails, shrink_scenario, Failure, FuzzFailure, FuzzReport, HarnessConfig, Invariant,
    INVARIANTS,
};
