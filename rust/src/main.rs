//! ipumm — CLI for the IPU squared/skewed matmul reproduction.
//!
//! Each subcommand regenerates one paper artifact (see DESIGN.md §4):
//!
//! ```text
//! ipumm table1                 Table 1 spec comparison
//! ipumm fig4   [--max-size N]  Fig. 4 squared sweep, IPU vs GPU
//! ipumm fig5   [--ks 1024,2048] Fig. 5 aspect-ratio sweep
//! ipumm vertices               §5.1 vertex census triple
//! ipumm memory                 §2.4 max-square memory study
//! ipumm phases                 Fig. 3 BSP phase breakdown
//! ipumm profile m n k [--json] PopVision-style profile of one shape
//!              [--chrome FILE] (--chrome records the run and writes a
//!                              Chrome trace-event JSON: planner stripes
//!                              in wall time + the BSP superstep timeline
//!                              in model cycles; open in chrome://tracing
//!                              or Perfetto)
//! ipumm plan m n k [--workers N]
//!                              show the planner's chosen partition
//!                              (prints the effective thread budget)
//! ipumm run m n k [--real]     one shape on all backends (+PJRT verify)
//! ipumm ablation               cost-model ablation study
//! ipumm trace [--jobs N]       trace-driven latency/throughput study
//! ipumm serve [--jobs N] [--cache N] [--batch N] [--warmup N]
//!             [--trace-out FILE] [--metrics-out FILE]
//!             [--slo "p99<5ms@99%[;...]"] [--window N]
//!             [--deadline-ms MS] [--retries N] [--fault-seed N]
//!             [--fault-profile NAME]
//!                              matmul-as-a-service demo (plan cache,
//!                              shape bucketing, coalescing dispatch;
//!                              --artifacts DIR + --features xla anchors
//!                              cold buckets to real PJRT execution;
//!                              --trace-out records workers, planner,
//!                              cache, and thread-budget activity to a
//!                              Chrome trace-event JSON; --metrics-out
//!                              writes Prometheus text at FILE plus a
//!                              JSON snapshot at FILE.json with the
//!                              per-window timeline; --slo evaluates
//!                              ';'-separated SLO specs and exits
//!                              nonzero when one is violated;
//!                              --fault-seed/--fault-profile turn on
//!                              seeded fault injection and
//!                              --deadline-ms/--retries configure the
//!                              per-request deadline + retry + circuit
//!                              breaker policy — every request then ends
//!                              in an explicit served/degraded/shed/
//!                              panicked outcome)
//! ipumm chaos [--jobs N] [--seed N] [--profiles a,b,...] [--json FILE]
//!             [--deadline-ms MS] [--retries N] [--workers N]
//!                              fault-injection scenario matrix over the
//!                              seeded paper-mix trace: runs each named
//!                              fault profile (none|transient|
//!                              transient-heavy|slow|breaker-trip|
//!                              gpu-outage|panic|mixed) through the
//!                              serving layer and prints a recovery
//!                              report (outcome accounting, retries,
//!                              breaker transitions, latency quantiles);
//!                              exits nonzero if any request is lost or
//!                              outcome accounting does not balance;
//!                              --json dumps the report; --shrink invokes
//!                              the fuzz harness's full-tuple shrinker on
//!                              a failing scenario and prints the minimal
//!                              one-line replay instead of the raw table
//! ipumm fuzz [--seed N] [--iters K] [--invariant NAME] [--json FILE]
//!            [--replay SPEC] [--mutate CLASS]
//!                              generative whole-pipeline fuzzing: grow K
//!                              seeded scenarios (perturbed arch, shapes,
//!                              sparsity, trace, faults, workers) and
//!                              check each against the registered
//!                              invariant suite (plan/serve/metrics
//!                              bit-identity, staged pricing, dense
//!                              identity, verifier cleanliness, serve
//!                              accounting); on failure, shrink to a
//!                              1-minimal counterexample and print a
//!                              deterministic replay line + culprit
//!                              report, exiting nonzero. --replay SPEC
//!                              re-runs one scenario from its replay
//!                              line; --invariant restricts the suite;
//!                              --mutate CLASS is the trip-wire twin of
//!                              `check --mutate`: the harness must find
//!                              and shrink the seeded graph mutation
//!                              (exit nonzero), so CI wraps it in an
//!                              expect-failure
//! ipumm slo-check --slo SPEC [--jobs N] [--seed N] [--window N]
//!           | --snapshot FILE  SLO gate: serve the demo trace (or read
//!                              a --metrics-out JSON snapshot) and exit
//!                              nonzero when any SLO is violated
//! ipumm sparse [--k N] [--block 4|8|16] [--kind random|banded|blockdiag]
//!              [--densities 1.0,0.5,...] [--seed N] [--json FILE]
//!                              block-sparse density x skew sweep
//!                              (dense-equivalent + effective TFlop/s,
//!                              per-density predicted memory wall;
//!                              --json dumps the wall curve)
//! ipumm bench-check [--dir D] [--tolerance PCT] [--against PREV_DIR]
//!                              CI regression gate: parse BENCH_*.json
//!                              and fail when a benchmark regressed more
//!                              than PCT% (default 20) vs its in-run
//!                              frozen baseline; --against additionally
//!                              compares baseline-normalized means to a
//!                              previous run's BENCH_*.json (the CI
//!                              cross-run trend gate). Missing, unreadable,
//!                              or malformed artifacts are skipped with an
//!                              advisory diagnostic — the gate exits
//!                              nonzero only on a confirmed regression
//! ipumm check [--json FILE] [--src DIR] [--mutate CLASS] [--seed N]
//!                              static verification gate: run the IR
//!                              verifier (races, Sync ordering, dead
//!                              exchange phases, liveness, SRAM capacity,
//!                              planner-bill cross-check) over the Fig. 4
//!                              dense shapes + a past-the-wall sparse
//!                              shape, then the repo-invariant lint over
//!                              --src (default rust/src); exits nonzero
//!                              on any diagnostic; --json dumps the full
//!                              report. --mutate CLASS (overlap-span|
//!                              drop-exchange|skew-residency|
//!                              reorder-superstep) is the CI trip-wire:
//!                              apply one seeded mutation and exit
//!                              nonzero iff the verifier catches it with
//!                              the expected rule — so CI wraps it in an
//!                              expect-failure and a blind or misfiring
//!                              verifier fails the build
//! ipumm streaming              §6 streaming-memory extension
//! ipumm multiipu               §6 multi-IPU scaling extension
//! ipumm e2e [--artifacts DIR]  end-to-end driver with real numerics
//! ipumm all                    every experiment, in order
//! ```
//!
//! Global options: --arch gc200|gc2|bow, --gpu a30|rtx2080ti|v100,
//! --csv FILE, --workers N.

#[cfg(feature = "xla")]
use std::path::Path;

use anyhow::{bail, Context, Result};

use ipumm::arch::{GpuArch, IpuArch};
use ipumm::coordinator::device::{run_shape, Backend};
#[cfg(feature = "xla")]
use ipumm::experiments::e2e;
use ipumm::experiments::{
    ablation, fig4, fig5, fp16, memory_study, multi_ipu_x, phases, sparse_sweep, streaming,
    table1, vertices,
};
use ipumm::coordinator::runner::ThreadBudget;
use ipumm::fault::{FaultPlan, FaultPolicy, FaultProfile};
use ipumm::planner::cost::CostConfig;
use ipumm::planner::partition::MmShape;
use ipumm::planner::search::{search_with_workers, search_workers};
use ipumm::sparse::pattern::PatternKind;
use ipumm::profiler::popvision::PopVisionReport;
#[cfg(feature = "xla")]
use ipumm::runtime::blockmm::BlockMmExecutor;
use ipumm::serve::{MmService, ServiceConfig};
use ipumm::sim::engine::SimEngine;
use ipumm::util::cli::Args;
#[cfg(feature = "xla")]
use ipumm::util::matrix::Matrix;
use ipumm::util::units::{fmt_bytes, fmt_tflops};

const OPTIONS: &[&str] = &[
    "arch", "gpu", "csv", "json", "workers", "max-size", "ks", "artifacts", "block", "chips",
    "jobs", "seed", "cache", "batch", "warmup", "k", "kind", "densities", "dir", "tolerance",
    "trace-out", "chrome", "metrics-out", "slo", "window", "against", "snapshot",
    "deadline-ms", "retries", "fault-seed", "fault-profile", "profiles", "src", "mutate",
    "iters", "invariant", "replay",
];
const FLAGS: &[&str] = &["real", "verbose", "shrink"];

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        print_usage();
        std::process::exit(2);
    }
    let cmd = argv[0].clone();
    match dispatch(&cmd, &argv[1..]) {
        Ok(()) => {}
        Err(e) => {
            eprintln!("ipumm {cmd}: {e:#}");
            std::process::exit(1);
        }
    }
}

fn print_usage() {
    eprintln!(
        "usage: ipumm <table1|fig4|fig5|vertices|memory|phases|profile|plan|run|trace|serve|chaos|fuzz|sparse|bench-check|slo-check|check|streaming|multiipu|e2e|all> [args]"
    );
    eprintln!("see rust/src/main.rs header for per-command options");
}

fn parse_common(raw: &[String]) -> Result<(Args, IpuArch, GpuArch, Option<usize>)> {
    let args = Args::parse(raw, OPTIONS, FLAGS)?;
    let arch = IpuArch::by_name(args.opt_or("arch", "gc200"))
        .with_context(|| format!("unknown IPU arch '{}'", args.opt_or("arch", "gc200")))?;
    let gpu = GpuArch::by_name(args.opt_or("gpu", "a30"))
        .with_context(|| format!("unknown GPU '{}'", args.opt_or("gpu", "a30")))?;
    // None -> the shared runner::default_workers sizing policy
    let workers = args.opt_usize_opt("workers")?;
    Ok((args, arch, gpu, workers))
}

fn write_csv(args: &Args, csv: String) -> Result<()> {
    if let Some(path) = args.opt("csv") {
        std::fs::write(path, csv).with_context(|| format!("writing {path}"))?;
        println!("(csv -> {path})");
    }
    Ok(())
}

fn shape_from(args: &Args) -> Result<MmShape> {
    Ok(MmShape::new(
        args.pos_usize(0, "m")?,
        args.pos_usize(1, "n")?,
        args.pos_usize(2, "k")?,
    ))
}

/// `--deadline-ms` as model-time seconds; `None` when the flag is absent.
fn deadline_seconds(args: &Args) -> Result<Option<f64>> {
    match args.opt("deadline-ms") {
        Some(_) => {
            let ms = args.opt_f64("deadline-ms", 0.0)?;
            anyhow::ensure!(ms > 0.0, "--deadline-ms must be > 0");
            Ok(Some(ms / 1e3))
        }
        None => Ok(None),
    }
}

/// The effective worker budget for perf-reproducible runs: every
/// `--workers` value is a request against the process-wide thread budget
/// (see `coordinator::runner::ThreadBudget`), so the line a run prints is
/// what actually bounds its parallelism.
fn budget_line(workers: Option<usize>) -> String {
    let b = ThreadBudget::global();
    format!(
        "thread budget: {} permits (override: IPUMM_THREAD_BUDGET); --workers request: {}",
        b.total(),
        workers.map_or_else(|| "auto".to_string(), |w| w.to_string()),
    )
}

fn dispatch(cmd: &str, raw: &[String]) -> Result<()> {
    match cmd {
        "table1" => {
            let (_, arch, gpu, _) = parse_common(raw)?;
            println!("{}", table1::table1(&arch, &gpu).to_ascii());
        }
        "fig4" => {
            let (args, arch, gpu, workers) = parse_common(raw)?;
            let max = args.opt_usize("max-size", 5120)?;
            let r = fig4::run(&arch, &gpu, max, workers);
            println!("{}", r.to_table().to_ascii());
            println!(
                "IPU best {} (paper 44.2) at wall {} (paper 3584); GPU best {} (paper 9.7)",
                fmt_tflops(r.ipu_best_tflops),
                r.ipu_max_square,
                fmt_tflops(r.gpu_best_tflops)
            );
            write_csv(&args, r.metrics.to_csv())?;
        }
        "fig5" => {
            let (args, arch, gpu, workers) = parse_common(raw)?;
            let ks: Vec<usize> = args
                .opt_or("ks", "1024,2048,4096")
                .split(',')
                .map(|s| s.trim().parse().context("bad --ks"))
                .collect::<Result<_>>()?;
            let r = fig5::run(&arch, &gpu, 22, 4, &ks, workers);
            println!("{}", r.to_table().to_ascii());
            for &k in &ks {
                let ipu = Backend::IpuSim(arch.clone()).name();
                let gpu_n = Backend::GpuModel(gpu.clone()).name();
                if let (Some((il, ir)), Some((gl, gr))) = (
                    fig5::drops(&r, &ipu, k, None),
                    fig5::drops(&r, &gpu_n, k, None),
                ) {
                    println!(
                        "k={k}: IPU drops left {:.0}% / right {:.0}% (asymmetric); GPU {:.0}% / {:.0}%",
                        il * 100.0,
                        ir * 100.0,
                        gl * 100.0,
                        gr * 100.0
                    );
                }
            }
            write_csv(&args, r.metrics.to_csv())?;
        }
        "ablation" => {
            let (_, arch, _, _) = parse_common(raw)?;
            let rows = ablation::run(&arch);
            println!("{}", ablation::to_table(&rows).to_ascii());
        }
        "fp16" => {
            let (_, arch, _, _) = parse_common(raw)?;
            let r = fp16::run(&arch, &fp16::default_sizes());
            println!("{}", fp16::to_table(&r).to_ascii());
        }
        "vertices" => {
            let (_, arch, _, _) = parse_common(raw)?;
            let rows = vertices::run(&arch);
            println!("{}", vertices::to_table(&rows).to_ascii());
        }
        "memory" => {
            let (_, _, _, workers) = parse_common(raw)?;
            let rows = memory_study::run(&memory_study::default_archs(), workers);
            println!("{}", memory_study::to_table(&rows).to_ascii());
        }
        "phases" => {
            let (_, arch, _, _) = parse_common(raw)?;
            let rows = phases::run(&arch, &phases::default_shapes());
            println!("{}", phases::to_table(&rows).to_ascii());
        }
        "profile" => {
            let (args, arch, _, _) = parse_common(raw)?;
            let shape = shape_from(&args)?;
            let chrome_path = args.opt("chrome");
            if chrome_path.is_some() {
                ipumm::obs::enable();
            }
            let engine = SimEngine::new(arch);
            let report = engine
                .simulate_mm(shape)
                .map_err(|e| anyhow::anyhow!("{e}"))?;
            let pv = PopVisionReport::new(&report);
            println!("{}", pv.to_text());
            // memory-over-time view (liveness sparkline + peak)
            let graph = engine.build_graph(shape, &report.plan);
            let liveness = ipumm::memory::liveness::LivenessProfile::of(&graph);
            println!("{}", PopVisionReport::liveness_text(&liveness));
            if let Some(path) = args.opt("json") {
                std::fs::write(path, pv.to_json().render())
                    .with_context(|| format!("writing {path}"))?;
                println!("(json -> {path})");
            }
            if let Some(path) = chrome_path {
                ipumm::obs::disable();
                let data = ipumm::obs::take();
                std::fs::write(path, ipumm::obs::chrome_trace_json(&data).render())
                    .with_context(|| format!("writing {path}"))?;
                println!("(chrome trace -> {path}; open in chrome://tracing or Perfetto)");
                println!("{}", ipumm::obs::flame_summary(&data));
            }
        }
        "plan" => {
            let (args, arch, _, workers) = parse_common(raw)?;
            let shape = shape_from(&args)?;
            println!("{}", budget_line(workers));
            let result = search_with_workers(
                &arch,
                shape,
                CostConfig::default(),
                workers.unwrap_or_else(search_workers),
            );
            match result {
                Ok(plan) => {
                    let p = plan.partition();
                    let c = &plan.cost;
                    println!(
                        "plan for A[{},{}]xB[{},{}] on {}:",
                        shape.m, shape.n, shape.n, shape.k, arch.name
                    );
                    println!(
                        "  pm={} pn={} pk={} cn={} ({} tiles, {} supersteps)",
                        p.pm,
                        p.pn,
                        p.pk,
                        p.cn,
                        p.tiles_used(),
                        c.supersteps
                    );
                    println!(
                        "  {} | efficiency {:.1}% | {} vertices | max tile {}",
                        fmt_tflops(plan.tflops(&arch)),
                        c.efficiency() * 100.0,
                        c.total_vertices(),
                        fmt_bytes(c.tile_bytes_total)
                    );
                }
                Err(e) => println!("planner: {e} (the paper's §2.4 memory wall)"),
            }
        }
        "run" => {
            let (args, arch, gpu, _) = parse_common(raw)?;
            let shape = shape_from(&args)?;
            for backend in [Backend::IpuSim(arch), Backend::GpuModel(gpu)] {
                let name = backend.name();
                match run_shape(&backend, shape).tflops() {
                    Some(t) => println!("{name:<18} {}", fmt_tflops(t)),
                    None => println!("{name:<18} OOM"),
                }
            }
            if args.flag("real") {
                #[cfg(feature = "xla")]
                {
                    let dir = args.opt_or("artifacts", "artifacts");
                    let block = args.opt_usize("block", 256)?;
                    let mut ex = BlockMmExecutor::load(Path::new(dir), block)?;
                    let a = Matrix::random(shape.m, shape.n, 1);
                    let b = Matrix::random(shape.n, shape.k, 2);
                    let (_c, stats, err) = ex.mm_verified(&a, &b)?;
                    println!(
                        "pjrt-real/cpu      {} block calls ({}^3) in {:.3}s, max|err| {err:.1e} (verified)",
                        stats.block_calls, stats.block, stats.seconds
                    );
                }
                #[cfg(not(feature = "xla"))]
                bail!("--real needs the PJRT runtime; rebuild with `--features xla`");
            }
        }
        "trace" => {
            let (args, arch, gpu, workers) = parse_common(raw)?;
            let n_jobs = args.opt_usize("jobs", 200)?;
            let seed = args.opt_usize("seed", 42)? as u64;
            let trace = ipumm::coordinator::trace::TraceSpec::paper_mix(n_jobs, seed);
            let r = ipumm::coordinator::trace::run_trace(&arch, &gpu, &trace, workers);
            println!("{}", r.to_table().to_ascii());
            write_csv(&args, r.to_csv())?;
        }
        "serve" => {
            let (args, arch, gpu, workers) = parse_common(raw)?;
            let n_jobs = args.opt_usize("jobs", 1000)?;
            let seed = args.opt_usize("seed", 42)? as u64;
            // clamp so short traces still report a meaningful steady state
            let warmup = (args.opt_usize("warmup", 100)? as u64).min(n_jobs as u64 / 2);
            let cache_capacity = args.opt_usize("cache", 256)?;
            anyhow::ensure!(cache_capacity >= 1, "--cache must be >= 1");
            let max_batch = args.opt_usize("batch", 32)?;
            anyhow::ensure!(max_batch >= 1, "--batch must be >= 1");
            // fault-tolerance knobs: any of them switches dispatch onto
            // the deterministic resolve-then-serve path (lib.rs role 10)
            let deadline_s = deadline_seconds(&args)?;
            let retries = args.opt_usize_opt("retries")?;
            let fault_seed = args.opt_usize_opt("fault-seed")?.map(|s| s as u64);
            let profile = match args.opt("fault-profile") {
                Some(name) => FaultProfile::by_name(name).with_context(|| {
                    format!(
                        "unknown fault profile '{name}' (known: {})",
                        FaultProfile::names().join(", ")
                    )
                })?,
                // a bare --fault-seed means "inject the default mix"
                None if fault_seed.is_some() => {
                    FaultProfile::by_name("transient").expect("transient is a known profile")
                }
                None => FaultProfile::none(),
            };
            let faults = if profile.is_zero() {
                FaultPlan::none()
            } else {
                FaultPlan::seeded(fault_seed.unwrap_or(seed), profile)
            };
            let fault_policy = if faults.is_active() || deadline_s.is_some() || retries.is_some()
            {
                let mut p = FaultPolicy::standard();
                p.deadline_s = deadline_s;
                if let Some(r) = retries {
                    p.retry = ipumm::fault::RetryPolicy::standard(r as u32);
                }
                p
            } else {
                FaultPolicy::passthrough()
            };
            if faults.is_active() {
                println!(
                    "fault injection: seed {} over {} requests (deadline {}, {} retries)",
                    fault_seed.unwrap_or(seed),
                    n_jobs,
                    deadline_s.map_or_else(|| "off".into(), |d| format!("{:.1}ms", d * 1e3)),
                    fault_policy.retry.max_retries,
                );
            }
            let config = ServiceConfig {
                arch,
                gpu,
                workers,
                cache_capacity,
                max_batch,
                // real-PJRT anchor when built with --features xla
                artifacts: args.opt("artifacts").map(std::path::PathBuf::from),
                faults,
                fault_policy,
                ..ServiceConfig::default()
            };
            let trace_path = args.opt("trace-out");
            if trace_path.is_some() {
                ipumm::obs::enable();
            }
            let svc = MmService::new(config);
            if args.opt("artifacts").is_some() {
                #[cfg(not(feature = "xla"))]
                eprintln!(
                    "warning: --artifacts ignored (built without --features xla; \
                     no real PJRT anchoring will run)"
                );
                #[cfg(feature = "xla")]
                if !svc.backends().iter().any(|b| b.contains("pjrt-real")) {
                    eprintln!(
                        "warning: --artifacts given but artifacts failed to load; \
                         serving without real PJRT anchoring"
                    );
                }
            }
            let spec = ipumm::coordinator::trace::TraceSpec::paper_mix(n_jobs, seed);
            let shapes: Vec<MmShape> = spec.jobs.iter().map(|(_, s)| *s).collect();
            let report = svc.serve_trace(&shapes);
            println!("{}", report.bucket_table().to_ascii());
            println!("{}", report.summary());
            println!(
                "steady state (after request {warmup}): {:.1}% plan-cache hit rate",
                100.0 * report.hit_rate_after(warmup)
            );
            write_csv(&args, report.metrics.to_csv())?;
            if let Some(path) = trace_path {
                // re-simulate the busiest dense bucket once while tracing
                // is still on, so the exported trace carries all three
                // layers: serve workers (wall), planner stripes (wall),
                // and the bucket's BSP superstep timeline (model cycles)
                if let Some(top) = report
                    .bucket_stats()
                    .into_iter()
                    .find(|s| s.sparsity.is_none() && s.oom == 0)
                {
                    if let Ok(plan) = svc.cache().get_or_plan(&svc.config().arch, top.bucket) {
                        let _ = SimEngine::new(svc.config().arch.clone())
                            .simulate_plan(top.bucket, plan);
                    }
                }
                ipumm::obs::disable();
                let data = ipumm::obs::take();
                std::fs::write(path, ipumm::obs::chrome_trace_json(&data).render())
                    .with_context(|| format!("writing {path}"))?;
                println!("(chrome trace -> {path}; open in chrome://tracing or Perfetto)");
                println!("{}", ipumm::obs::flame_summary(&data));
            }
            let metrics_path = args.opt("metrics-out");
            let slo_arg = args.opt("slo");
            if metrics_path.is_some() || slo_arg.is_some() {
                let window = args.opt_usize("window", 100)? as u64;
                anyhow::ensure!(window >= 1, "--window must be >= 1");
                let slos = match slo_arg {
                    Some(text) => ipumm::obs::slo::SloSpec::parse_list(text)
                        .map_err(|e| anyhow::anyhow!("--slo: {e}"))?,
                    None => Vec::new(),
                };
                let snap = report.metrics_snapshot(window, &slos);
                for v in &snap.slos {
                    println!("{}", v.line());
                }
                if let Some(path) = metrics_path {
                    std::fs::write(path, snap.prometheus_text())
                        .with_context(|| format!("writing {path}"))?;
                    let json_path = format!("{path}.json");
                    std::fs::write(&json_path, snap.to_json().render())
                        .with_context(|| format!("writing {json_path}"))?;
                    println!(
                        "(metrics -> {path} [Prometheus text], {json_path} [JSON snapshot, \
                         {}-request windows])",
                        window
                    );
                }
                anyhow::ensure!(
                    !snap.any_slo_violated(),
                    "SLO violated over the served trace (see verdict lines above)"
                );
            }
        }
        "chaos" => {
            let (args, arch, gpu, workers) = parse_common(raw)?;
            let n_jobs = args.opt_usize("jobs", 200)?;
            let seed = args.opt_usize("seed", 42)? as u64;
            let deadline_s = deadline_seconds(&args)?;
            let retries = args.opt_usize("retries", 3)? as u32;
            let names = args.opt_or(
                "profiles",
                "transient,transient-heavy,slow,breaker-trip,panic,mixed",
            );
            let mut scenarios = Vec::new();
            for name in names.split(',').map(str::trim).filter(|s| !s.is_empty()) {
                scenarios.push(
                    ipumm::fault::chaos::scenario(name, deadline_s, retries)
                        .map_err(|e| anyhow::anyhow!(e))?,
                );
            }
            anyhow::ensure!(!scenarios.is_empty(), "--profiles named no scenarios");
            println!("{}", budget_line(workers));
            let report =
                ipumm::fault::chaos::run_matrix(&arch, &gpu, n_jobs, seed, workers, &scenarios);
            let violations = report.violations();
            if !violations.is_empty() && args.flag("shrink") {
                // hand the failing cell to the fuzz harness's full-tuple
                // shrinker and print the minimal one-line repro instead
                // of the raw failing table
                let failing = report
                    .scenarios
                    .iter()
                    .find(|s| !ipumm::fault::chaos::invariant_violations(s).is_empty())
                    .expect("violations imply a failing scenario");
                let cell = scenarios
                    .iter()
                    .find(|c| c.name == failing.name)
                    .expect("report rows mirror the scenario list");
                let spec = ipumm::coordinator::trace::TraceSpec::paper_mix(n_jobs, seed);
                let trace: Vec<ipumm::fault::chaos::ChaosRequest> = spec
                    .jobs
                    .iter()
                    .enumerate()
                    .map(|(i, (_, s))| (i as u64, *s, None))
                    .collect();
                let effective_workers = workers
                    .unwrap_or_else(ipumm::coordinator::runner::default_workers)
                    .max(1);
                let scenario = ipumm::fuzz::Scenario {
                    arch_base: ipumm::fuzz::ArchBase::by_name(args.opt_or("arch", "gc200"))
                        .context("chaos --shrink supports --arch gc200|gc2|bow")?,
                    arch_perturb: 0,
                    plan_workers: effective_workers,
                    serve_workers: effective_workers,
                    profile: cell.name.clone(),
                    fault_seed: seed,
                    deadline_us: cell.policy.deadline_s.map(|s| (s * 1e6).round() as u64),
                    retries: cell.policy.retry.max_retries,
                    trace,
                };
                let cfg = ipumm::fuzz::HarnessConfig::default();
                eprintln!(
                    "chaos --shrink: scenario '{}' failed its accounting gate; shrinking...",
                    cell.name
                );
                if !ipumm::fuzz::scenario_fails(&scenario, &cfg, Some("serve-accounting")) {
                    for v in &violations {
                        eprintln!("chaos violation: {v}");
                    }
                    bail!(
                        "the failure did not reproduce through the harness's serve-accounting \
                         invariant — see raw violations above"
                    );
                }
                let (minimal, steps) =
                    ipumm::fuzz::shrink_scenario(&scenario, &cfg, "serve-accounting");
                let detail = ipumm::fuzz::check_scenario(&minimal, &cfg, Some("serve-accounting"))
                    .map(|f| f.detail)
                    .unwrap_or_default();
                println!("{}", ipumm::fuzz::culprit_report(&minimal, "serve-accounting", &detail));
                println!(
                    "shrunk {} request(s) -> {} in {steps} step(s)",
                    n_jobs,
                    minimal.trace.len()
                );
                println!("replay: ipumm fuzz --replay '{}'", minimal.to_line());
                bail!(
                    "chaos scenario '{}' violated its accounting gate (minimal replay above)",
                    cell.name
                );
            }
            println!("{}", report.to_table().to_ascii());
            if let Some(path) = args.opt("json") {
                std::fs::write(path, report.to_json().render())
                    .with_context(|| format!("writing {path}"))?;
                println!("(json -> {path})");
            }
            for v in &violations {
                eprintln!("chaos violation: {v}");
            }
            anyhow::ensure!(
                violations.is_empty(),
                "{} recovery invariant(s) violated over the chaos matrix",
                violations.len()
            );
            println!(
                "chaos: {} scenario(s) x {} requests — zero lost, outcome accounting exact",
                report.scenarios.len(),
                n_jobs
            );
        }
        "fuzz" => {
            use ipumm::analysis::mutate::MutationClass;
            use ipumm::fuzz::{self, HarnessConfig, Scenario};
            let args = Args::parse(raw, OPTIONS, FLAGS)?;
            let seed = args.opt_usize("seed", 42)? as u64;
            let iters = args.opt_usize("iters", 200)?;
            anyhow::ensure!(iters >= 1, "--iters must be >= 1");
            // --mutate CLASS arms the trip-wire; the fuzz seed doubles as
            // the mutation-site seed (like `check --mutate --seed`)
            let cfg = match args.opt("mutate") {
                None => HarnessConfig::default(),
                Some(class_name) => {
                    let class = MutationClass::by_name(class_name).with_context(|| {
                        let all: Vec<&str> = MutationClass::ALL.iter().map(|c| c.name()).collect();
                        format!(
                            "unknown mutation class '{class_name}' (one of: {})",
                            all.join("|")
                        )
                    })?;
                    HarnessConfig { mutate: Some((class, seed)) }
                }
            };
            let only = match args.opt("invariant") {
                Some(name) => {
                    anyhow::ensure!(
                        fuzz::invariant_names().iter().any(|n| *n == name),
                        "unknown invariant '{name}' (one of: {})",
                        fuzz::invariant_names().join("|")
                    );
                    Some(name)
                }
                // mutate mode targets the verifier; skip the serve-level
                // invariants so the trip-wire stays fast and deterministic
                None if cfg.mutate.is_some() => Some("verify-clean"),
                None => None,
            };
            if let Some(spec) = args.opt("replay") {
                let sc = Scenario::parse(spec).map_err(|e| anyhow::anyhow!("--replay: {e}"))?;
                println!("replaying: {}", sc.to_line());
                match fuzz::check_scenario(&sc, &cfg, only) {
                    Some(f) => {
                        println!("{}", fuzz::culprit_report(&sc, f.invariant, &f.detail));
                        bail!("replayed scenario violates invariant '{}'", f.invariant);
                    }
                    None => println!("replay clean: no invariant violated"),
                }
                return Ok(());
            }
            match (only, cfg.mutate) {
                (_, Some((class, _))) => println!(
                    "fuzz: seed {seed}, {iters} iteration(s), trip-wire mutation [{}]",
                    class.name()
                ),
                (Some(name), None) => {
                    println!("fuzz: seed {seed}, {iters} iteration(s), invariant '{name}'")
                }
                (None, None) => println!(
                    "fuzz: seed {seed}, {iters} iteration(s), {} invariant(s)",
                    fuzz::INVARIANTS.len()
                ),
            }
            let report = fuzz::fuzz(seed, iters, only, &cfg);
            if let Some(path) = args.opt("json") {
                std::fs::write(path, report.to_json().render())
                    .with_context(|| format!("writing {path}"))?;
                println!("(json -> {path})");
            }
            match &report.failure {
                None => {
                    if let Some((class, _)) = cfg.mutate {
                        // exit 0: the CI expect-failure wrapper turns a
                        // blind harness into a build failure
                        eprintln!(
                            "fuzz --mutate {}: harness did NOT find the seeded mutation in \
                             {iters} iteration(s) — the gate is blind to this class",
                            class.name()
                        );
                    } else {
                        println!(
                            "fuzz: {} scenario(s) clean (seed {seed}) — every invariant held",
                            report.completed
                        );
                    }
                }
                Some(f) => {
                    println!(
                        "fuzz: invariant '{}' violated at iteration {}",
                        f.invariant, report.completed
                    );
                    println!("  original: {}", f.original.to_line());
                    println!("  shrunk in {} step(s) to a 1-minimal counterexample:", f.shrink_steps);
                    println!("{}", f.culprit);
                    println!("replay: ipumm fuzz --replay '{}'", f.replay);
                    if let Some((class, _)) = cfg.mutate {
                        bail!(
                            "harness found and shrank the seeded [{}] mutation as expected; \
                             trip-wire armed",
                            class.name()
                        );
                    }
                    bail!(
                        "invariant '{}' violated — the replay line above reproduces it \
                         deterministically",
                        f.invariant
                    );
                }
            }
        }
        "slo-check" => {
            let (args, arch, gpu, workers) = parse_common(raw)?;
            if let Some(path) = args.opt("snapshot") {
                // gate a previously-exported snapshot without re-serving
                use ipumm::util::json::Json;
                let text = std::fs::read_to_string(path)
                    .with_context(|| format!("reading {path}"))?;
                let doc = Json::parse(&text)
                    .map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
                let slos = doc
                    .get("slos")
                    .and_then(Json::items)
                    .with_context(|| format!("{path}: no 'slos' array"))?;
                anyhow::ensure!(
                    !slos.is_empty(),
                    "{path} records no SLO verdicts — re-run serve with --slo and --metrics-out"
                );
                let mut violated = 0usize;
                for v in slos {
                    let spec = v.get("spec").and_then(Json::as_str).unwrap_or("?");
                    let bad = matches!(v.get("violated"), Some(Json::Bool(true)));
                    println!("{:>4}  SLO {spec}", if bad { "FAIL" } else { "ok" });
                    violated += bad as usize;
                }
                anyhow::ensure!(violated == 0, "{violated} SLO(s) violated in {path}");
            } else {
                let slo_text = args.opt("slo").context(
                    "slo-check needs --slo \"p99<5ms@99%\" (';'-separated) or --snapshot FILE",
                )?;
                let slos = ipumm::obs::slo::SloSpec::parse_list(slo_text)
                    .map_err(|e| anyhow::anyhow!("--slo: {e}"))?;
                let n_jobs = args.opt_usize("jobs", 200)?;
                let seed = args.opt_usize("seed", 42)? as u64;
                let window = args.opt_usize("window", 100)? as u64;
                anyhow::ensure!(window >= 1, "--window must be >= 1");
                let spec = ipumm::coordinator::trace::TraceSpec::paper_mix(n_jobs, seed);
                let shapes: Vec<MmShape> = spec.jobs.iter().map(|(_, s)| *s).collect();
                let svc = MmService::new(ServiceConfig {
                    arch,
                    gpu,
                    workers,
                    ..ServiceConfig::default()
                });
                let report = svc.serve_trace(&shapes);
                let snap = report.metrics_snapshot(window, &slos);
                for v in &snap.slos {
                    println!("{}", v.line());
                }
                anyhow::ensure!(
                    !snap.any_slo_violated(),
                    "SLO violated over the demo trace ({n_jobs} requests, seed {seed})"
                );
                println!("slo-check: all {} SLO(s) met", snap.slos.len());
            }
        }
        "sparse" => {
            let (args, arch, _, workers) = parse_common(raw)?;
            let k = args.opt_usize("k", 2048)?;
            let block = args.opt_usize("block", 8)?;
            anyhow::ensure!(
                ipumm::sparse::pattern::BLOCK_SIZES.contains(&block),
                "--block must be one of {:?}",
                ipumm::sparse::pattern::BLOCK_SIZES
            );
            let kind = PatternKind::by_name(args.opt_or("kind", "random"))
                .with_context(|| format!("unknown pattern kind '{}'", args.opt_or("kind", "random")))?;
            let densities: Vec<f64> = args
                .opt_or("densities", "1.0,0.5,0.25,0.1")
                .split(',')
                .map(|s| s.trim().parse().context("bad --densities"))
                .collect::<Result<_>>()?;
            let seed = args.opt_usize("seed", 42)? as u64;
            println!("{}", budget_line(workers));
            let rows = sparse_sweep::run(&arch, 22, 4, k, block, &densities, kind, seed, workers);
            println!("{}", sparse_sweep::to_table(&rows).to_ascii());
            for &d in &densities {
                let permille = ((d * 1000.0).round() as i64).clamp(1, 1000) as u32;
                let at = |label: &str| {
                    rows.iter()
                        .find(|r| r.spec.density_permille == permille && r.label == label)
                        .and_then(|r| r.effective_tflops)
                };
                if let (Some(sq), Some((blabel, btf))) =
                    (at("square"), sparse_sweep::best_effective_at(&rows, permille))
                {
                    let retention = |side: Option<f64>| {
                        side.map(|t| format!("{:.0}%", 100.0 * t / sq))
                            .unwrap_or_else(|| "OOM".to_string())
                    };
                    println!(
                        "density {d:.2}: best effective {} at {blabel}; vs squared the \
                         extremes keep left {} / right {}",
                        fmt_tflops(btf),
                        retention(at("left 2^8")),
                        retention(at("right 2^8")),
                    );
                }
            }
            // the §2.4 wall as a density curve (CSR-aware admission):
            // constant per density, read off any row of that density
            println!("predicted memory wall on {} (max fitting square):", arch.name);
            let mut walls: Vec<(f64, usize)> = Vec::new();
            for &d in &densities {
                let permille = ((d * 1000.0).round() as i64).clamp(1, 1000) as u32;
                if let Some(r) = rows.iter().find(|r| r.spec.density_permille == permille) {
                    println!("  density {d:.2}: {}^2", r.predicted_max_square);
                    walls.push((d, r.predicted_max_square));
                }
            }
            if let Some(path) = args.opt("json") {
                use ipumm::util::json::Json;
                let mut arr = Json::Arr(Vec::new());
                for (density, wall) in &walls {
                    let mut o = Json::obj();
                    o.set("density", Json::Num(*density));
                    o.set("max_fitting_square", Json::Int(*wall as i64));
                    arr.push(o);
                }
                let mut j = Json::obj();
                j.set("arch", Json::Str(arch.name.to_string()));
                j.set("kind", Json::Str(kind.name().to_string()));
                j.set("block", Json::Int(block as i64));
                j.set("seed", Json::Int(seed as i64));
                j.set("predicted_walls", arr);
                std::fs::write(path, j.render()).with_context(|| format!("writing {path}"))?;
                println!("(json -> {path})");
            }
            write_csv(&args, sparse_sweep::to_csv(&rows))?;
        }
        "bench-check" => {
            // CI regression gate over the perf-trajectory JSON the bench
            // smoke step emits: every `<name>_baseline` row frozen by
            // bench_planner/bench_sparse gates its `<name>` twin
            let args = Args::parse(raw, OPTIONS, FLAGS)?;
            let dir = args.opt_or("dir", ".");
            let tolerance_pct = args.opt_usize("tolerance", 20)?;
            let tolerance = tolerance_pct as f64 / 100.0;
            let mut checked = 0usize;
            let mut failures = 0usize;
            let mut gated_files = 0usize;
            // Missing, unreadable, or malformed artifacts are advisory:
            // the gate only fails on a *confirmed* regression, never on a
            // half-written or corrupted BENCH_*.json (a crashed bench run
            // should surface as its own CI failure, not masquerade as a
            // perf regression here).
            for file in ["BENCH_planner.json", "BENCH_sparse.json", "BENCH_obs.json"] {
                let path = std::path::Path::new(dir).join(file);
                let text = match std::fs::read_to_string(&path) {
                    Ok(text) => text,
                    Err(e) => {
                        eprintln!("bench-check: skipping {} ({e})", path.display());
                        continue;
                    }
                };
                let doc = match ipumm::util::json::Json::parse(&text) {
                    Ok(doc) => doc,
                    Err(e) => {
                        eprintln!(
                            "bench-check: skipping {} (malformed JSON: {e}) — rerun the \
                             bench smoke step (IPUMM_BENCH_JSON=1 cargo bench ...)",
                            path.display()
                        );
                        continue;
                    }
                };
                let verdicts = match ipumm::util::bench::regression_verdicts(&doc, tolerance) {
                    Ok(v) => v,
                    Err(e) => {
                        eprintln!(
                            "bench-check: skipping {} (unusable artifact: {e})",
                            path.display()
                        );
                        continue;
                    }
                };
                gated_files += 1;
                for v in &verdicts {
                    checked += 1;
                    let status = if v.regressed {
                        failures += 1;
                        "FAIL"
                    } else {
                        "ok"
                    };
                    println!(
                        "{status:>4}  {}/{:<44} {:>10.3}ms vs baseline {:>10.3}ms ({:.2}x)",
                        v.group,
                        v.name,
                        v.mean_s * 1e3,
                        v.baseline_mean_s * 1e3,
                        v.ratio
                    );
                }
            }
            if gated_files == 0 {
                eprintln!(
                    "bench-check: no readable bench artifacts in {dir} — nothing gated \
                     (advisory; run the bench smoke step first)"
                );
            }
            println!(
                "bench-check: {checked} gated rows, {failures} regressions \
                 (tolerance {tolerance_pct}%)"
            );
            anyhow::ensure!(
                failures == 0,
                "{failures} benchmark(s) regressed more than {tolerance_pct}% vs the in-run baseline"
            );
            // cross-run trend gate: compare against a previous run's
            // artifacts (CI restores them from the branch-keyed cache)
            if let Some(prev_dir) = args.opt("against") {
                let mut trend_checked = 0usize;
                let mut trend_failures = 0usize;
                for file in ["BENCH_planner.json", "BENCH_sparse.json", "BENCH_obs.json"] {
                    let cur_path = std::path::Path::new(dir).join(file);
                    let prev_path = std::path::Path::new(prev_dir).join(file);
                    let (Ok(cur_text), Ok(prev_text)) = (
                        std::fs::read_to_string(&cur_path),
                        std::fs::read_to_string(&prev_path),
                    ) else {
                        eprintln!(
                            "bench-check: no cross-run pair for {file} (need both {} and {})",
                            cur_path.display(),
                            prev_path.display()
                        );
                        continue;
                    };
                    let (cur, prev) = match (
                        ipumm::util::json::Json::parse(&cur_text),
                        ipumm::util::json::Json::parse(&prev_text),
                    ) {
                        (Ok(cur), Ok(prev)) => (cur, prev),
                        (Err(e), _) => {
                            eprintln!(
                                "bench-check: skipping {} (malformed JSON: {e})",
                                cur_path.display()
                            );
                            continue;
                        }
                        (_, Err(e)) => {
                            eprintln!(
                                "bench-check: skipping {} (malformed JSON: {e})",
                                prev_path.display()
                            );
                            continue;
                        }
                    };
                    let verdicts =
                        match ipumm::util::bench::trend_verdicts(&cur, &prev, tolerance) {
                            Ok(v) => v,
                            Err(e) => {
                                eprintln!(
                                    "bench-check: skipping {file} (unusable artifact pair: {e})"
                                );
                                continue;
                            }
                        };
                    for v in &verdicts {
                        trend_checked += v.normalized as usize;
                        let status = if v.regressed {
                            trend_failures += 1;
                            "FAIL"
                        } else if v.normalized {
                            "ok"
                        } else {
                            "info"
                        };
                        println!(
                            "{status:>4}  {}/{:<44} {:>10.3}ms vs prev {:>10.3}ms (drift {:.2}x{})",
                            v.group,
                            v.name,
                            v.current_s * 1e3,
                            v.prev_s * 1e3,
                            v.drift,
                            if v.normalized { ", baseline-normalized" } else { ", raw — advisory" },
                        );
                    }
                }
                println!(
                    "bench-check --against: {trend_checked} gated rows, {trend_failures} \
                     cross-run regressions (tolerance {tolerance_pct}%)"
                );
                anyhow::ensure!(
                    trend_failures == 0,
                    "{trend_failures} benchmark(s) drifted more than {tolerance_pct}% vs the \
                     previous run in {prev_dir}"
                );
            }
        }
        "check" => {
            use ipumm::analysis::{lint, mutate, report_json, report_text, verify};
            use ipumm::planner::search::search;
            use ipumm::sparse::pattern::{BlockPattern, SparsitySpec};
            use ipumm::sparse::planner::sparse_search;

            let (args, arch, _, _) = parse_common(raw)?;
            let engine = SimEngine::new(arch.clone());

            // --mutate CLASS: the CI trip-wire. Exit nonzero iff the
            // verifier catches the seeded mutation with its expected
            // rule; a blind or misfiring verifier exits zero, which the
            // expect-failure CI wrapper turns into a build failure.
            if let Some(class_name) = args.opt("mutate") {
                let class = mutate::MutationClass::by_name(class_name).with_context(|| {
                    let all: Vec<&str> =
                        mutate::MutationClass::ALL.iter().map(|c| c.name()).collect();
                    format!("unknown mutation class '{class_name}' (one of: {})", all.join("|"))
                })?;
                let seed = args.opt_usize("seed", 0)? as u64;
                let shape = MmShape::square(1024);
                let plan = search(&arch, shape)?;
                let mut g = engine.build_graph(shape, &plan);
                let edit = mutate::apply(&mut g, class, seed)
                    .context("no eligible mutation site in the planned graph")?;
                println!("mutation [{}] seed {seed}: {edit}", class.name());
                let ds = verify::verify_dense(&arch, shape, &plan, &g);
                println!("{}", report_text(&ds));
                if ds.iter().any(|d| d.rule == class.expected_rule()) {
                    bail!(
                        "verifier caught the mutation with rule '{}' as expected \
                         ({} diagnostic(s)); trip-wire armed",
                        class.expected_rule(),
                        ds.len()
                    );
                }
                eprintln!(
                    "check --mutate {}: verifier did NOT flag rule '{}' ({} other \
                     diagnostic(s)) — the gate is blind to this mutation class",
                    class.name(),
                    class.expected_rule(),
                    ds.len()
                );
                return Ok(());
            }

            // clean sweep: IR verification over the paper's Fig. 4 dense
            // squares and a past-the-dense-wall sparse shape, then the
            // repo-invariant lint over the source tree
            let mut all = Vec::new();
            for size in [512usize, 1024, 2048, 3072, 3584] {
                let shape = MmShape::square(size);
                let plan = search(&arch, shape)?;
                let g = engine.build_graph(shape, &plan);
                let ds = verify::verify_dense(&arch, shape, &plan, &g);
                println!(
                    "check: dense {size}x{size} — {} ({} groups, {} supersteps)",
                    if ds.is_empty() { "ok" } else { "FAIL" },
                    g.groups().len(),
                    g.program.superstep_count(),
                );
                all.extend(ds);
            }
            {
                let shape = MmShape::square(4096);
                let spec = SparsitySpec::new(PatternKind::Random, 8, 0.25, 42);
                let pattern = BlockPattern::for_shape(spec, shape);
                let plan = sparse_search(&arch, shape, &pattern)
                    .context("past-wall sparse shape no longer plans")?;
                let g = engine.build_sparse_graph(shape, &plan, &pattern);
                let ds = verify::verify_sparse(&arch, shape, &plan, &pattern, &g);
                println!(
                    "check: sparse 4096x4096 @ d=0.25 — {} ({} groups)",
                    if ds.is_empty() { "ok" } else { "FAIL" },
                    g.groups().len(),
                );
                all.extend(ds);
            }
            let src = args.opt_or("src", "rust/src");
            let lint_ds =
                lint::lint_dir(std::path::Path::new(src)).with_context(|| format!("linting {src}"))?;
            println!(
                "check: lint {src} — {} ({} finding(s))",
                if lint_ds.is_empty() { "ok" } else { "FAIL" },
                lint_ds.len(),
            );
            all.extend(lint_ds);

            if !all.is_empty() {
                println!("{}", report_text(&all));
            }
            if let Some(path) = args.opt("json") {
                let mut j = report_json(&all);
                j.set("src", ipumm::util::json::Json::Str(src.to_string()));
                std::fs::write(path, j.render()).with_context(|| format!("writing {path}"))?;
                println!("(json -> {path})");
            }
            anyhow::ensure!(
                all.is_empty(),
                "{} diagnostic(s) — see report above",
                all.len()
            );
            println!("check: clean");
        }
        "streaming" => {
            let (_, arch, _, _) = parse_common(raw)?;
            let rows = streaming::run(&arch, &streaming::default_sizes());
            println!("{}", streaming::to_table(&rows).to_ascii());
        }
        "multiipu" => {
            let (args, arch, _, _) = parse_common(raw)?;
            let chips: Vec<usize> = args
                .opt_or("chips", "1,2,4")
                .split(',')
                .map(|s| s.trim().parse().context("bad --chips"))
                .collect::<Result<_>>()?;
            let shape = MmShape::square(3584);
            let rows = multi_ipu_x::run(&arch, shape, &chips);
            println!("{}", multi_ipu_x::to_table(&rows, shape).to_ascii());
        }
        "e2e" => {
            #[cfg(feature = "xla")]
            {
                let (args, _, _, _) = parse_common(raw)?;
                let dir = args.opt_or("artifacts", "artifacts");
                let block = args.opt_usize("block", 256)?;
                let r = e2e::run(Path::new(dir), &e2e::default_trace(), block)?;
                println!("{}", e2e::to_table(&r).to_ascii());
                println!(
                    "headline: IPU-sim beats A30-model by {:.1}x geomean on the trace; \
                     {} real block executions verified against the oracle in {:.2}s",
                    r.geomean_speedup, r.total_block_calls, r.total_real_seconds
                );
            }
            #[cfg(not(feature = "xla"))]
            bail!("e2e needs the PJRT runtime; rebuild with `--features xla`");
        }
        "all" => {
            for sub in [
                "table1", "fig4", "fig5", "vertices", "memory", "phases", "streaming",
                "multiipu", "ablation", "trace", "serve", "fp16", "sparse",
            ] {
                println!("==== ipumm {sub} ====");
                dispatch(sub, raw)?;
            }
        }
        other => {
            print_usage();
            bail!("unknown command '{other}'");
        }
    }
    Ok(())
}
