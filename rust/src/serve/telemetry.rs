//! Per-bucket serving telemetry.
//!
//! Reuses the coordinator's metrics plumbing: every served batch emits a
//! [`MetricsRecord`] (backend, bucket label, bucket shape, outcome) into
//! a [`MetricsTable`], so the serving layer's output renders with the
//! same table/CSV/JSON emitters as the paper sweeps. On top of that,
//! per-request [`RequestRecord`]s carry the serving-specific axes —
//! queue wait, amortized planning time, cache hit, batch size — and
//! aggregate into per-bucket latency summaries.

use std::collections::BTreeMap;

use crate::coordinator::metrics::MetricsTable;
use crate::fault::{BreakerEvent, RequestOutcome};
use crate::obs::export::MetricsSnapshot;
use crate::obs::sketch::QuantileSketch;
use crate::obs::slo::SloSpec;
use crate::obs::window::{windowed, MetricEvent, WindowSpec, WindowStats};
use crate::planner::partition::MmShape;
use crate::serve::bucket::BucketLadder;
use crate::serve::cache::CacheStats;
use crate::serve::queue::QueueStats;
use crate::sparse::pattern::SparsitySpec;
use crate::util::stats::Summary;
use crate::util::table::Table;

/// One served request, as observed by the service.
#[derive(Clone, Debug)]
pub struct RequestRecord {
    pub id: u64,
    /// The caller's shape.
    pub shape: MmShape,
    /// The bucket it was served at.
    pub bucket: MmShape,
    /// Block-sparsity descriptor the request carried (`None` = dense).
    pub sparsity: Option<crate::sparse::pattern::SparsitySpec>,
    /// Backend that served it (coordinator backend naming).
    pub backend: String,
    /// Identity of the coalesced batch it rode in: the smallest rider id,
    /// which is unique per batch (every request joins exactly one batch).
    /// Counting distinct `batch_id`s is exact where the old
    /// `sum(1/batch_size)` float estimate could drift.
    pub batch_id: u64,
    /// Size of the coalesced batch it rode in.
    pub batch_size: usize,
    /// Whether the batch's plan lookup hit the cache; `None` when the
    /// dispatch policy never consulted it (e.g. GPU-only).
    pub cache_hit: Option<bool>,
    /// Wall seconds spent queued before a worker drained the batch.
    pub queue_seconds: f64,
    /// Queue depth left behind when this request's batch was drained
    /// ([`crate::serve::queue::Batch::queued_behind`]).
    pub queue_depth: usize,
    /// Planner wall seconds charged to this request (cold search time
    /// divided over the batch; 0 on a cache hit).
    pub plan_seconds: f64,
    /// Model-predicted device seconds for the bucket (0 on OOM).
    pub device_seconds: f64,
    /// Real PJRT wall seconds, when the artifact path verified the batch.
    pub real_seconds: Option<f64>,
    /// Request could not be served on any configured backend.
    pub oom: bool,
    /// How the fault layer resolved the request. Always `Served` on the
    /// legacy (fault-free) path.
    pub outcome: RequestOutcome,
    /// Device attempts across both legs (1 on the fault-free path).
    pub attempts: u32,
    /// Model seconds lost to wasted attempts and retry backoff (0 on
    /// the fault-free path).
    pub retry_seconds: f64,
}

impl RequestRecord {
    /// End-to-end request latency the serving model reports: queue wait
    /// plus amortized planning plus retry waste plus device time.
    pub fn latency_seconds(&self) -> f64 {
        self.queue_seconds + self.plan_seconds + self.retry_seconds + self.device_seconds
    }

    /// Padded-work factor paid for bucketing this request.
    pub fn overprovision(&self) -> f64 {
        BucketLadder::overprovision(self.shape, self.bucket)
    }
}

/// Aggregated view of one `(bucket, sparsity)` traffic class. Dense and
/// sparse requests of the same bucket are separate rows — they plan
/// through different cache keys and run different codelets, so lumping
/// them would average incomparable latencies (ROADMAP: per-sparsity
/// telemetry grouping).
#[derive(Clone, Debug)]
pub struct BucketStats {
    pub bucket: MmShape,
    /// Sparsity class of this row (`None` = the bucket's dense traffic).
    pub sparsity: Option<SparsitySpec>,
    pub requests: usize,
    pub batches: usize,
    pub cache_hits: usize,
    pub oom: usize,
    pub latency: Summary,
    pub mean_overprovision: f64,
    pub mean_batch: f64,
}

/// Everything one serving run produced.
#[derive(Clone, Debug)]
pub struct ServeReport {
    /// Per-request records, ordered by request id.
    pub requests: Vec<RequestRecord>,
    /// One record per served batch (bucket-labelled), coordinator format.
    pub metrics: MetricsTable,
    /// Plan-cache counters accumulated during this run (delta since the
    /// trace started; `entries` is the absolute population — see
    /// `CacheStats::since`). Lifetime totals live on `MmService::cache`.
    pub cache: CacheStats,
    /// The same per-run deltas split per cache shard, in shard order.
    /// Component-wise sums reproduce [`Self::cache`] (tested), so a hot
    /// shard is directly visible. Empty for hand-built reports.
    pub cache_shards: Vec<CacheStats>,
    pub queue: QueueStats,
    pub batches: usize,
    /// Wall-clock seconds for the whole run (producer + workers).
    pub wall_seconds: f64,
    /// End-to-end latency distribution as a fixed-memory sketch: each
    /// worker folds its requests into a local sketch and the service
    /// merges them (deterministically, in worker order) at join time.
    pub latency_sketch: QuantileSketch,
    /// Circuit-breaker state changes during the run, merged across
    /// backends, in request-id (tick) order. Empty on the legacy path.
    pub breaker_transitions: Vec<BreakerEvent>,
    /// Faults the plan injected across every attempt of the run.
    pub injected_faults: u64,
}

/// Fault-layer accounting folded from per-request records.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultStats {
    pub served: usize,
    pub degraded: usize,
    pub shed: usize,
    pub panicked: usize,
    /// Device re-attempts (attempts beyond each request's first).
    pub retries: u64,
    /// Injected-fault total (mirrors `ServeReport::injected_faults`).
    pub injected: u64,
}

impl ServeReport {
    /// Fraction of requests served from a cached plan.
    pub fn hit_rate(&self) -> f64 {
        self.hit_rate_after(0)
    }

    /// Hit rate over requests with `id >= warmup` — the steady-state
    /// number once the cache has seen each bucket once. Requests whose
    /// dispatch never consulted the cache are excluded.
    pub fn hit_rate_after(&self, warmup: u64) -> f64 {
        let (mut hits, mut total) = (0usize, 0usize);
        for r in self.requests.iter().filter(|r| r.id >= warmup) {
            if let Some(hit) = r.cache_hit {
                total += 1;
                hits += hit as usize;
            }
        }
        if total == 0 {
            0.0
        } else {
            hits as f64 / total as f64
        }
    }

    /// Fold per-request outcomes into fault-layer accounting. On the
    /// legacy path this is all-served, zero everything else.
    pub fn fault_stats(&self) -> FaultStats {
        let mut s = FaultStats { injected: self.injected_faults, ..FaultStats::default() };
        for r in &self.requests {
            match r.outcome {
                RequestOutcome::Served => s.served += 1,
                RequestOutcome::Degraded(_) => s.degraded += 1,
                RequestOutcome::Shed(_) => s.shed += 1,
                RequestOutcome::Panicked => s.panicked += 1,
            }
            s.retries += u64::from(r.attempts.saturating_sub(1));
        }
        s
    }

    /// Served requests per wall second.
    pub fn throughput_rps(&self) -> f64 {
        if self.wall_seconds == 0.0 {
            0.0
        } else {
            self.requests.len() as f64 / self.wall_seconds
        }
    }

    /// Group request records per `(bucket, sparsity)` class, largest
    /// traffic first. Dense-only traces group exactly as before (one
    /// `None` row per bucket).
    pub fn bucket_stats(&self) -> Vec<BucketStats> {
        let mut classes: Vec<(MmShape, Option<SparsitySpec>)> =
            self.requests.iter().map(|r| (r.bucket, r.sparsity)).collect();
        classes.sort_by_key(|(b, s)| (b.m, b.n, b.k, s.map(|spec| spec.fingerprint())));
        classes.dedup();
        let mut out: Vec<BucketStats> = classes
            .into_iter()
            .map(|(bucket, sparsity)| {
                let recs: Vec<&RequestRecord> = self
                    .requests
                    .iter()
                    .filter(|r| r.bucket == bucket && r.sparsity == sparsity)
                    .collect();
                let lat: Vec<f64> = recs.iter().map(|r| r.latency_seconds()).collect();
                // every rider carries its batch's identity, so distinct
                // ids count batches exactly (the old sum(1/batch_size)
                // float estimate survives only as a test cross-check)
                let batches: std::collections::BTreeSet<u64> =
                    recs.iter().map(|r| r.batch_id).collect();
                BucketStats {
                    bucket,
                    sparsity,
                    requests: recs.len(),
                    batches: batches.len(),
                    cache_hits: recs.iter().filter(|r| r.cache_hit == Some(true)).count(),
                    oom: recs.iter().filter(|r| r.oom).count(),
                    latency: Summary::of(&lat),
                    mean_overprovision: recs.iter().map(|r| r.overprovision()).sum::<f64>()
                        / recs.len() as f64,
                    mean_batch: recs.iter().map(|r| r.batch_size as f64).sum::<f64>()
                        / recs.len() as f64,
                }
            })
            .collect();
        out.sort_by(|a, b| b.requests.cmp(&a.requests));
        out
    }

    /// Per-bucket latency table (the acceptance-criteria artifact).
    pub fn bucket_table(&self) -> Table {
        let mut t = Table::new(
            "serve: per-bucket latency / cache / batching",
            &[
                "bucket", "req", "batches", "hit%", "oom", "p50", "p95", "p99",
                "overprov", "avg batch",
            ],
        );
        for s in self.bucket_stats() {
            let label = match &s.sparsity {
                Some(spec) => format!("{} {}", BucketLadder::label(s.bucket), spec.label()),
                None => BucketLadder::label(s.bucket),
            };
            t.row(&[
                label,
                s.requests.to_string(),
                s.batches.to_string(),
                format!("{:.0}%", 100.0 * s.cache_hits as f64 / s.requests as f64),
                s.oom.to_string(),
                format!("{:.3} ms", s.latency.median * 1e3),
                format!("{:.3} ms", s.latency.p95 * 1e3),
                format!("{:.3} ms", s.latency.p99 * 1e3),
                format!("{:.2}x", s.mean_overprovision),
                format!("{:.1}", s.mean_batch),
            ]);
        }
        t
    }

    /// One-paragraph run summary for CLI/demo output.
    pub fn summary(&self) -> String {
        let lat: Vec<f64> = self.requests.iter().map(|r| r.latency_seconds()).collect();
        let line1 = format!(
            "served {} requests in {} batches over {:.2}s wall ({:.0} req/s)",
            self.requests.len(),
            self.batches,
            self.wall_seconds,
            self.throughput_rps(),
        );
        let line2 = format!(
            "plan cache: {:.1}% hit rate ({} hits / {} misses / {} evictions), {:.2}s of cold planning amortized",
            100.0 * self.cache.hit_rate(),
            self.cache.hits,
            self.cache.misses,
            self.cache.evictions,
            self.cache.cold_plan_seconds,
        );
        let line3 = if lat.is_empty() {
            "no requests served".to_string()
        } else {
            let s = Summary::of(&lat);
            format!(
                "request latency p50 {:.3} / p95 {:.3} / p99 {:.3} / p999 {:.3} ms; queue peak depth {}, {} rejected",
                s.median * 1e3,
                s.p95 * 1e3,
                s.p99 * 1e3,
                s.p999 * 1e3,
                self.queue.max_depth,
                self.queue.rejected,
            )
        };
        let f = self.fault_stats();
        if f.injected > 0
            || f.degraded + f.shed + f.panicked > 0
            || !self.breaker_transitions.is_empty()
        {
            let line4 = format!(
                "faults: {} injected, {} retries; {} degraded / {} shed / {} panicked; {} breaker transitions",
                f.injected,
                f.retries,
                f.degraded,
                f.shed,
                f.panicked,
                self.breaker_transitions.len(),
            );
            return format!("{line1}\n{line2}\n{line3}\n{line4}");
        }
        format!("{line1}\n{line2}\n{line3}")
    }

    /// The `(bucket, sparsity)` traffic-class label the tables use —
    /// also the window/export class key, so timeline rows line up with
    /// [`Self::bucket_table`] rows.
    fn class_label(bucket: MmShape, sparsity: &Option<SparsitySpec>) -> String {
        match sparsity {
            Some(spec) => format!("{} {}", BucketLadder::label(bucket), spec.label()),
            None => BucketLadder::label(bucket),
        }
    }

    /// Per-request metric events for the obs window/SLO/export layers,
    /// positioned by request id so windowing is deterministic across
    /// worker counts and machines.
    pub fn events(&self) -> Vec<MetricEvent> {
        self.requests
            .iter()
            .map(|r| MetricEvent {
                pos: r.id,
                class: Self::class_label(r.bucket, &r.sparsity),
                latency_s: r.latency_seconds(),
                cache_lookup: r.cache_hit.is_some(),
                cache_hit: r.cache_hit == Some(true),
                queue_depth: r.queue_depth as u64,
                oom: r.oom,
            })
            .collect()
    }

    /// Tumbling-window view of the run: per-class rps / hit rate /
    /// queue depth / latency sketch for each `width`-request window.
    pub fn timeline(&self, width: u64) -> Vec<WindowStats> {
        windowed(&self.events(), WindowSpec::tumbling(width))
    }

    /// Fold the whole run into an exportable [`MetricsSnapshot`]:
    /// counters, gauges, per-class aggregates, a `window`-request
    /// tumbling timeline, and one verdict per SLO spec.
    pub fn metrics_snapshot(&self, window: u64, slos: &[SloSpec]) -> MetricsSnapshot {
        let events = self.events();
        let mut counters = BTreeMap::new();
        counters.insert("ipumm_serve_requests_total".to_string(), self.requests.len() as u64);
        counters.insert("ipumm_serve_batches_total".to_string(), self.batches as u64);
        counters.insert("ipumm_serve_cache_hits_total".to_string(), self.cache.hits);
        counters.insert("ipumm_serve_cache_misses_total".to_string(), self.cache.misses);
        counters.insert("ipumm_serve_cache_evictions_total".to_string(), self.cache.evictions);
        counters.insert("ipumm_serve_queue_rejected_total".to_string(), self.queue.rejected);
        counters.insert("ipumm_serve_queue_throttled_total".to_string(), self.queue.throttled);
        counters.insert(
            "ipumm_serve_oom_total".to_string(),
            self.requests.iter().filter(|r| r.oom).count() as u64,
        );
        // fault-layer counters: always present (zero on the legacy
        // path) so dashboards and CI can assert on the family names
        let f = self.fault_stats();
        counters.insert("ipumm_serve_retries_total".to_string(), f.retries);
        counters.insert("ipumm_serve_shed_total".to_string(), f.shed as u64);
        counters.insert("ipumm_serve_degraded_total".to_string(), f.degraded as u64);
        counters.insert("ipumm_serve_panicked_total".to_string(), f.panicked as u64);
        counters.insert("ipumm_serve_faults_injected_total".to_string(), f.injected);
        counters.insert(
            "ipumm_serve_breaker_transitions_total".to_string(),
            self.breaker_transitions.len() as u64,
        );
        let mut gauges = BTreeMap::new();
        gauges.insert("ipumm_serve_wall_seconds".to_string(), self.wall_seconds);
        gauges.insert("ipumm_serve_throughput_rps".to_string(), self.throughput_rps());
        gauges.insert("ipumm_serve_cache_hit_rate".to_string(), self.hit_rate());
        gauges.insert(
            "ipumm_serve_cold_plan_seconds".to_string(),
            self.cache.cold_plan_seconds,
        );
        gauges.insert("ipumm_serve_queue_max_depth".to_string(), self.queue.max_depth as f64);
        MetricsSnapshot::build(&events, counters, gauges, WindowSpec::tumbling(window), slos)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(id: u64, bucket: usize, hit: bool, batch: usize) -> RequestRecord {
        RequestRecord {
            id,
            shape: MmShape::square(bucket - 8),
            bucket: MmShape::square(bucket),
            sparsity: None,
            backend: "ipu-sim/GC200".into(),
            batch_id: id, // solo batch by default; tests override for riders
            batch_size: batch,
            cache_hit: Some(hit),
            queue_seconds: 1e-4,
            queue_depth: 1,
            plan_seconds: if hit { 0.0 } else { 1e-2 },
            device_seconds: 1e-3,
            real_seconds: None,
            oom: false,
            outcome: RequestOutcome::Served,
            attempts: 1,
            retry_seconds: 0.0,
        }
    }

    fn report(requests: Vec<RequestRecord>) -> ServeReport {
        let batches = requests
            .iter()
            .map(|r| 1.0 / r.batch_size as f64)
            .sum::<f64>()
            .round() as usize;
        let mut latency_sketch = QuantileSketch::new();
        for r in &requests {
            latency_sketch.observe(r.latency_seconds());
        }
        ServeReport {
            requests,
            metrics: MetricsTable::default(),
            cache: CacheStats { hits: 3, misses: 1, ..CacheStats::default() },
            cache_shards: Vec::new(),
            queue: QueueStats::default(),
            batches,
            wall_seconds: 0.5,
            latency_sketch,
            breaker_transitions: Vec::new(),
            injected_faults: 0,
        }
    }

    #[test]
    fn hit_rate_counts_requests_not_batches() {
        let r = report(vec![
            rec(0, 256, false, 1),
            rec(1, 256, true, 2),
            rec(2, 256, true, 2),
            rec(3, 512, false, 1),
        ]);
        assert!((r.hit_rate() - 0.5).abs() < 1e-12);
        assert!((r.hit_rate_after(1) - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn hit_rate_ignores_requests_that_skipped_the_cache() {
        let mut no_cache = rec(0, 256, false, 1);
        no_cache.cache_hit = None;
        no_cache.backend = "gpu-model/A30".into();
        let r = report(vec![no_cache, rec(1, 256, true, 1)]);
        assert!((r.hit_rate() - 1.0).abs() < 1e-12, "None records excluded");
    }

    #[test]
    fn latency_includes_amortized_planning() {
        let cold = rec(0, 256, false, 1);
        let warm = rec(1, 256, true, 1);
        assert!(cold.latency_seconds() > warm.latency_seconds());
        assert!((warm.latency_seconds() - 1.1e-3).abs() < 1e-9);
    }

    #[test]
    fn bucket_stats_group_and_count_batches() {
        // riders 1 and 2 share one batch (batch_id = first rider id)
        let pair = |id: u64| {
            let mut r = rec(id, 256, true, 2);
            r.batch_id = 1;
            r
        };
        let r = report(vec![rec(0, 256, false, 1), pair(1), pair(2), rec(3, 512, false, 1)]);
        let stats = r.bucket_stats();
        assert_eq!(stats.len(), 2);
        assert_eq!(stats[0].bucket, MmShape::square(256), "busiest first");
        assert_eq!(stats[0].requests, 3);
        assert_eq!(stats[0].batches, 2, "one solo + one coalesced pair");
        assert_eq!(stats[0].cache_hits, 2);
        assert!((stats[0].mean_batch - 5.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn bucket_batches_count_distinct_batch_ids() {
        // three batches of sizes 1, 2, 3: distinct ids are exact by
        // construction — no float accumulation involved
        let mk = |id: u64, bid: u64, size: usize| {
            let mut r = rec(id, 256, true, size);
            r.batch_id = bid;
            r
        };
        let r = report(vec![
            mk(0, 0, 1),
            mk(1, 1, 2),
            mk(2, 1, 2),
            mk(3, 3, 3),
            mk(4, 3, 3),
            mk(5, 3, 3),
        ]);
        let stats = r.bucket_stats();
        assert_eq!(stats[0].batches, 3);
        // cross-check: on complete batches the retired sum(1/batch_size)
        // estimate agrees with the exact count
        let est: f64 = r.requests.iter().map(|q| 1.0 / q.batch_size as f64).sum();
        assert_eq!(est.round() as usize, 3);
    }

    #[test]
    fn bucket_stats_split_per_sparsity_class() {
        use crate::sparse::pattern::PatternKind;
        let half = SparsitySpec::new(PatternKind::Random, 8, 0.5, 1);
        let tenth = SparsitySpec::new(PatternKind::Banded, 8, 0.1, 1);
        let with_spec = |id: u64, spec: Option<SparsitySpec>| {
            let mut r = rec(id, 256, true, 1);
            r.sparsity = spec;
            r
        };
        let r = report(vec![
            with_spec(0, None),
            with_spec(1, Some(half)),
            with_spec(2, Some(half)),
            with_spec(3, Some(tenth)),
        ]);
        let stats = r.bucket_stats();
        assert_eq!(stats.len(), 3, "one row per (bucket, sparsity) class");
        assert_eq!(stats[0].sparsity, Some(half), "busiest class first");
        assert_eq!(stats[0].requests, 2);
        assert_eq!(
            stats.iter().filter(|s| s.sparsity.is_none()).count(),
            1,
            "dense traffic keeps its own row"
        );
        let ascii = r.bucket_table().to_ascii();
        assert!(ascii.contains(&half.label()), "{ascii}");
        assert!(ascii.contains(&tenth.label()), "{ascii}");
    }

    #[test]
    fn bucket_table_renders_every_bucket() {
        let r = report(vec![rec(0, 256, false, 1), rec(1, 512, false, 1)]);
        let t = r.bucket_table();
        assert_eq!(t.n_rows(), 2);
        let ascii = t.to_ascii();
        assert!(ascii.contains("256x256x256"));
        assert!(ascii.contains("512x512x512"));
    }

    #[test]
    fn summary_mentions_cache_and_latency() {
        let r = report(vec![rec(0, 256, false, 1), rec(1, 256, true, 1)]);
        let s = r.summary();
        assert!(s.contains("hit rate"));
        assert!(s.contains("p95"));
        assert!(s.contains("2 requests"));
    }

    #[test]
    fn empty_report_is_well_defined() {
        let r = report(vec![]);
        assert_eq!(r.hit_rate(), 0.0);
        assert!(r.summary().contains("no requests"));
        assert!(r.bucket_stats().is_empty());
        assert!(r.events().is_empty());
        assert!(r.timeline(10).is_empty());
    }

    #[test]
    fn events_carry_class_labels_matching_the_bucket_table() {
        use crate::sparse::pattern::PatternKind;
        let spec = SparsitySpec::new(PatternKind::Random, 8, 0.5, 1);
        let mut sparse = rec(1, 256, true, 1);
        sparse.sparsity = Some(spec);
        let r = report(vec![rec(0, 256, false, 1), sparse]);
        let events = r.events();
        assert_eq!(events.len(), 2);
        assert_eq!(events[0].class, "256x256x256");
        assert_eq!(events[1].class, format!("256x256x256 {}", spec.label()));
        assert_eq!(events[0].pos, 0);
        assert!(events[0].cache_lookup && !events[0].cache_hit);
        assert!(events[1].cache_hit);
        assert_eq!(events[0].queue_depth, 1);
    }

    #[test]
    fn timeline_windows_by_request_id() {
        let recs: Vec<RequestRecord> = (0..25).map(|i| rec(i, 256, true, 1)).collect();
        let r = report(recs);
        let tl = r.timeline(10);
        assert_eq!(tl.len(), 3);
        assert_eq!(tl[0].total_requests(), 10);
        assert_eq!(tl[2].total_requests(), 5);
        assert_eq!(tl[2].start, 20);
    }

    #[test]
    fn metrics_snapshot_counts_and_gates() {
        let r = report(vec![rec(0, 256, false, 1), rec(1, 256, true, 1), rec(2, 512, true, 1)]);
        let loose = crate::obs::slo::SloSpec::parse("p99<60s@99%").unwrap();
        let tight = crate::obs::slo::SloSpec::parse("p50<1ns@50%").unwrap();
        let snap = r.metrics_snapshot(10, &[loose, tight]);
        assert_eq!(snap.counters["ipumm_serve_requests_total"], 3);
        assert_eq!(snap.counters["ipumm_serve_cache_hits_total"], 3);
        assert_eq!(snap.classes.len(), 2);
        assert_eq!(snap.timeline.len(), 1);
        assert_eq!(snap.slos.len(), 2);
        assert!(!snap.slos[0].violated, "60s threshold passes");
        assert!(snap.slos[1].violated, "1ns threshold cannot pass");
        assert!(snap.any_slo_violated());
        let text = snap.prometheus_text();
        assert!(text.contains("ipumm_serve_requests_total 3"));
        assert!(text.contains("ipumm_serve_latency_seconds{class=\"256x256x256\",quantile=\"0.5\"}"));
    }

    #[test]
    fn fault_stats_fold_outcomes_and_retries() {
        use crate::fault::{DegradeReason, ShedReason};
        let mut degraded = rec(1, 256, true, 1);
        degraded.outcome = RequestOutcome::Degraded(DegradeReason::RetriesExhausted);
        degraded.attempts = 4;
        degraded.retry_seconds = 3e-4;
        let mut shed = rec(2, 256, true, 1);
        shed.outcome = RequestOutcome::Shed(ShedReason::DeadlineExceeded);
        shed.attempts = 2;
        let mut panicked = rec(3, 256, true, 1);
        panicked.outcome = RequestOutcome::Panicked;
        let mut r = report(vec![rec(0, 256, true, 1), degraded, shed, panicked]);
        r.injected_faults = 5;
        let f = r.fault_stats();
        assert_eq!(
            (f.served, f.degraded, f.shed, f.panicked),
            (1, 1, 1, 1),
            "one of each outcome"
        );
        assert_eq!(f.retries, 4, "3 from the degraded + 1 from the shed");
        assert_eq!(f.injected, 5);
        let s = r.summary();
        assert!(s.contains("faults: 5 injected"), "{s}");
        assert!(s.contains("1 degraded / 1 shed / 1 panicked"), "{s}");
    }

    #[test]
    fn legacy_reports_keep_zeroed_fault_counters_and_no_fault_line() {
        let r = report(vec![rec(0, 256, true, 1)]);
        let f = r.fault_stats();
        assert_eq!(f, FaultStats { served: 1, ..FaultStats::default() });
        assert!(!r.summary().contains("faults:"), "legacy summary unchanged");
        let snap = r.metrics_snapshot(10, &[]);
        for name in [
            "ipumm_serve_retries_total",
            "ipumm_serve_shed_total",
            "ipumm_serve_degraded_total",
            "ipumm_serve_panicked_total",
            "ipumm_serve_faults_injected_total",
            "ipumm_serve_breaker_transitions_total",
        ] {
            assert_eq!(snap.counters[name], 0, "{name} present and zero");
        }
    }

    #[test]
    fn retry_seconds_count_into_latency_and_snapshot_counters() {
        let mut retried = rec(0, 256, true, 1);
        retried.attempts = 3;
        retried.retry_seconds = 2e-3;
        let base = rec(1, 256, true, 1);
        assert!(
            retried.latency_seconds() > base.latency_seconds() + 1.9e-3,
            "retry waste is part of end-to-end latency"
        );
        let mut r = report(vec![retried, base]);
        r.injected_faults = 2;
        r.breaker_transitions.push(BreakerEvent {
            backend: "ipu-sim/GC200".into(),
            tick: 4,
            from: crate::fault::BreakerState::Closed,
            to: crate::fault::BreakerState::Open,
        });
        let snap = r.metrics_snapshot(10, &[]);
        assert_eq!(snap.counters["ipumm_serve_retries_total"], 2);
        assert_eq!(snap.counters["ipumm_serve_faults_injected_total"], 2);
        assert_eq!(snap.counters["ipumm_serve_breaker_transitions_total"], 1);
        let text = snap.prometheus_text();
        assert!(text.contains("ipumm_serve_retries_total 2"), "{text}");
    }
}
