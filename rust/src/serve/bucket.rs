//! Shape bucketing: round requests up to a ladder of block classes.
//!
//! A serving workload's shapes form a long, skewed tail (the paper's §5.2:
//! "skewed matrices are dominant in the field of AI and ML"), and every
//! distinct shape costs a planner search. Bucketing rounds each incoming
//! `(m, n, k)` **up** to the nearest rung of a ladder so near-miss shapes
//! share one cached plan. The invariant the rest of the stack relies on:
//! a bucket is never smaller than the request in any dimension, so a plan
//! (or OOM verdict) for the bucket is always sufficient for the request.
//!
//! The default ladder walks `{2^i, 3·2^(i-1)}` multiples of a base block —
//! the same geometric spacing as the paper's Fig. 5 aspect-ratio sweep
//! (ratios 4^i), so every sweep point is itself a rung and skew classes
//! stay distinguishable after rounding. Consecutive rung ratios
//! alternate 3/2 and 4/3, bounding padded work per dimension at 50% and
//! padded flops at (3/2)^3 ~ 3.4x worst case (typical traffic sits far
//! below; see `overprovision`). [`BucketLadder::block_aligned`] snaps the
//! rungs to multiples of an AOT block edge so the real execution path
//! (`runtime::blockmm`, which pads to block multiples anyway) wastes no
//! extra flops on bucketed shapes; [`BucketLadder::from_manifest`] derives
//! that alignment from the artifact manifest.

use crate::planner::partition::MmShape;
use crate::runtime::manifest::Manifest;
use crate::util::units::round_up;

/// An ascending ladder of dimension classes.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BucketLadder {
    rungs: Vec<usize>,
}

impl BucketLadder {
    /// Geometric `{base·2^i, base·3·2^(i-1)}` ladder up to `max`
    /// (inclusive; `max` itself is always a rung).
    pub fn geometric(base: usize, max: usize) -> BucketLadder {
        assert!(base >= 1, "ladder base must be positive");
        assert!(max >= base, "ladder max {max} below base {base}");
        let mut rungs = Vec::new();
        let mut b = base;
        while b <= max {
            rungs.push(b);
            let mid = b / 2 * 3;
            if b % 2 == 0 && mid <= max {
                rungs.push(mid);
            }
            b *= 2;
        }
        if *rungs.last().expect("base <= max") != max {
            rungs.push(max);
        }
        BucketLadder { rungs }
    }

    /// Geometric ladder whose rungs are rounded up to multiples of
    /// `block`, so every bucket dimension quantizes exactly into the
    /// fixed-shape block artifacts `runtime::blockmm` composes.
    pub fn block_aligned(block: usize, max: usize) -> BucketLadder {
        assert!(block >= 1, "block edge must be positive");
        let geo = BucketLadder::geometric(block, round_up(max, block));
        let mut rungs: Vec<usize> = geo.rungs.iter().map(|&r| round_up(r, block)).collect();
        rungs.dedup();
        BucketLadder { rungs }
    }

    /// Ladder aligned to the best block artifact in `manifest` no larger
    /// than `block_cap` (the same choice `runtime::blockmm` makes).
    pub fn from_manifest(manifest: &Manifest, block_cap: usize, max: usize) -> Option<BucketLadder> {
        manifest
            .pick_block(block_cap)
            .map(|spec| BucketLadder::block_aligned(spec.m, max))
    }

    /// Explicit rungs (must be ascending and positive).
    pub fn from_rungs(rungs: Vec<usize>) -> BucketLadder {
        assert!(!rungs.is_empty(), "ladder needs at least one rung");
        assert!(rungs[0] >= 1, "rungs must be positive");
        assert!(
            rungs.windows(2).all(|w| w[0] < w[1]),
            "rungs must be strictly ascending"
        );
        BucketLadder { rungs }
    }

    pub fn rungs(&self) -> &[usize] {
        &self.rungs
    }

    /// Round one dimension up to its class: the smallest rung that holds
    /// it, or — past the top rung — the next multiple of the top rung
    /// (so the never-smaller invariant holds for any input).
    pub fn bucket_dim(&self, dim: usize) -> usize {
        assert!(dim >= 1, "degenerate dimension");
        match self.rungs.iter().find(|&&r| r >= dim) {
            Some(&r) => r,
            None => round_up(dim, *self.rungs.last().expect("non-empty ladder")),
        }
    }

    /// The bucket (plan-cache key shape) for a request.
    pub fn bucket(&self, shape: MmShape) -> MmShape {
        MmShape::new(
            self.bucket_dim(shape.m),
            self.bucket_dim(shape.n),
            self.bucket_dim(shape.k),
        )
    }

    /// Human label for a bucket, e.g. `1024x512x256`.
    pub fn label(bucket: MmShape) -> String {
        format!("{}x{}x{}", bucket.m, bucket.n, bucket.k)
    }

    /// Padded-work factor of serving `request` at `bucket` size:
    /// bucket flops / request flops (>= 1).
    pub fn overprovision(request: MmShape, bucket: MmShape) -> f64 {
        bucket.flops() as f64 / request.flops() as f64
    }
}

impl Default for BucketLadder {
    /// Covers the GC200's whole fitting range: base 64 up past the §2.4
    /// memory wall (out-of-tolerance requests still bucket, they just
    /// cache an OOM verdict).
    fn default() -> BucketLadder {
        BucketLadder::geometric(64, 8192)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    #[test]
    fn geometric_ladder_has_expected_rungs() {
        let l = BucketLadder::geometric(64, 1024);
        assert_eq!(l.rungs(), &[64, 96, 128, 192, 256, 384, 512, 768, 1024]);
    }

    #[test]
    fn consecutive_rungs_within_three_halves() {
        let l = BucketLadder::default();
        for w in l.rungs().windows(2) {
            let ratio = w[1] as f64 / w[0] as f64;
            assert!(ratio <= 1.5 + 1e-9, "gap {} -> {}", w[0], w[1]);
        }
    }

    #[test]
    fn bucket_never_smaller_than_request() {
        let l = BucketLadder::default();
        for &(m, n, k) in &[(1, 1, 1), (65, 2000, 511), (8193, 64, 12_000)] {
            let req = MmShape::new(m, n, k);
            let b = l.bucket(req);
            assert!(b.m >= req.m && b.n >= req.n && b.k >= req.k, "{req:?} -> {b:?}");
        }
    }

    #[test]
    fn bucket_is_idempotent() {
        let l = BucketLadder::default();
        let b = l.bucket(MmShape::new(700, 130, 9000));
        assert_eq!(l.bucket(b), b, "bucketing a bucket must be a fixpoint");
    }

    #[test]
    fn past_top_rung_rounds_to_top_multiples() {
        let l = BucketLadder::geometric(64, 1024);
        assert_eq!(l.bucket_dim(1025), 2048);
        assert_eq!(l.bucket_dim(2049), 3072);
    }

    #[test]
    fn near_miss_shapes_share_a_bucket() {
        // jittered variants of one workload collapse to one cache key
        let l = BucketLadder::default();
        let a = l.bucket(MmShape::new(1000, 490, 250));
        let b = l.bucket(MmShape::new(970, 512, 241));
        assert_eq!(a, b);
        assert_eq!(a, MmShape::new(1024, 512, 256));
    }

    #[test]
    fn block_aligned_rungs_are_multiples() {
        let l = BucketLadder::block_aligned(128, 4096);
        assert!(l.rungs().iter().all(|r| r % 128 == 0), "{:?}", l.rungs());
        assert!(l.rungs().contains(&128));
        assert_eq!(l.bucket_dim(100), 128);
    }

    #[test]
    fn from_manifest_uses_picked_block() {
        let tsv = "block\tmm_block_64\tmm_block_64.hlo.txt\t64\t64\t64\tf32\n\
                   block\tmm_block_128\tmm_block_128.hlo.txt\t128\t128\t128\tf32\n";
        let manifest = Manifest::parse(tsv, Path::new("/art")).unwrap();
        let l = BucketLadder::from_manifest(&manifest, 4096, 2048).unwrap();
        assert!(l.rungs().iter().all(|r| r % 128 == 0));
    }

    #[test]
    fn overprovision_is_at_least_one() {
        let l = BucketLadder::default();
        let req = MmShape::new(900, 450, 220);
        let b = l.bucket(req);
        let f = BucketLadder::overprovision(req, b);
        assert!((1.0..=2.4).contains(&f), "overprovision {f}");
        assert_eq!(BucketLadder::overprovision(b, b), 1.0);
    }

    #[test]
    fn skew_classes_stay_distinguishable() {
        // the paper's fig5 ladder points are fixpoints of the default
        // ladder: aspect-ratio structure survives bucketing
        let l = BucketLadder::default();
        for p in crate::coordinator::sweep::aspect_ratio_ladder(22, 4, 2048) {
            if p.shape.m <= 8192 && p.shape.n <= 8192 {
                assert_eq!(l.bucket(p.shape), p.shape, "{:?}", p.shape);
            }
        }
    }

    #[test]
    #[should_panic(expected = "ascending")]
    fn unsorted_rungs_rejected() {
        BucketLadder::from_rungs(vec![64, 32]);
    }
}
