//! The serving front door: bucket, enqueue, coalesce, plan-from-cache,
//! dispatch across backends.
//!
//! A worker pool (sized by the same policy as `coordinator::runner`, see
//! [`default_workers`]) drains the bounded request queue. Each coalesced
//! batch costs **one** plan-cache lookup; the search result decides the
//! dispatch:
//!
//! * plan found — the request is priced on the IPU simulator directly
//!   from the cached plan (no re-search, no graph rebuild: the plan cost
//!   already carries cycles, efficiency, vertex census and peak tile
//!   bytes — execution uses the same outcome contract as
//!   `coordinator::device::run_shape`);
//! * out of memory (the paper's §2.4 wall) — the batch falls back to the
//!   GPU model (policy permitting), mirroring how a heterogeneous fleet
//!   sheds IPU-infeasible shapes;
//! * with the `xla` feature and AOT artifacts present, miss batches are
//!   additionally executed for real through `runtime::blockmm` and
//!   verified against the oracle, so the serving path stays anchored to
//!   actually-performed multiplications.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::Mutex;
use std::time::Instant;

use crate::arch::{GpuArch, IpuArch};
use crate::obs::sketch::QuantileSketch;
use crate::coordinator::device::{run_shape, Backend, RunOutcome};
use crate::coordinator::metrics::{MetricsRecord, MetricsTable};
use crate::coordinator::runner::default_workers;
use crate::fault::{
    resolve_one, BackendLeg, BreakerEvent, CircuitBreaker, FaultPlan, FaultPolicy,
    RequestOutcome, Resolution,
};
use crate::planner::partition::MmShape;
use crate::planner::search::Plan;
use crate::serve::bucket::BucketLadder;
use crate::serve::cache::PlanCache;
use crate::serve::queue::{Batch, MmRequest, RequestQueue};
use crate::serve::telemetry::{RequestRecord, ServeReport};
use crate::sparse::pattern::SparsitySpec;
use crate::sparse::planner::SparsePlan;

/// How batches spread over the configured backends.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DispatchPolicy {
    /// IPU simulator first; shapes past the IPU memory wall go to the
    /// GPU model (default).
    IpuWithGpuFallback,
    /// IPU only; infeasible shapes are reported OOM.
    IpuOnly,
    /// GPU model only (baseline / ablation).
    GpuOnly,
}

/// Serving-layer configuration.
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    pub arch: IpuArch,
    pub gpu: GpuArch,
    pub ladder: BucketLadder,
    pub policy: DispatchPolicy,
    /// Plan-cache entries (shape x arch keys).
    pub cache_capacity: usize,
    /// Bounded queue depth (admission control beyond it).
    pub queue_capacity: usize,
    /// Max requests coalesced into one batch.
    pub max_batch: usize,
    /// Worker threads; `None` uses the shared
    /// `coordinator::runner::default_workers` policy.
    pub workers: Option<usize>,
    /// AOT artifact directory for the real PJRT path (used only when the
    /// `xla` feature is enabled and the directory holds a manifest).
    pub artifacts: Option<std::path::PathBuf>,
    /// Seeded fault plan. [`FaultPlan::none`] (the default) injects
    /// nothing and — together with a passthrough policy — keeps the
    /// serve path bit-identical to a fault-layer-free build.
    pub faults: FaultPlan,
    /// Deadline / retry / breaker policy. [`FaultPolicy::passthrough`]
    /// (the default) disables all of it.
    pub fault_policy: FaultPolicy,
}

impl Default for ServiceConfig {
    fn default() -> ServiceConfig {
        ServiceConfig {
            arch: IpuArch::gc200(),
            gpu: GpuArch::a30(),
            ladder: BucketLadder::default(),
            policy: DispatchPolicy::IpuWithGpuFallback,
            cache_capacity: 256,
            queue_capacity: 1024,
            max_batch: 32,
            workers: None,
            artifacts: None,
            faults: FaultPlan::none(),
            fault_policy: FaultPolicy::passthrough(),
        }
    }
}

/// Matmul-as-a-service: owns the plan cache and the dispatch policy.
pub struct MmService {
    config: ServiceConfig,
    cache: PlanCache,
    #[cfg(feature = "xla")]
    real: Option<Mutex<crate::runtime::blockmm::BlockMmExecutor>>,
}

impl MmService {
    pub fn new(config: ServiceConfig) -> MmService {
        #[cfg(feature = "xla")]
        let (config, real) = {
            let mut config = config;
            let real = config
                .artifacts
                .as_deref()
                .and_then(|dir| crate::runtime::blockmm::BlockMmExecutor::load(dir, 256).ok());
            if let Some(ex) = &real {
                // align the ladder to the loaded block artifact so the
                // real path pads no extra flops on bucketed shapes
                let top = *config.ladder.rungs().last().expect("non-empty ladder");
                config.ladder = BucketLadder::block_aligned(ex.block, top);
            }
            (config, real.map(Mutex::new))
        };
        MmService {
            cache: PlanCache::new(config.cache_capacity),
            config,
            #[cfg(feature = "xla")]
            real,
        }
    }

    pub fn config(&self) -> &ServiceConfig {
        &self.config
    }

    /// The long-lived plan cache (persists across traces — a warm
    /// service keeps its plans).
    pub fn cache(&self) -> &PlanCache {
        &self.cache
    }

    /// Backend names this service can dispatch to, coordinator naming.
    pub fn backends(&self) -> Vec<String> {
        let mut out = Vec::new();
        if self.config.policy != DispatchPolicy::GpuOnly {
            out.push(Backend::IpuSim(self.config.arch.clone()).name());
        }
        if self.config.policy != DispatchPolicy::IpuOnly {
            out.push(Backend::GpuModel(self.config.gpu.clone()).name());
        }
        #[cfg(feature = "xla")]
        if self.real.is_some() {
            out.push("pjrt-real/cpu".to_string());
        }
        out
    }

    /// Serve a request trace to completion: submit every shape through
    /// the bounded queue (blocking backpressure) while a worker pool
    /// drains coalesced batches. Returns per-request and per-bucket
    /// telemetry.
    pub fn serve_trace(&self, shapes: &[MmShape]) -> ServeReport {
        let dense: Vec<(MmShape, Option<SparsitySpec>)> =
            shapes.iter().map(|&s| (s, None)).collect();
        self.serve_trace_mixed(&dense)
    }

    /// [`Self::serve_trace`] for a mixed dense/sparse trace: each request
    /// optionally carries a block-sparsity descriptor. Sparse requests
    /// bucket like dense ones but coalesce and cache per sparsity
    /// fingerprint (see `serve::cache`).
    pub fn serve_trace_mixed(&self, reqs: &[(MmShape, Option<SparsitySpec>)]) -> ServeReport {
        let queue = RequestQueue::new(self.config.queue_capacity);
        let fault_mode =
            self.config.faults.is_active() || !self.config.fault_policy.is_passthrough();
        // the configured count is a request against the process-wide
        // thread budget: a service embedded in a sweep (or several
        // services in one process) cannot oversubscribe the machine, and
        // nested cold-miss planner searches draw from the same pool
        let lease = crate::coordinator::runner::ThreadBudget::global().acquire(
            self.config
                .workers
                .unwrap_or_else(default_workers)
                .max(1),
        );
        let workers = lease.workers();
        let records: Mutex<Vec<RequestRecord>> = Mutex::new(Vec::with_capacity(reqs.len()));
        // keyed by earliest rider id so the emitted table/CSV row order is
        // deterministic regardless of worker scheduling (run_jobs makes
        // the same guarantee via submission order)
        let batch_records: Mutex<Vec<(u64, MetricsRecord)>> = Mutex::new(Vec::new());
        // each worker folds latencies into a local sketch (no shared
        // lock on the per-sample path); merged in worker order below so
        // the report sketch is deterministic for a given rider->worker
        // assignment
        let worker_sketches: Mutex<Vec<(usize, QuantileSketch)>> = Mutex::new(Vec::new());
        let cache_baseline = self.cache.stats();
        let shard_baseline = self.cache.shard_stats();

        // A worker that unwinds must close the queue on its way out:
        // otherwise a blocked producer waits forever on a condvar nobody
        // will signal and the panic never propagates out of the scope.
        struct CloseOnDrop<'a>(&'a RequestQueue);
        impl Drop for CloseOnDrop<'_> {
            fn drop(&mut self) {
                self.0.close();
            }
        }

        let t_trace = crate::obs::now();
        let t0 = Instant::now();
        let mut breaker_events: Vec<BreakerEvent> = Vec::new();
        // Fault pipeline pre-pass: resolve every request's outcome in
        // request-id order *before* workers fan out. The breaker ticks
        // on request ids and every fault draw is a pure hash, so the
        // resolved outcomes — and hence the whole served trace — are
        // identical across runs and worker counts. Workers then only
        // emit what was already decided. `None` on the legacy path.
        let resolutions: Option<Vec<Resolution>> = fault_mode.then(|| {
            let indexed: Vec<(u64, MmShape, Option<SparsitySpec>)> = reqs
                .iter()
                .enumerate()
                .map(|(i, &(shape, sparsity))| (i as u64, shape, sparsity))
                .collect();
            let (res, events) = self.resolve_requests(&indexed);
            breaker_events = events;
            res
        });
        let resolutions = resolutions.as_deref();
        std::thread::scope(|scope| {
            for w in 0..workers {
                let queue = &queue;
                let records = &records;
                let batch_records = &batch_records;
                let worker_sketches = &worker_sketches;
                scope.spawn(move || {
                    let _guard = CloseOnDrop(queue);
                    let mut lat = QuantileSketch::new();
                    let mut qwait = QuantileSketch::new();
                    while let Some(batch) = queue.next_batch(self.config.max_batch) {
                        // riders the plan panics are peeled into solo
                        // batches so the unwind takes out exactly one
                        // request, not its batchmates
                        for sub in self.split_for_panics(batch, fault_mode) {
                            // panic isolation: a panicking plan/dispatch
                            // (injected or genuine) marks this batch
                            // Panicked and the worker keeps draining
                            let unwound = catch_unwind(AssertUnwindSafe(|| {
                                self.process_batch(
                                    w, &sub, resolutions, records, batch_records, &mut lat,
                                    &mut qwait,
                                );
                            }))
                            .is_err();
                            if unwound {
                                self.record_panicked(&sub, records);
                            }
                        }
                    }
                    // one global-recorder merge per worker, not per sample
                    crate::obs::merge_sketch("serve.latency_seconds", &lat);
                    crate::obs::merge_sketch("serve.queue_seconds", &qwait);
                    worker_sketches
                        .lock()
                        .unwrap_or_else(|e| e.into_inner())
                        .push((w, lat));
                });
            }
            for (i, &(shape, sparsity)) in reqs.iter().enumerate() {
                let bucket = self.config.ladder.bucket(shape);
                let mut req = MmRequest::new(i as u64, shape, bucket);
                if let Some(spec) = sparsity {
                    req = req.with_sparsity(spec);
                }
                if queue.submit_blocking(req).is_err() {
                    // queue closed early: a worker died; stop producing
                    // and let scope join propagate its panic
                    break;
                }
            }
            queue.close();
        });
        let wall_seconds = t0.elapsed().as_secs_f64();
        if t_trace.is_some() {
            crate::obs::wall_span_since(
                t_trace,
                "serve",
                &format!("serve_trace ({} requests)", reqs.len()),
                "serve",
                &[("workers", workers.to_string())],
            );
        }

        // a panicked worker may have poisoned these; per-entry writes
        // are atomic, so the state is valid — recover, don't cascade
        let mut requests = records.into_inner().unwrap_or_else(|e| e.into_inner());
        requests.sort_by_key(|r| r.id);
        let mut batch_recs = batch_records.into_inner().unwrap_or_else(|e| e.into_inner());
        batch_recs.sort_by_key(|(first_id, _)| *first_id);
        let mut metrics = MetricsTable::default();
        for (_, rec) in batch_recs {
            metrics.push(rec);
        }
        let mut shards = worker_sketches.into_inner().unwrap_or_else(|e| e.into_inner());
        shards.sort_by_key(|(w, _)| *w);
        let mut latency_sketch = QuantileSketch::new();
        for (_, s) in &shards {
            latency_sketch.merge(s);
        }
        ServeReport {
            batches: metrics.len(),
            latency_sketch,
            // per-run delta: a warm service's lifetime counters would
            // otherwise masquerade as this trace's behavior
            cache: self.cache.stats().since(&cache_baseline),
            cache_shards: self
                .cache
                .shard_stats()
                .iter()
                .zip(&shard_baseline)
                .map(|(now, base)| now.since(base))
                .collect(),
            queue: queue.stats(),
            requests,
            metrics,
            wall_seconds,
            breaker_transitions: breaker_events,
            injected_faults: resolutions
                .map(|res| res.iter().map(|r| u64::from(r.injected)).sum())
                .unwrap_or(0),
        }
    }

    /// Resolve a whole trace through the fault pipeline, in request-id
    /// order, with one long-lived breaker per backend. Ids are explicit
    /// (not positional) so the chaos shrinker can remove requests while
    /// the survivors keep their original fault draws. Legs are built
    /// fault-free (per-request cache lookups); [`resolve_one`] decides
    /// what the faults and policy make of them.
    pub fn resolve_requests(
        &self,
        reqs: &[(u64, MmShape, Option<SparsitySpec>)],
    ) -> (Vec<Resolution>, Vec<BreakerEvent>) {
        let plan = &self.config.faults;
        let policy = &self.config.fault_policy;
        let ipu_name = Backend::IpuSim(self.config.arch.clone()).name();
        let gpu_backend = Backend::GpuModel(self.config.gpu.clone());
        let gpu_name = gpu_backend.name();
        let mut ipu_breaker = CircuitBreaker::new(policy.breaker);
        let mut gpu_breaker = CircuitBreaker::new(policy.breaker);
        let mut out = Vec::with_capacity(reqs.len());
        for &(id, shape, sparsity) in reqs {
            let bucket = self.config.ladder.bucket(shape);
            let ipu_leg = (self.config.policy != DispatchPolicy::GpuOnly).then(|| {
                let (result, hit, plan_seconds) = match sparsity {
                    None => {
                        let (r, h, s) = self.cache.get_or_plan_timed(&self.config.arch, bucket);
                        (r.map(|p| self.outcome_from_plan(&p)), h, s)
                    }
                    Some(spec) => {
                        let (r, h, s) =
                            self.cache.get_or_plan_sparse_timed(&self.config.arch, bucket, spec);
                        (r.map(|p| self.outcome_from_sparse_plan(&p)), h, s)
                    }
                };
                BackendLeg {
                    // a planner error is the §2.4 wall: an OOM verdict
                    run: result.unwrap_or(RunOutcome::OutOfMemory),
                    backend: ipu_name.clone(),
                    cache_hit: Some(hit),
                    plan_seconds,
                }
            });
            let gpu_leg = (self.config.policy != DispatchPolicy::IpuOnly).then(|| BackendLeg {
                run: run_shape(&gpu_backend, bucket),
                backend: gpu_name.clone(),
                cache_hit: None,
                plan_seconds: 0.0,
            });
            out.push(resolve_one(
                id,
                ipu_leg.as_ref(),
                gpu_leg.as_ref(),
                plan,
                policy,
                &mut ipu_breaker,
                &mut gpu_breaker,
            ));
        }
        let label = |backend: &str, t: &crate::fault::BreakerTransition| BreakerEvent {
            backend: backend.to_string(),
            tick: t.tick,
            from: t.from,
            to: t.to,
        };
        let mut events: Vec<BreakerEvent> = ipu_breaker
            .transitions()
            .iter()
            .map(|t| label(&ipu_name, t))
            .chain(gpu_breaker.transitions().iter().map(|t| label(&gpu_name, t)))
            .collect();
        // stable: same-tick events keep IPU-before-GPU order
        events.sort_by_key(|e| e.tick);
        (out, events)
    }

    /// Peel riders the fault plan panics into solo batches, so the
    /// unwind is scoped to exactly one request. A no-op (one untouched
    /// batch) outside fault mode or when the profile never panics.
    fn split_for_panics(&self, batch: Batch, fault_mode: bool) -> Vec<Batch> {
        if !fault_mode || self.config.faults.profile.panic_permille == 0 {
            return vec![batch];
        }
        let (doomed, clean): (Vec<MmRequest>, Vec<MmRequest>) = batch
            .requests
            .into_iter()
            .partition(|r| self.config.faults.injects_panic(r.id));
        let mut out = Vec::with_capacity(doomed.len() + 1);
        if !clean.is_empty() {
            out.push(Batch {
                bucket: batch.bucket,
                sparsity: batch.sparsity,
                requests: clean,
                queued_behind: batch.queued_behind,
            });
        }
        for rider in doomed {
            out.push(Batch {
                bucket: batch.bucket,
                sparsity: batch.sparsity,
                requests: vec![rider],
                queued_behind: batch.queued_behind,
            });
        }
        out
    }

    /// Post-unwind accounting: every rider of a panicked batch gets a
    /// `Panicked` record (and nothing else — no metrics row, no latency
    /// sample: the batch never produced an answer to time).
    fn record_panicked(&self, batch: &Batch, records: &Mutex<Vec<RequestRecord>>) {
        let backend = if self.config.policy == DispatchPolicy::GpuOnly {
            Backend::GpuModel(self.config.gpu.clone()).name()
        } else {
            Backend::IpuSim(self.config.arch.clone()).name()
        };
        let drained_at = Instant::now();
        let first_id = batch.requests.iter().map(|r| r.id).min().unwrap_or(0);
        let n = batch.len().max(1);
        let mut recs = records.lock().unwrap_or_else(|e| e.into_inner());
        for req in &batch.requests {
            crate::obs::count("serve.panicked", 1);
            recs.push(RequestRecord {
                id: req.id,
                shape: req.shape,
                bucket: batch.bucket,
                sparsity: req.sparsity,
                backend: backend.clone(),
                batch_id: first_id,
                batch_size: n,
                cache_hit: None,
                queue_seconds: drained_at
                    .saturating_duration_since(req.submitted)
                    .as_secs_f64(),
                queue_depth: batch.queued_behind,
                plan_seconds: 0.0,
                device_seconds: 0.0,
                real_seconds: None,
                oom: false,
                outcome: RequestOutcome::Panicked,
                attempts: 1,
                retry_seconds: 0.0,
            });
        }
    }

    /// Serve one batch: one plan lookup, one dispatch, one telemetry
    /// record per rider. In fault mode the dispatch verdicts were fixed
    /// by the resolution pre-pass; this emits them (and panics first on
    /// an injected worker panic — the peeled solo batch guarantees the
    /// blast radius is one request).
    fn process_batch(
        &self,
        worker: usize,
        batch: &Batch,
        resolutions: Option<&[Resolution]>,
        records: &Mutex<Vec<RequestRecord>>,
        batch_records: &Mutex<Vec<(u64, MetricsRecord)>>,
        lat: &mut QuantileSketch,
        qwait: &mut QuantileSketch,
    ) {
        if let Some(res) = resolutions {
            return self.process_batch_resolved(worker, batch, res, records, batch_records, lat, qwait);
        }
        let t_batch = crate::obs::now();
        let drained_at = Instant::now();
        let bucket = batch.bucket;
        // batch identity = smallest rider id: unique per batch, and the
        // key the deterministic table/CSV ordering already sorts by
        let first_id = batch.requests.iter().map(|r| r.id).min().unwrap_or(0);
        let (outcome, backend, cache_hit, plan_seconds) =
            self.dispatch(bucket, batch.sparsity);
        // anchor cold dense buckets to the real path; hits, cache-less
        // dispatches and sparse batches (no sparse AOT artifacts) skip it
        let real_seconds = if cache_hit == Some(false) && batch.sparsity.is_none() {
            self.verify_real(bucket)
        } else {
            None
        };

        let n = batch.len().max(1);
        let device_seconds = match &outcome {
            RunOutcome::Ok { seconds, .. } => *seconds,
            RunOutcome::OutOfMemory => 0.0,
        };
        let oom = outcome.is_oom();

        {
            let mut recs = records.lock().unwrap_or_else(|e| e.into_inner());
            for req in &batch.requests {
                let queue_seconds = drained_at
                    .saturating_duration_since(req.submitted)
                    .as_secs_f64();
                let amortized_plan = plan_seconds / n as f64;
                qwait.observe(queue_seconds);
                lat.observe(queue_seconds + amortized_plan + device_seconds);
                recs.push(RequestRecord {
                    id: req.id,
                    shape: req.shape,
                    bucket,
                    sparsity: req.sparsity,
                    backend: backend.clone(),
                    batch_id: first_id,
                    batch_size: n,
                    cache_hit,
                    queue_seconds,
                    queue_depth: batch.queued_behind,
                    plan_seconds: amortized_plan,
                    device_seconds,
                    real_seconds,
                    oom,
                    outcome: RequestOutcome::Served,
                    attempts: 1,
                    retry_seconds: 0.0,
                });
            }
        }
        if t_batch.is_some() {
            crate::obs::wall_span_since(
                t_batch,
                &format!("serve/worker-{worker}"),
                &format!("batch {}", BucketLadder::label(bucket)),
                "serve",
                &[
                    ("riders", n.to_string()),
                    ("batch_id", first_id.to_string()),
                    ("cache_hit", format!("{cache_hit:?}")),
                    ("oom", oom.to_string()),
                ],
            );
        }
        let label = match &batch.sparsity {
            Some(spec) => format!("{} {}", BucketLadder::label(bucket), spec.label()),
            None => BucketLadder::label(bucket),
        };
        batch_records.lock().unwrap_or_else(|e| e.into_inner()).push((
            first_id,
            MetricsRecord { backend, label, shape: bucket, outcome },
        ));
    }

    /// Fault-mode twin of [`Self::process_batch`]: emit the pre-resolved
    /// verdicts for every rider. Request ids are positional (0..n) in
    /// the serve path, so `resolutions[id]` is the rider's resolution.
    #[allow(clippy::too_many_arguments)]
    fn process_batch_resolved(
        &self,
        worker: usize,
        batch: &Batch,
        resolutions: &[Resolution],
        records: &Mutex<Vec<RequestRecord>>,
        batch_records: &Mutex<Vec<(u64, MetricsRecord)>>,
        lat: &mut QuantileSketch,
        qwait: &mut QuantileSketch,
    ) {
        // injected worker panic: unwind before any bookkeeping, so the
        // catch_unwind wrapper sees exactly what a genuine panic does
        if batch.requests.iter().any(|r| self.config.faults.injects_panic(r.id)) {
            panic!("injected worker panic (fault plan)");
        }
        let t_batch = crate::obs::now();
        let drained_at = Instant::now();
        let bucket = batch.bucket;
        let first_id = batch.requests.iter().map(|r| r.id).min().unwrap_or(0);
        let n = batch.len().max(1);
        {
            let mut recs = records.lock().unwrap_or_else(|e| e.into_inner());
            for req in &batch.requests {
                let r = &resolutions[req.id as usize];
                debug_assert_eq!(r.id, req.id, "resolutions must be id-indexed");
                match r.outcome {
                    RequestOutcome::Shed(_) => crate::obs::count("serve.shed", 1),
                    RequestOutcome::Degraded(_) => crate::obs::count("serve.degraded", 1),
                    _ => {}
                }
                let queue_seconds = drained_at
                    .saturating_duration_since(req.submitted)
                    .as_secs_f64();
                qwait.observe(queue_seconds);
                lat.observe(
                    queue_seconds + r.plan_seconds + r.retry_seconds + r.device_seconds,
                );
                recs.push(RequestRecord {
                    id: req.id,
                    shape: req.shape,
                    bucket,
                    sparsity: req.sparsity,
                    backend: r.backend.clone(),
                    batch_id: first_id,
                    batch_size: n,
                    cache_hit: r.cache_hit,
                    queue_seconds,
                    queue_depth: batch.queued_behind,
                    // per-request lookups in fault mode: the cold cost
                    // lands on the request that planned, not amortized
                    plan_seconds: r.plan_seconds,
                    device_seconds: r.device_seconds,
                    real_seconds: None,
                    oom: r.oom,
                    outcome: r.outcome,
                    attempts: r.attempts,
                    retry_seconds: r.retry_seconds,
                });
            }
        }
        let head = &resolutions[first_id as usize];
        if t_batch.is_some() {
            crate::obs::wall_span_since(
                t_batch,
                &format!("serve/worker-{worker}"),
                &format!("batch {}", BucketLadder::label(bucket)),
                "serve",
                &[
                    ("riders", n.to_string()),
                    ("batch_id", first_id.to_string()),
                    ("outcome", head.outcome.label().to_string()),
                    ("attempts", head.attempts.to_string()),
                ],
            );
        }
        let label = match &batch.sparsity {
            Some(spec) => format!("{} {}", BucketLadder::label(bucket), spec.label()),
            None => BucketLadder::label(bucket),
        };
        batch_records.lock().unwrap_or_else(|e| e.into_inner()).push((
            first_id,
            MetricsRecord {
                backend: head.backend.clone(),
                label,
                shape: bucket,
                // a shed head rider ran nothing to completion; the
                // metrics row reports the no-result case as OOM-shaped
                outcome: head.run.clone().unwrap_or(RunOutcome::OutOfMemory),
            },
        ));
    }

    /// Resolve one bucket to an outcome on some backend. The `Option<bool>`
    /// is the cache verdict: `None` when the policy never consulted it.
    /// Sparse buckets plan through the sparsity-keyed cache path; on the
    /// GPU fallback they are priced dense-equivalent (the cuBLAS model
    /// has no block-sparse kernel — conservative for the GPU).
    fn dispatch(
        &self,
        bucket: MmShape,
        sparsity: Option<SparsitySpec>,
    ) -> (RunOutcome, String, Option<bool>, f64) {
        let gpu_backend = || Backend::GpuModel(self.config.gpu.clone());
        if self.config.policy == DispatchPolicy::GpuOnly {
            let out = run_shape(&gpu_backend(), bucket);
            return (out, gpu_backend().name(), None, 0.0);
        }
        let ipu_name = Backend::IpuSim(self.config.arch.clone()).name();
        let (result, hit, plan_seconds) = match sparsity {
            None => {
                let (result, hit, secs) =
                    self.cache.get_or_plan_timed(&self.config.arch, bucket);
                (result.map(|plan| self.outcome_from_plan(&plan)), hit, secs)
            }
            Some(spec) => {
                let (result, hit, secs) =
                    self.cache
                        .get_or_plan_sparse_timed(&self.config.arch, bucket, spec);
                (result.map(|plan| self.outcome_from_sparse_plan(&plan)), hit, secs)
            }
        };
        match result {
            Ok(outcome) => (outcome, ipu_name, Some(hit), plan_seconds),
            Err(_) if self.config.policy == DispatchPolicy::IpuWithGpuFallback => {
                let out = run_shape(&gpu_backend(), bucket);
                (out, gpu_backend().name(), Some(hit), plan_seconds)
            }
            Err(_) => (RunOutcome::OutOfMemory, ipu_name, Some(hit), plan_seconds),
        }
    }

    /// Price a cached plan without re-searching or materializing a graph
    /// — same outcome contract as `coordinator::device::run_shape`.
    fn outcome_from_plan(&self, plan: &Plan) -> RunOutcome {
        RunOutcome::Ok {
            seconds: self.config.arch.cycles_to_secs(plan.cost.total_cycles),
            tflops: plan.tflops(&self.config.arch),
            efficiency: plan.cost.efficiency(),
            vertices: Some(plan.cost.total_vertices()),
            max_tile_bytes: Some(plan.cost.tile_bytes_total),
        }
    }

    /// Sparse twin of [`Self::outcome_from_plan`]. `tflops` reports the
    /// *effective* convention (nonzero work only) — the dense-equivalent
    /// figure is recoverable from `seconds` and the bucket shape.
    fn outcome_from_sparse_plan(&self, plan: &SparsePlan) -> RunOutcome {
        RunOutcome::Ok {
            seconds: plan.seconds(&self.config.arch),
            tflops: plan.effective_tflops(&self.config.arch),
            efficiency: plan.efficiency(),
            // past the dense wall there is no dense baseline census
            vertices: plan.dense_plan.as_ref().map(|d| d.cost.total_vertices()),
            // the CSR-aware bill is the plan's true residency (the dense
            // bill can exceed SRAM for past-the-wall sparse plans)
            max_tile_bytes: Some(plan.cost.sparse_tile_bytes),
        }
    }

    /// Real-path anchor: on cold buckets, execute the bucket shape
    /// through the AOT block artifacts and verify against the oracle.
    /// Compiled out without the `xla` feature; returns `None` when
    /// artifacts are absent or the shape is too large to verify cheaply.
    #[cfg(feature = "xla")]
    fn verify_real(&self, bucket: MmShape) -> Option<f64> {
        const MAX_REAL_FLOPS: u64 = 1 << 28;
        let ex = self.real.as_ref()?;
        if bucket.flops() > MAX_REAL_FLOPS {
            return None;
        }
        let a = crate::util::matrix::Matrix::random(bucket.m, bucket.n, bucket.m as u64);
        let b = crate::util::matrix::Matrix::random(bucket.n, bucket.k, bucket.k as u64);
        let mut ex = ex.lock().unwrap_or_else(|e| e.into_inner());
        ex.mm_verified(&a, &b).ok().map(|(_, stats, _)| stats.seconds)
    }

    #[cfg(not(feature = "xla"))]
    fn verify_real(&self, _bucket: MmShape) -> Option<f64> {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn service(policy: DispatchPolicy) -> MmService {
        MmService::new(ServiceConfig {
            policy,
            workers: Some(4),
            ..ServiceConfig::default()
        })
    }

    fn mixed_trace() -> Vec<MmShape> {
        // two repeated workloads with jitter + one IPU-infeasible shape
        let mut shapes = Vec::new();
        for i in 0..30 {
            shapes.push(MmShape::new(1000 + i % 7, 500 - i % 5, 250));
            shapes.push(MmShape::new(120 + i % 3, 4000 + i % 9, 1000));
        }
        shapes.push(MmShape::square(8000)); // past the §2.4 wall
        shapes
    }

    #[test]
    fn serves_whole_trace_with_high_hit_rate() {
        let svc = service(DispatchPolicy::IpuWithGpuFallback);
        // warm the cache with one representative per bucket, then serve:
        // every steady-state lookup must hit
        let warm = svc.serve_trace(&[
            MmShape::new(1000, 500, 250),
            MmShape::new(120, 4000, 1000),
            MmShape::square(8000),
        ]);
        assert_eq!(warm.cache.misses, 3, "3 distinct buckets -> 3 cold searches");
        let report = svc.serve_trace(&mixed_trace());
        assert_eq!(report.requests.len(), 61);
        assert_eq!(report.cache.misses, 0, "jittered shapes reuse warm buckets");
        assert!(report.cache.hits >= 3, "every batch lookup hits");
        assert!(
            (report.hit_rate() - 1.0).abs() < 1e-12,
            "hit rate {}",
            report.hit_rate()
        );
        assert!(report.batches >= 3);
        assert_eq!(
            report.requests.iter().map(|r| r.id).collect::<Vec<_>>(),
            (0..61u64).collect::<Vec<_>>(),
            "every request answered exactly once, in id order"
        );
    }

    #[test]
    fn oversized_shapes_fall_back_to_gpu() {
        let svc = service(DispatchPolicy::IpuWithGpuFallback);
        let report = svc.serve_trace(&[MmShape::square(8000)]);
        let r = &report.requests[0];
        assert!(r.backend.contains("gpu-model"), "{}", r.backend);
        assert!(!r.oom, "GPU model fits what the IPU cannot");
    }

    #[test]
    fn ipu_only_reports_oom_instead_of_falling_back() {
        let svc = service(DispatchPolicy::IpuOnly);
        let report = svc.serve_trace(&[MmShape::square(8000)]);
        assert!(report.requests[0].oom);
        assert!(report.requests[0].backend.contains("ipu-sim"));
    }

    #[test]
    fn gpu_only_never_touches_the_plan_cache() {
        let svc = service(DispatchPolicy::GpuOnly);
        let report = svc.serve_trace(&[MmShape::square(512); 8]);
        assert_eq!(report.cache.hits + report.cache.misses, 0);
        assert!(report.requests.iter().all(|r| r.backend.contains("gpu-model")));
        assert!(
            report.requests.iter().all(|r| r.cache_hit.is_none()),
            "cache-less dispatch must not masquerade as misses"
        );
        assert_eq!(report.hit_rate(), 0.0, "no lookups -> rate is 0, not skewed");
    }

    #[test]
    fn cache_survives_across_traces() {
        let svc = service(DispatchPolicy::IpuWithGpuFallback);
        let shape = MmShape::square(768);
        let first = svc.serve_trace(&[shape]);
        assert_eq!((first.cache.hits, first.cache.misses), (0, 1));
        let second = svc.serve_trace(&[shape]);
        // per-run stats: the second trace does no cold planning at all
        assert_eq!((second.cache.hits, second.cache.misses), (1, 0));
        assert_eq!(second.cache.entries, 1, "entries stay absolute");
        assert_eq!(second.requests[0].cache_hit, Some(true));
    }

    #[test]
    fn report_shard_stats_sum_to_global_delta() {
        let svc = service(DispatchPolicy::IpuWithGpuFallback);
        let report = svc.serve_trace(&mixed_trace());
        assert_eq!(report.cache_shards.len(), svc.cache().shards());
        let sum = |f: fn(&crate::serve::cache::CacheStats) -> u64| {
            report.cache_shards.iter().map(f).sum::<u64>()
        };
        assert_eq!(sum(|s| s.hits), report.cache.hits);
        assert_eq!(sum(|s| s.misses), report.cache.misses);
        assert_eq!(sum(|s| s.evictions), report.cache.evictions);
        assert_eq!(
            report.cache_shards.iter().map(|s| s.entries).sum::<usize>(),
            report.cache.entries
        );
        // batch ids in the live path are the min rider id per batch:
        // distinct ids must agree with the batch records emitted
        let ids: std::collections::BTreeSet<u64> =
            report.requests.iter().map(|r| r.batch_id).collect();
        assert_eq!(ids.len(), report.batches);
    }

    #[test]
    fn batch_metrics_are_bucket_labelled() {
        let svc = service(DispatchPolicy::IpuWithGpuFallback);
        let report = svc.serve_trace(&[MmShape::new(1000, 500, 250); 4]);
        assert!(!report.metrics.is_empty());
        for rec in &report.metrics.records {
            assert_eq!(rec.label, "1024x512x256");
            assert_eq!(rec.shape, MmShape::new(1024, 512, 256));
        }
    }

    #[test]
    fn cached_outcome_matches_run_shape_pricing() {
        // the plan-cost fast path must agree with the coordinator's
        // full sim on the throughput it reports
        let svc = service(DispatchPolicy::IpuWithGpuFallback);
        let bucket = MmShape::square(1024);
        let (outcome, _, _, _) = svc.dispatch(bucket, None);
        let direct = run_shape(&Backend::IpuSim(IpuArch::gc200()), bucket);
        let (a, b) = (outcome.tflops().unwrap(), direct.tflops().unwrap());
        assert!((a - b).abs() < 1e-9, "serve {a} vs coordinator {b}");
    }

    #[test]
    fn mixed_trace_keeps_distinct_entries_per_sparsity_fingerprint() {
        use crate::sparse::pattern::{PatternKind, SparsitySpec};
        let svc = service(DispatchPolicy::IpuWithGpuFallback);
        let shape = MmShape::square(1024);
        let half = SparsitySpec::new(PatternKind::Random, 8, 0.5, 1);
        let tenth = SparsitySpec::new(PatternKind::Banded, 8, 0.1, 1);
        // warm each key once (distinct keys -> no same-key cold races)
        let warm = svc.serve_trace_mixed(&[
            (shape, None),
            (shape, Some(half)),
            (shape, Some(tenth)),
        ]);
        assert_eq!(warm.cache.misses, 3, "dense + two sparse fingerprints");
        let mut trace: Vec<(MmShape, Option<SparsitySpec>)> = Vec::new();
        for _ in 0..6 {
            trace.push((shape, None));
            trace.push((shape, Some(half)));
            trace.push((shape, Some(tenth)));
        }
        let report = svc.serve_trace_mixed(&trace);
        assert_eq!(report.requests.len(), 18);
        // steady state: every lookup hits its own fingerprint's entry
        assert_eq!(report.cache.misses, 0, "warm keys never re-plan");
        assert_eq!(svc.cache().len(), 3, "entries stay distinct");
        // every request is answered and carries its own sparsity tag
        for r in &report.requests {
            let expected = match r.id % 3 {
                0 => None,
                1 => Some(half),
                _ => Some(tenth),
            };
            assert_eq!(r.sparsity, expected, "request {}", r.id);
            assert!(!r.oom);
        }
        // sparse batches are labelled with the spec in the metrics table
        assert!(report
            .metrics
            .records
            .iter()
            .any(|m| m.label.contains("random/b8/d0.50")));
    }

    #[test]
    fn sparse_outcome_reports_effective_throughput() {
        use crate::sparse::pattern::{PatternKind, SparsitySpec};
        let svc = service(DispatchPolicy::IpuWithGpuFallback);
        let bucket = MmShape::square(1024);
        let spec = SparsitySpec::new(PatternKind::Random, 8, 0.25, 1);
        let (sparse, _, _, _) = svc.dispatch(bucket, Some(spec));
        let (dense, _, _, _) = svc.dispatch(bucket, None);
        let (s, d) = (sparse.tflops().unwrap(), dense.tflops().unwrap());
        // effective throughput on a quarter-dense pattern sits well below
        // the dense figure even though the sparse run finishes sooner
        assert!(s < d, "effective {s} vs dense {d}");
        match (sparse, dense) {
            (
                RunOutcome::Ok { seconds: ss, .. },
                RunOutcome::Ok { seconds: ds, .. },
            ) => assert!(ss < ds, "sparse {ss}s should beat dense {ds}s"),
            _ => panic!("both dispatches must succeed"),
        }
    }

    #[test]
    fn report_latency_sketch_covers_every_request() {
        let svc = service(DispatchPolicy::IpuWithGpuFallback);
        let report = svc.serve_trace(&mixed_trace());
        assert_eq!(report.latency_sketch.count(), report.requests.len() as u64);
        // the merged worker sketches hold the same value multiset as the
        // request records, so every bucket count — and hence every
        // quantile — matches a directly-built sketch (sums can differ in
        // the last bits across merge orders, so compare quantiles)
        let mut direct = QuantileSketch::new();
        for r in &report.requests {
            direct.observe(r.latency_seconds());
        }
        assert_eq!(report.latency_sketch.min(), direct.min());
        assert_eq!(report.latency_sketch.max(), direct.max());
        for q in [0.0, 0.25, 0.5, 0.95, 0.99, 1.0] {
            assert_eq!(report.latency_sketch.quantile(q), direct.quantile(q), "q={q}");
        }
    }

    #[test]
    fn backends_reflect_policy() {
        assert_eq!(service(DispatchPolicy::IpuOnly).backends().len(), 1);
        assert_eq!(service(DispatchPolicy::GpuOnly).backends().len(), 1);
        assert_eq!(
            service(DispatchPolicy::IpuWithGpuFallback).backends().len(),
            2
        );
    }

    // ---- fault layer -------------------------------------------------

    use crate::fault::{
        BreakerState, DegradeReason, FaultProfile, RetryPolicy, ShedReason,
    };

    fn fault_service(profile: FaultProfile, seed: u64, policy: FaultPolicy) -> MmService {
        MmService::new(ServiceConfig {
            workers: Some(4),
            faults: FaultPlan::seeded(seed, profile),
            fault_policy: policy,
            ..ServiceConfig::default()
        })
    }

    #[test]
    fn transient_faults_lose_no_requests_and_account_exactly() {
        let svc = fault_service(
            FaultProfile::transient(100),
            42,
            FaultPolicy::standard(),
        );
        let shapes = vec![MmShape::new(1000, 500, 250); 60];
        let report = svc.serve_trace(&shapes);
        assert_eq!(report.requests.len(), 60, "zero lost");
        let f = report.fault_stats();
        assert_eq!(
            f.served + f.degraded + f.shed + f.panicked,
            60,
            "every request resolves to exactly one outcome"
        );
        assert_eq!(f.shed, 0, "no deadline -> nothing sheds");
        assert_eq!(f.panicked, 0, "profile injects no panics");
        // self-consistency: the plan's own draws predict the injection
        // count for first attempts at least
        let any_injected = (0..60u64).any(|id| {
            svc.config().faults.inject(id, crate::fault::BackendKind::Ipu, 0).is_some()
        });
        assert_eq!(any_injected, report.injected_faults > 0);
        // retried requests pay retry latency; served ones carry a run
        for r in &report.requests {
            match r.outcome {
                RequestOutcome::Served | RequestOutcome::Degraded(_) => {
                    assert!(r.device_seconds > 0.0, "request {} has an answer", r.id)
                }
                other => panic!("unexpected outcome {other:?} for request {}", r.id),
            }
            if r.attempts > 1 {
                assert!(r.retry_seconds > 0.0, "request {} retried for free", r.id);
            }
        }
    }

    #[test]
    fn breaker_trip_profile_degrades_exactly_the_cooldown_window() {
        let svc = fault_service(
            FaultProfile::by_name("breaker-trip").unwrap(),
            7,
            FaultPolicy::standard(),
        );
        let shapes = vec![MmShape::square(512); 100];
        let report = svc.serve_trace(&shapes);
        assert_eq!(report.requests.len(), 100);
        let degraded: Vec<u64> = report
            .requests
            .iter()
            .filter(|r| r.outcome.is_degraded())
            .map(|r| r.id)
            .collect();
        // outage [40,60): id 40's own retries trip the breaker at tick
        // 40; ids 40..=64 ride the cooldown to the GPU; the id-65
        // half-open probe succeeds and re-closes — exactly 25 degraded,
        // deterministically, whatever the seed
        assert_eq!(degraded, (40..=64).collect::<Vec<u64>>());
        for r in &report.requests {
            if r.outcome.is_degraded() {
                assert!(r.backend.contains("gpu-model"), "request {}", r.id);
                assert_eq!(r.outcome, RequestOutcome::Degraded(DegradeReason::BreakerOpen));
            } else {
                assert_eq!(r.outcome, RequestOutcome::Served);
                assert!(r.backend.contains("ipu-sim"), "request {}", r.id);
            }
        }
        let kinds: Vec<(BreakerState, BreakerState)> = report
            .breaker_transitions
            .iter()
            .map(|t| (t.from, t.to))
            .collect();
        assert_eq!(
            kinds,
            vec![
                (BreakerState::Closed, BreakerState::Open),
                (BreakerState::Open, BreakerState::HalfOpen),
                (BreakerState::HalfOpen, BreakerState::Closed),
            ]
        );
        assert_eq!(report.breaker_transitions[0].tick, 40);
        assert_eq!(report.breaker_transitions[1].tick, 65);
        assert!(report.breaker_transitions[0].backend.contains("ipu-sim"));
    }

    #[test]
    fn always_failing_ipu_degrades_everything_to_gpu() {
        let svc = fault_service(
            FaultProfile::transient(1000),
            3,
            FaultPolicy::standard(),
        );
        let report = svc.serve_trace(&[MmShape::square(512); 20]);
        let f = report.fault_stats();
        assert_eq!(f.degraded, 20, "no IPU attempt can ever succeed");
        assert!(report.requests.iter().all(|r| r.backend.contains("gpu-model")));
        // request 0 exhausts its own breaker: 3 IPU attempts + 1 GPU
        assert_eq!(report.requests[0].attempts, 4);
        assert_eq!(report.requests[0].outcome, RequestOutcome::Degraded(DegradeReason::BreakerOpen));
    }

    #[test]
    fn slow_spikes_past_the_deadline_shed_with_a_distinct_outcome() {
        let svc = fault_service(
            FaultProfile::slow(1000, 1e6),
            5,
            FaultPolicy::standard().with_deadline(1e-6),
        );
        let report = svc.serve_trace(&[MmShape::square(512); 12]);
        assert_eq!(report.requests.len(), 12, "shed requests still get records");
        for r in &report.requests {
            assert_eq!(
                r.outcome,
                RequestOutcome::Shed(ShedReason::DeadlineExceeded),
                "request {}",
                r.id
            );
            assert_eq!(r.device_seconds, 0.0, "nothing ran to completion");
            assert!(!r.oom, "shedding is not an OOM verdict");
        }
        assert_eq!(report.fault_stats().shed, 12);
    }

    #[test]
    fn injected_panics_take_out_only_their_own_request() {
        let profile = FaultProfile { panic_permille: 300, ..FaultProfile::none() };
        let svc = fault_service(profile, 9, FaultPolicy::standard());
        let n = 40u64;
        let doomed: Vec<u64> =
            (0..n).filter(|&id| svc.config().faults.injects_panic(id)).collect();
        assert!(!doomed.is_empty(), "300 permille must hit some of 40 ids");
        assert!((doomed.len() as u64) < n, "and must miss some");
        let report = svc.serve_trace(&vec![MmShape::square(512); n as usize]);
        assert_eq!(report.requests.len(), n as usize, "panic loses no records");
        for r in &report.requests {
            if doomed.contains(&r.id) {
                assert_eq!(r.outcome, RequestOutcome::Panicked, "request {}", r.id);
                assert_eq!(r.device_seconds, 0.0);
            } else {
                assert_eq!(r.outcome, RequestOutcome::Served, "request {}", r.id);
                assert!(r.device_seconds > 0.0);
                assert!(!r.oom);
            }
        }
        assert_eq!(report.fault_stats().panicked, doomed.len());
        // the service survives: a fresh trace on the same instance works
        // (panicked workers recovered, locks unpoisoned or recovered)
        let again = svc.serve_trace(&[MmShape::square(512); 4]);
        assert_eq!(again.requests.len(), 4);
    }

    #[test]
    fn retry_and_deadline_flags_without_faults_change_no_verdicts() {
        // an active policy with the identity fault plan routes through
        // the resolver, but every verdict must match the legacy path
        let faulty = MmService::new(ServiceConfig {
            workers: Some(2),
            faults: FaultPlan::none(),
            fault_policy: FaultPolicy {
                deadline_s: Some(60.0),
                retry: RetryPolicy::standard(3),
                breaker: crate::fault::BreakerConfig::standard(),
            },
            ..ServiceConfig::default()
        });
        let legacy = service(DispatchPolicy::IpuWithGpuFallback);
        let shapes = mixed_trace();
        let a = faulty.serve_trace(&shapes);
        let b = legacy.serve_trace(&shapes);
        assert_eq!(a.requests.len(), b.requests.len());
        for (x, y) in a.requests.iter().zip(&b.requests) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.bucket, y.bucket);
            assert_eq!(x.backend, y.backend);
            assert_eq!(x.oom, y.oom);
            assert_eq!(
                x.device_seconds.to_bits(),
                y.device_seconds.to_bits(),
                "request {} device bits drifted",
                x.id
            );
            assert_eq!(x.outcome, RequestOutcome::Served);
            assert_eq!(x.attempts, 1);
        }
        assert!(a.breaker_transitions.is_empty());
        assert_eq!(a.injected_faults, 0);
    }
}
