//! The serving front door: bucket, enqueue, coalesce, plan-from-cache,
//! dispatch across backends.
//!
//! A worker pool (sized by the same policy as `coordinator::runner`, see
//! [`default_workers`]) drains the bounded request queue. Each coalesced
//! batch costs **one** plan-cache lookup; the search result decides the
//! dispatch:
//!
//! * plan found — the request is priced on the IPU simulator directly
//!   from the cached plan (no re-search, no graph rebuild: the plan cost
//!   already carries cycles, efficiency, vertex census and peak tile
//!   bytes — execution uses the same outcome contract as
//!   `coordinator::device::run_shape`);
//! * out of memory (the paper's §2.4 wall) — the batch falls back to the
//!   GPU model (policy permitting), mirroring how a heterogeneous fleet
//!   sheds IPU-infeasible shapes;
//! * with the `xla` feature and AOT artifacts present, miss batches are
//!   additionally executed for real through `runtime::blockmm` and
//!   verified against the oracle, so the serving path stays anchored to
//!   actually-performed multiplications.

use std::sync::Mutex;
use std::time::Instant;

use crate::arch::{GpuArch, IpuArch};
use crate::obs::sketch::QuantileSketch;
use crate::coordinator::device::{run_shape, Backend, RunOutcome};
use crate::coordinator::metrics::{MetricsRecord, MetricsTable};
use crate::coordinator::runner::default_workers;
use crate::planner::partition::MmShape;
use crate::planner::search::Plan;
use crate::serve::bucket::BucketLadder;
use crate::serve::cache::PlanCache;
use crate::serve::queue::{Batch, MmRequest, RequestQueue};
use crate::serve::telemetry::{RequestRecord, ServeReport};
use crate::sparse::pattern::SparsitySpec;
use crate::sparse::planner::SparsePlan;

/// How batches spread over the configured backends.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DispatchPolicy {
    /// IPU simulator first; shapes past the IPU memory wall go to the
    /// GPU model (default).
    IpuWithGpuFallback,
    /// IPU only; infeasible shapes are reported OOM.
    IpuOnly,
    /// GPU model only (baseline / ablation).
    GpuOnly,
}

/// Serving-layer configuration.
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    pub arch: IpuArch,
    pub gpu: GpuArch,
    pub ladder: BucketLadder,
    pub policy: DispatchPolicy,
    /// Plan-cache entries (shape x arch keys).
    pub cache_capacity: usize,
    /// Bounded queue depth (admission control beyond it).
    pub queue_capacity: usize,
    /// Max requests coalesced into one batch.
    pub max_batch: usize,
    /// Worker threads; `None` uses the shared
    /// `coordinator::runner::default_workers` policy.
    pub workers: Option<usize>,
    /// AOT artifact directory for the real PJRT path (used only when the
    /// `xla` feature is enabled and the directory holds a manifest).
    pub artifacts: Option<std::path::PathBuf>,
}

impl Default for ServiceConfig {
    fn default() -> ServiceConfig {
        ServiceConfig {
            arch: IpuArch::gc200(),
            gpu: GpuArch::a30(),
            ladder: BucketLadder::default(),
            policy: DispatchPolicy::IpuWithGpuFallback,
            cache_capacity: 256,
            queue_capacity: 1024,
            max_batch: 32,
            workers: None,
            artifacts: None,
        }
    }
}

/// Matmul-as-a-service: owns the plan cache and the dispatch policy.
pub struct MmService {
    config: ServiceConfig,
    cache: PlanCache,
    #[cfg(feature = "xla")]
    real: Option<Mutex<crate::runtime::blockmm::BlockMmExecutor>>,
}

impl MmService {
    pub fn new(config: ServiceConfig) -> MmService {
        #[cfg(feature = "xla")]
        let (config, real) = {
            let mut config = config;
            let real = config
                .artifacts
                .as_deref()
                .and_then(|dir| crate::runtime::blockmm::BlockMmExecutor::load(dir, 256).ok());
            if let Some(ex) = &real {
                // align the ladder to the loaded block artifact so the
                // real path pads no extra flops on bucketed shapes
                let top = *config.ladder.rungs().last().expect("non-empty ladder");
                config.ladder = BucketLadder::block_aligned(ex.block, top);
            }
            (config, real.map(Mutex::new))
        };
        MmService {
            cache: PlanCache::new(config.cache_capacity),
            config,
            #[cfg(feature = "xla")]
            real,
        }
    }

    pub fn config(&self) -> &ServiceConfig {
        &self.config
    }

    /// The long-lived plan cache (persists across traces — a warm
    /// service keeps its plans).
    pub fn cache(&self) -> &PlanCache {
        &self.cache
    }

    /// Backend names this service can dispatch to, coordinator naming.
    pub fn backends(&self) -> Vec<String> {
        let mut out = Vec::new();
        if self.config.policy != DispatchPolicy::GpuOnly {
            out.push(Backend::IpuSim(self.config.arch.clone()).name());
        }
        if self.config.policy != DispatchPolicy::IpuOnly {
            out.push(Backend::GpuModel(self.config.gpu.clone()).name());
        }
        #[cfg(feature = "xla")]
        if self.real.is_some() {
            out.push("pjrt-real/cpu".to_string());
        }
        out
    }

    /// Serve a request trace to completion: submit every shape through
    /// the bounded queue (blocking backpressure) while a worker pool
    /// drains coalesced batches. Returns per-request and per-bucket
    /// telemetry.
    pub fn serve_trace(&self, shapes: &[MmShape]) -> ServeReport {
        let dense: Vec<(MmShape, Option<SparsitySpec>)> =
            shapes.iter().map(|&s| (s, None)).collect();
        self.serve_trace_mixed(&dense)
    }

    /// [`Self::serve_trace`] for a mixed dense/sparse trace: each request
    /// optionally carries a block-sparsity descriptor. Sparse requests
    /// bucket like dense ones but coalesce and cache per sparsity
    /// fingerprint (see `serve::cache`).
    pub fn serve_trace_mixed(&self, reqs: &[(MmShape, Option<SparsitySpec>)]) -> ServeReport {
        let queue = RequestQueue::new(self.config.queue_capacity);
        // the configured count is a request against the process-wide
        // thread budget: a service embedded in a sweep (or several
        // services in one process) cannot oversubscribe the machine, and
        // nested cold-miss planner searches draw from the same pool
        let lease = crate::coordinator::runner::ThreadBudget::global().acquire(
            self.config
                .workers
                .unwrap_or_else(default_workers)
                .max(1),
        );
        let workers = lease.workers();
        let records: Mutex<Vec<RequestRecord>> = Mutex::new(Vec::with_capacity(reqs.len()));
        // keyed by earliest rider id so the emitted table/CSV row order is
        // deterministic regardless of worker scheduling (run_jobs makes
        // the same guarantee via submission order)
        let batch_records: Mutex<Vec<(u64, MetricsRecord)>> = Mutex::new(Vec::new());
        // each worker folds latencies into a local sketch (no shared
        // lock on the per-sample path); merged in worker order below so
        // the report sketch is deterministic for a given rider->worker
        // assignment
        let worker_sketches: Mutex<Vec<(usize, QuantileSketch)>> = Mutex::new(Vec::new());
        let cache_baseline = self.cache.stats();
        let shard_baseline = self.cache.shard_stats();

        // A worker that unwinds must close the queue on its way out:
        // otherwise a blocked producer waits forever on a condvar nobody
        // will signal and the panic never propagates out of the scope.
        struct CloseOnDrop<'a>(&'a RequestQueue);
        impl Drop for CloseOnDrop<'_> {
            fn drop(&mut self) {
                self.0.close();
            }
        }

        let t_trace = crate::obs::now();
        let t0 = Instant::now();
        std::thread::scope(|scope| {
            for w in 0..workers {
                let queue = &queue;
                let records = &records;
                let batch_records = &batch_records;
                let worker_sketches = &worker_sketches;
                scope.spawn(move || {
                    let _guard = CloseOnDrop(queue);
                    let mut lat = QuantileSketch::new();
                    let mut qwait = QuantileSketch::new();
                    while let Some(batch) = queue.next_batch(self.config.max_batch) {
                        self.process_batch(w, batch, records, batch_records, &mut lat, &mut qwait);
                    }
                    // one global-recorder merge per worker, not per sample
                    crate::obs::merge_sketch("serve.latency_seconds", &lat);
                    crate::obs::merge_sketch("serve.queue_seconds", &qwait);
                    worker_sketches.lock().expect("sketches poisoned").push((w, lat));
                });
            }
            for (i, &(shape, sparsity)) in reqs.iter().enumerate() {
                let bucket = self.config.ladder.bucket(shape);
                let mut req = MmRequest::new(i as u64, shape, bucket);
                if let Some(spec) = sparsity {
                    req = req.with_sparsity(spec);
                }
                if queue.submit_blocking(req).is_err() {
                    // queue closed early: a worker died; stop producing
                    // and let scope join propagate its panic
                    break;
                }
            }
            queue.close();
        });
        let wall_seconds = t0.elapsed().as_secs_f64();
        if t_trace.is_some() {
            crate::obs::wall_span_since(
                t_trace,
                "serve",
                &format!("serve_trace ({} requests)", reqs.len()),
                "serve",
                &[("workers", workers.to_string())],
            );
        }

        let mut requests = records.into_inner().expect("records poisoned");
        requests.sort_by_key(|r| r.id);
        let mut batch_recs = batch_records.into_inner().expect("metrics poisoned");
        batch_recs.sort_by_key(|(first_id, _)| *first_id);
        let mut metrics = MetricsTable::default();
        for (_, rec) in batch_recs {
            metrics.push(rec);
        }
        let mut shards = worker_sketches.into_inner().expect("sketches poisoned");
        shards.sort_by_key(|(w, _)| *w);
        let mut latency_sketch = QuantileSketch::new();
        for (_, s) in &shards {
            latency_sketch.merge(s);
        }
        ServeReport {
            batches: metrics.len(),
            latency_sketch,
            // per-run delta: a warm service's lifetime counters would
            // otherwise masquerade as this trace's behavior
            cache: self.cache.stats().since(&cache_baseline),
            cache_shards: self
                .cache
                .shard_stats()
                .iter()
                .zip(&shard_baseline)
                .map(|(now, base)| now.since(base))
                .collect(),
            queue: queue.stats(),
            requests,
            metrics,
            wall_seconds,
        }
    }

    /// Serve one batch: one plan lookup, one dispatch, one telemetry
    /// record per rider.
    fn process_batch(
        &self,
        worker: usize,
        batch: Batch,
        records: &Mutex<Vec<RequestRecord>>,
        batch_records: &Mutex<Vec<(u64, MetricsRecord)>>,
        lat: &mut QuantileSketch,
        qwait: &mut QuantileSketch,
    ) {
        let t_batch = crate::obs::now();
        let drained_at = Instant::now();
        let bucket = batch.bucket;
        // batch identity = smallest rider id: unique per batch, and the
        // key the deterministic table/CSV ordering already sorts by
        let first_id = batch.requests.iter().map(|r| r.id).min().unwrap_or(0);
        let (outcome, backend, cache_hit, plan_seconds) =
            self.dispatch(bucket, batch.sparsity);
        // anchor cold dense buckets to the real path; hits, cache-less
        // dispatches and sparse batches (no sparse AOT artifacts) skip it
        let real_seconds = if cache_hit == Some(false) && batch.sparsity.is_none() {
            self.verify_real(bucket)
        } else {
            None
        };

        let n = batch.len().max(1);
        let device_seconds = match &outcome {
            RunOutcome::Ok { seconds, .. } => *seconds,
            RunOutcome::OutOfMemory => 0.0,
        };
        let oom = outcome.is_oom();

        {
            let mut recs = records.lock().expect("records poisoned");
            for req in &batch.requests {
                let queue_seconds = drained_at
                    .saturating_duration_since(req.submitted)
                    .as_secs_f64();
                let amortized_plan = plan_seconds / n as f64;
                qwait.observe(queue_seconds);
                lat.observe(queue_seconds + amortized_plan + device_seconds);
                recs.push(RequestRecord {
                    id: req.id,
                    shape: req.shape,
                    bucket,
                    sparsity: req.sparsity,
                    backend: backend.clone(),
                    batch_id: first_id,
                    batch_size: n,
                    cache_hit,
                    queue_seconds,
                    queue_depth: batch.queued_behind,
                    plan_seconds: amortized_plan,
                    device_seconds,
                    real_seconds,
                    oom,
                });
            }
        }
        if t_batch.is_some() {
            crate::obs::wall_span_since(
                t_batch,
                &format!("serve/worker-{worker}"),
                &format!("batch {}", BucketLadder::label(bucket)),
                "serve",
                &[
                    ("riders", n.to_string()),
                    ("batch_id", first_id.to_string()),
                    ("cache_hit", format!("{cache_hit:?}")),
                    ("oom", oom.to_string()),
                ],
            );
        }
        let label = match &batch.sparsity {
            Some(spec) => format!("{} {}", BucketLadder::label(bucket), spec.label()),
            None => BucketLadder::label(bucket),
        };
        batch_records.lock().expect("metrics poisoned").push((
            first_id,
            MetricsRecord { backend, label, shape: bucket, outcome },
        ));
    }

    /// Resolve one bucket to an outcome on some backend. The `Option<bool>`
    /// is the cache verdict: `None` when the policy never consulted it.
    /// Sparse buckets plan through the sparsity-keyed cache path; on the
    /// GPU fallback they are priced dense-equivalent (the cuBLAS model
    /// has no block-sparse kernel — conservative for the GPU).
    fn dispatch(
        &self,
        bucket: MmShape,
        sparsity: Option<SparsitySpec>,
    ) -> (RunOutcome, String, Option<bool>, f64) {
        let gpu_backend = || Backend::GpuModel(self.config.gpu.clone());
        if self.config.policy == DispatchPolicy::GpuOnly {
            let out = run_shape(&gpu_backend(), bucket);
            return (out, gpu_backend().name(), None, 0.0);
        }
        let ipu_name = Backend::IpuSim(self.config.arch.clone()).name();
        let (result, hit, plan_seconds) = match sparsity {
            None => {
                let (result, hit, secs) =
                    self.cache.get_or_plan_timed(&self.config.arch, bucket);
                (result.map(|plan| self.outcome_from_plan(&plan)), hit, secs)
            }
            Some(spec) => {
                let (result, hit, secs) =
                    self.cache
                        .get_or_plan_sparse_timed(&self.config.arch, bucket, spec);
                (result.map(|plan| self.outcome_from_sparse_plan(&plan)), hit, secs)
            }
        };
        match result {
            Ok(outcome) => (outcome, ipu_name, Some(hit), plan_seconds),
            Err(_) if self.config.policy == DispatchPolicy::IpuWithGpuFallback => {
                let out = run_shape(&gpu_backend(), bucket);
                (out, gpu_backend().name(), Some(hit), plan_seconds)
            }
            Err(_) => (RunOutcome::OutOfMemory, ipu_name, Some(hit), plan_seconds),
        }
    }

    /// Price a cached plan without re-searching or materializing a graph
    /// — same outcome contract as `coordinator::device::run_shape`.
    fn outcome_from_plan(&self, plan: &Plan) -> RunOutcome {
        RunOutcome::Ok {
            seconds: self.config.arch.cycles_to_secs(plan.cost.total_cycles),
            tflops: plan.tflops(&self.config.arch),
            efficiency: plan.cost.efficiency(),
            vertices: Some(plan.cost.total_vertices()),
            max_tile_bytes: Some(plan.cost.tile_bytes_total),
        }
    }

    /// Sparse twin of [`Self::outcome_from_plan`]. `tflops` reports the
    /// *effective* convention (nonzero work only) — the dense-equivalent
    /// figure is recoverable from `seconds` and the bucket shape.
    fn outcome_from_sparse_plan(&self, plan: &SparsePlan) -> RunOutcome {
        RunOutcome::Ok {
            seconds: plan.seconds(&self.config.arch),
            tflops: plan.effective_tflops(&self.config.arch),
            efficiency: plan.efficiency(),
            // past the dense wall there is no dense baseline census
            vertices: plan.dense_plan.as_ref().map(|d| d.cost.total_vertices()),
            // the CSR-aware bill is the plan's true residency (the dense
            // bill can exceed SRAM for past-the-wall sparse plans)
            max_tile_bytes: Some(plan.cost.sparse_tile_bytes),
        }
    }

    /// Real-path anchor: on cold buckets, execute the bucket shape
    /// through the AOT block artifacts and verify against the oracle.
    /// Compiled out without the `xla` feature; returns `None` when
    /// artifacts are absent or the shape is too large to verify cheaply.
    #[cfg(feature = "xla")]
    fn verify_real(&self, bucket: MmShape) -> Option<f64> {
        const MAX_REAL_FLOPS: u64 = 1 << 28;
        let ex = self.real.as_ref()?;
        if bucket.flops() > MAX_REAL_FLOPS {
            return None;
        }
        let a = crate::util::matrix::Matrix::random(bucket.m, bucket.n, bucket.m as u64);
        let b = crate::util::matrix::Matrix::random(bucket.n, bucket.k, bucket.k as u64);
        let mut ex = ex.lock().expect("real executor poisoned");
        ex.mm_verified(&a, &b).ok().map(|(_, stats, _)| stats.seconds)
    }

    #[cfg(not(feature = "xla"))]
    fn verify_real(&self, _bucket: MmShape) -> Option<f64> {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn service(policy: DispatchPolicy) -> MmService {
        MmService::new(ServiceConfig {
            policy,
            workers: Some(4),
            ..ServiceConfig::default()
        })
    }

    fn mixed_trace() -> Vec<MmShape> {
        // two repeated workloads with jitter + one IPU-infeasible shape
        let mut shapes = Vec::new();
        for i in 0..30 {
            shapes.push(MmShape::new(1000 + i % 7, 500 - i % 5, 250));
            shapes.push(MmShape::new(120 + i % 3, 4000 + i % 9, 1000));
        }
        shapes.push(MmShape::square(8000)); // past the §2.4 wall
        shapes
    }

    #[test]
    fn serves_whole_trace_with_high_hit_rate() {
        let svc = service(DispatchPolicy::IpuWithGpuFallback);
        // warm the cache with one representative per bucket, then serve:
        // every steady-state lookup must hit
        let warm = svc.serve_trace(&[
            MmShape::new(1000, 500, 250),
            MmShape::new(120, 4000, 1000),
            MmShape::square(8000),
        ]);
        assert_eq!(warm.cache.misses, 3, "3 distinct buckets -> 3 cold searches");
        let report = svc.serve_trace(&mixed_trace());
        assert_eq!(report.requests.len(), 61);
        assert_eq!(report.cache.misses, 0, "jittered shapes reuse warm buckets");
        assert!(report.cache.hits >= 3, "every batch lookup hits");
        assert!(
            (report.hit_rate() - 1.0).abs() < 1e-12,
            "hit rate {}",
            report.hit_rate()
        );
        assert!(report.batches >= 3);
        assert_eq!(
            report.requests.iter().map(|r| r.id).collect::<Vec<_>>(),
            (0..61u64).collect::<Vec<_>>(),
            "every request answered exactly once, in id order"
        );
    }

    #[test]
    fn oversized_shapes_fall_back_to_gpu() {
        let svc = service(DispatchPolicy::IpuWithGpuFallback);
        let report = svc.serve_trace(&[MmShape::square(8000)]);
        let r = &report.requests[0];
        assert!(r.backend.contains("gpu-model"), "{}", r.backend);
        assert!(!r.oom, "GPU model fits what the IPU cannot");
    }

    #[test]
    fn ipu_only_reports_oom_instead_of_falling_back() {
        let svc = service(DispatchPolicy::IpuOnly);
        let report = svc.serve_trace(&[MmShape::square(8000)]);
        assert!(report.requests[0].oom);
        assert!(report.requests[0].backend.contains("ipu-sim"));
    }

    #[test]
    fn gpu_only_never_touches_the_plan_cache() {
        let svc = service(DispatchPolicy::GpuOnly);
        let report = svc.serve_trace(&[MmShape::square(512); 8]);
        assert_eq!(report.cache.hits + report.cache.misses, 0);
        assert!(report.requests.iter().all(|r| r.backend.contains("gpu-model")));
        assert!(
            report.requests.iter().all(|r| r.cache_hit.is_none()),
            "cache-less dispatch must not masquerade as misses"
        );
        assert_eq!(report.hit_rate(), 0.0, "no lookups -> rate is 0, not skewed");
    }

    #[test]
    fn cache_survives_across_traces() {
        let svc = service(DispatchPolicy::IpuWithGpuFallback);
        let shape = MmShape::square(768);
        let first = svc.serve_trace(&[shape]);
        assert_eq!((first.cache.hits, first.cache.misses), (0, 1));
        let second = svc.serve_trace(&[shape]);
        // per-run stats: the second trace does no cold planning at all
        assert_eq!((second.cache.hits, second.cache.misses), (1, 0));
        assert_eq!(second.cache.entries, 1, "entries stay absolute");
        assert_eq!(second.requests[0].cache_hit, Some(true));
    }

    #[test]
    fn report_shard_stats_sum_to_global_delta() {
        let svc = service(DispatchPolicy::IpuWithGpuFallback);
        let report = svc.serve_trace(&mixed_trace());
        assert_eq!(report.cache_shards.len(), svc.cache().shards());
        let sum = |f: fn(&crate::serve::cache::CacheStats) -> u64| {
            report.cache_shards.iter().map(f).sum::<u64>()
        };
        assert_eq!(sum(|s| s.hits), report.cache.hits);
        assert_eq!(sum(|s| s.misses), report.cache.misses);
        assert_eq!(sum(|s| s.evictions), report.cache.evictions);
        assert_eq!(
            report.cache_shards.iter().map(|s| s.entries).sum::<usize>(),
            report.cache.entries
        );
        // batch ids in the live path are the min rider id per batch:
        // distinct ids must agree with the batch records emitted
        let ids: std::collections::BTreeSet<u64> =
            report.requests.iter().map(|r| r.batch_id).collect();
        assert_eq!(ids.len(), report.batches);
    }

    #[test]
    fn batch_metrics_are_bucket_labelled() {
        let svc = service(DispatchPolicy::IpuWithGpuFallback);
        let report = svc.serve_trace(&[MmShape::new(1000, 500, 250); 4]);
        assert!(!report.metrics.is_empty());
        for rec in &report.metrics.records {
            assert_eq!(rec.label, "1024x512x256");
            assert_eq!(rec.shape, MmShape::new(1024, 512, 256));
        }
    }

    #[test]
    fn cached_outcome_matches_run_shape_pricing() {
        // the plan-cost fast path must agree with the coordinator's
        // full sim on the throughput it reports
        let svc = service(DispatchPolicy::IpuWithGpuFallback);
        let bucket = MmShape::square(1024);
        let (outcome, _, _, _) = svc.dispatch(bucket, None);
        let direct = run_shape(&Backend::IpuSim(IpuArch::gc200()), bucket);
        let (a, b) = (outcome.tflops().unwrap(), direct.tflops().unwrap());
        assert!((a - b).abs() < 1e-9, "serve {a} vs coordinator {b}");
    }

    #[test]
    fn mixed_trace_keeps_distinct_entries_per_sparsity_fingerprint() {
        use crate::sparse::pattern::{PatternKind, SparsitySpec};
        let svc = service(DispatchPolicy::IpuWithGpuFallback);
        let shape = MmShape::square(1024);
        let half = SparsitySpec::new(PatternKind::Random, 8, 0.5, 1);
        let tenth = SparsitySpec::new(PatternKind::Banded, 8, 0.1, 1);
        // warm each key once (distinct keys -> no same-key cold races)
        let warm = svc.serve_trace_mixed(&[
            (shape, None),
            (shape, Some(half)),
            (shape, Some(tenth)),
        ]);
        assert_eq!(warm.cache.misses, 3, "dense + two sparse fingerprints");
        let mut trace: Vec<(MmShape, Option<SparsitySpec>)> = Vec::new();
        for _ in 0..6 {
            trace.push((shape, None));
            trace.push((shape, Some(half)));
            trace.push((shape, Some(tenth)));
        }
        let report = svc.serve_trace_mixed(&trace);
        assert_eq!(report.requests.len(), 18);
        // steady state: every lookup hits its own fingerprint's entry
        assert_eq!(report.cache.misses, 0, "warm keys never re-plan");
        assert_eq!(svc.cache().len(), 3, "entries stay distinct");
        // every request is answered and carries its own sparsity tag
        for r in &report.requests {
            let expected = match r.id % 3 {
                0 => None,
                1 => Some(half),
                _ => Some(tenth),
            };
            assert_eq!(r.sparsity, expected, "request {}", r.id);
            assert!(!r.oom);
        }
        // sparse batches are labelled with the spec in the metrics table
        assert!(report
            .metrics
            .records
            .iter()
            .any(|m| m.label.contains("random/b8/d0.50")));
    }

    #[test]
    fn sparse_outcome_reports_effective_throughput() {
        use crate::sparse::pattern::{PatternKind, SparsitySpec};
        let svc = service(DispatchPolicy::IpuWithGpuFallback);
        let bucket = MmShape::square(1024);
        let spec = SparsitySpec::new(PatternKind::Random, 8, 0.25, 1);
        let (sparse, _, _, _) = svc.dispatch(bucket, Some(spec));
        let (dense, _, _, _) = svc.dispatch(bucket, None);
        let (s, d) = (sparse.tflops().unwrap(), dense.tflops().unwrap());
        // effective throughput on a quarter-dense pattern sits well below
        // the dense figure even though the sparse run finishes sooner
        assert!(s < d, "effective {s} vs dense {d}");
        match (sparse, dense) {
            (
                RunOutcome::Ok { seconds: ss, .. },
                RunOutcome::Ok { seconds: ds, .. },
            ) => assert!(ss < ds, "sparse {ss}s should beat dense {ds}s"),
            _ => panic!("both dispatches must succeed"),
        }
    }

    #[test]
    fn report_latency_sketch_covers_every_request() {
        let svc = service(DispatchPolicy::IpuWithGpuFallback);
        let report = svc.serve_trace(&mixed_trace());
        assert_eq!(report.latency_sketch.count(), report.requests.len() as u64);
        // the merged worker sketches hold the same value multiset as the
        // request records, so every bucket count — and hence every
        // quantile — matches a directly-built sketch (sums can differ in
        // the last bits across merge orders, so compare quantiles)
        let mut direct = QuantileSketch::new();
        for r in &report.requests {
            direct.observe(r.latency_seconds());
        }
        assert_eq!(report.latency_sketch.min(), direct.min());
        assert_eq!(report.latency_sketch.max(), direct.max());
        for q in [0.0, 0.25, 0.5, 0.95, 0.99, 1.0] {
            assert_eq!(report.latency_sketch.quantile(q), direct.quantile(q), "q={q}");
        }
    }

    #[test]
    fn backends_reflect_policy() {
        assert_eq!(service(DispatchPolicy::IpuOnly).backends().len(), 1);
        assert_eq!(service(DispatchPolicy::GpuOnly).backends().len(), 1);
        assert_eq!(
            service(DispatchPolicy::IpuWithGpuFallback).backends().len(),
            2
        );
    }
}
