//! Thread-safe LRU plan cache.
//!
//! PopLibs memoizes its matmul/convolution planner in production because
//! the exhaustive partition search (thousands of candidates per shape,
//! see `planner::search`) is far too expensive to repeat per request.
//! This cache plays that role for the serving layer: it memoizes the
//! *result* of the search — the winning [`Plan`] or the out-of-memory
//! verdict — keyed by the problem shape and a fingerprint of every
//! plan-relevant architecture parameter, so a GC200 plan is never served
//! to a GC2 request.
//!
//! Negative results (OOM) are cached too: shapes past the §2.4 memory
//! wall are exactly the ones whose searches evaluate the most candidates
//! before failing, so they benefit the most from memoization.
//!
//! Block-sparse requests add a third key dimension: the
//! [`SparsitySpec`] fingerprint. A sparse plan depends on the exact
//! pattern (generator, block size, density, seed), so two requests only
//! share an entry when their sparsity fingerprints are equal; dense
//! requests key with `sparsity: None` and never collide with sparse
//! entries for the same shape.

use std::collections::HashMap;
use std::sync::Mutex;
use std::time::Instant;

use crate::arch::IpuArch;
use crate::planner::partition::MmShape;
use crate::planner::search::{search, Plan, PlannerError};
use crate::sparse::pattern::SparsitySpec;
use crate::sparse::planner::{sparse_search_spec, SparsePlan};

/// Cache key: problem shape + architecture fingerprint + (for sparse
/// requests) the sparsity-spec fingerprint.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct PlanKey {
    pub shape: MmShape,
    pub arch_fingerprint: u64,
    /// `None` for dense plans, `Some(spec.fingerprint())` for sparse.
    pub sparsity: Option<u64>,
}

/// Monotonic counters; `entries` is the current population.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub entries: usize,
    /// Wall seconds spent in cold `planner::search` calls (the cost the
    /// hits amortize away).
    pub cold_plan_seconds: f64,
}

impl CacheStats {
    /// Hits over all lookups; 0 when nothing was looked up.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    /// Counters accumulated since `baseline` (an earlier snapshot of the
    /// same cache); `entries` stays absolute. Lets a serving run report
    /// per-run cache behavior from a long-lived cache.
    pub fn since(&self, baseline: &CacheStats) -> CacheStats {
        CacheStats {
            hits: self.hits - baseline.hits,
            misses: self.misses - baseline.misses,
            evictions: self.evictions - baseline.evictions,
            entries: self.entries,
            cold_plan_seconds: self.cold_plan_seconds - baseline.cold_plan_seconds,
        }
    }
}

/// What a cache entry memoizes: a dense or a sparse planner verdict.
#[derive(Clone)]
enum CachedResult {
    Dense(Result<Plan, PlannerError>),
    Sparse(Result<SparsePlan, PlannerError>),
}

struct Entry {
    result: CachedResult,
    last_used: u64,
}

struct Inner {
    map: HashMap<PlanKey, Entry>,
    tick: u64,
    stats: CacheStats,
}

/// Bounded, thread-safe, least-recently-used plan cache.
pub struct PlanCache {
    inner: Mutex<Inner>,
    capacity: usize,
}

impl PlanCache {
    /// `capacity` is the maximum number of cached (shape, arch) entries.
    pub fn new(capacity: usize) -> PlanCache {
        assert!(capacity >= 1, "plan cache needs capacity >= 1");
        PlanCache {
            inner: Mutex::new(Inner {
                map: HashMap::new(),
                tick: 0,
                stats: CacheStats::default(),
            }),
            capacity,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        self.lock().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn stats(&self) -> CacheStats {
        let inner = self.lock();
        CacheStats { entries: inner.map.len(), ..inner.stats }
    }

    pub fn clear(&self) {
        let mut inner = self.lock();
        inner.map.clear();
    }

    /// Memoized [`search`]: returns the cached plan (or cached OOM
    /// verdict) on a hit, runs the planner and populates the cache on a
    /// miss.
    pub fn get_or_plan(
        &self,
        arch: &IpuArch,
        shape: MmShape,
    ) -> Result<Plan, PlannerError> {
        self.get_or_plan_timed(arch, shape).0
    }

    /// [`Self::get_or_plan`] plus `(was_hit, planning_seconds)` — the
    /// telemetry the serving layer charges to a batch. `planning_seconds`
    /// is 0 on a hit.
    pub fn get_or_plan_timed(
        &self,
        arch: &IpuArch,
        shape: MmShape,
    ) -> (Result<Plan, PlannerError>, bool, f64) {
        let key = PlanKey { shape, arch_fingerprint: arch.fingerprint(), sparsity: None };

        if let Some(CachedResult::Dense(result)) = self.lookup(&key) {
            return (result, true, 0.0);
        }

        // Plan outside the lock: a slow search must not serialize other
        // workers' hits. `search` is deterministic, so concurrent misses
        // on the same key insert identical entries (last write wins).
        let t0 = Instant::now();
        let result = search(arch, shape);
        let seconds = t0.elapsed().as_secs_f64();
        self.insert(key, CachedResult::Dense(result.clone()), seconds);
        (result, false, seconds)
    }

    /// Memoized sparse search: the key extends the dense one with the
    /// spec's fingerprint, so hits require equal sparsity fingerprints.
    pub fn get_or_plan_sparse(
        &self,
        arch: &IpuArch,
        shape: MmShape,
        spec: SparsitySpec,
    ) -> Result<SparsePlan, PlannerError> {
        self.get_or_plan_sparse_timed(arch, shape, spec).0
    }

    /// [`Self::get_or_plan_sparse`] plus `(was_hit, planning_seconds)`.
    pub fn get_or_plan_sparse_timed(
        &self,
        arch: &IpuArch,
        shape: MmShape,
        spec: SparsitySpec,
    ) -> (Result<SparsePlan, PlannerError>, bool, f64) {
        let key = PlanKey {
            shape,
            arch_fingerprint: arch.fingerprint(),
            sparsity: Some(spec.fingerprint()),
        };

        if let Some(CachedResult::Sparse(result)) = self.lookup(&key) {
            return (result, true, 0.0);
        }

        let t0 = Instant::now();
        let result = sparse_search_spec(arch, shape, spec);
        let seconds = t0.elapsed().as_secs_f64();
        self.insert(key, CachedResult::Sparse(result.clone()), seconds);
        (result, false, seconds)
    }

    /// Hit path shared by the dense and sparse lookups: counts a hit and
    /// refreshes LRU order on success, a miss otherwise.
    fn lookup(&self, key: &PlanKey) -> Option<CachedResult> {
        let mut guard = self.lock();
        let inner = &mut *guard;
        inner.tick += 1;
        let tick = inner.tick;
        if let Some(entry) = inner.map.get_mut(key) {
            entry.last_used = tick;
            let result = entry.result.clone();
            inner.stats.hits += 1;
            return Some(result);
        }
        inner.stats.misses += 1;
        None
    }

    /// Cold-miss insert shared by both paths, with LRU eviction.
    fn insert(&self, key: PlanKey, result: CachedResult, seconds: f64) {
        let mut guard = self.lock();
        let inner = &mut *guard;
        inner.tick += 1;
        let tick = inner.tick;
        inner.stats.cold_plan_seconds += seconds;
        inner.map.insert(key, Entry { result, last_used: tick });
        // eviction is an O(capacity) scan, paid only on cold misses once
        // the cache is full; misses also run a full planner search, which
        // dwarfs the scan at realistic capacities. Revisit with an
        // ordered index if very large capacities become a hot path.
        while inner.map.len() > self.capacity {
            let lru = inner
                .map
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| *k)
                .expect("non-empty map above capacity");
            inner.map.remove(&lru);
            inner.stats.evictions += 1;
        }
    }

    /// Peek without planning or touching LRU order (diagnostics only).
    pub fn peek(&self, arch: &IpuArch, shape: MmShape) -> Option<Result<Plan, PlannerError>> {
        let key = PlanKey { shape, arch_fingerprint: arch.fingerprint(), sparsity: None };
        self.lock().map.get(&key).and_then(|e| match &e.result {
            CachedResult::Dense(result) => Some(result.clone()),
            CachedResult::Sparse(_) => None,
        })
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Inner> {
        self.inner.lock().expect("plan cache poisoned")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn hit_returns_identical_plan() {
        let arch = IpuArch::gc200();
        let cache = PlanCache::new(8);
        let shape = MmShape::square(768);
        let cold = cache.get_or_plan(&arch, shape).unwrap();
        let warm = cache.get_or_plan(&arch, shape).unwrap();
        let fresh = search(&arch, shape).unwrap();
        assert_eq!(warm.cost.partition, cold.cost.partition);
        assert_eq!(warm.cost.total_cycles, fresh.cost.total_cycles);
        assert_eq!(warm.candidates_evaluated, fresh.candidates_evaluated);
        let s = cache.stats();
        assert_eq!((s.hits, s.misses), (1, 1));
        assert!(s.cold_plan_seconds > 0.0);
    }

    #[test]
    fn oom_verdict_is_cached() {
        let arch = IpuArch::gc200();
        let cache = PlanCache::new(8);
        let shape = MmShape::square(8192); // past the §2.4 wall
        assert!(cache.get_or_plan(&arch, shape).is_err());
        assert!(cache.get_or_plan(&arch, shape).is_err());
        let s = cache.stats();
        assert_eq!((s.hits, s.misses), (1, 1));
    }

    #[test]
    fn distinct_archs_do_not_share_entries() {
        let gc200 = IpuArch::gc200();
        let gc2 = IpuArch::gc2();
        let cache = PlanCache::new(8);
        let shape = MmShape::square(512);
        let a = cache.get_or_plan(&gc200, shape).unwrap();
        let b = cache.get_or_plan(&gc2, shape).unwrap();
        assert_eq!(cache.stats().misses, 2, "different fingerprints must miss");
        // GC2 has fewer tiles: the winning grids genuinely differ
        assert!(a.cost.partition.tiles_used() <= gc200.tiles);
        assert!(b.cost.partition.tiles_used() <= gc2.tiles);
    }

    #[test]
    fn lru_evicts_oldest_entry() {
        let arch = IpuArch::gc200();
        let cache = PlanCache::new(2);
        let s1 = MmShape::square(256);
        let s2 = MmShape::square(512);
        let s3 = MmShape::square(768);
        cache.get_or_plan(&arch, s1).unwrap();
        cache.get_or_plan(&arch, s2).unwrap();
        cache.get_or_plan(&arch, s1).unwrap(); // refresh s1
        cache.get_or_plan(&arch, s3).unwrap(); // evicts s2 (LRU)
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.stats().evictions, 1);
        assert!(cache.peek(&arch, s1).is_some());
        assert!(cache.peek(&arch, s2).is_none());
        assert!(cache.peek(&arch, s3).is_some());
    }

    #[test]
    fn timed_lookup_reports_hit_flag() {
        let arch = IpuArch::gc200();
        let cache = PlanCache::new(4);
        let shape = MmShape::new(640, 320, 160);
        let (_, hit, cold_s) = cache.get_or_plan_timed(&arch, shape);
        assert!(!hit);
        assert!(cold_s > 0.0);
        let (_, hit, warm_s) = cache.get_or_plan_timed(&arch, shape);
        assert!(hit);
        assert_eq!(warm_s, 0.0);
    }

    #[test]
    fn concurrent_lookups_converge() {
        let cache = Arc::new(PlanCache::new(16));
        let shapes: Vec<MmShape> =
            (1..=4).map(|i| MmShape::square(256 * i)).collect();
        std::thread::scope(|scope| {
            for _ in 0..4 {
                let cache = Arc::clone(&cache);
                let shapes = shapes.clone();
                scope.spawn(move || {
                    for _ in 0..5 {
                        for &s in &shapes {
                            cache.get_or_plan(&IpuArch::gc200(), s).unwrap();
                        }
                    }
                });
            }
        });
        let s = cache.stats();
        assert_eq!(s.entries, 4);
        assert_eq!(s.hits + s.misses, 80);
        // at most one duplicated search per (thread, shape) race
        assert!(s.misses >= 4 && s.misses <= 16, "misses {}", s.misses);
        assert!(s.hit_rate() > 0.7, "hit rate {}", s.hit_rate());
    }

    #[test]
    fn hit_rate_zero_when_unused() {
        assert_eq!(PlanCache::new(1).stats().hit_rate(), 0.0);
    }

    #[test]
    fn sparse_hits_require_equal_fingerprints() {
        use crate::sparse::pattern::{PatternKind, SparsitySpec};
        let arch = IpuArch::gc200();
        let cache = PlanCache::new(16);
        let shape = MmShape::square(768);
        let spec = SparsitySpec::new(PatternKind::Random, 8, 0.5, 1);
        let cold = cache.get_or_plan_sparse(&arch, shape, spec).unwrap();
        let warm = cache.get_or_plan_sparse(&arch, shape, spec).unwrap();
        assert_eq!(warm.cost.total_cycles, cold.cost.total_cycles);
        assert_eq!((cache.stats().hits, cache.stats().misses), (1, 1));
        // any fingerprint-changing tweak must miss
        for other in [
            SparsitySpec::new(PatternKind::Banded, 8, 0.5, 1),
            SparsitySpec::new(PatternKind::Random, 16, 0.5, 1),
            SparsitySpec::new(PatternKind::Random, 8, 0.25, 1),
            SparsitySpec::new(PatternKind::Random, 8, 0.5, 2),
        ] {
            cache.get_or_plan_sparse(&arch, shape, other).unwrap();
        }
        assert_eq!(cache.stats().misses, 5, "distinct specs are distinct entries");
        assert_eq!(cache.stats().entries, 5);
    }

    #[test]
    fn dense_and_sparse_entries_do_not_collide() {
        use crate::sparse::pattern::SparsitySpec;
        let arch = IpuArch::gc200();
        let cache = PlanCache::new(8);
        let shape = MmShape::square(512);
        cache.get_or_plan(&arch, shape).unwrap();
        cache
            .get_or_plan_sparse(&arch, shape, SparsitySpec::dense(8))
            .unwrap();
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.entries), (0, 2, 2));
        // the dense entry is still intact and hit by the dense path
        cache.get_or_plan(&arch, shape).unwrap();
        assert_eq!(cache.stats().hits, 1);
    }

    #[test]
    fn stats_since_subtracts_counters_but_not_entries() {
        let arch = IpuArch::gc200();
        let cache = PlanCache::new(8);
        cache.get_or_plan(&arch, MmShape::square(256)).unwrap();
        let base = cache.stats();
        cache.get_or_plan(&arch, MmShape::square(256)).unwrap();
        cache.get_or_plan(&arch, MmShape::square(512)).unwrap();
        let delta = cache.stats().since(&base);
        assert_eq!((delta.hits, delta.misses), (1, 1));
        assert_eq!(delta.entries, 2, "entries are absolute, not a delta");
        assert!(delta.cold_plan_seconds > 0.0);
    }
}
