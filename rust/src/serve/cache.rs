//! Thread-safe LRU plan cache.
//!
//! PopLibs memoizes its matmul/convolution planner in production because
//! the exhaustive partition search (thousands of candidates per shape,
//! see `planner::search`) is far too expensive to repeat per request.
//! This cache plays that role for the serving layer: it memoizes the
//! *result* of the search — the winning [`Plan`] or the out-of-memory
//! verdict — keyed by the problem shape and a fingerprint of every
//! plan-relevant architecture parameter, so a GC200 plan is never served
//! to a GC2 request.
//!
//! Negative results (OOM) are cached too: shapes past the §2.4 memory
//! wall are exactly the ones whose searches evaluate the most candidates
//! before failing, so they benefit the most from memoization. OOM
//! verdicts are **fingerprint-dependent** now that the sparse planner's
//! memory wall moves with density: a dense OOM entry (`sparsity: None`)
//! must never satisfy a sparse lookup for the same shape (which may plan
//! fine at low density), and each density memoizes its own verdict —
//! both fall out of the key carrying the sparsity fingerprint, and both
//! are pinned by tests below.
//!
//! Block-sparse requests add a third key dimension: the
//! [`SparsitySpec`] fingerprint. A sparse plan depends on the exact
//! pattern (generator, block size, density, seed), so two requests only
//! share an entry when their sparsity fingerprints are equal; dense
//! requests key with `sparsity: None` and never collide with sparse
//! entries for the same shape.
//!
//! §Perf: the lock is sharded N-way by key hash so a cold-start storm of
//! distinct buckets never serializes behind one mutex — each shard owns
//! an independent map, planning always happens outside any lock, and
//! stats aggregate across shards. LRU order is **global** even though the
//! locks are not: every touch stamps the entry from one shared atomic
//! clock (no cross-shard lock), and eviction compares the shard-local
//! oldest stamps across shards and removes the globally oldest — the
//! per-shard-clock design this replaces let a hot shard evict entries
//! younger than a cold shard's oldest. Capacity is likewise a global
//! bound on the total population (a population counter triggers
//! eviction), so sharding no longer under-commits non-divisible
//! capacities.

use std::collections::hash_map::DefaultHasher;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::arch::IpuArch;
use crate::planner::partition::MmShape;
use crate::planner::search::{search, Plan, PlannerError};
use crate::sparse::pattern::SparsitySpec;
use crate::sparse::planner::{sparse_search_spec, SparsePlan};

/// Cache key: problem shape + architecture fingerprint + (for sparse
/// requests) the sparsity-spec fingerprint.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct PlanKey {
    pub shape: MmShape,
    pub arch_fingerprint: u64,
    /// `None` for dense plans, `Some(spec.fingerprint())` for sparse.
    pub sparsity: Option<u64>,
}

/// Monotonic counters; `entries` is the current population.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub entries: usize,
    /// Wall seconds spent in cold `planner::search` calls (the cost the
    /// hits amortize away).
    pub cold_plan_seconds: f64,
}

impl CacheStats {
    /// Hits over all lookups; 0 when nothing was looked up.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    /// Counters accumulated since `baseline` (an earlier snapshot of the
    /// same cache); `entries` stays absolute. Lets a serving run report
    /// per-run cache behavior from a long-lived cache.
    pub fn since(&self, baseline: &CacheStats) -> CacheStats {
        CacheStats {
            hits: self.hits - baseline.hits,
            misses: self.misses - baseline.misses,
            evictions: self.evictions - baseline.evictions,
            entries: self.entries,
            cold_plan_seconds: self.cold_plan_seconds - baseline.cold_plan_seconds,
        }
    }
}

/// What a cache entry memoizes: a dense or a sparse planner verdict.
#[derive(Clone)]
enum CachedResult {
    Dense(Result<Plan, PlannerError>),
    Sparse(Result<SparsePlan, PlannerError>),
}

struct Entry {
    result: CachedResult,
    /// Stamp from the cache-wide [`PlanCache::clock`] at the last touch —
    /// globally comparable across shards.
    last_used: u64,
}

#[derive(Default)]
struct Inner {
    map: HashMap<PlanKey, Entry>,
    stats: CacheStats,
}

/// Bounded, thread-safe, least-recently-used plan cache with an N-way
/// sharded lock and a sampled global LRU clock (see the module docs).
pub struct PlanCache {
    shards: Vec<Mutex<Inner>>,
    capacity: usize,
    /// Shared LRU clock: one `fetch_add` per touch, no cross-shard lock.
    clock: AtomicU64,
    /// Total entries across shards — the global capacity trigger.
    population: AtomicUsize,
}

impl PlanCache {
    /// `capacity` is the maximum number of cached (shape, arch) entries.
    /// The shard count follows [`Self::default_shards`]: one shard per 64
    /// entries of capacity, capped at 16.
    pub fn new(capacity: usize) -> PlanCache {
        Self::with_shards(capacity, Self::default_shards(capacity))
    }

    /// Shard policy: small caches keep exact global LRU under one lock;
    /// big ones spread contention across up to 16 locks.
    pub fn default_shards(capacity: usize) -> usize {
        (capacity / 64).clamp(1, 16)
    }

    /// Explicit shard count (tests, tuning). `shards` is clamped to
    /// `[1, capacity]`; `capacity` bounds the **total** population — the
    /// global clock lets eviction pick the globally oldest entry from
    /// whichever shard holds it, so shards need no per-shard budget.
    pub fn with_shards(capacity: usize, shards: usize) -> PlanCache {
        assert!(capacity >= 1, "plan cache needs capacity >= 1");
        let shards = shards.clamp(1, capacity);
        PlanCache {
            shards: (0..shards).map(|_| Mutex::new(Inner::default())).collect(),
            capacity,
            clock: AtomicU64::new(0),
            population: AtomicUsize::new(0),
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn shards(&self) -> usize {
        self.shards.len()
    }

    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| self.lock(s).map.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Aggregated counters across every shard.
    pub fn stats(&self) -> CacheStats {
        let mut out = CacheStats::default();
        for shard in &self.shards {
            let inner = self.lock(shard);
            out.hits += inner.stats.hits;
            out.misses += inner.stats.misses;
            out.evictions += inner.stats.evictions;
            out.cold_plan_seconds += inner.stats.cold_plan_seconds;
            out.entries += inner.map.len();
        }
        out
    }

    /// Per-shard counters, in shard order. Each element has the same
    /// shape as [`Self::stats`] restricted to one shard; summing the
    /// vector component-wise reproduces the aggregate (tested), which is
    /// what makes per-shard hot-spot diagnosis trustworthy.
    pub fn shard_stats(&self) -> Vec<CacheStats> {
        self.shards
            .iter()
            .map(|shard| {
                let inner = self.lock(shard);
                CacheStats { entries: inner.map.len(), ..inner.stats }
            })
            .collect()
    }

    pub fn clear(&self) {
        for shard in &self.shards {
            let mut inner = self.lock(shard);
            let removed = inner.map.len();
            inner.map.clear();
            self.population.fetch_sub(removed, Ordering::Relaxed);
        }
    }

    /// The shard owning `key` (stable hash of the full key).
    fn shard_for(&self, key: &PlanKey) -> &Mutex<Inner> {
        let mut h = DefaultHasher::new();
        key.hash(&mut h);
        &self.shards[(h.finish() as usize) % self.shards.len()]
    }

    /// Memoized [`search`]: returns the cached plan (or cached OOM
    /// verdict) on a hit, runs the planner and populates the cache on a
    /// miss.
    pub fn get_or_plan(
        &self,
        arch: &IpuArch,
        shape: MmShape,
    ) -> Result<Plan, PlannerError> {
        self.get_or_plan_timed(arch, shape).0
    }

    /// [`Self::get_or_plan`] plus `(was_hit, planning_seconds)` — the
    /// telemetry the serving layer charges to a batch. `planning_seconds`
    /// is 0 on a hit.
    pub fn get_or_plan_timed(
        &self,
        arch: &IpuArch,
        shape: MmShape,
    ) -> (Result<Plan, PlannerError>, bool, f64) {
        let key = PlanKey { shape, arch_fingerprint: arch.fingerprint(), sparsity: None };

        if let Some(CachedResult::Dense(result)) = self.lookup(&key) {
            return (result, true, 0.0);
        }

        // Plan outside the lock: a slow search must not serialize other
        // workers' hits. `search` is deterministic, so concurrent misses
        // on the same key insert identical entries (last write wins).
        let t0 = Instant::now();
        let result = search(arch, shape);
        let seconds = t0.elapsed().as_secs_f64();
        crate::obs::observe("cache.cold_plan_seconds", seconds);
        self.insert(key, CachedResult::Dense(result.clone()), seconds);
        (result, false, seconds)
    }

    /// Memoized sparse search: the key extends the dense one with the
    /// spec's fingerprint, so hits require equal sparsity fingerprints.
    pub fn get_or_plan_sparse(
        &self,
        arch: &IpuArch,
        shape: MmShape,
        spec: SparsitySpec,
    ) -> Result<SparsePlan, PlannerError> {
        self.get_or_plan_sparse_timed(arch, shape, spec).0
    }

    /// [`Self::get_or_plan_sparse`] plus `(was_hit, planning_seconds)`.
    pub fn get_or_plan_sparse_timed(
        &self,
        arch: &IpuArch,
        shape: MmShape,
        spec: SparsitySpec,
    ) -> (Result<SparsePlan, PlannerError>, bool, f64) {
        let key = PlanKey {
            shape,
            arch_fingerprint: arch.fingerprint(),
            sparsity: Some(spec.fingerprint()),
        };

        if let Some(CachedResult::Sparse(result)) = self.lookup(&key) {
            return (result, true, 0.0);
        }

        let t0 = Instant::now();
        let result = sparse_search_spec(arch, shape, spec);
        let seconds = t0.elapsed().as_secs_f64();
        crate::obs::observe("cache.cold_plan_seconds", seconds);
        self.insert(key, CachedResult::Sparse(result.clone()), seconds);
        (result, false, seconds)
    }

    /// One tick of the shared LRU clock — globally ordered across shards.
    fn tick(&self) -> u64 {
        self.clock.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// Hit path shared by the dense and sparse lookups: counts a hit and
    /// stamps the entry from the global clock on success, a miss
    /// otherwise.
    fn lookup(&self, key: &PlanKey) -> Option<CachedResult> {
        let mut guard = self.lock(self.shard_for(key));
        // tick *inside* the shard lock: drawn outside, a stalled reader
        // could stamp an entry with an older tick than a later touch,
        // re-ordering LRU against real access order within the shard
        let tick = self.tick();
        let inner = &mut *guard;
        if let Some(entry) = inner.map.get_mut(key) {
            entry.last_used = tick;
            let result = entry.result.clone();
            inner.stats.hits += 1;
            crate::obs::count("cache.hits", 1);
            return Some(result);
        }
        inner.stats.misses += 1;
        crate::obs::count("cache.misses", 1);
        None
    }

    /// Cold-miss insert shared by both paths, with sampled-global-LRU
    /// eviction: when the total population exceeds `capacity`, the
    /// shard-local oldest stamps are compared across shards and the
    /// globally oldest entry is evicted — from whichever shard holds it.
    fn insert(&self, key: PlanKey, result: CachedResult, seconds: f64) {
        {
            let mut guard = self.lock(self.shard_for(&key));
            let tick = self.tick(); // inside the lock — see lookup()
            let inner = &mut *guard;
            inner.stats.cold_plan_seconds += seconds;
            if inner.map.insert(key, Entry { result, last_used: tick }).is_none() {
                self.population.fetch_add(1, Ordering::Relaxed);
            }
        }
        // eviction runs outside the inserting shard's lock (shards are
        // locked one at a time — no lock-order cycles) and is an
        // O(entries) scan paid only on cold misses at a full cache; the
        // miss also ran a full planner search, which dwarfs the scan.
        while self.population.load(Ordering::Relaxed) > self.capacity {
            if !self.evict_globally_oldest() {
                break; // raced to empty; nothing left to evict
            }
        }
    }

    /// Sample every shard's locally-oldest stamp and evict the globally
    /// oldest entry. Returns false when the cache is empty. Concurrent
    /// touches can re-stamp the sampled victim between the sample and the
    /// removal — the re-check under the victim shard's lock then resamples
    /// rather than evicting a freshly-used entry.
    fn evict_globally_oldest(&self) -> bool {
        let mut victim: Option<(usize, PlanKey, u64)> = None;
        for (idx, shard) in self.shards.iter().enumerate() {
            let inner = self.lock(shard);
            if let Some((k, e)) = inner.map.iter().min_by_key(|(_, e)| e.last_used) {
                let older = match &victim {
                    None => true,
                    Some((_, _, stamp)) => e.last_used < *stamp,
                };
                if older {
                    victim = Some((idx, *k, e.last_used));
                }
            }
        }
        let Some((idx, key, stamp)) = victim else {
            return false;
        };
        let mut inner = self.lock(&self.shards[idx]);
        match inner.map.get(&key) {
            // only evict the entry we sampled: if a concurrent touch
            // refreshed it, resample on the next loop iteration
            Some(e) if e.last_used == stamp => {
                inner.map.remove(&key);
                inner.stats.evictions += 1;
                crate::obs::count("cache.evictions", 1);
                self.population.fetch_sub(1, Ordering::Relaxed);
                true
            }
            _ => true, // entry moved on; report progress, caller re-checks
        }
    }

    /// Peek without planning or touching LRU order (diagnostics only).
    pub fn peek(&self, arch: &IpuArch, shape: MmShape) -> Option<Result<Plan, PlannerError>> {
        let key = PlanKey { shape, arch_fingerprint: arch.fingerprint(), sparsity: None };
        self.lock(self.shard_for(&key)).map.get(&key).and_then(|e| match &e.result {
            CachedResult::Dense(result) => Some(result.clone()),
            CachedResult::Sparse(_) => None,
        })
    }

    fn lock<'a>(&self, shard: &'a Mutex<Inner>) -> std::sync::MutexGuard<'a, Inner> {
        // a worker that panicked mid-lookup poisons the shard, but every
        // write under this lock is a complete entry insertion or LRU
        // touch — the map is valid after an unwind, so recover instead
        // of cascading the panic into every later serve call
        shard.lock().unwrap_or_else(|e| e.into_inner())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn poisoned_shards_recover_after_a_worker_panic() {
        let arch = IpuArch::gc200();
        let cache = PlanCache::new(8);
        let shape = MmShape::square(768);
        cache.get_or_plan(&arch, shape).unwrap();
        // a panicking worker unwinds while holding each shard lock
        for shard in &cache.shards {
            let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                let _guard = shard.lock().unwrap_or_else(|e| e.into_inner());
                panic!("worker died mid-lookup");
            }));
        }
        assert!(
            cache.shards.iter().all(|s| s.lock().is_err()),
            "every shard mutex must actually be poisoned"
        );
        // per-entry writes are atomic: the state is valid, so later
        // lookups recover instead of cascading the dead worker's panic
        let warm = cache.get_or_plan(&arch, shape).unwrap();
        assert!(warm.cost.total_cycles > 0);
        let s = cache.stats();
        assert_eq!((s.hits, s.misses), (1, 1), "pre-panic entry intact");
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn hit_returns_identical_plan() {
        let arch = IpuArch::gc200();
        let cache = PlanCache::new(8);
        let shape = MmShape::square(768);
        let cold = cache.get_or_plan(&arch, shape).unwrap();
        let warm = cache.get_or_plan(&arch, shape).unwrap();
        let fresh = search(&arch, shape).unwrap();
        assert_eq!(warm.cost.partition, cold.cost.partition);
        assert_eq!(warm.cost.total_cycles, fresh.cost.total_cycles);
        assert_eq!(warm.candidates_evaluated, fresh.candidates_evaluated);
        let s = cache.stats();
        assert_eq!((s.hits, s.misses), (1, 1));
        assert!(s.cold_plan_seconds > 0.0);
    }

    #[test]
    fn oom_verdict_is_cached() {
        let arch = IpuArch::gc200();
        let cache = PlanCache::new(8);
        let shape = MmShape::square(8192); // past the §2.4 wall
        assert!(cache.get_or_plan(&arch, shape).is_err());
        assert!(cache.get_or_plan(&arch, shape).is_err());
        let s = cache.stats();
        assert_eq!((s.hits, s.misses), (1, 1));
    }

    #[test]
    fn distinct_archs_do_not_share_entries() {
        let gc200 = IpuArch::gc200();
        let gc2 = IpuArch::gc2();
        let cache = PlanCache::new(8);
        let shape = MmShape::square(512);
        let a = cache.get_or_plan(&gc200, shape).unwrap();
        let b = cache.get_or_plan(&gc2, shape).unwrap();
        assert_eq!(cache.stats().misses, 2, "different fingerprints must miss");
        // GC2 has fewer tiles: the winning grids genuinely differ
        assert!(a.cost.partition.tiles_used() <= gc200.tiles);
        assert!(b.cost.partition.tiles_used() <= gc2.tiles);
    }

    #[test]
    fn lru_evicts_oldest_entry() {
        let arch = IpuArch::gc200();
        let cache = PlanCache::new(2);
        let s1 = MmShape::square(256);
        let s2 = MmShape::square(512);
        let s3 = MmShape::square(768);
        cache.get_or_plan(&arch, s1).unwrap();
        cache.get_or_plan(&arch, s2).unwrap();
        cache.get_or_plan(&arch, s1).unwrap(); // refresh s1
        cache.get_or_plan(&arch, s3).unwrap(); // evicts s2 (LRU)
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.stats().evictions, 1);
        assert!(cache.peek(&arch, s1).is_some());
        assert!(cache.peek(&arch, s2).is_none());
        assert!(cache.peek(&arch, s3).is_some());
    }

    #[test]
    fn timed_lookup_reports_hit_flag() {
        let arch = IpuArch::gc200();
        let cache = PlanCache::new(4);
        let shape = MmShape::new(640, 320, 160);
        let (_, hit, cold_s) = cache.get_or_plan_timed(&arch, shape);
        assert!(!hit);
        assert!(cold_s > 0.0);
        let (_, hit, warm_s) = cache.get_or_plan_timed(&arch, shape);
        assert!(hit);
        assert_eq!(warm_s, 0.0);
    }

    #[test]
    fn concurrent_lookups_converge() {
        let cache = Arc::new(PlanCache::new(16));
        let shapes: Vec<MmShape> =
            (1..=4).map(|i| MmShape::square(256 * i)).collect();
        std::thread::scope(|scope| {
            for _ in 0..4 {
                let cache = Arc::clone(&cache);
                let shapes = shapes.clone();
                scope.spawn(move || {
                    for _ in 0..5 {
                        for &s in &shapes {
                            cache.get_or_plan(&IpuArch::gc200(), s).unwrap();
                        }
                    }
                });
            }
        });
        let s = cache.stats();
        assert_eq!(s.entries, 4);
        assert_eq!(s.hits + s.misses, 80);
        // at most one duplicated search per (thread, shape) race
        assert!(s.misses >= 4 && s.misses <= 16, "misses {}", s.misses);
        assert!(s.hit_rate() > 0.7, "hit rate {}", s.hit_rate());
    }

    #[test]
    fn hit_rate_zero_when_unused() {
        assert_eq!(PlanCache::new(1).stats().hit_rate(), 0.0);
    }

    #[test]
    fn default_shard_policy_scales_with_capacity() {
        assert_eq!(PlanCache::new(8).shards(), 1, "small caches keep exact LRU");
        assert_eq!(PlanCache::new(256).shards(), 4);
        assert_eq!(PlanCache::new(4096).shards(), 16, "shard count is capped");
    }

    #[test]
    fn sharded_cache_spreads_keys_and_bounds_population() {
        let arch = IpuArch::gc200();
        let cache = PlanCache::with_shards(32, 4);
        assert_eq!(cache.shards(), 4);
        for i in 0..48usize {
            let _ = cache.get_or_plan(&arch, MmShape::new(32 + 8 * i, 64, 32));
        }
        assert!(cache.len() <= 32, "population {} above capacity", cache.len());
        let s = cache.stats();
        assert_eq!(s.misses, 48, "distinct shapes never hit");
        assert_eq!(s.evictions as usize, 48 - cache.len());
    }

    #[test]
    fn non_divisible_capacity_never_overcommits() {
        // 3 shards under capacity 10: per-shard budget floors to 3, so
        // the stated capacity is a true upper bound
        let arch = IpuArch::gc200();
        let cache = PlanCache::with_shards(10, 3);
        for i in 0..20usize {
            let _ = cache.get_or_plan(&arch, MmShape::new(16 + 8 * i, 32, 16));
        }
        assert!(cache.len() <= 10, "population {} above capacity", cache.len());
    }

    #[test]
    fn sharded_cold_storm_converges_across_threads() {
        // the cold-start-storm scenario the sharding exists for: many
        // workers missing on distinct buckets at once must neither lose
        // entries nor miscount, and repeated rounds must hit
        let cache = Arc::new(PlanCache::with_shards(64, 8));
        let shapes: Vec<MmShape> =
            (0..8).map(|i| MmShape::new(128 + 32 * i, 256, 128)).collect();
        std::thread::scope(|scope| {
            for _ in 0..4 {
                let cache = Arc::clone(&cache);
                let shapes = shapes.clone();
                scope.spawn(move || {
                    for _ in 0..3 {
                        for &s in &shapes {
                            cache.get_or_plan(&IpuArch::gc200(), s).unwrap();
                        }
                    }
                });
            }
        });
        let s = cache.stats();
        assert_eq!(s.entries, 8);
        assert_eq!(s.hits + s.misses, 96);
        // at most one duplicated search per (thread, shape) race
        assert!(s.misses >= 8 && s.misses <= 32, "misses {}", s.misses);
    }

    #[test]
    fn cross_shard_pattern_evicts_globally_oldest() {
        // the satellite regression: with per-shard clocks a hot shard
        // evicted entries younger than a cold shard's oldest. The global
        // clock + cross-shard victim sampling must evict the entry that
        // is oldest by *global* access order, wherever it hashes.
        let arch = IpuArch::gc200();
        let cache = PlanCache::with_shards(6, 3);
        let shapes: Vec<MmShape> = (0..6).map(|i| MmShape::new(64 + 16 * i, 128, 64)).collect();
        for &s in &shapes {
            cache.get_or_plan(&arch, s).unwrap();
        }
        assert_eq!(cache.len(), 6);
        // touch everything except shapes[2] — it becomes the global LRU
        for &s in shapes.iter().enumerate().filter(|(i, _)| *i != 2).map(|(_, s)| s) {
            cache.get_or_plan(&arch, s).unwrap();
        }
        cache.get_or_plan(&arch, MmShape::new(4096, 128, 64)).unwrap(); // 7th entry
        assert_eq!(cache.len(), 6);
        assert_eq!(cache.stats().evictions, 1);
        assert!(
            cache.peek(&arch, shapes[2]).is_none(),
            "the globally oldest entry must be the victim"
        );
        for (i, &s) in shapes.iter().enumerate() {
            if i != 2 {
                assert!(cache.peek(&arch, s).is_some(), "younger entry {i} evicted");
            }
        }
    }

    #[test]
    fn sharded_lru_matches_exact_single_shard_lru() {
        // stronger form: for any access sequence, the sharded cache with
        // the global clock keeps exactly the entries a one-shard (exact
        // LRU) cache keeps — sampling the shard-local minima recovers the
        // global minimum
        let arch = IpuArch::gc200();
        let exact = PlanCache::with_shards(8, 1);
        let sharded = PlanCache::with_shards(8, 4);
        let shapes: Vec<MmShape> =
            (0..14).map(|i| MmShape::new(48 + 16 * i, 96, 48)).collect();
        // interleaved inserts and touches
        let sequence: Vec<usize> =
            vec![0, 1, 2, 3, 4, 0, 5, 6, 1, 7, 8, 9, 2, 10, 11, 0, 12, 13, 3];
        for &i in &sequence {
            exact.get_or_plan(&arch, shapes[i]).unwrap();
            sharded.get_or_plan(&arch, shapes[i]).unwrap();
        }
        assert_eq!(exact.len(), sharded.len());
        for (i, &s) in shapes.iter().enumerate() {
            assert_eq!(
                exact.peek(&arch, s).is_some(),
                sharded.peek(&arch, s).is_some(),
                "shape {i} residency diverges from exact LRU"
            );
        }
    }

    #[test]
    fn sparse_hits_require_equal_fingerprints() {
        use crate::sparse::pattern::{PatternKind, SparsitySpec};
        let arch = IpuArch::gc200();
        let cache = PlanCache::new(16);
        let shape = MmShape::square(768);
        let spec = SparsitySpec::new(PatternKind::Random, 8, 0.5, 1);
        let cold = cache.get_or_plan_sparse(&arch, shape, spec).unwrap();
        let warm = cache.get_or_plan_sparse(&arch, shape, spec).unwrap();
        assert_eq!(warm.cost.total_cycles, cold.cost.total_cycles);
        assert_eq!((cache.stats().hits, cache.stats().misses), (1, 1));
        // any fingerprint-changing tweak must miss
        for other in [
            SparsitySpec::new(PatternKind::Banded, 8, 0.5, 1),
            SparsitySpec::new(PatternKind::Random, 16, 0.5, 1),
            SparsitySpec::new(PatternKind::Random, 8, 0.25, 1),
            SparsitySpec::new(PatternKind::Random, 8, 0.5, 2),
        ] {
            cache.get_or_plan_sparse(&arch, shape, other).unwrap();
        }
        assert_eq!(cache.stats().misses, 5, "distinct specs are distinct entries");
        assert_eq!(cache.stats().entries, 5);
    }

    #[test]
    fn dense_and_sparse_entries_do_not_collide() {
        use crate::sparse::pattern::SparsitySpec;
        let arch = IpuArch::gc200();
        let cache = PlanCache::new(8);
        let shape = MmShape::square(512);
        cache.get_or_plan(&arch, shape).unwrap();
        cache
            .get_or_plan_sparse(&arch, shape, SparsitySpec::dense(8))
            .unwrap();
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.entries), (0, 2, 2));
        // the dense entry is still intact and hit by the dense path
        cache.get_or_plan(&arch, shape).unwrap();
        assert_eq!(cache.stats().hits, 1);
    }

    #[test]
    fn dense_oom_does_not_poison_sparse_lookups() {
        use crate::sparse::pattern::{PatternKind, SparsitySpec};
        // 4096^2 is past the dense §2.4 wall but plans sparse at 25%
        // density — a cached dense OOM verdict must not be served for
        // the sparse key, and the sparse success must not overwrite the
        // dense verdict
        let arch = IpuArch::gc200();
        let cache = PlanCache::new(8);
        let shape = MmShape::square(4096);
        assert!(cache.get_or_plan(&arch, shape).is_err(), "dense 4096^2 must OOM");
        let spec = SparsitySpec::new(PatternKind::Random, 8, 0.25, 42);
        let plan = cache
            .get_or_plan_sparse(&arch, shape, spec)
            .expect("sparse 4096^2 at 25% density must plan despite the cached dense OOM");
        assert!(plan.cost.fits);
        // both verdicts are now warm and independent
        assert!(cache.get_or_plan(&arch, shape).is_err(), "dense verdict intact");
        assert!(cache.get_or_plan_sparse(&arch, shape, spec).is_ok());
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.entries), (2, 2, 2));
    }

    #[test]
    fn per_density_oom_verdicts_memoize_separately() {
        use crate::sparse::pattern::{PatternKind, SparsitySpec};
        // at 4096^2 the sparse wall is density-dependent: 25% fits,
        // 100% reproduces the dense OOM — each density is its own entry
        let arch = IpuArch::gc200();
        let cache = PlanCache::new(8);
        let shape = MmShape::square(4096);
        let fits = SparsitySpec::new(PatternKind::Random, 8, 0.25, 42);
        let dense_d = SparsitySpec::new(PatternKind::Random, 8, 1.0, 42);
        assert!(cache.get_or_plan_sparse(&arch, shape, fits).is_ok());
        assert!(cache.get_or_plan_sparse(&arch, shape, dense_d).is_err());
        // warm lookups return the memoized verdicts without re-planning
        assert!(cache.get_or_plan_sparse(&arch, shape, fits).is_ok());
        assert!(cache.get_or_plan_sparse(&arch, shape, dense_d).is_err());
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.entries), (2, 2, 2));
        // and a sparse success never satisfies a dense lookup
        assert!(cache.get_or_plan(&arch, shape).is_err());
        assert_eq!(cache.stats().entries, 3);
    }

    #[test]
    fn shard_stats_sum_to_global_aggregate() {
        let arch = IpuArch::gc200();
        let cache = PlanCache::with_shards(8, 4);
        // 12 distinct shapes through capacity 8 forces evictions; the
        // second pass mixes hits with re-plans of evicted entries
        for i in 0..12usize {
            let _ = cache.get_or_plan(&arch, MmShape::new(32 + 8 * i, 64, 32));
        }
        for i in 0..6usize {
            let _ = cache.get_or_plan(&arch, MmShape::new(32 + 8 * i, 64, 32));
        }
        let shards = cache.shard_stats();
        assert_eq!(shards.len(), 4);
        let total = cache.stats();
        assert_eq!(shards.iter().map(|s| s.hits).sum::<u64>(), total.hits);
        assert_eq!(shards.iter().map(|s| s.misses).sum::<u64>(), total.misses);
        assert_eq!(shards.iter().map(|s| s.evictions).sum::<u64>(), total.evictions);
        assert_eq!(shards.iter().map(|s| s.entries).sum::<usize>(), total.entries);
        let cold: f64 = shards.iter().map(|s| s.cold_plan_seconds).sum();
        assert!((cold - total.cold_plan_seconds).abs() < 1e-9);
        assert!(total.evictions > 0, "test must exercise the eviction path");
    }

    #[test]
    fn stats_since_subtracts_counters_but_not_entries() {
        let arch = IpuArch::gc200();
        let cache = PlanCache::new(8);
        cache.get_or_plan(&arch, MmShape::square(256)).unwrap();
        let base = cache.stats();
        cache.get_or_plan(&arch, MmShape::square(256)).unwrap();
        cache.get_or_plan(&arch, MmShape::square(512)).unwrap();
        let delta = cache.stats().since(&base);
        assert_eq!((delta.hits, delta.misses), (1, 1));
        assert_eq!(delta.entries, 2, "entries are absolute, not a delta");
        assert!(delta.cold_plan_seconds > 0.0);
    }
}
