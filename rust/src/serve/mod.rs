//! Matmul-as-a-service — the L4 serving layer.
//!
//! The paper's central finding is that plan *choice*, not raw flops,
//! determines IPU matmul performance — and the planner search that makes
//! that choice is expensive enough that PopLibs memoizes it in
//! production. This module turns the one-shot benchmark pipeline into a
//! request-serving front end that amortizes planner searches across
//! sustained traffic, the way Graphcore's own stack does:
//!
//! * [`cache`] — a thread-safe LRU **plan cache** keyed by
//!   `(MmShape, IpuArch fingerprint, sparsity fingerprint)` that memoizes
//!   [`crate::planner::search`] and [`crate::sparse::planner`] results
//!   (including out-of-memory verdicts) and exposes hit/miss/eviction
//!   counters. Dense requests key with no sparsity dimension; sparse
//!   requests only hit entries with an equal
//!   [`crate::sparse::pattern::SparsitySpec`] fingerprint. The lock is
//!   sharded N-way by key hash and planning happens outside it, so a
//!   cold-start storm of distinct buckets plans concurrently instead of
//!   serializing behind one mutex.
//! * [`bucket`] — **shape bucketing**: incoming `(m, n, k)` requests are
//!   rounded up to a ladder of block classes so the skewed long tail
//!   shares cached plans. The ladder's rungs are the same power-of-two /
//!   3·2^i block classes the paper's aspect-ratio sweep walks, and can be
//!   aligned to the AOT block artifacts `runtime::blockmm` composes.
//! * [`queue`] — a bounded MPSC **request queue** with admission control
//!   (reject-on-full) and batch coalescing of same-bucket requests.
//! * [`service`] — the front door: coalesced batches are dispatched
//!   across backends (IPU simulator, GPU model, and the real PJRT
//!   runtime when artifacts are present) on a worker pool sized by the
//!   same policy as [`crate::coordinator::runner`].
//! * [`telemetry`] — per-`(bucket, sparsity)` latency/throughput/cache
//!   records that reuse [`crate::coordinator::metrics`] for rendering.
//!
//! Dispatch is fault-aware: when a [`crate::fault::FaultPlan`] or an
//! active [`crate::fault::FaultPolicy`] is configured on
//! [`ServiceConfig`], every request is resolved through the seeded
//! injection / retry / circuit-breaker layer in [`crate::fault`] before
//! workers run, each request ends with an explicit
//! [`crate::fault::RequestOutcome`], and workers are panic-isolated via
//! `catch_unwind`. With faults disabled the served trace is bit-identical
//! to the passthrough path (property-tested).
//!
//! The demo driver is `examples/serve_demo.rs`; `benches/bench_serve.rs`
//! measures cached-vs-cold planning throughput.

pub mod bucket;
pub mod cache;
pub mod queue;
pub mod service;
pub mod telemetry;

pub use bucket::BucketLadder;
pub use cache::{CacheStats, PlanCache};
pub use queue::{AdmissionError, Batch, MmRequest, QueueStats, RequestQueue};
pub use service::{DispatchPolicy, MmService, ServiceConfig};
pub use telemetry::{FaultStats, RequestRecord, ServeReport};
