//! Bounded MPSC request queue with admission control and batch
//! coalescing.
//!
//! The Citadel microbenchmark study (arXiv 1912.03413) shows fixed
//! per-launch overheads dominate small repeated IPU kernels; the serving
//! answer is to coalesce same-bucket requests into one batch so a single
//! plan lookup and one modeled execution amortize over every request in
//! the batch. The queue is bounded: producers either get an immediate
//! [`AdmissionError::QueueFull`] (admission control for latency-sensitive
//! callers) or block for space ([`RequestQueue::submit_blocking`],
//! backpressure for throughput callers). Consumers pop the oldest
//! request and sweep every other queued request in the same bucket into
//! its [`Batch`] (FIFO across buckets, so no bucket can starve another).

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::Instant;

use crate::planner::partition::MmShape;
use crate::sparse::pattern::SparsitySpec;

/// One matmul request, already bucketed by the front door.
#[derive(Clone, Debug)]
pub struct MmRequest {
    pub id: u64,
    /// The caller's shape.
    pub shape: MmShape,
    /// The plan-cache key shape (`>= shape` in every dimension).
    pub bucket: MmShape,
    /// Block-sparsity descriptor; `None` is a dense request. Part of the
    /// coalescing key: sparse plans depend on the exact pattern, so only
    /// requests with equal specs may share a batch (and a cache entry).
    pub sparsity: Option<SparsitySpec>,
    /// Enqueue timestamp (queue-wait telemetry).
    pub submitted: Instant,
}

impl MmRequest {
    pub fn new(id: u64, shape: MmShape, bucket: MmShape) -> MmRequest {
        debug_assert!(
            bucket.m >= shape.m && bucket.n >= shape.n && bucket.k >= shape.k,
            "bucket {bucket:?} smaller than request {shape:?}"
        );
        MmRequest { id, shape, bucket, sparsity: None, submitted: Instant::now() }
    }

    /// Tag the request with a block-sparsity descriptor.
    pub fn with_sparsity(mut self, spec: SparsitySpec) -> MmRequest {
        self.sparsity = Some(spec);
        self
    }
}

/// A coalesced group of same-bucket, same-sparsity requests, served by
/// one plan lookup.
#[derive(Debug)]
pub struct Batch {
    pub bucket: MmShape,
    /// Shared sparsity of every rider (`None` = dense batch).
    pub sparsity: Option<SparsitySpec>,
    pub requests: Vec<MmRequest>,
    /// Queue depth left behind when this batch was drained — the
    /// windowed queue-depth signal in serve telemetry.
    pub queued_behind: usize,
}

impl Batch {
    pub fn len(&self) -> usize {
        self.requests.len()
    }

    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }
}

/// Why a submission was not admitted.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdmissionError {
    /// Queue is at capacity; the caller should shed or retry later.
    QueueFull { capacity: usize },
    /// Queue was closed; no further work is accepted.
    Closed,
}

impl std::fmt::Display for AdmissionError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AdmissionError::QueueFull { capacity } => {
                write!(f, "request queue full (capacity {capacity})")
            }
            AdmissionError::Closed => write!(f, "request queue closed"),
        }
    }
}

impl std::error::Error for AdmissionError {}

/// Counters observed over the queue's lifetime.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct QueueStats {
    pub submitted: u64,
    /// Submissions bounced by admission control (`submit` on full).
    pub rejected: u64,
    /// Times a blocking submitter had to wait for space.
    pub throttled: u64,
    /// Peak queue depth seen.
    pub max_depth: usize,
}

struct QueueInner {
    queue: VecDeque<MmRequest>,
    closed: bool,
    stats: QueueStats,
}

/// Bounded multi-producer queue; any number of consumer threads may call
/// [`Self::next_batch`].
pub struct RequestQueue {
    inner: Mutex<QueueInner>,
    not_empty: Condvar,
    not_full: Condvar,
    capacity: usize,
}

impl RequestQueue {
    pub fn new(capacity: usize) -> RequestQueue {
        assert!(capacity >= 1, "queue needs capacity >= 1");
        RequestQueue {
            inner: Mutex::new(QueueInner {
                queue: VecDeque::new(),
                closed: false,
                stats: QueueStats::default(),
            }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            capacity,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        self.lock().queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn stats(&self) -> QueueStats {
        self.lock().stats
    }

    /// Admission-controlled submit: immediately rejects when full.
    pub fn submit(&self, req: MmRequest) -> Result<(), AdmissionError> {
        let mut inner = self.lock();
        if inner.closed {
            return Err(AdmissionError::Closed);
        }
        if inner.queue.len() >= self.capacity {
            inner.stats.rejected += 1;
            crate::obs::count("queue.rejected", 1);
            return Err(AdmissionError::QueueFull { capacity: self.capacity });
        }
        self.push(&mut inner, req);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Backpressure submit: waits for space instead of rejecting. Errors
    /// only if the queue closes while waiting.
    pub fn submit_blocking(&self, req: MmRequest) -> Result<(), AdmissionError> {
        let mut inner = self.lock();
        let mut counted = false;
        while !inner.closed && inner.queue.len() >= self.capacity {
            if !counted {
                // one throttle event per submission, not per wakeup
                inner.stats.throttled += 1;
                counted = true;
            }
            inner = self
                .not_full
                .wait(inner)
                .unwrap_or_else(|e| e.into_inner());
        }
        if inner.closed {
            return Err(AdmissionError::Closed);
        }
        self.push(&mut inner, req);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Close the queue: pending requests still drain; new submissions
    /// fail; blocked consumers wake with `None` once empty.
    pub fn close(&self) {
        let mut inner = self.lock();
        inner.closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    /// Block until work is available; pop the oldest request and coalesce
    /// every other queued request with the same bucket (up to
    /// `max_batch` total). Returns `None` when closed and drained.
    pub fn next_batch(&self, max_batch: usize) -> Option<Batch> {
        let max_batch = max_batch.max(1);
        let mut inner = self.lock();
        loop {
            if let Some(head) = inner.queue.pop_front() {
                let bucket = head.bucket;
                let sparsity = head.sparsity;
                let mut requests = vec![head];
                // rebuild the queue only when there is actually something
                // to coalesce — the no-rider case stays allocation-free
                if max_batch > 1
                    && inner
                        .queue
                        .iter()
                        .any(|r| r.bucket == bucket && r.sparsity == sparsity)
                {
                    let mut kept = VecDeque::with_capacity(inner.queue.len());
                    for req in inner.queue.drain(..) {
                        if requests.len() < max_batch
                            && req.bucket == bucket
                            && req.sparsity == sparsity
                        {
                            requests.push(req);
                        } else {
                            kept.push_back(req);
                        }
                    }
                    inner.queue = kept;
                }
                self.not_full.notify_all();
                crate::obs::count("queue.batches", 1);
                if requests.len() > 1 {
                    crate::obs::count("queue.coalesced_riders", (requests.len() - 1) as u64);
                }
                let queued_behind = inner.queue.len();
                return Some(Batch { bucket, sparsity, requests, queued_behind });
            }
            if inner.closed {
                return None;
            }
            inner = self
                .not_empty
                .wait(inner)
                .unwrap_or_else(|e| e.into_inner());
        }
    }

    fn push(&self, inner: &mut QueueInner, req: MmRequest) {
        inner.queue.push_back(req);
        inner.stats.submitted += 1;
        inner.stats.max_depth = inner.stats.max_depth.max(inner.queue.len());
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, QueueInner> {
        // per-entry pushes/pops are atomic under this lock, so the queue
        // is valid even after a panicking worker poisoned it — recover
        // rather than take down every subsequent submit/drain
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn req(id: u64, s: usize) -> MmRequest {
        MmRequest::new(id, MmShape::square(s), MmShape::square(s))
    }

    #[test]
    fn poisoned_queue_recovers_after_a_worker_panic() {
        let q = RequestQueue::new(8);
        q.submit(req(0, 512)).unwrap();
        // a panicking worker unwinds while holding the queue lock
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            // lint:allow(no-lock-unwrap) — this test *creates* the poison
            let _guard = q.inner.lock().unwrap();
            panic!("worker died mid-drain");
        }));
        assert!(q.inner.lock().is_err(), "queue mutex must actually be poisoned");
        // submissions and drains recover: the queue's state was valid
        // when the worker died, so nothing cascades
        q.submit(req(1, 512)).unwrap();
        q.submit_blocking(req(2, 512)).unwrap();
        let batch = q.next_batch(8).unwrap();
        assert_eq!(
            batch.requests.iter().map(|r| r.id).collect::<Vec<_>>(),
            vec![0, 1, 2],
            "pre-panic request drains alongside post-panic ones"
        );
        assert_eq!(q.stats().submitted, 3);
        assert!(q.is_empty());
    }

    #[test]
    fn coalesces_same_bucket_preserving_fifo_across_buckets() {
        let q = RequestQueue::new(16);
        q.submit(req(0, 512)).unwrap();
        q.submit(req(1, 1024)).unwrap();
        q.submit(req(2, 512)).unwrap();
        q.submit(req(3, 512)).unwrap();
        let b1 = q.next_batch(8).unwrap();
        assert_eq!(b1.bucket, MmShape::square(512));
        assert_eq!(
            b1.requests.iter().map(|r| r.id).collect::<Vec<_>>(),
            vec![0, 2, 3]
        );
        assert_eq!(b1.queued_behind, 1, "the 1024 request stays queued");
        let b2 = q.next_batch(8).unwrap();
        assert_eq!(b2.bucket, MmShape::square(1024));
        assert_eq!(b2.len(), 1);
        assert!(q.is_empty());
    }

    #[test]
    fn sparsity_splits_batches() {
        use crate::sparse::pattern::{PatternKind, SparsitySpec};
        let spec = SparsitySpec::new(PatternKind::Random, 8, 0.5, 1);
        let other = SparsitySpec::new(PatternKind::Random, 8, 0.25, 1);
        let q = RequestQueue::new(16);
        q.submit(req(0, 512)).unwrap();
        q.submit(req(1, 512).with_sparsity(spec)).unwrap();
        q.submit(req(2, 512)).unwrap();
        q.submit(req(3, 512).with_sparsity(spec)).unwrap();
        q.submit(req(4, 512).with_sparsity(other)).unwrap();
        // dense batch coalesces only dense riders of the bucket
        let dense = q.next_batch(8).unwrap();
        assert_eq!(dense.sparsity, None);
        assert_eq!(dense.requests.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 2]);
        // then the first sparse spec, then the second — never mixed
        let s1 = q.next_batch(8).unwrap();
        assert_eq!(s1.sparsity, Some(spec));
        assert_eq!(s1.requests.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 3]);
        let s2 = q.next_batch(8).unwrap();
        assert_eq!(s2.sparsity, Some(other));
        assert_eq!(s2.len(), 1);
        assert!(q.is_empty());
    }

    #[test]
    fn max_batch_caps_coalescing() {
        let q = RequestQueue::new(16);
        for i in 0..5 {
            q.submit(req(i, 256)).unwrap();
        }
        let b = q.next_batch(3).unwrap();
        assert_eq!(b.len(), 3);
        assert_eq!(q.len(), 2, "uncoalesced remainder stays queued");
        let rest = q.next_batch(3).unwrap();
        assert_eq!(rest.requests.iter().map(|r| r.id).collect::<Vec<_>>(), vec![3, 4]);
    }

    #[test]
    fn admission_control_rejects_when_full() {
        let q = RequestQueue::new(2);
        q.submit(req(0, 64)).unwrap();
        q.submit(req(1, 64)).unwrap();
        assert_eq!(
            q.submit(req(2, 64)),
            Err(AdmissionError::QueueFull { capacity: 2 })
        );
        let s = q.stats();
        assert_eq!((s.submitted, s.rejected, s.max_depth), (2, 1, 2));
    }

    #[test]
    fn closed_queue_rejects_and_drains() {
        let q = RequestQueue::new(4);
        q.submit(req(0, 64)).unwrap();
        q.close();
        assert_eq!(q.submit(req(1, 64)), Err(AdmissionError::Closed));
        assert_eq!(q.next_batch(4).unwrap().len(), 1);
        assert!(q.next_batch(4).is_none(), "closed + empty ends consumption");
    }

    #[test]
    fn blocking_submit_waits_for_space() {
        let q = Arc::new(RequestQueue::new(1));
        q.submit(req(0, 64)).unwrap();
        std::thread::scope(|scope| {
            let qp = Arc::clone(&q);
            let producer = scope.spawn(move || qp.submit_blocking(req(1, 128)));
            // wait until the producer is provably throttled, then free
            // the slot; the blocked producer then lands
            while q.stats().throttled == 0 {
                std::thread::yield_now();
            }
            let b = q.next_batch(4).unwrap();
            assert_eq!(b.bucket, MmShape::square(64));
            producer.join().unwrap().unwrap();
        });
        assert_eq!(q.next_batch(4).unwrap().bucket, MmShape::square(128));
        assert!(q.stats().throttled >= 1);
    }

    #[test]
    fn multi_producer_multi_consumer_drains_everything() {
        let q = Arc::new(RequestQueue::new(64));
        let total = 200u64;
        let drained = Arc::new(Mutex::new(Vec::new()));
        std::thread::scope(|scope| {
            for p in 0..4u64 {
                let q = Arc::clone(&q);
                scope.spawn(move || {
                    for i in 0..total / 4 {
                        let id = p * (total / 4) + i;
                        let size = 64 * (1 + (id % 3) as usize);
                        q.submit_blocking(req(id, size)).unwrap();
                    }
                });
            }
            for _ in 0..3 {
                let q = Arc::clone(&q);
                let drained = Arc::clone(&drained);
                scope.spawn(move || {
                    while let Some(b) = q.next_batch(8) {
                        let mut ids = drained.lock().unwrap_or_else(|e| e.into_inner());
                        ids.extend(b.requests.iter().map(|r| r.id));
                    }
                });
            }
            // close only once every submission has landed, so consumers
            // terminate without dropping work
            let q = Arc::clone(&q);
            scope.spawn(move || {
                while q.stats().submitted < total {
                    std::thread::yield_now();
                }
                q.close();
            });
        });
        let mut ids = drained.lock().unwrap_or_else(|e| e.into_inner()).clone();
        ids.sort_unstable();
        assert_eq!(ids.len(), total as usize, "every request served exactly once");
        assert!(ids.windows(2).all(|w| w[0] != w[1]));
    }
}
