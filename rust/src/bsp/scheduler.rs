//! The BSP engine: walk a program, price each phase, emit a trace.
//!
//! Compute phases: each tile's cost is the sum of its vertices' cycle
//! estimates divided by the worker-thread overlap factor (six time-sliced
//! threads hide instruction latency; the AMP pipeline is already saturated
//! by one supervisor vertex, so overlap applies to non-AMP codelets).
//! The phase takes the *maximum* over tiles — BSP is lockstep — and the
//! mean/max ratio is the tile balance the profiler reports.
//!
//! The engine prices phases; it does not re-check that the schedule is
//! *safe* to price (barriers between phases, race-free supersteps, reads
//! that land on delivered data). Those are static properties of the
//! program tree and are proven up front by [`crate::analysis::verify`]
//! (`ipumm check`), so pricing here can assume them.

use crate::arch::IpuArch;
use crate::bsp::trace::{Phase, PhaseRecord, Trace};
use crate::exchange::fabric::ExchangeFabric;
use crate::graph::builder::Graph;
use crate::graph::program::ProgramStep;
use crate::graph::vertex::VertexKind;

pub struct BspEngine<'a> {
    arch: &'a IpuArch,
    fabric: ExchangeFabric,
}

impl<'a> BspEngine<'a> {
    pub fn new(arch: &'a IpuArch) -> Self {
        BspEngine { arch, fabric: ExchangeFabric::new(arch) }
    }

    /// Execute (price) the graph's program; returns the phase trace.
    pub fn run(&self, graph: &Graph) -> Trace {
        let mut trace = Trace::default();
        for step in graph.program.steps() {
            match step {
                ProgramStep::Execute(cs_id) => {
                    let cs = graph.compute_set(cs_id);
                    let mut per_tile = vec![0u64; self.arch.tiles];
                    for &vid in &cs.vertices {
                        let v = graph.vertex(vid);
                        per_tile[v.tile] += self.vertex_cycles(&v.kind);
                    }
                    // replicated groups: every spanned tile carries
                    // `per_tile` identical vertices, so the sum expands to
                    // count x per-vertex cycles — bit-identical to the
                    // per-vertex form
                    for &gid in &cs.groups {
                        let g = graph.group(gid);
                        let cycles = g.per_tile as u64 * self.vertex_cycles(&g.kind);
                        for tile in g.span.iter() {
                            per_tile[tile] += cycles;
                        }
                    }
                    let active: Vec<u64> =
                        per_tile.iter().copied().filter(|&c| c > 0).collect();
                    let max = active.iter().copied().max().unwrap_or(0);
                    let mean = if active.is_empty() {
                        0.0
                    } else {
                        active.iter().sum::<u64>() as f64 / active.len() as f64
                    };
                    trace.push(PhaseRecord {
                        phase: Phase::Compute,
                        label: cs.name.clone(),
                        cycles: max,
                        tile_balance: if max == 0 { 0.0 } else { mean / max as f64 },
                        active_tiles: active.len(),
                    });
                }
                ProgramStep::Sync => {
                    trace.push(PhaseRecord {
                        phase: Phase::Sync,
                        label: "sync".to_string(),
                        cycles: self.arch.sync_cycles,
                        tile_balance: 0.0,
                        active_tiles: self.arch.tiles,
                    });
                }
                ProgramStep::Exchange(ex_id) => {
                    let plan = graph.exchange(ex_id);
                    let cost = self.fabric.cost(plan);
                    trace.push(PhaseRecord {
                        phase: Phase::Exchange,
                        label: plan.name.clone(),
                        cycles: cost.cycles,
                        tile_balance: 0.0,
                        active_tiles: plan.participants(),
                    });
                }
            }
        }
        trace
    }

    /// Per-vertex cycles with worker-thread overlap for non-AMP codelets.
    fn vertex_cycles(&self, kind: &VertexKind) -> u64 {
        let raw = kind.cycles(self.arch.fp32_macs_per_tile_cycle);
        match kind {
            // the AMP pipeline is a per-tile resource: no thread speedup
            // (dense and block-sparse supervisors alike)
            VertexKind::AmpMacc { .. } | VertexKind::BlockSparseMm { .. } => raw,
            // memory-bound codelets overlap across the 6 hardware threads;
            // model a conservative 2x effective overlap
            _ => raw.div_ceil(2),
        }
    }

    /// Seconds for a trace on this architecture.
    pub fn trace_secs(&self, trace: &Trace) -> f64 {
        self.arch.cycles_to_secs(trace.total_cycles())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exchange::plan::{ExchangePattern, ExchangePlan};
    use crate::graph::program::Program;
    use crate::graph::vertex::VertexKind;

    fn arch() -> IpuArch {
        IpuArch::gc200()
    }

    #[test]
    fn empty_program_empty_trace() {
        let g = Graph::new(arch().tiles);
        let a = arch();
        let t = BspEngine::new(&a).run(&g);
        assert_eq!(t.total_cycles(), 0);
    }

    #[test]
    fn compute_phase_is_max_over_tiles() {
        let a = arch();
        let mut g = Graph::new(a.tiles);
        let cs = g.add_compute_set("mm");
        // tile 0: one big vertex; tile 1: one small vertex
        g.add_vertex(cs, VertexKind::AmpMacc { rows: 64, cols: 64, acc: 64 }, 0, vec![], vec![]);
        g.add_vertex(cs, VertexKind::AmpMacc { rows: 16, cols: 16, acc: 16 }, 1, vec![], vec![]);
        g.set_program(Program::Execute(cs));
        let t = BspEngine::new(&a).run(&g);
        let big = VertexKind::AmpMacc { rows: 64, cols: 64, acc: 64 }.cycles(16);
        assert_eq!(t.total_cycles(), big);
        // balance: (big + small)/2 / big < 1
        assert!(t.records[0].tile_balance < 1.0);
        assert_eq!(t.records[0].active_tiles, 2);
    }

    #[test]
    fn balanced_tiles_have_unit_balance() {
        let a = arch();
        let mut g = Graph::new(a.tiles);
        let cs = g.add_compute_set("mm");
        for tile in 0..8 {
            g.add_vertex(cs, VertexKind::AmpMacc { rows: 32, cols: 32, acc: 32 }, tile, vec![], vec![]);
        }
        g.set_program(Program::Execute(cs));
        let t = BspEngine::new(&a).run(&g);
        assert!((t.tile_utilization() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn sync_costs_arch_sync_cycles() {
        let a = arch();
        let mut g = Graph::new(a.tiles);
        g.set_program(Program::Sequence(vec![Program::Sync, Program::Sync]));
        let t = BspEngine::new(&a).run(&g);
        assert_eq!(t.total_cycles(), 2 * a.sync_cycles);
        assert_eq!(t.phase_cycles(Phase::Sync), 2 * a.sync_cycles);
    }

    #[test]
    fn exchange_priced_by_fabric() {
        let a = arch();
        let mut g = Graph::new(a.tiles);
        let mut plan = ExchangePlan::new("x", ExchangePattern::AllToAll);
        plan.add(0, 1, 8_000);
        let ex = g.add_exchange(plan);
        g.set_program(Program::Exchange(ex));
        let t = BspEngine::new(&a).run(&g);
        assert!(t.phase_cycles(Phase::Exchange) >= 1000);
    }

    #[test]
    fn repeat_scales_cycles() {
        let a = arch();
        let mut g = Graph::new(a.tiles);
        let cs = g.add_compute_set("mm");
        g.add_vertex(cs, VertexKind::AmpMacc { rows: 32, cols: 32, acc: 32 }, 0, vec![], vec![]);
        let once = {
            let mut g1 = g.clone();
            g1.set_program(Program::Execute(cs));
            BspEngine::new(&a).run(&g1).total_cycles()
        };
        g.set_program(Program::Repeat(4, Box::new(Program::Execute(cs))));
        let four = BspEngine::new(&a).run(&g).total_cycles();
        assert_eq!(four, 4 * once);
    }

    #[test]
    fn grouped_vertices_price_identically_to_individual() {
        use crate::graph::vertex::TileSpan;
        let a = arch();
        let kind = VertexKind::AmpMacc { rows: 48, cols: 32, acc: 64 };
        let re = VertexKind::Rearrange { bytes: 4096 };
        // individual form: 3 tiles x (2 AmpMacc + 1 Rearrange)
        let mut gi = Graph::new(a.tiles);
        let cs = gi.add_compute_set("mm");
        for tile in 0..3 {
            gi.add_vertex(cs, kind.clone(), tile, vec![], vec![]);
            gi.add_vertex(cs, kind.clone(), tile, vec![], vec![]);
            gi.add_vertex(cs, re.clone(), tile, vec![], vec![]);
        }
        gi.set_program(Program::Execute(cs));
        // grouped form of the same graph
        let mut gg = Graph::new(a.tiles);
        let cs = gg.add_compute_set("mm");
        gg.add_vertex_group(cs, kind, TileSpan::range(0, 3), 2, vec![], vec![]);
        gg.add_vertex_group(cs, re, TileSpan::range(0, 3), 1, vec![], vec![]);
        gg.set_program(Program::Execute(cs));
        let engine = BspEngine::new(&a);
        let ti = engine.run(&gi);
        let tg = engine.run(&gg);
        assert_eq!(ti.total_cycles(), tg.total_cycles());
        assert_eq!(ti.records[0].active_tiles, tg.records[0].active_tiles);
        assert!((ti.records[0].tile_balance - tg.records[0].tile_balance).abs() < 1e-15);
    }

    #[test]
    fn non_amp_codelets_get_thread_overlap() {
        let a = arch();
        let raw = VertexKind::Rearrange { bytes: 8_000 }.cycles(16);
        let mut g = Graph::new(a.tiles);
        let cs = g.add_compute_set("re");
        g.add_vertex(cs, VertexKind::Rearrange { bytes: 8_000 }, 0, vec![], vec![]);
        g.set_program(Program::Execute(cs));
        let t = BspEngine::new(&a).run(&g);
        assert_eq!(t.total_cycles(), raw.div_ceil(2));
    }

    #[test]
    fn trace_secs_uses_clock() {
        let a = arch();
        let mut g = Graph::new(a.tiles);
        g.set_program(Program::Sync);
        let engine = BspEngine::new(&a);
        let t = engine.run(&g);
        let s = engine.trace_secs(&t);
        assert!((s - a.sync_cycles as f64 / a.clock_hz).abs() < 1e-15);
    }
}
