//! Bulk-Synchronous Parallel execution engine (paper §2.5, Fig. 3).
//!
//! The IPU executes in supersteps: (1) local tile compute, (2) global
//! cross-tile sync, (3) data exchange. `scheduler` walks a graph's program
//! and prices each phase against the architecture's cycle models;
//! `trace` records the phase timeline the profiler renders (the Fig. 3
//! red/blue/yellow bars) and the tile-utilisation metric the paper reads
//! off PopVision.

pub mod scheduler;
pub mod trace;

pub use scheduler::BspEngine;
pub use trace::{Phase, PhaseRecord, Trace};
