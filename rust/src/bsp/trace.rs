//! Phase traces: the data behind the PopVision execution timeline.

/// BSP phase kind, colour-coded as in the paper's Fig. 3.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// Local tile compute (red).
    Compute,
    /// Global synchronisation (blue).
    Sync,
    /// Data exchange (yellow).
    Exchange,
}

impl Phase {
    pub fn name(&self) -> &'static str {
        match self {
            Phase::Compute => "compute",
            Phase::Sync => "sync",
            Phase::Exchange => "exchange",
        }
    }
}

/// One timeline entry.
#[derive(Clone, Debug)]
pub struct PhaseRecord {
    pub phase: Phase,
    pub label: String,
    pub cycles: u64,
    /// For compute phases: mean per-tile busy cycles / critical-path cycles
    /// over *active* tiles — PopVision's "tile balance" within a step.
    pub tile_balance: f64,
    /// Tiles that did any work in this phase.
    pub active_tiles: usize,
}

/// Full execution trace of one program run.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    pub records: Vec<PhaseRecord>,
}

impl Trace {
    pub fn push(&mut self, rec: PhaseRecord) {
        self.records.push(rec);
    }

    pub fn total_cycles(&self) -> u64 {
        self.records.iter().map(|r| r.cycles).sum()
    }

    pub fn phase_cycles(&self, phase: Phase) -> u64 {
        self.records
            .iter()
            .filter(|r| r.phase == phase)
            .map(|r| r.cycles)
            .sum()
    }

    /// (compute, sync, exchange) fractions of total cycles.
    pub fn phase_fractions(&self) -> (f64, f64, f64) {
        let total = self.total_cycles().max(1) as f64;
        (
            self.phase_cycles(Phase::Compute) as f64 / total,
            self.phase_cycles(Phase::Sync) as f64 / total,
            self.phase_cycles(Phase::Exchange) as f64 / total,
        )
    }

    /// Cycle-weighted mean tile balance over compute phases — the trace's
    /// aggregate "Tile Utilisation" figure.
    pub fn tile_utilization(&self) -> f64 {
        let (num, den) = self
            .records
            .iter()
            .filter(|r| r.phase == Phase::Compute && r.cycles > 0)
            .fold((0.0, 0u64), |(n, d), r| {
                (n + r.tile_balance * r.cycles as f64, d + r.cycles)
            });
        if den == 0 {
            0.0
        } else {
            num / den as f64
        }
    }

    pub fn superstep_count(&self) -> usize {
        self.records.iter().filter(|r| r.phase == Phase::Compute).count()
    }

    /// Timeline spans: each record paired with its cumulative start
    /// offset (phases run back-to-back — BSP is lockstep), as
    /// `(start_cycle, duration_cycles, record)`. This is what the obs
    /// layer converts into model-time trace spans.
    pub fn spans(&self) -> impl Iterator<Item = (u64, u64, &PhaseRecord)> {
        let mut start = 0u64;
        self.records.iter().map(move |r| {
            let s = start;
            start += r.cycles;
            (s, r.cycles, r)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(phase: Phase, cycles: u64, balance: f64) -> PhaseRecord {
        PhaseRecord { phase, label: String::new(), cycles, tile_balance: balance, active_tiles: 1 }
    }

    #[test]
    fn totals_and_phase_sums() {
        let mut t = Trace::default();
        t.push(rec(Phase::Compute, 100, 0.9));
        t.push(rec(Phase::Sync, 10, 0.0));
        t.push(rec(Phase::Exchange, 40, 0.0));
        assert_eq!(t.total_cycles(), 150);
        assert_eq!(t.phase_cycles(Phase::Compute), 100);
        let (c, s, e) = t.phase_fractions();
        assert!((c - 100.0 / 150.0).abs() < 1e-12);
        assert!((s - 10.0 / 150.0).abs() < 1e-12);
        assert!((e - 40.0 / 150.0).abs() < 1e-12);
    }

    #[test]
    fn utilization_is_cycle_weighted() {
        let mut t = Trace::default();
        t.push(rec(Phase::Compute, 100, 1.0));
        t.push(rec(Phase::Compute, 300, 0.5));
        // (100*1.0 + 300*0.5) / 400 = 0.625
        assert!((t.tile_utilization() - 0.625).abs() < 1e-12);
    }

    #[test]
    fn empty_trace_defaults() {
        let t = Trace::default();
        assert_eq!(t.total_cycles(), 0);
        assert_eq!(t.tile_utilization(), 0.0);
        assert_eq!(t.superstep_count(), 0);
    }

    #[test]
    fn spans_accumulate_start_offsets() {
        let mut t = Trace::default();
        t.push(rec(Phase::Compute, 100, 0.9));
        t.push(rec(Phase::Sync, 10, 0.0));
        t.push(rec(Phase::Exchange, 40, 0.0));
        let spans: Vec<(u64, u64)> = t.spans().map(|(s, d, _)| (s, d)).collect();
        assert_eq!(spans, vec![(0, 100), (100, 10), (110, 40)]);
        let (_, _, last) = t.spans().last().unwrap();
        assert_eq!(last.phase, Phase::Exchange);
    }

    #[test]
    fn superstep_count_counts_compute() {
        let mut t = Trace::default();
        t.push(rec(Phase::Compute, 1, 1.0));
        t.push(rec(Phase::Sync, 1, 0.0));
        t.push(rec(Phase::Compute, 1, 1.0));
        assert_eq!(t.superstep_count(), 2);
    }
}
