//! Multi-IPU scaling (paper §6 future work, X2 in DESIGN.md).
//!
//! The M2000 carries four GC200s linked at 350 GB/s (Table 1). The
//! natural matmul sharding keeps A replicated-by-rows and splits B's
//! columns (the k dim) across chips: no cross-chip reduction is needed,
//! but each chip must receive its B shard and the A panel over IPU-Link,
//! and the per-chip problem must still clear the per-chip SRAM wall. The
//! paper notes PopLin "is currently lacking support for multiple IPUs"
//! (§2.3) — this model quantifies what that support would buy.

use crate::arch::IpuArch;
use crate::planner::partition::MmShape;
use crate::planner::search::{bisect_max_fitting, search, search_fits, PlannerError};

#[derive(Clone, Copy, Debug)]
pub struct MultiIpuReport {
    pub shape: MmShape,
    pub chips: usize,
    pub seconds: f64,
    pub tflops: f64,
    /// Speedup over the best single-chip run of the same shape (None when
    /// the shape does not fit one chip at all).
    pub single_chip_tflops: Option<f64>,
    /// Fraction of time spent in IPU-Link distribution.
    pub link_fraction: f64,
    pub per_chip_shape: MmShape,
}

pub struct MultiIpu {
    pub arch: IpuArch,
    pub chips: usize,
}

impl MultiIpu {
    /// An M2000-like pod of `chips` IPUs.
    pub fn new(arch: IpuArch, chips: usize) -> MultiIpu {
        assert!(chips >= 1);
        MultiIpu { arch, chips }
    }

    /// Simulate k-sharded execution across the pod.
    pub fn simulate_mm(&self, shape: MmShape) -> Result<MultiIpuReport, PlannerError> {
        // shard k as evenly as possible; every chip must fit its shard
        let k_shard = shape.k.div_ceil(self.chips).max(1);
        let per_chip = MmShape::new(shape.m, shape.n, k_shard);
        let plan = search(&self.arch, per_chip)?;
        let compute_secs = self.arch.cycles_to_secs(plan.cost.total_cycles);

        // distribution: A (m x n) broadcast to all chips + each chip's B
        // shard, over IPU-Link; the link is shared so transfers serialize
        let a_bytes = (shape.m * shape.n * 4) as f64;
        let b_bytes = (shape.n * shape.k * 4) as f64;
        let link_secs = if self.chips > 1 {
            ((self.chips - 1) as f64 * a_bytes + b_bytes)
                / self.arch.interchip_bw_bytes_per_s
        } else {
            0.0
        };

        let seconds = compute_secs + link_secs;
        let tflops = shape.flops() as f64 / seconds / 1e12;
        let single = search(&self.arch, shape)
            .ok()
            .map(|p| p.tflops(&self.arch));
        Ok(MultiIpuReport {
            shape,
            chips: self.chips,
            seconds,
            tflops,
            single_chip_tflops: single,
            link_fraction: link_secs / seconds,
            per_chip_shape: per_chip,
        })
    }

    /// Largest fitting square across the pod (the §6 "maximum processable
    /// matrices" improvement), at `step` granularity. §Perf: a pod square
    /// fits iff its k-shard clears the single-chip wall, so this bisects
    /// over the fits-only probe like `planner::search::max_fitting_square`.
    pub fn max_fitting_square(&self, step: usize, limit: usize) -> usize {
        bisect_max_fitting(step, limit, |s| {
            let k_shard = s.div_ceil(self.chips).max(1);
            search_fits(&self.arch, MmShape::new(s, s, k_shard))
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pod(chips: usize) -> MultiIpu {
        MultiIpu::new(IpuArch::gc200(), chips)
    }

    #[test]
    fn one_chip_matches_single_search() {
        let r = pod(1).simulate_mm(MmShape::square(2048)).unwrap();
        let single = search(&IpuArch::gc200(), MmShape::square(2048)).unwrap();
        let expect = single.tflops(&IpuArch::gc200());
        assert!((r.tflops - expect).abs() / expect < 0.01);
    }

    #[test]
    fn four_chips_speed_up_large_squares() {
        let r1 = pod(1).simulate_mm(MmShape::square(3584)).unwrap();
        let r4 = pod(4).simulate_mm(MmShape::square(3584)).unwrap();
        assert!(r4.tflops > 1.5 * r1.tflops, "{} vs {}", r4.tflops, r1.tflops);
    }

    #[test]
    fn four_chips_extend_the_memory_wall() {
        let m1 = pod(1).max_fitting_square(256, 16384);
        let m4 = pod(4).max_fitting_square(256, 16384);
        assert!(m4 > m1, "{m4} vs {m1}");
    }

    #[test]
    fn link_time_is_visible_but_not_dominant_for_squares() {
        let r = pod(4).simulate_mm(MmShape::square(3584)).unwrap();
        assert!(r.link_fraction > 0.0 && r.link_fraction < 0.8, "{}", r.link_fraction);
    }

    #[test]
    fn scaling_efficiency_degrades_for_small_problems() {
        let small = pod(4).simulate_mm(MmShape::square(512)).unwrap();
        let big = pod(4).simulate_mm(MmShape::square(3584)).unwrap();
        let eff_small = small.tflops / small.single_chip_tflops.unwrap();
        let eff_big = big.tflops / big.single_chip_tflops.unwrap();
        assert!(eff_big > eff_small, "{eff_big} vs {eff_small}");
    }
}
