//! Backends a benchmark job can target.

use crate::arch::{GpuArch, IpuArch};
use crate::gpu::cublas_model::GpuModel;
use crate::planner::partition::MmShape;
use crate::planner::search::PlannerError;
use crate::sim::engine::SimEngine;

/// What a job runs on.
#[derive(Clone, Debug)]
pub enum Backend {
    /// The calibrated IPU simulator.
    IpuSim(IpuArch),
    /// The analytical cuBLAS model.
    GpuModel(GpuArch),
}

impl Backend {
    pub fn name(&self) -> String {
        match self {
            Backend::IpuSim(a) => format!("ipu-sim/{}", a.name),
            Backend::GpuModel(g) => format!("gpu-model/{}", g.name),
        }
    }

    pub fn peak_tflops(&self) -> f64 {
        match self {
            Backend::IpuSim(a) => a.peak_fp32_tflops(),
            Backend::GpuModel(g) => g.peak_fp32_tflops(),
        }
    }
}

/// Normalized result of one run.
#[derive(Clone, Debug)]
pub enum RunOutcome {
    Ok {
        seconds: f64,
        tflops: f64,
        efficiency: f64,
        /// IPU only: vertex census total.
        vertices: Option<usize>,
        /// IPU only: heaviest-tile bytes.
        max_tile_bytes: Option<u64>,
    },
    /// Shape does not fit this backend's memory (the Fig. 4 IPU wall /
    /// GPU DRAM limit).
    OutOfMemory,
}

impl RunOutcome {
    pub fn tflops(&self) -> Option<f64> {
        match self {
            RunOutcome::Ok { tflops, .. } => Some(*tflops),
            RunOutcome::OutOfMemory => None,
        }
    }

    pub fn is_oom(&self) -> bool {
        matches!(self, RunOutcome::OutOfMemory)
    }
}

/// Execute one shape on a backend.
pub fn run_shape(backend: &Backend, shape: MmShape) -> RunOutcome {
    match backend {
        Backend::IpuSim(arch) => {
            let engine = SimEngine::new(arch.clone());
            match engine.simulate_mm(shape) {
                Ok(report) => RunOutcome::Ok {
                    seconds: report.seconds,
                    tflops: report.tflops,
                    efficiency: report.efficiency,
                    vertices: Some(report.total_vertices),
                    max_tile_bytes: Some(report.memory.max_tile_used),
                },
                Err(PlannerError::OutOfMemory { .. }) => RunOutcome::OutOfMemory,
            }
        }
        Backend::GpuModel(gpu) => {
            let model = GpuModel::new(gpu.clone());
            if !model.fits(shape) {
                return RunOutcome::OutOfMemory;
            }
            let r = model.simulate_mm(shape);
            RunOutcome::Ok {
                seconds: r.seconds,
                tflops: r.tflops,
                efficiency: r.efficiency,
                vertices: None,
                max_tile_bytes: None,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ipu_backend_runs() {
        let out = run_shape(&Backend::IpuSim(IpuArch::gc200()), MmShape::square(1024));
        match out {
            RunOutcome::Ok { tflops, vertices, .. } => {
                assert!(tflops > 0.0);
                assert!(vertices.is_some());
            }
            _ => panic!("expected success"),
        }
    }

    #[test]
    fn gpu_backend_runs() {
        let out = run_shape(&Backend::GpuModel(GpuArch::a30()), MmShape::square(1024));
        assert!(out.tflops().unwrap() > 0.0);
    }

    #[test]
    fn ipu_oom_past_wall() {
        let out = run_shape(&Backend::IpuSim(IpuArch::gc200()), MmShape::square(8192));
        assert!(out.is_oom());
    }

    #[test]
    fn gpu_survives_past_ipu_wall() {
        let out = run_shape(&Backend::GpuModel(GpuArch::a30()), MmShape::square(8192));
        assert!(!out.is_oom());
    }

    #[test]
    fn names_and_peaks() {
        let b = Backend::IpuSim(IpuArch::gc200());
        assert_eq!(b.name(), "ipu-sim/GC200");
        assert!((b.peak_tflops() - 62.6).abs() < 0.2);
        let g = Backend::GpuModel(GpuArch::a30());
        assert_eq!(g.name(), "gpu-model/A30");
    }
}
