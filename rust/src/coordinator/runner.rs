//! Worker-pool job runner.
//!
//! Simulator and GPU-model jobs are pure CPU work with no shared state, so
//! they fan out over a scoped thread pool (no tokio offline; std threads +
//! mpsc). Results are re-ordered to match submission order so tables are
//! deterministic regardless of scheduling.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};

use crate::coordinator::device::{run_shape, Backend};
use crate::coordinator::metrics::{MetricsRecord, MetricsTable};
use crate::planner::partition::MmShape;

/// One unit of benchmark work.
#[derive(Clone, Debug)]
pub struct Job {
    pub backend: Backend,
    pub label: String,
    pub shape: MmShape,
}

impl Job {
    pub fn new(backend: Backend, label: impl Into<String>, shape: MmShape) -> Job {
        Job { backend, label: label.into(), shape }
    }
}

/// Order-preserving parallel map over `items` on the shared worker
/// policy: `workers: None` sizes the pool from [`default_workers`]. Items
/// are dealt dynamically (work stealing from one queue); results land in
/// submission order regardless of scheduling, so any deterministic `f`
/// yields a deterministic output for every worker count. This is the
/// §Perf primitive the sweep drivers (`fig4` via [`run_jobs`],
/// `memory_study`, `sparse_sweep`) plan their grid points through.
pub fn par_map<T, R, F>(items: Vec<T>, workers: Option<usize>, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n = items.len();
    let workers = workers
        .unwrap_or_else(default_workers)
        .max(1)
        .min(n.max(1));
    if workers <= 1 {
        return items.into_iter().map(f).collect();
    }
    let queue = Arc::new(Mutex::new(
        items.into_iter().enumerate().collect::<Vec<(usize, T)>>(),
    ));
    let (tx, rx) = mpsc::channel::<(usize, R)>();

    std::thread::scope(|scope| {
        for _ in 0..workers {
            let queue = Arc::clone(&queue);
            let tx = tx.clone();
            let f = &f;
            scope.spawn(move || loop {
                let item = queue.lock().expect("queue poisoned").pop();
                let Some((idx, item)) = item else { break };
                if tx.send((idx, f(item))).is_err() {
                    break;
                }
            });
        }
        drop(tx);
    });

    let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
    for (idx, r) in rx {
        slots[idx] = Some(r);
    }
    slots
        .into_iter()
        .map(|slot| slot.expect("worker dropped an item"))
        .collect()
}

/// Run all jobs across a worker pool; results in submission order.
///
/// `workers: None` sizes the pool from [`default_workers`]
/// (`available_parallelism` minus one) — the single sizing policy shared
/// by the paper sweeps and the serving layer (`serve::service`). Pass
/// `Some(n)` only to pin a count (tests, reproducible bench runs).
pub fn run_jobs(jobs: Vec<Job>, workers: Option<usize>) -> MetricsTable {
    let mut table = MetricsTable::default();
    for rec in par_map(jobs, workers, |job: Job| {
        let outcome = run_shape(&job.backend, job.shape);
        MetricsRecord {
            backend: job.backend.name(),
            label: job.label,
            shape: job.shape,
            outcome,
        }
    }) {
        table.push(rec);
    }
    table
}

/// Default worker count: physical parallelism minus one for the collector.
pub fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get().saturating_sub(1).max(1))
        .unwrap_or(4)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{GpuArch, IpuArch};

    fn jobs(sizes: &[usize]) -> Vec<Job> {
        sizes
            .iter()
            .flat_map(|&s| {
                [
                    Job::new(Backend::IpuSim(IpuArch::gc200()), s.to_string(), MmShape::square(s)),
                    Job::new(Backend::GpuModel(GpuArch::a30()), s.to_string(), MmShape::square(s)),
                ]
            })
            .collect()
    }

    #[test]
    fn runs_all_jobs_in_submission_order() {
        let table = run_jobs(jobs(&[256, 512, 768]), Some(4));
        assert_eq!(table.len(), 6);
        let labels: Vec<&str> = table.records.iter().map(|r| r.label.as_str()).collect();
        assert_eq!(labels, vec!["256", "256", "512", "512", "768", "768"]);
    }

    #[test]
    fn single_worker_matches_parallel() {
        let a = run_jobs(jobs(&[256, 512]), Some(1));
        let b = run_jobs(jobs(&[256, 512]), Some(8));
        assert_eq!(a.len(), b.len());
        for (ra, rb) in a.records.iter().zip(&b.records) {
            assert_eq!(ra.tflops_cell(), rb.tflops_cell());
        }
    }

    #[test]
    fn default_sizing_policy_runs_everything() {
        let table = run_jobs(jobs(&[256, 512]), None);
        assert_eq!(table.len(), 4);
    }

    #[test]
    fn empty_job_list_is_fine() {
        let table = run_jobs(vec![], Some(4));
        assert!(table.is_empty());
    }

    #[test]
    fn default_workers_is_positive() {
        assert!(default_workers() >= 1);
    }

    #[test]
    fn par_map_preserves_order_for_any_worker_count() {
        let items: Vec<usize> = (0..50).collect();
        let expect: Vec<usize> = items.iter().map(|i| i * i).collect();
        for workers in [Some(1), Some(3), Some(8), None] {
            assert_eq!(par_map(items.clone(), workers, |i| i * i), expect);
        }
        assert!(par_map(Vec::<usize>::new(), Some(4), |i: usize| i).is_empty());
    }
}
