//! Worker-pool job runner and the process-wide thread-budget governor.
//!
//! Simulator and GPU-model jobs are pure CPU work with no shared state, so
//! they fan out over a scoped thread pool (no tokio offline; std threads +
//! mpsc). Results are re-ordered to match submission order so tables are
//! deterministic regardless of scheduling.
//!
//! ## §Perf — the thread budget
//!
//! Every pool in the crate ([`par_map`], `planner::search_with_workers`,
//! `sparse::planner`'s past-the-wall shards, `serve::MmService`'s batch
//! workers) draws its threads from one shared [`ThreadBudget`]: a
//! process-wide permit pool sized to the machine width. Worker counts
//! (`--workers`, `IPUMM_SEARCH_WORKERS`, `workers:` arguments) are
//! **requests** against the budget, not absolute counts — when sweeps
//! nest planner searches inside sweep workers, the inner pools are
//! granted whatever is left (always at least the calling thread), so
//! sweep-workers × planner-workers can no longer oversubscribe the
//! machine. Grants never block and never change results: every governed
//! pool is deterministic for any worker count, so the governor only
//! shapes wall-clock, never output.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex, OnceLock};

use crate::coordinator::device::{run_shape, Backend};
use crate::coordinator::metrics::{MetricsRecord, MetricsTable};
use crate::planner::partition::MmShape;

/// Process-wide worker-thread permit pool (see the module docs). One
/// global instance governs every pool in the crate; `new` exists for
/// tests that need an isolated budget.
pub struct ThreadBudget {
    total: usize,
    available: AtomicUsize,
}

/// A grant of worker threads from a [`ThreadBudget`]. Holds
/// `workers() - 1` permits (the calling thread is always free, so every
/// grant is at least 1 and [`ThreadBudget::acquire`] never blocks);
/// dropping the lease returns the permits.
pub struct BudgetLease<'a> {
    budget: &'a ThreadBudget,
    granted: usize,
}

impl<'a> BudgetLease<'a> {
    /// Worker threads this lease entitles the holder to run (>= 1).
    pub fn workers(&self) -> usize {
        self.granted
    }
}

impl Drop for BudgetLease<'_> {
    fn drop(&mut self) {
        let extra = self.granted.saturating_sub(1);
        if extra > 0 {
            self.budget.available.fetch_add(extra, Ordering::Relaxed);
        }
    }
}

impl ThreadBudget {
    /// An isolated budget of `total` permits (tests / tuning).
    pub fn new(total: usize) -> ThreadBudget {
        let total = total.max(1);
        ThreadBudget { total, available: AtomicUsize::new(total) }
    }

    /// The shared process-wide budget: machine width
    /// (`available_parallelism`), overridable with `IPUMM_THREAD_BUDGET`
    /// (read once, at first use — benches pin it for reproducible runs).
    pub fn global() -> &'static ThreadBudget {
        static GLOBAL: OnceLock<ThreadBudget> = OnceLock::new();
        GLOBAL.get_or_init(|| {
            let total = std::env::var("IPUMM_THREAD_BUDGET")
                .ok()
                .and_then(|v| v.parse().ok())
                .filter(|&n: &usize| n >= 1)
                .unwrap_or_else(|| {
                    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
                });
            ThreadBudget::new(total)
        })
    }

    /// Total permits (the machine width this budget models).
    pub fn total(&self) -> usize {
        self.total
    }

    /// Permits currently free (diagnostics; racy by nature).
    pub fn available(&self) -> usize {
        self.available.load(Ordering::Relaxed)
    }

    /// Grant between 1 and `request` workers without blocking: the
    /// calling thread is always allowed, and up to `request - 1` extra
    /// permits are taken from whatever is free. Nested pools therefore
    /// degrade to serial (grant 1) when the budget is exhausted instead
    /// of oversubscribing the machine.
    pub fn acquire(&self, request: usize) -> BudgetLease<'_> {
        let wanted = request.max(1) - 1;
        let mut taken = 0usize;
        if wanted > 0 {
            let _ = self.available.fetch_update(
                Ordering::Relaxed,
                Ordering::Relaxed,
                |free| {
                    taken = free.min(wanted);
                    Some(free - taken)
                },
            );
        }
        // Write-only telemetry: grants are decided above, so tracing can
        // never change who gets how many permits.
        if crate::obs::enabled() {
            crate::obs::count("budget.requests", 1);
            crate::obs::count("budget.granted_permits", taken as u64);
            if taken < wanted {
                crate::obs::count("budget.denied_permits", (wanted - taken) as u64);
            }
            if request > 1 && taken == 0 {
                crate::obs::count("budget.degraded_serial", 1);
                crate::obs::event(
                    "budget",
                    "degraded-to-serial",
                    "budget",
                    &[("requested", request.to_string())],
                );
            }
        }
        BudgetLease { budget: self, granted: 1 + taken }
    }
}

/// One unit of benchmark work.
#[derive(Clone, Debug)]
pub struct Job {
    pub backend: Backend,
    pub label: String,
    pub shape: MmShape,
}

impl Job {
    pub fn new(backend: Backend, label: impl Into<String>, shape: MmShape) -> Job {
        Job { backend, label: label.into(), shape }
    }
}

/// Order-preserving parallel map over `items` on the shared worker
/// policy: `workers: None` sizes the pool from [`default_workers`]. Items
/// are dealt dynamically (work stealing from one queue); results land in
/// submission order regardless of scheduling, so any deterministic `f`
/// yields a deterministic output for every worker count. This is the
/// §Perf primitive the sweep drivers (`fig4` via [`run_jobs`],
/// `memory_study`, `sparse_sweep`) plan their grid points through.
///
/// The worker count is a *request* against [`ThreadBudget::global`]: a
/// `par_map` nested inside another governed pool is granted whatever the
/// budget has left (at least the calling thread), so nested sweeps stay
/// within the machine width.
pub fn par_map<T, R, F>(items: Vec<T>, workers: Option<usize>, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n = items.len();
    let request = workers
        .unwrap_or_else(default_workers)
        .max(1)
        .min(n.max(1));
    let lease = if request > 1 {
        Some(ThreadBudget::global().acquire(request))
    } else {
        None
    };
    let workers = lease.as_ref().map_or(1, |l| l.workers());
    if workers <= 1 {
        return items.into_iter().map(f).collect();
    }
    let queue = Arc::new(Mutex::new(
        items.into_iter().enumerate().collect::<Vec<(usize, T)>>(),
    ));
    let (tx, rx) = mpsc::channel::<(usize, R)>();

    std::thread::scope(|scope| {
        for _ in 0..workers {
            let queue = Arc::clone(&queue);
            let tx = tx.clone();
            let f = &f;
            scope.spawn(move || loop {
                let item = queue.lock().unwrap_or_else(|e| e.into_inner()).pop();
                let Some((idx, item)) = item else { break };
                if tx.send((idx, f(item))).is_err() {
                    break;
                }
            });
        }
        drop(tx);
    });

    let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
    for (idx, r) in rx {
        slots[idx] = Some(r);
    }
    slots
        .into_iter()
        .map(|slot| slot.expect("worker dropped an item"))
        .collect()
}

/// Run all jobs across a worker pool; results in submission order.
///
/// `workers: None` sizes the pool from [`default_workers`]
/// (`available_parallelism` minus one) — the single sizing policy shared
/// by the paper sweeps and the serving layer (`serve::service`). Pass
/// `Some(n)` only to pin a count (tests, reproducible bench runs).
pub fn run_jobs(jobs: Vec<Job>, workers: Option<usize>) -> MetricsTable {
    let mut table = MetricsTable::default();
    for rec in par_map(jobs, workers, |job: Job| {
        let outcome = run_shape(&job.backend, job.shape);
        MetricsRecord {
            backend: job.backend.name(),
            label: job.label,
            shape: job.shape,
            outcome,
        }
    }) {
        table.push(rec);
    }
    table
}

/// Default worker count: physical parallelism minus one for the collector.
pub fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get().saturating_sub(1).max(1))
        .unwrap_or(4)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{GpuArch, IpuArch};

    fn jobs(sizes: &[usize]) -> Vec<Job> {
        sizes
            .iter()
            .flat_map(|&s| {
                [
                    Job::new(Backend::IpuSim(IpuArch::gc200()), s.to_string(), MmShape::square(s)),
                    Job::new(Backend::GpuModel(GpuArch::a30()), s.to_string(), MmShape::square(s)),
                ]
            })
            .collect()
    }

    #[test]
    fn runs_all_jobs_in_submission_order() {
        let table = run_jobs(jobs(&[256, 512, 768]), Some(4));
        assert_eq!(table.len(), 6);
        let labels: Vec<&str> = table.records.iter().map(|r| r.label.as_str()).collect();
        assert_eq!(labels, vec!["256", "256", "512", "512", "768", "768"]);
    }

    #[test]
    fn single_worker_matches_parallel() {
        let a = run_jobs(jobs(&[256, 512]), Some(1));
        let b = run_jobs(jobs(&[256, 512]), Some(8));
        assert_eq!(a.len(), b.len());
        for (ra, rb) in a.records.iter().zip(&b.records) {
            assert_eq!(ra.tflops_cell(), rb.tflops_cell());
        }
    }

    #[test]
    fn default_sizing_policy_runs_everything() {
        let table = run_jobs(jobs(&[256, 512]), None);
        assert_eq!(table.len(), 4);
    }

    #[test]
    fn empty_job_list_is_fine() {
        let table = run_jobs(vec![], Some(4));
        assert!(table.is_empty());
    }

    #[test]
    fn default_workers_is_positive() {
        assert!(default_workers() >= 1);
    }

    #[test]
    fn par_map_preserves_order_for_any_worker_count() {
        let items: Vec<usize> = (0..50).collect();
        let expect: Vec<usize> = items.iter().map(|i| i * i).collect();
        for workers in [Some(1), Some(3), Some(8), None] {
            assert_eq!(par_map(items.clone(), workers, |i| i * i), expect);
        }
        assert!(par_map(Vec::<usize>::new(), Some(4), |i: usize| i).is_empty());
    }

    #[test]
    fn budget_grants_are_bounded_and_returned() {
        let budget = ThreadBudget::new(4);
        assert_eq!((budget.total(), budget.available()), (4, 4));
        let a = budget.acquire(3); // takes 2 extra permits
        assert_eq!(a.workers(), 3);
        assert_eq!(budget.available(), 2);
        let b = budget.acquire(8); // only 2 permits left -> 3 workers
        assert_eq!(b.workers(), 3);
        assert_eq!(budget.available(), 0);
        let c = budget.acquire(5); // exhausted -> the calling thread only
        assert_eq!(c.workers(), 1);
        drop(b);
        drop(c);
        assert_eq!(budget.available(), 2);
        drop(a);
        assert_eq!(budget.available(), 4, "every permit returned");
    }

    #[test]
    fn budget_request_of_one_takes_no_permits() {
        let budget = ThreadBudget::new(2);
        let lease = budget.acquire(1);
        assert_eq!(lease.workers(), 1);
        assert_eq!(budget.available(), 2, "serial requests are free");
    }

    #[test]
    fn budget_never_blocks_even_at_zero() {
        let budget = ThreadBudget::new(1);
        let outer = budget.acquire(4);
        assert_eq!(outer.workers(), 1, "budget of 1 is the calling thread");
        let nested = budget.acquire(4);
        assert_eq!(nested.workers(), 1, "nested acquire degrades to serial");
    }

    #[test]
    fn global_budget_is_shared_and_positive() {
        let g = ThreadBudget::global();
        assert!(g.total() >= 1);
        assert!(std::ptr::eq(g, ThreadBudget::global()), "one global pool");
    }

    #[test]
    fn par_map_results_identical_under_exhausted_budget() {
        // drain the global budget, then fan out: the grant degrades to 1
        // worker but the output is bit-identical (determinism for any
        // worker count is the governor's contract)
        let items: Vec<usize> = (0..32).collect();
        let expect: Vec<usize> = items.iter().map(|i| i * 3).collect();
        let hog = ThreadBudget::global().acquire(usize::MAX - 1);
        assert!(hog.workers() >= 1);
        assert_eq!(par_map(items, Some(8), |i| i * 3), expect);
    }
}
