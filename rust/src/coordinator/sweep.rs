//! Workload sweep builders for the paper's figures.

use crate::planner::partition::MmShape;

/// One point of an aspect-ratio sweep.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SweepPoint {
    pub shape: MmShape,
    /// log2 of A's aspect ratio m/n: negative = right-skewed (wide A),
    /// 0 = squared, positive = left-skewed (tall A).
    pub log2_ratio: i32,
}

impl SweepPoint {
    pub fn label(&self) -> String {
        match self.log2_ratio.cmp(&0) {
            std::cmp::Ordering::Greater => format!("left 2^{}", self.log2_ratio),
            std::cmp::Ordering::Equal => "square".to_string(),
            std::cmp::Ordering::Less => format!("right 2^{}", -self.log2_ratio),
        }
    }
}

/// Fig. 4's squared-size axis: multiples of 256 from 256 to `max`.
pub fn squared_sizes(max: usize) -> Vec<usize> {
    (1..).map(|i| i * 256).take_while(|&s| s <= max).collect()
}

/// Fig. 5's aspect-ratio ladder: A is m x n with m*n = `mn_budget`
/// (a power of 4 keeps both dims integral) and m/n = 4^i for
/// i in [-half_steps, +half_steps]; B is n x k.
///
/// Paper: "different aspect ratios are used ... the two dimensions of A
/// are varied. Specifically, k is varied ... to keep the aspect ratios
/// but vary the data size."
pub fn aspect_ratio_ladder(mn_budget_log2: u32, half_steps: u32, k: usize) -> Vec<SweepPoint> {
    assert!(mn_budget_log2 % 2 == 0, "mn budget must be a power of 4");
    assert!(2 * half_steps < mn_budget_log2, "ratio exceeds budget");
    let half = (mn_budget_log2 / 2) as i32;
    let mut out = Vec::new();
    for i in -(half_steps as i32)..=(half_steps as i32) {
        // m = 2^(half + i), n = 2^(half - i) -> m*n = 2^budget, m/n = 4^i
        let m = 1usize << (half + i);
        let n = 1usize << (half - i);
        out.push(SweepPoint { shape: MmShape::new(m, n, k), log2_ratio: 2 * i });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn squared_sizes_are_256_multiples() {
        let s = squared_sizes(1024);
        assert_eq!(s, vec![256, 512, 768, 1024]);
    }

    #[test]
    fn ladder_conserves_mn_product() {
        let pts = aspect_ratio_ladder(22, 4, 2048);
        assert_eq!(pts.len(), 9);
        for p in &pts {
            assert_eq!(p.shape.m * p.shape.n, 1 << 22);
            assert_eq!(p.shape.k, 2048);
        }
    }

    #[test]
    fn ladder_is_symmetric_in_ratio() {
        let pts = aspect_ratio_ladder(22, 3, 1024);
        let first = pts.first().unwrap();
        let last = pts.last().unwrap();
        assert_eq!(first.shape.m, last.shape.n);
        assert_eq!(first.shape.n, last.shape.m);
        assert_eq!(first.log2_ratio, -last.log2_ratio);
    }

    #[test]
    fn center_is_square() {
        let pts = aspect_ratio_ladder(22, 2, 512);
        let mid = &pts[2];
        assert_eq!(mid.shape.m, mid.shape.n);
        assert_eq!(mid.label(), "square");
    }

    #[test]
    fn labels_name_skew_direction() {
        let pts = aspect_ratio_ladder(22, 1, 512);
        assert_eq!(pts[0].label(), "right 2^2");
        assert_eq!(pts[2].label(), "left 2^2");
    }

    #[test]
    #[should_panic(expected = "power of 4")]
    fn odd_budget_rejected() {
        aspect_ratio_ladder(21, 2, 512);
    }
}
