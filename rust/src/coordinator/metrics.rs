//! Metrics records and table emission.

use crate::coordinator::device::RunOutcome;
use crate::planner::partition::MmShape;
use crate::util::json::Json;
use crate::util::table::Table;

/// One (backend, shape) measurement.
#[derive(Clone, Debug)]
pub struct MetricsRecord {
    pub backend: String,
    pub label: String,
    pub shape: MmShape,
    pub outcome: RunOutcome,
}

impl MetricsRecord {
    pub fn tflops_cell(&self) -> String {
        match self.outcome.tflops() {
            Some(t) => format!("{t:.2}"),
            None => "OOM".to_string(),
        }
    }
}

/// Ordered collection with emitters.
#[derive(Clone, Debug, Default)]
pub struct MetricsTable {
    pub records: Vec<MetricsRecord>,
}

impl MetricsTable {
    pub fn push(&mut self, rec: MetricsRecord) {
        self.records.push(rec);
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Records for one backend, in insertion order.
    pub fn for_backend(&self, backend: &str) -> Vec<&MetricsRecord> {
        self.records.iter().filter(|r| r.backend == backend).collect()
    }

    pub fn backends(&self) -> Vec<String> {
        let mut names: Vec<String> = self.records.iter().map(|r| r.backend.clone()).collect();
        names.dedup();
        names.sort();
        names.dedup();
        names
    }

    /// Wide table: one row per label, one TFlop/s column per backend.
    pub fn to_table(&self, title: &str) -> Table {
        let backends = self.backends();
        let mut headers: Vec<&str> = vec!["shape"];
        let backend_headers: Vec<String> =
            backends.iter().map(|b| format!("{b} TFlop/s")).collect();
        headers.extend(backend_headers.iter().map(|s| s.as_str()));
        let mut table = Table::new(title, &headers);

        let mut labels: Vec<String> = Vec::new();
        for r in &self.records {
            if !labels.contains(&r.label) {
                labels.push(r.label.clone());
            }
        }
        for label in &labels {
            let mut cells = vec![label.clone()];
            for b in &backends {
                let cell = self
                    .records
                    .iter()
                    .find(|r| &r.label == label && &r.backend == b)
                    .map(|r| r.tflops_cell())
                    .unwrap_or_else(|| "-".to_string());
                cells.push(cell);
            }
            table.row(&cells);
        }
        table
    }

    pub fn to_csv(&self) -> String {
        let mut out =
            String::from("backend,label,m,n,k,seconds,tflops,efficiency,vertices,oom\n");
        for r in &self.records {
            match &r.outcome {
                RunOutcome::Ok { seconds, tflops, efficiency, vertices, .. } => {
                    out.push_str(&format!(
                        "{},{},{},{},{},{},{},{},{},false\n",
                        r.backend,
                        r.label,
                        r.shape.m,
                        r.shape.n,
                        r.shape.k,
                        seconds,
                        tflops,
                        efficiency,
                        vertices.map(|v| v.to_string()).unwrap_or_default()
                    ));
                }
                RunOutcome::OutOfMemory => {
                    out.push_str(&format!(
                        "{},{},{},{},{},,,,,true\n",
                        r.backend, r.label, r.shape.m, r.shape.n, r.shape.k
                    ));
                }
            }
        }
        out
    }

    pub fn to_json(&self) -> Json {
        let mut arr = Json::Arr(vec![]);
        for r in &self.records {
            let mut o = Json::obj();
            o.set("backend", r.backend.as_str().into());
            o.set("label", r.label.as_str().into());
            o.set("m", r.shape.m.into());
            o.set("n", r.shape.n.into());
            o.set("k", r.shape.k.into());
            match &r.outcome {
                RunOutcome::Ok { seconds, tflops, efficiency, vertices, max_tile_bytes } => {
                    o.set("seconds", (*seconds).into());
                    o.set("tflops", (*tflops).into());
                    o.set("efficiency", (*efficiency).into());
                    if let Some(v) = vertices {
                        o.set("vertices", (*v).into());
                    }
                    if let Some(b) = max_tile_bytes {
                        o.set("max_tile_bytes", (*b).into());
                    }
                    o.set("oom", false.into());
                }
                RunOutcome::OutOfMemory => {
                    o.set("oom", true.into());
                }
            }
            arr.push(o);
        }
        arr
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(backend: &str, label: &str, tflops: Option<f64>) -> MetricsRecord {
        MetricsRecord {
            backend: backend.to_string(),
            label: label.to_string(),
            shape: MmShape::square(64),
            outcome: match tflops {
                Some(t) => RunOutcome::Ok {
                    seconds: 1.0,
                    tflops: t,
                    efficiency: 0.5,
                    vertices: Some(100),
                    max_tile_bytes: None,
                },
                None => RunOutcome::OutOfMemory,
            },
        }
    }

    #[test]
    fn wide_table_pivots_backends() {
        let mut m = MetricsTable::default();
        m.push(rec("ipu", "1024", Some(30.0)));
        m.push(rec("gpu", "1024", Some(8.0)));
        m.push(rec("ipu", "4096", None));
        m.push(rec("gpu", "4096", Some(9.5)));
        let t = m.to_table("fig4");
        let ascii = t.to_ascii();
        assert!(ascii.contains("30.00"));
        assert!(ascii.contains("OOM"));
        assert_eq!(t.n_rows(), 2);
    }

    #[test]
    fn csv_includes_oom_flag() {
        let mut m = MetricsTable::default();
        m.push(rec("ipu", "x", None));
        let csv = m.to_csv();
        assert!(csv.contains(",true\n"));
        assert!(csv.starts_with("backend,"));
    }

    #[test]
    fn json_roundtrip_fields() {
        let mut m = MetricsTable::default();
        m.push(rec("ipu", "x", Some(12.0)));
        let json = m.to_json().render();
        assert!(json.contains("\"tflops\": 12"));
        assert!(json.contains("\"vertices\": 100"));
    }

    #[test]
    fn backend_listing_dedups() {
        let mut m = MetricsTable::default();
        m.push(rec("b", "1", Some(1.0)));
        m.push(rec("a", "1", Some(1.0)));
        m.push(rec("b", "2", Some(1.0)));
        assert_eq!(m.backends(), vec!["a".to_string(), "b".to_string()]);
        assert_eq!(m.for_backend("b").len(), 2);
    }
}
