//! Benchmark coordinator — the L3 orchestration layer.
//!
//! Owns the benchmark lifecycle: build job lists ([`sweep`]), fan them out
//! over a worker-thread pool ([`runner`] — the offline environment has no
//! tokio, so this is a std::thread scoped pool with mpsc channels),
//! collect [`metrics`] records, and emit tables/CSV/JSON. Simulation and
//! GPU-model jobs parallelize across workers; real PJRT jobs run on the
//! caller's thread (one PJRT client per process).

pub mod device;
pub mod metrics;
pub mod runner;
pub mod sweep;
pub mod trace;

pub use device::{Backend, RunOutcome};
pub use metrics::{MetricsRecord, MetricsTable};
pub use runner::{run_jobs, Job};
pub use sweep::{aspect_ratio_ladder, squared_sizes, SweepPoint};
