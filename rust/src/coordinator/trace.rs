//! Trace-driven workload study: a synthetic stream of matmul requests
//! with the paper's shape mix (squared + both skew directions, §2.4),
//! dispatched through the coordinator and summarized with the latency /
//! throughput statistics a serving system would report.
//!
//! This is the "real-world applications" lens of the paper's discussion
//! (§5.2: "skewed matrices are dominant in the field of AI and ML"):
//! rather than one shape at a time, how do the two devices compare over a
//! mixed stream?

use crate::arch::{GpuArch, IpuArch};
use crate::coordinator::device::{Backend, RunOutcome};
use crate::coordinator::metrics::{MetricsRecord, MetricsTable};
use crate::coordinator::runner::{run_jobs, Job};
use crate::planner::partition::MmShape;
use crate::util::rng::Rng;
use crate::util::stats::Summary;
use crate::util::table::Table;

/// Workload class mix (weights need not sum to anything particular).
#[derive(Clone, Debug)]
pub struct TraceSpec {
    pub jobs: Vec<(String, MmShape)>,
}

impl TraceSpec {
    /// The paper-motivated mix: 40% squared, 30% left-skewed (tall A),
    /// 30% right-skewed (wide A), sizes log-uniform within the GC200's
    /// fitting range.
    pub fn paper_mix(n_jobs: usize, seed: u64) -> TraceSpec {
        let mut rng = Rng::new(seed);
        let mut jobs = Vec::with_capacity(n_jobs);
        for i in 0..n_jobs {
            let class = rng.next_f64();
            let base = 1usize << rng.gen_usize(9, 11); // 512..2048
            let ratio = 1usize << rng.gen_usize(2, 4); // 4x..16x
            let k = 1usize << rng.gen_usize(8, 11); // 256..2048
            let (label, shape) = if class < 0.4 {
                ("squared", MmShape::new(base, base, k))
            } else if class < 0.7 {
                ("left", MmShape::new(base * ratio, base / ratio, k))
            } else {
                ("right", MmShape::new(base / ratio, base * ratio, k))
            };
            jobs.push((format!("{label}-{i}"), shape));
        }
        TraceSpec { jobs }
    }
}

/// Per-class latency/throughput summary for one backend.
#[derive(Clone, Debug)]
pub struct ClassStats {
    pub backend: String,
    pub class: String,
    pub count: usize,
    pub oom: usize,
    /// Model-predicted execution seconds per request.
    pub latency: Summary,
    pub mean_tflops: f64,
}

#[derive(Clone, Debug)]
pub struct TraceResult {
    pub metrics: MetricsTable,
    pub stats: Vec<ClassStats>,
}

fn class_of(label: &str) -> String {
    label.split('-').next().unwrap_or("?").to_string()
}

/// Run the trace on the IPU simulator and the GPU model.
pub fn run_trace(
    ipu: &IpuArch,
    gpu: &GpuArch,
    spec: &TraceSpec,
    workers: Option<usize>,
) -> TraceResult {
    let mut jobs = Vec::new();
    for (label, shape) in &spec.jobs {
        jobs.push(Job::new(Backend::IpuSim(ipu.clone()), label.clone(), *shape));
        jobs.push(Job::new(Backend::GpuModel(gpu.clone()), label.clone(), *shape));
    }
    let metrics = run_jobs(jobs, workers);

    let mut stats = Vec::new();
    for backend in metrics.backends() {
        let mut classes: Vec<String> = metrics
            .for_backend(&backend)
            .iter()
            .map(|r| class_of(&r.label))
            .collect();
        classes.sort();
        classes.dedup();
        for class in classes {
            let recs: Vec<&MetricsRecord> = metrics
                .for_backend(&backend)
                .into_iter()
                .filter(|r| class_of(&r.label) == class)
                .collect();
            let lat: Vec<f64> = recs
                .iter()
                .filter_map(|r| match &r.outcome {
                    RunOutcome::Ok { seconds, .. } => Some(*seconds),
                    RunOutcome::OutOfMemory => None,
                })
                .collect();
            let tfs: Vec<f64> = recs.iter().filter_map(|r| r.outcome.tflops()).collect();
            if lat.is_empty() {
                continue;
            }
            stats.push(ClassStats {
                backend: backend.clone(),
                class,
                count: recs.len(),
                oom: recs.iter().filter(|r| r.outcome.is_oom()).count(),
                latency: Summary::of(&lat),
                mean_tflops: tfs.iter().sum::<f64>() / tfs.len() as f64,
            });
        }
    }
    TraceResult { metrics, stats }
}

impl TraceResult {
    pub fn to_table(&self) -> Table {
        let mut t = Table::new(
            "Trace-driven study: per-class request latency (model time) and throughput",
            &["backend", "class", "n", "oom", "p50", "p95", "mean TFlop/s"],
        );
        for s in &self.stats {
            t.row(&[
                s.backend.clone(),
                s.class.clone(),
                s.count.to_string(),
                s.oom.to_string(),
                format!("{:.3} ms", s.latency.median * 1e3),
                format!("{:.3} ms", s.latency.p95 * 1e3),
                format!("{:.2}", s.mean_tflops),
            ]);
        }
        t
    }

    pub fn to_csv(&self) -> String {
        self.metrics.to_csv()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_trace() -> TraceResult {
        let spec = TraceSpec::paper_mix(60, 7);
        run_trace(&IpuArch::gc200(), &GpuArch::a30(), &spec, Some(4))
    }

    #[test]
    fn mix_has_all_three_classes() {
        let spec = TraceSpec::paper_mix(100, 1);
        for class in ["squared", "left", "right"] {
            assert!(
                spec.jobs.iter().any(|(l, _)| l.starts_with(class)),
                "missing class {class}"
            );
        }
        // deterministic for a seed
        let again = TraceSpec::paper_mix(100, 1);
        assert_eq!(spec.jobs.len(), again.jobs.len());
        assert!(spec
            .jobs
            .iter()
            .zip(&again.jobs)
            .all(|(a, b)| a.0 == b.0 && a.1 == b.1));
    }

    #[test]
    fn stats_cover_both_backends() {
        let r = small_trace();
        let backends: Vec<&str> = r.stats.iter().map(|s| s.backend.as_str()).collect();
        assert!(backends.iter().any(|b| b.contains("ipu")));
        assert!(backends.iter().any(|b| b.contains("gpu")));
    }

    #[test]
    fn ipu_wins_every_class_in_the_fitting_mix(){
        let r = small_trace();
        for class in ["squared", "left", "right"] {
            let get = |pat: &str| {
                r.stats
                    .iter()
                    .find(|s| s.backend.contains(pat) && s.class == class)
                    .map(|s| s.mean_tflops)
                    .unwrap()
            };
            assert!(
                get("ipu") > get("gpu"),
                "{class}: IPU should win the mixed trace"
            );
        }
    }

    #[test]
    fn latency_percentiles_ordered() {
        let r = small_trace();
        for s in &r.stats {
            assert!(s.latency.p95 >= s.latency.median);
            assert!(s.latency.min <= s.latency.median);
        }
    }

    #[test]
    fn table_and_csv_render() {
        let r = small_trace();
        assert!(r.to_table().n_rows() >= 4);
        assert!(r.to_csv().starts_with("backend,"));
    }
}
