//! M1 — paper §2.4 memory analysis: the largest squared MM per IPU
//! generation, its tensor footprint, and the fraction of In-Processor
//! memory that is actual tensor data vs. overhead.
//!
//! Paper anchors: GC2 max 2944^2 (104 MB = 35% of ~300 MB SRAM);
//! GC200 max 3584^2 (154 MB = 17% of 918 MB SRAM). The binding constraint
//! is the *overhead* (exchange code, chunk buffers), not tensor bytes.

use crate::arch::IpuArch;
use crate::coordinator::runner::par_map;
use crate::planner::partition::MmShape;
use crate::planner::search::{max_fitting_square, search};
use crate::util::table::Table;

#[derive(Clone, Debug)]
pub struct MemoryRow {
    pub arch_name: String,
    pub max_square: usize,
    pub paper_max_square: usize,
    pub tensor_mb: f64,
    pub sram_mb: f64,
    pub tensor_fraction: f64,
    /// Heaviest-tile occupancy at the max square (the binding constraint).
    pub max_tile_fraction: f64,
    pub tflops_at_max: f64,
    pub peak_fraction: f64,
}

/// One row per architecture. §Perf: the per-arch walls bisect over the
/// fits-only probe (see `planner::search::max_fitting_square`) and the
/// rows are planned in parallel through the shared `run_jobs`/`par_map`
/// worker policy (`workers: None` = `default_workers`; results stay in
/// `archs` order for any count).
pub fn run(archs: &[(IpuArch, usize)], workers: Option<usize>) -> Vec<MemoryRow> {
    par_map(archs.to_vec(), workers, |(arch, paper_max)| {
        let max_square = max_fitting_square(&arch, 128, 8192);
        let shape = MmShape::square(max_square);
        let plan = search(&arch, shape).expect("max square must fit");
        let tensor_mb = shape.tensor_bytes() as f64 / 1e6;
        let sram_mb = arch.total_sram_bytes() as f64 / 1e6;
        MemoryRow {
            arch_name: arch.name.to_string(),
            max_square,
            paper_max_square: paper_max,
            tensor_mb,
            sram_mb,
            tensor_fraction: tensor_mb / sram_mb,
            max_tile_fraction: plan.cost.tile_bytes_total as f64
                / arch.tile_sram_bytes as f64,
            tflops_at_max: plan.tflops(&arch),
            peak_fraction: plan.tflops(&arch) / arch.peak_fp32_tflops(),
        }
    })
}

pub fn default_archs() -> Vec<(IpuArch, usize)> {
    vec![
        (IpuArch::gc200(), crate::arch::ipu::paper::GC200_MAX_SQUARE),
        (IpuArch::gc2(), crate::arch::ipu::paper::GC2_MAX_SQUARE),
    ]
}

pub fn to_table(rows: &[MemoryRow]) -> Table {
    let mut t = Table::new(
        "Memory study (paper §2.4: GC200 3584^2 = 154 MB = 17%; GC2 2944^2 = 104 MB = 35%)",
        &[
            "arch", "max square", "paper", "tensors MB", "SRAM MB",
            "tensor %", "max-tile %", "TFlop/s", "of peak",
        ],
    );
    for r in rows {
        t.row(&[
            r.arch_name.clone(),
            r.max_square.to_string(),
            r.paper_max_square.to_string(),
            format!("{:.1}", r.tensor_mb),
            format!("{:.0}", r.sram_mb),
            format!("{:.1}%", r.tensor_fraction * 100.0),
            format!("{:.1}%", r.max_tile_fraction * 100.0),
            format!("{:.2}", r.tflops_at_max),
            format!("{:.1}%", r.peak_fraction * 100.0),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gc200_wall_matches_paper() {
        let rows = run(&[(IpuArch::gc200(), 3584)], Some(1));
        let r = &rows[0];
        // paper: 3584; accept one 128-step of slack
        assert!(
            (3456..=3712).contains(&r.max_square),
            "GC200 max square {}",
            r.max_square
        );
        // paper: 154 MB = 17% of SRAM (tensor bytes are NOT the constraint)
        assert!(r.tensor_fraction < 0.30, "tensor fraction {}", r.tensor_fraction);
        // the heaviest tile is nearly full — that's the real wall
        assert!(r.max_tile_fraction > 0.85, "max tile {}", r.max_tile_fraction);
        // paper: 44.2 / 62.5 = 70.7% at the wall
        assert!((0.55..=0.85).contains(&r.peak_fraction), "{}", r.peak_fraction);
    }

    #[test]
    fn gc2_wall_matches_jia() {
        let rows = run(&[(IpuArch::gc2(), 2944)], Some(1));
        let r = &rows[0];
        // paper/Jia: 2944 at 60.7% of 31.1 TFlop/s
        assert!(
            (2688..=3200).contains(&r.max_square),
            "GC2 max square {}",
            r.max_square
        );
        assert!((0.45..=0.75).contains(&r.peak_fraction), "{}", r.peak_fraction);
        // GC2's tensor fraction is higher than GC200's (35% vs 17%)
        let gc200 = &run(&[(IpuArch::gc200(), 3584)], Some(1))[0];
        assert!(r.tensor_fraction > gc200.tensor_fraction);
    }

    #[test]
    fn table_renders() {
        let t = to_table(&run(&default_archs(), Some(2)));
        assert_eq!(t.n_rows(), 2);
        assert!(t.to_ascii().contains("GC200"));
    }
}
