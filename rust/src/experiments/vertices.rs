//! V1 — paper §5.1 vertex census: 5542 (left) / 5762 (squared) / 31743
//! (right) "for a given k".
//!
//! Our census triple uses m*n = 2^23 at k = 2048: tall A (16384 x 512),
//! near-square A (2896 x 2896), wide A (512 x 16384). The right-skewed
//! shape forces the planner to split the reduction (the unsplit plan's
//! per-superstep exchange code overflows tile memory), and the reduction
//! stage's worklist vertices produce the explosion.

use crate::arch::ipu::paper;
use crate::arch::IpuArch;
use crate::planner::partition::MmShape;
use crate::sim::engine::SimEngine;
use crate::util::table::Table;

#[derive(Clone, Debug)]
pub struct CensusRow {
    pub name: &'static str,
    pub shape: MmShape,
    pub vertices: usize,
    pub reduce_vertices: usize,
    pub pn: usize,
    pub paper_vertices: usize,
    pub tflops: f64,
}

/// The census shapes (k fixed at 2048, m*n = 2^23).
pub fn census_shapes() -> [(&'static str, MmShape, usize); 3] {
    [
        ("left-skewed", MmShape::new(16384, 512, 2048), paper::VERTICES_LEFT),
        ("squared", MmShape::new(2896, 2896, 2048), paper::VERTICES_SQUARED),
        ("right-skewed", MmShape::new(512, 16384, 2048), paper::VERTICES_RIGHT),
    ]
}

pub fn run(arch: &IpuArch) -> Vec<CensusRow> {
    let engine = SimEngine::new(arch.clone());
    census_shapes()
        .into_iter()
        .map(|(name, shape, paper_vertices)| {
            let r = engine
                .simulate_mm(shape)
                .expect("census shapes must fit the GC200");
            CensusRow {
                name,
                shape,
                vertices: r.total_vertices,
                reduce_vertices: r.plan.cost.reduce_vertices,
                pn: r.plan.partition().pn,
                paper_vertices,
                tflops: r.tflops,
            }
        })
        .collect()
}

pub fn to_table(rows: &[CensusRow]) -> Table {
    let mut t = Table::new(
        "Vertex census (paper §5.1: 5542 / 5762 / 31743)",
        &["experiment", "A shape", "pn", "vertices", "reduce", "paper", "TFlop/s"],
    );
    for r in rows {
        t.row(&[
            r.name.to_string(),
            format!("{}x{}", r.shape.m, r.shape.n),
            r.pn.to_string(),
            r.vertices.to_string(),
            r.reduce_vertices.to_string(),
            r.paper_vertices.to_string(),
            format!("{:.2}", r.tflops),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn census_reproduces_paper_pattern() {
        let rows = run(&IpuArch::gc200());
        let (left, squared, right) = (&rows[0], &rows[1], &rows[2]);

        // squared: ~4 vertices/tile, within 10% of the paper's 5762
        let err = (squared.vertices as f64 - 5762.0).abs() / 5762.0;
        assert!(err < 0.10, "squared census {} vs 5762", squared.vertices);

        // left is close to squared (paper: 5542 vs 5762)
        let left_ratio = left.vertices as f64 / squared.vertices as f64;
        assert!((0.85..=1.1).contains(&left_ratio), "left ratio {left_ratio}");

        // right explodes: paper ratio 31743 / 5762 = 5.5x
        let right_ratio = right.vertices as f64 / squared.vertices as f64;
        assert!((3.5..=8.0).contains(&right_ratio), "right ratio {right_ratio}");
        assert!(right.pn > 1);
        assert!(right.reduce_vertices > right.vertices / 2);

        // and the explosion costs performance (Finding 2)
        assert!(right.tflops < 0.85 * squared.tflops);
    }

    #[test]
    fn table_lists_three_experiments() {
        let rows = run(&IpuArch::gc200());
        let t = to_table(&rows);
        assert_eq!(t.n_rows(), 3);
        let ascii = t.to_ascii();
        assert!(ascii.contains("left-skewed"));
        assert!(ascii.contains("31743"));
    }
}
