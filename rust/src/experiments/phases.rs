//! P1 — paper Fig. 3: BSP phase structure (compute / sync / exchange) of
//! a matmul as the PopVision timeline shows it.

use crate::arch::IpuArch;
use crate::planner::partition::MmShape;
use crate::profiler::popvision::PopVisionReport;
use crate::sim::engine::SimEngine;
use crate::sim::report::SimReport;
use crate::util::table::Table;

#[derive(Clone, Debug)]
pub struct PhaseRow {
    pub label: String,
    pub compute: f64,
    pub sync: f64,
    pub exchange: f64,
    pub supersteps: usize,
    pub tile_utilization: f64,
}

/// Profile the paper's flagship shape plus a small and a skewed one.
pub fn default_shapes() -> Vec<(String, MmShape)> {
    vec![
        ("squared 3584".to_string(), MmShape::square(3584)),
        ("squared 1024".to_string(), MmShape::square(1024)),
        ("right-skewed".to_string(), MmShape::new(512, 16384, 2048)),
    ]
}

pub fn run(arch: &IpuArch, shapes: &[(String, MmShape)]) -> Vec<(PhaseRow, SimReport)> {
    let engine = SimEngine::new(arch.clone());
    shapes
        .iter()
        .map(|(label, shape)| {
            let r = engine.simulate_mm(*shape).expect("phase shapes must fit");
            let (c, s, e) = r.trace.phase_fractions();
            (
                PhaseRow {
                    label: label.clone(),
                    compute: c,
                    sync: s,
                    exchange: e,
                    supersteps: r.trace.superstep_count(),
                    tile_utilization: r.trace.tile_utilization(),
                },
                r,
            )
        })
        .collect()
}

pub fn to_table(rows: &[(PhaseRow, SimReport)]) -> Table {
    let mut t = Table::new(
        "BSP phase breakdown (paper Fig. 3: compute red / sync blue / exchange yellow)",
        &["shape", "compute", "sync", "exchange", "supersteps", "tile util"],
    );
    for (r, _) in rows {
        t.row(&[
            r.label.clone(),
            format!("{:.1}%", r.compute * 100.0),
            format!("{:.1}%", r.sync * 100.0),
            format!("{:.1}%", r.exchange * 100.0),
            r.supersteps.to_string(),
            format!("{:.1}%", r.tile_utilization * 100.0),
        ]);
    }
    t
}

/// Full-text profile (timeline bar + census + memory) of one shape.
pub fn profile_text(arch: &IpuArch, shape: MmShape) -> String {
    let engine = SimEngine::new(arch.clone());
    match engine.simulate_mm(shape) {
        Ok(r) => PopVisionReport::new(&r).to_text(),
        Err(e) => format!("planner: {e}\n"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phases_partition_unity() {
        let rows = run(&IpuArch::gc200(), &default_shapes());
        for (r, _) in &rows {
            let total = r.compute + r.sync + r.exchange;
            assert!((total - 1.0).abs() < 1e-9, "{}: {total}", r.label);
        }
    }

    #[test]
    fn compute_dominates_large_squared() {
        let rows = run(&IpuArch::gc200(), &default_shapes());
        let squared = &rows[0].0;
        assert!(squared.compute > 0.5, "compute {}", squared.compute);
        assert!(squared.exchange > 0.05, "exchange {}", squared.exchange);
    }

    #[test]
    fn skewed_shifts_cycles_to_exchange() {
        let rows = run(&IpuArch::gc200(), &default_shapes());
        let squared = &rows[0].0;
        let skewed = &rows[2].0;
        assert!(
            skewed.exchange > squared.exchange,
            "skewed exchange {} vs squared {}",
            skewed.exchange,
            squared.exchange
        );
    }

    #[test]
    fn profile_text_is_complete() {
        let text = profile_text(&IpuArch::gc200(), MmShape::square(1024));
        assert!(text.contains("compute"));
        assert!(text.contains("vertex census"));
    }

    #[test]
    fn table_renders() {
        let rows = run(&IpuArch::gc200(), &default_shapes());
        assert_eq!(to_table(&rows).n_rows(), 3);
    }
}
