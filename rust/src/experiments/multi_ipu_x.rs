//! X2 — §6 future work: scaling to the M2000's four GC200s.

use crate::arch::IpuArch;
use crate::multi_ipu::{MultiIpu, MultiIpuReport};
use crate::planner::partition::MmShape;
use crate::util::table::Table;

#[derive(Clone, Debug)]
pub struct ScalingRow {
    pub chips: usize,
    pub report: Option<MultiIpuReport>,
    pub max_square: usize,
}

/// Scaling study at a fixed shape + capacity study per chip count.
pub fn run(arch: &IpuArch, shape: MmShape, chip_counts: &[usize]) -> Vec<ScalingRow> {
    chip_counts
        .iter()
        .map(|&chips| {
            let pod = MultiIpu::new(arch.clone(), chips);
            ScalingRow {
                chips,
                report: pod.simulate_mm(shape).ok(),
                max_square: pod.max_fitting_square(256, 16384),
            }
        })
        .collect()
}

pub fn to_table(rows: &[ScalingRow], shape: MmShape) -> Table {
    let mut t = Table::new(
        &format!(
            "Multi-IPU scaling (§6) at {}x{}x{} (M2000 pod, IPU-Link)",
            shape.m, shape.n, shape.k
        ),
        &["chips", "TFlop/s", "speedup", "link time", "max square"],
    );
    let base = rows
        .first()
        .and_then(|r| r.report.as_ref())
        .map(|r| r.tflops)
        .unwrap_or(1.0);
    for r in rows {
        match &r.report {
            Some(rep) => t.row(&[
                r.chips.to_string(),
                format!("{:.2}", rep.tflops),
                format!("{:.2}x", rep.tflops / base),
                format!("{:.1}%", rep.link_fraction * 100.0),
                r.max_square.to_string(),
            ]),
            None => t.row(&[
                r.chips.to_string(),
                "OOM".to_string(),
                "-".to_string(),
                "-".to_string(),
                r.max_square.to_string(),
            ]),
        };
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pod_scales_throughput_and_capacity() {
        let rows = run(&IpuArch::gc200(), MmShape::square(3584), &[1, 2, 4]);
        let t1 = rows[0].report.as_ref().unwrap().tflops;
        let t4 = rows[2].report.as_ref().unwrap().tflops;
        assert!(t4 > 1.5 * t1, "4-chip {t4} vs 1-chip {t1}");
        assert!(rows[2].max_square > rows[0].max_square);
    }

    #[test]
    fn speedup_is_sublinear_due_to_link() {
        let rows = run(&IpuArch::gc200(), MmShape::square(3584), &[1, 4]);
        let t1 = rows[0].report.as_ref().unwrap().tflops;
        let r4 = rows[1].report.as_ref().unwrap();
        assert!(r4.tflops / t1 < 4.0);
        assert!(r4.link_fraction > 0.0);
    }

    #[test]
    fn table_renders() {
        let rows = run(&IpuArch::gc200(), MmShape::square(2048), &[1, 2]);
        assert_eq!(to_table(&rows, MmShape::square(2048)).n_rows(), 2);
    }
}
