//! E2E — the end-to-end validation driver (DESIGN.md §4).
//!
//! Proves the three layers compose on a real workload: a trace of matrix
//! multiplications (squared + both skew directions, the paper's §2.4
//! workload) where every shape is
//!
//! 1. **actually computed** on the PJRT CPU client through the AOT
//!    JAX/Pallas block artifact and verified bit-for-bit against the
//!    in-tree oracle (the real compute path),
//! 2. priced on the calibrated GC200 simulator,
//! 3. priced on the A30 cuBLAS model,
//!
//! and the headline metric — who wins, by what factor, per skew class —
//! is reported in the paper's own terms.

use std::path::Path;

use anyhow::{Context, Result};

use crate::arch::{GpuArch, IpuArch};
use crate::coordinator::device::{run_shape, Backend};
use crate::planner::partition::MmShape;
use crate::runtime::blockmm::BlockMmExecutor;
use crate::util::matrix::Matrix;
use crate::util::stats::geomean;
use crate::util::table::Table;

#[derive(Clone, Debug)]
pub struct E2eRow {
    pub label: String,
    pub shape: MmShape,
    /// Real PJRT execution: wall seconds, block calls, max |err| vs oracle.
    pub real_seconds: f64,
    pub real_block_calls: u64,
    pub real_max_err: f32,
    /// Simulated GC200 TFlop/s (None = OOM wall).
    pub ipu_tflops: Option<f64>,
    /// Modelled A30 TFlop/s.
    pub gpu_tflops: f64,
}

#[derive(Clone, Debug)]
pub struct E2eResult {
    pub rows: Vec<E2eRow>,
    /// Geometric-mean IPU/GPU speedup over shapes that fit the IPU.
    pub geomean_speedup: f64,
    pub total_real_seconds: f64,
    pub total_block_calls: u64,
}

/// The default workload trace: small enough that the real path verifies
/// in seconds, shaped like the paper's experiment mix.
pub fn default_trace() -> Vec<(String, MmShape)> {
    vec![
        ("squared-256".into(), MmShape::square(256)),
        ("squared-384".into(), MmShape::square(384)),
        ("squared-512".into(), MmShape::square(512)),
        ("left-skew-4x".into(), MmShape::new(1024, 256, 256)),
        ("left-skew-16x".into(), MmShape::new(2048, 128, 256)),
        ("right-skew-4x".into(), MmShape::new(256, 1024, 256)),
        ("right-skew-16x".into(), MmShape::new(128, 2048, 256)),
        ("ragged".into(), MmShape::new(300, 177, 421)),
    ]
}

/// Run the driver. `artifacts_dir` must contain `make artifacts` output.
pub fn run(
    artifacts_dir: &Path,
    trace: &[(String, MmShape)],
    block_cap: usize,
) -> Result<E2eResult> {
    let mut executor = BlockMmExecutor::load(artifacts_dir, block_cap)
        .context("loading AOT artifacts (run `make artifacts`)")?;
    let ipu = Backend::IpuSim(IpuArch::gc200());
    let gpu = Backend::GpuModel(GpuArch::a30());

    let mut rows = Vec::new();
    for (idx, (label, shape)) in trace.iter().enumerate() {
        // real numerics, verified against the oracle
        let a = Matrix::random(shape.m, shape.n, 2 * idx as u64 + 1);
        let b = Matrix::random(shape.n, shape.k, 2 * idx as u64 + 2);
        let (_c, stats, err) = executor
            .mm_verified(&a, &b)
            .with_context(|| format!("real compute path failed for {label}"))?;

        let ipu_out = run_shape(&ipu, *shape);
        let gpu_out = run_shape(&gpu, *shape);
        rows.push(E2eRow {
            label: label.clone(),
            shape: *shape,
            real_seconds: stats.seconds,
            real_block_calls: stats.block_calls,
            real_max_err: err,
            ipu_tflops: ipu_out.tflops(),
            gpu_tflops: gpu_out.tflops().expect("A30 fits every trace shape"),
        });
    }

    let speedups: Vec<f64> = rows
        .iter()
        .filter_map(|r| r.ipu_tflops.map(|i| i / r.gpu_tflops))
        .collect();
    Ok(E2eResult {
        geomean_speedup: if speedups.is_empty() { 0.0 } else { geomean(&speedups) },
        total_real_seconds: rows.iter().map(|r| r.real_seconds).sum(),
        total_block_calls: rows.iter().map(|r| r.real_block_calls).sum(),
        rows,
    })
}

pub fn to_table(result: &E2eResult) -> Table {
    let mut t = Table::new(
        "End-to-end validation: real PJRT numerics + simulated devices",
        &[
            "workload", "shape", "real time", "blocks", "max|err|",
            "IPU TFlop/s", "A30 TFlop/s", "IPU/GPU",
        ],
    );
    for r in &result.rows {
        t.row(&[
            r.label.clone(),
            format!("{}x{}x{}", r.shape.m, r.shape.n, r.shape.k),
            format!("{:.3}s", r.real_seconds),
            r.real_block_calls.to_string(),
            format!("{:.1e}", r.real_max_err),
            r.ipu_tflops
                .map(|t| format!("{t:.2}"))
                .unwrap_or_else(|| "OOM".into()),
            format!("{:.2}", r.gpu_tflops),
            r.ipu_tflops
                .map(|t| format!("{:.1}x", t / r.gpu_tflops))
                .unwrap_or_else(|| "-".into()),
        ]);
    }
    t.row(&[
        "geomean".into(),
        "-".into(),
        format!("{:.3}s", result.total_real_seconds),
        result.total_block_calls.to_string(),
        "-".into(),
        "-".into(),
        "-".into(),
        format!("{:.1}x", result.geomean_speedup),
    ]);
    t
}

// Integration coverage lives in rust/tests/integration_runtime.rs (needs
// `make artifacts`); the trace builder is testable standalone:
#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_trace_covers_all_skew_classes() {
        let trace = default_trace();
        assert!(trace.len() >= 6);
        assert!(trace.iter().any(|(_, s)| s.m == s.n));
        assert!(trace.iter().any(|(_, s)| s.m > 4 * s.n)); // left
        assert!(trace.iter().any(|(_, s)| s.n > 4 * s.m)); // right
        // ragged (non-multiple-of-block) shape exercises the padding path
        assert!(trace.iter().any(|(_, s)| s.m % 64 != 0));
    }

    #[test]
    fn trace_shapes_fit_the_simulated_gc200() {
        for (label, shape) in default_trace() {
            let out = run_shape(&Backend::IpuSim(IpuArch::gc200()), shape);
            assert!(!out.is_oom(), "{label} should fit the GC200");
        }
    }
}
