//! S1 — density x aspect-ratio sweep: the paper's skew axis (Fig. 5)
//! crossed with PopSparse's density axis.
//!
//! Neither source paper answers this alone: the dense paper shows where
//! the IPU's skew advantage lives, PopSparse shows block-sparse matmul
//! works on the same hardware — this sweep asks **where the skew
//! advantage survives under sparsity**. Every point reports both
//! throughput conventions (Domke et al.): dense-equivalent TFlop/s
//! (what a dense replacement would need) and effective TFlop/s (nonzero
//! work only). At density 1.0 the squared points reproduce the dense
//! Fig. 4 path exactly. Every row also carries the **predicted memory
//! wall** for its density (`sparse_max_fitting_square`): with the
//! CSR-aware bill the §2.4 wall is a density curve, and ladder points
//! past the dense wall plan through the sparse fallback instead of
//! reporting a blanket OOM.

use crate::arch::IpuArch;
use crate::coordinator::runner::par_map;
use crate::coordinator::sweep::aspect_ratio_ladder;
use crate::planner::cost::CostConfig;
use crate::planner::partition::MmShape;
use crate::planner::search::search;
use crate::sim::engine::SimEngine;
use crate::sparse::pattern::{BlockPattern, PatternKind, SparsitySpec};
use crate::sparse::planner::{
    sparse_max_fitting_square, sparse_plan_from_dense, sparse_search_past_dense_wall,
};
use crate::util::table::Table;

/// Resolution of the per-density predicted-wall bisection (the paper
/// plots the dense §2.4 wall at the same 128 step).
pub const WALL_STEP: usize = 128;
/// Upper bound of the wall bisection — comfortably above every modeled
/// density's wall on the paper architectures.
pub const WALL_LIMIT: usize = 6144;

/// One (aspect ratio, density) grid point.
#[derive(Clone, Debug)]
pub struct SparseSweepRow {
    /// Sweep-point label (`square`, `left 2^4`, ...).
    pub label: String,
    pub shape: MmShape,
    pub spec: SparsitySpec,
    /// Nonzero-block fraction the generator realized.
    pub realized_density: f64,
    /// Densest partition-cell density (the planner's scaling bottleneck).
    pub critical_density: f64,
    /// `None` = past this density's memory wall (with the CSR-aware bill
    /// the wall is density-dependent; dense OOM no longer implies sparse
    /// OOM).
    pub dense_equiv_tflops: Option<f64>,
    pub effective_tflops: Option<f64>,
    /// Runtime ratio vs the dense plan of the same shape; `None` past
    /// the dense wall (no dense baseline exists there).
    pub speedup_vs_dense: Option<f64>,
    /// Predicted max fitting square at this row's density on this arch
    /// (`sparse_max_fitting_square`, step [`WALL_STEP`] up to
    /// [`WALL_LIMIT`]) — the paper's §2.4 statistic as a density curve.
    pub predicted_max_square: usize,
}

/// The density axis of the default grid.
pub fn default_densities() -> Vec<f64> {
    vec![1.0, 0.5, 0.25, 0.1]
}

/// Run the grid: the Fig. 5 ladder (m*n = 2^`mn_budget_log2`, ratios
/// 4^i for |i| <= `half_steps`) at fixed `k`, crossed with `densities`,
/// end-to-end on the simulator (graph build + BSP trace per point).
///
/// §Perf: ladder points are independent, so they plan/build/simulate in
/// parallel through the shared `run_jobs`/`par_map` worker policy
/// (`workers: None` = `default_workers`; rows stay in ladder x density
/// order for any worker count).
#[allow(clippy::too_many_arguments)]
pub fn run(
    arch: &IpuArch,
    mn_budget_log2: u32,
    half_steps: u32,
    k: usize,
    block: usize,
    densities: &[f64],
    kind: PatternKind,
    seed: u64,
    workers: Option<usize>,
) -> Vec<SparseSweepRow> {
    let engine = SimEngine::new(arch.clone());
    // the predicted wall depends only on (arch, spec): bisect once per
    // density, fanned through the same worker policy as the ladder
    // (each bisection is several full-space admission scans)
    let walls: Vec<usize> = par_map(densities.to_vec(), workers, |density| {
        let spec = SparsitySpec::new(kind, block, density, seed);
        sparse_max_fitting_square(arch, spec, WALL_STEP, WALL_LIMIT)
    });
    let point_rows = par_map(
        aspect_ratio_ladder(mn_budget_log2, half_steps, k),
        workers,
        |point| {
            // one dense search per ladder point: the dense winner (and the
            // OOM verdict) depend only on the shape, so every density on
            // this point amortizes the same expensive search
            let dense = search(arch, point.shape).ok();
            let mut rows = Vec::with_capacity(densities.len());
            for (di, &density) in densities.iter().enumerate() {
                let spec = SparsitySpec::new(kind, block, density, seed);
                let pattern = BlockPattern::for_shape(spec, point.shape);
                let plan = match &dense {
                    Some(dense_plan) => Some(sparse_plan_from_dense(
                        arch,
                        point.shape,
                        &pattern,
                        CostConfig::default(),
                        dense_plan.clone(),
                    )),
                    // past the dense wall the CSR-aware bill may still
                    // admit a plan at this density; the dense OOM verdict
                    // is already known, so skip straight to the sparse
                    // full-space search (fully dense specs keep the OOM)
                    None if spec.is_dense() => None,
                    None => {
                        sparse_search_past_dense_wall(
                            arch,
                            point.shape,
                            &pattern,
                            CostConfig::default(),
                        )
                        .ok()
                    }
                };
                let row = match plan {
                    Some(plan) => {
                        let report = engine.simulate_sparse_plan(point.shape, plan, &pattern);
                        SparseSweepRow {
                            label: point.label(),
                            shape: point.shape,
                            spec,
                            realized_density: report.plan.realized_density,
                            critical_density: report.plan.cost.critical_density,
                            dense_equiv_tflops: Some(report.dense_equiv_tflops),
                            effective_tflops: Some(report.effective_tflops),
                            speedup_vs_dense: report.plan.speedup_vs_dense(),
                            predicted_max_square: walls[di],
                        }
                    }
                    None => SparseSweepRow {
                        label: point.label(),
                        shape: point.shape,
                        spec,
                        realized_density: density,
                        critical_density: 0.0,
                        dense_equiv_tflops: None,
                        effective_tflops: None,
                        speedup_vs_dense: None,
                        predicted_max_square: walls[di],
                    },
                };
                rows.push(row);
            }
            rows
        },
    );
    point_rows.into_iter().flatten().collect()
}

/// Best effective TFlop/s at one density across the whole ladder —
/// the "does skew survive" headline per density.
pub fn best_effective_at(rows: &[SparseSweepRow], density_permille: u32) -> Option<(String, f64)> {
    rows.iter()
        .filter(|r| r.spec.density_permille == density_permille)
        .filter_map(|r| r.effective_tflops.map(|t| (r.label.clone(), t)))
        .max_by(|a, b| a.1.partial_cmp(&b.1).expect("finite tflops"))
}

pub fn to_table(rows: &[SparseSweepRow]) -> Table {
    let mut t = Table::new(
        "S1 — block-sparse MM: density x aspect ratio (dense-equivalent vs effective TFlop/s)",
        &[
            "shape", "A", "density", "crit", "dense-equiv", "effective", "vs dense",
        ],
    );
    for r in rows {
        let fmt = |v: Option<f64>, suffix: &str| match v {
            Some(v) => format!("{v:.2}{suffix}"),
            None => "OOM".to_string(),
        };
        t.row(&[
            r.label.clone(),
            format!("{}x{}", r.shape.m, r.shape.n),
            format!("{:.2}", r.realized_density),
            format!("{:.2}", r.critical_density),
            fmt(r.dense_equiv_tflops, ""),
            fmt(r.effective_tflops, ""),
            fmt(r.speedup_vs_dense, "x"),
        ]);
    }
    t
}

/// CSV twin of the table for downstream plotting. The
/// `predicted_max_square` column is the per-density memory wall
/// (constant across ladder points of one density).
pub fn to_csv(rows: &[SparseSweepRow]) -> String {
    let mut out = String::from(
        "label,m,n,k,kind,block,density,realized_density,critical_density,\
         dense_equiv_tflops,effective_tflops,speedup_vs_dense,predicted_max_square\n",
    );
    for r in rows {
        let opt = |v: Option<f64>| v.map(|x| x.to_string()).unwrap_or_default();
        out.push_str(&format!(
            "{},{},{},{},{},{},{},{},{},{},{},{},{}\n",
            r.label,
            r.shape.m,
            r.shape.n,
            r.shape.k,
            r.spec.kind.name(),
            r.spec.block,
            r.spec.density(),
            r.realized_density,
            r.critical_density,
            opt(r.dense_equiv_tflops),
            opt(r.effective_tflops),
            opt(r.speedup_vs_dense),
            r.predicted_max_square,
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::device::{run_shape, Backend};

    fn small_grid() -> Vec<SparseSweepRow> {
        run(
            &IpuArch::gc200(),
            20,
            2,
            1024,
            8,
            &[1.0, 0.25],
            PatternKind::Random,
            42,
            Some(2),
        )
    }

    #[test]
    fn parallel_sweep_matches_serial() {
        let serial = run(
            &IpuArch::gc200(),
            20,
            2,
            1024,
            8,
            &[1.0, 0.25],
            PatternKind::Random,
            42,
            Some(1),
        );
        let parallel = small_grid();
        assert_eq!(serial.len(), parallel.len());
        for (s, p) in serial.iter().zip(&parallel) {
            assert_eq!(s.label, p.label);
            assert_eq!(s.shape, p.shape);
            assert_eq!(s.spec, p.spec);
            assert_eq!(s.dense_equiv_tflops, p.dense_equiv_tflops);
            assert_eq!(s.effective_tflops, p.effective_tflops);
        }
    }

    #[test]
    fn grid_covers_ladder_times_densities() {
        let rows = small_grid();
        assert_eq!(rows.len(), 5 * 2, "5 ladder points x 2 densities");
        assert_eq!(to_table(&rows).n_rows(), 10);
    }

    #[test]
    fn density_one_squared_matches_dense_fig4_path() {
        // acceptance criterion: the sweep's dense-equivalent figure at
        // density 1.0 equals the dense path fig4 runs through run_shape
        let rows = small_grid();
        let squared = rows
            .iter()
            .find(|r| r.label == "square" && r.spec.is_dense())
            .unwrap();
        let dense = run_shape(&Backend::IpuSim(IpuArch::gc200()), squared.shape)
            .tflops()
            .unwrap();
        let ours = squared.dense_equiv_tflops.unwrap();
        assert!(
            (ours - dense).abs() < 1e-9,
            "sweep {ours} vs fig4 path {dense}"
        );
        assert!((squared.speedup_vs_dense.unwrap() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn sparsity_speeds_up_but_effective_drops() {
        let rows = small_grid();
        for point in ["square", "left 2^4", "right 2^4"] {
            let dense = rows
                .iter()
                .find(|r| r.label == point && r.spec.is_dense())
                .unwrap();
            let sparse = rows
                .iter()
                .find(|r| r.label == point && !r.spec.is_dense())
                .unwrap();
            let (dd, sd) = (
                dense.dense_equiv_tflops.unwrap(),
                sparse.dense_equiv_tflops.unwrap(),
            );
            assert!(sd >= dd, "{point}: sparse dense-equiv {sd} < dense {dd}");
            let (de, se) = (
                dense.effective_tflops.unwrap(),
                sparse.effective_tflops.unwrap(),
            );
            assert!(se < de, "{point}: effective should drop ({se} vs {de})");
            assert!(sparse.speedup_vs_dense.unwrap() > 1.0);
        }
    }

    #[test]
    fn best_effective_finds_the_headline() {
        let rows = small_grid();
        let (label, tf) = best_effective_at(&rows, 1000).unwrap();
        assert!(tf > 0.0);
        assert!(rows.iter().any(|r| r.label == label));
        assert!(best_effective_at(&rows, 777).is_none());
    }

    #[test]
    fn csv_has_all_rows() {
        let rows = small_grid();
        let csv = to_csv(&rows);
        assert!(csv.starts_with("label,m,n,k,"));
        assert_eq!(csv.lines().count(), 1 + rows.len());
        assert!(
            csv.lines().next().unwrap().ends_with("predicted_max_square"),
            "CSV must carry the per-density wall column"
        );
    }

    #[test]
    fn predicted_wall_grows_as_density_falls() {
        // acceptance: the CSV's wall column turns the §2.4 wall into a
        // density curve — 3584 at density 1.0 (the paper's number), and
        // strictly larger at 25% density
        let rows = small_grid();
        let dense_wall = rows
            .iter()
            .find(|r| r.spec.is_dense())
            .unwrap()
            .predicted_max_square;
        let sparse_wall = rows
            .iter()
            .find(|r| !r.spec.is_dense())
            .unwrap()
            .predicted_max_square;
        assert_eq!(dense_wall, 3584, "density 1.0 must keep the paper's wall");
        assert!(
            sparse_wall >= 4096,
            "25%-density wall {sparse_wall} should clear the 4096 acceptance shape"
        );
        // constant across ladder points of one density
        for r in &rows {
            let want = if r.spec.is_dense() { dense_wall } else { sparse_wall };
            assert_eq!(r.predicted_max_square, want, "{} d{}", r.label, r.spec.density());
        }
    }
}
