//! Experiment drivers — one per paper table/figure (DESIGN.md §4).
//!
//! Each driver produces structured rows plus a rendered table so the same
//! code serves the CLI, the criterion-style benches, the integration
//! tests, and EXPERIMENTS.md generation:
//!
//! | id | paper artifact                          | module        |
//! |----|------------------------------------------|---------------|
//! | T1 | Table 1 spec comparison                  | `table1`      |
//! | F4 | Fig. 4 squared MM, IPU vs GPU            | `fig4`        |
//! | F5 | Fig. 5 skewed MM sweep                   | `fig5`        |
//! | V1 | §5.1 vertex census 5542/5762/31743       | `vertices`    |
//! | M1 | §2.4 memory wall 3584/2944               | `memory_study`|
//! | P1 | Fig. 3 BSP phase breakdown               | `phases`      |
//! | X1 | §6 streaming-memory extension            | `streaming`   |
//! | X2 | §6 multi-IPU extension                   | `multi_ipu_x` |
//! | S1 | block-sparse density x skew sweep        | `sparse_sweep`|
//! | E2E| end-to-end driver with real PJRT numerics| `e2e`         |

pub mod ablation;
#[cfg(feature = "xla")]
pub mod e2e;
pub mod fig4;
pub mod fp16;
pub mod fig5;
pub mod memory_study;
pub mod multi_ipu_x;
pub mod phases;
pub mod sparse_sweep;
pub mod streaming;
pub mod table1;
pub mod vertices;
