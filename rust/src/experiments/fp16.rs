//! X3 — FP16 extension: squared MM through the AMP's fp16.16 mode
//! (fp16 operands, fp32 accumulation — 4x MAC rate, half operand bytes).
//!
//! The paper evaluates FP32 only; Jia et al. report the fp16 peaks this
//! mode targets (GC200: 250 TFlop/s). The interesting questions mirror
//! Fig. 4: how close to the fp16 peak does the model get (exchange and
//! vertex overheads do not shrink 4x), and how far does the memory wall
//! move with half-size operands?

use crate::arch::IpuArch;
use crate::planner::cost::{CostConfig, CostModel, MmDtype};
use crate::planner::partition::MmShape;
use crate::planner::search::{max_fitting_square_with_config, search_with_config};
use crate::util::table::Table;

#[derive(Clone, Debug)]
pub struct Fp16Row {
    pub size: usize,
    pub fp32_tflops: Option<f64>,
    pub fp16_tflops: Option<f64>,
}

#[derive(Clone, Debug)]
pub struct Fp16Result {
    pub rows: Vec<Fp16Row>,
    pub fp32_wall: usize,
    pub fp16_wall: usize,
    pub fp16_peak_tflops: f64,
}

fn fp16_config() -> CostConfig {
    CostConfig { dtype: MmDtype::F16, ..CostConfig::default() }
}

pub fn run(arch: &IpuArch, sizes: &[usize]) -> Fp16Result {
    let fp32 = CostConfig::default();
    let fp16 = fp16_config();
    let m32 = CostModel::with_config(arch, fp32);
    let m16 = CostModel::with_config(arch, fp16);
    let rows = sizes
        .iter()
        .map(|&s| {
            let shape = MmShape::square(s);
            Fp16Row {
                size: s,
                fp32_tflops: search_with_config(arch, shape, fp32)
                    .ok()
                    .map(|p| m32.tflops(shape, &p.cost)),
                fp16_tflops: search_with_config(arch, shape, fp16)
                    .ok()
                    .map(|p| m16.tflops(shape, &p.cost)),
            }
        })
        .collect();
    Fp16Result {
        rows,
        fp32_wall: max_fitting_square_with_config(arch, 256, 10240, fp32),
        fp16_wall: max_fitting_square_with_config(arch, 256, 10240, fp16),
        fp16_peak_tflops: arch.peak_fp16_flops() / 1e12,
    }
}

pub fn default_sizes() -> Vec<usize> {
    vec![1024, 2048, 3584, 4096, 4608]
}

pub fn to_table(r: &Fp16Result) -> Table {
    let mut t = Table::new(
        &format!(
            "FP16 extension (AMP fp16.16; fp16 peak {:.0} TFlop/s) — walls: fp32 {}, fp16 {}",
            r.fp16_peak_tflops, r.fp32_wall, r.fp16_wall
        ),
        &["size", "fp32 TFlop/s", "fp16 TFlop/s", "fp16/fp32"],
    );
    for row in &r.rows {
        let fmt = |v: Option<f64>| v.map(|x| format!("{x:.2}")).unwrap_or_else(|| "OOM".into());
        let speedup = match (row.fp32_tflops, row.fp16_tflops) {
            (Some(a), Some(b)) => format!("{:.2}x", b / a),
            _ => "-".into(),
        };
        t.row(&[row.size.to_string(), fmt(row.fp32_tflops), fmt(row.fp16_tflops), speedup]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    fn result() -> Fp16Result {
        run(&IpuArch::gc200(), &default_sizes())
    }

    #[test]
    fn fp16_beats_fp32_but_sublinearly() {
        let r = result();
        let row = r.rows.iter().find(|x| x.size == 3584).unwrap();
        let speedup = row.fp16_tflops.unwrap() / row.fp32_tflops.unwrap();
        // 4x MAC rate, but exchange/sync/vertex overheads do not shrink:
        // expect a real but sub-4x gain
        assert!(
            (1.3..4.0).contains(&speedup),
            "fp16 speedup {speedup} at 3584"
        );
    }

    #[test]
    fn fp16_moves_the_memory_wall_out() {
        let r = result();
        assert_eq!(r.fp32_wall, 3584);
        assert!(
            r.fp16_wall > r.fp32_wall,
            "fp16 wall {} should exceed fp32 wall {}",
            r.fp16_wall,
            r.fp32_wall
        );
    }

    #[test]
    fn fp16_stays_under_its_peak() {
        let r = result();
        for row in &r.rows {
            if let Some(t) = row.fp16_tflops {
                assert!(t < r.fp16_peak_tflops, "{t} >= peak");
            }
        }
    }

    #[test]
    fn table_renders_walls() {
        let r = result();
        let ascii = to_table(&r).to_ascii();
        assert!(ascii.contains("fp16 peak 25"));
        assert!(ascii.contains("OOM") || r.rows.iter().all(|x| x.fp16_tflops.is_some()));
    }
}
