//! A1 — ablation study over the cost model's mechanisms (DESIGN.md's
//! "ablation benches for the design choices").
//!
//! Each row disables exactly one mechanism and reports which paper
//! finding breaks:
//!
//! * squared-3584 throughput and the memory wall (Fig. 4 anchors),
//! * the right-skew vertex census and throughput (Finding 2/3).
//!
//! This is the evidence that the reproduction's headline numbers come
//! from the modelled mechanisms, not from tuned coincidences.

use crate::arch::IpuArch;
use crate::planner::cost::{CostConfig, CostModel, Mechanism};
use crate::planner::partition::MmShape;
use crate::planner::search::{max_fitting_square_with_config, search_with_config};
use crate::util::table::Table;

#[derive(Clone, Debug)]
pub struct AblationRow {
    pub name: &'static str,
    /// TFlop/s at the paper's flagship 3584^2 (None = OOM under this config).
    pub squared_tflops: Option<f64>,
    /// Max fitting square at 256-step (the Fig. 4 wall).
    pub max_square: usize,
    /// Vertex census at the right-skew census shape.
    pub right_vertices: Option<usize>,
    /// TFlop/s at the right-skew census shape.
    pub right_tflops: Option<f64>,
}

fn row(arch: &IpuArch, name: &'static str, config: CostConfig) -> AblationRow {
    let squared = MmShape::square(3584);
    let right = MmShape::new(512, 16384, 2048);
    let model = CostModel::with_config(arch, config);
    let sq = search_with_config(arch, squared, config).ok();
    let rt = search_with_config(arch, right, config).ok();
    AblationRow {
        name,
        squared_tflops: sq.as_ref().map(|p| model.tflops(squared, &p.cost)),
        max_square: max_fitting_square_with_config(arch, 256, 8192, config),
        right_vertices: rt.as_ref().map(|p| p.cost.total_vertices()),
        right_tflops: rt.as_ref().map(|p| model.tflops(right, &p.cost)),
    }
}

pub fn run(arch: &IpuArch) -> Vec<AblationRow> {
    let mut rows = vec![row(arch, "full model", CostConfig::default())];
    for mech in Mechanism::all() {
        rows.push(row(arch, mech.name(), CostConfig::without(mech)));
    }
    rows
}

pub fn to_table(rows: &[AblationRow]) -> Table {
    let mut t = Table::new(
        "Ablation — disable one mechanism per row (full model on top)",
        &["mechanism off", "3584^2 TF/s", "max square", "right-skew verts", "right-skew TF/s"],
    );
    let fmt_opt = |v: Option<f64>| v.map(|x| format!("{x:.2}")).unwrap_or_else(|| "OOM".into());
    for r in rows {
        t.row(&[
            r.name.to_string(),
            fmt_opt(r.squared_tflops),
            r.max_square.to_string(),
            r.right_vertices
                .map(|v| v.to_string())
                .unwrap_or_else(|| "OOM".into()),
            fmt_opt(r.right_tflops),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rows() -> Vec<AblationRow> {
        run(&IpuArch::gc200())
    }

    #[test]
    fn full_model_is_the_calibrated_baseline() {
        let r = &rows()[0];
        assert_eq!(r.name, "full model");
        assert!((r.squared_tflops.unwrap() - 43.8).abs() < 1.0);
        assert_eq!(r.max_square, 3584);
    }

    #[test]
    fn exchange_code_scaling_is_the_memory_wall() {
        let all = rows();
        let r = all
            .iter()
            .find(|r| r.name == "exchange-code-scaling")
            .unwrap();
        // without per-superstep exchange code, the wall moves far out —
        // this mechanism IS the Fig. 4 memory wall
        assert!(r.max_square > 3584 + 512, "wall at {}", r.max_square);
        // (the right-skew reduction split persists: even when unsplit
        // plans fit, splitting stays cheaper in cycles — the census is
        // governed by the reduce-stage pricing, see the next test)
        let full = &all[0];
        assert_eq!(r.right_vertices, full.right_vertices);
    }

    #[test]
    fn reduce_penalty_governs_the_census_size() {
        // without the reduce-stage penalty the planner splits even deeper
        // (higher pn), inflating the census further — the penalty is what
        // pins the census near the paper's 31743 rather than higher
        let all = rows();
        let full = all[0].right_vertices.unwrap();
        let r = all
            .iter()
            .find(|r| r.name == "reduce-stage-penalty")
            .unwrap();
        assert!(
            r.right_vertices.unwrap() > full,
            "{} should exceed full {full}",
            r.right_vertices.unwrap()
        );
    }

    #[test]
    fn congestion_and_quantization_lift_throughput_when_removed() {
        let all = rows();
        let full = all[0].squared_tflops.unwrap();
        for name in ["exchange-congestion", "amp-quantization"] {
            let r = all.iter().find(|r| r.name == name).unwrap();
            assert!(
                r.squared_tflops.unwrap() > full,
                "{name}: {} should beat full {full}",
                r.squared_tflops.unwrap()
            );
        }
    }

    #[test]
    fn reduce_penalty_shapes_right_skew_performance() {
        let all = rows();
        let full = all[0].right_tflops.unwrap();
        let r = all
            .iter()
            .find(|r| r.name == "reduce-stage-penalty")
            .unwrap();
        assert!(
            r.right_tflops.unwrap() > full,
            "without the penalty right-skew should look faster: {} vs {full}",
            r.right_tflops.unwrap()
        );
    }

    #[test]
    fn table_has_seven_rows() {
        assert_eq!(to_table(&rows()).n_rows(), 7);
    }
}
