//! F5 — paper Fig. 5: skewed MM throughput vs A's aspect ratio, IPU
//! (left panel) and GPU (right panel), for several k.
//!
//! Expected shape (paper §5.1): the GPU valley is symmetric; the IPU's is
//! asymmetric — the right-skewed (wide-A, huge reduction) side collapses
//! much harder than the left-skewed side, driven by the planner's
//! reduction splitting (Finding 2/3).

use crate::arch::{GpuArch, IpuArch};
use crate::coordinator::device::Backend;
use crate::coordinator::metrics::MetricsTable;
use crate::coordinator::runner::{run_jobs, Job};
use crate::coordinator::sweep::{aspect_ratio_ladder, SweepPoint};
use crate::util::table::Table;

#[derive(Clone, Debug)]
pub struct Fig5Result {
    pub metrics: MetricsTable,
    pub points: Vec<SweepPoint>,
    pub ks: Vec<usize>,
}

/// Run the Fig. 5 ladder (m*n = 2^`mn_budget_log2`) for each k.
pub fn run(
    ipu: &IpuArch,
    gpu: &GpuArch,
    mn_budget_log2: u32,
    half_steps: u32,
    ks: &[usize],
    workers: Option<usize>,
) -> Fig5Result {
    let mut jobs = Vec::new();
    let mut points = Vec::new();
    for &k in ks {
        for p in aspect_ratio_ladder(mn_budget_log2, half_steps, k) {
            let label = format!("k={k} {}", p.label());
            jobs.push(Job::new(Backend::IpuSim(ipu.clone()), label.clone(), p.shape));
            jobs.push(Job::new(Backend::GpuModel(gpu.clone()), label, p.shape));
            points.push(p);
        }
    }
    Fig5Result {
        metrics: run_jobs(jobs, workers),
        points,
        ks: ks.to_vec(),
    }
}

/// Skew-drop summary for one backend: (left_drop, right_drop) as
/// fractions of the squared throughput at aspect ratio 2^`log2_ratio`
/// (pass `None` for the ladder's outermost ratio).
pub fn drops(
    result: &Fig5Result,
    backend_name: &str,
    k: usize,
    log2_ratio: Option<i32>,
) -> Option<(f64, f64)> {
    let recs = result.metrics.for_backend(backend_name);
    let get = |label: &str| {
        recs.iter()
            .find(|r| r.label == format!("k={k} {label}"))
            .and_then(|r| r.outcome.tflops())
    };
    let ratio = log2_ratio.unwrap_or_else(|| {
        result
            .points
            .iter()
            .map(|p| p.log2_ratio)
            .max()
            .unwrap_or(0)
    });
    let square = get("square")?;
    let left = get(&format!("left 2^{ratio}"))?;
    let right = get(&format!("right 2^{ratio}"))?;
    Some((1.0 - left / square, 1.0 - right / square))
}

impl Fig5Result {
    pub fn to_table(&self) -> Table {
        self.metrics
            .to_table("Fig. 5 — skewed MM across A aspect ratios (left panel IPU, right panel GPU)")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_run() -> Fig5Result {
        run(&IpuArch::gc200(), &GpuArch::a30(), 22, 4, &[2048], Some(4))
    }

    #[test]
    fn ipu_asymmetry_and_gpu_symmetry() {
        let r = small_run();
        let ipu = Backend::IpuSim(IpuArch::gc200()).name();
        let gpu = Backend::GpuModel(GpuArch::a30()).name();

        // mid-ladder (ratio 2^4): the paper's "drop much more severe" on
        // the right side shows as a large right-minus-left drop gap on the
        // IPU but a small one on the GPU
        let (ipu_left, ipu_right) = drops(&r, &ipu, 2048, Some(4)).unwrap();
        let (gpu_left, gpu_right) = drops(&r, &gpu, 2048, Some(4)).unwrap();
        let ipu_gap = ipu_right - ipu_left;
        let gpu_gap = (gpu_right - gpu_left).abs();
        assert!(ipu_gap > 0.15, "IPU right-left gap {ipu_gap}");
        assert!(ipu_gap > gpu_gap, "IPU gap {ipu_gap} vs GPU gap {gpu_gap}");

        // extremes: both IPU sides drop (paper: decreases on both sides),
        // and the GPU valley is deep on both sides too
        let (ipu_l8, ipu_r8) = drops(&r, &ipu, 2048, None).unwrap();
        let (gpu_l8, gpu_r8) = drops(&r, &gpu, 2048, None).unwrap();
        assert!(ipu_l8 > 0.1 && ipu_r8 > 0.1, "{ipu_l8} / {ipu_r8}");
        assert!(ipu_r8 > ipu_l8, "right remains worse at the extreme");
        assert!(gpu_l8 > 0.15 && gpu_r8 > 0.15, "{gpu_l8} / {gpu_r8}");
    }

    #[test]
    fn ipu_beats_gpu_wherever_it_fits() {
        // paper §5.2: "the IPU surpasses the GPU ... for all aspect ratios
        // as long as they fit into the IPU's In-Processor memory"
        let r = small_run();
        let ipu = Backend::IpuSim(IpuArch::gc200()).name();
        let gpu = Backend::GpuModel(GpuArch::a30()).name();
        for p in &r.points {
            let label = format!("k=2048 {}", p.label());
            let ipu_t = r
                .metrics
                .for_backend(&ipu)
                .iter()
                .find(|x| x.label == label)
                .and_then(|x| x.outcome.tflops());
            let gpu_t = r
                .metrics
                .for_backend(&gpu)
                .iter()
                .find(|x| x.label == label)
                .and_then(|x| x.outcome.tflops())
                .unwrap();
            if let Some(ipu_t) = ipu_t {
                assert!(ipu_t > gpu_t, "{label}: IPU {ipu_t} vs GPU {gpu_t}");
            }
        }
    }

    #[test]
    fn table_has_rows_for_every_point() {
        let r = small_run();
        assert_eq!(r.to_table().n_rows(), 9);
    }
}
