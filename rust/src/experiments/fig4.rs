//! F4 — paper Fig. 4: squared MM throughput vs problem size, IPU and GPU,
//! against their theoretical peaks.
//!
//! Expected shape (paper §5.1): the GPU approaches its 10.3 TFlop/s peak
//! (9.7 achieved); the IPU reaches ~44.2 of 62.5 TFlop/s and *wins while
//! the problem fits*, then hits the 3584^2 memory wall while the GPU keeps
//! going to much larger sizes.

use crate::arch::{GpuArch, IpuArch};
use crate::coordinator::device::Backend;
use crate::coordinator::metrics::MetricsTable;
use crate::coordinator::runner::{run_jobs, Job};
use crate::coordinator::sweep::squared_sizes;
use crate::planner::partition::MmShape;
use crate::util::table::Table;

#[derive(Clone, Debug)]
pub struct Fig4Result {
    pub metrics: MetricsTable,
    pub ipu_peak: f64,
    pub gpu_peak: f64,
    /// Largest square that fit the IPU in this sweep.
    pub ipu_max_square: usize,
    /// Best IPU throughput seen (the paper's 44.2 TFlop/s headline).
    pub ipu_best_tflops: f64,
    pub gpu_best_tflops: f64,
}

/// Run the Fig. 4 sweep up to `max_size` (paper plots past the IPU wall).
/// `workers: None` uses the shared `runner::default_workers` policy.
pub fn run(ipu: &IpuArch, gpu: &GpuArch, max_size: usize, workers: Option<usize>) -> Fig4Result {
    let mut jobs = Vec::new();
    for s in squared_sizes(max_size) {
        let shape = MmShape::square(s);
        jobs.push(Job::new(Backend::IpuSim(ipu.clone()), s.to_string(), shape));
        jobs.push(Job::new(Backend::GpuModel(gpu.clone()), s.to_string(), shape));
    }
    let metrics = run_jobs(jobs, workers);

    let ipu_name = Backend::IpuSim(ipu.clone()).name();
    let gpu_name = Backend::GpuModel(gpu.clone()).name();
    let ipu_max_square = metrics
        .for_backend(&ipu_name)
        .iter()
        .filter(|r| !r.outcome.is_oom())
        .filter_map(|r| r.label.parse::<usize>().ok())
        .max()
        .unwrap_or(0);
    let best = |name: &str| {
        metrics
            .for_backend(name)
            .iter()
            .filter_map(|r| r.outcome.tflops())
            .fold(0.0f64, f64::max)
    };
    Fig4Result {
        ipu_best_tflops: best(&ipu_name),
        gpu_best_tflops: best(&gpu_name),
        ipu_max_square,
        ipu_peak: ipu.peak_fp32_tflops(),
        gpu_peak: gpu.peak_fp32_tflops(),
        metrics,
    }
}

impl Fig4Result {
    pub fn to_table(&self) -> Table {
        let mut t = self.metrics.to_table(&format!(
            "Fig. 4 — squared MM (peaks: IPU {:.1}, GPU {:.1} TFlop/s)",
            self.ipu_peak, self.gpu_peak
        ));
        t.row(&[
            "best/peak".to_string(),
            format!("{:.1}%", 100.0 * self.ipu_best_tflops / self.ipu_peak),
            format!("{:.1}%", 100.0 * self.gpu_best_tflops / self.gpu_peak),
        ]);
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig4_shape_holds() {
        let r = run(&IpuArch::gc200(), &GpuArch::a30(), 5120, Some(4));
        // paper: IPU max square 3584 (we land 3584 at 256-granularity)
        assert_eq!(r.ipu_max_square, 3584, "IPU wall at {}", r.ipu_max_square);
        // paper: 44.2 of 62.5 (70.7%); accept the shape within a band
        let eff = r.ipu_best_tflops / r.ipu_peak;
        assert!((0.60..=0.80).contains(&eff), "IPU best/peak {eff}");
        // paper: GPU 9.7 of 10.3 (94%)
        let geff = r.gpu_best_tflops / r.gpu_peak;
        assert!(geff > 0.85, "GPU best/peak {geff}");
        // IPU wins at its max square; GPU survives past the wall
        let ipu_name = Backend::IpuSim(IpuArch::gc200()).name();
        let gpu_name = Backend::GpuModel(GpuArch::a30()).name();
        let at = |name: &str, label: &str| {
            r.metrics
                .for_backend(name)
                .iter()
                .find(|x| x.label == label)
                .and_then(|x| x.outcome.tflops())
        };
        assert!(at(&ipu_name, "3584").unwrap() > at(&gpu_name, "3584").unwrap());
        assert!(at(&ipu_name, "4096").is_none());
        assert!(at(&gpu_name, "4096").is_some());
    }

    #[test]
    fn table_renders_with_peak_row() {
        let r = run(&IpuArch::gc200(), &GpuArch::a30(), 1024, Some(2));
        let ascii = r.to_table().to_ascii();
        assert!(ascii.contains("best/peak"));
        assert!(ascii.contains("Fig. 4"));
    }
}
