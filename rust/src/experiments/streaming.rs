//! X1 — §6 future work: streaming-memory MM past the In-Processor wall.

use crate::arch::IpuArch;
use crate::ipu::streaming::{StreamingMm, StreamingReport};
use crate::planner::partition::MmShape;
use crate::planner::search::search;
use crate::util::table::Table;

#[derive(Clone, Debug)]
pub struct StreamingRow {
    pub size: usize,
    pub resident_tflops: Option<f64>,
    pub streamed: Option<StreamingReport>,
}

/// Sweep squares across the wall: resident (when it fits) vs streamed.
pub fn run(arch: &IpuArch, sizes: &[usize]) -> Vec<StreamingRow> {
    let streaming = StreamingMm::new(arch.clone());
    sizes
        .iter()
        .map(|&size| {
            let shape = MmShape::square(size);
            StreamingRow {
                size,
                resident_tflops: search(arch, shape).ok().map(|p| p.tflops(arch)),
                streamed: streaming.simulate_mm(shape).ok(),
            }
        })
        .collect()
}

pub fn default_sizes() -> Vec<usize> {
    vec![2048, 3584, 4096, 8192, 16384, 32768]
}

pub fn to_table(rows: &[StreamingRow]) -> Table {
    let mut t = Table::new(
        "Streaming memory extension (§6): resident vs DRAM-staged TFlop/s",
        &["size", "resident", "streamed", "panels", "stream-bound"],
    );
    for r in rows {
        t.row(&[
            r.size.to_string(),
            r.resident_tflops
                .map(|t| format!("{t:.2}"))
                .unwrap_or_else(|| "OOM".to_string()),
            r.streamed
                .map(|s| format!("{:.2}", s.tflops))
                .unwrap_or_else(|| "OOM".to_string()),
            r.streamed
                .map(|s| s.panels_total.to_string())
                .unwrap_or_default(),
            r.streamed
                .map(|s| {
                    if s.stream_bound_fraction > 0.5 { "yes" } else { "no" }.to_string()
                })
                .unwrap_or_default(),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn streaming_extends_capacity() {
        let rows = run(&IpuArch::gc200(), &default_sizes());
        // inside the wall: resident works
        assert!(rows[1].resident_tflops.is_some()); // 3584
        // past the wall: resident OOMs, streaming still goes
        let past = rows.iter().find(|r| r.size == 8192).unwrap();
        assert!(past.resident_tflops.is_none());
        assert!(past.streamed.is_some());
    }

    #[test]
    fn streamed_throughput_is_bandwidth_limited() {
        let rows = run(&IpuArch::gc200(), &[16384]);
        let s = rows[0].streamed.unwrap();
        assert!(s.stream_bound_fraction > 0.5);
        assert!(s.tflops < s.panel_tflops);
    }

    #[test]
    fn table_marks_oom_correctly() {
        let t = to_table(&run(&IpuArch::gc200(), &[3584, 8192]));
        let ascii = t.to_ascii();
        assert!(ascii.contains("OOM"));
        assert_eq!(t.n_rows(), 2);
    }
}
