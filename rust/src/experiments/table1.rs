//! T1 — paper Table 1: GC200 vs A30 specification comparison, with a
//! third column showing the values our models derive from first
//! principles (so the calibration is auditable).

use crate::arch::{GpuArch, IpuArch};
use crate::util::table::Table;
use crate::util::units::fmt_bytes_si;

pub fn table1(ipu: &IpuArch, gpu: &GpuArch) -> Table {
    let mut t = Table::new(
        "Table 1 — IPU vs GPU comparison (paper values; model-derived in parentheses)",
        &["Property", ipu.name, gpu.name],
    );
    t.row(&[
        "Number of cores".into(),
        format!("{}", ipu.tiles),
        format!("{}", gpu.cuda_cores()),
    ]);
    t.row(&[
        "Number of threads".into(),
        format!("{}", ipu.total_threads()),
        format!("{}", gpu.total_thread_slots()),
    ]);
    t.row(&[
        "Total SRAM".into(),
        format!("{} ({} derived)", "918 MB", fmt_bytes_si(ipu.total_sram_bytes())),
        fmt_bytes_si(gpu.l2_bytes + gpu.sms as u64 * 192 * 1024),
    ]);
    t.row(&[
        "Total DRAM memory".into(),
        fmt_bytes_si(ipu.streaming_bytes),
        fmt_bytes_si(gpu.dram_bytes),
    ]);
    t.row(&[
        "DRAM bandwidth".into(),
        format!("{:.0} GB/s", ipu.streaming_bw_bytes_per_s / 1e9),
        format!("{:.0} GB/s", gpu.dram_bw_bytes_per_s / 1e9),
    ]);
    t.row(&[
        "Clock frequency".into(),
        format!("{:.2} GHz", ipu.clock_hz / 1e9),
        format!("{:.2} GHz", gpu.clock_hz / 1e9),
    ]);
    t.row(&[
        "FP32 peak compute".into(),
        format!("{:.1} TFlop/s", ipu.peak_fp32_tflops()),
        format!("{:.1} TFlop/s", gpu.peak_fp32_tflops()),
    ]);
    t.row(&[
        "Power consumption".into(),
        format!("{:.0} W", ipu.power_w),
        format!("{:.0} W", gpu.power_w),
    ]);
    t.row(&[
        "Inter-chip bandwidth".into(),
        format!("{:.0} GB/s", ipu.interchip_bw_bytes_per_s / 1e9),
        format!("{:.0} GB/s", gpu.interchip_bw_bytes_per_s / 1e9),
    ]);
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn has_all_table1_rows() {
        let t = table1(&IpuArch::gc200(), &GpuArch::a30());
        assert_eq!(t.n_rows(), 9);
        let ascii = t.to_ascii();
        // paper Table 1 anchor values
        assert!(ascii.contains("1472"));
        assert!(ascii.contains("3584"));
        assert!(ascii.contains("8832"));
        assert!(ascii.contains("229376"));
        assert!(ascii.contains("62.")); // 62.5/62.6 TFlop/s
        assert!(ascii.contains("10.3"));
    }

    #[test]
    fn works_for_other_pairings() {
        let t = table1(&IpuArch::gc2(), &GpuArch::rtx2080ti());
        assert!(t.to_ascii().contains("GC2"));
        assert!(t.to_markdown().contains("RTX 2080 Ti"));
    }
}
