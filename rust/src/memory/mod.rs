//! In-Processor memory modelling (paper §2.3).
//!
//! The paper's central constraint: "all data required for a computational
//! step must reside in the In-Processor Memory of each tile", and memory —
//! not compute — bounds the largest multipliable matrices (§2.4: 3584^2 on
//! GC200 at only 17% *tensor* occupancy; the rest is code, vertex state,
//! exchange buffers and rearrangement copies).
//!
//! * `mapping`    — tensor->tile layout strategies,
//! * `tile_mem`   — a per-tile region allocator,
//! * `accounting` — whole-graph per-tile memory bills and fit checks.

pub mod accounting;
pub mod mapping;
pub mod liveness;
pub mod tile_mem;

pub use accounting::{MemoryAccountant, MemoryReport};
pub use mapping::{grid_2d_mapping, linear_balanced_mapping};
pub use liveness::LivenessProfile;
pub use tile_mem::{RegionKind, TileMemory};
