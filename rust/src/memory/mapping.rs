//! Tensor->tile mapping strategies (the Poplar `setTileMapping` analogue).

use crate::graph::tensor::{Interval, TileMapping};

/// Spread `numel` elements across `tiles` tiles in contiguous, balanced
/// chunks (Poplar's `mapTensorLinearly`). The first `numel % tiles` tiles
/// get one extra element.
pub fn linear_balanced_mapping(numel: usize, tiles: usize) -> TileMapping {
    assert!(tiles > 0);
    let base = numel / tiles;
    let extra = numel % tiles;
    let mut out: TileMapping = Vec::with_capacity(tiles);
    let mut cursor = 0;
    for t in 0..tiles {
        let len = base + usize::from(t < extra);
        out.push(if len == 0 {
            vec![]
        } else {
            vec![Interval::new(cursor, cursor + len)]
        });
        cursor += len;
    }
    debug_assert_eq!(cursor, numel);
    out
}

/// Map a row-major `rows x cols` tensor as a `pr x pc` grid of blocks, block
/// (i, j) going to `tile_of(i, j)`. Rows/cols need not divide evenly; edge
/// blocks are smaller. Produces one interval per (block-row-slice) so the
/// mapping stays exact.
pub fn grid_2d_mapping(
    rows: usize,
    cols: usize,
    pr: usize,
    pc: usize,
    tiles: usize,
    tile_of: impl Fn(usize, usize) -> usize,
) -> TileMapping {
    assert!(pr > 0 && pc > 0);
    let mut out: TileMapping = vec![vec![]; tiles];
    let rb = rows.div_ceil(pr);
    let cb = cols.div_ceil(pc);
    for bi in 0..pr {
        let r0 = bi * rb;
        if r0 >= rows {
            continue;
        }
        let r1 = ((bi + 1) * rb).min(rows);
        for bj in 0..pc {
            let c0 = bj * cb;
            if c0 >= cols {
                continue;
            }
            let c1 = ((bj + 1) * cb).min(cols);
            let tile = tile_of(bi, bj);
            assert!(tile < tiles, "tile_of({bi},{bj}) = {tile} out of range");
            for r in r0..r1 {
                out[tile].push(Interval::new(r * cols + c0, r * cols + c1));
            }
        }
    }
    out
}

/// Bytes on the heaviest tile for a mapping of element size `elem_bytes`.
pub fn max_tile_bytes(mapping: &TileMapping, elem_bytes: usize) -> usize {
    mapping
        .iter()
        .map(|ivs| ivs.iter().map(Interval::len).sum::<usize>() * elem_bytes)
        .max()
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::tensor::{DType, Tensor, TensorId};

    fn validate(numel: usize, mapping: TileMapping) {
        let t = Tensor {
            id: TensorId(0),
            name: "t".into(),
            shape: vec![numel],
            dtype: DType::F32,
            mapping: Some(mapping),
        };
        t.validate_mapping().unwrap();
    }

    #[test]
    fn linear_even_split() {
        let m = linear_balanced_mapping(8, 4);
        assert_eq!(m.len(), 4);
        assert_eq!(m[0], vec![Interval::new(0, 2)]);
        assert_eq!(m[3], vec![Interval::new(6, 8)]);
        validate(8, m);
    }

    #[test]
    fn linear_remainder_goes_to_early_tiles() {
        let m = linear_balanced_mapping(10, 4);
        let lens: Vec<usize> = m.iter().map(|iv| iv.iter().map(Interval::len).sum()).collect();
        assert_eq!(lens, vec![3, 3, 2, 2]);
        validate(10, m);
    }

    #[test]
    fn linear_more_tiles_than_elements() {
        let m = linear_balanced_mapping(2, 5);
        let used = m.iter().filter(|iv| !iv.is_empty()).count();
        assert_eq!(used, 2);
        validate(2, m);
    }

    #[test]
    fn grid_even_blocks() {
        // 4x4 over 2x2 grid -> 4 tiles, each 2x2 block = 2 intervals of 2
        let m = grid_2d_mapping(4, 4, 2, 2, 4, |i, j| i * 2 + j);
        validate(16, m.clone());
        for t in 0..4 {
            let n: usize = m[t].iter().map(Interval::len).sum();
            assert_eq!(n, 4);
        }
    }

    #[test]
    fn grid_uneven_edges() {
        // 5x3 over 2x2 grid: row blocks of 3/2 rows, col blocks of 2/1
        let m = grid_2d_mapping(5, 3, 2, 2, 4, |i, j| i * 2 + j);
        validate(15, m.clone());
        let n0: usize = m[0].iter().map(Interval::len).sum();
        assert_eq!(n0, 6); // 3 rows x 2 cols
        let n3: usize = m[3].iter().map(Interval::len).sum();
        assert_eq!(n3, 2); // 2 rows x 1 col
    }

    #[test]
    fn grid_degenerate_partitions_skip_empty() {
        // more partitions than rows: pr=4 over 2 rows
        let m = grid_2d_mapping(2, 2, 4, 1, 4, |i, _| i);
        validate(4, m);
    }

    #[test]
    fn max_tile_bytes_reports_heaviest() {
        let m = linear_balanced_mapping(10, 4);
        assert_eq!(max_tile_bytes(&m, 4), 12);
    }
}
