//! Program-step liveness: how per-tile memory demand evolves across the
//! BSP program — the PopVision "memory over time" view that underlies the
//! paper's observation that *transient* state (chunk landings, partial
//! gathers), not resident tensors, sets the peak.
//!
//! This is the *temporal* memory view; the static gate
//! ([`crate::analysis::verify`], `ipumm check`) cross-checks the same
//! resident bytes against the planner's `tile_bill` per tile and bounds
//! them by SRAM capacity before any program is priced.

use crate::graph::builder::Graph;
use crate::graph::program::ProgramStep;

/// Memory demand at one program step.
#[derive(Clone, Debug)]
pub struct LivenessPoint {
    pub step_index: usize,
    pub label: String,
    /// Resident tensor bytes (constant across the program in our model —
    /// tensors are allocated for the whole run, as in Poplar).
    pub resident_bytes: u64,
    /// Transient bytes in flight at this step on the busiest tile
    /// (exchange landings for Exchange steps, zero otherwise).
    pub peak_transient_tile_bytes: u64,
}

/// Liveness profile of a graph's program.
#[derive(Clone, Debug)]
pub struct LivenessProfile {
    pub points: Vec<LivenessPoint>,
    pub resident_bytes: u64,
}

impl LivenessProfile {
    /// Compute the profile. Resident = all mapped tensors; transient =
    /// per-step exchange receive maxima.
    pub fn of(graph: &Graph) -> LivenessProfile {
        let resident: u64 = graph
            .tensors()
            .iter()
            .filter(|t| t.mapping.is_some())
            .map(|t| t.bytes() as u64)
            .sum();
        let mut points = Vec::new();
        for (i, step) in graph.program.steps().into_iter().enumerate() {
            let (label, transient) = match step {
                ProgramStep::Execute(cs) => {
                    (format!("execute:{}", graph.compute_set(cs).name), 0)
                }
                ProgramStep::Sync => ("sync".to_string(), 0),
                ProgramStep::Exchange(ex) => {
                    let plan = graph.exchange(ex);
                    let max_recv = plan
                        .recv_per_tile(graph.tiles)
                        .into_iter()
                        .max()
                        .unwrap_or(0);
                    (format!("exchange:{}", plan.name), max_recv)
                }
            };
            points.push(LivenessPoint {
                step_index: i,
                label,
                resident_bytes: resident,
                peak_transient_tile_bytes: transient,
            });
        }
        LivenessProfile { points, resident_bytes: resident }
    }

    /// Step with the largest transient demand (the liveness peak).
    pub fn peak(&self) -> Option<&LivenessPoint> {
        self.points
            .iter()
            .max_by_key(|p| p.peak_transient_tile_bytes)
    }

    /// Sparkline of transient demand across steps ('.' none .. '#' peak).
    pub fn sparkline(&self) -> String {
        let max = self
            .points
            .iter()
            .map(|p| p.peak_transient_tile_bytes)
            .max()
            .unwrap_or(0)
            .max(1);
        self.points
            .iter()
            .map(|p| {
                let frac = p.peak_transient_tile_bytes as f64 / max as f64;
                match (frac * 4.0).round() as u32 {
                    0 => '.',
                    1 => '-',
                    2 => '=',
                    3 => '+',
                    _ => '#',
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::IpuArch;
    use crate::planner::partition::MmShape;
    use crate::planner::search::search;
    use crate::sim::engine::SimEngine;

    fn profile(shape: MmShape) -> LivenessProfile {
        let arch = IpuArch::gc200();
        let engine = SimEngine::new(arch.clone());
        let plan = search(&arch, shape).unwrap();
        LivenessProfile::of(&engine.build_graph(shape, &plan))
    }

    #[test]
    fn resident_equals_tensor_totals() {
        let shape = MmShape::square(512);
        let p = profile(shape);
        // A + B + C in f32
        assert_eq!(p.resident_bytes, shape.tensor_bytes());
    }

    #[test]
    fn exchanges_carry_transient_demand() {
        let p = profile(MmShape::square(1024));
        let peak = p.peak().unwrap();
        assert!(peak.peak_transient_tile_bytes > 0);
        assert!(peak.label.starts_with("exchange:"));
    }

    #[test]
    fn execute_steps_have_no_transients() {
        let p = profile(MmShape::square(512));
        for pt in &p.points {
            if pt.label.starts_with("execute:") {
                assert_eq!(pt.peak_transient_tile_bytes, 0);
            }
        }
    }

    #[test]
    fn step_count_matches_program() {
        let arch = IpuArch::gc200();
        let engine = SimEngine::new(arch.clone());
        let shape = MmShape::square(1024);
        let plan = search(&arch, shape).unwrap();
        let g = engine.build_graph(shape, &plan);
        let p = LivenessProfile::of(&g);
        assert_eq!(p.points.len(), g.program.steps().len());
    }

    #[test]
    fn sparkline_length_matches_steps() {
        let p = profile(MmShape::square(512));
        assert_eq!(p.sparkline().chars().count(), p.points.len());
        assert!(p.sparkline().contains('#'));
    }

    #[test]
    fn split_reduction_adds_gather_peak() {
        let p = profile(MmShape::new(512, 16384, 2048));
        assert!(p
            .points
            .iter()
            .any(|pt| pt.label == "exchange:gather-partials"));
    }
}
