//! Whole-graph memory accounting: per-tile bills and fit checks.
//!
//! Prices everything PopVision's memory tab shows for a PopLin matmul:
//! mapped tensor data, per-vertex state, per-family codelet code, exchange
//! code (scales with the transfers a tile participates in), exchange
//! receive buffers (double-buffered rearrangement landing zones), and a
//! fixed control-code floor per tile.

use crate::arch::IpuArch;
use crate::graph::builder::Graph;
use crate::graph::program::ProgramStep;
use crate::memory::tile_mem::{RegionKind, TileMemory};

/// Calibration constants (see DESIGN.md §5). These are the knobs that make
/// the max fitting square land at 3584 (GC200) / 2944 (GC2) as measured by
/// the paper.
pub mod overheads {
    /// Fixed control-program code per tile.
    pub const CONTROL_CODE_BYTES: u64 = 2 * 1024;
    /// Codelet code per vertex *family* present on a tile.
    pub const CODE_BYTES_PER_FAMILY: u64 = 1024;
    /// Exchange-program code per transfer endpoint on a tile.
    pub const EXCHANGE_CODE_PER_TRANSFER: u64 = 48;
    /// Receive-side landing buffers: fraction of the bytes a tile receives
    /// in its heaviest exchange that must be double-buffered.
    pub const RECV_BUFFER_FACTOR: f64 = 1.0;
}

#[derive(Clone, Debug)]
pub struct MemoryReport {
    pub per_tile: Vec<TileMemory>,
    pub max_tile_used: u64,
    pub max_tile: usize,
    pub total_used: u64,
    pub capacity_per_tile: u64,
}

impl MemoryReport {
    pub fn fits(&self) -> bool {
        self.max_tile_used <= self.capacity_per_tile
    }

    /// Fraction of total SRAM used (the paper's "17% of available
    /// In-Processor Memory" statistic).
    pub fn total_fraction(&self) -> f64 {
        self.total_used as f64 / (self.capacity_per_tile * self.per_tile.len() as u64) as f64
    }

    /// Fraction of the bottleneck tile used (the binding constraint).
    pub fn max_tile_fraction(&self) -> f64 {
        self.max_tile_used as f64 / self.capacity_per_tile as f64
    }

    /// Histogram of per-tile usage in `buckets` equal-width bins over
    /// [0, capacity] (PopVision's per-tile memory chart).
    pub fn histogram(&self, buckets: usize) -> Vec<usize> {
        let mut h = vec![0usize; buckets];
        for tm in &self.per_tile {
            let frac = (tm.used() as f64 / self.capacity_per_tile as f64).min(1.0);
            let b = ((frac * buckets as f64) as usize).min(buckets - 1);
            h[b] += 1;
        }
        h
    }

    /// Sum of one region across tiles.
    pub fn region_total(&self, kind: RegionKind) -> u64 {
        self.per_tile.iter().map(|t| t.region(kind)).sum()
    }
}

pub struct MemoryAccountant<'a> {
    arch: &'a IpuArch,
}

impl<'a> MemoryAccountant<'a> {
    pub fn new(arch: &'a IpuArch) -> Self {
        MemoryAccountant { arch }
    }

    /// Price a whole graph. Never fails: over-committed tiles are visible
    /// via `fits() == false` so the planner can reject candidate plans.
    pub fn account(&self, graph: &Graph) -> MemoryReport {
        let tiles = self.arch.tiles;
        let mut mems: Vec<TileMemory> = (0..tiles)
            .map(|t| TileMemory::new(t, self.arch.tile_sram_bytes))
            .collect();

        // control code floor on every tile that does anything
        for tm in mems.iter_mut() {
            tm.alloc_unchecked(RegionKind::ControlCode, overheads::CONTROL_CODE_BYTES);
        }

        // tensor data per mapping
        for t in graph.tensors() {
            if t.mapping.is_some() {
                for (tile, tm) in mems.iter_mut().enumerate() {
                    let b = t.bytes_on_tile(tile) as u64;
                    if b > 0 {
                        tm.alloc_unchecked(RegionKind::TensorData, b);
                    }
                }
            }
        }

        // vertex state + codelet code per family present
        let mut families_on_tile: Vec<Vec<&'static str>> = vec![Vec::new(); tiles];
        let mut charge = |tile: usize, state: u64, fam: &'static str, mems: &mut Vec<TileMemory>| {
            mems[tile].alloc_unchecked(RegionKind::VertexState, state);
            if !families_on_tile[tile].contains(&fam) {
                families_on_tile[tile].push(fam);
                mems[tile].alloc_unchecked(RegionKind::VertexCode, overheads::CODE_BYTES_PER_FAMILY);
            }
        };
        for v in graph.vertices() {
            charge(v.tile, v.kind.state_bytes() as u64, v.kind.family(), &mut mems);
        }
        // replicated groups expand arithmetically: each spanned tile holds
        // `per_tile` copies of the state and one family-code charge
        for g in graph.groups() {
            let state = g.per_tile as u64 * g.kind.state_bytes() as u64;
            let fam = g.kind.family();
            for tile in g.span.iter() {
                charge(tile, state, fam, &mut mems);
            }
        }

        // exchange code + receive buffers, per exchange the program runs
        let mut max_recv = vec![0u64; tiles];
        for step in graph.program.steps() {
            if let ProgramStep::Exchange(ex) = step {
                let plan = graph.exchange(ex);
                let recv = plan.recv_per_tile(tiles);
                // one pass over transfers (not tiles x transfers — §Perf)
                let mut endpoints = vec![0u64; tiles];
                for t in &plan.transfers {
                    endpoints[t.src_tile] += 1;
                    endpoints[t.dst_tile] += 1;
                }
                for tile in 0..tiles {
                    if endpoints[tile] > 0 {
                        mems[tile].alloc_unchecked(
                            RegionKind::ExchangeCode,
                            endpoints[tile] * overheads::EXCHANGE_CODE_PER_TRANSFER,
                        );
                    }
                    max_recv[tile] = max_recv[tile].max(recv[tile]);
                }
            }
        }
        for (tile, tm) in mems.iter_mut().enumerate() {
            let buf = (max_recv[tile] as f64 * overheads::RECV_BUFFER_FACTOR) as u64;
            if buf > 0 {
                tm.alloc_unchecked(RegionKind::ExchangeBuffers, buf);
            }
        }

        let (max_tile, max_tile_used) = mems
            .iter()
            .enumerate()
            .map(|(i, m)| (i, m.used()))
            .max_by_key(|&(_, u)| u)
            .unwrap_or((0, 0));
        let total_used = mems.iter().map(|m| m.used()).sum();
        MemoryReport {
            per_tile: mems,
            max_tile_used,
            max_tile,
            total_used,
            capacity_per_tile: self.arch.tile_sram_bytes,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exchange::plan::{ExchangePattern, ExchangePlan};
    use crate::graph::program::Program;
    use crate::graph::tensor::DType;
    use crate::graph::vertex::VertexKind;
    use crate::memory::mapping::linear_balanced_mapping;

    fn arch() -> IpuArch {
        IpuArch::gc200()
    }

    fn graph_with_tensor(numel: usize) -> Graph {
        let a = arch();
        let mut g = Graph::new(a.tiles);
        let t = g.add_tensor("x", &[numel], DType::F32);
        g.set_tile_mapping(t, linear_balanced_mapping(numel, a.tiles));
        g
    }

    #[test]
    fn control_code_floor_everywhere() {
        let g = Graph::new(arch().tiles);
        let r = MemoryAccountant::new(&arch()).account(&g);
        assert_eq!(
            r.region_total(RegionKind::ControlCode),
            overheads::CONTROL_CODE_BYTES * arch().tiles as u64
        );
    }

    #[test]
    fn tensor_bytes_counted_once_total() {
        let g = graph_with_tensor(1472 * 100);
        let r = MemoryAccountant::new(&arch()).account(&g);
        assert_eq!(r.region_total(RegionKind::TensorData), 1472 * 100 * 4);
        assert!(r.fits());
    }

    #[test]
    fn vertex_state_and_family_code() {
        let mut g = Graph::new(arch().tiles);
        let cs = g.add_compute_set("c");
        for _ in 0..3 {
            g.add_vertex(cs, VertexKind::Zero { elems: 8 }, 5, vec![], vec![]);
        }
        let r = MemoryAccountant::new(&arch()).account(&g);
        let tile5 = &r.per_tile[5];
        assert_eq!(tile5.region(RegionKind::VertexCode), overheads::CODE_BYTES_PER_FAMILY);
        assert_eq!(
            tile5.region(RegionKind::VertexState),
            3 * VertexKind::Zero { elems: 8 }.state_bytes() as u64
        );
    }

    #[test]
    fn block_sparse_state_scales_with_worklist() {
        // the accountant prices BlockSparseMm like any codelet: per-vertex
        // state (CSR worklist entries) + one code charge for the family
        let mut g = Graph::new(arch().tiles);
        let cs = g.add_compute_set("bsmm");
        g.add_vertex(cs, VertexKind::BlockSparseMm { block: 8, nz_blocks: 100 }, 3, vec![], vec![]);
        let r = MemoryAccountant::new(&arch()).account(&g);
        let tile3 = &r.per_tile[3];
        assert_eq!(
            tile3.region(RegionKind::VertexState),
            VertexKind::BlockSparseMm { block: 8, nz_blocks: 100 }.state_bytes() as u64
        );
        assert_eq!(tile3.region(RegionKind::VertexCode), overheads::CODE_BYTES_PER_FAMILY);
    }

    #[test]
    fn grouped_vertices_account_identically_to_individual() {
        use crate::graph::vertex::TileSpan;
        let a = arch();
        let zero = VertexKind::Zero { elems: 8 };
        let reduce = VertexKind::Reduce { inputs: 4, width: 40 };
        let mut gi = Graph::new(a.tiles);
        let cs = gi.add_compute_set("c");
        for tile in 2..6 {
            for _ in 0..3 {
                gi.add_vertex(cs, zero.clone(), tile, vec![], vec![]);
            }
            gi.add_vertex(cs, reduce.clone(), tile, vec![], vec![]);
        }
        let mut gg = Graph::new(a.tiles);
        let cs = gg.add_compute_set("c");
        gg.add_vertex_group(cs, zero, TileSpan::range(2, 6), 3, vec![], vec![]);
        gg.add_vertex_group(cs, reduce, TileSpan::List(vec![2, 3, 4, 5]), 1, vec![], vec![]);
        let acct = MemoryAccountant::new(&a);
        let ri = acct.account(&gi);
        let rg = acct.account(&gg);
        assert_eq!(ri.max_tile_used, rg.max_tile_used);
        assert_eq!(ri.total_used, rg.total_used);
        assert_eq!(
            ri.region_total(RegionKind::VertexState),
            rg.region_total(RegionKind::VertexState)
        );
        assert_eq!(
            ri.region_total(RegionKind::VertexCode),
            rg.region_total(RegionKind::VertexCode)
        );
    }

    #[test]
    fn accountant_charges_planner_csr_residency_for_sparse_graphs() {
        // the sparse planner admits by `BlockCsr::residency_per_tile`;
        // the accountant must charge exactly that in TensorData for the
        // graph's CSR tensors — same equality discipline as the grouped
        // == individual pricing above. Dense B + C live in TensorData
        // too, so compare the summed CSR-tensor region directly.
        use crate::planner::partition::MmShape;
        use crate::sim::engine::SimEngine;
        use crate::sparse::csr::BlockCsr;
        use crate::sparse::pattern::{BlockPattern, PatternKind, SparsitySpec};
        use crate::sparse::planner::sparse_search;

        let a = arch();
        let engine = SimEngine::new(a.clone());
        let shape = MmShape::new(768, 1024, 512);
        let spec = SparsitySpec::new(PatternKind::Banded, 16, 0.4, 9);
        let pattern = BlockPattern::for_shape(spec, shape);
        let plan = sparse_search(&a, shape, &pattern).unwrap();
        let g = engine.build_sparse_graph(shape, &plan, &pattern);
        let report = MemoryAccountant::new(&a).account(&g);
        assert!(
            g.tensors().iter().any(|t| t.name == "A_csr_col"),
            "a 0.4-density pattern must take the CSR layout branch"
        );

        let csr = BlockCsr::from_pattern(&pattern);
        let expected = csr.residency_per_tile(a.tiles, 4);
        // the whole TensorData region minus the dense B and C shares is
        // the CSR footprint, per tile
        let dense_names = ["B", "C"];
        for (tile, want) in expected.iter().enumerate() {
            let dense_bytes: u64 = g
                .tensors()
                .iter()
                .filter(|t| dense_names.contains(&t.name.as_str()))
                .map(|t| t.bytes_on_tile(tile) as u64)
                .sum();
            let tensor_data = report.per_tile[tile].region(RegionKind::TensorData);
            assert_eq!(
                tensor_data - dense_bytes,
                *want,
                "tile {tile}: CSR TensorData diverges from planner residency"
            );
        }
        // totals: values + index, once across the chip
        assert_eq!(
            expected.iter().sum::<u64>(),
            csr.values_bytes(4) + csr.index_bytes()
        );
    }

    #[test]
    fn exchange_costs_show_up() {
        let mut g = Graph::new(arch().tiles);
        let mut plan = ExchangePlan::new("x", ExchangePattern::Broadcast);
        plan.add(0, 1, 1000);
        plan.add(0, 2, 1000);
        let ex = g.add_exchange(plan);
        g.set_program(Program::Exchange(ex));
        let r = MemoryAccountant::new(&arch()).account(&g);
        assert_eq!(r.per_tile[1].region(RegionKind::ExchangeBuffers), 1000);
        assert!(r.per_tile[0].region(RegionKind::ExchangeCode) > 0);
        // sender holds no receive buffer
        assert_eq!(r.per_tile[0].region(RegionKind::ExchangeBuffers), 0);
    }

    #[test]
    fn oversized_tensor_fails_fit() {
        // one tile's share exceeds 624 KiB: 1472 tiles * 700 KiB total
        let numel = arch().tiles * 180 * 1024; // 720 KiB/tile in f32
        let g = graph_with_tensor(numel);
        let r = MemoryAccountant::new(&arch()).account(&g);
        assert!(!r.fits());
        assert!(r.max_tile_fraction() > 1.0);
    }

    #[test]
    fn histogram_sums_to_tiles() {
        let g = graph_with_tensor(1000);
        let r = MemoryAccountant::new(&arch()).account(&g);
        let h = r.histogram(10);
        assert_eq!(h.iter().sum::<usize>(), arch().tiles);
    }

    #[test]
    fn repeated_exchange_buffers_use_max_not_sum() {
        let mut g = Graph::new(arch().tiles);
        let mut plan = ExchangePlan::new("x", ExchangePattern::Broadcast);
        plan.add(0, 1, 500);
        let ex = g.add_exchange(plan);
        g.set_program(Program::Repeat(5, Box::new(Program::Exchange(ex))));
        let r = MemoryAccountant::new(&arch()).account(&g);
        // buffer is reused across repeats: 500, not 2500
        assert_eq!(r.per_tile[1].region(RegionKind::ExchangeBuffers), 500);
    }
}
