//! Per-tile memory region allocator.
//!
//! Tracks how one tile's 624 KiB (GC200) splits across the categories
//! PopVision reports: tensor data, vertex state, codelet code, exchange
//! code and buffers, and control code. Over-commit is an error carrying
//! the full bill — the message a Poplar user sees as
//! "Out of memory on tile N".

use std::collections::BTreeMap;

use anyhow::{bail, Result};

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum RegionKind {
    TensorData,
    VertexState,
    VertexCode,
    ExchangeCode,
    ExchangeBuffers,
    ControlCode,
}

impl RegionKind {
    pub fn name(&self) -> &'static str {
        match self {
            RegionKind::TensorData => "tensor-data",
            RegionKind::VertexState => "vertex-state",
            RegionKind::VertexCode => "vertex-code",
            RegionKind::ExchangeCode => "exchange-code",
            RegionKind::ExchangeBuffers => "exchange-buffers",
            RegionKind::ControlCode => "control-code",
        }
    }

    pub fn all() -> [RegionKind; 6] {
        [
            RegionKind::TensorData,
            RegionKind::VertexState,
            RegionKind::VertexCode,
            RegionKind::ExchangeCode,
            RegionKind::ExchangeBuffers,
            RegionKind::ControlCode,
        ]
    }
}

#[derive(Clone, Debug)]
pub struct TileMemory {
    pub tile: usize,
    pub capacity: u64,
    regions: BTreeMap<RegionKind, u64>,
}

impl TileMemory {
    pub fn new(tile: usize, capacity: u64) -> TileMemory {
        TileMemory { tile, capacity, regions: BTreeMap::new() }
    }

    /// Reserve `bytes` in `kind`; errors with the full bill on overflow.
    pub fn alloc(&mut self, kind: RegionKind, bytes: u64) -> Result<()> {
        *self.regions.entry(kind).or_insert(0) += bytes;
        if self.used() > self.capacity {
            let bill = self.bill();
            bail!(
                "Out of memory on tile {}: need {} of {} bytes ({bill})",
                self.tile,
                self.used(),
                self.capacity
            );
        }
        Ok(())
    }

    /// Reserve without the capacity check (for what-if accounting).
    pub fn alloc_unchecked(&mut self, kind: RegionKind, bytes: u64) {
        *self.regions.entry(kind).or_insert(0) += bytes;
    }

    pub fn used(&self) -> u64 {
        self.regions.values().sum()
    }

    pub fn free(&self) -> i64 {
        self.capacity as i64 - self.used() as i64
    }

    pub fn fits(&self) -> bool {
        self.used() <= self.capacity
    }

    pub fn region(&self, kind: RegionKind) -> u64 {
        self.regions.get(&kind).copied().unwrap_or(0)
    }

    /// "tensor-data=1024 vertex-state=96 ..." (only non-zero regions).
    pub fn bill(&self) -> String {
        RegionKind::all()
            .iter()
            .filter_map(|k| {
                let v = self.region(*k);
                (v > 0).then(|| format!("{}={}", k.name(), v))
            })
            .collect::<Vec<_>>()
            .join(" ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_within_capacity() {
        let mut m = TileMemory::new(0, 1000);
        m.alloc(RegionKind::TensorData, 600).unwrap();
        m.alloc(RegionKind::VertexState, 300).unwrap();
        assert_eq!(m.used(), 900);
        assert_eq!(m.free(), 100);
        assert!(m.fits());
    }

    #[test]
    fn overflow_reports_bill() {
        let mut m = TileMemory::new(7, 100);
        m.alloc(RegionKind::TensorData, 80).unwrap();
        let e = m.alloc(RegionKind::ExchangeBuffers, 30).unwrap_err();
        let msg = e.to_string();
        assert!(msg.contains("Out of memory on tile 7"), "{msg}");
        assert!(msg.contains("tensor-data=80"), "{msg}");
        assert!(msg.contains("exchange-buffers=30"), "{msg}");
    }

    #[test]
    fn unchecked_alloc_allows_overcommit() {
        let mut m = TileMemory::new(0, 10);
        m.alloc_unchecked(RegionKind::ControlCode, 100);
        assert!(!m.fits());
        assert_eq!(m.free(), -90);
    }

    #[test]
    fn regions_accumulate() {
        let mut m = TileMemory::new(0, 1000);
        m.alloc(RegionKind::TensorData, 10).unwrap();
        m.alloc(RegionKind::TensorData, 15).unwrap();
        assert_eq!(m.region(RegionKind::TensorData), 25);
    }

    #[test]
    fn bill_skips_zero_regions() {
        let mut m = TileMemory::new(0, 100);
        m.alloc(RegionKind::VertexCode, 5).unwrap();
        assert_eq!(m.bill(), "vertex-code=5");
    }
}
