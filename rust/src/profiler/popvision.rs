//! Report rendering: SimReport -> PopVision-style text / JSON.

use crate::bsp::trace::Phase;
use crate::memory::tile_mem::RegionKind;
use crate::sim::report::SimReport;
use crate::util::json::Json;
use crate::util::units::{fmt_bytes, fmt_secs};

/// A rendered profile of one simulated run.
pub struct PopVisionReport<'a> {
    pub sim: &'a SimReport,
}

impl<'a> PopVisionReport<'a> {
    pub fn new(sim: &'a SimReport) -> Self {
        PopVisionReport { sim }
    }

    /// ASCII phase bar like the Fig. 3 timeline, proportional widths.
    pub fn phase_bar(&self, width: usize) -> String {
        let (c, s, e) = self.sim.trace.phase_fractions();
        let wc = (c * width as f64).round() as usize;
        let ws = (s * width as f64).round() as usize;
        let we = width.saturating_sub(wc + ws);
        format!(
            "[{}{}{}] compute {:.1}% | sync {:.1}% | exchange {:.1}%",
            "#".repeat(wc),
            "-".repeat(ws),
            "~".repeat(we),
            c * 100.0,
            s * 100.0,
            e * 100.0
        )
    }

    /// Full text report.
    pub fn to_text(&self) -> String {
        let sim = self.sim;
        let mut out = String::new();
        out.push_str(&format!("== PopVision-style profile: {}\n", sim.summary()));
        out.push_str(&format!(
            "   time {} | supersteps {} | tile utilisation {:.1}%\n",
            fmt_secs(sim.seconds),
            sim.trace.superstep_count(),
            sim.trace.tile_utilization() * 100.0
        ));
        out.push_str(&format!("   {}\n", self.phase_bar(48)));

        out.push_str("   vertex census:\n");
        for (family, count) in &sim.census {
            out.push_str(&format!("     {family:<12} {count}\n"));
        }
        out.push_str(&format!("     {:<12} {}\n", "TOTAL", sim.total_vertices));

        let mem = &sim.memory;
        out.push_str(&format!(
            "   memory: max tile {} of {} ({:.1}%), chip total {} ({:.1}%)\n",
            fmt_bytes(mem.max_tile_used),
            fmt_bytes(mem.capacity_per_tile),
            mem.max_tile_fraction() * 100.0,
            fmt_bytes(mem.total_used),
            mem.total_fraction() * 100.0
        ));
        let heaviest = &mem.per_tile[mem.max_tile];
        out.push_str(&format!(
            "   heaviest tile #{} bill: {}\n",
            mem.max_tile,
            heaviest.bill()
        ));
        out.push_str("   per-tile occupancy histogram (10% buckets): ");
        let hist = mem.histogram(10);
        out.push_str(
            &hist
                .iter()
                .map(|c| c.to_string())
                .collect::<Vec<_>>()
                .join(" "),
        );
        out.push('\n');
        out
    }

    /// Append the liveness view (memory-over-time) for a graph's program.
    pub fn liveness_text(profile: &crate::memory::liveness::LivenessProfile) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "   liveness: resident {} | transient-per-step: {}\n",
            fmt_bytes(profile.resident_bytes),
            profile.sparkline()
        ));
        if let Some(peak) = profile.peak() {
            out.push_str(&format!(
                "   liveness peak: step {} ({}) lands {} on the busiest tile\n",
                peak.step_index,
                peak.label,
                fmt_bytes(peak.peak_transient_tile_bytes)
            ));
        }
        out
    }

    /// Machine-readable JSON export.
    pub fn to_json(&self) -> Json {
        let sim = self.sim;
        let mut root = Json::obj();
        root.set("arch", sim.arch_name.as_str().into());
        let mut shape = Json::obj();
        shape.set("m", sim.shape.m.into());
        shape.set("n", sim.shape.n.into());
        shape.set("k", sim.shape.k.into());
        root.set("shape", shape);

        let p = sim.plan.partition();
        let mut plan = Json::obj();
        plan.set("pm", p.pm.into());
        plan.set("pn", p.pn.into());
        plan.set("pk", p.pk.into());
        plan.set("cn", p.cn.into());
        plan.set("tiles_used", p.tiles_used().into());
        root.set("plan", plan);

        let mut perf = Json::obj();
        perf.set("seconds", sim.seconds.into());
        perf.set("tflops", sim.tflops.into());
        perf.set("efficiency", sim.efficiency.into());
        perf.set("total_cycles", sim.plan.cost.total_cycles.into());
        perf.set("tile_utilization", sim.trace.tile_utilization().into());
        root.set("performance", perf);

        let (c, s, e) = sim.trace.phase_fractions();
        let mut phases = Json::obj();
        phases.set("compute", c.into());
        phases.set("sync", s.into());
        phases.set("exchange", e.into());
        phases.set(
            "compute_cycles",
            sim.trace.phase_cycles(Phase::Compute).into(),
        );
        phases.set("sync_cycles", sim.trace.phase_cycles(Phase::Sync).into());
        phases.set(
            "exchange_cycles",
            sim.trace.phase_cycles(Phase::Exchange).into(),
        );
        root.set("phases", phases);

        let mut census = Json::obj();
        for (family, count) in &sim.census {
            census.set(family, (*count).into());
        }
        census.set("total", sim.total_vertices.into());
        root.set("vertex_census", census);

        let mem = &sim.memory;
        let mut memory = Json::obj();
        memory.set("max_tile_bytes", mem.max_tile_used.into());
        memory.set("max_tile", mem.max_tile.into());
        memory.set("capacity_per_tile", mem.capacity_per_tile.into());
        memory.set("total_bytes", mem.total_used.into());
        memory.set("total_fraction", mem.total_fraction().into());
        memory.set("fits", mem.fits().into());
        let mut regions = Json::obj();
        for kind in RegionKind::all() {
            regions.set(kind.name(), mem.region_total(kind).into());
        }
        memory.set("region_totals", regions);
        memory.set(
            "histogram",
            mem.histogram(10)
                .into_iter()
                .collect::<Vec<usize>>()
                .into(),
        );
        root.set("memory", memory);
        root
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::IpuArch;
    use crate::planner::partition::MmShape;
    use crate::sim::engine::SimEngine;

    fn report_for(shape: MmShape) -> SimReport {
        SimEngine::new(IpuArch::gc200()).simulate_mm(shape).unwrap()
    }

    #[test]
    fn text_report_has_all_sections() {
        let sim = report_for(MmShape::square(1024));
        let text = PopVisionReport::new(&sim).to_text();
        assert!(text.contains("PopVision-style profile"));
        assert!(text.contains("vertex census"));
        assert!(text.contains("AmpMacc"));
        assert!(text.contains("memory: max tile"));
        assert!(text.contains("histogram"));
    }

    #[test]
    fn phase_bar_fractions_sum_to_100() {
        let sim = report_for(MmShape::square(512));
        let bar = PopVisionReport::new(&sim).phase_bar(40);
        assert!(bar.contains("compute"));
        assert!(bar.starts_with('['));
    }

    #[test]
    fn json_export_is_complete() {
        let sim = report_for(MmShape::square(1024));
        let json = PopVisionReport::new(&sim).to_json().render();
        for key in [
            "\"arch\"", "\"shape\"", "\"plan\"", "\"performance\"",
            "\"phases\"", "\"vertex_census\"", "\"memory\"",
        ] {
            assert!(json.contains(key), "missing {key} in {json}");
        }
    }

    #[test]
    fn json_census_total_matches() {
        let sim = report_for(MmShape::square(512));
        let json = PopVisionReport::new(&sim).to_json().render();
        assert!(json.contains(&format!("\"total\": {}", sim.total_vertices)));
    }

    #[test]
    fn split_reduction_census_shows_reduce_family() {
        let sim = report_for(MmShape::new(512, 16384, 2048));
        let text = PopVisionReport::new(&sim).to_text();
        assert!(text.contains("Reduce"), "{text}");
    }
}
