//! PopVision Graph Analyser analogue (paper §4.2, Fig. 3).
//!
//! Renders what the paper reads off PopVision for each run: the BSP phase
//! timeline (compute red / sync blue / exchange yellow), tile utilisation,
//! the vertex census behind Finding 2, and the per-tile memory breakdown
//! behind the §2.4 memory analysis. Text for terminals, JSON for tooling.

pub mod popvision;

pub use popvision::PopVisionReport;
