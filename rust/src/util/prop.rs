//! Property-based testing loop (proptest is not available offline).
//!
//! A property is a closure over a [`Rng`]-driven case generator; the runner
//! executes many cases with a deterministic seed ladder, and on failure
//! re-reports the exact seed so the case can be replayed in isolation:
//!
//! ```text
//! property 'plan fits memory' failed at case 17 (seed 0x11000011): ...
//! ```
//!
//! Shrinking is replaced by *sized* generation: early cases draw from small
//! ranges, later cases from the full range, so the first failure found is
//! usually already near-minimal.

use crate::util::rng::Rng;

pub struct PropConfig {
    pub cases: u32,
    pub base_seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        // IPUMM_PROP_CASES overrides for deeper local runs
        let cases = std::env::var("IPUMM_PROP_CASES")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(64);
        PropConfig { cases, base_seed: 0x5EED }
    }
}

/// Size knob in [0,1]: 0 for the first case, 1 for the last. Generators use
/// it to scale ranges so early failures are small.
#[derive(Clone, Copy, Debug)]
pub struct Size(pub f64);

impl Size {
    /// Interpolated inclusive upper bound: lo at size 0, hi at size 1.
    pub fn scale(&self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + ((hi - lo) as f64 * self.0).round() as usize
    }
}

/// Run `prop` for `config.cases` cases; panic with seed info on failure.
/// `prop` returns `Err(reason)` or panics to signal failure.
pub fn check<F>(name: &str, config: PropConfig, mut prop: F)
where
    F: FnMut(&mut Rng, Size) -> Result<(), String>,
{
    for case in 0..config.cases {
        let seed = config
            .base_seed
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(case as u64);
        let mut rng = Rng::new(seed);
        let size = Size(if config.cases <= 1 {
            1.0
        } else {
            case as f64 / (config.cases - 1) as f64
        });
        if let Err(reason) = prop(&mut rng, size) {
            panic!(
                "property '{name}' failed at case {case} (seed {seed:#x}): {reason}"
            );
        }
    }
}

/// Convenience: run with default config.
pub fn check_default<F>(name: &str, prop: F)
where
    F: FnMut(&mut Rng, Size) -> Result<(), String>,
{
    check(name, PropConfig::default(), prop);
}

/// Assert helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("always true", PropConfig { cases: 10, base_seed: 1 }, |_, _| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 10);
    }

    #[test]
    #[should_panic(expected = "property 'fails' failed at case 0")]
    fn failing_property_reports_case_and_seed() {
        check("fails", PropConfig { cases: 5, base_seed: 1 }, |_, _| {
            Err("boom".to_string())
        });
    }

    #[test]
    fn size_ramps_from_zero_to_one() {
        let mut sizes = Vec::new();
        check("sizes", PropConfig { cases: 3, base_seed: 1 }, |_, s| {
            sizes.push(s.0);
            Ok(())
        });
        assert_eq!(sizes, vec![0.0, 0.5, 1.0]);
    }

    #[test]
    fn size_scale_interpolates() {
        assert_eq!(Size(0.0).scale(1, 100), 1);
        assert_eq!(Size(1.0).scale(1, 100), 100);
        assert_eq!(Size(0.5).scale(0, 10), 5);
    }

    #[test]
    fn prop_assert_macro_returns_err() {
        fn body(x: i32) -> Result<(), String> {
            prop_assert!(x < 5, "x was {x}");
            Ok(())
        }
        assert!(body(3).is_ok());
        assert_eq!(body(9).unwrap_err(), "x was 9");
    }
}
