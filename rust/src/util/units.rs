//! Unit formatting and conversions used across reports: bytes, flops,
//! cycles<->seconds. The paper reports TFlop/s and MB; we keep both SI (MB)
//! and binary (MiB) explicit to avoid the GC200 918-vs-897 "MB" ambiguity.

pub const KIB: u64 = 1024;
pub const MIB: u64 = 1024 * 1024;
pub const GIB: u64 = 1024 * 1024 * 1024;

/// Matrix-multiply flop count under the paper's convention (§2.4):
/// A[m,n] x B[n,k] -> 2*m*n*k flops (multiply + add).
pub fn mm_flops(m: usize, n: usize, k: usize) -> u64 {
    2 * m as u64 * n as u64 * k as u64
}

/// Tera-flops/s from flops and seconds.
pub fn tflops(flops: u64, seconds: f64) -> f64 {
    assert!(seconds > 0.0, "tflops: non-positive time {seconds}");
    flops as f64 / seconds / 1e12
}

/// Cycles at a clock to seconds.
pub fn cycles_to_secs(cycles: u64, clock_hz: f64) -> f64 {
    cycles as f64 / clock_hz
}

/// Human bytes, binary units ("154.0 MiB").
pub fn fmt_bytes(bytes: u64) -> String {
    let b = bytes as f64;
    if bytes >= GIB {
        format!("{:.2} GiB", b / GIB as f64)
    } else if bytes >= MIB {
        format!("{:.1} MiB", b / MIB as f64)
    } else if bytes >= KIB {
        format!("{:.1} KiB", b / KIB as f64)
    } else {
        format!("{bytes} B")
    }
}

/// Human bytes, SI units ("154.0 MB") — what the paper's prose uses.
pub fn fmt_bytes_si(bytes: u64) -> String {
    let b = bytes as f64;
    if b >= 1e9 {
        format!("{:.2} GB", b / 1e9)
    } else if b >= 1e6 {
        format!("{:.1} MB", b / 1e6)
    } else if b >= 1e3 {
        format!("{:.1} kB", b / 1e3)
    } else {
        format!("{bytes} B")
    }
}

/// Seconds to a human string ("3.2 ms").
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} us", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// "12.34 TFlop/s"
pub fn fmt_tflops(t: f64) -> String {
    format!("{t:.2} TFlop/s")
}

/// Round `v` up to the next multiple of `m`.
pub fn round_up(v: usize, m: usize) -> usize {
    assert!(m > 0);
    v.div_ceil(m) * m
}

/// Ceiling division.
pub fn div_ceil(a: usize, b: usize) -> usize {
    assert!(b > 0);
    a.div_ceil(b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_flop_convention() {
        // 3584^3 squared MM = 92.09 Gflop * 2
        assert_eq!(mm_flops(3584, 3584, 3584), 2 * 3584u64.pow(3));
    }

    #[test]
    fn tflops_of_known_case() {
        // 62.5 TFlop/s peak: 62.5e12 flops in 1 s
        assert!((tflops(62_500_000_000_000, 1.0) - 62.5).abs() < 1e-9);
    }

    #[test]
    fn cycles_seconds_roundtrip() {
        let s = cycles_to_secs(1_330_000_000, 1.33e9);
        assert!((s - 1.0).abs() < 1e-9);
    }

    #[test]
    fn byte_formatting() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(154 * MIB), "154.0 MiB");
        assert_eq!(fmt_bytes_si(154_000_000), "154.0 MB");
        assert_eq!(fmt_bytes_si(918_000_000), "918.0 MB");
        assert_eq!(fmt_bytes_si(1_500_000_000), "1.50 GB");
    }

    #[test]
    fn secs_formatting() {
        assert_eq!(fmt_secs(0.0032), "3.200 ms");
        assert_eq!(fmt_secs(2.5), "2.500 s");
    }

    #[test]
    fn rounding_helpers() {
        assert_eq!(round_up(100, 128), 128);
        assert_eq!(round_up(128, 128), 128);
        assert_eq!(div_ceil(1, 128), 1);
        assert_eq!(div_ceil(0, 128), 0);
    }
}
