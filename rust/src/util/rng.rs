//! Deterministic PRNG (xoshiro256**) for synthetic workload data and the
//! property-testing loop. No external `rand` crate is available offline.

/// xoshiro256** — fast, high-quality, deterministic across platforms.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so any u64 (including 0) yields a good state.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in [-1, 1) — the synthetic matrix entry distribution.
    pub fn next_f32_unit(&mut self) -> f32 {
        (self.next_f64() * 2.0 - 1.0) as f32
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn gen_range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi, "gen_range: lo {lo} > hi {hi}");
        let span = hi - lo + 1;
        if span == 0 {
            // full u64 range
            return self.next_u64();
        }
        // Lemire-style rejection-free-enough reduction (bias < 2^-64*span).
        let hi128 = (self.next_u64() as u128 * span as u128) >> 64;
        lo + hi128 as u64
    }

    pub fn gen_usize(&mut self, lo: usize, hi: usize) -> usize {
        self.gen_range(lo as u64, hi as u64) as usize
    }

    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Pick an element of a non-empty slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty(), "choose on empty slice");
        &xs[self.gen_usize(0, xs.len() - 1)]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn range_bounds_inclusive() {
        let mut r = Rng::new(9);
        let mut saw_lo = false;
        let mut saw_hi = false;
        for _ in 0..2000 {
            let v = r.gen_range(3, 6);
            assert!((3..=6).contains(&v));
            saw_lo |= v == 3;
            saw_hi |= v == 6;
        }
        assert!(saw_lo && saw_hi, "uniformity smoke: endpoints reached");
    }

    #[test]
    fn range_single_point() {
        let mut r = Rng::new(11);
        assert_eq!(r.gen_range(5, 5), 5);
    }

    #[test]
    fn mean_is_roughly_half() {
        let mut r = Rng::new(13);
        let n = 10_000;
        let sum: f64 = (0..n).map(|_| r.next_f64()).sum();
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }
}
