//! Row-major f32 matrix used on the real compute path (runtime block
//! executor) and by the correctness oracles. Deliberately simple: the hot
//! math runs inside the PJRT executable, not here.

use crate::util::rng::Rng;

#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Matrix {
        assert_eq!(data.len(), rows * cols, "data length mismatch");
        Matrix { rows, cols, data }
    }

    /// Deterministic synthetic data in [-1, 1) — the benchmark workload.
    pub fn random(rows: usize, cols: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let data = (0..rows * cols).map(|_| rng.next_f32_unit()).collect();
        Matrix { rows, cols, data }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c] = v;
    }

    pub fn numel(&self) -> usize {
        self.rows * self.cols
    }

    pub fn bytes(&self) -> usize {
        self.numel() * 4
    }

    /// Copy of the block starting at (r0, c0), `br` x `bc`, zero-padded when
    /// it overhangs the matrix edge — the runtime's padding path.
    pub fn block_padded(&self, r0: usize, c0: usize, br: usize, bc: usize) -> Matrix {
        let mut out = Matrix::zeros(br, bc);
        let rmax = self.rows.saturating_sub(r0).min(br);
        let cmax = self.cols.saturating_sub(c0).min(bc);
        for r in 0..rmax {
            let src = (r0 + r) * self.cols + c0;
            let dst = r * bc;
            out.data[dst..dst + cmax].copy_from_slice(&self.data[src..src + cmax]);
        }
        out
    }

    /// `block_padded` into a caller-owned buffer (hot-path variant: the
    /// runtime block executor reuses two of these per reduction step
    /// instead of allocating — see EXPERIMENTS.md §Perf L3).
    pub fn block_padded_into(&self, r0: usize, c0: usize, out: &mut Matrix) {
        out.data.fill(0.0);
        let (br, bc) = (out.rows, out.cols);
        let rmax = self.rows.saturating_sub(r0).min(br);
        let cmax = self.cols.saturating_sub(c0).min(bc);
        for r in 0..rmax {
            let src = (r0 + r) * self.cols + c0;
            let dst = r * bc;
            out.data[dst..dst + cmax].copy_from_slice(&self.data[src..src + cmax]);
        }
    }

    /// Write `block`'s overlap into self at (r0, c0) (inverse of
    /// `block_padded`: drops the padded fringe).
    pub fn write_block(&mut self, r0: usize, c0: usize, block: &Matrix) {
        let rmax = self.rows.saturating_sub(r0).min(block.rows);
        let cmax = self.cols.saturating_sub(c0).min(block.cols);
        for r in 0..rmax {
            let dst = (r0 + r) * self.cols + c0;
            let src = r * block.cols;
            self.data[dst..dst + cmax].copy_from_slice(&block.data[src..src + cmax]);
        }
    }

    /// Naive triple-loop oracle (i-k-j order for locality). Ground truth for
    /// the PJRT path; only used in tests and verification modes.
    pub fn matmul_oracle(&self, b: &Matrix) -> Matrix {
        assert_eq!(self.cols, b.rows, "oracle: inner dims {} vs {}", self.cols, b.rows);
        let mut c = Matrix::zeros(self.rows, b.cols);
        for i in 0..self.rows {
            for l in 0..self.cols {
                let a_il = self.at(i, l);
                if a_il == 0.0 {
                    continue;
                }
                let brow = &b.data[l * b.cols..(l + 1) * b.cols];
                let crow = &mut c.data[i * b.cols..(i + 1) * b.cols];
                for (cv, bv) in crow.iter_mut().zip(brow) {
                    *cv += a_il * bv;
                }
            }
        }
        c
    }

    /// Max absolute elementwise difference.
    pub fn max_abs_diff(&self, other: &Matrix) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Approximate equality with an absolute tolerance scaled by the
    /// reduction length (fp32 accumulation-order noise).
    pub fn allclose(&self, other: &Matrix, atol: f32) -> bool {
        self.rows == other.rows
            && self.cols == other.cols
            && self.max_abs_diff(other) <= atol
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_access() {
        let mut m = Matrix::zeros(2, 3);
        m.set(1, 2, 5.0);
        assert_eq!(m.at(1, 2), 5.0);
        assert_eq!(m.numel(), 6);
        assert_eq!(m.bytes(), 24);
    }

    #[test]
    fn random_is_deterministic() {
        assert_eq!(Matrix::random(4, 4, 9), Matrix::random(4, 4, 9));
        assert_ne!(Matrix::random(4, 4, 9), Matrix::random(4, 4, 10));
    }

    #[test]
    fn block_roundtrip_interior() {
        let m = Matrix::random(8, 8, 1);
        let b = m.block_padded(2, 4, 3, 2);
        assert_eq!(b.at(0, 0), m.at(2, 4));
        assert_eq!(b.at(2, 1), m.at(4, 5));
    }

    #[test]
    fn block_pads_fringe_with_zeros() {
        let m = Matrix::random(5, 5, 2);
        let b = m.block_padded(4, 4, 3, 3);
        assert_eq!(b.at(0, 0), m.at(4, 4));
        assert_eq!(b.at(1, 1), 0.0);
        assert_eq!(b.at(2, 2), 0.0);
    }

    #[test]
    fn write_block_drops_fringe() {
        let mut m = Matrix::zeros(4, 4);
        let b = Matrix::from_vec(3, 3, vec![1.0; 9]);
        m.write_block(2, 2, &b);
        assert_eq!(m.at(2, 2), 1.0);
        assert_eq!(m.at(3, 3), 1.0);
        assert_eq!(m.at(1, 1), 0.0);
        // no panic from overhang; the fringe was dropped
    }

    #[test]
    fn oracle_identity() {
        let a = Matrix::random(5, 5, 3);
        let mut id = Matrix::zeros(5, 5);
        for i in 0..5 {
            id.set(i, i, 1.0);
        }
        let c = a.matmul_oracle(&id);
        assert!(c.allclose(&a, 1e-6));
    }

    #[test]
    fn oracle_known_2x2() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Matrix::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul_oracle(&b);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn oracle_rectangular() {
        let a = Matrix::random(3, 7, 4);
        let b = Matrix::random(7, 2, 5);
        let c = a.matmul_oracle(&b);
        assert_eq!((c.rows, c.cols), (3, 2));
        // spot-check one element
        let mut want = 0.0;
        for l in 0..7 {
            want += a.at(1, l) * b.at(l, 1);
        }
        assert!((c.at(1, 1) - want).abs() < 1e-5);
    }

    #[test]
    fn max_abs_diff_and_allclose() {
        let a = Matrix::from_vec(1, 2, vec![1.0, 2.0]);
        let b = Matrix::from_vec(1, 2, vec![1.0, 2.5]);
        assert_eq!(a.max_abs_diff(&b), 0.5);
        assert!(a.allclose(&b, 0.5));
        assert!(!a.allclose(&b, 0.4));
    }
}
