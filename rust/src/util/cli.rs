//! Minimal CLI argument parser (clap is not available offline).
//!
//! Grammar: `ipumm <subcommand> [positional...] [--key value] [--flag]`.
//! Subcommands declare their options; unknown options are hard errors so
//! typos never silently run the wrong experiment.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse raw argv (without the program name / subcommand), validating
    /// against declared option and flag names.
    pub fn parse(
        raw: &[String],
        allowed_options: &[&str],
        allowed_flags: &[&str],
    ) -> Result<Args> {
        let mut out = Args::default();
        let mut it = raw.iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if allowed_flags.contains(&name) {
                    out.flags.push(name.to_string());
                } else if allowed_options.contains(&name) {
                    let val = it
                        .next()
                        .with_context(|| format!("--{name} requires a value"))?;
                    out.options.insert(name.to_string(), val.clone());
                } else {
                    bail!(
                        "unknown option --{name}; valid options: {}, flags: {}",
                        fmt_list(allowed_options),
                        fmt_list(allowed_flags)
                    );
                }
            } else {
                out.positional.push(tok.clone());
            }
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn opt_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.opt(name).unwrap_or(default)
    }

    pub fn opt_usize(&self, name: &str, default: usize) -> Result<usize> {
        Ok(self.opt_usize_opt(name)?.unwrap_or(default))
    }

    /// Optional integer option with no default — `None` when absent, so
    /// the callee can apply its own policy (e.g. worker-pool sizing).
    pub fn opt_usize_opt(&self, name: &str) -> Result<Option<usize>> {
        match self.opt(name) {
            None => Ok(None),
            Some(v) => v
                .parse::<usize>()
                .map(Some)
                .with_context(|| format!("--{name} expects an integer, got '{v}'")),
        }
    }

    pub fn opt_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v
                .parse::<f64>()
                .with_context(|| format!("--{name} expects a number, got '{v}'")),
        }
    }

    /// Positional argument parsed as usize.
    pub fn pos_usize(&self, idx: usize, what: &str) -> Result<usize> {
        let v = self
            .positional
            .get(idx)
            .with_context(|| format!("missing positional argument <{what}>"))?;
        v.parse::<usize>()
            .with_context(|| format!("<{what}> expects an integer, got '{v}'"))
    }
}

fn fmt_list(xs: &[&str]) -> String {
    if xs.is_empty() {
        "(none)".to_string()
    } else {
        xs.iter().map(|x| format!("--{x}")).collect::<Vec<_>>().join(" ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn raw(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_positional_options_flags() {
        let a = Args::parse(
            &raw(&["3584", "--arch", "gc200", "--real"]),
            &["arch"],
            &["real"],
        )
        .unwrap();
        assert_eq!(a.positional, vec!["3584"]);
        assert_eq!(a.opt("arch"), Some("gc200"));
        assert!(a.flag("real"));
        assert!(!a.flag("json"));
    }

    #[test]
    fn unknown_option_is_error() {
        let e = Args::parse(&raw(&["--bogus", "1"]), &["arch"], &[]).unwrap_err();
        assert!(e.to_string().contains("unknown option --bogus"));
    }

    #[test]
    fn missing_value_is_error() {
        let e = Args::parse(&raw(&["--arch"]), &["arch"], &[]).unwrap_err();
        assert!(e.to_string().contains("requires a value"));
    }

    #[test]
    fn typed_accessors() {
        let a = Args::parse(&raw(&["--k", "2048"]), &["k"], &[]).unwrap();
        assert_eq!(a.opt_usize("k", 0).unwrap(), 2048);
        assert_eq!(a.opt_usize("missing", 7).unwrap(), 7);
        assert!(Args::parse(&raw(&["--k", "xyz"]), &["k"], &[])
            .unwrap()
            .opt_usize("k", 0)
            .is_err());
    }

    #[test]
    fn positional_typed() {
        let a = Args::parse(&raw(&["128", "256"]), &[], &[]).unwrap();
        assert_eq!(a.pos_usize(0, "m").unwrap(), 128);
        assert_eq!(a.pos_usize(1, "n").unwrap(), 256);
        assert!(a.pos_usize(2, "k").is_err());
    }
}
