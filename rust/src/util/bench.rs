//! Micro-benchmark harness (criterion is not available offline).
//!
//! `cargo bench` targets are `harness = false` binaries that call
//! [`Bench::run`] per case: warmup, timed iterations, outlier-robust
//! summary, criterion-like one-line output, and optional CSV dump so
//! EXPERIMENTS.md tables can be regenerated from bench runs.

use std::time::Instant;

use crate::util::json::Json;
use crate::util::stats::Summary;
use crate::util::units::fmt_secs;

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub summary: Summary,
    /// user-defined throughput value (e.g. model TFlop/s) attached via
    /// `Bench::throughput`
    pub throughput: Option<(f64, &'static str)>,
}

pub struct Bench {
    pub group: String,
    warmup_iters: u32,
    sample_iters: u32,
    results: Vec<BenchResult>,
}

impl Bench {
    pub fn new(group: &str) -> Bench {
        // fast-bench escape hatch for CI: IPUMM_BENCH_FAST=1 shrinks runs
        let fast = std::env::var("IPUMM_BENCH_FAST").is_ok();
        Bench {
            group: group.to_string(),
            warmup_iters: if fast { 1 } else { 3 },
            sample_iters: if fast { 5 } else { 20 },
            results: Vec::new(),
        }
    }

    pub fn with_iters(mut self, warmup: u32, samples: u32) -> Bench {
        self.warmup_iters = warmup;
        self.sample_iters = samples.max(1);
        self
    }

    /// Time `f` (its return value is black-boxed) and record a result row.
    pub fn run<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &BenchResult {
        for _ in 0..self.warmup_iters {
            black_box(f());
        }
        let mut samples = Vec::with_capacity(self.sample_iters as usize);
        for _ in 0..self.sample_iters {
            let t0 = Instant::now();
            black_box(f());
            samples.push(t0.elapsed().as_secs_f64());
        }
        let summary = Summary::of(&samples);
        println!(
            "{}/{:<40} time: [{} {} {}] (n={}, cv={:.1}%)",
            self.group,
            name,
            fmt_secs(summary.min),
            fmt_secs(summary.mean),
            fmt_secs(summary.max),
            summary.n,
            summary.cv() * 100.0
        );
        self.results.push(BenchResult {
            name: name.to_string(),
            summary,
            throughput: None,
        });
        self.results.last().expect("just pushed")
    }

    /// Attach a throughput annotation to the most recent result.
    pub fn throughput(&mut self, value: f64, unit: &'static str) {
        if let Some(last) = self.results.last_mut() {
            last.throughput = Some((value, unit));
            println!(
                "{}/{:<40} thrpt: {value:.3} {unit}",
                self.group, last.name
            );
        }
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// CSV of all results: name,mean_s,stddev_s,min_s,max_s,throughput,unit
    pub fn to_csv(&self) -> String {
        let mut out = String::from("name,mean_s,stddev_s,min_s,max_s,throughput,unit\n");
        for r in &self.results {
            let (tp, unit) = r
                .throughput
                .map(|(v, u)| (format!("{v}"), u))
                .unwrap_or((String::new(), ""));
            out.push_str(&format!(
                "{},{},{},{},{},{},{}\n",
                r.name,
                r.summary.mean,
                r.summary.stddev,
                r.summary.min,
                r.summary.max,
                tp,
                unit
            ));
        }
        out
    }

    /// Machine-readable twin of [`Self::to_csv`]: one deterministic JSON
    /// document per bench group, so the perf trajectory can be tracked
    /// across commits without parsing bench stdout.
    pub fn to_json(&self) -> Json {
        let mut doc = Json::obj();
        doc.set("group", self.group.as_str().into());
        let mut results = Json::Arr(vec![]);
        for r in &self.results {
            let mut o = Json::obj();
            o.set("name", r.name.as_str().into());
            o.set("mean_s", r.summary.mean.into());
            o.set("stddev_s", r.summary.stddev.into());
            o.set("min_s", r.summary.min.into());
            o.set("max_s", r.summary.max.into());
            o.set("n", r.summary.n.into());
            match r.throughput {
                Some((value, unit)) => {
                    o.set("throughput", value.into());
                    o.set("throughput_unit", unit.into());
                }
                None => {
                    o.set("throughput", Json::Null);
                }
            }
            results.push(o);
        }
        doc.set("results", results);
        doc
    }

    /// Should `BENCH_<group>.json` be written? Any non-empty
    /// `IPUMM_BENCH_JSON` other than `0` opts in explicitly (`1`, `true`,
    /// ...), `IPUMM_BENCH_JSON=0` (or empty) opts out explicitly, and
    /// when the variable is unset **any CI environment emits
    /// unconditionally** (`CI` is set by GitHub Actions and most other
    /// providers) — the perf trajectory must accumulate per commit even
    /// when a workflow step forgets the env var (the satellite
    /// regression: three benched PRs produced an empty trajectory
    /// because the var was scoped to one step).
    fn json_dump_enabled() -> bool {
        match std::env::var("IPUMM_BENCH_JSON").ok().as_deref() {
            Some("0") | Some("") => false,
            Some(_) => true,
            None => std::env::var_os("CI").is_some(),
        }
    }

    /// Write `BENCH_<group>.json` at the repo root when
    /// [`Self::json_dump_enabled`] says so (explicit opt-in/out via
    /// `IPUMM_BENCH_JSON`, unconditional under CI); default local runs
    /// touch nothing outside `target/`. The repo root is the crate
    /// manifest dir, so the file lands in the same place no matter where
    /// the bench runs from.
    pub fn dump_json(&self) {
        if !Self::json_dump_enabled() {
            return;
        }
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join(format!("BENCH_{}.json", self.group.replace('/', "_")));
        if let Err(e) = std::fs::write(&path, self.to_json().render()) {
            eprintln!("warn: could not write {}: {e}", path.display());
        } else {
            println!("(json -> {})", path.display());
        }
    }

    /// Write the CSV next to `target/` so bench outputs are collectable
    /// (and the JSON dump when `IPUMM_BENCH_JSON=1`).
    pub fn dump_csv(&self) {
        let dir = std::path::Path::new("target/bench-results");
        if std::fs::create_dir_all(dir).is_ok() {
            let path = dir.join(format!("{}.csv", self.group.replace('/', "_")));
            if let Err(e) = std::fs::write(&path, self.to_csv()) {
                eprintln!("warn: could not write {}: {e}", path.display());
            } else {
                println!("(csv -> {})", path.display());
            }
        }
        self.dump_json();
    }
}

/// Prevent the optimizer from deleting benchmarked work (stable-rust
/// equivalent of `std::hint::black_box` — which we use directly; kept as a
/// named wrapper so call sites read like criterion).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// One `ipumm bench-check` verdict: a benchmark row compared against its
/// in-run baseline twin.
#[derive(Clone, Debug)]
pub struct RegressionVerdict {
    pub group: String,
    pub name: String,
    pub baseline_mean_s: f64,
    pub mean_s: f64,
    /// `mean / baseline` — above `1 + tolerance` fails the gate.
    pub ratio: f64,
    pub regressed: bool,
}

/// The CI regression gate's core: scan one `BENCH_<group>.json` document
/// for `<name>_baseline` / `<name>` row pairs (the in-run seed baselines
/// `bench_planner.rs` / `bench_sparse.rs` freeze) and compare means. A
/// row regresses when `mean > baseline_mean * (1 + tolerance)` — e.g.
/// `tolerance = 0.2` is the ">20% cold-plan latency regression" gate.
/// Returns an error only for malformed documents; an empty verdict list
/// means the file had no baseline pairs.
pub fn regression_verdicts(doc: &Json, tolerance: f64) -> Result<Vec<RegressionVerdict>, String> {
    let (group, means) = doc_means(doc)?;
    let mut out = Vec::new();
    for (name, baseline_mean_s) in &means {
        let Some(current) = name.strip_suffix("_baseline") else {
            continue;
        };
        let Some((_, mean_s)) = means.iter().find(|(n, _)| n == current) else {
            continue; // a baseline row without a current twin is not a gate
        };
        let ratio = if *baseline_mean_s > 0.0 { mean_s / baseline_mean_s } else { f64::INFINITY };
        out.push(RegressionVerdict {
            group: group.clone(),
            name: current.to_string(),
            baseline_mean_s: *baseline_mean_s,
            mean_s: *mean_s,
            ratio,
            regressed: ratio > 1.0 + tolerance,
        });
    }
    Ok(out)
}

/// Parse one `BENCH_<group>.json` document into `(group, [(name,
/// mean_s)])` — shared by the in-run and cross-run gates.
fn doc_means(doc: &Json) -> Result<(String, Vec<(String, f64)>), String> {
    let group = doc
        .get("group")
        .and_then(Json::as_str)
        .ok_or("missing 'group'")?
        .to_string();
    let results = doc
        .get("results")
        .and_then(Json::items)
        .ok_or("missing 'results' array")?;
    let mut means: Vec<(String, f64)> = Vec::with_capacity(results.len());
    for row in results {
        let name = row
            .get("name")
            .and_then(Json::as_str)
            .ok_or("result row missing 'name'")?;
        let mean = row
            .get("mean_s")
            .and_then(Json::as_f64)
            .ok_or_else(|| format!("result '{name}' missing 'mean_s'"))?;
        means.push((name.to_string(), mean));
    }
    Ok((group, means))
}

/// One `ipumm bench-check --against <dir>` verdict: a benchmark row in
/// the current run compared to the same row in a previous run's
/// artifact.
#[derive(Clone, Debug)]
pub struct TrendVerdict {
    pub group: String,
    pub name: String,
    /// Previous run's mean (raw seconds).
    pub prev_s: f64,
    /// Current run's mean (raw seconds).
    pub current_s: f64,
    /// The gated quantity. When both runs carry a `<name>_baseline`
    /// twin this is the ratio of baseline-normalized means —
    /// `(cur/cur_base) / (prev/prev_base)` — so absolute machine speed
    /// cancels and only the benchmark's cost *relative to its frozen
    /// seed baseline* is compared across runs. Without baseline twins
    /// it is the raw `cur/prev` ratio.
    pub drift: f64,
    /// True when `drift` was baseline-normalized (and therefore
    /// machine-speed-robust enough to gate on).
    pub normalized: bool,
    /// Only normalized rows regress; raw rows are advisory, because two
    /// CI hosts can legitimately differ by more than any tolerance.
    pub regressed: bool,
}

/// The cross-run trend gate: compare the current `BENCH_<group>.json`
/// against the same group's document from a previous run (restored from
/// the CI cache by branch). Rows present in both runs produce one
/// [`TrendVerdict`] each; rows whose runs both carry a positive
/// `<name>_baseline` twin are baseline-normalized and gate at
/// `drift > 1 + tolerance`, the rest are advisory (`regressed` stays
/// false). `_baseline` rows themselves never produce verdicts.
pub fn trend_verdicts(
    current: &Json,
    previous: &Json,
    tolerance: f64,
) -> Result<Vec<TrendVerdict>, String> {
    let (group, cur) = doc_means(current)?;
    let (prev_group, prev) = doc_means(previous)?;
    if group != prev_group {
        return Err(format!(
            "group mismatch: current '{group}' vs previous '{prev_group}'"
        ));
    }
    let mean_of = |rows: &[(String, f64)], name: &str| -> Option<f64> {
        rows.iter().find(|(n, _)| n == name).map(|(_, m)| *m)
    };
    let mut out = Vec::new();
    for (name, current_s) in &cur {
        if name.ends_with("_baseline") {
            continue;
        }
        let Some(prev_s) = mean_of(&prev, name) else {
            continue; // new benchmark this run: nothing to compare
        };
        let base = format!("{name}_baseline");
        let bases = (mean_of(&cur, &base), mean_of(&prev, &base));
        let (drift, normalized) = match bases {
            (Some(cb), Some(pb)) if cb > 0.0 && pb > 0.0 && prev_s > 0.0 => {
                ((current_s / cb) / (prev_s / pb), true)
            }
            _ if prev_s > 0.0 => (current_s / prev_s, false),
            _ => (f64::INFINITY, false),
        };
        out.push(TrendVerdict {
            group: group.clone(),
            name: name.clone(),
            prev_s,
            current_s: *current_s,
            drift,
            normalized,
            regressed: normalized && drift > 1.0 + tolerance,
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_and_records() {
        let mut b = Bench::new("test").with_iters(1, 3);
        b.run("noop", || 1 + 1);
        assert_eq!(b.results().len(), 1);
        assert_eq!(b.results()[0].summary.n, 3);
    }

    #[test]
    fn throughput_attaches_to_last() {
        let mut b = Bench::new("test").with_iters(0, 2);
        b.run("x", || ());
        b.throughput(12.5, "TFlop/s");
        assert_eq!(b.results()[0].throughput, Some((12.5, "TFlop/s")));
    }

    #[test]
    fn csv_has_header_and_rows() {
        let mut b = Bench::new("test").with_iters(0, 2);
        b.run("alpha", || ());
        let csv = b.to_csv();
        assert!(csv.starts_with("name,mean_s"));
        assert!(csv.contains("alpha,"));
    }

    #[test]
    fn json_mirrors_results() {
        let mut b = Bench::new("test").with_iters(0, 2);
        b.run("alpha", || ());
        b.throughput(3.5, "x");
        b.run("beta", || ());
        let json = b.to_json().render();
        assert!(json.contains("\"group\": \"test\""));
        assert!(json.contains("\"name\": \"alpha\""));
        assert!(json.contains("\"throughput\": 3.5"));
        assert!(json.contains("\"throughput_unit\": \"x\""));
        assert!(json.contains("\"name\": \"beta\""));
        assert!(json.contains("\"mean_s\""));
    }

    #[test]
    fn json_dump_is_env_gated() {
        // without IPUMM_BENCH_JSON=1 (and outside CI, where the dump is
        // unconditional), dump_json must write nothing
        if Bench::json_dump_enabled() {
            return; // the gate is open in this environment; nothing to test
        }
        let mut b = Bench::new("envgate-test").with_iters(0, 1);
        b.run("x", || ());
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("BENCH_envgate-test.json");
        let _ = std::fs::remove_file(&path);
        b.dump_json();
        assert!(!path.exists(), "dump_json must be a no-op without the env var");
    }

    fn bench_doc(rows: &[(&str, f64)]) -> Json {
        let mut doc = Json::obj();
        doc.set("group", "planner".into());
        let mut results = Json::Arr(vec![]);
        for (name, mean) in rows {
            let mut o = Json::obj();
            o.set("name", (*name).into());
            o.set("mean_s", (*mean).into());
            results.push(o);
        }
        doc.set("results", results);
        doc
    }

    #[test]
    fn regression_verdicts_pair_baselines() {
        let doc = bench_doc(&[
            ("search_3584_baseline", 0.010),
            ("search_3584", 0.004),     // 2.5x faster: passes
            ("wall_baseline", 0.008),
            ("wall", 0.012),            // 1.5x slower: regresses at 20%
            ("unpaired_baseline", 1.0), // no current twin: skipped
            ("loose_row", 0.5),         // no baseline: skipped
        ]);
        let verdicts = regression_verdicts(&doc, 0.2).unwrap();
        assert_eq!(verdicts.len(), 2);
        let search = verdicts.iter().find(|v| v.name == "search_3584").unwrap();
        assert!(!search.regressed);
        assert!((search.ratio - 0.4).abs() < 1e-12);
        let wall = verdicts.iter().find(|v| v.name == "wall").unwrap();
        assert!(wall.regressed, "ratio {} must fail the 20% gate", wall.ratio);
        assert_eq!(wall.group, "planner");
    }

    #[test]
    fn regression_tolerance_is_inclusive_at_the_boundary() {
        // exactly +20% does not regress; anything above does
        let doc = bench_doc(&[("x_baseline", 1.0), ("x", 1.2)]);
        assert!(!regression_verdicts(&doc, 0.2).unwrap()[0].regressed);
        let doc = bench_doc(&[("x_baseline", 1.0), ("x", 1.2001)]);
        assert!(regression_verdicts(&doc, 0.2).unwrap()[0].regressed);
    }

    #[test]
    fn regression_verdicts_reject_malformed_docs() {
        assert!(regression_verdicts(&Json::obj(), 0.2).is_err());
        let mut doc = Json::obj();
        doc.set("group", "g".into());
        assert!(regression_verdicts(&doc, 0.2).is_err(), "missing results");
        let mut row = Json::obj();
        row.set("name", "x".into()); // no mean_s
        let mut doc = bench_doc(&[]);
        match &mut doc {
            Json::Obj(m) => {
                m.insert("results".into(), Json::Arr(vec![row]));
            }
            _ => unreachable!(),
        }
        assert!(regression_verdicts(&doc, 0.2).is_err());
    }

    #[test]
    fn regression_verdicts_round_trip_through_bench_json() {
        // the real pipeline: Bench -> to_json -> render -> parse -> gate
        let spin = || {
            let mut acc = 0u64;
            for i in 0..50_000u64 {
                acc = acc.wrapping_add(black_box(i));
            }
            acc
        };
        let mut b = Bench::new("planner").with_iters(0, 2);
        b.run("probe_baseline", spin);
        b.run("probe", spin);
        let parsed = Json::parse(&b.to_json().render()).unwrap();
        let verdicts = regression_verdicts(&parsed, 10.0).unwrap();
        assert_eq!(verdicts.len(), 1);
        assert_eq!(verdicts[0].name, "probe");
        assert!(!verdicts[0].regressed, "10x tolerance cannot fail on noise");
    }

    #[test]
    fn trend_verdicts_normalize_out_machine_speed() {
        // previous run on a fast machine, current on a 2x slower one:
        // every raw mean doubled, but relative to its own baseline the
        // benchmark is unchanged -> drift 1.0, no regression
        let prev = bench_doc(&[("search_baseline", 0.010), ("search", 0.005)]);
        let cur = bench_doc(&[("search_baseline", 0.020), ("search", 0.010)]);
        let v = trend_verdicts(&cur, &prev, 0.2).unwrap();
        assert_eq!(v.len(), 1);
        assert!(v[0].normalized);
        assert!((v[0].drift - 1.0).abs() < 1e-12);
        assert!(!v[0].regressed);
    }

    #[test]
    fn trend_verdicts_catch_real_relative_drift() {
        // same machine speed (baselines equal), benchmark got 60% slower
        let prev = bench_doc(&[("search_baseline", 0.010), ("search", 0.005)]);
        let cur = bench_doc(&[("search_baseline", 0.010), ("search", 0.008)]);
        let v = trend_verdicts(&cur, &prev, 0.2).unwrap();
        assert!(v[0].normalized);
        assert!((v[0].drift - 1.6).abs() < 1e-12);
        assert!(v[0].regressed);
    }

    #[test]
    fn trend_verdicts_without_baselines_are_advisory() {
        let prev = bench_doc(&[("observe_100k", 0.002)]);
        let cur = bench_doc(&[("observe_100k", 0.040)]); // 20x slower host
        let v = trend_verdicts(&cur, &prev, 0.2).unwrap();
        assert_eq!(v.len(), 1);
        assert!(!v[0].normalized);
        assert!((v[0].drift - 20.0).abs() < 1e-9);
        assert!(!v[0].regressed, "raw cross-run ratios must never gate");
    }

    #[test]
    fn trend_verdicts_skip_unmatched_rows_and_reject_group_mismatch() {
        let prev = bench_doc(&[("old_only", 1.0)]);
        let cur = bench_doc(&[("new_only", 1.0)]);
        assert!(trend_verdicts(&cur, &prev, 0.2).unwrap().is_empty());
        let mut other = bench_doc(&[]);
        other.set("group", "sparse".into());
        assert!(trend_verdicts(&other, &prev, 0.2).is_err());
    }

    #[test]
    fn timer_measures_something() {
        let mut b = Bench::new("test").with_iters(0, 3);
        let r = b.run("spin", || {
            let mut acc = 0u64;
            for i in 0..10_000u64 {
                acc = acc.wrapping_add(black_box(i));
            }
            acc
        });
        assert!(r.summary.mean > 0.0);
    }
}
