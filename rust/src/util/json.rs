//! Minimal JSON document model for profiler exports (PopVision analogue
//! dumps) and bench-trajectory files. No serde available offline; the
//! writer covers the dumps and [`Json::parse`] covers the one consumer in
//! the tree (`ipumm bench-check` reading its own `BENCH_*.json` output —
//! the artifact manifest stays TSV by design).

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Int(i64),
    Str(String),
    Arr(Vec<Json>),
    // BTreeMap for deterministic key order — reports must diff cleanly.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(&mut self, key: &str, val: Json) -> &mut Json {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), val);
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    pub fn push(&mut self, val: Json) -> &mut Json {
        match self {
            Json::Arr(v) => v.push(val),
            _ => panic!("Json::push on non-array"),
        }
        self
    }

    pub fn render(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0);
        s
    }

    fn write(&self, out: &mut String, indent: usize) {
        let pad = "  ".repeat(indent);
        let pad_in = "  ".repeat(indent + 1);
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => {
                let _ = write!(out, "{b}");
            }
            Json::Int(i) => {
                let _ = write!(out, "{i}");
            }
            Json::Num(n) => {
                if n.is_finite() {
                    let _ = write!(out, "{n}");
                } else {
                    // JSON has no Inf/NaN; encode as null like most emitters
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                if v.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push_str("[\n");
                for (i, item) in v.iter().enumerate() {
                    out.push_str(&pad_in);
                    item.write(out, indent + 1);
                    if i + 1 < v.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                out.push_str(&pad);
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    out.push_str(&pad_in);
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write(out, indent + 1);
                    if i + 1 < m.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                out.push_str(&pad);
                out.push('}');
            }
        }
    }
}

impl Json {
    /// Parse one JSON document (strict enough for round-tripping
    /// [`Json::render`] output; numbers with a `.`, exponent, or that
    /// overflow `i64` parse as [`Json::Num`], the rest as [`Json::Int`]).
    /// Errors carry the byte offset of the failure.
    pub fn parse(text: &str) -> Result<Json, String> {
        let bytes = text.as_bytes();
        let mut pos = 0usize;
        let value = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            return Err(format!("trailing data at byte {pos}"));
        }
        Ok(value)
    }

    /// Object field accessor (`None` on non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Array elements (`None` on non-arrays).
    pub fn items(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// String payload (`None` on non-strings).
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Numeric payload, unifying [`Json::Num`] and [`Json::Int`].
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            Json::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
}

fn skip_ws(bytes: &[u8], pos: &mut usize) {
    while *pos < bytes.len() && matches!(bytes[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn expect(bytes: &[u8], pos: &mut usize, lit: &str) -> Result<(), String> {
    if bytes[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(())
    } else {
        Err(format!("expected '{lit}' at byte {pos}", pos = *pos))
    }
}

fn parse_value(bytes: &[u8], pos: &mut usize) -> Result<Json, String> {
    skip_ws(bytes, pos);
    match bytes.get(*pos) {
        None => Err("unexpected end of input".to_string()),
        Some(b'n') => expect(bytes, pos, "null").map(|()| Json::Null),
        Some(b't') => expect(bytes, pos, "true").map(|()| Json::Bool(true)),
        Some(b'f') => expect(bytes, pos, "false").map(|()| Json::Bool(false)),
        Some(b'"') => parse_string(bytes, pos).map(Json::Str),
        Some(b'[') => {
            *pos += 1;
            let mut items = Vec::new();
            skip_ws(bytes, pos);
            if bytes.get(*pos) == Some(&b']') {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            loop {
                items.push(parse_value(bytes, pos)?);
                skip_ws(bytes, pos);
                match bytes.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b']') => {
                        *pos += 1;
                        return Ok(Json::Arr(items));
                    }
                    _ => return Err(format!("expected ',' or ']' at byte {}", *pos)),
                }
            }
        }
        Some(b'{') => {
            *pos += 1;
            let mut map = BTreeMap::new();
            skip_ws(bytes, pos);
            if bytes.get(*pos) == Some(&b'}') {
                *pos += 1;
                return Ok(Json::Obj(map));
            }
            loop {
                skip_ws(bytes, pos);
                let key = parse_string(bytes, pos)?;
                skip_ws(bytes, pos);
                expect(bytes, pos, ":")?;
                map.insert(key, parse_value(bytes, pos)?);
                skip_ws(bytes, pos);
                match bytes.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b'}') => {
                        *pos += 1;
                        return Ok(Json::Obj(map));
                    }
                    _ => return Err(format!("expected ',' or '}}' at byte {}", *pos)),
                }
            }
        }
        Some(_) => parse_number(bytes, pos),
    }
}

fn parse_string(bytes: &[u8], pos: &mut usize) -> Result<String, String> {
    if bytes.get(*pos) != Some(&b'"') {
        return Err(format!("expected string at byte {}", *pos));
    }
    *pos += 1;
    let mut out = String::new();
    loop {
        match bytes.get(*pos) {
            None => return Err("unterminated string".to_string()),
            Some(b'"') => {
                *pos += 1;
                return Ok(out);
            }
            Some(b'\\') => {
                *pos += 1;
                match bytes.get(*pos) {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let hex = bytes
                            .get(*pos + 1..*pos + 5)
                            .and_then(|h| std::str::from_utf8(h).ok())
                            .ok_or_else(|| format!("bad \\u escape at byte {}", *pos))?;
                        let code = u32::from_str_radix(hex, 16)
                            .map_err(|_| format!("bad \\u escape at byte {}", *pos))?;
                        out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                        *pos += 4;
                    }
                    _ => return Err(format!("bad escape at byte {}", *pos)),
                }
                *pos += 1;
            }
            Some(&b) => {
                // copy one UTF-8 scalar (the input came from a &str, so
                // the leading byte determines the sequence length)
                let len = match b {
                    0x00..=0x7F => 1,
                    0xC0..=0xDF => 2,
                    0xE0..=0xEF => 3,
                    _ => 4,
                };
                let chunk = bytes
                    .get(*pos..*pos + len)
                    .and_then(|c| std::str::from_utf8(c).ok())
                    .ok_or_else(|| format!("invalid UTF-8 at byte {}", *pos))?;
                out.push_str(chunk);
                *pos += len;
            }
        }
    }
}

fn parse_number(bytes: &[u8], pos: &mut usize) -> Result<Json, String> {
    let start = *pos;
    while *pos < bytes.len()
        && matches!(bytes[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
    {
        *pos += 1;
    }
    let text = std::str::from_utf8(&bytes[start..*pos]).map_err(|e| e.to_string())?;
    if text.is_empty() {
        return Err(format!("expected a value at byte {start}"));
    }
    let is_float = text.contains(['.', 'e', 'E']);
    if !is_float {
        if let Ok(i) = text.parse::<i64>() {
            return Ok(Json::Int(i));
        }
    }
    text.parse::<f64>()
        .map(Json::Num)
        .map_err(|_| format!("bad number '{text}' at byte {start}"))
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Json {
        Json::Int(n)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Json {
        Json::Int(n as i64)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Int(n as i64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars_render() {
        assert_eq!(Json::Null.render(), "null");
        assert_eq!(Json::from(true).render(), "true");
        assert_eq!(Json::from(42i64).render(), "42");
        assert_eq!(Json::from(1.5).render(), "1.5");
        assert_eq!(Json::from("hi").render(), "\"hi\"");
    }

    #[test]
    fn escaping() {
        assert_eq!(Json::from("a\"b\\c\nd").render(), "\"a\\\"b\\\\c\\nd\"");
    }

    #[test]
    fn object_key_order_is_deterministic() {
        let mut o = Json::obj();
        o.set("zeta", 1i64.into());
        o.set("alpha", 2i64.into());
        let r = o.render();
        assert!(r.find("alpha").unwrap() < r.find("zeta").unwrap());
    }

    #[test]
    fn nested_structure() {
        let mut o = Json::obj();
        o.set("xs", vec![1i64, 2, 3].into());
        let mut inner = Json::obj();
        inner.set("k", "v".into());
        o.set("inner", inner);
        let r = o.render();
        assert!(r.contains("\"xs\": [\n"));
        assert!(r.contains("\"k\": \"v\""));
    }

    #[test]
    fn nan_becomes_null() {
        assert_eq!(Json::from(f64::NAN).render(), "null");
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::Arr(vec![]).render(), "[]");
        assert_eq!(Json::obj().render(), "{}");
    }

    #[test]
    fn parse_round_trips_render() {
        let mut doc = Json::obj();
        doc.set("group", "planner".into());
        doc.set("pi", 3.25.into());
        doc.set("count", 42i64.into());
        doc.set("none", Json::Null);
        doc.set("flag", true.into());
        doc.set("tricky", "a\"b\\c\nd".into());
        let mut results = Json::Arr(vec![]);
        let mut row = Json::obj();
        row.set("name", "search_3584".into());
        row.set("mean_s", 0.001625.into());
        results.push(row);
        doc.set("results", results);
        let parsed = Json::parse(&doc.render()).unwrap();
        assert_eq!(parsed, doc);
    }

    #[test]
    fn parse_accessors() {
        let doc = Json::parse(r#"{"results": [{"name": "x", "mean_s": 2}], "n": 1.5}"#).unwrap();
        assert_eq!(doc.get("n").and_then(Json::as_f64), Some(1.5));
        let rows = doc.get("results").and_then(Json::items).unwrap();
        assert_eq!(rows[0].get("name").and_then(Json::as_str), Some("x"));
        assert_eq!(rows[0].get("mean_s").and_then(Json::as_f64), Some(2.0));
        assert!(doc.get("absent").is_none());
        assert!(Json::Null.get("x").is_none());
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1, 2").is_err());
        assert!(Json::parse("{\"a\": 1} extra").is_err());
        assert!(Json::parse("nulll").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    /// Every distinct parse-error path returns a diagnostic (never
    /// panics) naming what went wrong — `ipumm bench-check` shows these
    /// verbatim when a `BENCH_*.json` artifact is truncated or corrupt.
    #[test]
    fn parse_error_messages_name_the_failure() {
        let cases: &[(&str, &str)] = &[
            ("", "unexpected end of input"),
            ("   \n\t", "unexpected end of input"),
            ("{\"a\": 1} extra", "trailing data"),
            ("nulll", "trailing data"),  // parses "null", chokes on the rest
            ("nul", "expected 'null'"),
            ("tru", "expected 'true'"),
            ("falsy", "expected 'false'"),
            ("[1 2]", "expected ',' or ']'"),
            ("[1, 2", "expected ',' or ']'"),
            ("{\"a\": 1 \"b\": 2}", "expected ',' or '}'"),
            ("{\"a\": 1", "expected ',' or '}'"),
            ("{\"a\" 1}", "expected ':'"),
            ("{1: 2}", "expected string"),
            ("\"unterminated", "unterminated string"),
            ("\"bad \\u12", "bad \\u escape"),
            ("\"bad \\uZZZZ\"", "bad \\u escape"),
            ("\"bad \\q\"", "bad escape"),
            ("@", "expected a value"),
            ("-", "bad number '-'"),
            ("1.2.3", "bad number '1.2.3'"), // number scan is greedy
        ];
        for (input, want) in cases {
            let err = Json::parse(input).expect_err(input);
            assert!(
                err.contains(want),
                "parse({input:?}) -> {err:?}, expected it to mention {want:?}"
            );
        }
    }

    /// A half-written artifact (truncated mid-stream, as a crashed bench
    /// run leaves behind) errors instead of yielding a partial document.
    #[test]
    fn parse_rejects_truncated_artifact() {
        let full = {
            let mut doc = Json::obj();
            doc.set("group", "planner".into());
            let mut row = Json::obj();
            row.set("name", "search_3584".into());
            row.set("mean_s", 0.001625.into());
            doc.set("results", Json::Arr(vec![row]));
            doc.render()
        };
        // cut at every prefix length that ends on a char boundary: no
        // prefix except the full document may parse
        for cut in 1..full.len() {
            if !full.is_char_boundary(cut) {
                continue;
            }
            assert!(
                Json::parse(&full[..cut]).is_err(),
                "truncated prefix of {cut} bytes unexpectedly parsed"
            );
        }
        assert!(Json::parse(&full).is_ok());
    }

    #[test]
    fn parse_numbers() {
        assert_eq!(Json::parse("42").unwrap(), Json::Int(42));
        assert_eq!(Json::parse("-7").unwrap(), Json::Int(-7));
        assert_eq!(Json::parse("1.5e3").unwrap(), Json::Num(1500.0));
        assert_eq!(Json::parse("0.001625").unwrap(), Json::Num(0.001625));
        // i64 overflow falls back to f64
        assert!(matches!(Json::parse("99999999999999999999").unwrap(), Json::Num(_)));
    }
}
