//! Minimal write-only JSON document model for profiler exports (PopVision
//! analogue dumps). No serde available offline; nothing in the repo needs
//! JSON *parsing* (the artifact manifest is TSV by design).

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Int(i64),
    Str(String),
    Arr(Vec<Json>),
    // BTreeMap for deterministic key order — reports must diff cleanly.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(&mut self, key: &str, val: Json) -> &mut Json {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), val);
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    pub fn push(&mut self, val: Json) -> &mut Json {
        match self {
            Json::Arr(v) => v.push(val),
            _ => panic!("Json::push on non-array"),
        }
        self
    }

    pub fn render(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0);
        s
    }

    fn write(&self, out: &mut String, indent: usize) {
        let pad = "  ".repeat(indent);
        let pad_in = "  ".repeat(indent + 1);
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => {
                let _ = write!(out, "{b}");
            }
            Json::Int(i) => {
                let _ = write!(out, "{i}");
            }
            Json::Num(n) => {
                if n.is_finite() {
                    let _ = write!(out, "{n}");
                } else {
                    // JSON has no Inf/NaN; encode as null like most emitters
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                if v.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push_str("[\n");
                for (i, item) in v.iter().enumerate() {
                    out.push_str(&pad_in);
                    item.write(out, indent + 1);
                    if i + 1 < v.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                out.push_str(&pad);
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    out.push_str(&pad_in);
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write(out, indent + 1);
                    if i + 1 < m.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                out.push_str(&pad);
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Json {
        Json::Int(n)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Json {
        Json::Int(n as i64)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Int(n as i64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars_render() {
        assert_eq!(Json::Null.render(), "null");
        assert_eq!(Json::from(true).render(), "true");
        assert_eq!(Json::from(42i64).render(), "42");
        assert_eq!(Json::from(1.5).render(), "1.5");
        assert_eq!(Json::from("hi").render(), "\"hi\"");
    }

    #[test]
    fn escaping() {
        assert_eq!(Json::from("a\"b\\c\nd").render(), "\"a\\\"b\\\\c\\nd\"");
    }

    #[test]
    fn object_key_order_is_deterministic() {
        let mut o = Json::obj();
        o.set("zeta", 1i64.into());
        o.set("alpha", 2i64.into());
        let r = o.render();
        assert!(r.find("alpha").unwrap() < r.find("zeta").unwrap());
    }

    #[test]
    fn nested_structure() {
        let mut o = Json::obj();
        o.set("xs", vec![1i64, 2, 3].into());
        let mut inner = Json::obj();
        inner.set("k", "v".into());
        o.set("inner", inner);
        let r = o.render();
        assert!(r.contains("\"xs\": [\n"));
        assert!(r.contains("\"k\": \"v\""));
    }

    #[test]
    fn nan_becomes_null() {
        assert_eq!(Json::from(f64::NAN).render(), "null");
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::Arr(vec![]).render(), "[]");
        assert_eq!(Json::obj().render(), "{}");
    }
}
