//! ASCII / markdown table rendering for experiment reports — the repo's
//! analogue of the paper's tables and figure data dumps.

#[derive(Clone, Debug)]
pub struct Table {
    pub title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row arity {} != header arity {}",
            cells.len(),
            self.headers.len()
        );
        self.rows.push(cells.to_vec());
        self
    }

    pub fn row_strs(&mut self, cells: &[&str]) -> &mut Self {
        let owned: Vec<String> = cells.iter().map(|s| s.to_string()).collect();
        self.row(&owned)
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        w
    }

    /// Fixed-width ASCII rendering for terminal output.
    pub fn to_ascii(&self) -> String {
        let w = self.widths();
        let sep: String = w
            .iter()
            .map(|n| format!("+{}", "-".repeat(n + 2)))
            .collect::<String>()
            + "+";
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("| {:width$} ", c, width = w[i]))
                .collect::<String>()
                + "|"
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("{}\n", self.title));
        }
        out.push_str(&sep);
        out.push('\n');
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r));
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out
    }

    /// GitHub-flavoured markdown rendering for EXPERIMENTS.md.
    pub fn to_markdown(&self) -> String {
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("### {}\n\n", self.title));
        }
        out.push_str(&format!("| {} |\n", self.headers.join(" | ")));
        out.push_str(&format!(
            "|{}\n",
            self.headers.iter().map(|_| "---|").collect::<String>()
        ));
        for r in &self.rows {
            out.push_str(&format!("| {} |\n", r.join(" | ")));
        }
        out
    }

    /// CSV rendering (no quoting needed: cells are numeric/identifier-ish;
    /// commas in cells are replaced to keep the format trivially parseable).
    pub fn to_csv(&self) -> String {
        let clean = |s: &str| s.replace(',', ";");
        let mut out = String::new();
        out.push_str(&self.headers.iter().map(|h| clean(h)).collect::<Vec<_>>().join(","));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&r.iter().map(|c| clean(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Table {
        let mut t = Table::new("T", &["a", "bb"]);
        t.row_strs(&["1", "2"]);
        t.row_strs(&["333", "4"]);
        t
    }

    #[test]
    fn ascii_aligns_columns() {
        let s = sample().to_ascii();
        assert!(s.contains("| a   | bb |"));
        assert!(s.contains("| 333 | 4  |"));
    }

    #[test]
    fn markdown_shape() {
        let s = sample().to_markdown();
        assert!(s.contains("| a | bb |"));
        assert!(s.contains("|---|---|"));
        assert!(s.contains("| 333 | 4 |"));
    }

    #[test]
    fn csv_escapes_commas() {
        let mut t = Table::new("", &["x"]);
        t.row_strs(&["a,b"]);
        assert_eq!(t.to_csv(), "x\na;b\n");
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_mismatch_panics() {
        Table::new("", &["a", "b"]).row_strs(&["only-one"]);
    }
}
