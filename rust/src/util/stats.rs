//! Summary statistics for benchmark samples and metric aggregation.

/// Summary of a sample set (times, throughputs, ...).
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub stddev: f64,
    pub min: f64,
    pub max: f64,
    pub median: f64,
    /// Nearest-rank tail percentiles ([`percentile_nearest`]) — exact
    /// order statistics, well-defined even on tiny samples. All three
    /// tails use the same estimator so `p95 <= p99 <= p999` always
    /// holds (an interpolated p95 could exceed a nearest-rank p99 on
    /// small samples).
    pub p95: f64,
    pub p99: f64,
    pub p999: f64,
}

impl Summary {
    pub fn of(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "Summary::of on empty sample set");
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).expect("NaN in samples"));
        Summary {
            n,
            mean,
            stddev: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            median: percentile_sorted(&sorted, 50.0),
            p95: percentile_nearest(&sorted, 95.0),
            p99: percentile_nearest(&sorted, 99.0),
            p999: percentile_nearest(&sorted, 99.9),
        }
    }

    /// Relative stddev (coefficient of variation); 0 for a constant sample.
    pub fn cv(&self) -> f64 {
        if self.mean == 0.0 { 0.0 } else { self.stddev / self.mean.abs() }
    }
}

/// Linear-interpolated percentile of an ascending-sorted slice, p in [0,100].
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=100.0).contains(&p), "percentile {p} out of range");
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Nearest-rank percentile of an ascending-sorted slice, p in (0,100]:
/// the ceil(p/100 * n)-th order statistic (1-based, clamped to [1, n]).
/// Unlike the interpolated [`percentile_sorted`] this always returns an
/// observed sample, so tail percentiles stay meaningful on tiny n (p99
/// of 10 samples is the max, not an extrapolation).
pub fn percentile_nearest(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=100.0).contains(&p), "percentile {p} out of range");
    let rank = (p / 100.0 * sorted.len() as f64).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

/// Geometric mean — used for cross-shape speedup aggregation in reports.
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let log_sum: f64 = xs
        .iter()
        .map(|&x| {
            assert!(x > 0.0, "geomean needs positive values, got {x}");
            x.ln()
        })
        .sum();
    (log_sum / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert!((s.median - 2.5).abs() < 1e-12);
    }

    #[test]
    fn summary_single_sample() {
        let s = Summary::of(&[7.0]);
        assert_eq!(s.stddev, 0.0);
        assert_eq!(s.median, 7.0);
        assert_eq!(s.p95, 7.0);
        assert_eq!(s.p99, 7.0);
        assert_eq!(s.p999, 7.0);
    }

    #[test]
    fn nearest_rank_tail_percentiles() {
        // 1..=1000: p99 is the 990th order statistic, p999 the 999th
        let v: Vec<f64> = (1..=1000).map(|i| i as f64).collect();
        assert_eq!(percentile_nearest(&v, 99.0), 990.0);
        assert_eq!(percentile_nearest(&v, 99.9), 999.0);
        assert_eq!(percentile_nearest(&v, 100.0), 1000.0);
        let s = Summary::of(&v);
        assert_eq!(s.p95, 950.0);
        assert_eq!(s.p99, 990.0);
        assert_eq!(s.p999, 999.0);
        // tiny samples: always an observed value, never extrapolated
        let tiny = [1.0, 2.0, 3.0];
        assert_eq!(percentile_nearest(&tiny, 99.0), 3.0);
        assert_eq!(percentile_nearest(&tiny, 50.0), 2.0);
        assert_eq!(percentile_nearest(&tiny, 0.0), 1.0);
    }

    #[test]
    fn stddev_sample_form() {
        // sample (n-1) stddev of [2,4,4,4,5,5,7,9] is ~2.138
        let s = Summary::of(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((s.stddev - 2.13809).abs() < 1e-4, "got {}", s.stddev);
    }

    #[test]
    fn percentile_interpolates() {
        let v = [10.0, 20.0, 30.0, 40.0];
        assert!((percentile_sorted(&v, 0.0) - 10.0).abs() < 1e-12);
        assert!((percentile_sorted(&v, 100.0) - 40.0).abs() < 1e-12);
        assert!((percentile_sorted(&v, 50.0) - 25.0).abs() < 1e-12);
    }

    #[test]
    fn geomean_of_speedups() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn cv_constant_sample() {
        let s = Summary::of(&[3.0, 3.0, 3.0]);
        assert_eq!(s.cv(), 0.0);
    }

    #[test]
    fn tails_are_monotone_on_small_samples() {
        // p95 <= p99 <= p999 must hold on any sample — guaranteed only
        // because all three tails use the same (nearest-rank) estimator
        for samples in [vec![1.0, 10.0], vec![1.0, 2.0, 100.0], vec![5.0; 7]] {
            let s = Summary::of(&samples);
            assert!(s.p95 <= s.p99 && s.p99 <= s.p999, "{samples:?}: {s:?}");
        }
    }
}
