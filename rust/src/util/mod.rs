//! Small in-tree substrate crates-worth of utilities.
//!
//! The build environment is offline with only the `xla` crate's dependency
//! closure vendored, so everything a production repo would normally pull
//! from crates.io (CLI parsing, JSON emission, stats, a bench harness, a
//! property-testing loop, matrices, PRNG) lives here instead.

pub mod bench;
pub mod cli;
pub mod json;
pub mod matrix;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;
pub mod units;
