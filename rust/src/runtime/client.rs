//! PJRT client wrapper: compile HLO-text artifacts once, execute many.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::runtime::manifest::{ArtifactSpec, Manifest};

/// A compiled artifact ready to execute.
struct LoadedArtifact {
    spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

/// Owns the PJRT CPU client and the compiled executables.
pub struct RuntimeClient {
    client: xla::PjRtClient,
    loaded: HashMap<String, LoadedArtifact>,
    pub manifest: Manifest,
    /// Cumulative execution count (perf accounting).
    pub executions: u64,
}

impl RuntimeClient {
    /// Load every artifact in `dir`'s manifest and compile it.
    pub fn load(dir: &Path) -> Result<RuntimeClient> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut loaded = HashMap::new();
        for spec in &manifest.artifacts {
            let proto = xla::HloModuleProto::from_text_file(
                spec.path
                    .to_str()
                    .with_context(|| format!("non-utf8 path {:?}", spec.path))?,
            )
            .with_context(|| format!("parsing HLO text {}", spec.path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling {}", spec.name))?;
            loaded.insert(spec.name.clone(), LoadedArtifact { spec: spec.clone(), exe });
        }
        Ok(RuntimeClient { client, loaded, manifest, executions: 0 })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn artifact_names(&self) -> Vec<String> {
        let mut names: Vec<String> = self.loaded.keys().cloned().collect();
        names.sort();
        names
    }

    fn literal(data: &[f32], rows: usize, cols: usize) -> Result<xla::Literal> {
        anyhow::ensure!(data.len() == rows * cols, "literal shape mismatch");
        Ok(xla::Literal::vec1(data).reshape(&[rows as i64, cols as i64])?)
    }

    /// Execute the accumulating block artifact `name`:
    /// returns `c + a @ b` for row-major inputs of the artifact's shape.
    pub fn execute_block(
        &mut self,
        name: &str,
        a: &[f32],
        b: &[f32],
        c: &[f32],
    ) -> Result<Vec<f32>> {
        let art = self
            .loaded
            .get(name)
            .with_context(|| format!("artifact '{name}' not loaded"))?;
        let (m, n, k) = (art.spec.m, art.spec.n, art.spec.k);
        let la = Self::literal(a, m, n)?;
        let lb = Self::literal(b, n, k)?;
        let lc = Self::literal(c, m, k)?;
        let result = art.exe.execute::<xla::Literal>(&[la, lb, lc])?[0][0]
            .to_literal_sync()?;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple
        let out = result.to_tuple1()?;
        self.executions += 1;
        Ok(out.to_vec::<f32>()?)
    }

    /// Execute a `full` artifact (two inputs, a @ b).
    pub fn execute_full(&mut self, name: &str, a: &[f32], b: &[f32]) -> Result<Vec<f32>> {
        let art = self
            .loaded
            .get(name)
            .with_context(|| format!("artifact '{name}' not loaded"))?;
        if art.spec.kind != crate::runtime::manifest::ArtifactKind::Full {
            bail!("artifact '{name}' is not a full-matmul artifact");
        }
        let (m, n, k) = (art.spec.m, art.spec.n, art.spec.k);
        let la = Self::literal(a, m, n)?;
        let lb = Self::literal(b, n, k)?;
        let result = art.exe.execute::<xla::Literal>(&[la, lb])?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        self.executions += 1;
        Ok(out.to_vec::<f32>()?)
    }

    pub fn spec(&self, name: &str) -> Option<&ArtifactSpec> {
        self.loaded.get(name).map(|l| &l.spec)
    }
}
