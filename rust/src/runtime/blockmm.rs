//! Arbitrary-shape matrix multiplication composed from fixed-shape block
//! artifacts — the runtime mirror of the IPU's partial-sum accumulation
//! across BSP supersteps.
//!
//! For C[i,j] blocks the executor threads the accumulator through repeated
//! `c + a @ b` executions along the reduction dimension, exactly the
//! contract `python/compile/kernels/amp_mm.py` exports. Padding at the
//! fringe mirrors the AMP quantization the simulator models.

use std::path::Path;

use anyhow::{Context, Result};

use crate::runtime::client::RuntimeClient;
use crate::util::matrix::Matrix;

/// Execution statistics for one composed matmul.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct BlockMmStats {
    pub block: usize,
    pub block_calls: u64,
    pub padded_m: usize,
    pub padded_n: usize,
    pub padded_k: usize,
    pub seconds: f64,
}

pub struct BlockMmExecutor {
    pub client: RuntimeClient,
    /// Preferred block edge (must name a `mm_block_<B>` artifact).
    pub block: usize,
}

impl BlockMmExecutor {
    /// Load artifacts from `dir`; prefer blocks of edge `block_cap` or the
    /// largest available below it.
    pub fn load(dir: &Path, block_cap: usize) -> Result<BlockMmExecutor> {
        let client = RuntimeClient::load(dir)?;
        let block = client
            .manifest
            .pick_block(block_cap)
            .context("no block artifacts in manifest")?
            .m;
        Ok(BlockMmExecutor { client, block })
    }

    /// Pick the cheapest available block size for a shape (§Perf L3):
    /// bigger blocks amortize the fixed PJRT call cost (~0.13 ms measured
    /// on this CPU client) but pay padded flops on short dimensions.
    pub fn choose_block(&self, m: usize, n: usize, k: usize) -> usize {
        const CALL_OVERHEAD_S: f64 = 0.13e-3;
        const REAL_FLOPS_PER_S: f64 = 30e9;
        let mut best = (self.block, f64::INFINITY);
        for spec in self.client.manifest.blocks() {
            let b = spec.m;
            if b > self.block {
                continue; // respect the configured cap
            }
            let (gm, gn, gk) = (m.div_ceil(b), n.div_ceil(b), k.div_ceil(b));
            let calls = (gm * gn * gk) as f64;
            let padded_flops = 2.0 * (gm * b) as f64 * (gn * b) as f64 * (gk * b) as f64;
            let cost = padded_flops / REAL_FLOPS_PER_S + calls * CALL_OVERHEAD_S;
            if cost < best.1 {
                best = (b, cost);
            }
        }
        best.0
    }

    /// C = A @ B for arbitrary shapes, composed from block executions.
    pub fn mm(&mut self, a: &Matrix, b: &Matrix) -> Result<(Matrix, BlockMmStats)> {
        anyhow::ensure!(
            a.cols == b.rows,
            "inner dimension mismatch: {} vs {}",
            a.cols,
            b.rows
        );
        let t0 = std::time::Instant::now();
        let bsz = self.choose_block(a.rows, a.cols, b.cols);
        let name = format!("mm_block_{bsz}");
        let (m, n, k) = (a.rows, a.cols, b.cols);
        let gm = m.div_ceil(bsz);
        let gn = n.div_ceil(bsz);
        let gk = k.div_ceil(bsz);
        let mut c = Matrix::zeros(m, k);
        let mut calls = 0u64;
        // §Perf L3: reuse the operand staging buffers across every block
        // call instead of allocating 2 matrices per reduction step
        let mut a_buf = Matrix::zeros(bsz, bsz);
        let mut b_buf = Matrix::zeros(bsz, bsz);
        let zero = vec![0.0f32; bsz * bsz];
        for i in 0..gm {
            for j in 0..gk {
                // thread the accumulator through the reduction blocks
                let mut acc = zero.clone();
                for l in 0..gn {
                    a.block_padded_into(i * bsz, l * bsz, &mut a_buf);
                    b.block_padded_into(l * bsz, j * bsz, &mut b_buf);
                    acc = self
                        .client
                        .execute_block(&name, &a_buf.data, &b_buf.data, &acc)?;
                    calls += 1;
                }
                c.write_block(i * bsz, j * bsz, &Matrix::from_vec(bsz, bsz, acc));
            }
        }
        let stats = BlockMmStats {
            block: bsz,
            block_calls: calls,
            padded_m: gm * bsz,
            padded_n: gn * bsz,
            padded_k: gk * bsz,
            seconds: t0.elapsed().as_secs_f64(),
        };
        Ok((c, stats))
    }

    /// Run `mm` and verify against the in-tree oracle; returns the max
    /// absolute error. The correctness gate for the real compute path.
    pub fn mm_verified(&mut self, a: &Matrix, b: &Matrix) -> Result<(Matrix, BlockMmStats, f32)> {
        let (c, stats) = self.mm(a, b)?;
        let want = a.matmul_oracle(b);
        let err = c.max_abs_diff(&want);
        let atol = 1e-4 * (a.cols as f32).sqrt().max(1.0);
        anyhow::ensure!(
            err <= atol,
            "block mm diverged from oracle: err {err} > atol {atol}"
        );
        Ok((c, stats, err))
    }
}

// Execution requires artifacts/ to exist; correctness tests live in
// rust/tests/integration_runtime.rs (run after `make artifacts`). The
// pure block-composition arithmetic (padding, accumulation threading) is
// unit-tested through Matrix in util::matrix and the integration suite.
