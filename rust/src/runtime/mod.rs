//! The real compute path: AOT-compiled JAX/Pallas HLO artifacts executed
//! on the PJRT CPU client via the `xla` crate.
//!
//! Python runs only at build time (`make artifacts`); this module loads
//! `artifacts/manifest.tsv` + `*.hlo.txt` (HLO *text* — serialized protos
//! from jax >= 0.5 are rejected by xla_extension 0.5.1) and serves block
//! matmuls to the coordinator. [`blockmm`] composes arbitrary (m, n, k)
//! multiplications out of fixed-shape accumulating block calls, mirroring
//! how the IPU accumulates partials across BSP supersteps — and every
//! result is checkable against the in-tree oracle.

pub mod blockmm;
pub mod client;
pub mod manifest;

pub use blockmm::BlockMmExecutor;
pub use client::RuntimeClient;
pub use manifest::{ArtifactKind, ArtifactSpec, Manifest};
