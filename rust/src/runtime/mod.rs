//! The real compute path: AOT-compiled JAX/Pallas HLO artifacts executed
//! on the PJRT CPU client via the `xla` crate.
//!
//! Python runs only at build time (`make artifacts`); this module loads
//! `artifacts/manifest.tsv` + `*.hlo.txt` (HLO *text* — serialized protos
//! from jax >= 0.5 are rejected by xla_extension 0.5.1) and serves block
//! matmuls to the coordinator. [`blockmm`] composes arbitrary (m, n, k)
//! multiplications out of fixed-shape accumulating block calls, mirroring
//! how the IPU accumulates partials across BSP supersteps — and every
//! result is checkable against the in-tree oracle.

//! The execution layer is behind the off-by-default `xla` cargo feature:
//! manifest parsing is always available (the serve layer uses it to align
//! bucket ladders with block artifacts), while the PJRT client and the
//! block executor need the `xla` crate and compiled artifacts.

#[cfg(feature = "xla")]
pub mod blockmm;
#[cfg(feature = "xla")]
pub mod client;
pub mod manifest;

#[cfg(feature = "xla")]
pub use blockmm::BlockMmExecutor;
#[cfg(feature = "xla")]
pub use client::RuntimeClient;
pub use manifest::{ArtifactKind, ArtifactSpec, Manifest};
