//! Artifact manifest parsing.
//!
//! `python/compile/aot.py` writes a TSV (deliberately not JSON so the
//! loader needs no parser dependency):
//!
//! ```text
//! kind<TAB>name<TAB>file<TAB>m<TAB>n<TAB>k<TAB>dtype
//! block	mm_block_128	mm_block_128.hlo.txt	128	128	128	f32
//! ```

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArtifactKind {
    /// Accumulating block matmul: out = c + a @ b.
    Block,
    /// Small full matmul: out = a @ b (smoke tests).
    Full,
}

#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub kind: ArtifactKind,
    pub name: String,
    pub path: PathBuf,
    pub m: usize,
    pub n: usize,
    pub k: usize,
}

#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    /// Parse `<dir>/manifest.tsv`; artifact paths are resolved against
    /// `dir`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.tsv");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        Manifest::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: &Path) -> Result<Manifest> {
        let mut artifacts = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let fields: Vec<&str> = line.split('\t').collect();
            if fields.len() != 7 {
                bail!(
                    "manifest line {}: expected 7 tab-separated fields, got {}",
                    lineno + 1,
                    fields.len()
                );
            }
            let kind = match fields[0] {
                "block" => ArtifactKind::Block,
                "full" => ArtifactKind::Full,
                other => bail!("manifest line {}: unknown kind '{other}'", lineno + 1),
            };
            if fields[6] != "f32" {
                bail!("manifest line {}: unsupported dtype '{}'", lineno + 1, fields[6]);
            }
            let parse_dim = |s: &str, what: &str| -> Result<usize> {
                s.parse()
                    .with_context(|| format!("manifest line {}: bad {what} '{s}'", lineno + 1))
            };
            artifacts.push(ArtifactSpec {
                kind,
                name: fields[1].to_string(),
                path: dir.join(fields[2]),
                m: parse_dim(fields[3], "m")?,
                n: parse_dim(fields[4], "n")?,
                k: parse_dim(fields[5], "k")?,
            });
        }
        if artifacts.is_empty() {
            bail!("manifest is empty");
        }
        Ok(Manifest { artifacts })
    }

    pub fn blocks(&self) -> impl Iterator<Item = &ArtifactSpec> {
        self.artifacts
            .iter()
            .filter(|a| a.kind == ArtifactKind::Block)
    }

    /// Best (largest, square) block artifact no larger than `cap`; falls
    /// back to the smallest block when everything exceeds `cap`.
    pub fn pick_block(&self, cap: usize) -> Option<&ArtifactSpec> {
        let mut blocks: Vec<&ArtifactSpec> = self.blocks().collect();
        blocks.sort_by_key(|a| a.m);
        blocks
            .iter()
            .rev()
            .find(|a| a.m <= cap)
            .copied()
            .or_else(|| blocks.first().copied())
    }

    pub fn by_name(&self, name: &str) -> Option<&ArtifactSpec> {
        self.artifacts.iter().find(|a| a.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "block\tmm_block_64\tmm_block_64.hlo.txt\t64\t64\t64\tf32\n\
                          block\tmm_block_128\tmm_block_128.hlo.txt\t128\t128\t128\tf32\n\
                          full\tmm_full_32\tmm_full_32.hlo.txt\t32\t32\t32\tf32\n";

    fn sample() -> Manifest {
        Manifest::parse(SAMPLE, Path::new("/art")).unwrap()
    }

    #[test]
    fn parses_all_rows() {
        let m = sample();
        assert_eq!(m.artifacts.len(), 3);
        assert_eq!(m.blocks().count(), 2);
        assert_eq!(m.artifacts[0].path, PathBuf::from("/art/mm_block_64.hlo.txt"));
    }

    #[test]
    fn pick_block_prefers_largest_under_cap() {
        let m = sample();
        assert_eq!(m.pick_block(4096).unwrap().m, 128);
        assert_eq!(m.pick_block(100).unwrap().m, 64);
        // nothing fits -> smallest
        assert_eq!(m.pick_block(16).unwrap().m, 64);
    }

    #[test]
    fn by_name_lookup() {
        assert!(sample().by_name("mm_full_32").is_some());
        assert!(sample().by_name("nope").is_none());
    }

    #[test]
    fn rejects_malformed_lines() {
        assert!(Manifest::parse("block\tonly-two", Path::new(".")).is_err());
        assert!(Manifest::parse("weird\ta\tb\t1\t1\t1\tf32", Path::new(".")).is_err());
        assert!(Manifest::parse("block\ta\tb\tx\t1\t1\tf32", Path::new(".")).is_err());
        assert!(Manifest::parse("block\ta\tb\t1\t1\t1\tf64", Path::new(".")).is_err());
        assert!(Manifest::parse("", Path::new(".")).is_err());
    }

    #[test]
    fn skips_comments_and_blanks() {
        let m = Manifest::parse(
            "# comment\n\nblock\tb\tb.hlo.txt\t64\t64\t64\tf32\n",
            Path::new("."),
        )
        .unwrap();
        assert_eq!(m.artifacts.len(), 1);
    }
}
