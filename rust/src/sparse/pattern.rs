//! Block-sparsity patterns: seeded generators and compact descriptors.
//!
//! Sparsity is static (PopSparse's regime: the pattern is fixed at
//! compile time) and block-granular: `A[m, n]` is a grid of
//! `block x block` tiles, each wholly zero or wholly present. A pattern
//! is described by a tiny, hashable [`SparsitySpec`] — generator kind,
//! block size, target density, seed — so the serving layer can key its
//! plan cache on the spec's fingerprint without materializing the
//! pattern; [`BlockPattern`] is the materialized occupancy grid the
//! planner and graph builder consume.
//!
//! Generators are *nested across densities* for a fixed seed and kind
//! (the nonzero set at density d1 <= d2 is a subset of the set at d2,
//! except block-diagonal whose group boundaries shift), which is what
//! makes sparse plan cost provably monotone in density — see
//! `sparse::planner` and the property tests.

use std::hash::{Hash, Hasher};

use crate::planner::partition::MmShape;
use crate::util::rng::Rng;
use crate::util::units::div_ceil;

/// Block edges PopSparse's codelets support (and the AMP digests well).
pub const BLOCK_SIZES: [usize; 3] = [4, 8, 16];

/// Pattern generator family.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PatternKind {
    /// Uniform random nonzero blocks (seeded permutation prefix, so the
    /// nonzero sets nest across densities).
    Random,
    /// Diagonal band of blocks (half-width grown to the target density).
    Banded,
    /// Square diagonal groups (`~1/density` groups along the diagonal).
    BlockDiagonal,
}

impl PatternKind {
    pub fn name(&self) -> &'static str {
        match self {
            PatternKind::Random => "random",
            PatternKind::Banded => "banded",
            PatternKind::BlockDiagonal => "blockdiag",
        }
    }

    pub fn by_name(name: &str) -> Option<PatternKind> {
        match name.to_ascii_lowercase().as_str() {
            "random" => Some(PatternKind::Random),
            "banded" | "band" => Some(PatternKind::Banded),
            "blockdiag" | "block-diagonal" | "blockdiagonal" => Some(PatternKind::BlockDiagonal),
            _ => None,
        }
    }

    pub fn all() -> [PatternKind; 3] {
        [PatternKind::Random, PatternKind::Banded, PatternKind::BlockDiagonal]
    }
}

/// Compact, hashable sparsity descriptor — the serving layer's cache-key
/// dimension. Density is stored in permille so the spec stays `Eq + Hash`
/// (no floats in cache keys).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct SparsitySpec {
    pub kind: PatternKind,
    /// Block edge, one of [`BLOCK_SIZES`].
    pub block: usize,
    /// Target nonzero-block density in 1/1000ths, in [1, 1000].
    pub density_permille: u32,
    /// Generator seed (two specs differing only in seed are distinct
    /// cache entries — their patterns differ).
    pub seed: u64,
}

impl SparsitySpec {
    /// `density` is clamped to [0.001, 1.0] and quantized to permille.
    pub fn new(kind: PatternKind, block: usize, density: f64, seed: u64) -> SparsitySpec {
        assert!(
            BLOCK_SIZES.contains(&block),
            "block {block} not in supported sizes {BLOCK_SIZES:?}"
        );
        let density_permille = ((density * 1000.0).round() as i64).clamp(1, 1000) as u32;
        SparsitySpec { kind, block, density_permille, seed }
    }

    /// The degenerate fully-dense spec (every block present).
    pub fn dense(block: usize) -> SparsitySpec {
        SparsitySpec::new(PatternKind::Random, block, 1.0, 0)
    }

    pub fn density(&self) -> f64 {
        self.density_permille as f64 / 1000.0
    }

    pub fn is_dense(&self) -> bool {
        self.density_permille == 1000
    }

    /// Fingerprint over every pattern-determining field — the sparsity
    /// half of the serving layer's plan-cache key (cf.
    /// `IpuArch::fingerprint`). Two specs that would generate different
    /// patterns must not collide.
    pub fn fingerprint(&self) -> u64 {
        let mut h = std::collections::hash_map::DefaultHasher::new();
        self.kind.name().hash(&mut h);
        self.block.hash(&mut h);
        self.density_permille.hash(&mut h);
        self.seed.hash(&mut h);
        h.finish()
    }

    /// Human label, e.g. `random/b8/d0.50`.
    pub fn label(&self) -> String {
        format!("{}/b{}/d{:.2}", self.kind.name(), self.block, self.density())
    }
}

/// Materialized block-occupancy grid of `A[m, n]` for one spec.
#[derive(Clone, Debug)]
pub struct BlockPattern {
    pub spec: SparsitySpec,
    /// Grid extents in blocks: `ceil(m / block)` x `ceil(n / block)`.
    pub block_rows: usize,
    pub block_cols: usize,
    /// Row-major occupancy, `block_rows * block_cols` entries.
    nz: Vec<bool>,
}

impl BlockPattern {
    /// Generate the pattern for an `m x n` operand.
    pub fn generate(spec: SparsitySpec, m: usize, n: usize) -> BlockPattern {
        assert!(m > 0 && n > 0, "degenerate operand {m}x{n}");
        let block_rows = div_ceil(m, spec.block);
        let block_cols = div_ceil(n, spec.block);
        let total = block_rows * block_cols;
        let mut nz = vec![false; total];
        if spec.is_dense() {
            // exact by construction: density 1.0 must reproduce dense
            nz.fill(true);
        } else {
            match spec.kind {
                PatternKind::Random => {
                    // nonzero set = prefix of one seeded permutation, so
                    // densities nest and the realized count is exact
                    let target = ((spec.density() * total as f64).ceil() as usize).clamp(1, total);
                    let mut order: Vec<usize> = (0..total).collect();
                    let mut rng = Rng::new(spec.seed ^ 0xB10C_5EED);
                    for i in (1..total).rev() {
                        let j = rng.gen_usize(0, i);
                        order.swap(i, j);
                    }
                    for &b in order.iter().take(target) {
                        nz[b] = true;
                    }
                }
                PatternKind::Banded => {
                    // half-width grows with density (nested); the band
                    // follows the grid diagonal even for skewed grids
                    let w = ((spec.density() * block_cols as f64) / 2.0).ceil() as usize;
                    for bi in 0..block_rows {
                        let centre = if block_rows <= 1 {
                            0
                        } else {
                            bi * (block_cols - 1) / (block_rows - 1)
                        };
                        for bj in 0..block_cols {
                            if bj.abs_diff(centre) <= w {
                                nz[bi * block_cols + bj] = true;
                            }
                        }
                    }
                }
                PatternKind::BlockDiagonal => {
                    // ~1/density square groups along the diagonal
                    let groups = ((1.0 / spec.density()).round() as usize).max(1);
                    for bi in 0..block_rows {
                        let gi = bi * groups / block_rows;
                        for bj in 0..block_cols {
                            let gj = bj * groups / block_cols;
                            if gi == gj {
                                nz[bi * block_cols + bj] = true;
                            }
                        }
                    }
                }
            }
        }
        BlockPattern { spec, block_rows, block_cols, nz }
    }

    /// Pattern over a matmul's `A` operand.
    pub fn for_shape(spec: SparsitySpec, shape: MmShape) -> BlockPattern {
        BlockPattern::generate(spec, shape.m, shape.n)
    }

    pub fn total_blocks(&self) -> usize {
        self.block_rows * self.block_cols
    }

    pub fn nonzero_blocks(&self) -> usize {
        self.nz.iter().filter(|&&b| b).count()
    }

    /// Fraction of blocks present (may differ slightly from the spec's
    /// target — generators quantize).
    pub fn realized_density(&self) -> f64 {
        self.nonzero_blocks() as f64 / self.total_blocks() as f64
    }

    pub fn is_nonzero(&self, bi: usize, bj: usize) -> bool {
        self.nz[bi * self.block_cols + bj]
    }

    /// Nonzero *elements* of the `m x n` operand (edge blocks clipped) —
    /// the numerator of effective TFlop/s.
    pub fn nnz_elems(&self, m: usize, n: usize) -> u64 {
        let b = self.spec.block;
        let mut total = 0u64;
        for bi in 0..self.block_rows {
            let rh = (m - bi * b).min(b);
            for bj in 0..self.block_cols {
                if self.nz[bi * self.block_cols + bj] {
                    let cw = (n - bj * b).min(b);
                    total += (rh * cw) as u64;
                }
            }
        }
        total
    }

    /// Per-cell density of a `pm x pn` partition grid, row-major
    /// (`pm * pn` entries; a cell no block maps to is 0.0). The graph
    /// builder uses this to give each tile's worklist its own cell's
    /// density, so load imbalance is visible in the BSP trace.
    pub fn cell_density_matrix(&self, pm: usize, pn: usize) -> Vec<f64> {
        assert!(pm >= 1 && pn >= 1, "degenerate partition grid {pm}x{pn}");
        let mut counts = vec![0u64; pm * pn];
        let mut caps = vec![0u64; pm * pn];
        for bi in 0..self.block_rows {
            let ci = (bi * pm / self.block_rows).min(pm - 1);
            for bj in 0..self.block_cols {
                let cj = (bj * pn / self.block_cols).min(pn - 1);
                let cell = ci * pn + cj;
                caps[cell] += 1;
                if self.nz[bi * self.block_cols + bj] {
                    counts[cell] += 1;
                }
            }
        }
        counts
            .iter()
            .zip(&caps)
            .map(|(c, cap)| if *cap == 0 { 0.0 } else { *c as f64 / *cap as f64 })
            .collect()
    }

    /// Density of every `pm x pn` partition cell, reduced to
    /// `(max, mean)` over non-empty cells. The **max** is the planner's
    /// critical density: BSP is lockstep, so the densest cell's tile
    /// prices the compute phase.
    pub fn cell_densities(&self, pm: usize, pn: usize) -> (f64, f64) {
        let pm = pm.clamp(1, self.block_rows);
        let pn = pn.clamp(1, self.block_cols);
        // clamped grids are surjective (pm <= block_rows, pn <= block_cols),
        // so every cell holds at least one block and counts toward the mean
        let cells = self.cell_density_matrix(pm, pn);
        let mut max = 0.0f64;
        let mut sum = 0.0f64;
        for &d in &cells {
            max = max.max(d);
            sum += d;
        }
        (max, sum / cells.len() as f64)
    }

    /// Prefix-summed occupancy index: build once in O(blocks), then every
    /// [`CellIndex::cell_densities`] query is O(pm * pn). The CSR-aware
    /// admission scans ask for hundreds of distinct partition grids per
    /// pattern (one per `(pm, pn)` the candidate space visits), which
    /// would be O(blocks) each through [`Self::cell_densities`].
    pub fn cell_index(&self) -> CellIndex {
        let (rows, cols) = (self.block_rows, self.block_cols);
        let mut prefix = vec![0u32; (rows + 1) * (cols + 1)];
        for bi in 0..rows {
            let mut row_run = 0u32;
            for bj in 0..cols {
                row_run += u32::from(self.nz[bi * cols + bj]);
                prefix[(bi + 1) * (cols + 1) + (bj + 1)] =
                    prefix[bi * (cols + 1) + (bj + 1)] + row_run;
            }
        }
        CellIndex { block_rows: rows, block_cols: cols, prefix }
    }

    /// Content fingerprint (spec + occupancy bits) for diagnostics.
    pub fn fingerprint(&self) -> u64 {
        let mut h = std::collections::hash_map::DefaultHasher::new();
        self.spec.fingerprint().hash(&mut h);
        self.block_rows.hash(&mut h);
        self.block_cols.hash(&mut h);
        self.nz.hash(&mut h);
        h.finish()
    }
}

/// O(1)-per-cell occupancy queries over a [`BlockPattern`] (see
/// [`BlockPattern::cell_index`]). Queries reproduce
/// [`BlockPattern::cell_densities`] bit-for-bit: same cell boundaries,
/// same accumulation order.
pub struct CellIndex {
    block_rows: usize,
    block_cols: usize,
    /// `(block_rows + 1) x (block_cols + 1)` 2-D prefix counts.
    prefix: Vec<u32>,
}

impl CellIndex {
    fn count(&self, r0: usize, r1: usize, c0: usize, c1: usize) -> u64 {
        let w = self.block_cols + 1;
        (self.prefix[r1 * w + c1] as u64 + self.prefix[r0 * w + c0] as u64)
            - (self.prefix[r0 * w + c1] as u64 + self.prefix[r1 * w + c0] as u64)
    }

    /// `(max, mean)` cell density of the `pm x pn` partition grid —
    /// identical to [`BlockPattern::cell_densities`] for every grid.
    pub fn cell_densities(&self, pm: usize, pn: usize) -> (f64, f64) {
        let pm = pm.clamp(1, self.block_rows);
        let pn = pn.clamp(1, self.block_cols);
        // the same floor-partition boundaries cell_density_matrix induces:
        // block row bi belongs to cell bi * pm / block_rows, so cell ci
        // spans rows [ceil(ci * R / pm), ceil((ci + 1) * R / pm))
        let row_at = |ci: usize| (ci * self.block_rows).div_ceil(pm);
        let col_at = |cj: usize| (cj * self.block_cols).div_ceil(pn);
        let mut max = 0.0f64;
        let mut sum = 0.0f64;
        for ci in 0..pm {
            let (r0, r1) = (row_at(ci), row_at(ci + 1));
            for cj in 0..pn {
                let (c0, c1) = (col_at(cj), col_at(cj + 1));
                let cap = ((r1 - r0) * (c1 - c0)) as u64;
                let d = if cap == 0 {
                    0.0
                } else {
                    self.count(r0, r1, c0, c1) as f64 / cap as f64
                };
                max = max.max(d);
                sum += d;
            }
        }
        (max, sum / (pm * pn) as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(kind: PatternKind, density: f64) -> SparsitySpec {
        SparsitySpec::new(kind, 8, density, 42)
    }

    #[test]
    fn dense_spec_fills_every_block() {
        for kind in PatternKind::all() {
            let p = BlockPattern::generate(spec(kind, 1.0), 256, 512);
            assert_eq!(p.nonzero_blocks(), p.total_blocks(), "{kind:?}");
            assert_eq!(p.realized_density(), 1.0);
            assert_eq!(p.nnz_elems(256, 512), 256 * 512);
        }
    }

    #[test]
    fn random_density_is_exact() {
        let p = BlockPattern::generate(spec(PatternKind::Random, 0.25), 512, 512);
        // 64x64 blocks, target ceil(0.25 * 4096) = 1024
        assert_eq!(p.nonzero_blocks(), 1024);
    }

    #[test]
    fn random_and_banded_nest_across_densities() {
        for kind in [PatternKind::Random, PatternKind::Banded] {
            let lo = BlockPattern::generate(spec(kind, 0.2), 384, 768);
            let hi = BlockPattern::generate(spec(kind, 0.7), 384, 768);
            assert!(lo.nonzero_blocks() <= hi.nonzero_blocks());
            for bi in 0..lo.block_rows {
                for bj in 0..lo.block_cols {
                    if lo.is_nonzero(bi, bj) {
                        assert!(hi.is_nonzero(bi, bj), "{kind:?} not nested at ({bi},{bj})");
                    }
                }
            }
        }
    }

    #[test]
    fn generators_are_deterministic() {
        let a = BlockPattern::generate(spec(PatternKind::Random, 0.3), 400, 400);
        let b = BlockPattern::generate(spec(PatternKind::Random, 0.3), 400, 400);
        assert_eq!(a.fingerprint(), b.fingerprint());
        let c = BlockPattern::generate(SparsitySpec::new(PatternKind::Random, 8, 0.3, 43), 400, 400);
        assert_ne!(a.fingerprint(), c.fingerprint(), "seed must matter");
    }

    #[test]
    fn banded_concentrates_near_diagonal() {
        let p = BlockPattern::generate(spec(PatternKind::Banded, 0.1), 1024, 1024);
        assert!(p.is_nonzero(0, 0));
        assert!(p.is_nonzero(p.block_rows - 1, p.block_cols - 1));
        assert!(!p.is_nonzero(0, p.block_cols - 1), "far corner must be zero");
        let d = p.realized_density();
        assert!((0.02..=0.3).contains(&d), "density {d}");
    }

    #[test]
    fn block_diagonal_groups() {
        let p = BlockPattern::generate(spec(PatternKind::BlockDiagonal, 0.25), 512, 512);
        // 4 groups of 16x16 blocks each -> exactly 1/4 of blocks
        assert_eq!(p.nonzero_blocks() * 4, p.total_blocks());
        assert!(p.is_nonzero(0, 0));
        assert!(!p.is_nonzero(0, p.block_cols - 1));
    }

    #[test]
    fn cell_densities_bound_realized() {
        let p = BlockPattern::generate(spec(PatternKind::Banded, 0.2), 2048, 2048);
        let (max, mean) = p.cell_densities(8, 4);
        assert!(max >= mean, "max {max} < mean {mean}");
        assert!(max <= 1.0 && mean > 0.0);
        // full pattern: every cell fully dense
        let full = BlockPattern::generate(spec(PatternKind::Random, 1.0), 2048, 2048);
        let (fmax, fmean) = full.cell_densities(8, 4);
        assert_eq!((fmax, fmean), (1.0, 1.0));
    }

    #[test]
    fn cell_index_matches_cell_densities_exactly() {
        // the prefix-sum index must be a bit-for-bit drop-in for the
        // O(blocks) scan, for every grid the candidate space can visit
        use crate::util::rng::Rng;
        let mut rng = Rng::new(0xCE11);
        for kind in PatternKind::all() {
            for density in [0.08, 0.35, 1.0] {
                let p = BlockPattern::generate(spec(kind, density), 1111, 733);
                let idx = p.cell_index();
                for _ in 0..40 {
                    let pm = rng.gen_usize(1, 200);
                    let pn = rng.gen_usize(1, 200);
                    let (emax, emean) = p.cell_densities(pm, pn);
                    let (imax, imean) = idx.cell_densities(pm, pn);
                    assert_eq!(emax.to_bits(), imax.to_bits(), "{kind:?} d{density} {pm}x{pn}");
                    assert_eq!(emean.to_bits(), imean.to_bits(), "{kind:?} d{density} {pm}x{pn}");
                }
            }
        }
    }

    #[test]
    fn edge_blocks_clip_nnz_elems() {
        // 100x100 with block 8 -> 13x13 blocks, edge blocks 4 wide/high
        let p = BlockPattern::generate(spec(PatternKind::Random, 1.0), 100, 100);
        assert_eq!(p.block_rows, 13);
        assert_eq!(p.nnz_elems(100, 100), 100 * 100);
    }

    #[test]
    fn fingerprints_distinguish_specs() {
        let a = SparsitySpec::new(PatternKind::Random, 8, 0.5, 1);
        assert_eq!(a.fingerprint(), a.fingerprint());
        for b in [
            SparsitySpec::new(PatternKind::Banded, 8, 0.5, 1),
            SparsitySpec::new(PatternKind::Random, 16, 0.5, 1),
            SparsitySpec::new(PatternKind::Random, 8, 0.25, 1),
            SparsitySpec::new(PatternKind::Random, 8, 0.5, 2),
        ] {
            assert_ne!(a.fingerprint(), b.fingerprint(), "{b:?}");
        }
    }

    #[test]
    fn kind_name_roundtrip() {
        for kind in PatternKind::all() {
            assert_eq!(PatternKind::by_name(kind.name()), Some(kind));
        }
        assert_eq!(PatternKind::by_name("block-diagonal"), Some(PatternKind::BlockDiagonal));
        assert_eq!(PatternKind::by_name("dense"), None);
    }

    #[test]
    #[should_panic(expected = "not in supported sizes")]
    fn bad_block_size_rejected() {
        SparsitySpec::new(PatternKind::Random, 32, 0.5, 0);
    }

    #[test]
    fn spec_density_quantizes_and_clamps() {
        assert_eq!(SparsitySpec::new(PatternKind::Random, 8, 0.3333, 0).density_permille, 333);
        assert_eq!(SparsitySpec::new(PatternKind::Random, 8, 0.0, 0).density_permille, 1);
        assert_eq!(SparsitySpec::new(PatternKind::Random, 8, 2.0, 0).density_permille, 1000);
        assert!(SparsitySpec::dense(4).is_dense());
    }

    #[test]
    fn label_is_compact() {
        let s = SparsitySpec::new(PatternKind::Banded, 16, 0.25, 9);
        assert_eq!(s.label(), "banded/b16/d0.25");
    }
}
