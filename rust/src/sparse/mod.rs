//! Block-sparse matmul subsystem (PopSparse-style static block-CSR).
//!
//! PopSparse (Li et al., arXiv 2303.16999) shows that the IPU's natural
//! next matmul workload after the paper's dense squared/skewed study is
//! *block-sparse* multiplication: `A` is sparse at block granularity
//! (blocks of 4/8/16), the sparsity pattern is known at compile time, and
//! the planner's job gets strictly harder because per-tile work becomes
//! irregular. This module opens that workload on the existing stack:
//!
//! * [`pattern`] — seeded block-sparsity patterns (random / banded /
//!   block-diagonal generators at a target density) plus the compact
//!   [`pattern::SparsitySpec`] descriptor whose fingerprint extends the
//!   serving layer's plan-cache key.
//! * [`csr`] — the block-CSR layout (`row_ptr`/`col_idx` over block
//!   coordinates) and per-tile nonzero-block assignment that reuses
//!   [`crate::memory::mapping`]'s balancing.
//! * [`planner`] — a sparsity-aware cost/search wrapper over
//!   [`crate::planner`]: compute and exchange scale with the realized
//!   density of the *densest* partition cell (BSP is lockstep, so the
//!   bottleneck tile prices the phase), and the memory bill is
//!   CSR-aware — the A operand is admitted at its block-CSR footprint
//!   (`planner::sparse_tile_bytes`), so the paper's §2.4 wall becomes a
//!   density-dependent curve (`planner::sparse_max_fitting_square`)
//!   while density 1.0 reproduces the dense bill and OOM verdict
//!   bit-for-bit.
//!
//! Reports carry both throughput conventions Domke et al.'s matrix-engine
//! survey distinguishes: **dense-equivalent** TFlop/s (all `2mnk` flops
//! over the sparse runtime) and **effective** TFlop/s (nonzero work
//! only). The density x aspect-ratio sweep lives in
//! `experiments::sparse_sweep` (`ipumm sparse`).

pub mod csr;
pub mod pattern;
pub mod planner;

pub use csr::{BlockCsr, TileAssignment};
pub use pattern::{BlockPattern, PatternKind, SparsitySpec};
pub use planner::{
    sparse_max_fitting_square, sparse_max_fitting_square_linear, sparse_plan_from_dense,
    sparse_search, sparse_search_fits, sparse_search_spec, sparse_tile_bytes, SparseCost,
    SparsePlan,
};
