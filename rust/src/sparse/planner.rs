//! Sparsity-aware plan search: a wrapper over `planner::{cost, search}`.
//!
//! PopSparse keeps the *memory* picture of a static block-sparse matmul
//! essentially dense (dense-equivalent buffers, unrolled exchange code),
//! while *work* shrinks with the nonzero blocks each tile owns. The
//! wrapper models exactly that split:
//!
//! * **memory** — candidates are admitted by the *dense* memory bill
//!   (`CostModel::tile_bytes`), so the paper's §2.4 wall is unchanged:
//!   a shape that OOMs dense also OOMs sparse;
//! * **compute** — the dense compute bucket scales by the density of the
//!   *densest* `pm x pn` partition cell (BSP is lockstep: the bottleneck
//!   tile prices the phase, which is how block-sparse load imbalance
//!   shows up as lost throughput);
//! * **exchange** — only the A-chunk share of per-superstep traffic
//!   scales with density (B stays dense), split by the `sm/(sm+sk)`
//!   byte ratio; syncs are unchanged (every superstep still runs).
//!
//! The search seeds from the dense winner — optimal at density 1.0 by
//! construction, so density 1.0 reproduces the dense plan's cost exactly
//! — and refines the reduction split and chunk size, where sparsity
//! shifts the optimum. Candidates are density-independent and the
//! per-candidate cost is monotone in the nonzero set, which makes total
//! sparse cost monotone non-increasing as density falls (for nested
//! generators; see the property tests).

use crate::arch::IpuArch;
use crate::planner::cost::{consts, CostConfig, CostModel, PlanCost};
use crate::planner::partition::{MmShape, Partition};
use crate::planner::search::{search_with_config, Plan, PlannerError};
use crate::sparse::pattern::{BlockPattern, SparsitySpec};
use crate::util::units::div_ceil;

/// Dense candidate cost plus its sparsity-scaled cycle buckets.
#[derive(Clone, Copy, Debug)]
pub struct SparseCost {
    /// The dense pricing of the same partition (memory authority).
    pub dense: PlanCost,
    /// Density of the densest partition cell — the scaling bottleneck.
    pub critical_density: f64,
    /// Mean cell density (load-balance diagnostic: mean/critical).
    pub mean_density: f64,
    pub compute_cycles: u64,
    pub exchange_cycles: u64,
    pub sync_cycles: u64,
    pub total_cycles: u64,
}

/// The sparse search's winning plan.
#[derive(Clone, Debug)]
pub struct SparsePlan {
    pub shape: MmShape,
    pub spec: SparsitySpec,
    /// The dense incumbent the wrapper refined from (and the plan served
    /// at density 1.0).
    pub dense_plan: Plan,
    pub cost: SparseCost,
    /// Whole-pattern nonzero-block fraction.
    pub realized_density: f64,
    /// Nonzero elements of A (edge-clipped) — effective-flops numerator.
    pub nnz_elems: u64,
    /// Sparse candidates priced on top of the dense search.
    pub candidates_evaluated: usize,
}

impl SparsePlan {
    pub fn partition(&self) -> Partition {
        self.cost.dense.partition
    }

    pub fn seconds(&self, arch: &IpuArch) -> f64 {
        arch.cycles_to_secs(self.cost.total_cycles)
    }

    /// Dense-equivalent TFlop/s: the full `2mnk` flops over the sparse
    /// runtime (Domke et al.'s "marketing" convention — what a dense
    /// replacement would have had to sustain).
    pub fn dense_equiv_tflops(&self, arch: &IpuArch) -> f64 {
        self.shape.flops() as f64 / self.seconds(arch) / 1e12
    }

    /// Effective TFlop/s: only the nonzero work counts.
    pub fn effective_tflops(&self, arch: &IpuArch) -> f64 {
        self.effective_flops() as f64 / self.seconds(arch) / 1e12
    }

    /// Flops actually performed: `2 * nnz(A) * k`.
    pub fn effective_flops(&self) -> u64 {
        2 * self.nnz_elems * self.shape.k as u64
    }

    /// Runtime ratio vs the dense plan for the same shape (>= 1.0: the
    /// dense winner is always a sparse candidate and sparsity only
    /// removes work).
    pub fn speedup_vs_dense(&self) -> f64 {
        self.dense_plan.cost.total_cycles as f64 / self.cost.total_cycles.max(1) as f64
    }

    /// Model efficiency under the effective convention: nonzero MAC
    /// cycles over the critical path.
    pub fn efficiency(&self) -> f64 {
        if self.cost.total_cycles == 0 {
            0.0
        } else {
            (self.dense_plan.cost.useful_cycles as f64 * self.realized_density
                / self.cost.total_cycles as f64)
                .min(1.0)
        }
    }
}

fn scale_cycles(cycles: u64, factor: f64) -> u64 {
    (cycles as f64 * factor).ceil() as u64
}

/// Price one partition for a pattern: dense evaluation, then density
/// scaling of the compute and A-traffic buckets.
pub fn sparse_cost(
    model: &CostModel,
    shape: MmShape,
    part: Partition,
    pattern: &BlockPattern,
) -> SparseCost {
    let dense = model.evaluate(shape, part);
    let (critical, mean) = pattern.cell_densities(part.pm, part.pn);
    let (sm, _, sk) = part.sub_block(shape);
    let a_frac = sm as f64 / (sm + sk) as f64;
    let compute_cycles = scale_cycles(dense.compute_cycles, critical);
    let exchange_cycles =
        scale_cycles(dense.exchange_cycles, a_frac * critical + (1.0 - a_frac));
    let sync_cycles = dense.sync_cycles;
    SparseCost {
        dense,
        critical_density: critical,
        mean_density: mean,
        compute_cycles,
        exchange_cycles,
        sync_cycles,
        total_cycles: compute_cycles + exchange_cycles + sync_cycles,
    }
}

/// Refinement candidates around the dense winner: re-balanced reduction
/// splits (sparsity starves the reduction dimension, shifting the
/// split/no-split tradeoff) and the planner's chunk-size ladder. The
/// seed itself is always first, so ties resolve to the dense optimum.
fn candidate_partitions(shape: MmShape, seed: Partition) -> Vec<Partition> {
    let mut out = vec![seed];
    let push = |p: Partition, out: &mut Vec<Partition>| {
        if !out.contains(&p) {
            out.push(p);
        }
    };
    for pn in [1usize, 2, 4, 8] {
        if pn == seed.pn {
            continue;
        }
        // preserve the tile budget: trade pm against the reduction plane
        let pm = (seed.pm * seed.pn / pn).max(1);
        let cn = seed.cn.min(div_ceil(shape.n, pn)).max(1);
        push(Partition { pm, pn, pk: seed.pk, cn }, &mut out);
    }
    for &cn in &consts::CN_CANDIDATES {
        let cn = cn.min(div_ceil(shape.n, seed.pn)).max(1);
        push(Partition { cn, ..seed }, &mut out);
    }
    out
}

/// Find the fastest plan for `shape` under `pattern` (full cost model).
/// `Err` is the *dense* §2.4 memory wall — unchanged by sparsity.
pub fn sparse_search(
    arch: &IpuArch,
    shape: MmShape,
    pattern: &BlockPattern,
) -> Result<SparsePlan, PlannerError> {
    sparse_search_with_config(arch, shape, pattern, CostConfig::default())
}

/// [`sparse_search`] under an ablated cost model.
pub fn sparse_search_with_config(
    arch: &IpuArch,
    shape: MmShape,
    pattern: &BlockPattern,
    config: CostConfig,
) -> Result<SparsePlan, PlannerError> {
    let dense_plan = search_with_config(arch, shape, config)?;
    Ok(sparse_plan_from_dense(arch, shape, pattern, config, dense_plan))
}

/// Price `pattern` against a *precomputed* dense plan for the same
/// `(arch, shape, config)`. The dense search is the expensive step and
/// depends only on the shape, so sweeps over many densities of one
/// shape should run it once and amortize it here (the plan cache plays
/// the same role for the serving layer). Infallible: a fitting dense
/// plan is always a valid sparse candidate.
pub fn sparse_plan_from_dense(
    arch: &IpuArch,
    shape: MmShape,
    pattern: &BlockPattern,
    config: CostConfig,
    dense_plan: Plan,
) -> SparsePlan {
    let model = CostModel::with_config(arch, config);
    if pattern.nonzero_blocks() == pattern.total_blocks() {
        // fully dense pattern IS the dense problem: serve the dense
        // winner verbatim (every scale factor is 1.0, and the dense
        // search's optimum is authoritative)
        let cost = sparse_cost(&model, shape, dense_plan.partition(), pattern);
        return SparsePlan {
            shape,
            spec: pattern.spec,
            realized_density: 1.0,
            nnz_elems: pattern.nnz_elems(shape.m, shape.n),
            dense_plan,
            cost,
            candidates_evaluated: 1,
        };
    }
    let mut best: Option<SparseCost> = None;
    let mut evaluated = 0usize;
    for part in candidate_partitions(shape, dense_plan.partition()) {
        if !part.is_valid(shape, arch.tiles) {
            continue;
        }
        // dense memory admission: sparsity never relaxes the wall
        if model.tile_bytes(shape, part) > arch.tile_sram_bytes {
            continue;
        }
        evaluated += 1;
        let cost = sparse_cost(&model, shape, part, pattern);
        debug_assert!(cost.dense.fits);
        let better = match &best {
            None => true,
            Some(b) => cost.total_cycles < b.total_cycles,
        };
        if better {
            best = Some(cost);
        }
    }
    // the dense winner always passes both filters, so `best` is set
    let cost = best.expect("dense winner is a valid sparse candidate");
    SparsePlan {
        shape,
        spec: pattern.spec,
        realized_density: pattern.realized_density(),
        nnz_elems: pattern.nnz_elems(shape.m, shape.n),
        dense_plan,
        cost,
        candidates_evaluated: evaluated,
    }
}

/// Plan from a spec alone (materializes the pattern) — the serving
/// layer's entry point: the cache key is `(shape, arch, spec)`.
pub fn sparse_search_spec(
    arch: &IpuArch,
    shape: MmShape,
    spec: SparsitySpec,
) -> Result<SparsePlan, PlannerError> {
    let pattern = BlockPattern::for_shape(spec, shape);
    sparse_search(arch, shape, &pattern)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::planner::search::search;
    use crate::sparse::pattern::PatternKind;

    fn arch() -> IpuArch {
        IpuArch::gc200()
    }

    fn plan_at(shape: MmShape, kind: PatternKind, density: f64) -> SparsePlan {
        let spec = SparsitySpec::new(kind, 8, density, 42);
        sparse_search_spec(&arch(), shape, spec).unwrap()
    }

    #[test]
    fn density_one_reproduces_dense_plan_exactly() {
        let shape = MmShape::square(1536);
        let dense = search(&arch(), shape).unwrap();
        for kind in PatternKind::all() {
            let sparse = plan_at(shape, kind, 1.0);
            assert_eq!(sparse.partition(), dense.partition(), "{kind:?}");
            assert_eq!(
                sparse.cost.total_cycles, dense.cost.total_cycles,
                "{kind:?}: sparse {} vs dense {}",
                sparse.cost.total_cycles, dense.cost.total_cycles
            );
            assert!((sparse.speedup_vs_dense() - 1.0).abs() < 1e-12);
            assert_eq!(sparse.effective_flops(), shape.flops());
        }
    }

    #[test]
    fn sparser_is_never_slower() {
        let shape = MmShape::square(2048);
        let mut prev: Option<u64> = None;
        for permille in [100u32, 250, 500, 750, 1000] {
            let p = plan_at(shape, PatternKind::Random, permille as f64 / 1000.0);
            if let Some(prev) = prev {
                assert!(
                    prev <= p.cost.total_cycles,
                    "cost fell from {} to {} as density rose to {permille}",
                    prev,
                    p.cost.total_cycles
                );
            }
            assert!(p.speedup_vs_dense() >= 1.0 - 1e-12);
            prev = Some(p.cost.total_cycles);
        }
    }

    #[test]
    fn effective_tflops_below_dense_equiv() {
        let a = arch();
        let p = plan_at(MmShape::square(2048), PatternKind::Random, 0.25);
        let eff = p.effective_tflops(&a);
        let deq = p.dense_equiv_tflops(&a);
        assert!(eff > 0.0 && eff < deq, "effective {eff} vs dense-equiv {deq}");
        // a quarter of the blocks -> a quarter of the effective flops
        let ratio = p.effective_flops() as f64 / p.shape.flops() as f64;
        assert!((ratio - 0.25).abs() < 0.01, "nnz ratio {ratio}");
    }

    #[test]
    fn dense_memory_wall_survives_sparsity() {
        // far past the §2.4 wall: even a 10%-dense pattern must OOM,
        // because static block-CSR keeps the dense memory bill
        let spec = SparsitySpec::new(PatternKind::Random, 8, 0.1, 1);
        let err = sparse_search_spec(&arch(), MmShape::square(6144), spec).unwrap_err();
        assert!(matches!(err, PlannerError::OutOfMemory { .. }));
    }

    #[test]
    fn skewed_sparse_plans_still_fit_and_win() {
        // the headline question: does the skew advantage survive sparsity?
        let a = arch();
        let right = MmShape::new(512, 8192, 2048);
        let p = plan_at(right, PatternKind::Random, 0.5);
        assert!(p.cost.dense.fits);
        assert!(p.speedup_vs_dense() > 1.0, "sparsity should pay: {}", p.speedup_vs_dense());
        assert!(p.effective_tflops(&a) > 0.0);
    }

    #[test]
    fn banded_right_skew_can_resplit_reduction() {
        // candidates include re-balanced pn variants; whatever wins must
        // beat or match the dense winner priced sparse
        let shape = MmShape::new(512, 16384, 2048);
        let p = plan_at(shape, PatternKind::Banded, 0.2);
        let a = arch();
        let model = CostModel::new(&a);
        let pattern = BlockPattern::for_shape(p.spec, shape);
        let seeded = sparse_cost(&model, shape, p.dense_plan.partition(), &pattern);
        assert!(p.cost.total_cycles <= seeded.total_cycles);
        assert!(p.candidates_evaluated >= 2);
    }

    #[test]
    fn critical_density_bounds_mean() {
        let p = plan_at(MmShape::square(1024), PatternKind::Banded, 0.3);
        assert!(p.cost.critical_density >= p.cost.mean_density);
        assert!(p.cost.critical_density <= 1.0);
        assert!(p.efficiency() > 0.0 && p.efficiency() <= 1.0);
    }

    #[test]
    fn sync_cycles_do_not_scale() {
        let dense = plan_at(MmShape::square(1024), PatternKind::Random, 1.0);
        let sparse = plan_at(MmShape::square(1024), PatternKind::Random, 0.2);
        if sparse.partition() == dense.partition() {
            assert_eq!(sparse.cost.sync_cycles, dense.cost.sync_cycles);
        }
        assert!(sparse.cost.compute_cycles < dense.cost.compute_cycles);
    }
}
