//! Sparsity-aware plan search: a wrapper over `planner::{cost, search}`.
//!
//! PopSparse keeps the *work* picture of a static block-sparse matmul
//! proportional to the nonzero blocks each tile owns, and — unlike the
//! seed model — its *memory* picture is sparse too: only the nonzero
//! `A` blocks (plus their block-CSR index) are resident per tile. The
//! wrapper models exactly that split:
//!
//! * **memory** — candidates are admitted by [`sparse_tile_bytes`]: the
//!   dense bill ([`CostModel::tile_bill`]) with the A-side components
//!   substituted — the A home share becomes the block-CSR footprint
//!   ([`BlockCsr::residency_per_tile`], balanced per tile the way the
//!   graph builder maps it) and the A chunk buffers scale with the
//!   densest-cell density. B, C, landing, and exchange code stay dense.
//!   The paper's §2.4 wall becomes **density-dependent**: shapes past
//!   the dense wall can plan sparse ([`sparse_max_fitting_square`]
//!   reports the wall per density), while density 1.0 reproduces the
//!   dense bill — and the dense OOM verdict — bit-for-bit. Each sparse
//!   A component is capped at its dense share (a dense layout is always
//!   a legal fallback), so admission is monotone: anything fitting dense
//!   fits at every density.
//! * **compute** — the dense compute bucket scales by the density of the
//!   *densest* `pm x pn` partition cell (BSP is lockstep: the bottleneck
//!   tile prices the phase, which is how block-sparse load imbalance
//!   shows up as lost throughput);
//! * **exchange** — scaled per sub-bucket (the seed scaled the whole
//!   bucket by the chunk factor, under-pricing reduction-heavy plans):
//!   the per-superstep **chunk** A share scales by critical density, the
//!   one-shot **prologue** A share by realized density (only nonzero
//!   blocks are scattered), and the **reduction** landing is pure C
//!   traffic — it stays dense. Syncs are unchanged (every superstep
//!   still runs).
//!
//! When the dense planner finds a winner, the search seeds from it —
//! optimal at density 1.0 by construction — and refines the reduction
//! split and chunk size. Past the dense wall there is no incumbent, so
//! [`sparse_search`] falls back to a full scan of the candidate space
//! under the sparse bill. Candidates are density-independent and the
//! per-candidate cost is monotone in the nonzero set, which makes total
//! sparse cost monotone non-increasing as density falls (for nested
//! generators; see the property tests).

use std::cell::RefCell;
use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};

use crate::arch::IpuArch;
use crate::coordinator::runner::ThreadBudget;
use crate::planner::cost::{consts, CostConfig, CostModel, PlanCost};
use crate::planner::partition::{MmShape, Partition};
use crate::planner::search::{
    bisect_max_fitting, for_each_candidate, for_each_candidate_in_stripe, search_fits_with_config,
    search_with_config, search_workers, CandidateSpace, Plan, PlannerError, StripeObs,
    PARALLEL_MIN_PMS,
};
use crate::sparse::csr::BlockCsr;
use crate::sparse::pattern::{BlockPattern, CellIndex, SparsitySpec};
use crate::util::units::div_ceil;

/// Dense candidate cost plus its sparsity-scaled cycle buckets and the
/// CSR-aware memory bill.
#[derive(Clone, Copy, Debug)]
pub struct SparseCost {
    /// The dense pricing of the same partition (cycle-bucket baseline;
    /// its `fits` flag is the *dense* verdict, not the sparse one).
    pub dense: PlanCost,
    /// Density of the densest partition cell — the scaling bottleneck.
    pub critical_density: f64,
    /// Mean cell density (load-balance diagnostic: mean/critical).
    pub mean_density: f64,
    pub compute_cycles: u64,
    pub exchange_cycles: u64,
    pub sync_cycles: u64,
    pub total_cycles: u64,
    /// Cycles of *actual* MAC work (`nnz_elems * k` spread over the used
    /// tiles) — the effective-efficiency numerator. Equals the dense
    /// `useful_cycles` at density 1.0.
    pub useful_cycles: u64,
    /// Heaviest-tile bytes under the CSR-aware bill
    /// ([`sparse_tile_bytes`]); `<=` the dense `tile_bytes_total`.
    pub sparse_tile_bytes: u64,
    /// The sparse admission verdict: `sparse_tile_bytes` fits SRAM.
    pub fits: bool,
}

/// The sparse search's winning plan.
#[derive(Clone, Debug)]
pub struct SparsePlan {
    pub shape: MmShape,
    pub spec: SparsitySpec,
    /// The dense incumbent the wrapper refined from (and the plan served
    /// at density 1.0). `None` past the dense §2.4 wall, where only the
    /// CSR-aware bill admits a plan and no dense baseline exists.
    pub dense_plan: Option<Plan>,
    pub cost: SparseCost,
    /// Whole-pattern nonzero-block fraction.
    pub realized_density: f64,
    /// Nonzero elements of A (edge-clipped) — effective-flops numerator.
    pub nnz_elems: u64,
    /// Sparse candidates priced: refinements on top of the dense search,
    /// or the admitted slice of the full space past the dense wall.
    pub candidates_evaluated: usize,
}

impl SparsePlan {
    pub fn partition(&self) -> Partition {
        self.cost.dense.partition
    }

    pub fn seconds(&self, arch: &IpuArch) -> f64 {
        arch.cycles_to_secs(self.cost.total_cycles)
    }

    /// Dense-equivalent TFlop/s: the full `2mnk` flops over the sparse
    /// runtime (Domke et al.'s "marketing" convention — what a dense
    /// replacement would have had to sustain).
    pub fn dense_equiv_tflops(&self, arch: &IpuArch) -> f64 {
        self.shape.flops() as f64 / self.seconds(arch) / 1e12
    }

    /// Effective TFlop/s: only the nonzero work counts.
    pub fn effective_tflops(&self, arch: &IpuArch) -> f64 {
        self.effective_flops() as f64 / self.seconds(arch) / 1e12
    }

    /// Flops actually performed: `2 * nnz(A) * k`.
    pub fn effective_flops(&self) -> u64 {
        2 * self.nnz_elems * self.shape.k as u64
    }

    /// Runtime ratio vs the dense plan for the same shape (>= 1.0: the
    /// dense winner is always a sparse candidate and sparsity only
    /// removes work). `None` past the dense wall — no dense baseline.
    pub fn speedup_vs_dense(&self) -> Option<f64> {
        self.dense_plan
            .as_ref()
            .map(|d| d.cost.total_cycles as f64 / self.cost.total_cycles.max(1) as f64)
    }

    /// Model efficiency under the effective convention: cycles of actual
    /// MAC work over the critical path. Unclamped — pricing compute by
    /// the *critical* (not realized) density keeps this `<= 1` under
    /// load imbalance, which the old realized-density metric only
    /// achieved by clamping.
    pub fn efficiency(&self) -> f64 {
        if self.cost.total_cycles == 0 {
            0.0
        } else {
            self.cost.useful_cycles as f64 / self.cost.total_cycles as f64
        }
    }
}

fn scale_cycles(cycles: u64, factor: f64) -> u64 {
    (cycles as f64 * factor).ceil() as u64
}

/// Scale a byte quantity by a density in `[0, 1]` — the ceil never
/// exceeds the input, so density-scaled components stay capped at their
/// dense share by construction.
fn scale_bytes(bytes: u64, density: f64) -> u64 {
    debug_assert!((0.0..=1.0).contains(&density), "density {density} out of range");
    (bytes as f64 * density).ceil() as u64
}

/// Partition-independent facts about one pattern, hoisted out of the
/// per-candidate loops (`nnz_elems` and the CSR residency are O(blocks)
/// scans — pricing thousands of candidates must not repeat them).
struct PatternStats {
    realized: f64,
    nnz_elems: u64,
    /// Heaviest-tile block-CSR footprint (values + index) of the A
    /// operand, balanced over the whole chip.
    csr_resident: u64,
}

fn pattern_stats(model: &CostModel, shape: MmShape, pattern: &BlockPattern) -> PatternStats {
    let csr = BlockCsr::from_pattern(pattern);
    PatternStats {
        realized: pattern.realized_density(),
        nnz_elems: pattern.nnz_elems(shape.m, shape.n),
        csr_resident: csr.max_tile_residency(model.arch.tiles, model.eb()),
    }
}

/// Everything the admission scans need from one `(shape, pattern)` pair,
/// hoisted out of the per-candidate (and per-probe) loops: the O(blocks)
/// [`pattern_stats`] scan (including the [`BlockCsr`] residency balance)
/// and the O(blocks) [`CellIndex`] prefix build each happen **once** per
/// context, shared by the fits probe, the past-the-wall search, and every
/// parallel stripe — the seed rebuilt all three per call site.
pub(crate) struct PatternContext {
    stats: PatternStats,
    index: CellIndex,
}

impl PatternContext {
    pub(crate) fn new(model: &CostModel, shape: MmShape, pattern: &BlockPattern) -> PatternContext {
        PatternContext {
            stats: pattern_stats(model, shape, pattern),
            index: pattern.cell_index(),
        }
    }
}

/// Per-bucket density scale factors of one candidate:
/// `(compute, chunk exchange, prologue exchange)`. One definition shared
/// by the full [`sparse_cost`] pricing and the staged total
/// ([`sparse_staged_total`]), so the two agree bit-for-bit.
fn sparse_bucket_factors(
    shape: MmShape,
    part: Partition,
    critical: f64,
    realized: f64,
) -> (f64, f64, f64) {
    let (sm, _, sk) = part.sub_block(shape);
    // per-bucket A byte shares: chunks move sm vs sk columns per
    // superstep; the prologue moves the whole m x n vs n x k homes
    let a_frac_chunk = sm as f64 / (sm + sk) as f64;
    let a_frac_prologue = shape.m as f64 / (shape.m + shape.k) as f64;
    (
        critical,
        a_frac_chunk * critical + (1.0 - a_frac_chunk),
        a_frac_prologue * realized + (1.0 - a_frac_prologue),
    )
}

/// §Perf staged sparse pricing: the sparse `total_cycles` of one
/// candidate — bit-identical to [`sparse_cost`]'s — from the cycle-bucket
/// breakdown alone, without materializing the dense [`PlanCost`] or the
/// [`SparseCost`] wrapper. The past-the-wall search ranks every admitted
/// candidate through this and materializes the full cost only for the
/// merged winner.
fn sparse_staged_total(
    model: &CostModel,
    shape: MmShape,
    part: Partition,
    critical: f64,
    realized: f64,
) -> u64 {
    let cc = model.cycle_costs(shape, part);
    let (f_compute, f_chunk, f_prologue) = sparse_bucket_factors(shape, part, critical, realized);
    scale_cycles(cc.compute_cycles, f_compute)
        + scale_cycles(cc.exchange_chunk_cycles, f_chunk)
        + scale_cycles(cc.exchange_prologue_cycles, f_prologue)
        + cc.exchange_reduction_cycles
        + cc.sync_cycles
}

/// The CSR-aware heaviest-tile memory bill of one candidate: the dense
/// [`CostModel::tile_bill`] with the A home share replaced by the
/// block-CSR footprint and the A chunk buffers scaled by the densest-cell
/// density. Each A component is capped at its dense share (dense layout
/// is always a legal fallback), so the bill is `<=` the dense bill at
/// every density and equals it bit-for-bit at density 1.0. Under
/// `config.sparse_residency == false` the dense bill is returned
/// unchanged — the seed's dense-wall admission, kept as the ablation.
pub fn sparse_tile_bytes(
    model: &CostModel,
    shape: MmShape,
    part: Partition,
    pattern: &BlockPattern,
) -> u64 {
    let stats = pattern_stats(model, shape, pattern);
    let (critical, _) = pattern.cell_densities(part.pm, part.pn);
    sparse_bill_bytes(model, shape, part, critical, stats.csr_resident)
}

/// [`sparse_tile_bytes`] from precomputed pattern facts (the admission
/// scans pay the O(blocks) parts once, not per candidate).
fn sparse_bill_bytes(
    model: &CostModel,
    shape: MmShape,
    part: Partition,
    critical: f64,
    csr_resident: u64,
) -> u64 {
    let bill = model.tile_bill(shape, part);
    if !model.config.sparse_residency {
        return bill.total();
    }
    // the home cap is a real layout choice, not just a bound:
    // `sim::build_sparse_graph` stores A dense whenever the CSR
    // footprint (index + padded edge blocks) overshoots the dense share,
    // so the billed residency is what the graph actually maps
    let home_a = bill.home_a.min(csr_resident);
    let chunk_a = scale_bytes(bill.chunk_a, critical);
    bill.total() - bill.a_bytes() + home_a + chunk_a
}

/// Price one partition for a pattern: dense evaluation, then density
/// scaling of the compute and A-traffic buckets plus the CSR memory bill.
pub fn sparse_cost(
    model: &CostModel,
    shape: MmShape,
    part: Partition,
    pattern: &BlockPattern,
) -> SparseCost {
    let stats = pattern_stats(model, shape, pattern);
    let (critical, mean) = pattern.cell_densities(part.pm, part.pn);
    sparse_cost_inner(model, shape, part, critical, mean, &stats)
}

fn sparse_cost_inner(
    model: &CostModel,
    shape: MmShape,
    part: Partition,
    critical: f64,
    mean: f64,
    stats: &PatternStats,
) -> SparseCost {
    let dense = model.evaluate(shape, part);
    let (f_compute, f_chunk, f_prologue) =
        sparse_bucket_factors(shape, part, critical, stats.realized);
    let compute_cycles = scale_cycles(dense.compute_cycles, f_compute);
    let chunk = scale_cycles(dense.exchange_chunk_cycles, f_chunk);
    let prologue = scale_cycles(dense.exchange_prologue_cycles, f_prologue);
    // reduction traffic is C partials — dense regardless of A sparsity
    let exchange_cycles = chunk + prologue + dense.exchange_reduction_cycles;
    let sync_cycles = dense.sync_cycles;
    let useful_macs = stats.nnz_elems * shape.k as u64 / part.tiles_used().max(1) as u64;
    let useful_cycles = useful_macs / model.macs() as u64;
    let sparse_tile_bytes = sparse_bill_bytes(model, shape, part, critical, stats.csr_resident);
    SparseCost {
        dense,
        critical_density: critical,
        mean_density: mean,
        compute_cycles,
        exchange_cycles,
        sync_cycles,
        total_cycles: compute_cycles + exchange_cycles + sync_cycles,
        useful_cycles,
        sparse_tile_bytes,
        fits: sparse_tile_bytes <= model.arch.tile_sram_bytes,
    }
}

/// Refinement candidates around the dense winner: re-balanced reduction
/// splits (sparsity starves the reduction dimension, shifting the
/// split/no-split tradeoff) and the planner's chunk-size ladder. The
/// seed itself is always first, so ties resolve to the dense optimum.
fn candidate_partitions(shape: MmShape, seed: Partition) -> Vec<Partition> {
    let mut out = vec![seed];
    let push = |p: Partition, out: &mut Vec<Partition>| {
        if !out.contains(&p) {
            out.push(p);
        }
    };
    for pn in [1usize, 2, 4, 8] {
        if pn == seed.pn {
            continue;
        }
        // preserve the tile budget: trade pm against the reduction plane
        let pm = (seed.pm * seed.pn / pn).max(1);
        let cn = seed.cn.min(div_ceil(shape.n, pn)).max(1);
        push(Partition { pm, pn, pk: seed.pk, cn }, &mut out);
    }
    for &cn in &consts::CN_CANDIDATES {
        let cn = cn.min(div_ceil(shape.n, seed.pn)).max(1);
        push(Partition { cn, ..seed }, &mut out);
    }
    out
}

/// Find the fastest plan for `shape` under `pattern` (full cost model).
/// `Err` is the **sparse** memory wall: with the CSR-aware bill a shape
/// past the dense §2.4 wall can still plan at low enough density, and
/// the verdict depends on the pattern. A fully dense pattern reproduces
/// the dense plan — and the dense OOM verdict — bit-for-bit.
pub fn sparse_search(
    arch: &IpuArch,
    shape: MmShape,
    pattern: &BlockPattern,
) -> Result<SparsePlan, PlannerError> {
    sparse_search_with_config(arch, shape, pattern, CostConfig::default())
}

/// [`sparse_search`] under an ablated cost model.
pub fn sparse_search_with_config(
    arch: &IpuArch,
    shape: MmShape,
    pattern: &BlockPattern,
    config: CostConfig,
) -> Result<SparsePlan, PlannerError> {
    match search_with_config(arch, shape, config) {
        Ok(dense_plan) => Ok(sparse_plan_from_dense(arch, shape, pattern, config, dense_plan)),
        Err(err) => {
            if pattern.nonzero_blocks() == pattern.total_blocks() {
                // fully dense IS the dense problem: reproduce the dense
                // OOM verdict (statistics included) bit-for-bit
                return Err(err);
            }
            sparse_search_past_dense_wall(arch, shape, pattern, config)
        }
    }
}

/// Price `pattern` against a *precomputed* dense plan for the same
/// `(arch, shape, config)`. The dense search is the expensive step and
/// depends only on the shape, so sweeps over many densities of one
/// shape should run it once and amortize it here (the plan cache plays
/// the same role for the serving layer). Infallible: a fitting dense
/// plan always passes the sparse admission (the CSR bill never exceeds
/// the dense bill), so it is always a valid sparse candidate.
pub fn sparse_plan_from_dense(
    arch: &IpuArch,
    shape: MmShape,
    pattern: &BlockPattern,
    config: CostConfig,
    dense_plan: Plan,
) -> SparsePlan {
    let model = CostModel::with_config(arch, config);
    let stats = pattern_stats(&model, shape, pattern);
    if pattern.nonzero_blocks() == pattern.total_blocks() {
        // fully dense pattern IS the dense problem: serve the dense
        // winner verbatim (every scale factor is 1.0, and the dense
        // search's optimum is authoritative)
        let part = dense_plan.partition();
        let (critical, mean) = pattern.cell_densities(part.pm, part.pn);
        let cost = sparse_cost_inner(&model, shape, part, critical, mean, &stats);
        return SparsePlan {
            shape,
            spec: pattern.spec,
            realized_density: 1.0,
            nnz_elems: stats.nnz_elems,
            dense_plan: Some(dense_plan),
            cost,
            candidates_evaluated: 1,
        };
    }
    let mut best: Option<SparseCost> = None;
    let mut evaluated = 0usize;
    for part in candidate_partitions(shape, dense_plan.partition()) {
        if !part.is_valid(shape, arch.tiles) {
            continue;
        }
        let (critical, mean) = pattern.cell_densities(part.pm, part.pn);
        // CSR-aware admission: the sparse bill, not the dense §2.4 wall
        if sparse_bill_bytes(&model, shape, part, critical, stats.csr_resident)
            > arch.tile_sram_bytes
        {
            continue;
        }
        evaluated += 1;
        let cost = sparse_cost_inner(&model, shape, part, critical, mean, &stats);
        debug_assert!(cost.fits);
        let better = match &best {
            None => true,
            Some(b) => cost.total_cycles < b.total_cycles,
        };
        if better {
            best = Some(cost);
        }
    }
    // the dense winner always passes both filters, so `best` is set
    let cost = best.expect("dense winner is a valid sparse candidate");
    SparsePlan {
        shape,
        spec: pattern.spec,
        realized_density: stats.realized,
        nnz_elems: stats.nnz_elems,
        dense_plan: Some(dense_plan),
        cost,
        candidates_evaluated: evaluated,
    }
}

/// Full-space sparse search for shapes past the *dense* §2.4 wall: the
/// dense planner found nothing, so there is no incumbent to refine from.
/// Every candidate the dense search would enumerate is admitted by the
/// CSR-aware bill instead and priced sparse. Runs on [`search_workers`]
/// threads through [`sparse_search_past_dense_wall_with_workers`].
///
/// Contract: the caller has already established that the dense search
/// fails for `(arch, shape, config)` — sweeps that amortize one dense
/// search per shape call this directly per density instead of paying a
/// redundant full dense OOM enumeration through [`sparse_search`].
pub fn sparse_search_past_dense_wall(
    arch: &IpuArch,
    shape: MmShape,
    pattern: &BlockPattern,
    config: CostConfig,
) -> Result<SparsePlan, PlannerError> {
    sparse_search_past_dense_wall_with_workers(arch, shape, pattern, config, search_workers())
}

/// [`sparse_search_past_dense_wall`] with an explicit worker count —
/// sharded over `pm` stripes exactly like the dense
/// `planner::search::search_with_workers`: stripes are dealt dynamically
/// to scoped workers, each keeps its local best, and the merge picks the
/// minimum by `(total_cycles, enumeration rank)`, so **any worker count
/// returns a bit-identical [`SparsePlan`]** (see
/// `parallel_past_wall_matches_serial`). The count is a request against
/// the process-wide
/// [`ThreadBudget`](crate::coordinator::runner::ThreadBudget); pass 1 to
/// pin the serial baseline. Candidates are priced by the staged
/// [`sparse_staged_total`] over the hoisted [`PatternContext`]; the full
/// [`SparseCost`] is materialized only for the merged winner. Unlike the
/// dense search there is no cross-stripe incumbent prune yet: the dense
/// `grid_lower_bound` is unsound once buckets scale with density, and a
/// certified sparse bound is an open ROADMAP follow-up.
pub fn sparse_search_past_dense_wall_with_workers(
    arch: &IpuArch,
    shape: MmShape,
    pattern: &BlockPattern,
    config: CostConfig,
    workers: usize,
) -> Result<SparsePlan, PlannerError> {
    let model = CostModel::with_config(arch, config);
    let ctx = PatternContext::new(&model, shape, pattern);
    let space = CandidateSpace::new(shape, arch.tiles);
    let n_pms = space.n_pms();
    let request = if n_pms < PARALLEL_MIN_PMS { 1 } else { workers.max(1).min(n_pms) };
    let lease = ThreadBudget::global().acquire(request);
    let workers = lease.workers();

    // (staged total, enumeration rank, partition, critical, mean)
    type StripeBest = Option<(u64, u64, Partition, f64, f64)>;
    let stripe = |pm_idx: usize,
                  best: &mut StripeBest,
                  stats: &mut StripeObs,
                  cells: &mut HashMap<(usize, usize), (f64, f64)>| {
        for_each_candidate_in_stripe(&space, arch.tiles, shape, pm_idx, |part, rank| {
            stats.enumerated += 1;
            let (critical, mean) = *cells
                .entry((part.pm, part.pn))
                .or_insert_with(|| ctx.index.cell_densities(part.pm, part.pn));
            if sparse_bill_bytes(&model, shape, part, critical, ctx.stats.csr_resident)
                > arch.tile_sram_bytes
            {
                return false;
            }
            stats.admitted += 1;
            let total = sparse_staged_total(&model, shape, part, critical, ctx.stats.realized);
            stats.staged_priced += 1;
            let replace = match best {
                None => true,
                Some((b_total, b_rank, ..)) => (total, rank) < (*b_total, *b_rank),
            };
            if replace {
                *best = Some((total, rank, part, critical, mean));
                stats.improvements += 1;
            }
            false
        });
    };

    let t_search = crate::obs::now();
    let (best, totals) = if workers <= 1 {
        let mut best: StripeBest = None;
        let mut totals = StripeObs::default();
        let mut cells = HashMap::new();
        for pm_idx in 0..n_pms {
            let t_stripe = crate::obs::now();
            let mut stats = StripeObs::default();
            stripe(pm_idx, &mut best, &mut stats, &mut cells);
            totals.add(&stats);
            if t_stripe.is_some() {
                crate::obs::wall_span_since(
                    t_stripe,
                    "sparse/w0",
                    &format!("stripe {pm_idx}"),
                    "sparse",
                    &stats.span_args(),
                );
            }
        }
        (best, totals)
    } else {
        let next_pm = AtomicUsize::new(0);
        let stripe_results: Vec<(StripeBest, StripeObs)> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..workers)
                .map(|w| {
                    let stripe = &stripe;
                    let next_pm = &next_pm;
                    scope.spawn(move || {
                        let mut best: StripeBest = None;
                        let mut totals = StripeObs::default();
                        // per-worker cell-density memo: stripes repeat
                        // (pm, pn) grids, the index makes misses O(pm*pn)
                        let mut cells = HashMap::new();
                        loop {
                            let pm_idx = next_pm.fetch_add(1, Ordering::Relaxed);
                            if pm_idx >= n_pms {
                                break;
                            }
                            let t_stripe = crate::obs::now();
                            let mut stats = StripeObs::default();
                            stripe(pm_idx, &mut best, &mut stats, &mut cells);
                            totals.add(&stats);
                            if t_stripe.is_some() {
                                crate::obs::wall_span_since(
                                    t_stripe,
                                    &format!("sparse/w{w}"),
                                    &format!("stripe {pm_idx}"),
                                    "sparse",
                                    &stats.span_args(),
                                );
                            }
                        }
                        (best, totals)
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("sparse planner worker panicked"))
                .collect()
        });
        let mut best: StripeBest = None;
        let mut totals = StripeObs::default();
        for (stripe_best, stripe_totals) in stripe_results {
            totals.add(&stripe_totals);
            if let Some((total, rank, part, critical, mean)) = stripe_best {
                let replace = match &best {
                    None => true,
                    Some((b_total, b_rank, ..)) => (total, rank) < (*b_total, *b_rank),
                };
                if replace {
                    best = Some((total, rank, part, critical, mean));
                }
            }
        }
        (best, totals)
    };

    let (valid, admitted) = (totals.enumerated as usize, totals.admitted as usize);
    if t_search.is_some() {
        totals.record_counters("sparse");
        crate::obs::wall_span_since(
            t_search,
            "planner",
            &format!("sparse_past_wall {}x{}x{}", shape.m, shape.n, shape.k),
            "sparse",
            &[("workers", workers.to_string()), ("admitted", admitted.to_string())],
        );
    }

    match best {
        Some((total, _, part, critical, mean)) => {
            // the only full SparseCost materialization of the search
            let cost = sparse_cost_inner(&model, shape, part, critical, mean, &ctx.stats);
            debug_assert_eq!(cost.total_cycles, total, "staged sparse total diverged");
            debug_assert!(cost.fits);
            Ok(SparsePlan {
                shape,
                spec: pattern.spec,
                realized_density: ctx.stats.realized,
                nnz_elems: ctx.stats.nnz_elems,
                dense_plan: None,
                cost,
                candidates_evaluated: admitted,
            })
        }
        None => Err(PlannerError::OutOfMemory { candidates_evaluated: valid }),
    }
}

/// Does *any* partition of `shape` fit under `spec`'s CSR-aware bill?
/// The sparse twin of [`crate::planner::search::search_fits`]: no cycle
/// model, early exit on the first admissible candidate, and agreement
/// with `sparse_search(..).is_ok()` by construction. Fully dense specs
/// defer to the dense probe (same verdict, no pattern scan).
pub fn sparse_search_fits(arch: &IpuArch, shape: MmShape, spec: SparsitySpec) -> bool {
    sparse_search_fits_with_config(arch, shape, spec, CostConfig::default())
}

/// Ablation variant of [`sparse_search_fits`].
pub fn sparse_search_fits_with_config(
    arch: &IpuArch,
    shape: MmShape,
    spec: SparsitySpec,
    config: CostConfig,
) -> bool {
    if spec.is_dense() {
        // §Perf: a fully dense spec defers to the dense probe without
        // materializing the pattern at all (the wall bisection probes
        // density 1.0 constantly; the verdict is provably identical —
        // every scale factor is 1.0 and the CSR bill caps at the dense
        // bill)
        return search_fits_with_config(arch, shape, config);
    }
    let pattern = BlockPattern::for_shape(spec, shape);
    sparse_search_fits_pattern(arch, shape, &pattern, config)
}

/// [`sparse_search_fits`] over an already-materialized pattern — callers
/// holding one (sweeps, the wall bisection's memoized probes) skip the
/// O(blocks) generation.
pub fn sparse_search_fits_pattern(
    arch: &IpuArch,
    shape: MmShape,
    pattern: &BlockPattern,
    config: CostConfig,
) -> bool {
    if pattern.nonzero_blocks() == pattern.total_blocks() {
        return search_fits_with_config(arch, shape, config);
    }
    let model = CostModel::with_config(arch, config);
    let ctx = PatternContext::new(&model, shape, pattern);
    sparse_fits_scan(&model, shape, &ctx)
}

/// The admission scan shared by the fits probes: first candidate whose
/// CSR-aware bill fits wins (early exit), over a hoisted
/// [`PatternContext`].
fn sparse_fits_scan(model: &CostModel, shape: MmShape, ctx: &PatternContext) -> bool {
    let mut cells: HashMap<(usize, usize), f64> = HashMap::new();
    let mut found = false;
    for_each_candidate(shape, model.arch.tiles, |part| {
        let critical = *cells
            .entry((part.pm, part.pn))
            .or_insert_with(|| ctx.index.cell_densities(part.pm, part.pn).0);
        if sparse_bill_bytes(model, shape, part, critical, ctx.stats.csr_resident)
            <= model.arch.tile_sram_bytes
        {
            found = true;
        }
        found
    });
    found
}

/// Largest fitting squared block-sparse MM under `spec` — the paper's
/// §2.4 memory-wall statistic per density. Bisects over the fits-only
/// probe [`sparse_search_fits`], like the dense
/// [`crate::planner::search::max_fitting_square`]; validated against
/// [`sparse_max_fitting_square_linear`]. Non-decreasing as density falls
/// (the CSR bill is monotone in the nonzero set for nested generators).
pub fn sparse_max_fitting_square(
    arch: &IpuArch,
    spec: SparsitySpec,
    step: usize,
    limit: usize,
) -> usize {
    sparse_max_fitting_square_with_config(arch, spec, step, limit, CostConfig::default())
}

/// Ablation variant of [`sparse_max_fitting_square`].
///
/// §Perf: every probe of the bisection materializes its pattern, CSR
/// residency, and cell index exactly once (through
/// [`sparse_search_fits_with_config`]'s hoisted [`PatternContext`]), and
/// a per-call verdict memo keeps repeated probes of the same size (the
/// bisection's endpoint re-checks, validation harnesses running bisect
/// and linear side by side) from rebuilding the pattern at all.
pub fn sparse_max_fitting_square_with_config(
    arch: &IpuArch,
    spec: SparsitySpec,
    step: usize,
    limit: usize,
    config: CostConfig,
) -> usize {
    let memo: RefCell<HashMap<usize, bool>> = RefCell::new(HashMap::new());
    bisect_max_fitting(step, limit, |s| {
        *memo
            .borrow_mut()
            .entry(s)
            .or_insert_with(|| sparse_search_fits_with_config(arch, MmShape::square(s), spec, config))
    })
}

/// Linear-scan reference for [`sparse_max_fitting_square`] (tests and
/// benches — mirrors `max_fitting_square_linear`'s contract).
pub fn sparse_max_fitting_square_linear(
    arch: &IpuArch,
    spec: SparsitySpec,
    step: usize,
    limit: usize,
) -> usize {
    sparse_max_fitting_square_linear_with_config(arch, spec, step, limit, CostConfig::default())
}

/// Ablation variant of [`sparse_max_fitting_square_linear`].
pub fn sparse_max_fitting_square_linear_with_config(
    arch: &IpuArch,
    spec: SparsitySpec,
    step: usize,
    limit: usize,
    config: CostConfig,
) -> usize {
    let mut best = 0;
    let mut s = step;
    while s <= limit {
        if sparse_search_fits_with_config(arch, MmShape::square(s), spec, config) {
            best = s;
        } else if best > 0 {
            break; // monotone past the wall
        }
        s += step;
    }
    best
}

/// Plan from a spec alone (materializes the pattern) — the serving
/// layer's entry point: the cache key is `(shape, arch, spec)`.
pub fn sparse_search_spec(
    arch: &IpuArch,
    shape: MmShape,
    spec: SparsitySpec,
) -> Result<SparsePlan, PlannerError> {
    let pattern = BlockPattern::for_shape(spec, shape);
    sparse_search(arch, shape, &pattern)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::planner::search::{max_fitting_square, search};
    use crate::sparse::pattern::PatternKind;

    fn arch() -> IpuArch {
        IpuArch::gc200()
    }

    fn plan_at(shape: MmShape, kind: PatternKind, density: f64) -> SparsePlan {
        let spec = SparsitySpec::new(kind, 8, density, 42);
        sparse_search_spec(&arch(), shape, spec).unwrap()
    }

    #[test]
    fn density_one_reproduces_dense_plan_exactly() {
        let shape = MmShape::square(1536);
        let dense = search(&arch(), shape).unwrap();
        for kind in PatternKind::all() {
            let sparse = plan_at(shape, kind, 1.0);
            assert_eq!(sparse.partition(), dense.partition(), "{kind:?}");
            assert_eq!(
                sparse.cost.total_cycles, dense.cost.total_cycles,
                "{kind:?}: sparse {} vs dense {}",
                sparse.cost.total_cycles, dense.cost.total_cycles
            );
            assert!((sparse.speedup_vs_dense().unwrap() - 1.0).abs() < 1e-12);
            assert_eq!(sparse.effective_flops(), shape.flops());
        }
    }

    #[test]
    fn density_one_buckets_and_bill_exact() {
        // the per-bucket exchange split and the CSR-aware bill must both
        // collapse to the dense numbers at density 1.0 (satellite
        // regression: the old whole-bucket scaling was exact here too,
        // and the component substitution must not break it)
        let a = arch();
        let model = CostModel::new(&a);
        for shape in [MmShape::square(1536), MmShape::new(512, 16384, 2048)] {
            let dense = search(&a, shape).unwrap();
            let part = dense.partition();
            let pattern =
                BlockPattern::for_shape(SparsitySpec::new(PatternKind::Random, 8, 1.0, 7), shape);
            let sc = sparse_cost(&model, shape, part, &pattern);
            assert_eq!(sc.compute_cycles, dense.cost.compute_cycles);
            assert_eq!(sc.exchange_cycles, dense.cost.exchange_cycles);
            assert_eq!(sc.sync_cycles, dense.cost.sync_cycles);
            assert_eq!(sc.useful_cycles, dense.cost.useful_cycles);
            assert_eq!(sc.sparse_tile_bytes, model.tile_bytes(shape, part));
            assert_eq!(sc.sparse_tile_bytes, dense.cost.tile_bytes_total);
        }
    }

    #[test]
    fn sparser_is_never_slower() {
        let shape = MmShape::square(2048);
        let mut prev: Option<u64> = None;
        for permille in [100u32, 250, 500, 750, 1000] {
            let p = plan_at(shape, PatternKind::Random, permille as f64 / 1000.0);
            if let Some(prev) = prev {
                assert!(
                    prev <= p.cost.total_cycles,
                    "cost fell from {} to {} as density rose to {permille}",
                    prev,
                    p.cost.total_cycles
                );
            }
            assert!(p.speedup_vs_dense().unwrap() >= 1.0 - 1e-12);
            prev = Some(p.cost.total_cycles);
        }
    }

    #[test]
    fn effective_tflops_below_dense_equiv() {
        let a = arch();
        let p = plan_at(MmShape::square(2048), PatternKind::Random, 0.25);
        let eff = p.effective_tflops(&a);
        let deq = p.dense_equiv_tflops(&a);
        assert!(eff > 0.0 && eff < deq, "effective {eff} vs dense-equiv {deq}");
        // a quarter of the blocks -> a quarter of the effective flops
        let ratio = p.effective_flops() as f64 / p.shape.flops() as f64;
        assert!((ratio - 0.25).abs() < 0.01, "nnz ratio {ratio}");
    }

    #[test]
    fn far_past_wall_still_ooms_sparse() {
        // far past the wall even for the CSR bill: at 6144^2 the *dense*
        // components alone (B home + B chunks + C block + exchange code)
        // overflow the tile for every candidate, so even a 10%-dense
        // pattern must OOM — the sparse wall is density-dependent, not
        // gone
        let spec = SparsitySpec::new(PatternKind::Random, 8, 0.1, 1);
        let err = sparse_search_spec(&arch(), MmShape::square(6144), spec).unwrap_err();
        assert!(matches!(err, PlannerError::OutOfMemory { .. }));
        assert!(!sparse_search_fits(&arch(), MmShape::square(6144), spec));
    }

    #[test]
    fn past_dense_wall_low_density_plans() {
        // the tentpole acceptance: 4096^2 OOMs dense (§2.4) but plans at
        // 25% density under the CSR-aware bill; at density 1.0 the dense
        // OOM verdict is reproduced bit-for-bit
        let a = arch();
        let shape = MmShape::square(4096);
        let dense_err = search(&a, shape).unwrap_err();
        let spec = SparsitySpec::new(PatternKind::Random, 8, 0.25, 42);
        let plan = sparse_search_spec(&a, shape, spec).unwrap();
        assert!(plan.cost.fits);
        assert!(plan.cost.sparse_tile_bytes <= a.tile_sram_bytes);
        assert!(plan.dense_plan.is_none(), "no dense baseline past the wall");
        assert!(plan.speedup_vs_dense().is_none());
        assert!(plan.partition().is_valid(shape, a.tiles));
        assert!(plan.cost.total_cycles > 0 && plan.candidates_evaluated > 0);
        assert!(sparse_search_fits(&a, shape, spec));
        // density 1.0: identical OOM verdict, fits probe agrees
        let dense_spec = SparsitySpec::new(PatternKind::Random, 8, 1.0, 42);
        let sparse_err = sparse_search_spec(&a, shape, dense_spec).unwrap_err();
        assert_eq!(sparse_err, dense_err);
        assert!(!sparse_search_fits(&a, shape, dense_spec));
    }

    #[test]
    fn sparse_residency_knob_restores_dense_wall() {
        // ablation: with the CSR residency off, admission falls back to
        // the dense bill and the 4096^2 shape OOMs at every density
        let a = arch();
        let shape = MmShape::square(4096);
        let spec = SparsitySpec::new(PatternKind::Random, 8, 0.25, 42);
        let config = CostConfig { sparse_residency: false, ..CostConfig::default() };
        let pattern = BlockPattern::for_shape(spec, shape);
        assert!(sparse_search_with_config(&a, shape, &pattern, config).is_err());
        assert!(!sparse_search_fits_with_config(&a, shape, spec, config));
        // and the bill itself degenerates to the dense one
        let model = CostModel::with_config(&a, config);
        let part = Partition { pm: 40, pn: 1, pk: 36, cn: 128 };
        assert_eq!(
            sparse_tile_bytes(&model, shape, part, &pattern),
            model.tile_bytes(shape, part)
        );
    }

    #[test]
    fn sparse_bill_never_exceeds_dense_bill() {
        // the dense-layout fallback cap: admission is monotone because
        // the sparse bill is bounded by the dense bill at every density
        let a = arch();
        let model = CostModel::new(&a);
        for shape in [MmShape::square(2048), MmShape::new(512, 8192, 1024)] {
            for density in [0.1, 0.5, 0.999, 1.0] {
                let pattern = BlockPattern::for_shape(
                    SparsitySpec::new(PatternKind::Random, 8, density, 3),
                    shape,
                );
                for part in [
                    Partition { pm: 40, pn: 1, pk: 36, cn: 128 },
                    Partition { pm: 8, pn: 4, pk: 44, cn: 256 },
                ] {
                    if !part.is_valid(shape, a.tiles) {
                        continue;
                    }
                    assert!(
                        sparse_tile_bytes(&model, shape, part, &pattern)
                            <= model.tile_bytes(shape, part),
                        "sparse bill above dense at d={density} for {shape:?} {part:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn reduction_exchange_no_longer_underscaled() {
        // satellite regression: the seed scaled the whole exchange bucket
        // (prologue and reduction landing included) by the A-chunk
        // factor; the per-bucket split keeps reduction traffic dense, so
        // a reduction-heavy plan must price at or above the old formula
        let a = arch();
        let model = CostModel::new(&a);
        let shape = MmShape::new(512, 16384, 2048);
        let dense = search(&a, shape).unwrap();
        let part = dense.partition();
        assert!(part.pn > 1, "need a reduction-heavy plan: {part:?}");
        let pattern =
            BlockPattern::for_shape(SparsitySpec::new(PatternKind::Random, 8, 0.25, 42), shape);
        let sc = sparse_cost(&model, shape, part, &pattern);
        let (sm, _, sk) = part.sub_block(shape);
        let a_frac = sm as f64 / (sm + sk) as f64;
        let old_factor = a_frac * sc.critical_density + (1.0 - a_frac);
        let old = (sc.dense.exchange_cycles as f64 * old_factor).ceil() as u64;
        assert!(
            sc.exchange_cycles >= old,
            "per-bucket exchange {} under-prices the old formula {}",
            sc.exchange_cycles,
            old
        );
        // the reduction share specifically survives unscaled
        assert!(sc.exchange_cycles >= sc.dense.exchange_reduction_cycles);
        assert!(sc.dense.exchange_reduction_cycles > 0);
    }

    #[test]
    fn efficiency_unclamped_on_imbalanced_banded() {
        // satellite regression: the old metric multiplied dense useful
        // cycles by *realized* density while compute is priced by
        // *critical* density — under banded imbalance that overstates,
        // hidden only by the clamp. The nnz-based metric stays <= 1
        // without any clamp (block-aligned shape: no edge padding).
        for shape in [MmShape::square(2048), MmShape::new(512, 8192, 2048)] {
            for density in [0.1, 0.2, 0.4] {
                let p = plan_at(shape, PatternKind::Banded, density);
                let raw = p.cost.useful_cycles as f64 / p.cost.total_cycles as f64;
                assert!(raw > 0.0 && raw <= 1.0, "raw efficiency {raw} for {shape:?} d{density}");
                assert_eq!(p.efficiency(), raw, "efficiency must be the unclamped ratio");
                assert!(
                    p.cost.critical_density > p.cost.mean_density,
                    "banded pattern should be imbalanced ({} vs {})",
                    p.cost.critical_density,
                    p.cost.mean_density
                );
            }
        }
    }

    #[test]
    fn skewed_sparse_plans_still_fit_and_win() {
        // the headline question: does the skew advantage survive sparsity?
        let a = arch();
        let right = MmShape::new(512, 8192, 2048);
        let p = plan_at(right, PatternKind::Random, 0.5);
        assert!(p.cost.fits);
        assert!(
            p.speedup_vs_dense().unwrap() > 1.0,
            "sparsity should pay: {:?}",
            p.speedup_vs_dense()
        );
        assert!(p.effective_tflops(&a) > 0.0);
    }

    #[test]
    fn banded_right_skew_can_resplit_reduction() {
        // candidates include re-balanced pn variants; whatever wins must
        // beat or match the dense winner priced sparse
        let shape = MmShape::new(512, 16384, 2048);
        let p = plan_at(shape, PatternKind::Banded, 0.2);
        let a = arch();
        let model = CostModel::new(&a);
        let pattern = BlockPattern::for_shape(p.spec, shape);
        let dense_part = p.dense_plan.as_ref().unwrap().partition();
        let seeded = sparse_cost(&model, shape, dense_part, &pattern);
        assert!(p.cost.total_cycles <= seeded.total_cycles);
        assert!(p.candidates_evaluated >= 2);
    }

    #[test]
    fn critical_density_bounds_mean() {
        let p = plan_at(MmShape::square(1024), PatternKind::Banded, 0.3);
        assert!(p.cost.critical_density >= p.cost.mean_density);
        assert!(p.cost.critical_density <= 1.0);
        assert!(p.efficiency() > 0.0 && p.efficiency() <= 1.0);
    }

    #[test]
    fn sync_cycles_do_not_scale() {
        let dense = plan_at(MmShape::square(1024), PatternKind::Random, 1.0);
        let sparse = plan_at(MmShape::square(1024), PatternKind::Random, 0.2);
        if sparse.partition() == dense.partition() {
            assert_eq!(sparse.cost.sync_cycles, dense.cost.sync_cycles);
        }
        assert!(sparse.cost.compute_cycles < dense.cost.compute_cycles);
    }

    #[test]
    fn parallel_past_wall_matches_serial() {
        // the tentpole acceptance: the sharded past-the-wall search
        // returns a bit-identical SparsePlan for any worker count
        let a = arch();
        for (shape, density) in [
            (MmShape::square(4096), 0.25),
            (MmShape::new(2048, 8192, 4096), 0.2),
        ] {
            if shape.m == shape.n {
                // the square case is the acceptance shape — pin that it
                // really is past the dense wall (the skewed case tests
                // determinism regardless of its wall status)
                assert!(search(&a, shape).is_err(), "{shape:?} must be past the dense wall");
            }
            let spec = SparsitySpec::new(PatternKind::Random, 8, density, 42);
            let pattern = BlockPattern::for_shape(spec, shape);
            let serial = sparse_search_past_dense_wall_with_workers(
                &a,
                shape,
                &pattern,
                CostConfig::default(),
                1,
            );
            if shape.m == shape.n {
                assert!(serial.is_ok(), "4096^2 at 25% density must plan sparse");
            }
            for workers in [2, 4, 7] {
                let par = sparse_search_past_dense_wall_with_workers(
                    &a,
                    shape,
                    &pattern,
                    CostConfig::default(),
                    workers,
                );
                match (&serial, &par) {
                    (Ok(s), Ok(p)) => {
                        assert_eq!(p.partition(), s.partition(), "{shape:?} w={workers}");
                        assert_eq!(p.cost.total_cycles, s.cost.total_cycles);
                        assert_eq!(p.cost.sparse_tile_bytes, s.cost.sparse_tile_bytes);
                        assert_eq!(p.candidates_evaluated, s.candidates_evaluated);
                        assert_eq!(p.nnz_elems, s.nnz_elems);
                    }
                    (Err(se), Err(pe)) => assert_eq!(se, pe, "{shape:?} w={workers}"),
                    _ => panic!("verdicts diverge for {shape:?} with {workers} workers"),
                }
            }
        }
    }

    #[test]
    fn staged_past_wall_matches_reference_full_pricing() {
        // the staged (cycles-only) past-the-wall winner must equal a
        // reference scan that fully prices every admitted candidate
        let a = arch();
        let shape = MmShape::square(4096);
        // Random/seed-42 nests under the proven-planning 0.25 pattern
        // (same generator prefix), so admission is guaranteed non-empty
        let spec = SparsitySpec::new(PatternKind::Random, 8, 0.2, 42);
        let pattern = BlockPattern::for_shape(spec, shape);
        let model = CostModel::new(&a);
        let stats_ctx = PatternContext::new(&model, shape, &pattern);
        let mut best: Option<SparseCost> = None;
        let mut cells: HashMap<(usize, usize), (f64, f64)> = HashMap::new();
        for_each_candidate(shape, a.tiles, |part| {
            let (critical, mean) = *cells
                .entry((part.pm, part.pn))
                .or_insert_with(|| stats_ctx.index.cell_densities(part.pm, part.pn));
            if sparse_bill_bytes(&model, shape, part, critical, stats_ctx.stats.csr_resident)
                <= a.tile_sram_bytes
            {
                let cost =
                    sparse_cost_inner(&model, shape, part, critical, mean, &stats_ctx.stats);
                let better = match &best {
                    None => true,
                    Some(b) => cost.total_cycles < b.total_cycles,
                };
                if better {
                    best = Some(cost);
                }
            }
            false
        });
        let reference = best.expect("reference scan must admit a plan");
        let staged =
            sparse_search_past_dense_wall(&a, shape, &pattern, CostConfig::default()).unwrap();
        assert_eq!(staged.partition(), reference.dense.partition);
        assert_eq!(staged.cost.total_cycles, reference.total_cycles);
        assert_eq!(staged.cost.compute_cycles, reference.compute_cycles);
        assert_eq!(staged.cost.exchange_cycles, reference.exchange_cycles);
        assert_eq!(staged.cost.sparse_tile_bytes, reference.sparse_tile_bytes);
    }

    #[test]
    fn fits_pattern_variant_agrees_with_spec_probe() {
        let a = arch();
        for (shape, density) in [
            (MmShape::square(4096), 0.25),
            (MmShape::square(6144), 0.1),
            (MmShape::square(1024), 0.5),
        ] {
            let spec = SparsitySpec::new(PatternKind::Random, 8, density, 3);
            let pattern = BlockPattern::for_shape(spec, shape);
            assert_eq!(
                sparse_search_fits_pattern(&a, shape, &pattern, CostConfig::default()),
                sparse_search_fits(&a, shape, spec),
                "{shape:?} d={density}"
            );
        }
    }

    #[test]
    fn wall_bisection_matches_linear_and_tracks_density() {
        let a = arch();
        // density 1.0 defers to the dense probe: the paper's 3584 wall
        let dense_spec = SparsitySpec::new(PatternKind::Random, 8, 1.0, 42);
        assert_eq!(
            sparse_max_fitting_square(&a, dense_spec, 128, 8192),
            max_fitting_square(&a, 128, 8192)
        );
        assert_eq!(sparse_max_fitting_square(&a, dense_spec, 128, 8192), 3584);
        // low density pushes the wall past 3584 (the acceptance shape
        // 4096^2 fits at 25%), and bisection equals the linear scan
        let quarter = SparsitySpec::new(PatternKind::Random, 8, 0.25, 42);
        let wall = sparse_max_fitting_square(&a, quarter, 128, 6144);
        assert!(wall >= 4096, "25%-density wall {wall} should clear 4096");
        assert_eq!(
            sparse_max_fitting_square(&a, quarter, 512, 5120),
            sparse_max_fitting_square_linear(&a, quarter, 512, 5120)
        );
        // the wall never shrinks as density falls
        let mut prev = 0usize;
        for density in [1.0, 0.5, 0.25, 0.1] {
            let spec = SparsitySpec::new(PatternKind::Random, 8, density, 42);
            let w = sparse_max_fitting_square(&a, spec, 256, 6144);
            assert!(
                w >= prev || prev == 0,
                "wall shrank from {prev} to {w} as density fell to {density}"
            );
            prev = w;
        }
    }
}
