//! Block-CSR layout and per-tile nonzero-block assignment.
//!
//! The PopSparse on-device format: block coordinates in CSR
//! (`row_ptr`/`col_idx` over the block grid) with dense `block x block`
//! value tiles. Assignment of nonzero blocks to tiles reuses
//! [`crate::memory::mapping::linear_balanced_mapping`] — the same
//! contiguous-balanced policy Poplar's `mapTensorLinearly` applies to
//! dense tensors — so per-tile work stays within one block of the mean
//! and the planner's load-balance assumption holds.

use crate::graph::tensor::{Interval, TileMapping};
use crate::memory::mapping::linear_balanced_mapping;
use crate::sparse::pattern::BlockPattern;

/// Block-compressed-sparse-row index of a pattern.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BlockCsr {
    /// Block edge (values are `block x block` dense tiles).
    pub block: usize,
    pub block_rows: usize,
    pub block_cols: usize,
    /// `block_rows + 1` offsets into `col_idx`.
    pub row_ptr: Vec<u32>,
    /// Block-column index of each nonzero block, row-major.
    pub col_idx: Vec<u32>,
}

impl BlockCsr {
    pub fn from_pattern(p: &BlockPattern) -> BlockCsr {
        let mut row_ptr = Vec::with_capacity(p.block_rows + 1);
        let mut col_idx = Vec::new();
        row_ptr.push(0u32);
        for bi in 0..p.block_rows {
            for bj in 0..p.block_cols {
                if p.is_nonzero(bi, bj) {
                    col_idx.push(bj as u32);
                }
            }
            row_ptr.push(col_idx.len() as u32);
        }
        BlockCsr {
            block: p.spec.block,
            block_rows: p.block_rows,
            block_cols: p.block_cols,
            row_ptr,
            col_idx,
        }
    }

    pub fn nnz_blocks(&self) -> usize {
        self.col_idx.len()
    }

    /// Nonzero blocks in one block-row.
    pub fn row_nnz(&self, bi: usize) -> usize {
        (self.row_ptr[bi + 1] - self.row_ptr[bi]) as usize
    }

    /// Bytes of the dense value tiles at `elem_bytes` per element.
    pub fn values_bytes(&self, elem_bytes: u64) -> u64 {
        self.nnz_blocks() as u64 * (self.block * self.block) as u64 * elem_bytes
    }

    /// Bytes of the CSR index structure (u32 offsets + columns).
    pub fn index_bytes(&self) -> u64 {
        4 * (self.row_ptr.len() + self.col_idx.len()) as u64
    }

    /// Spread the nonzero blocks over `tiles` tiles in contiguous,
    /// balanced runs (CSR order), via the dense mapping balancer.
    pub fn assign_tiles(&self, tiles: usize) -> TileAssignment {
        let mapping = linear_balanced_mapping(self.nnz_blocks(), tiles);
        let per_tile_blocks: Vec<usize> = mapping
            .iter()
            .map(|ivs| ivs.iter().map(|iv| iv.len()).sum())
            .collect();
        TileAssignment::new(per_tile_blocks)
    }

    /// Element-level tile mapping of the dense value tiles: the block
    /// assignment of [`Self::assign_tiles`], scaled to `block x block`
    /// elements per block. This is the mapping `sim::build_sparse_graph`
    /// gives the `A_bsr` tensor, so the accountant's per-tile tensor
    /// bytes equal [`Self::residency_per_tile`]'s value component.
    pub fn value_elem_mapping(&self, tiles: usize) -> TileMapping {
        let bsq = self.block * self.block;
        self.block_mapping(tiles)
            .iter()
            .map(|ivs| {
                ivs.iter()
                    .map(|iv| Interval::new(iv.begin * bsq, iv.end * bsq))
                    .collect()
            })
            .collect()
    }

    /// Block-granular balanced assignment (one entry per nonzero block) —
    /// also the mapping of the `col_idx` metadata (one u32 per block).
    pub fn block_mapping(&self, tiles: usize) -> TileMapping {
        linear_balanced_mapping(self.nnz_blocks(), tiles)
    }

    /// Per-tile resident bytes of the block-CSR `A` operand: dense value
    /// tiles (balanced per [`Self::assign_tiles`]) plus the u32 index
    /// metadata each tile holds (`col_idx` travels with its blocks,
    /// `row_ptr` is spread linearly). This is the planner's sparse A home
    /// share *and*, by construction, exactly what the memory accountant
    /// charges for the CSR tensors of the built sparse graph — the
    /// equality the sparse memory model is pinned by.
    pub fn residency_per_tile(&self, tiles: usize, elem_bytes: u64) -> Vec<u64> {
        let value_and_col = (self.block * self.block) as u64 * elem_bytes + 4;
        let blocks = self.block_mapping(tiles);
        let rowptr = linear_balanced_mapping(self.row_ptr.len(), tiles);
        (0..tiles)
            .map(|t| {
                let nb: usize = blocks[t].iter().map(|iv| iv.len()).sum();
                let rp: usize = rowptr[t].iter().map(|iv| iv.len()).sum();
                nb as u64 * value_and_col + rp as u64 * 4
            })
            .collect()
    }

    /// Heaviest tile of [`Self::residency_per_tile`] — the sparse
    /// planner's A home-share bill.
    pub fn max_tile_residency(&self, tiles: usize, elem_bytes: u64) -> u64 {
        self.residency_per_tile(tiles, elem_bytes)
            .into_iter()
            .max()
            .unwrap_or(0)
    }
}

/// How many nonzero blocks each tile owns.
#[derive(Clone, Debug)]
pub struct TileAssignment {
    pub per_tile_blocks: Vec<usize>,
    pub max_blocks: usize,
    pub active_tiles: usize,
}

impl TileAssignment {
    pub fn new(per_tile_blocks: Vec<usize>) -> TileAssignment {
        let max_blocks = per_tile_blocks.iter().copied().max().unwrap_or(0);
        let active_tiles = per_tile_blocks.iter().filter(|&&b| b > 0).count();
        TileAssignment { per_tile_blocks, max_blocks, active_tiles }
    }

    pub fn total_blocks(&self) -> usize {
        self.per_tile_blocks.iter().sum()
    }

    pub fn mean_blocks(&self) -> f64 {
        if self.active_tiles == 0 {
            0.0
        } else {
            self.total_blocks() as f64 / self.active_tiles as f64
        }
    }

    /// Load balance of the assignment: mean / max over active tiles
    /// (1.0 = perfectly even, the quantity BSP lockstep cares about).
    pub fn balance(&self) -> f64 {
        if self.max_blocks == 0 {
            0.0
        } else {
            self.mean_blocks() / self.max_blocks as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::pattern::{PatternKind, SparsitySpec};

    fn pattern(density: f64) -> BlockPattern {
        BlockPattern::generate(
            SparsitySpec::new(PatternKind::Random, 8, density, 7),
            512,
            1024,
        )
    }

    #[test]
    fn csr_roundtrips_the_pattern() {
        let p = pattern(0.3);
        let csr = BlockCsr::from_pattern(&p);
        assert_eq!(csr.nnz_blocks(), p.nonzero_blocks());
        assert_eq!(csr.row_ptr.len(), p.block_rows + 1);
        // every (row, col) listed in the CSR is nonzero in the pattern
        for bi in 0..csr.block_rows {
            let lo = csr.row_ptr[bi] as usize;
            let hi = csr.row_ptr[bi + 1] as usize;
            for &bj in &csr.col_idx[lo..hi] {
                assert!(p.is_nonzero(bi, bj as usize));
            }
            assert_eq!(csr.row_nnz(bi), hi - lo);
        }
    }

    #[test]
    fn dense_pattern_fills_every_row() {
        let p = pattern(1.0);
        let csr = BlockCsr::from_pattern(&p);
        assert_eq!(csr.nnz_blocks(), p.total_blocks());
        for bi in 0..csr.block_rows {
            assert_eq!(csr.row_nnz(bi), csr.block_cols);
        }
    }

    #[test]
    fn byte_accounting() {
        let csr = BlockCsr::from_pattern(&pattern(0.5));
        assert_eq!(
            csr.values_bytes(4),
            csr.nnz_blocks() as u64 * 64 * 4
        );
        assert_eq!(
            csr.index_bytes(),
            4 * (csr.row_ptr.len() + csr.col_idx.len()) as u64
        );
    }

    #[test]
    fn tile_assignment_is_balanced() {
        let csr = BlockCsr::from_pattern(&pattern(0.5));
        let asn = csr.assign_tiles(1472);
        assert_eq!(asn.total_blocks(), csr.nnz_blocks());
        assert_eq!(asn.per_tile_blocks.len(), 1472);
        // linear balancing: max and min (over active tiles) differ by <= 1
        let min_active = asn
            .per_tile_blocks
            .iter()
            .copied()
            .filter(|&b| b > 0)
            .min()
            .unwrap();
        assert!(asn.max_blocks - min_active <= 1, "{} vs {min_active}", asn.max_blocks);
        assert!(asn.balance() > 0.9, "balance {}", asn.balance());
    }

    #[test]
    fn residency_sums_to_values_plus_index() {
        // per-tile residency is a partition of the whole CSR footprint,
        // and its heaviest tile tracks the balanced block assignment
        let csr = BlockCsr::from_pattern(&pattern(0.37));
        for tiles in [1usize, 7, 1472] {
            let per_tile = csr.residency_per_tile(tiles, 4);
            assert_eq!(per_tile.len(), tiles);
            assert_eq!(
                per_tile.iter().sum::<u64>(),
                csr.values_bytes(4) + csr.index_bytes()
            );
            assert_eq!(
                csr.max_tile_residency(tiles, 4),
                per_tile.iter().copied().max().unwrap()
            );
        }
        // one tile holds everything
        assert_eq!(
            csr.max_tile_residency(1, 4),
            csr.values_bytes(4) + csr.index_bytes()
        );
    }

    #[test]
    fn value_elem_mapping_scales_block_assignment() {
        let csr = BlockCsr::from_pattern(&pattern(0.5));
        let tiles = 1472;
        let elems = csr.value_elem_mapping(tiles);
        let blocks = csr.block_mapping(tiles);
        let bsq = csr.block * csr.block;
        let mut covered = 0usize;
        for (ev, bv) in elems.iter().zip(&blocks) {
            let e: usize = ev.iter().map(|iv| iv.len()).sum();
            let b: usize = bv.iter().map(|iv| iv.len()).sum();
            assert_eq!(e, b * bsq);
            covered += e;
        }
        assert_eq!(covered, csr.nnz_blocks() * bsq);
    }

    #[test]
    fn more_tiles_than_blocks() {
        let p = BlockPattern::generate(
            SparsitySpec::new(PatternKind::Random, 16, 0.1, 1),
            64,
            64,
        );
        let csr = BlockCsr::from_pattern(&p);
        let asn = csr.assign_tiles(1472);
        assert_eq!(asn.active_tiles, csr.nnz_blocks());
        assert_eq!(asn.max_blocks, 1);
    }
}
