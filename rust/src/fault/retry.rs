//! Retry budgets, capped exponential backoff with deterministic jitter,
//! and the per-request deadline knob.
//!
//! All time here is **model time** (the same clock `RunOutcome::seconds`
//! reports): wasted attempts, backoff and the deadline ledger are summed
//! in seconds the cost model predicts, never wall-clock — so the retry
//! layer stays bit-deterministic across machines and worker counts.

use crate::fault::breaker::BreakerConfig;

/// Capped exponential backoff with seeded jitter.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RetryPolicy {
    /// Retries after the first attempt (0 = try once).
    pub max_retries: u32,
    /// Backoff before retry 1 (doubles per retry).
    pub base_backoff_s: f64,
    /// Backoff cap.
    pub max_backoff_s: f64,
    /// Jitter amplitude as permille of the backoff: the drawn backoff is
    /// `b * (1 + jitter * u)` for a seeded `u` in [-1, 1).
    pub jitter_permille: u32,
}

impl RetryPolicy {
    /// Never retry.
    pub fn none() -> RetryPolicy {
        RetryPolicy {
            max_retries: 0,
            base_backoff_s: 0.0,
            max_backoff_s: 0.0,
            jitter_permille: 0,
        }
    }

    /// The serving default: `max_retries` retries, 0.2ms base backoff
    /// doubling to a 2ms cap, 25% jitter. Scaled to the model clock,
    /// where device times are 1us..10ms.
    pub fn standard(max_retries: u32) -> RetryPolicy {
        RetryPolicy {
            max_retries,
            base_backoff_s: 2e-4,
            max_backoff_s: 2e-3,
            jitter_permille: 250,
        }
    }

    /// Backoff charged before retry `attempt` (0-based: the backoff
    /// between attempt `attempt` and `attempt + 1`). Deterministic in
    /// `(seed, id, attempt)` — the jitter is hashed, not sampled.
    pub fn backoff_seconds(&self, seed: u64, id: u64, attempt: u32) -> f64 {
        if self.base_backoff_s <= 0.0 {
            return 0.0;
        }
        let exp = self.base_backoff_s * 2f64.powi(attempt.min(30) as i32);
        let capped = exp.min(self.max_backoff_s);
        if self.jitter_permille == 0 {
            return capped;
        }
        // u in [-1, 1) from a splitmix64-style finalizer over the jitter
        // coordinates; same chain as FaultPlan so runs replay exactly
        let mut z = (seed ^ 0x0FF5E7)
            .wrapping_add(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(id.wrapping_mul(0xD129_0215_04A3_59DB))
            .wrapping_add(attempt as u64);
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^= z >> 31;
        let u = (z >> 11) as f64 / (1u64 << 53) as f64 * 2.0 - 1.0;
        let jitter = self.jitter_permille as f64 / 1000.0;
        capped * (1.0 + jitter * u)
    }
}

/// The whole per-request fault policy: deadline + retry + breaker.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultPolicy {
    /// Model-time latency budget per request (waste + backoff + device
    /// seconds). `None` = no deadline, nothing is shed for lateness.
    pub deadline_s: Option<f64>,
    pub retry: RetryPolicy,
    pub breaker: BreakerConfig,
}

impl FaultPolicy {
    /// The do-nothing policy: no deadline, no retries, breaker disabled.
    /// With a passthrough policy *and* `FaultPlan::none()` the service
    /// takes the legacy dispatch path verbatim.
    pub fn passthrough() -> FaultPolicy {
        FaultPolicy {
            deadline_s: None,
            retry: RetryPolicy::none(),
            breaker: BreakerConfig::disabled(),
        }
    }

    /// The serving default: no deadline unless set, 3 retries, standard
    /// breaker.
    pub fn standard() -> FaultPolicy {
        FaultPolicy {
            deadline_s: None,
            retry: RetryPolicy::standard(3),
            breaker: BreakerConfig::standard(),
        }
    }

    pub fn with_deadline(mut self, deadline_s: f64) -> FaultPolicy {
        self.deadline_s = Some(deadline_s);
        self
    }

    pub fn is_passthrough(&self) -> bool {
        self == &FaultPolicy::passthrough()
    }

    /// True when `elapsed` model seconds blow the deadline.
    pub fn past_deadline(&self, elapsed: f64) -> bool {
        matches!(self.deadline_s, Some(d) if elapsed > d)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_doubles_and_caps() {
        let p = RetryPolicy {
            max_retries: 8,
            base_backoff_s: 1e-4,
            max_backoff_s: 8e-4,
            jitter_permille: 0,
        };
        assert_eq!(p.backoff_seconds(0, 0, 0), 1e-4);
        assert_eq!(p.backoff_seconds(0, 0, 1), 2e-4);
        assert_eq!(p.backoff_seconds(0, 0, 2), 4e-4);
        assert_eq!(p.backoff_seconds(0, 0, 3), 8e-4);
        assert_eq!(p.backoff_seconds(0, 0, 7), 8e-4, "capped");
    }

    #[test]
    fn jitter_is_deterministic_bounded_and_id_dependent() {
        let p = RetryPolicy::standard(3);
        let b0 = p.backoff_seconds(42, 7, 0);
        assert_eq!(b0, p.backoff_seconds(42, 7, 0), "replays bit-identically");
        // +-25% around the 2e-4 base
        assert!(b0 >= 2e-4 * 0.75 && b0 < 2e-4 * 1.25, "b0 {b0}");
        let different = (0..50u64).any(|id| p.backoff_seconds(42, id, 0) != b0);
        assert!(different, "jitter must decorrelate ids");
    }

    #[test]
    fn none_policy_backs_off_zero() {
        assert_eq!(RetryPolicy::none().backoff_seconds(1, 2, 3), 0.0);
    }

    #[test]
    fn passthrough_detection_and_deadline() {
        assert!(FaultPolicy::passthrough().is_passthrough());
        assert!(!FaultPolicy::standard().is_passthrough());
        let p = FaultPolicy::passthrough().with_deadline(1e-3);
        assert!(!p.is_passthrough(), "a deadline is an active policy");
        assert!(!p.past_deadline(1e-3), "budget is inclusive");
        assert!(p.past_deadline(1.001e-3));
        assert!(!FaultPolicy::standard().past_deadline(f64::MAX), "no deadline");
    }
}
