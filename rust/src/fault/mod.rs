//! Fault tolerance for the serving layer — deterministic fault
//! injection, deadlines/retries/backoff, circuit breakers, and the
//! chaos-testing harness.
//!
//! The paper's serving story (skewed matmuls routed to the IPU, squares
//! past the §2.4 wall to the GPU) only holds up in production if the
//! pipeline survives the failures a fleet actually sees. Because every
//! backend here is a *deterministic model*, failures can be injected as
//! a pure function of `(seed, request id, backend, attempt)` — so every
//! fault scenario replays bit-identically, which real hardware can never
//! offer (see [`plan`]).
//!
//! Layout:
//!
//! * [`plan`] — the seeded [`FaultPlan`]: fault taxonomy
//!   (exchange-link drop, tile-OOM flake, slow device, unavailability
//!   windows, worker panic) and named [`FaultProfile`]s.
//! * [`retry`] — [`RetryPolicy`] (capped exponential backoff,
//!   deterministic jitter) and the per-request [`FaultPolicy`]
//!   (deadline + retry + breaker knobs).
//! * [`breaker`] — the per-backend [`CircuitBreaker`]
//!   (closed → open → half-open on the request-id clock).
//! * this module — the **resolution engine**: [`resolve_one`] runs one
//!   request through breaker admission, the IPU attempt/retry loop, the
//!   deadline ledger, and GPU degradation, producing a [`Resolution`].
//!   `MmService::resolve_requests` drives it in request-id order
//!   *before* batch workers fan out, which is what keeps outcomes
//!   bit-identical across runs and worker counts.
//! * [`chaos`] — the `ipumm chaos` scenario matrix, the recovery
//!   report, and the ddmin-style shrinker for failing fault scenarios.

pub mod breaker;
pub mod chaos;
pub mod plan;
pub mod retry;

pub use breaker::{BreakerConfig, BreakerState, BreakerTransition, CircuitBreaker};
pub use chaos::{ChaosReport, ChaosScenario, ScenarioReport};
pub use plan::{BackendKind, FaultKind, FaultPlan, FaultProfile};
pub use retry::{FaultPolicy, RetryPolicy};

use crate::coordinator::device::RunOutcome;

/// How a request ultimately left the service.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RequestOutcome {
    /// Answered on the preferred path (includes the legacy memory-wall
    /// GPU fallback, which is a verdict-driven route, not a fault).
    Served,
    /// Answered, but on the GPU model because faults took the IPU out.
    /// Skewed batches are priced dense-equivalent on the GPU — the
    /// graceful-degradation cost the recovery report surfaces.
    Degraded(DegradeReason),
    /// Not answered: dropped with an explicit verdict instead of
    /// blocking its batch.
    Shed(ShedReason),
    /// Not answered: the batch worker panicked dispatching it. The
    /// panic was isolated (`catch_unwind`) — only this request failed.
    Panicked,
}

/// Why a request was degraded to the fallback backend.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DegradeReason {
    /// The IPU breaker was open when the request arrived.
    BreakerOpen,
    /// Every allowed IPU attempt failed.
    RetriesExhausted,
}

/// Why a request was shed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShedReason {
    /// The model-time ledger (wasted attempts + backoff + device time)
    /// blew the per-request deadline.
    DeadlineExceeded,
    /// No backend could take the request (outage / breaker open / final
    /// fallback failed) within the policy.
    Unavailable,
}

impl RequestOutcome {
    pub fn label(self) -> &'static str {
        match self {
            RequestOutcome::Served => "served",
            RequestOutcome::Degraded(_) => "degraded",
            RequestOutcome::Shed(_) => "shed",
            RequestOutcome::Panicked => "panicked",
        }
    }

    pub fn is_served(self) -> bool {
        self == RequestOutcome::Served
    }

    pub fn is_degraded(self) -> bool {
        matches!(self, RequestOutcome::Degraded(_))
    }

    pub fn is_shed(self) -> bool {
        matches!(self, RequestOutcome::Shed(_))
    }
}

/// One backend's answer for a request, computed fault-free: the cached
/// plan's priced outcome (or OOM verdict) plus the cache bookkeeping
/// that produced it. The resolution engine decides what actually
/// happens to it under the fault plan.
#[derive(Clone, Debug)]
pub struct BackendLeg {
    pub run: RunOutcome,
    /// Coordinator backend naming (`Backend::name`).
    pub backend: String,
    /// Plan-cache verdict; `None` when the leg never consulted it.
    pub cache_hit: Option<bool>,
    /// Cold-planning wall seconds charged to this leg.
    pub plan_seconds: f64,
}

/// One breaker state change, labelled with the backend it guards.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BreakerEvent {
    pub backend: String,
    pub tick: u64,
    pub from: BreakerState,
    pub to: BreakerState,
}

/// The fault pipeline's verdict for one request, fixed before batch
/// workers run. Everything here is a deterministic function of the
/// request id, the legs, the fault plan, and the policy.
#[derive(Clone, Debug)]
pub struct Resolution {
    pub id: u64,
    pub outcome: RequestOutcome,
    /// The priced run behind a served/degraded outcome (`None` for
    /// shed requests — nothing ran to completion on their behalf).
    pub run: Option<RunOutcome>,
    /// Backend charged with the final verdict.
    pub backend: String,
    /// Device attempts made across both legs (0 = shed before any).
    pub attempts: u32,
    /// Model seconds lost to wasted attempts and backoff.
    pub retry_seconds: f64,
    /// Model seconds of the final (successful) attempt; 0 otherwise.
    pub device_seconds: f64,
    /// The §2.4 memory-wall verdict (never fault-caused).
    pub oom: bool,
    /// Faults the plan injected into this request's attempts.
    pub injected: u32,
    pub cache_hit: Option<bool>,
    pub plan_seconds: f64,
}

fn base_seconds(leg: &BackendLeg) -> f64 {
    match &leg.run {
        RunOutcome::Ok { seconds, .. } => *seconds,
        RunOutcome::OutOfMemory => 0.0,
    }
}

/// A slow-device spike: same result, `factor`x the latency (and the
/// throughput/efficiency scaled down to match).
fn slowed(run: &RunOutcome, factor: f64) -> (RunOutcome, f64) {
    match run {
        RunOutcome::Ok { seconds, tflops, efficiency, vertices, max_tile_bytes } => {
            let secs = seconds * factor;
            (
                RunOutcome::Ok {
                    seconds: secs,
                    tflops: tflops / factor,
                    efficiency: efficiency / factor,
                    vertices: *vertices,
                    max_tile_bytes: *max_tile_bytes,
                },
                secs,
            )
        }
        RunOutcome::OutOfMemory => (RunOutcome::OutOfMemory, 0.0),
    }
}

/// Mutable per-request bookkeeping threaded through the attempt loop.
struct Ledger {
    attempts: u32,
    injected: u32,
    /// Model-time spent so far that is *not* the final answer: wasted
    /// attempts + backoff. Compared against the deadline.
    elapsed: f64,
}

impl Ledger {
    fn resolution(
        &self,
        id: u64,
        outcome: RequestOutcome,
        run: Option<RunOutcome>,
        backend: String,
        device_seconds: f64,
        cache: (Option<bool>, f64),
    ) -> Resolution {
        let oom = matches!(run, Some(RunOutcome::OutOfMemory));
        Resolution {
            id,
            outcome,
            run,
            backend,
            attempts: self.attempts,
            retry_seconds: self.elapsed,
            device_seconds,
            oom,
            injected: self.injected,
            cache_hit: cache.0,
            plan_seconds: cache.1,
        }
    }
}

/// Resolve one request against the fault plan and policy.
///
/// `ipu`/`gpu` are the policy's legs (`None` when the dispatch policy
/// excludes that backend); breakers are the caller's long-lived
/// per-backend state, ticked by request id. The engine:
///
/// 1. asks the IPU breaker for admission (open → degrade to GPU);
/// 2. runs the IPU attempt loop: injected transient faults waste the
///    attempt's model time and feed the breaker; backoff (seeded
///    jitter) is charged to the ledger; the retry budget bounds the
///    loop; the deadline sheds the request whenever the ledger blows
///    the budget;
/// 3. a memory-wall OOM verdict is *not* a fault: it falls back to the
///    GPU as a served outcome (status quo) and never feeds the breaker;
/// 4. exhausted retries or an open breaker degrade to the GPU leg,
///    which gets one attempt under its own breaker and fault draws.
pub fn resolve_one(
    id: u64,
    ipu: Option<&BackendLeg>,
    gpu: Option<&BackendLeg>,
    plan: &FaultPlan,
    policy: &FaultPolicy,
    ipu_breaker: &mut CircuitBreaker,
    gpu_breaker: &mut CircuitBreaker,
) -> Resolution {
    let mut ledger = Ledger { attempts: 0, injected: 0, elapsed: 0.0 };
    let Some(ipu_leg) = ipu else {
        // GPU-only policy: the GPU is the primary, not a degradation
        return gpu_resolve(id, gpu, None, None, plan, policy, gpu_breaker, &mut ledger);
    };
    let cache = (ipu_leg.cache_hit, ipu_leg.plan_seconds);
    loop {
        if !ipu_breaker.allows(id) {
            return gpu_resolve(
                id,
                gpu,
                Some(DegradeReason::BreakerOpen),
                Some(ipu_leg),
                plan,
                policy,
                gpu_breaker,
                &mut ledger,
            );
        }
        ledger.attempts += 1;
        let attempt = ledger.attempts - 1;
        match plan.inject(id, BackendKind::Ipu, attempt) {
            fault @ (None | Some(FaultKind::SlowDevice)) => {
                let slow = fault.is_some();
                if slow {
                    ledger.injected += 1;
                    crate::obs::count("serve.faults.injected", 1);
                }
                if ipu_leg.run.is_oom() {
                    // the §2.4 wall is a verdict, not a fault: the
                    // legacy GPU fallback stays a *served* outcome and
                    // the breaker never hears about it
                    if gpu.is_some() {
                        return gpu_resolve(
                            id, gpu, None, Some(ipu_leg), plan, policy, gpu_breaker,
                            &mut ledger,
                        );
                    }
                    return ledger.resolution(
                        id,
                        RequestOutcome::Served,
                        Some(RunOutcome::OutOfMemory),
                        ipu_leg.backend.clone(),
                        0.0,
                        cache,
                    );
                }
                // the device answered (possibly slowly): a success for
                // the breaker either way
                ipu_breaker.on_success(id);
                let (run, secs) = if slow {
                    slowed(&ipu_leg.run, plan.profile.slow_factor)
                } else {
                    (ipu_leg.run.clone(), base_seconds(ipu_leg))
                };
                if policy.past_deadline(ledger.elapsed + secs) {
                    crate::obs::count("serve.deadline.exceeded", 1);
                    return ledger.resolution(
                        id,
                        RequestOutcome::Shed(ShedReason::DeadlineExceeded),
                        None,
                        ipu_leg.backend.clone(),
                        0.0,
                        cache,
                    );
                }
                return ledger.resolution(
                    id,
                    RequestOutcome::Served,
                    Some(run),
                    ipu_leg.backend.clone(),
                    secs,
                    cache,
                );
            }
            Some(fault) => {
                // transient (link drop / tile flake) or outage window
                ledger.injected += 1;
                crate::obs::count("serve.faults.injected", 1);
                ipu_breaker.on_failure(id);
                // a transient fault wastes the attempt's device time; an
                // unavailable backend fails instantly
                if fault.is_transient() {
                    ledger.elapsed += base_seconds(ipu_leg);
                }
                if policy.past_deadline(ledger.elapsed) {
                    crate::obs::count("serve.deadline.exceeded", 1);
                    return ledger.resolution(
                        id,
                        RequestOutcome::Shed(ShedReason::DeadlineExceeded),
                        None,
                        ipu_leg.backend.clone(),
                        0.0,
                        cache,
                    );
                }
                if ledger.attempts > policy.retry.max_retries {
                    return gpu_resolve(
                        id,
                        gpu,
                        Some(DegradeReason::RetriesExhausted),
                        Some(ipu_leg),
                        plan,
                        policy,
                        gpu_breaker,
                        &mut ledger,
                    );
                }
                let backoff = policy.retry.backoff_seconds(plan.seed, id, attempt);
                crate::obs::count("serve.retries", 1);
                crate::obs::observe("serve.retry_backoff_seconds", backoff);
                ledger.elapsed += backoff;
                if policy.past_deadline(ledger.elapsed) {
                    crate::obs::count("serve.deadline.exceeded", 1);
                    return ledger.resolution(
                        id,
                        RequestOutcome::Shed(ShedReason::DeadlineExceeded),
                        None,
                        ipu_leg.backend.clone(),
                        0.0,
                        cache,
                    );
                }
            }
        }
    }
}

/// Finish a request on the GPU leg. `reason` is `None` when the GPU is
/// the legitimate route (GPU-only policy, or the legacy memory-wall
/// fallback — a served outcome) and `Some` when faults degraded the
/// request here. One attempt: the GPU is already the last resort.
#[allow(clippy::too_many_arguments)]
fn gpu_resolve(
    id: u64,
    gpu: Option<&BackendLeg>,
    reason: Option<DegradeReason>,
    ipu_leg: Option<&BackendLeg>,
    plan: &FaultPlan,
    policy: &FaultPolicy,
    gpu_breaker: &mut CircuitBreaker,
    ledger: &mut Ledger,
) -> Resolution {
    // the cache verdict follows the leg that consulted it (legacy
    // semantics: the GPU fallback keeps the IPU lookup's verdict)
    let cache = match ipu_leg {
        Some(leg) => (leg.cache_hit, leg.plan_seconds),
        None => gpu.map_or((None, 0.0), |leg| (leg.cache_hit, leg.plan_seconds)),
    };
    let shed_backend = |gpu: Option<&BackendLeg>| {
        gpu.map(|l| l.backend.clone())
            .or_else(|| ipu_leg.map(|l| l.backend.clone()))
            .unwrap_or_else(|| "none".to_string())
    };
    let Some(leg) = gpu else {
        // nowhere left to go (e.g. IPU-only policy with a dead IPU)
        return ledger.resolution(
            id,
            RequestOutcome::Shed(ShedReason::Unavailable),
            None,
            shed_backend(None),
            0.0,
            cache,
        );
    };
    if !gpu_breaker.allows(id) {
        return ledger.resolution(
            id,
            RequestOutcome::Shed(ShedReason::Unavailable),
            None,
            leg.backend.clone(),
            0.0,
            cache,
        );
    }
    ledger.attempts += 1;
    match plan.inject(id, BackendKind::Gpu, 0) {
        Some(FaultKind::Unavailable) => {
            ledger.injected += 1;
            crate::obs::count("serve.faults.injected", 1);
            gpu_breaker.on_failure(id);
            ledger.resolution(
                id,
                RequestOutcome::Shed(ShedReason::Unavailable),
                None,
                leg.backend.clone(),
                0.0,
                cache,
            )
        }
        fault @ (None | Some(_)) => {
            // None or SlowDevice (the only kinds the GPU can draw)
            let slow = matches!(fault, Some(FaultKind::SlowDevice));
            if slow {
                ledger.injected += 1;
                crate::obs::count("serve.faults.injected", 1);
            }
            gpu_breaker.on_success(id);
            let (run, secs) = if slow {
                slowed(&leg.run, plan.profile.slow_factor)
            } else {
                (leg.run.clone(), base_seconds(leg))
            };
            if policy.past_deadline(ledger.elapsed + secs) {
                crate::obs::count("serve.deadline.exceeded", 1);
                return ledger.resolution(
                    id,
                    RequestOutcome::Shed(ShedReason::DeadlineExceeded),
                    None,
                    leg.backend.clone(),
                    0.0,
                    cache,
                );
            }
            let outcome = match reason {
                None => RequestOutcome::Served,
                Some(r) => RequestOutcome::Degraded(r),
            };
            ledger.resolution(id, outcome, Some(run), leg.backend.clone(), secs, cache)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ok_leg(secs: f64, backend: &str) -> BackendLeg {
        BackendLeg {
            run: RunOutcome::Ok {
                seconds: secs,
                tflops: 10.0,
                efficiency: 0.5,
                vertices: Some(100),
                max_tile_bytes: Some(1 << 16),
            },
            backend: backend.to_string(),
            cache_hit: Some(true),
            plan_seconds: 0.0,
        }
    }

    fn oom_leg(backend: &str) -> BackendLeg {
        BackendLeg {
            run: RunOutcome::OutOfMemory,
            backend: backend.to_string(),
            cache_hit: Some(false),
            plan_seconds: 1e-3,
        }
    }

    fn breakers() -> (CircuitBreaker, CircuitBreaker) {
        (
            CircuitBreaker::new(BreakerConfig::standard()),
            CircuitBreaker::new(BreakerConfig::standard()),
        )
    }

    #[test]
    fn fault_free_request_serves_on_ipu_bit_identically() {
        let ipu = ok_leg(3.25e-4, "ipu-sim/GC200");
        let gpu = ok_leg(9e-4, "gpu-model/A30");
        let (mut ib, mut gb) = breakers();
        let r = resolve_one(
            7,
            Some(&ipu),
            Some(&gpu),
            &FaultPlan::none(),
            &FaultPolicy::standard(),
            &mut ib,
            &mut gb,
        );
        assert_eq!(r.outcome, RequestOutcome::Served);
        assert_eq!(r.backend, "ipu-sim/GC200");
        assert_eq!(r.attempts, 1);
        assert_eq!(r.retry_seconds, 0.0);
        assert_eq!(r.device_seconds.to_bits(), 3.25e-4f64.to_bits());
        assert_eq!(r.injected, 0);
        assert!(!r.oom);
    }

    #[test]
    fn always_failing_ipu_exhausts_retries_and_degrades_to_gpu() {
        let ipu = ok_leg(1e-4, "ipu");
        let gpu = ok_leg(5e-4, "gpu");
        let plan = FaultPlan::seeded(1, FaultProfile::transient(1000));
        let policy = FaultPolicy {
            deadline_s: None,
            retry: RetryPolicy::standard(2),
            breaker: BreakerConfig::disabled(),
        };
        let mut ib = CircuitBreaker::new(policy.breaker);
        let mut gb = CircuitBreaker::new(policy.breaker);
        let r = resolve_one(0, Some(&ipu), Some(&gpu), &plan, &policy, &mut ib, &mut gb);
        assert_eq!(r.outcome, RequestOutcome::Degraded(DegradeReason::RetriesExhausted));
        assert_eq!(r.backend, "gpu");
        assert_eq!(r.attempts, 4, "3 IPU attempts + 1 GPU attempt");
        assert_eq!(r.injected, 3);
        assert!(r.retry_seconds > 3e-4, "3 wasted attempts + 2 backoffs");
        assert_eq!(r.device_seconds, 5e-4);
    }

    #[test]
    fn outage_with_no_fallback_sheds_unavailable() {
        let ipu = ok_leg(1e-4, "ipu");
        let plan = FaultPlan::seeded(
            1,
            FaultProfile { ipu_outages: vec![(0, 10)], ..FaultProfile::none() },
        );
        let policy = FaultPolicy {
            deadline_s: None,
            retry: RetryPolicy::none(),
            breaker: BreakerConfig::disabled(),
        };
        let (mut ib, mut gb) = breakers();
        let r = resolve_one(5, Some(&ipu), None, &plan, &policy, &mut ib, &mut gb);
        assert_eq!(r.outcome, RequestOutcome::Shed(ShedReason::Unavailable));
        assert!(r.run.is_none());
        assert_eq!(r.device_seconds, 0.0);
        // outage attempts waste no device time (nothing launched)
        assert_eq!(r.retry_seconds, 0.0);
    }

    #[test]
    fn slow_device_over_deadline_sheds_under_deadline_serves_scaled() {
        let ipu = ok_leg(1e-4, "ipu");
        let plan = FaultPlan::seeded(1, FaultProfile::slow(1000, 100.0));
        let (mut ib, mut gb) = breakers();
        // 1e-4 * 100 = 1e-2 > 5e-3: shed
        let tight = FaultPolicy::standard().with_deadline(5e-3);
        let r = resolve_one(0, Some(&ipu), None, &plan, &tight, &mut ib, &mut gb);
        assert_eq!(r.outcome, RequestOutcome::Shed(ShedReason::DeadlineExceeded));
        // generous deadline: served, with the run slowed 100x
        let loose = FaultPolicy::standard().with_deadline(1.0);
        let (mut ib, mut gb) = breakers();
        let r = resolve_one(0, Some(&ipu), None, &plan, &loose, &mut ib, &mut gb);
        assert_eq!(r.outcome, RequestOutcome::Served);
        assert_eq!(r.device_seconds, 1e-2);
        match r.run.unwrap() {
            RunOutcome::Ok { seconds, tflops, .. } => {
                assert_eq!(seconds, 1e-2);
                assert!((tflops - 0.1).abs() < 1e-12, "throughput scaled down");
            }
            RunOutcome::OutOfMemory => panic!("slow device still answers"),
        }
    }

    #[test]
    fn memory_wall_fallback_stays_served_and_never_feeds_the_breaker() {
        let ipu = oom_leg("ipu");
        let gpu = ok_leg(2e-3, "gpu");
        let (mut ib, mut gb) = breakers();
        let r = resolve_one(
            3,
            Some(&ipu),
            Some(&gpu),
            &FaultPlan::none(),
            &FaultPolicy::standard(),
            &mut ib,
            &mut gb,
        );
        assert_eq!(r.outcome, RequestOutcome::Served, "the wall is a verdict, not a fault");
        assert_eq!(r.backend, "gpu");
        assert!(!r.oom, "the GPU answered");
        assert_eq!(r.cache_hit, Some(false), "the IPU lookup's verdict is kept");
        assert!(ib.transitions().is_empty(), "breaker never hears about the wall");
        // without a GPU the OOM verdict itself is served (IPU-only)
        let (mut ib, mut gb) = breakers();
        let r = resolve_one(
            3,
            Some(&ipu),
            None,
            &FaultPlan::none(),
            &FaultPolicy::standard(),
            &mut ib,
            &mut gb,
        );
        assert_eq!(r.outcome, RequestOutcome::Served);
        assert!(r.oom);
        assert_eq!(r.backend, "ipu");
    }

    #[test]
    fn retried_success_returns_the_same_bits_as_first_try() {
        let ipu = ok_leg(7.75e-4, "ipu");
        let plan = FaultPlan::seeded(11, FaultProfile::transient(500));
        // find an id that faults on attempt 0 but recovers on attempt 1
        let id = (0..500u64)
            .find(|&id| {
                plan.inject(id, BackendKind::Ipu, 0).map(FaultKind::is_transient)
                    == Some(true)
                    && plan.inject(id, BackendKind::Ipu, 1).is_none()
            })
            .expect("a recovering id exists at 50%");
        let policy = FaultPolicy {
            deadline_s: None,
            retry: RetryPolicy::standard(3),
            breaker: BreakerConfig::disabled(),
        };
        let mut ib = CircuitBreaker::new(policy.breaker);
        let mut gb = CircuitBreaker::new(policy.breaker);
        let retried = resolve_one(id, Some(&ipu), None, &plan, &policy, &mut ib, &mut gb);
        let clean = resolve_one(
            id,
            Some(&ipu),
            None,
            &FaultPlan::none(),
            &policy,
            &mut CircuitBreaker::new(policy.breaker),
            &mut CircuitBreaker::new(policy.breaker),
        );
        assert_eq!(retried.outcome, RequestOutcome::Served);
        assert_eq!(retried.attempts, 2);
        assert_eq!(
            retried.device_seconds.to_bits(),
            clean.device_seconds.to_bits(),
            "the retried answer is the first-try answer, bit for bit"
        );
        assert!(retried.retry_seconds > clean.retry_seconds);
    }

    #[test]
    fn breaker_open_degrades_without_attempting_the_ipu() {
        let ipu = ok_leg(1e-4, "ipu");
        let gpu = ok_leg(5e-4, "gpu");
        let policy = FaultPolicy::standard();
        let mut ib = CircuitBreaker::new(policy.breaker);
        let mut gb = CircuitBreaker::new(policy.breaker);
        // trip the IPU breaker by hand at tick 0
        for _ in 0..3 {
            ib.allows(0);
            ib.on_failure(0);
        }
        let r = resolve_one(
            1,
            Some(&ipu),
            Some(&gpu),
            &FaultPlan::none(),
            &policy,
            &mut ib,
            &mut gb,
        );
        assert_eq!(r.outcome, RequestOutcome::Degraded(DegradeReason::BreakerOpen));
        assert_eq!(r.attempts, 1, "only the GPU attempt ran");
        assert_eq!(r.backend, "gpu");
    }
}
