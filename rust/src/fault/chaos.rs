//! Chaos harness: run a scenario matrix (fault profile × retry/breaker
//! policy) over a seeded trace and report how the serving layer
//! recovered — plus the ddmin-style shrinker that reduces a failing
//! chaos invariant to a minimal (request, fault) pair (the ROADMAP §5
//! down payment).
//!
//! Everything here is deterministic: the trace comes from
//! `TraceSpec::paper_mix(seed)`, the faults from [`FaultPlan::seeded`],
//! and the breaker from the request-id clock — so a chaos report is a
//! regression artifact, not a flaky observation.

use crate::arch::{GpuArch, IpuArch};
use crate::coordinator::trace::TraceSpec;
use crate::fault::plan::{BackendKind, FaultPlan, FaultProfile};
use crate::fault::retry::{FaultPolicy, RetryPolicy};
use crate::fault::{BreakerEvent, RequestOutcome};
use crate::planner::partition::MmShape;
use crate::serve::service::{MmService, ServiceConfig};
use crate::serve::telemetry::ServeReport;
use crate::sparse::pattern::SparsitySpec;
use crate::util::json::Json;
use crate::util::table::Table;

/// One cell of the chaos matrix: a named fault profile plus the policy
/// meant to survive it.
#[derive(Clone, Debug)]
pub struct ChaosScenario {
    pub name: String,
    pub profile: FaultProfile,
    pub policy: FaultPolicy,
}

/// Build a scenario from a profile name (see [`FaultProfile::names`]).
/// The `slow` profile gets a 5ms default deadline when none is given —
/// a 1000x latency spike with no deadline would never shed, which is
/// the behavior the scenario exists to exercise.
pub fn scenario(
    name: &str,
    deadline_s: Option<f64>,
    retries: u32,
) -> Result<ChaosScenario, String> {
    let profile = FaultProfile::by_name(name).ok_or_else(|| {
        format!(
            "unknown fault profile '{name}' (known: {})",
            FaultProfile::names().join(", ")
        )
    })?;
    let deadline_s = deadline_s.or(if name == "slow" { Some(5e-3) } else { None });
    Ok(ChaosScenario {
        name: name.to_string(),
        profile,
        policy: FaultPolicy {
            deadline_s,
            retry: RetryPolicy::standard(retries),
            breaker: crate::fault::breaker::BreakerConfig::standard(),
        },
    })
}

/// Recovery accounting for one scenario.
#[derive(Clone, Debug)]
pub struct ScenarioReport {
    pub name: String,
    /// Requests submitted.
    pub requests: usize,
    pub served: usize,
    pub degraded: usize,
    pub shed: usize,
    pub panicked: usize,
    /// Requests that vanished without a record — the invariant says 0.
    pub lost: usize,
    /// Device re-attempts across the trace (attempts beyond the first).
    pub retries: u64,
    /// Faults the plan injected.
    pub injected: u64,
    pub breaker: Vec<BreakerEvent>,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub wall_seconds: f64,
}

impl ScenarioReport {
    /// Fold a serve report into recovery accounting. `submitted` is the
    /// trace length — anything the report does not account for is lost.
    pub fn from_serve(name: &str, submitted: usize, report: &ServeReport) -> ScenarioReport {
        let stats = report.fault_stats();
        ScenarioReport {
            name: name.to_string(),
            requests: submitted,
            served: stats.served,
            degraded: stats.degraded,
            shed: stats.shed,
            panicked: stats.panicked,
            lost: submitted.saturating_sub(report.requests.len()),
            retries: stats.retries,
            injected: report.injected_faults,
            breaker: report.breaker_transitions.clone(),
            p50_ms: report.latency_sketch.quantile(0.5) * 1e3,
            p99_ms: report.latency_sketch.quantile(0.99) * 1e3,
            wall_seconds: report.wall_seconds,
        }
    }
}

/// The whole matrix run.
#[derive(Clone, Debug)]
pub struct ChaosReport {
    pub jobs: usize,
    pub seed: u64,
    pub scenarios: Vec<ScenarioReport>,
}

/// Run every scenario over the same seeded paper-mix trace, one fresh
/// service per scenario (a shared plan cache would leak warm state
/// between cells and muddy the comparison).
pub fn run_matrix(
    ipu: &IpuArch,
    gpu: &GpuArch,
    jobs: usize,
    seed: u64,
    workers: Option<usize>,
    scenarios: &[ChaosScenario],
) -> ChaosReport {
    let spec = TraceSpec::paper_mix(jobs, seed);
    let shapes: Vec<MmShape> = spec.jobs.iter().map(|(_, s)| *s).collect();
    let mut out = Vec::with_capacity(scenarios.len());
    for sc in scenarios {
        let svc = MmService::new(ServiceConfig {
            arch: ipu.clone(),
            gpu: gpu.clone(),
            workers,
            faults: FaultPlan::seeded(seed, sc.profile.clone()),
            fault_policy: sc.policy.clone(),
            ..ServiceConfig::default()
        });
        let report = svc.serve_trace(&shapes);
        out.push(ScenarioReport::from_serve(&sc.name, shapes.len(), &report));
    }
    ChaosReport { jobs, seed, scenarios: out }
}

/// The chaos invariants a scenario must satisfy, independent of profile:
///
/// 1. **accounting** — served + degraded + shed + panicked = requests;
/// 2. **zero lost** — every submitted request produced a record;
/// 3. **deadline respected** — no served/degraded record's model-time
///    ledger (retry + device seconds) exceeds the policy's deadline.
///
/// Returns human-readable violations (empty = healthy). The serve-layer
/// variant over raw records is [`record_violations`].
pub fn invariant_violations(sc: &ScenarioReport) -> Vec<String> {
    let mut v = Vec::new();
    let accounted = sc.served + sc.degraded + sc.shed + sc.panicked;
    if accounted != sc.requests {
        v.push(format!(
            "{}: accounting broken: {accounted} outcomes for {} requests",
            sc.name, sc.requests
        ));
    }
    if sc.lost != 0 {
        v.push(format!("{}: {} requests lost without a record", sc.name, sc.lost));
    }
    v
}

/// Per-record deadline check for one serve report (the accounting
/// identity lives in [`invariant_violations`]; this one needs the raw
/// records, which the folded [`ScenarioReport`] no longer carries).
pub fn record_violations(report: &ServeReport, policy: &FaultPolicy) -> Vec<String> {
    let mut v = Vec::new();
    if let Some(deadline) = policy.deadline_s {
        for r in &report.requests {
            let answered = matches!(
                r.outcome,
                RequestOutcome::Served | RequestOutcome::Degraded(_)
            );
            let ledger = r.retry_seconds + r.device_seconds;
            if answered && ledger > deadline {
                v.push(format!(
                    "request {}: answered {:.3e}s past a {:.3e}s deadline",
                    r.id, ledger, deadline
                ));
            }
        }
    }
    v
}

impl ChaosReport {
    /// Violations across every scenario (empty = the matrix is healthy).
    pub fn violations(&self) -> Vec<String> {
        self.scenarios.iter().flat_map(invariant_violations).collect()
    }

    pub fn to_table(&self) -> Table {
        let mut t = Table::new(
            &format!(
                "Chaos matrix: {} requests, seed {} (outcomes per scenario)",
                self.jobs, self.seed
            ),
            &[
                "scenario", "served", "degraded", "shed", "panicked", "lost", "retries",
                "injected", "breaker", "p50", "p99",
            ],
        );
        for s in &self.scenarios {
            t.row(&[
                s.name.clone(),
                s.served.to_string(),
                s.degraded.to_string(),
                s.shed.to_string(),
                s.panicked.to_string(),
                s.lost.to_string(),
                s.retries.to_string(),
                s.injected.to_string(),
                s.breaker.len().to_string(),
                format!("{:.3} ms", s.p50_ms),
                format!("{:.3} ms", s.p99_ms),
            ]);
        }
        t
    }

    /// The JSON recovery report `ipumm chaos --json` writes (and CI
    /// validates): deterministic key order, one object per scenario.
    pub fn to_json(&self) -> Json {
        let mut doc = Json::obj();
        doc.set("jobs", Json::Int(self.jobs as i64));
        doc.set("seed", Json::Int(self.seed as i64));
        let mut arr = Json::Arr(Vec::new());
        for s in &self.scenarios {
            let mut o = Json::obj();
            o.set("name", Json::Str(s.name.clone()));
            o.set("requests", Json::Int(s.requests as i64));
            o.set("served", Json::Int(s.served as i64));
            o.set("degraded", Json::Int(s.degraded as i64));
            o.set("shed", Json::Int(s.shed as i64));
            o.set("panicked", Json::Int(s.panicked as i64));
            o.set("lost", Json::Int(s.lost as i64));
            o.set("retries", Json::Int(s.retries as i64));
            o.set("injected", Json::Int(s.injected as i64));
            o.set("p50_ms", Json::Num(s.p50_ms));
            o.set("p99_ms", Json::Num(s.p99_ms));
            o.set("wall_seconds", Json::Num(s.wall_seconds));
            let mut tr = Json::Arr(Vec::new());
            for b in &s.breaker {
                let mut bt = Json::obj();
                bt.set("backend", Json::Str(b.backend.clone()));
                bt.set("tick", Json::Int(b.tick as i64));
                bt.set("from", Json::Str(b.from.name().to_string()));
                bt.set("to", Json::Str(b.to.name().to_string()));
                tr.push(bt);
            }
            o.set("breaker", tr);
            arr.push(o);
        }
        doc.set("scenarios", arr);
        doc
    }
}

/// A chaos-trace request with an **explicit** id. The fault plan keys on
/// ids, and every id's draw is an independent hash — so removing
/// requests from a trace never changes the faults the survivors see,
/// which is exactly what makes shrinking sound.
pub type ChaosRequest = (u64, MmShape, Option<SparsitySpec>);

/// Shrink a failing chaos trace to a (locally) minimal one: `fails`
/// must return true on `requests` (the invariant is broken); the result
/// is a subset, original ids preserved, on which `fails` still returns
/// true and from which no single request can be removed without the
/// failure disappearing. ddmin-style: halve-sized chunks first, then
/// ever-smaller ones down to single requests.
pub fn shrink_failing<F>(requests: &[ChaosRequest], fails: F) -> Vec<ChaosRequest>
where
    F: Fn(&[ChaosRequest]) -> bool,
{
    let mut cur: Vec<ChaosRequest> = requests.to_vec();
    if cur.is_empty() || !fails(&cur) {
        return cur;
    }
    let mut chunk = cur.len().div_ceil(2);
    loop {
        let mut i = 0;
        while i < cur.len() && cur.len() > 1 {
            let mut candidate = cur.clone();
            let end = (i + chunk).min(candidate.len());
            candidate.drain(i..end);
            if !candidate.is_empty() && fails(&candidate) {
                cur = candidate; // keep the smaller failing trace; retry at i
            } else {
                i += chunk;
            }
        }
        if chunk == 1 {
            break;
        }
        chunk = chunk.div_ceil(2).max(1);
    }
    cur
}

/// Name the (request, fault) pair a minimal trace points at: the first
/// fault the plan injects into the request's first attempts on either
/// backend, or the panic draw.
pub fn describe_minimal(plan: &FaultPlan, req: &ChaosRequest) -> String {
    let (id, shape, _) = req;
    let shape = format!("{}x{}x{}", shape.m, shape.n, shape.k);
    if plan.injects_panic(*id) {
        return format!("request {id} ({shape}): worker-panic");
    }
    for backend in [BackendKind::Ipu, BackendKind::Gpu] {
        for attempt in 0..4 {
            if let Some(kind) = plan.inject(*id, backend, attempt) {
                return format!(
                    "request {id} ({shape}): {} on {backend:?} attempt {attempt}",
                    kind.name()
                );
            }
        }
    }
    format!("request {id} ({shape}): no injected fault (policy-only failure)")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scenario_parses_known_profiles_and_rejects_unknown() {
        let sc = scenario("transient-heavy", None, 3).unwrap();
        assert_eq!(sc.profile.transient_permille, 250);
        assert_eq!(sc.policy.retry.max_retries, 3);
        assert!(sc.policy.deadline_s.is_none());
        let slow = scenario("slow", None, 3).unwrap();
        assert_eq!(slow.policy.deadline_s, Some(5e-3), "slow defaults a deadline");
        let explicit = scenario("slow", Some(1e-2), 3).unwrap();
        assert_eq!(explicit.policy.deadline_s, Some(1e-2));
        assert!(scenario("meteor-strike", None, 3).is_err());
    }

    #[test]
    fn accounting_violations_are_detected() {
        let mut sc = ScenarioReport {
            name: "t".into(),
            requests: 10,
            served: 9,
            degraded: 0,
            shed: 0,
            panicked: 0,
            lost: 1,
            retries: 0,
            injected: 0,
            breaker: Vec::new(),
            p50_ms: 0.0,
            p99_ms: 0.0,
            wall_seconds: 0.0,
        };
        assert_eq!(invariant_violations(&sc).len(), 2, "accounting + lost");
        sc.served = 10;
        sc.lost = 0;
        assert!(invariant_violations(&sc).is_empty());
    }

    #[test]
    fn shrinker_reduces_to_the_single_culprit_with_ids_preserved() {
        // ids 0..48; the "invariant" fails whenever id 7 is present —
        // the shape of a real chaos failure keyed by the fault plan
        let trace: Vec<ChaosRequest> = (0..48u64)
            .map(|id| (id, MmShape::square(512 + (id as usize % 4) * 128), None))
            .collect();
        let minimal = shrink_failing(&trace, |subset| subset.iter().any(|(id, ..)| *id == 7));
        assert_eq!(minimal.len(), 1, "minimal failing trace is one request");
        assert_eq!(minimal[0].0, 7, "original id survives shrinking");
    }

    #[test]
    fn shrinker_returns_input_when_nothing_fails() {
        let trace: Vec<ChaosRequest> = (0..8u64).map(|id| (id, MmShape::square(512), None)).collect();
        let out = shrink_failing(&trace, |_| false);
        assert_eq!(out.len(), 8, "no failure -> nothing to shrink");
    }

    #[test]
    fn describe_minimal_names_the_fault() {
        let plan = FaultPlan::seeded(
            1,
            FaultProfile { ipu_outages: vec![(7, 8)], ..FaultProfile::none() },
        );
        let desc = describe_minimal(&plan, &(7, MmShape::square(512), None));
        assert!(desc.contains("request 7"), "{desc}");
        assert!(desc.contains("unavailable"), "{desc}");
        let clean = describe_minimal(&plan, &(6, MmShape::square(512), None));
        assert!(clean.contains("policy-only"), "{clean}");
    }

    #[test]
    fn report_json_round_trips_counts() {
        let rep = ChaosReport {
            jobs: 12,
            seed: 3,
            scenarios: vec![ScenarioReport {
                name: "transient".into(),
                requests: 12,
                served: 10,
                degraded: 2,
                shed: 0,
                panicked: 0,
                lost: 0,
                retries: 4,
                injected: 5,
                breaker: vec![BreakerEvent {
                    backend: "ipu".into(),
                    tick: 40,
                    from: crate::fault::BreakerState::Closed,
                    to: crate::fault::BreakerState::Open,
                }],
                p50_ms: 0.5,
                p99_ms: 2.0,
                wall_seconds: 0.1,
            }],
        };
        let doc = Json::parse(&rep.to_json().render()).unwrap();
        match &doc {
            Json::Obj(m) => {
                assert_eq!(m.get("jobs"), Some(&Json::Int(12)));
                match m.get("scenarios") {
                    Some(Json::Arr(scs)) => match &scs[0] {
                        Json::Obj(s) => {
                            assert_eq!(s.get("served"), Some(&Json::Int(10)));
                            assert_eq!(s.get("lost"), Some(&Json::Int(0)));
                            match s.get("breaker") {
                                Some(Json::Arr(b)) => assert_eq!(b.len(), 1),
                                other => panic!("breaker: {other:?}"),
                            }
                        }
                        other => panic!("scenario: {other:?}"),
                    },
                    other => panic!("scenarios: {other:?}"),
                }
            }
            other => panic!("doc: {other:?}"),
        }
        assert!(rep.violations().is_empty());
        assert!(rep.to_table().n_rows() >= 1);
    }
}
