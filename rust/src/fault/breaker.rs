//! Per-backend circuit breaker: closed → open → half-open.
//!
//! The breaker protects a failing backend from retry storms and gives
//! the dispatch layer a cheap "is this device worth trying" answer. To
//! keep the serve path deterministic across worker counts, the breaker
//! is driven by the *request-id clock*, not the wall clock: `tick` is
//! the id of the request being resolved, and resolution happens in id
//! order (see `MmService::resolve_requests`), so every run replays the
//! same closed→open→half-open trajectory bit-identically.

/// Breaker automaton states.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BreakerState {
    /// Healthy: all requests pass through.
    Closed,
    /// Tripped: requests are rejected until the cooldown elapses.
    Open,
    /// Cooled down: a bounded number of probe requests pass; one
    /// success re-closes, one failure re-opens.
    HalfOpen,
}

impl BreakerState {
    pub fn name(self) -> &'static str {
        match self {
            BreakerState::Closed => "closed",
            BreakerState::Open => "open",
            BreakerState::HalfOpen => "half-open",
        }
    }
}

/// Trip thresholds and recovery pacing.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BreakerConfig {
    /// Master switch: a disabled breaker always allows and never trips.
    pub enabled: bool,
    /// Trip after this many consecutive failures.
    pub consecutive_failures: u32,
    /// Trip when the failure rate over the sliding outcome window
    /// reaches this permille (evaluated only once the window is full).
    pub failure_rate_permille: u32,
    /// Sliding outcome-window length for the rate threshold.
    pub window: usize,
    /// Request-id ticks an open breaker waits before half-opening.
    pub cooldown_ticks: u64,
    /// Probe requests allowed through in half-open.
    pub half_open_probes: u32,
}

impl BreakerConfig {
    /// Never trips; [`CircuitBreaker::allows`] is always true.
    pub fn disabled() -> BreakerConfig {
        BreakerConfig {
            enabled: false,
            consecutive_failures: u32::MAX,
            failure_rate_permille: 1000,
            window: 1,
            cooldown_ticks: 0,
            half_open_probes: 1,
        }
    }

    /// The default serving policy: 3 consecutive failures or a 50%
    /// failure rate over the last 16 outcomes trips; 25 ticks of
    /// cooldown; one probe re-closes.
    pub fn standard() -> BreakerConfig {
        BreakerConfig {
            enabled: true,
            consecutive_failures: 3,
            failure_rate_permille: 500,
            window: 16,
            cooldown_ticks: 25,
            half_open_probes: 1,
        }
    }
}

/// One recorded state transition, on the request-id clock.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BreakerTransition {
    pub tick: u64,
    pub from: BreakerState,
    pub to: BreakerState,
}

/// The breaker itself. Single-threaded by design: the fault pipeline
/// resolves requests in id order before workers fan out, which is what
/// makes the trajectory reproducible.
#[derive(Clone, Debug)]
pub struct CircuitBreaker {
    config: BreakerConfig,
    state: BreakerState,
    consecutive: u32,
    /// Recent outcomes, `true` = failure, newest last.
    window: std::collections::VecDeque<bool>,
    opened_at: u64,
    probes_left: u32,
    transitions: Vec<BreakerTransition>,
}

impl CircuitBreaker {
    pub fn new(config: BreakerConfig) -> CircuitBreaker {
        CircuitBreaker {
            config,
            state: BreakerState::Closed,
            consecutive: 0,
            window: std::collections::VecDeque::new(),
            opened_at: 0,
            probes_left: 0,
            transitions: Vec::new(),
        }
    }

    pub fn state(&self) -> BreakerState {
        self.state
    }

    /// Every state change this breaker went through, in tick order.
    pub fn transitions(&self) -> &[BreakerTransition] {
        &self.transitions
    }

    fn transition(&mut self, tick: u64, to: BreakerState) {
        let from = self.state;
        self.state = to;
        self.transitions.push(BreakerTransition { tick, from, to });
    }

    /// May request `tick` go to this backend? Open breakers half-open
    /// here once the cooldown has elapsed; half-open breakers meter out
    /// their probe budget.
    pub fn allows(&mut self, tick: u64) -> bool {
        if !self.config.enabled {
            return true;
        }
        match self.state {
            BreakerState::Closed => true,
            BreakerState::Open => {
                if tick >= self.opened_at.saturating_add(self.config.cooldown_ticks) {
                    self.transition(tick, BreakerState::HalfOpen);
                    self.probes_left = self.config.half_open_probes;
                    self.probes_left -= 1;
                    true
                } else {
                    false
                }
            }
            BreakerState::HalfOpen => {
                if self.probes_left > 0 {
                    self.probes_left -= 1;
                    true
                } else {
                    false
                }
            }
        }
    }

    /// Record a successful attempt at `tick`.
    pub fn on_success(&mut self, tick: u64) {
        if !self.config.enabled {
            return;
        }
        match self.state {
            BreakerState::HalfOpen => {
                // probe succeeded: full reset
                self.transition(tick, BreakerState::Closed);
                self.consecutive = 0;
                self.window.clear();
            }
            BreakerState::Closed => {
                self.consecutive = 0;
                self.push_outcome(false);
            }
            BreakerState::Open => {}
        }
    }

    /// Record a failed attempt at `tick`; may trip the breaker.
    pub fn on_failure(&mut self, tick: u64) {
        if !self.config.enabled {
            return;
        }
        match self.state {
            BreakerState::HalfOpen => {
                // probe failed: back to open, restart the cooldown
                self.transition(tick, BreakerState::Open);
                self.opened_at = tick;
            }
            BreakerState::Closed => {
                self.consecutive += 1;
                self.push_outcome(true);
                let rate_tripped = self.window.len() >= self.config.window && {
                    let failures = self.window.iter().filter(|&&f| f).count();
                    failures * 1000
                        >= self.config.failure_rate_permille as usize * self.window.len()
                };
                if self.consecutive >= self.config.consecutive_failures || rate_tripped {
                    self.transition(tick, BreakerState::Open);
                    self.opened_at = tick;
                }
            }
            BreakerState::Open => {}
        }
    }

    fn push_outcome(&mut self, failed: bool) {
        self.window.push_back(failed);
        while self.window.len() > self.config.window {
            self.window.pop_front();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_breaker_never_trips() {
        let mut b = CircuitBreaker::new(BreakerConfig::disabled());
        for tick in 0..100 {
            assert!(b.allows(tick));
            b.on_failure(tick);
        }
        assert_eq!(b.state(), BreakerState::Closed);
        assert!(b.transitions().is_empty());
    }

    #[test]
    fn consecutive_failures_trip_then_cooldown_then_probe_recloses() {
        let mut b = CircuitBreaker::new(BreakerConfig::standard());
        // three consecutive failures at tick 40 trip the breaker
        for _ in 0..3 {
            assert!(b.allows(40));
            b.on_failure(40);
        }
        assert_eq!(b.state(), BreakerState::Open);
        // rejected until the cooldown has elapsed
        assert!(!b.allows(41));
        assert!(!b.allows(64));
        // tick 65 = 40 + 25: half-open, one probe allowed
        assert!(b.allows(65));
        assert_eq!(b.state(), BreakerState::HalfOpen);
        assert!(!b.allows(65), "probe budget is one");
        b.on_success(65);
        assert_eq!(b.state(), BreakerState::Closed);
        assert!(b.allows(66));
        let kinds: Vec<(BreakerState, BreakerState)> =
            b.transitions().iter().map(|t| (t.from, t.to)).collect();
        assert_eq!(
            kinds,
            vec![
                (BreakerState::Closed, BreakerState::Open),
                (BreakerState::Open, BreakerState::HalfOpen),
                (BreakerState::HalfOpen, BreakerState::Closed),
            ]
        );
        assert_eq!(b.transitions()[0].tick, 40);
        assert_eq!(b.transitions()[1].tick, 65);
    }

    #[test]
    fn failed_probe_reopens_and_restarts_cooldown() {
        let mut b = CircuitBreaker::new(BreakerConfig::standard());
        for _ in 0..3 {
            b.allows(0);
            b.on_failure(0);
        }
        assert!(b.allows(25), "cooldown elapsed at tick 25");
        b.on_failure(25);
        assert_eq!(b.state(), BreakerState::Open);
        assert!(!b.allows(49), "cooldown restarted from tick 25");
        assert!(b.allows(50));
        b.on_success(50);
        assert_eq!(b.state(), BreakerState::Closed);
    }

    #[test]
    fn interleaved_successes_reset_the_consecutive_count() {
        let mut b = CircuitBreaker::new(BreakerConfig::standard());
        for tick in 0..20 {
            b.allows(tick);
            if tick % 3 == 2 {
                b.on_success(tick);
            } else {
                b.on_failure(tick);
            }
        }
        // never three in a row, and 2/3 failure rate only counts once
        // the 16-wide window is full — it is, so the rate path trips
        assert_eq!(b.state(), BreakerState::Open, "rate threshold must trip");
    }

    #[test]
    fn failure_rate_trips_without_consecutive_runs() {
        // alternate success/failure: 50% rate, never 3 consecutive
        let mut b = CircuitBreaker::new(BreakerConfig::standard());
        for tick in 0..40 {
            if b.allows(tick) {
                if tick % 2 == 0 {
                    b.on_failure(tick);
                } else {
                    b.on_success(tick);
                }
            }
        }
        assert!(
            b.transitions().iter().any(|t| t.to == BreakerState::Open),
            "50% failure rate over a full window must trip"
        );
    }
}
