//! Seeded, deterministic fault plans.
//!
//! A [`FaultPlan`] decides — purely as a function of `(seed, request id,
//! backend, attempt)` — whether a dispatch attempt fails, runs slow, or
//! panics its worker. No wall clock, no shared state: the same seed and
//! profile reproduce the same fault sequence bit-identically on any
//! machine, worker count, or run. That is what the simulator buys us
//! over real hardware (Jia et al. document the exchange-fabric and
//! tile-memory failure surfaces; here they are *replayable*).
//!
//! Fault draws use a splitmix64-finalizer hash chain, not the stateful
//! `util::rng::Rng`: every `(id, backend, attempt)` coordinate is hashed
//! independently, so injecting a fault for request 40 never perturbs the
//! draw for request 41 — the property the shrinking harness
//! (`fault::chaos::shrink_failing`) relies on to remove requests from a
//! trace without changing the faults the survivors see.

/// Which simulated device a dispatch attempt targets.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// The IPU simulator (`Backend::IpuSim`).
    Ipu,
    /// The analytical GPU model (`Backend::GpuModel`).
    Gpu,
}

impl BackendKind {
    fn tag(self) -> u64 {
        match self {
            BackendKind::Ipu => 0x1F0,
            BackendKind::Gpu => 0x6F0,
        }
    }
}

/// The failure taxonomy the plan can inject.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// Transient IPU-sim fault: an exchange-fabric link dropped the
    /// spread phase mid-superstep. The attempt's device time is wasted;
    /// a retry may succeed.
    ExchangeLinkDrop,
    /// Transient IPU-sim fault: a tile ran out of SRAM under a racing
    /// co-tenant (distinct from the deterministic §2.4 memory wall,
    /// which is a *verdict*, not a fault). Wasted attempt; retryable.
    TileOomFlake,
    /// The device answered, but slower by the profile's `slow_factor`
    /// (congested exchange / downclocked device). Not a failure — the
    /// result is valid — but it can blow a deadline.
    SlowDevice,
    /// Hard unavailability window: the backend is down for a range of
    /// request ids. The attempt costs no device time and always fails.
    Unavailable,
    /// The batch worker panics mid-dispatch (poisoned lock territory).
    WorkerPanic,
}

impl FaultKind {
    pub fn name(self) -> &'static str {
        match self {
            FaultKind::ExchangeLinkDrop => "exchange-link-drop",
            FaultKind::TileOomFlake => "tile-oom-flake",
            FaultKind::SlowDevice => "slow-device",
            FaultKind::Unavailable => "unavailable",
            FaultKind::WorkerPanic => "worker-panic",
        }
    }

    /// Transient faults waste the attempt's device time and are worth
    /// retrying; `SlowDevice` is not a failure at all.
    pub fn is_transient(self) -> bool {
        matches!(self, FaultKind::ExchangeLinkDrop | FaultKind::TileOomFlake)
    }
}

/// Fault rates and windows, independent of the seed. Rates are permille
/// (0..=1000) so profiles stay exact integers — no float thresholds in
/// the determinism-critical path.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultProfile {
    /// Per-attempt probability (permille) of a transient IPU fault
    /// (exchange-link drop or tile-OOM flake, split evenly by a hash
    /// bit). IPU-sim only: the GPU model has no exchange fabric.
    pub transient_permille: u32,
    /// Per-attempt probability (permille) of a slow-device spike, on
    /// either backend. Evaluated after the transient band, so
    /// `transient + slow` must stay <= 1000.
    pub slow_permille: u32,
    /// Latency multiplier a slow-device spike applies.
    pub slow_factor: f64,
    /// Per-request probability (permille) that the batch worker panics
    /// while dispatching this request.
    pub panic_permille: u32,
    /// Hard IPU unavailability windows as `[start, end)` request-id
    /// ranges — deterministic by construction.
    pub ipu_outages: Vec<(u64, u64)>,
    /// Hard GPU-model unavailability windows, same convention.
    pub gpu_outages: Vec<(u64, u64)>,
}

impl FaultProfile {
    /// No faults at all.
    pub fn none() -> FaultProfile {
        FaultProfile {
            transient_permille: 0,
            slow_permille: 0,
            slow_factor: 1.0,
            panic_permille: 0,
            ipu_outages: Vec::new(),
            gpu_outages: Vec::new(),
        }
    }

    /// Transient IPU faults at `permille`/1000 per attempt.
    pub fn transient(permille: u32) -> FaultProfile {
        assert!(permille <= 1000, "permille rate out of range");
        FaultProfile { transient_permille: permille, ..FaultProfile::none() }
    }

    /// Slow-device spikes at `permille`/1000 per attempt, `factor`x.
    pub fn slow(permille: u32, factor: f64) -> FaultProfile {
        assert!(permille <= 1000 && factor >= 1.0, "bad slow profile");
        FaultProfile { slow_permille: permille, slow_factor: factor, ..FaultProfile::none() }
    }

    /// True when the profile can never inject anything.
    pub fn is_zero(&self) -> bool {
        self.transient_permille == 0
            && self.slow_permille == 0
            && self.panic_permille == 0
            && self.ipu_outages.is_empty()
            && self.gpu_outages.is_empty()
    }

    /// Named profiles for the CLI (`ipumm chaos --profiles ...`,
    /// `ipumm serve --fault-profile ...`).
    ///
    /// `breaker-trip` is deterministic *by construction*: a pure IPU
    /// outage over ids `[40, 60)` with no probabilistic faults, so under
    /// the standard policy (3 consecutive failures, 25-tick cooldown,
    /// one half-open probe) the IPU breaker opens at tick 40, half-opens
    /// at 65, and re-closes on the id-65 probe — exactly 25 requests
    /// degrade to the GPU, independent of the seed.
    pub fn by_name(name: &str) -> Option<FaultProfile> {
        Some(match name {
            "none" => FaultProfile::none(),
            "transient" => FaultProfile::transient(100),
            "transient-heavy" => FaultProfile::transient(250),
            "slow" => FaultProfile::slow(150, 1000.0),
            "breaker-trip" => {
                FaultProfile { ipu_outages: vec![(40, 60)], ..FaultProfile::none() }
            }
            "gpu-outage" => {
                FaultProfile { gpu_outages: vec![(30, 50)], ..FaultProfile::none() }
            }
            "panic" => FaultProfile { panic_permille: 30, ..FaultProfile::none() },
            "mixed" => FaultProfile {
                transient_permille: 100,
                slow_permille: 50,
                slow_factor: 200.0,
                panic_permille: 10,
                ipu_outages: vec![(60, 75)],
                gpu_outages: Vec::new(),
            },
            _ => return None,
        })
    }

    /// Every name [`Self::by_name`] accepts, for usage/error text.
    pub fn names() -> &'static [&'static str] {
        &[
            "none", "transient", "transient-heavy", "slow", "breaker-trip", "gpu-outage",
            "panic", "mixed",
        ]
    }
}

/// A seeded fault plan: profile + seed, queried per dispatch attempt.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultPlan {
    pub seed: u64,
    pub profile: FaultProfile,
}

const SALT_FAULT: u64 = 0xFA17;
const SALT_SPLIT: u64 = 0x5711;
const SALT_PANIC: u64 = 0xBAD;

fn splitmix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl FaultPlan {
    /// The identity plan: injects nothing, ever. With this plan the
    /// serve path is bit-identical to a fault-layer-free build (the
    /// repo's crown-jewel invariant, property-tested).
    pub fn none() -> FaultPlan {
        FaultPlan { seed: 0, profile: FaultProfile::none() }
    }

    pub fn seeded(seed: u64, profile: FaultProfile) -> FaultPlan {
        assert!(
            profile.transient_permille + profile.slow_permille <= 1000,
            "transient + slow permille bands overflow the draw"
        );
        FaultPlan { seed, profile }
    }

    /// True when this plan can inject at least one fault kind.
    pub fn is_active(&self) -> bool {
        !self.profile.is_zero()
    }

    fn draw(&self, id: u64, backend: BackendKind, attempt: u32, salt: u64) -> u64 {
        let mut h = splitmix64(self.seed ^ salt);
        h = splitmix64(h ^ id.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        h = splitmix64(h ^ backend.tag());
        splitmix64(h ^ attempt as u64)
    }

    fn in_window(windows: &[(u64, u64)], id: u64) -> bool {
        windows.iter().any(|&(start, end)| id >= start && id < end)
    }

    /// The fault (if any) this plan injects into one dispatch attempt.
    /// Pure: same `(id, backend, attempt)` always answers the same.
    /// Outage windows dominate the probabilistic bands — a down device
    /// is down regardless of what the dice say.
    pub fn inject(&self, id: u64, backend: BackendKind, attempt: u32) -> Option<FaultKind> {
        let p = &self.profile;
        let outages = match backend {
            BackendKind::Ipu => &p.ipu_outages,
            BackendKind::Gpu => &p.gpu_outages,
        };
        if Self::in_window(outages, id) {
            return Some(FaultKind::Unavailable);
        }
        if p.transient_permille == 0 && p.slow_permille == 0 {
            return None;
        }
        let roll = (self.draw(id, backend, attempt, SALT_FAULT) % 1000) as u32;
        match backend {
            BackendKind::Ipu => {
                if roll < p.transient_permille {
                    // split the transient band into the two concrete
                    // IPU failure modes by an independent hash bit
                    if self.draw(id, backend, attempt, SALT_SPLIT) & 1 == 0 {
                        Some(FaultKind::ExchangeLinkDrop)
                    } else {
                        Some(FaultKind::TileOomFlake)
                    }
                } else if roll < p.transient_permille + p.slow_permille {
                    Some(FaultKind::SlowDevice)
                } else {
                    None
                }
            }
            // the GPU model has no exchange fabric or tile SRAM: only
            // slow spikes and outage windows apply
            BackendKind::Gpu => (roll < p.slow_permille).then_some(FaultKind::SlowDevice),
        }
    }

    /// Whether the batch worker panics while dispatching request `id`.
    /// Keyed by id only (not attempt): the panic kills the dispatch
    /// before any retry machinery runs.
    pub fn injects_panic(&self, id: u64) -> bool {
        self.profile.panic_permille > 0
            && (self.draw(id, BackendKind::Ipu, 0, SALT_PANIC) % 1000) as u32
                < self.profile.panic_permille
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_plan_injects_nothing() {
        let plan = FaultPlan::none();
        assert!(!plan.is_active());
        for id in 0..500u64 {
            for attempt in 0..4 {
                assert_eq!(plan.inject(id, BackendKind::Ipu, attempt), None);
                assert_eq!(plan.inject(id, BackendKind::Gpu, attempt), None);
            }
            assert!(!plan.injects_panic(id));
        }
    }

    #[test]
    fn injections_are_a_pure_function_of_coordinates() {
        let plan = FaultPlan::seeded(42, FaultProfile::by_name("mixed").unwrap());
        let again = FaultPlan::seeded(42, FaultProfile::by_name("mixed").unwrap());
        for id in 0..300u64 {
            for attempt in 0..4 {
                for backend in [BackendKind::Ipu, BackendKind::Gpu] {
                    assert_eq!(
                        plan.inject(id, backend, attempt),
                        again.inject(id, backend, attempt),
                        "id {id} attempt {attempt} {backend:?}"
                    );
                }
            }
            assert_eq!(plan.injects_panic(id), again.injects_panic(id));
        }
    }

    #[test]
    fn different_seeds_draw_different_fault_sets() {
        let a = FaultPlan::seeded(1, FaultProfile::transient(250));
        let b = FaultPlan::seeded(2, FaultProfile::transient(250));
        let faults = |p: &FaultPlan| {
            (0..400u64)
                .filter(|&id| p.inject(id, BackendKind::Ipu, 0).is_some())
                .collect::<Vec<_>>()
        };
        assert_ne!(faults(&a), faults(&b), "seeds must decorrelate");
    }

    #[test]
    fn transient_rate_lands_near_the_configured_permille() {
        let plan = FaultPlan::seeded(7, FaultProfile::transient(250));
        let n = 4000u64;
        let hits = (0..n)
            .filter(|&id| {
                matches!(
                    plan.inject(id, BackendKind::Ipu, 0),
                    Some(k) if k.is_transient()
                )
            })
            .count();
        let rate = hits as f64 / n as f64;
        assert!((rate - 0.25).abs() < 0.03, "rate {rate} far from 250 permille");
        // transient faults never hit the GPU model
        assert!((0..n).all(|id| {
            !matches!(plan.inject(id, BackendKind::Gpu, 0), Some(k) if k.is_transient())
        }));
    }

    #[test]
    fn outage_windows_dominate_and_bound_exactly() {
        let plan = FaultPlan::seeded(
            3,
            FaultProfile { ipu_outages: vec![(40, 60)], ..FaultProfile::none() },
        );
        for id in 0..100u64 {
            let fault = plan.inject(id, BackendKind::Ipu, 2);
            if (40..60).contains(&id) {
                assert_eq!(fault, Some(FaultKind::Unavailable), "id {id}");
            } else {
                assert_eq!(fault, None, "id {id}");
            }
            assert_eq!(plan.inject(id, BackendKind::Gpu, 0), None, "GPU unaffected");
        }
    }

    #[test]
    fn attempts_draw_independently_so_retries_can_succeed() {
        // with a 50% transient rate, some faulted first attempts must
        // see a clean second attempt — otherwise retrying is pointless
        let plan = FaultPlan::seeded(11, FaultProfile::transient(500));
        let recovered = (0..200u64).any(|id| {
            plan.inject(id, BackendKind::Ipu, 0).is_some()
                && plan.inject(id, BackendKind::Ipu, 1).is_none()
        });
        assert!(recovered, "no faulted request recovers on attempt 1");
    }

    #[test]
    fn named_profiles_parse_and_unknown_names_do_not() {
        for name in FaultProfile::names() {
            assert!(FaultProfile::by_name(name).is_some(), "{name}");
        }
        assert!(FaultProfile::by_name("meteor-strike").is_none());
        assert!(FaultProfile::by_name("none").unwrap().is_zero());
        assert!(!FaultProfile::by_name("breaker-trip").unwrap().is_zero());
    }
}
