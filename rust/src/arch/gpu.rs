//! NVIDIA GPU architecture descriptions for the baseline models.
//!
//! The paper's Table 1 compares against an A30 (Ampere); the abstract also
//! references a Turing RTX 2080 Ti; related work cites the V100. All three
//! are provided so the comparison benches can reproduce either pairing.

/// Static description of one GPU.
#[derive(Clone, Debug)]
pub struct GpuArch {
    pub name: &'static str,
    pub sms: usize,
    /// FP32 CUDA lanes per SM (2 flops/lane/cycle via FMA).
    pub fp32_lanes_per_sm: usize,
    pub clock_hz: f64,
    pub dram_bytes: u64,
    pub dram_bw_bytes_per_s: f64,
    pub l2_bytes: u64,
    /// Max thread blocks resident per SM (occupancy ceiling for the
    /// cuBLAS-style 256-thread GEMM CTAs we model).
    pub max_ctas_per_sm: usize,
    pub power_w: f64,
    pub interchip_bw_bytes_per_s: f64,
}

impl GpuArch {
    /// NVIDIA A30 (paper Table 1): 56 SMs, 10.3 TFlop/s FP32, 933 GB/s.
    pub fn a30() -> GpuArch {
        GpuArch {
            name: "A30",
            sms: 56,
            fp32_lanes_per_sm: 64,
            clock_hz: 1.44e9,
            dram_bytes: 24 << 30,
            dram_bw_bytes_per_s: 933e9,
            l2_bytes: 24 << 20,
            max_ctas_per_sm: 2,
            power_w: 165.0,
            interchip_bw_bytes_per_s: 200e9, // NVLink (Table 1)
        }
    }

    /// RTX 2080 Ti (abstract's Turing-class card): 68 SMs, 13.4 TFlop/s.
    pub fn rtx2080ti() -> GpuArch {
        GpuArch {
            name: "RTX 2080 Ti",
            sms: 68,
            fp32_lanes_per_sm: 64,
            clock_hz: 1.545e9,
            dram_bytes: 11 << 30,
            dram_bw_bytes_per_s: 616e9,
            l2_bytes: 5632 << 10,
            max_ctas_per_sm: 2,
            power_w: 250.0,
            interchip_bw_bytes_per_s: 0.0,
        }
    }

    /// V100 (Jia et al.'s comparison: 15.7 TFlop/s FP32).
    pub fn v100() -> GpuArch {
        GpuArch {
            name: "V100",
            sms: 80,
            fp32_lanes_per_sm: 64,
            clock_hz: 1.53e9,
            dram_bytes: 32 << 30,
            dram_bw_bytes_per_s: 900e9,
            l2_bytes: 6 << 20,
            max_ctas_per_sm: 2,
            power_w: 300.0,
            interchip_bw_bytes_per_s: 300e9,
        }
    }

    pub fn by_name(name: &str) -> Option<GpuArch> {
        match name.to_ascii_lowercase().replace([' ', '-', '_'], "").as_str() {
            "a30" => Some(GpuArch::a30()),
            "rtx2080ti" | "2080ti" | "turing" => Some(GpuArch::rtx2080ti()),
            "v100" => Some(GpuArch::v100()),
            _ => None,
        }
    }

    /// CUDA core count (Table 1 "Number of cores").
    pub fn cuda_cores(&self) -> usize {
        self.sms * self.fp32_lanes_per_sm
    }

    /// Max resident threads (Table 1 "Number of threads": A30 229,376
    /// = 56 SMs x 2048 threads + pipeline slots; we report SMs x 2048 x 2
    /// matching the paper's counting of schedulable thread slots).
    pub fn total_thread_slots(&self) -> usize {
        self.sms * 2048 * 2
    }

    /// Theoretical FP32 peak, flops/s: SMs x lanes x 2 (FMA) x clock.
    pub fn peak_fp32_flops(&self) -> f64 {
        self.sms as f64 * self.fp32_lanes_per_sm as f64 * 2.0 * self.clock_hz
    }

    pub fn peak_fp32_tflops(&self) -> f64 {
        self.peak_fp32_flops() / 1e12
    }

    /// Machine-balance ridge point, flops per byte: shapes with lower
    /// arithmetic intensity are DRAM-bound.
    pub fn ridge_flops_per_byte(&self) -> f64 {
        self.peak_fp32_flops() / self.dram_bw_bytes_per_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn a30_peak_matches_table1() {
        let g = GpuArch::a30();
        // 56 x 64 x 2 x 1.44 GHz = 10.32 TF; Table 1: 10.3
        assert!((g.peak_fp32_tflops() - 10.3).abs() < 0.1, "{}", g.peak_fp32_tflops());
    }

    #[test]
    fn a30_core_count_matches_table1() {
        assert_eq!(GpuArch::a30().cuda_cores(), 3584);
    }

    #[test]
    fn a30_thread_slots_match_table1() {
        assert_eq!(GpuArch::a30().total_thread_slots(), 229_376);
    }

    #[test]
    fn rtx2080ti_peak() {
        let g = GpuArch::rtx2080ti();
        assert!((g.peak_fp32_tflops() - 13.4).abs() < 0.2);
    }

    #[test]
    fn v100_peak_matches_jia() {
        let g = GpuArch::v100();
        assert!((g.peak_fp32_tflops() - 15.7).abs() < 0.2);
    }

    #[test]
    fn ridge_point_is_compute_heavy() {
        // A30: 10.3e12 / 933e9 ~= 11 flops/byte
        let r = GpuArch::a30().ridge_flops_per_byte();
        assert!(r > 10.0 && r < 12.0, "{r}");
    }

    #[test]
    fn by_name_variants() {
        assert_eq!(GpuArch::by_name("A30").unwrap().name, "A30");
        assert_eq!(GpuArch::by_name("rtx-2080-ti").unwrap().name, "RTX 2080 Ti");
        assert_eq!(GpuArch::by_name("v100").unwrap().name, "V100");
        assert!(GpuArch::by_name("h100").is_none());
    }
}
