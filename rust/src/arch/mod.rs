//! Hardware descriptions: the IPUs under study and the GPU baselines
//! (paper Table 1), with derived quantities (theoretical peaks, SRAM
//! totals) computed from first principles so the calibration tests can
//! check them against the paper's figures.

pub mod gpu;
pub mod ipu;

pub use gpu::GpuArch;
pub use ipu::{IpuArch, IpuGeneration};
