//! Graphcore IPU architecture descriptions.
//!
//! Numbers come from the paper (Table 1), the M2000 datasheet, and Jia et
//! al. "Dissecting the Graphcore IPU architecture" (arXiv:1912.03413):
//!
//! * GC200 (Mk2, the paper's device): 1472 tiles x 6 threads, 624 KiB
//!   In-Processor memory per tile, 1.33 GHz, FP32 peak 62.5 TFlop/s
//!   => 16 FP32 AMP MACs (32 flops) per tile-cycle.
//! * GC2 (Mk1, prior work's device): 1216 tiles, 256 KiB/tile, 1.6 GHz,
//!   FP32 peak 31.1 TFlop/s => 8 FP32 MACs per tile-cycle.
//! * Bow-2000 (Mk2 wafer-on-wafer, released during the paper's work):
//!   GC200 layout at ~1.85 GHz.
//!
//! The paper's Table 1 quotes "918 MB" total SRAM for the GC200; Graphcore
//! documents 624 KiB x 1472 tiles = 897 MiB ~= 918e6 bytes plus exchange
//! scratch. We model per-tile capacity exactly and report totals in both
//! conventions.


#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IpuGeneration {
    Mk1,
    Mk2,
    Bow,
}

/// Static description of one IPU processor.
#[derive(Clone, Debug)]
pub struct IpuArch {
    pub name: &'static str,
    pub generation: IpuGeneration,
    pub tiles: usize,
    pub threads_per_tile: usize,
    /// In-Processor memory per tile, bytes.
    pub tile_sram_bytes: u64,
    pub clock_hz: f64,
    /// FP32 multiply-accumulates per tile per cycle through the AMP unit.
    pub fp32_macs_per_tile_cycle: u32,
    /// FP16(/mixed) MACs per tile per cycle (AMP fp16.16 mode).
    pub fp16_macs_per_tile_cycle: u32,
    /// Usable exchange bandwidth per tile, bytes per clock cycle. The GC200
    /// exchange moves 8 B/cycle/tile inbound (Jia et al. measure ~5.5
    /// effective under congestion); we model the ideal and apply a
    /// congestion factor in `exchange::fabric`.
    pub exchange_bytes_per_tile_cycle: f64,
    /// Cycles for a global cross-tile sync (BSP phase 2).
    pub sync_cycles: u64,
    /// Exchange-program code bytes per source row descriptor per superstep
    /// (calibration constant, DESIGN.md §5: fit so the max squared MM that
    /// compiles matches the measured 3584 on GC200 / 2944 on GC2 — the
    /// wider Mk2 exchange bus needs larger transfer descriptors).
    pub exchange_code_row_bytes: u64,
    /// Streaming (host/remote-buffer) memory attached to the IPU-Machine.
    pub streaming_bytes: u64,
    /// Host/streaming bandwidth, bytes/s (paper Table 1: 20 GB/s "DRAM").
    pub streaming_bw_bytes_per_s: f64,
    /// IPU-Link inter-chip bandwidth, bytes/s (Table 1: 350 GB/s).
    pub interchip_bw_bytes_per_s: f64,
    pub power_w: f64,
}

impl IpuArch {
    /// The paper's device: one GC200 of the M2000 IPU-Machine.
    pub fn gc200() -> IpuArch {
        IpuArch {
            name: "GC200",
            generation: IpuGeneration::Mk2,
            tiles: 1472,
            threads_per_tile: 6,
            tile_sram_bytes: 624 * 1024,
            clock_hz: 1.33e9,
            fp32_macs_per_tile_cycle: 16,
            fp16_macs_per_tile_cycle: 64,
            exchange_bytes_per_tile_cycle: 8.0,
            sync_cycles: 150,
            exchange_code_row_bytes: 28,
            streaming_bytes: 256 << 30, // 256 GB Streaming Memory (Table 1)
            streaming_bw_bytes_per_s: 20e9,
            interchip_bw_bytes_per_s: 350e9,
            power_w: 150.0,
        }
    }

    /// Prior work's device (Jia et al.): Mk1 GC2.
    pub fn gc2() -> IpuArch {
        IpuArch {
            name: "GC2",
            generation: IpuGeneration::Mk1,
            tiles: 1216,
            threads_per_tile: 6,
            tile_sram_bytes: 256 * 1024,
            clock_hz: 1.6e9,
            fp32_macs_per_tile_cycle: 8,
            fp16_macs_per_tile_cycle: 32,
            // Mk1 exchange is half the Mk2 port width, further derated:
            // calibrated so the max-square run lands on Jia et al.'s
            // measured 18.9 TFlop/s (60.7% of peak) at 2944^2
            exchange_bytes_per_tile_cycle: 2.0,
            sync_cycles: 150,
            exchange_code_row_bytes: 4,
            streaming_bytes: 0, // no streaming memory on the Mk1 PCIe card
            streaming_bw_bytes_per_s: 8e9,
            interchip_bw_bytes_per_s: 80e9,
            power_w: 120.0,
        }
    }

    /// Third generation (released during the paper's work, §2.1).
    pub fn bow2000() -> IpuArch {
        IpuArch {
            name: "Bow-2000",
            generation: IpuGeneration::Bow,
            clock_hz: 1.85e9,
            power_w: 165.0,
            ..IpuArch::gc200()
        }
    }

    pub fn by_name(name: &str) -> Option<IpuArch> {
        match name.to_ascii_lowercase().as_str() {
            "gc200" | "mk2" => Some(IpuArch::gc200()),
            "gc2" | "mk1" => Some(IpuArch::gc2()),
            "bow" | "bow2000" | "bow-2000" => Some(IpuArch::bow2000()),
            _ => None,
        }
    }

    /// Total In-Processor memory (bytes).
    pub fn total_sram_bytes(&self) -> u64 {
        self.tile_sram_bytes * self.tiles as u64
    }

    /// Theoretical FP32 peak, flops/s: tiles x clock x MACs x 2.
    pub fn peak_fp32_flops(&self) -> f64 {
        self.tiles as f64 * self.clock_hz * self.fp32_macs_per_tile_cycle as f64 * 2.0
    }

    /// Theoretical FP16 peak, flops/s.
    pub fn peak_fp16_flops(&self) -> f64 {
        self.tiles as f64 * self.clock_hz * self.fp16_macs_per_tile_cycle as f64 * 2.0
    }

    pub fn peak_fp32_tflops(&self) -> f64 {
        self.peak_fp32_flops() / 1e12
    }

    /// Total hardware threads (Table 1 row).
    pub fn total_threads(&self) -> usize {
        self.tiles * self.threads_per_tile
    }

    /// Aggregate ideal exchange bandwidth, bytes/s.
    pub fn aggregate_exchange_bw(&self) -> f64 {
        self.tiles as f64 * self.exchange_bytes_per_tile_cycle * self.clock_hz
    }

    /// Fingerprint of every plan-relevant parameter — the architecture
    /// half of the serving layer's plan-cache key (`serve::cache`). Two
    /// archs that would make the planner choose differently must not
    /// collide, so everything `planner::cost` reads is hashed; host-side
    /// attributes (streaming memory, power) are deliberately excluded.
    pub fn fingerprint(&self) -> u64 {
        use std::hash::{Hash, Hasher};
        let mut h = std::collections::hash_map::DefaultHasher::new();
        self.name.hash(&mut h);
        self.tiles.hash(&mut h);
        self.threads_per_tile.hash(&mut h);
        self.tile_sram_bytes.hash(&mut h);
        self.clock_hz.to_bits().hash(&mut h);
        self.fp32_macs_per_tile_cycle.hash(&mut h);
        self.fp16_macs_per_tile_cycle.hash(&mut h);
        self.exchange_bytes_per_tile_cycle.to_bits().hash(&mut h);
        self.sync_cycles.hash(&mut h);
        self.exchange_code_row_bytes.hash(&mut h);
        h.finish()
    }

    pub fn cycles_to_secs(&self, cycles: u64) -> f64 {
        cycles as f64 / self.clock_hz
    }

    pub fn secs_to_cycles(&self, secs: f64) -> u64 {
        (secs * self.clock_hz).round() as u64
    }
}

/// Sanity anchors used by tests and Table 1 printing.
pub mod paper {
    /// Paper Table 1 / §2.4 headline numbers for the GC200.
    pub const GC200_PEAK_TFLOPS: f64 = 62.5;
    pub const GC200_TOTAL_SRAM_MB: f64 = 918.0;
    pub const GC200_ACHIEVED_TFLOPS: f64 = 44.2;
    pub const GC200_MAX_SQUARE: usize = 3584;
    /// Jia et al. numbers for the GC2 (§2.4).
    pub const GC2_PEAK_TFLOPS: f64 = 31.1;
    pub const GC2_ACHIEVED_TFLOPS: f64 = 18.9;
    pub const GC2_MAX_SQUARE: usize = 2944;
    /// PopVision vertex censuses for left/squared/right skew (§5.1).
    pub const VERTICES_LEFT: usize = 5542;
    pub const VERTICES_SQUARED: usize = 5762;
    pub const VERTICES_RIGHT: usize = 31743;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gc200_peak_matches_paper() {
        let a = IpuArch::gc200();
        // 1472 * 1.33 GHz * 32 flops = 62.65 TF; paper rounds to 62.5
        assert!(
            (a.peak_fp32_tflops() - paper::GC200_PEAK_TFLOPS).abs() < 0.5,
            "derived {} vs paper {}",
            a.peak_fp32_tflops(),
            paper::GC200_PEAK_TFLOPS
        );
    }

    #[test]
    fn gc2_peak_matches_jia() {
        let a = IpuArch::gc2();
        assert!(
            (a.peak_fp32_tflops() - paper::GC2_PEAK_TFLOPS).abs() < 0.1,
            "derived {}",
            a.peak_fp32_tflops()
        );
    }

    #[test]
    fn gc200_sram_total_near_918mb() {
        let a = IpuArch::gc200();
        let mb = a.total_sram_bytes() as f64 / 1e6;
        // 624 KiB x 1472 = 940.6e6 B; paper says 918 MB, Graphcore says
        // ~900 MB — all within 3%
        assert!((mb - paper::GC200_TOTAL_SRAM_MB).abs() / paper::GC200_TOTAL_SRAM_MB < 0.03,
            "total {mb} MB");
    }

    #[test]
    fn thread_count_table1() {
        assert_eq!(IpuArch::gc200().total_threads(), 8832); // Table 1
    }

    #[test]
    fn bow_is_faster_gc200() {
        let bow = IpuArch::bow2000();
        let gc200 = IpuArch::gc200();
        assert_eq!(bow.tiles, gc200.tiles);
        assert!(bow.peak_fp32_flops() > gc200.peak_fp32_flops());
    }

    #[test]
    fn by_name_lookup() {
        assert_eq!(IpuArch::by_name("gc200").unwrap().name, "GC200");
        assert_eq!(IpuArch::by_name("GC2").unwrap().name, "GC2");
        assert_eq!(IpuArch::by_name("bow").unwrap().name, "Bow-2000");
        assert!(IpuArch::by_name("tpu").is_none());
    }

    #[test]
    fn cycle_time_roundtrip() {
        let a = IpuArch::gc200();
        let s = a.cycles_to_secs(a.secs_to_cycles(0.001));
        assert!((s - 0.001).abs() < 1e-9);
    }

    #[test]
    fn fp16_peak_is_4x_fp32_on_mk2() {
        let a = IpuArch::gc200();
        assert!((a.peak_fp16_flops() / a.peak_fp32_flops() - 4.0).abs() < 1e-9);
    }

    #[test]
    fn fingerprint_distinguishes_archs() {
        let gc200 = IpuArch::gc200();
        assert_eq!(gc200.fingerprint(), IpuArch::gc200().fingerprint());
        assert_ne!(gc200.fingerprint(), IpuArch::gc2().fingerprint());
        assert_ne!(gc200.fingerprint(), IpuArch::bow2000().fingerprint());
        // a plan-relevant tweak must change the fingerprint
        let mut derated = IpuArch::gc200();
        derated.tile_sram_bytes -= 1;
        assert_ne!(gc200.fingerprint(), derated.fingerprint());
        // host-side attributes must not
        let mut repowered = IpuArch::gc200();
        repowered.power_w += 50.0;
        assert_eq!(gc200.fingerprint(), repowered.fingerprint());
    }

    #[test]
    fn tile_sram_is_624kib() {
        assert_eq!(IpuArch::gc200().tile_sram_bytes, 624 * 1024);
        assert_eq!(IpuArch::gc2().tile_sram_bytes, 256 * 1024);
    }
}
