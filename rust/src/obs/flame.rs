//! Text flamegraph-style digest of recorded trace data.
//!
//! For terminals and CI logs where a Chrome trace viewer is not at hand:
//! spans aggregate per `(track, name)` with a proportional bar, counters
//! print sorted, histograms summarize with the tail percentiles, and a
//! final section reports what the recorder itself retained (spans,
//! bytes, sketch memory) so instrumentation cost is observable.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::util::units::fmt_bytes;

use super::recorder::{ClockDomain, TraceData};

const BAR_WIDTH: usize = 24;

fn bar(frac: f64) -> String {
    let n = ((frac * BAR_WIDTH as f64).round() as usize).clamp(1, BAR_WIDTH);
    "#".repeat(n)
}

/// Aggregate rows of one clock domain: `(track, name) -> (total, count)`,
/// rendered sorted by total duration, descending.
fn domain_section(
    out: &mut String,
    data: &TraceData,
    domain: ClockDomain,
    header: &str,
    fmt_total: impl Fn(u64) -> String,
) {
    let mut rows: BTreeMap<(&str, &str), (u64, usize)> = BTreeMap::new();
    for span in data.spans.iter().filter(|s| s.domain == domain && !s.instant) {
        let row = rows.entry((span.track.as_str(), span.name.as_str())).or_insert((0, 0));
        row.0 += span.dur;
        row.1 += 1;
    }
    if rows.is_empty() {
        return;
    }
    let _ = writeln!(out, "{header}");
    let mut sorted: Vec<_> = rows.into_iter().collect();
    sorted.sort_by(|a, b| b.1 .0.cmp(&a.1 .0).then(a.0.cmp(&b.0)));
    let max = sorted[0].1 .0.max(1);
    for ((track, name), (total, count)) in sorted {
        let _ = writeln!(
            out,
            "  {:>12} x{:<5} {:<24} {track} {name}",
            fmt_total(total),
            count,
            bar(total as f64 / max as f64),
        );
    }
}

/// Render the whole [`TraceData`] as a text summary.
pub fn flame_summary(data: &TraceData) -> String {
    let mut out = String::from("== trace summary ==\n");
    if data.is_empty() {
        out.push_str("(no trace data recorded)\n");
        return out;
    }
    domain_section(&mut out, data, ClockDomain::Wall, "wall-time spans:", |ns| {
        format!("{:.3} ms", ns as f64 / 1e6)
    });
    domain_section(&mut out, data, ClockDomain::Model, "model-time spans (cycles):", |cy| {
        format!("{cy} cy")
    });
    if !data.counters.is_empty() {
        out.push_str("counters:\n");
        for (name, value) in &data.counters {
            let _ = writeln!(out, "  {value:>12}  {name}");
        }
    }
    if !data.histograms.is_empty() {
        out.push_str("histograms:\n");
        for (name, sketch) in &data.histograms {
            if sketch.is_empty() {
                continue;
            }
            let s = sketch.summary();
            let _ = writeln!(
                out,
                "  {name}: n={} p50={:.3} p95={:.3} p99={:.3} p999={:.3} max={:.3}",
                s.n, s.median, s.p95, s.p99, s.p999, s.max
            );
        }
    }
    let o = data.overhead();
    out.push_str("recorder overhead:\n");
    let _ = writeln!(
        out,
        "  {} spans retained ({}), {} counters, {} histograms ({} samples folded into {} of sketches)",
        o.spans,
        fmt_bytes(o.span_bytes as u64),
        o.counters,
        o.histograms,
        o.histogram_samples,
        fmt_bytes(o.sketch_bytes as u64),
    );
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::recorder::Recorder;

    #[test]
    fn empty_summary_says_so() {
        assert!(flame_summary(&TraceData::default()).contains("no trace data"));
    }

    #[test]
    fn aggregates_and_orders_by_total() {
        let r = Recorder::new();
        r.model_span("bsp", "compute", "model", 0, 100, &[]);
        r.model_span("bsp", "compute", "model", 140, 100, &[]);
        r.model_span("bsp", "exchange", "model", 100, 40, &[]);
        r.count("planner.candidates", 1234);
        r.observe("latency_ms", 2.0);
        let text = flame_summary(&r.take());
        assert!(text.contains("model-time spans"));
        assert!(text.contains("200 cy"));
        assert!(text.contains("x2"));
        // compute (200 cy) sorts above exchange (40 cy)
        assert!(text.find("compute").unwrap() < text.find("exchange").unwrap());
        assert!(text.contains("1234"));
        assert!(text.contains("p999=2.000"));
        assert!(text.contains("recorder overhead:"));
        assert!(text.contains("3 spans retained"));
        assert!(text.contains("1 samples folded"));
    }
}
