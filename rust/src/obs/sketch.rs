//! Fixed-memory, mergeable quantile sketch for streaming latency
//! distributions.
//!
//! The recorder's histograms (and the serve workers' latency streams)
//! must not grow with the number of observations: a million-request
//! serve run buffering every sample in a `Vec<f64>` is exactly the
//! pathology this module removes. [`QuantileSketch`] is a DDSketch/HDR
//! style log-bucketed histogram:
//!
//! * **deterministic bucket boundaries** — bucket `i` covers
//!   `(γ^(i-1), γ^i]` with `γ = (1+α)/(1-α)` fixed at construction, so
//!   two sketches built anywhere (different workers, different runs)
//!   agree bucket-for-bucket and merge by adding counts;
//! * **bounded relative error** — a quantile estimate `q̂` of the exact
//!   nearest-rank sample `q` satisfies `|q̂ - q| <= α·q` (default
//!   α = [`DEFAULT_RELATIVE_ERROR`] = 1%), because the reported bucket
//!   midpoint `2γ^i/(γ+1)` is within α of every value in the bucket;
//! * **O(buckets) memory, not O(samples)** — the count array covers
//!   [`MIN_VALUE`], [`MAX_VALUE`] (1 ns .. ~31 years in seconds) in
//!   ~2100 fixed buckets (~17 KiB), independent of how many samples
//!   stream through ([`Self::memory_bytes`] is property-tested constant
//!   over a 100k+ stream in `tests/prop_invariants.rs`).
//!
//! Exact `count`/`sum`/`sum_sq`/`min`/`max` ride alongside the buckets,
//! so [`Self::summary`] reports exact mean/stddev/min/max and
//! sketch-estimated p50/p95/p99/p999 in the same
//! [`crate::util::stats::Summary`] shape the rest of the tree consumes.
//! Rank selection is nearest-rank (`ceil(q·n)`), matching
//! [`crate::util::stats::percentile_nearest`]; the rank-1 and rank-n
//! queries return the exact `min`/`max`, so tiny samples keep exact
//! tails.
//!
//! Values below [`MIN_VALUE`] (including zero and negatives) land in a
//! dedicated underflow bucket reported as `0.0` — an absolute error
//! bound of 1 ns instead of a relative one. Values above [`MAX_VALUE`]
//! clamp into the top bucket (`max` stays exact).

use crate::util::stats::Summary;

/// Default relative-error bound α for [`QuantileSketch::new`].
pub const DEFAULT_RELATIVE_ERROR: f64 = 0.01;

/// Smallest value with a relative-error guarantee (1 ns, in seconds).
pub const MIN_VALUE: f64 = 1e-9;

/// Largest value with a relative-error guarantee (~31 years, in seconds).
pub const MAX_VALUE: f64 = 1e9;

/// A mergeable, fixed-memory log-bucketed quantile sketch (see the
/// module docs for the guarantees).
#[derive(Clone, Debug, PartialEq)]
pub struct QuantileSketch {
    gamma: f64,
    inv_log_gamma: f64,
    min_index: i32,
    /// `counts[i]` counts samples in `(γ^(min_index+i-1), γ^(min_index+i)]`.
    counts: Vec<u64>,
    /// Samples below [`MIN_VALUE`] (zero, negative, sub-ns).
    zero_count: u64,
    count: u64,
    sum: f64,
    sum_sq: f64,
    min: f64,
    max: f64,
}

impl Default for QuantileSketch {
    fn default() -> QuantileSketch {
        QuantileSketch::new()
    }
}

impl QuantileSketch {
    /// A sketch with the default [`DEFAULT_RELATIVE_ERROR`] bound.
    pub fn new() -> QuantileSketch {
        QuantileSketch::with_relative_error(DEFAULT_RELATIVE_ERROR)
    }

    /// A sketch with relative-error bound `alpha` in (0, 1). Sketches
    /// merge only with sketches of the same `alpha`.
    pub fn with_relative_error(alpha: f64) -> QuantileSketch {
        assert!(alpha > 0.0 && alpha < 1.0, "relative error {alpha} out of (0,1)");
        let gamma = (1.0 + alpha) / (1.0 - alpha);
        let inv_log_gamma = 1.0 / gamma.ln();
        let min_index = (MIN_VALUE.ln() * inv_log_gamma).ceil() as i32;
        let max_index = (MAX_VALUE.ln() * inv_log_gamma).ceil() as i32;
        QuantileSketch {
            gamma,
            inv_log_gamma,
            min_index,
            counts: vec![0; (max_index - min_index + 1) as usize],
            zero_count: 0,
            count: 0,
            sum: 0.0,
            sum_sq: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Record one sample. Non-finite values are counted as `0.0`.
    pub fn observe(&mut self, value: f64) {
        let v = if value.is_finite() { value } else { 0.0 };
        self.count += 1;
        self.sum += v;
        self.sum_sq += v * v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
        if v < MIN_VALUE {
            self.zero_count += 1;
        } else {
            let top = self.min_index + self.counts.len() as i32 - 1;
            let idx =
                ((v.ln() * self.inv_log_gamma).ceil() as i32).clamp(self.min_index, top);
            self.counts[(idx - self.min_index) as usize] += 1;
        }
    }

    /// Merge another sketch's counts into this one (bucket-wise
    /// addition — the merged sketch is exactly the sketch of the
    /// concatenated sample streams). Panics if the configurations
    /// (relative error, bucket layout) differ.
    pub fn merge(&mut self, other: &QuantileSketch) {
        assert!(
            self.gamma == other.gamma
                && self.min_index == other.min_index
                && self.counts.len() == other.counts.len(),
            "cannot merge sketches with different configurations"
        );
        if other.count == 0 {
            return;
        }
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.zero_count += other.zero_count;
        self.count += other.count;
        self.sum += other.sum;
        self.sum_sq += other.sum_sq;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Nearest-rank quantile estimate for `q` in [0, 1]: the bucket
    /// midpoint holding the `ceil(q·n)`-th smallest sample, within the
    /// configured relative error of the exact order statistic (clamped
    /// into the observed `[min, max]`; ranks 1 and n are exact).
    pub fn quantile(&self, q: f64) -> f64 {
        assert!(self.count > 0, "quantile on empty sketch");
        assert!((0.0..=1.0).contains(&q), "quantile {q} out of [0,1]");
        let rank = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        if rank == 1 {
            return self.min;
        }
        if rank == self.count {
            return self.max;
        }
        let mut seen = self.zero_count;
        if rank <= seen {
            return 0.0_f64.max(self.min).min(self.max);
        }
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                let idx = self.min_index + i as i32;
                // bucket (γ^(idx-1), γ^idx]: the midpoint 2γ^idx/(γ+1)
                // is within α of every value the bucket can hold
                let rep = 2.0 * self.gamma.powi(idx) / (self.gamma + 1.0);
                return rep.max(self.min).min(self.max);
            }
        }
        self.max
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Exact number of samples observed.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Exact sum of all samples.
    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Exact mean (0 on an empty sketch).
    pub fn mean(&self) -> f64 {
        if self.count == 0 { 0.0 } else { self.sum / self.count as f64 }
    }

    /// Exact smallest sample. Panics on an empty sketch.
    pub fn min(&self) -> f64 {
        assert!(self.count > 0, "min on empty sketch");
        self.min
    }

    /// Exact largest sample. Panics on an empty sketch.
    pub fn max(&self) -> f64 {
        assert!(self.count > 0, "max on empty sketch");
        self.max
    }

    /// The configured relative-error bound α.
    pub fn relative_error(&self) -> f64 {
        (self.gamma - 1.0) / (self.gamma + 1.0)
    }

    /// Number of buckets (fixed at construction).
    pub fn buckets(&self) -> usize {
        self.counts.len()
    }

    /// Retained bytes — a function of the bucket count only, never of
    /// how many samples were observed.
    pub fn memory_bytes(&self) -> usize {
        std::mem::size_of::<QuantileSketch>()
            + self.counts.len() * std::mem::size_of::<u64>()
    }

    /// [`Summary`]-shaped readout: exact n/mean/stddev/min/max, sketch
    /// p50/p95/p99/p999 (each within α of the exact nearest-rank
    /// value). Panics on an empty sketch, like `Summary::of` on an
    /// empty slice.
    pub fn summary(&self) -> Summary {
        assert!(self.count > 0, "summary of empty sketch");
        let n = self.count as f64;
        let var = if self.count > 1 {
            ((self.sum_sq - self.sum * self.sum / n) / (n - 1.0)).max(0.0)
        } else {
            0.0
        };
        Summary {
            n: self.count as usize,
            mean: self.sum / n,
            stddev: var.sqrt(),
            min: self.min,
            max: self.max,
            median: self.quantile(0.5),
            p95: self.quantile(0.95),
            p99: self.quantile(0.99),
            p999: self.quantile(0.999),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::util::stats::percentile_nearest;

    #[test]
    fn empty_and_single_sample() {
        let mut s = QuantileSketch::new();
        assert!(s.is_empty());
        s.observe(7.0e-3);
        assert_eq!(s.count(), 1);
        assert_eq!(s.quantile(0.5), 7.0e-3, "rank 1 == rank n == exact");
        let sum = s.summary();
        assert_eq!(sum.n, 1);
        assert_eq!(sum.p999, 7.0e-3);
        assert_eq!(sum.stddev, 0.0);
    }

    #[test]
    fn constant_stream_is_exact_at_every_quantile() {
        let mut s = QuantileSketch::new();
        for _ in 0..10_000 {
            s.observe(5.0e-3);
        }
        for q in [0.0, 0.5, 0.95, 0.99, 0.999, 1.0] {
            // min/max clamp pins every estimate to the one observed value
            assert_eq!(s.quantile(q), 5.0e-3, "q={q}");
        }
        assert!((s.mean() - 5.0e-3).abs() < 1e-15);
    }

    #[test]
    fn quantiles_within_documented_relative_error() {
        let mut rng = Rng::new(7);
        let mut s = QuantileSketch::new();
        let mut exact = Vec::new();
        for _ in 0..20_000 {
            // log-uniform over ~6 decades: microseconds to tens of seconds
            let v = 1e-6 * 10f64.powf(7.0 * rng.next_f64());
            s.observe(v);
            exact.push(v);
        }
        exact.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let alpha = s.relative_error();
        for q in [0.5, 0.9, 0.95, 0.99, 0.999] {
            let truth = percentile_nearest(&exact, q * 100.0);
            let est = s.quantile(q);
            // small slack over α for bucket-boundary float rounding
            assert!(
                (est - truth).abs() <= truth * alpha * 1.05 + 1e-12,
                "q={q}: est {est} vs exact {truth} (α={alpha})"
            );
        }
    }

    #[test]
    fn merge_equals_single_sketch_over_concatenated_stream() {
        let mut rng = Rng::new(11);
        let mut whole = QuantileSketch::new();
        let mut parts: Vec<QuantileSketch> = (0..4).map(|_| QuantileSketch::new()).collect();
        for i in 0..8_000 {
            let v = 1e-4 * (1.0 + rng.next_f64());
            whole.observe(v);
            parts[i % 4].observe(v);
        }
        let mut merged = QuantileSketch::new();
        for p in &parts {
            merged.merge(p);
        }
        // bucket counts are integers: the merge is exactly the whole-run
        // sketch, not merely close to it
        assert_eq!(merged, whole);
        assert_eq!(merged.count(), 8_000);
    }

    #[test]
    fn merge_into_empty_adopts_min_max() {
        let mut a = QuantileSketch::new();
        let mut b = QuantileSketch::new();
        b.observe(1.0);
        b.observe(3.0);
        a.merge(&b);
        assert_eq!(a.min(), 1.0);
        assert_eq!(a.max(), 3.0);
        assert_eq!(a.count(), 2);
    }

    #[test]
    #[should_panic(expected = "different configurations")]
    fn merge_rejects_config_mismatch() {
        let mut a = QuantileSketch::new();
        let b = QuantileSketch::with_relative_error(0.05);
        a.merge(&b);
    }

    #[test]
    fn memory_is_constant_in_sample_count() {
        let mut s = QuantileSketch::new();
        let before = s.memory_bytes();
        for i in 0..50_000 {
            s.observe(1e-6 * (i + 1) as f64);
        }
        assert_eq!(s.memory_bytes(), before, "memory must be O(buckets)");
        assert!(before < 64 * 1024, "sketch should stay under 64 KiB, got {before}");
        assert_eq!(s.buckets(), QuantileSketch::new().buckets());
    }

    #[test]
    fn underflow_bucket_reports_zero() {
        let mut s = QuantileSketch::new();
        for _ in 0..10 {
            s.observe(0.0);
        }
        s.observe(1e-12); // sub-ns: no relative guarantee, 1 ns absolute
        assert_eq!(s.quantile(0.5), 0.0);
        assert_eq!(s.max(), 1e-12, "exact max survives the underflow bucket");
    }

    #[test]
    fn summary_mean_and_stddev_are_exact() {
        let samples = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut s = QuantileSketch::new();
        for &v in &samples {
            s.observe(v);
        }
        let exact = Summary::of(&samples);
        let sk = s.summary();
        assert!((sk.mean - exact.mean).abs() < 1e-12);
        assert!((sk.stddev - exact.stddev).abs() < 1e-9);
        assert_eq!(sk.min, exact.min);
        assert_eq!(sk.max, exact.max);
        assert_eq!(sk.n, exact.n);
    }

    #[test]
    fn bimodal_stream_resolves_both_modes() {
        let mut s = QuantileSketch::new();
        for i in 0..1000 {
            s.observe(if i % 10 == 9 { 0.1 } else { 0.001 });
        }
        // 90% fast mode, 10% slow mode: p50 sits on the fast mode,
        // p95/p99 on the slow one
        assert!((s.quantile(0.5) - 0.001).abs() <= 0.001 * 0.011);
        assert!((s.quantile(0.95) - 0.1).abs() <= 0.1 * 0.011);
        assert!((s.quantile(0.99) - 0.1).abs() <= 0.1 * 0.011);
    }
}
