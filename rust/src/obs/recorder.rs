//! The span / counter / histogram recorder behind [`crate::obs`].
//!
//! One mutex around an append-only [`TraceData`]; recording sites hold it
//! only long enough to push a record. The wall-time epoch is re-anchored
//! on [`Recorder::reset`] so exported timestamps start near zero.

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Instant;

/// Which clock a span's `start`/`dur` are measured on.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum ClockDomain {
    /// Real time: nanoseconds since the recorder epoch.
    Wall,
    /// Simulated time: BSP engine cycles.
    Model,
}

/// One recorded span (or instant event, when `dur == 0 && instant`).
#[derive(Clone, Debug)]
pub struct SpanRecord {
    pub domain: ClockDomain,
    /// Display track — one row in the Chrome timeline (e.g.
    /// `serve/worker-0`, `planner/w1`, `bsp/superstep`).
    pub track: String,
    pub name: String,
    /// Chrome trace-event category (filterable in the viewer).
    pub cat: &'static str,
    /// Wall: ns since epoch. Model: start cycle.
    pub start: u64,
    /// Wall: ns. Model: cycles.
    pub dur: u64,
    pub args: Vec<(&'static str, String)>,
    pub instant: bool,
}

/// Everything one tracing session collected.
#[derive(Clone, Debug, Default)]
pub struct TraceData {
    pub spans: Vec<SpanRecord>,
    pub counters: BTreeMap<String, u64>,
    pub histograms: BTreeMap<String, Vec<f64>>,
}

impl TraceData {
    pub fn is_empty(&self) -> bool {
        self.spans.is_empty() && self.counters.is_empty() && self.histograms.is_empty()
    }

    /// Number of spans on one clock domain (acceptance checks).
    pub fn span_count(&self, domain: ClockDomain) -> usize {
        self.spans.iter().filter(|s| s.domain == domain).count()
    }
}

struct Inner {
    epoch: Instant,
    data: TraceData,
}

/// A span/counter recorder. The process-wide instance lives behind
/// [`crate::obs::enable`]; tests construct their own for isolation.
pub struct Recorder {
    inner: Mutex<Inner>,
}

impl Default for Recorder {
    fn default() -> Recorder {
        Recorder::new()
    }
}

impl Recorder {
    pub fn new() -> Recorder {
        Recorder {
            inner: Mutex::new(Inner { epoch: Instant::now(), data: TraceData::default() }),
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Inner> {
        // a poisoned recorder mutex only ever means a panicking test
        // thread; the data is append-only so it is still coherent
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Clear all data and re-anchor the wall-time epoch.
    pub fn reset(&self) {
        let mut g = self.lock();
        g.epoch = Instant::now();
        g.data = TraceData::default();
    }

    /// Drain the collected data, leaving the recorder empty.
    pub fn take(&self) -> TraceData {
        std::mem::take(&mut self.lock().data)
    }

    /// Record a wall-time span that started at `started` and ends now.
    pub fn wall_span_since(
        &self,
        started: Instant,
        track: &str,
        name: &str,
        cat: &'static str,
        args: &[(&'static str, String)],
    ) {
        let dur = started.elapsed().as_nanos() as u64;
        let mut g = self.lock();
        let start = started.saturating_duration_since(g.epoch).as_nanos() as u64;
        g.data.spans.push(SpanRecord {
            domain: ClockDomain::Wall,
            track: track.to_string(),
            name: name.to_string(),
            cat,
            start,
            dur,
            args: args.to_vec(),
            instant: false,
        });
    }

    /// Record a model-time span (simulated cycles).
    pub fn model_span(
        &self,
        track: &str,
        name: &str,
        cat: &'static str,
        start_cycles: u64,
        dur_cycles: u64,
        args: &[(&'static str, String)],
    ) {
        self.lock().data.spans.push(SpanRecord {
            domain: ClockDomain::Model,
            track: track.to_string(),
            name: name.to_string(),
            cat,
            start: start_cycles,
            dur: dur_cycles,
            args: args.to_vec(),
            instant: false,
        });
    }

    /// Record a wall-time instant event at "now".
    pub fn event(&self, track: &str, name: &str, cat: &'static str, args: &[(&'static str, String)]) {
        let at = Instant::now();
        let mut g = self.lock();
        let start = at.saturating_duration_since(g.epoch).as_nanos() as u64;
        g.data.spans.push(SpanRecord {
            domain: ClockDomain::Wall,
            track: track.to_string(),
            name: name.to_string(),
            cat,
            start,
            dur: 0,
            args: args.to_vec(),
            instant: true,
        });
    }

    pub fn count(&self, name: &str, delta: u64) {
        *self.lock().data.counters.entry(name.to_string()).or_insert(0) += delta;
    }

    pub fn observe(&self, name: &str, value: f64) {
        self.lock().data.histograms.entry(name.to_string()).or_default().push(value);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_both_domains() {
        let r = Recorder::new();
        let t0 = Instant::now();
        r.model_span("bsp", "compute s0", "model", 0, 120, &[("tiles", "8".to_string())]);
        r.wall_span_since(t0, "planner/w0", "search", "planner", &[]);
        r.event("planner/w0", "incumbent", "planner", &[]);
        let data = r.take();
        assert_eq!(data.spans.len(), 3);
        assert_eq!(data.span_count(ClockDomain::Model), 1);
        assert_eq!(data.span_count(ClockDomain::Wall), 2);
        let model = &data.spans[0];
        assert_eq!(model.start, 0);
        assert_eq!(model.dur, 120);
        assert_eq!(model.args, vec![("tiles", "8".to_string())]);
        assert!(data.spans[2].instant);
        // drained
        assert!(r.take().is_empty());
    }

    #[test]
    fn counters_accumulate_and_histograms_append() {
        let r = Recorder::new();
        r.count("cache.hits", 2);
        r.count("cache.hits", 3);
        r.observe("queue_wait_ms", 1.5);
        r.observe("queue_wait_ms", 2.5);
        let data = r.take();
        assert_eq!(data.counters["cache.hits"], 5);
        assert_eq!(data.histograms["queue_wait_ms"], vec![1.5, 2.5]);
    }

    #[test]
    fn reset_clears_and_reanchors() {
        let r = Recorder::new();
        r.count("x", 1);
        r.reset();
        assert!(r.take().is_empty());
    }

    #[test]
    fn wall_span_started_before_epoch_saturates() {
        // enable() re-anchors the epoch; a span handle captured just
        // before must clamp to 0, not panic or wrap
        let t0 = Instant::now();
        let r = Recorder::new();
        r.wall_span_since(t0, "t", "n", "c", &[]);
        let data = r.take();
        assert_eq!(data.spans[0].start, 0);
    }
}
