//! The span / counter / histogram recorder behind [`crate::obs`].
//!
//! One mutex around an append-only [`TraceData`]; recording sites hold it
//! only long enough to push a record. The wall-time epoch is re-anchored
//! on [`Recorder::reset`] so exported timestamps start near zero.

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Instant;

use super::sketch::QuantileSketch;

/// Which clock a span's `start`/`dur` are measured on.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum ClockDomain {
    /// Real time: nanoseconds since the recorder epoch.
    Wall,
    /// Simulated time: BSP engine cycles.
    Model,
}

/// One recorded span (or instant event, when `dur == 0 && instant`).
#[derive(Clone, Debug)]
pub struct SpanRecord {
    pub domain: ClockDomain,
    /// Display track — one row in the Chrome timeline (e.g.
    /// `serve/worker-0`, `planner/w1`, `bsp/superstep`).
    pub track: String,
    pub name: String,
    /// Chrome trace-event category (filterable in the viewer).
    pub cat: &'static str,
    /// Wall: ns since epoch. Model: start cycle.
    pub start: u64,
    /// Wall: ns. Model: cycles.
    pub dur: u64,
    pub args: Vec<(&'static str, String)>,
    pub instant: bool,
}

/// Everything one tracing session collected.
///
/// Histograms are [`QuantileSketch`]es, not raw sample vectors: memory
/// per histogram is O(buckets) regardless of how many values a run
/// observes (the O(samples) `Vec<f64>` this replaced made million-
/// request serve runs retain every latency forever).
#[derive(Clone, Debug, Default)]
pub struct TraceData {
    pub spans: Vec<SpanRecord>,
    pub counters: BTreeMap<String, u64>,
    pub histograms: BTreeMap<String, QuantileSketch>,
}

impl TraceData {
    pub fn is_empty(&self) -> bool {
        self.spans.is_empty() && self.counters.is_empty() && self.histograms.is_empty()
    }

    /// Number of spans on one clock domain (acceptance checks).
    pub fn span_count(&self, domain: ClockDomain) -> usize {
        self.spans.iter().filter(|s| s.domain == domain).count()
    }

    /// What the instrumentation itself cost this session — so the
    /// recorder's overhead is observable like everything else
    /// (`ipumm profile` and the flame digest print it).
    pub fn overhead(&self) -> RecorderOverhead {
        let span_bytes: usize = self
            .spans
            .iter()
            .map(|s| {
                std::mem::size_of::<SpanRecord>()
                    + s.track.len()
                    + s.name.len()
                    + s.args.iter().map(|(_, v)| v.len()).sum::<usize>()
            })
            .sum();
        RecorderOverhead {
            spans: self.spans.len(),
            counters: self.counters.len(),
            histograms: self.histograms.len(),
            span_bytes,
            sketch_bytes: self.histograms.values().map(|s| s.memory_bytes()).sum(),
            histogram_samples: self.histograms.values().map(|s| s.count()).sum(),
        }
    }
}

/// Self-measurement of the recorder: how much it retained and what that
/// retention costs in bytes. `sketch_bytes` stays flat as
/// `histogram_samples` grows — the bounded-memory guarantee.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RecorderOverhead {
    pub spans: usize,
    pub counters: usize,
    pub histograms: usize,
    /// Approximate heap retained by span records (struct + owned strings).
    pub span_bytes: usize,
    /// Heap retained by all histogram sketches.
    pub sketch_bytes: usize,
    /// Total samples folded into histograms (not retained individually).
    pub histogram_samples: u64,
}

struct Inner {
    epoch: Instant,
    data: TraceData,
}

/// A span/counter recorder. The process-wide instance lives behind
/// [`crate::obs::enable`]; tests construct their own for isolation.
pub struct Recorder {
    inner: Mutex<Inner>,
}

impl Default for Recorder {
    fn default() -> Recorder {
        Recorder::new()
    }
}

impl Recorder {
    pub fn new() -> Recorder {
        Recorder {
            inner: Mutex::new(Inner { epoch: Instant::now(), data: TraceData::default() }),
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Inner> {
        // a poisoned recorder mutex only ever means a panicking test
        // thread; the data is append-only so it is still coherent
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Clear all data and re-anchor the wall-time epoch.
    pub fn reset(&self) {
        let mut g = self.lock();
        g.epoch = Instant::now();
        g.data = TraceData::default();
    }

    /// Drain the collected data, leaving the recorder empty.
    pub fn take(&self) -> TraceData {
        std::mem::take(&mut self.lock().data)
    }

    /// Record a wall-time span that started at `started` and ends now.
    pub fn wall_span_since(
        &self,
        started: Instant,
        track: &str,
        name: &str,
        cat: &'static str,
        args: &[(&'static str, String)],
    ) {
        let dur = started.elapsed().as_nanos() as u64;
        let mut g = self.lock();
        let start = started.saturating_duration_since(g.epoch).as_nanos() as u64;
        g.data.spans.push(SpanRecord {
            domain: ClockDomain::Wall,
            track: track.to_string(),
            name: name.to_string(),
            cat,
            start,
            dur,
            args: args.to_vec(),
            instant: false,
        });
    }

    /// Record a model-time span (simulated cycles).
    pub fn model_span(
        &self,
        track: &str,
        name: &str,
        cat: &'static str,
        start_cycles: u64,
        dur_cycles: u64,
        args: &[(&'static str, String)],
    ) {
        self.lock().data.spans.push(SpanRecord {
            domain: ClockDomain::Model,
            track: track.to_string(),
            name: name.to_string(),
            cat,
            start: start_cycles,
            dur: dur_cycles,
            args: args.to_vec(),
            instant: false,
        });
    }

    /// Record a wall-time instant event at "now".
    pub fn event(&self, track: &str, name: &str, cat: &'static str, args: &[(&'static str, String)]) {
        let at = Instant::now();
        let mut g = self.lock();
        let start = at.saturating_duration_since(g.epoch).as_nanos() as u64;
        g.data.spans.push(SpanRecord {
            domain: ClockDomain::Wall,
            track: track.to_string(),
            name: name.to_string(),
            cat,
            start,
            dur: 0,
            args: args.to_vec(),
            instant: true,
        });
    }

    pub fn count(&self, name: &str, delta: u64) {
        *self.lock().data.counters.entry(name.to_string()).or_insert(0) += delta;
    }

    pub fn observe(&self, name: &str, value: f64) {
        self.lock()
            .data
            .histograms
            .entry(name.to_string())
            .or_insert_with(QuantileSketch::new)
            .observe(value);
    }

    /// Fold a locally-built sketch into a named histogram in one lock
    /// acquisition — the sharded-worker path: each serve worker
    /// aggregates into a thread-local sketch and merges once at exit
    /// instead of taking the recorder lock per sample.
    pub fn merge_sketch(&self, name: &str, sketch: &QuantileSketch) {
        if sketch.is_empty() {
            return;
        }
        self.lock()
            .data
            .histograms
            .entry(name.to_string())
            .or_insert_with(QuantileSketch::new)
            .merge(sketch);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_both_domains() {
        let r = Recorder::new();
        let t0 = Instant::now();
        r.model_span("bsp", "compute s0", "model", 0, 120, &[("tiles", "8".to_string())]);
        r.wall_span_since(t0, "planner/w0", "search", "planner", &[]);
        r.event("planner/w0", "incumbent", "planner", &[]);
        let data = r.take();
        assert_eq!(data.spans.len(), 3);
        assert_eq!(data.span_count(ClockDomain::Model), 1);
        assert_eq!(data.span_count(ClockDomain::Wall), 2);
        let model = &data.spans[0];
        assert_eq!(model.start, 0);
        assert_eq!(model.dur, 120);
        assert_eq!(model.args, vec![("tiles", "8".to_string())]);
        assert!(data.spans[2].instant);
        // drained
        assert!(r.take().is_empty());
    }

    #[test]
    fn counters_accumulate_and_histograms_fold_into_sketches() {
        let r = Recorder::new();
        r.count("cache.hits", 2);
        r.count("cache.hits", 3);
        r.observe("queue_wait_ms", 1.5);
        r.observe("queue_wait_ms", 2.5);
        let data = r.take();
        assert_eq!(data.counters["cache.hits"], 5);
        let h = &data.histograms["queue_wait_ms"];
        assert_eq!(h.count(), 2);
        assert_eq!(h.sum(), 4.0);
        assert_eq!(h.min(), 1.5);
        assert_eq!(h.max(), 2.5);
    }

    #[test]
    fn merge_sketch_equals_per_sample_observe() {
        let direct = Recorder::new();
        let merged = Recorder::new();
        let mut local = QuantileSketch::new();
        for i in 0..100 {
            let v = 1e-3 * (i + 1) as f64;
            direct.observe("lat", v);
            local.observe(v);
        }
        merged.merge_sketch("lat", &local);
        merged.merge_sketch("lat", &QuantileSketch::new()); // empty: no-op
        let a = direct.take();
        let b = merged.take();
        assert_eq!(a.histograms["lat"], b.histograms["lat"]);
    }

    #[test]
    fn overhead_reports_retention() {
        let r = Recorder::new();
        r.model_span("bsp", "compute", "model", 0, 10, &[("tiles", "8".to_string())]);
        r.count("c", 1);
        for i in 0..1000 {
            r.observe("lat", 1e-3 * (i + 1) as f64);
        }
        let data = r.take();
        let o = data.overhead();
        assert_eq!(o.spans, 1);
        assert_eq!(o.counters, 1);
        assert_eq!(o.histograms, 1);
        assert_eq!(o.histogram_samples, 1000);
        assert!(o.span_bytes > 0);
        assert_eq!(o.sketch_bytes, data.histograms["lat"].memory_bytes());
    }

    #[test]
    fn reset_clears_and_reanchors() {
        let r = Recorder::new();
        r.count("x", 1);
        r.reset();
        assert!(r.take().is_empty());
    }

    #[test]
    fn wall_span_started_before_epoch_saturates() {
        // enable() re-anchors the epoch; a span handle captured just
        // before must clamp to 0, not panic or wrap
        let t0 = Instant::now();
        let r = Recorder::new();
        r.wall_span_since(t0, "t", "n", "c", &[]);
        let data = r.take();
        assert_eq!(data.spans[0].start, 0);
    }
}
