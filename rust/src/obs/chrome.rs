//! Chrome trace-event JSON export.
//!
//! Emits the object form (`{"traceEvents": [...]}`) understood by
//! `chrome://tracing` and Perfetto: `ph:"X"` complete events with
//! microsecond `ts`/`dur`, `ph:"i"` instants, and `ph:"M"` metadata
//! naming processes and threads. The two clock domains render as two
//! processes — wall-time tracks under pid 1 (ns scaled to µs) and
//! model-time tracks under pid 2 (1 simulated cycle drawn as 1 µs, so
//! superstep proportions survive the viewer's unit assumptions). Extra
//! top-level keys (`counters`, `histograms`) carry the registry; trace
//! viewers ignore unknown keys, and `ipumm` itself round-trips the file
//! through [`Json::parse`] in the CI smoke step.
//!
//! Export is deterministic given the recorded data: tracks are numbered
//! in sorted order and [`Json`] objects render with sorted keys.

use std::collections::BTreeMap;

use crate::util::json::Json;

use super::recorder::{ClockDomain, TraceData};

const WALL_PID: i64 = 1;
const MODEL_PID: i64 = 2;

fn pid_of(domain: ClockDomain) -> i64 {
    match domain {
        ClockDomain::Wall => WALL_PID,
        ClockDomain::Model => MODEL_PID,
    }
}

fn meta_event(pid: i64, tid: i64, what: &str, name: &str) -> Json {
    let mut ev = Json::obj();
    ev.set("ph", "M".into());
    ev.set("pid", pid.into());
    ev.set("tid", tid.into());
    ev.set("name", what.into());
    let mut args = Json::obj();
    args.set("name", name.into());
    ev.set("args", args);
    ev
}

/// Render recorded trace data as a Chrome trace-event document.
pub fn chrome_trace_json(data: &TraceData) -> Json {
    let mut events = Json::Arr(Vec::new());
    events.push(meta_event(WALL_PID, 0, "process_name", "wall time"));
    events.push(meta_event(MODEL_PID, 0, "process_name", "model time (cycles)"));

    // deterministic track -> tid numbering: sorted distinct (domain,
    // track) keys, numbered 1.. within each domain
    let mut tids: BTreeMap<(ClockDomain, &str), i64> = data
        .spans
        .iter()
        .map(|s| ((s.domain, s.track.as_str()), 0))
        .collect();
    let mut per_domain: BTreeMap<ClockDomain, i64> = BTreeMap::new();
    let keys: Vec<(ClockDomain, &str)> = tids.keys().copied().collect();
    for key in keys {
        let n = per_domain.entry(key.0).or_insert(0);
        *n += 1;
        tids.insert(key, *n);
        events.push(meta_event(pid_of(key.0), *n, "thread_name", key.1));
    }

    for span in &data.spans {
        let tid = tids[&(span.domain, span.track.as_str())];
        // wall ns -> µs; model cycles drawn 1:1 as µs
        let (ts, dur) = match span.domain {
            ClockDomain::Wall => (span.start as f64 / 1000.0, span.dur as f64 / 1000.0),
            ClockDomain::Model => (span.start as f64, span.dur as f64),
        };
        let mut ev = Json::obj();
        ev.set("name", span.name.as_str().into());
        ev.set("cat", span.cat.into());
        ev.set("pid", pid_of(span.domain).into());
        ev.set("tid", tid.into());
        ev.set("ts", ts.into());
        if span.instant {
            ev.set("ph", "i".into());
            ev.set("s", "t".into()); // thread-scoped instant
        } else {
            ev.set("ph", "X".into());
            ev.set("dur", dur.into());
        }
        if !span.args.is_empty() {
            let mut args = Json::obj();
            for (k, v) in &span.args {
                args.set(k, v.as_str().into());
            }
            ev.set("args", args);
        }
        events.push(ev);
    }

    let mut doc = Json::obj();
    doc.set("traceEvents", events);
    doc.set("displayTimeUnit", "ms".into());

    let mut counters = Json::obj();
    for (name, value) in &data.counters {
        counters.set(name, (*value).into());
    }
    doc.set("counters", counters);

    let mut hists = Json::obj();
    for (name, sketch) in &data.histograms {
        if sketch.is_empty() {
            continue;
        }
        let s = sketch.summary();
        let mut h = Json::obj();
        h.set("n", s.n.into());
        h.set("mean", s.mean.into());
        h.set("min", s.min.into());
        h.set("p50", s.median.into());
        h.set("p95", s.p95.into());
        h.set("p99", s.p99.into());
        h.set("p999", s.p999.into());
        h.set("max", s.max.into());
        hists.set(name, h);
    }
    doc.set("histograms", hists);
    doc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::recorder::Recorder;
    use std::time::Instant;

    fn sample_data() -> TraceData {
        let r = Recorder::new();
        let t0 = Instant::now();
        r.model_span("bsp/superstep", "compute s0", "model", 0, 100, &[("tiles", "4".into())]);
        r.model_span("bsp/superstep", "exchange s0", "model", 100, 40, &[]);
        r.wall_span_since(t0, "planner/w0", "search 512x512x512", "planner", &[]);
        r.event("serve/worker-0", "reject", "serve", &[("id", "7".into())]);
        r.count("cache.hits", 3);
        r.observe("latency_ms", 1.0);
        r.observe("latency_ms", 9.0);
        r.take()
    }

    #[test]
    fn export_parses_and_has_both_processes() {
        let doc = chrome_trace_json(&sample_data());
        let text = doc.render();
        // round-trip is render-stable (integral floats normalize to Int
        // on parse, which renders identically)
        let parsed = Json::parse(&text).unwrap();
        assert_eq!(parsed.render(), text);
        let events = doc.get("traceEvents").and_then(Json::items).unwrap();
        // 2 process_name + 3 thread_name (3 distinct tracks) + 4 spans
        assert_eq!(events.len(), 9);
        let phases: Vec<&str> =
            events.iter().filter_map(|e| e.get("ph").and_then(Json::as_str)).collect();
        assert_eq!(phases.iter().filter(|p| **p == "M").count(), 5);
        assert_eq!(phases.iter().filter(|p| **p == "X").count(), 3);
        assert_eq!(phases.iter().filter(|p| **p == "i").count(), 1);
    }

    #[test]
    fn model_cycles_map_one_to_one_to_us() {
        let doc = chrome_trace_json(&sample_data());
        let events = doc.get("traceEvents").and_then(Json::items).unwrap();
        let exchange = events
            .iter()
            .find(|e| e.get("name").and_then(Json::as_str) == Some("exchange s0"))
            .unwrap();
        assert_eq!(exchange.get("ts").and_then(Json::as_f64), Some(100.0));
        assert_eq!(exchange.get("dur").and_then(Json::as_f64), Some(40.0));
        assert_eq!(exchange.get("pid").and_then(Json::as_f64), Some(2.0));
    }

    #[test]
    fn counters_and_histograms_exported() {
        let doc = chrome_trace_json(&sample_data());
        assert_eq!(
            doc.get("counters").and_then(|c| c.get("cache.hits")).and_then(Json::as_f64),
            Some(3.0)
        );
        let h = doc.get("histograms").and_then(|h| h.get("latency_ms")).unwrap();
        assert_eq!(h.get("n").and_then(Json::as_f64), Some(2.0));
        assert_eq!(h.get("p999").and_then(Json::as_f64), Some(9.0));
    }

    #[test]
    fn export_is_deterministic() {
        let data = sample_data();
        assert_eq!(chrome_trace_json(&data).render(), chrome_trace_json(&data).render());
    }

    #[test]
    fn empty_data_still_valid() {
        let doc = chrome_trace_json(&TraceData::default());
        assert!(Json::parse(&doc.render()).is_ok());
        assert_eq!(doc.get("traceEvents").and_then(Json::items).unwrap().len(), 2);
    }
}
