//! Observability: end-to-end tracing and counters for every layer.
//!
//! A lightweight, deterministic-by-construction span recorder with two
//! clock domains:
//!
//! * **wall time** — nanoseconds since the recorder epoch, for real work
//!   (planner stripe scans, serve batch draining, graph builds);
//! * **model time** — simulated cycles, for the BSP superstep phases the
//!   engine prices ([`crate::bsp::trace::Trace`] records become spans).
//!
//! plus a process-wide counter / histogram registry and a Chrome
//! trace-event JSON exporter ([`chrome_trace_json`], built on
//! [`crate::util::json`]) whose output loads in `chrome://tracing` and
//! Perfetto. [`flame_summary`] renders the same data as a text
//! flamegraph-style digest for terminals.
//!
//! On top of the recorder sits the streaming metrics pipeline:
//!
//! * [`sketch`] — a mergeable, fixed-memory log-bucketed quantile
//!   sketch ([`QuantileSketch`]) with a documented relative-error
//!   bound; it backs every histogram here (memory O(buckets), never
//!   O(samples)) and merges across sharded serve workers;
//! * [`window`] — tumbling/sliding window aggregation of per-request
//!   events into per-class rps / hit-rate / queue-depth / latency
//!   rows, keyed by request id or queue timestamp for determinism;
//! * [`slo`] — declarative SLO specs (`p99<5ms@99%/100`), error-budget
//!   accounting and multi-window burn-rate alerts, producing
//!   machine-readable verdicts;
//! * [`export`] — Prometheus text exposition + JSON snapshot
//!   (`ipumm serve --metrics-out`, `ipumm slo-check`).
//!
//! Two invariants the rest of the tree relies on:
//!
//! * **zero-cost when off** — every recording entry point is a no-op
//!   behind one relaxed atomic load ([`enabled`]); [`now`] returns `None`
//!   when tracing is off so disabled runs never even read the clock;
//! * **observation never influences planning** — instrumentation is
//!   strictly write-only: nothing in the planner, sparse search, serve
//!   pipeline, or governor reads recorder state, so plans are
//!   bit-identical with tracing on or off (property-tested in
//!   `tests/prop_invariants.rs`).
//!
//! The global recorder is enabled explicitly (`ipumm serve --trace-out`,
//! `ipumm profile --chrome`); library code only ever *records*. Tests
//! that need isolation construct their own [`Recorder`] instances.

pub mod chrome;
pub mod export;
pub mod flame;
pub mod recorder;
pub mod sketch;
pub mod slo;
pub mod window;

pub use chrome::chrome_trace_json;
pub use flame::flame_summary;
pub use recorder::{ClockDomain, Recorder, RecorderOverhead, SpanRecord, TraceData};
pub use sketch::QuantileSketch;

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

static ENABLED: AtomicBool = AtomicBool::new(false);
static RECORDER: OnceLock<Recorder> = OnceLock::new();

fn global() -> &'static Recorder {
    RECORDER.get_or_init(Recorder::new)
}

/// Is the global recorder collecting? One relaxed load — the whole cost
/// of instrumentation in a disabled run.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Start collecting into the global recorder (resetting any previous
/// data and re-anchoring the wall-time epoch).
pub fn enable() {
    global().reset();
    ENABLED.store(true, Ordering::SeqCst);
}

/// Stop collecting. Recorded data stays until [`take`] drains it.
pub fn disable() {
    ENABLED.store(false, Ordering::SeqCst);
}

/// Drain everything recorded so far.
pub fn take() -> TraceData {
    global().take()
}

/// `Some(Instant::now())` while tracing, `None` otherwise — the wall-span
/// start handle. Pairing with [`wall_span_since`] keeps even the clock
/// read off the disabled hot path.
#[inline]
pub fn now() -> Option<Instant> {
    if enabled() {
        Some(Instant::now())
    } else {
        None
    }
}

/// Close a wall-time span opened with [`now`]. A `None` start (tracing
/// was off at open time) is a no-op, as is tracing having been disabled
/// since.
pub fn wall_span_since(
    start: Option<Instant>,
    track: &str,
    name: &str,
    cat: &'static str,
    args: &[(&'static str, String)],
) {
    if let Some(start) = start {
        if enabled() {
            global().wall_span_since(start, track, name, cat, args);
        }
    }
}

/// Record a model-time span: `start`/`dur` are simulated cycles.
pub fn model_span(
    track: &str,
    name: &str,
    cat: &'static str,
    start_cycles: u64,
    dur_cycles: u64,
    args: &[(&'static str, String)],
) {
    if enabled() {
        global().model_span(track, name, cat, start_cycles, dur_cycles, args);
    }
}

/// Record a wall-time instant event (e.g. an incumbent improvement).
pub fn event(track: &str, name: &str, cat: &'static str, args: &[(&'static str, String)]) {
    if enabled() {
        global().event(track, name, cat, args);
    }
}

/// Bump a named counter by `delta`.
pub fn count(name: &str, delta: u64) {
    if enabled() {
        global().count(name, delta);
    }
}

/// Fold one sample into a named histogram sketch (read back as
/// p50/p95/p99/p999 at export time; memory stays O(buckets)).
pub fn observe(name: &str, value: f64) {
    if enabled() {
        global().observe(name, value);
    }
}

/// Merge a locally-aggregated [`QuantileSketch`] into a named global
/// histogram in one lock acquisition. Sharded serve workers use this:
/// observe into a worker-local sketch per sample, merge once at exit.
pub fn merge_sketch(name: &str, sketch: &QuantileSketch) {
    if enabled() {
        global().merge_sketch(name, sketch);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // NB: lib unit tests share one process; these exercise only the
    // *disabled* global path (the CLI and prop-test binaries own the
    // enabled path) so parallel test threads never race on the toggle.
    #[test]
    fn disabled_global_is_inert() {
        assert!(!enabled());
        assert!(now().is_none());
        wall_span_since(None, "t", "n", "c", &[]);
        model_span("t", "n", "c", 0, 10, &[]);
        event("t", "n", "c", &[]);
        count("x", 1);
        observe("h", 1.0);
        let mut local = QuantileSketch::new();
        local.observe(1.0);
        merge_sketch("h", &local);
        let data = take();
        assert!(data.spans.is_empty());
        assert!(data.counters.is_empty());
        assert!(data.histograms.is_empty());
    }
}
