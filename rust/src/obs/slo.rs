//! Declarative latency SLOs: spec parsing, error-budget accounting, and
//! multi-window burn-rate evaluation.
//!
//! A spec reads `p99<5ms@99%/100`:
//!
//! * `p99` — the monitored percentile (informational: it names the tail
//!   the threshold is aimed at, and supplies the default target);
//! * `<5ms` — the latency threshold; a request is **good** when its
//!   end-to-end latency is strictly below it and it did not OOM. Units:
//!   `ns`, `us`, `ms`, `s`;
//! * `@99%` — the compliance target: the SLO is met when at least this
//!   fraction of requests is good. Defaults to the monitored percentile
//!   (`p99` → 99%), so `p99<5ms` alone means "99% of requests under
//!   5 ms";
//! * `/100` — the evaluation window in requests (tumbling, keyed by
//!   request id so verdicts are deterministic). Defaults to
//!   [`DEFAULT_WINDOW`].
//!
//! **Error budget**: the allowed bad fraction is `1 - target`. The
//! verdict reports how much of it the run consumed
//! (`budget_consumed = bad_fraction / (1 - target)`; above 1.0 the SLO
//! is violated).
//!
//! **Burn rate** (Google SRE style, adapted to request-count windows):
//! the budget-consumption *speed*, `bad_fraction / (1 - target)`,
//! evaluated over two window lengths — the spec's short window and a
//! long window [`LONG_WINDOW_FACTOR`]× wider. A run is **burning** when
//! some short window burns at ≥ [`FAST_BURN`]× *and* the long window
//! containing it at ≥ [`SLOW_BURN`]× — the fast signal catches a spike,
//! the slow one confirms it is not a one-off. `burning` is an early
//! warning; the hard `violated` verdict is whole-run compliance below
//! target.

use crate::util::json::Json;

use super::window::MetricEvent;

/// Default evaluation window (requests) when a spec has no `/W` suffix.
pub const DEFAULT_WINDOW: u64 = 100;

/// The long burn-rate window is this many short windows wide.
pub const LONG_WINDOW_FACTOR: u64 = 10;

/// Short-window burn-rate alert threshold (×budget speed).
pub const FAST_BURN: f64 = 10.0;

/// Long-window burn-rate alert threshold (×budget speed).
pub const SLOW_BURN: f64 = 2.0;

/// One parsed latency SLO.
#[derive(Clone, Debug, PartialEq)]
pub struct SloSpec {
    /// The original spec text (label in reports and metric exports).
    pub raw: String,
    /// Monitored percentile in (0, 100), e.g. 99.0.
    pub percentile: f64,
    /// Latency threshold in seconds; good means strictly below.
    pub threshold_s: f64,
    /// Required good fraction in (0, 1).
    pub target: f64,
    /// Evaluation window in requests.
    pub window: u64,
}

impl SloSpec {
    /// Parse `pP<T[@G%][/W]`, e.g. `p99<5ms@99.5%/200`. See the module
    /// docs for semantics.
    pub fn parse(text: &str) -> Result<SloSpec, String> {
        let raw = text.trim().to_string();
        let rest = raw
            .strip_prefix('p')
            .ok_or_else(|| format!("SLO '{raw}': must start with 'p' (e.g. p99<5ms)"))?;
        let (pct_str, rest) = rest
            .split_once('<')
            .ok_or_else(|| format!("SLO '{raw}': missing '<threshold'"))?;
        let percentile: f64 = pct_str
            .parse()
            .map_err(|_| format!("SLO '{raw}': bad percentile '{pct_str}'"))?;
        if !(0.0 < percentile && percentile < 100.0) {
            return Err(format!("SLO '{raw}': percentile must be in (0,100)"));
        }
        let (thresh_str, rest) = match rest.split_once('@') {
            Some((t, tail)) => (t, Some(tail)),
            None => (rest, None),
        };
        // the window suffix may follow the threshold or the target
        let (thresh_str, window_after_thresh) = split_window(thresh_str)?;
        let threshold_s = parse_duration_s(thresh_str)
            .map_err(|e| format!("SLO '{raw}': {e}"))?;
        let (target, window) = match rest {
            None => (percentile / 100.0, window_after_thresh),
            Some(tail) => {
                let (target_str, window_after_target) = split_window(tail)?;
                let target_str = target_str.strip_suffix('%').ok_or_else(|| {
                    format!("SLO '{raw}': target must end in '%' (e.g. @99%)")
                })?;
                let pct: f64 = target_str
                    .parse()
                    .map_err(|_| format!("SLO '{raw}': bad target '{target_str}'"))?;
                if !(0.0 < pct && pct < 100.0) {
                    return Err(format!("SLO '{raw}': target must be in (0,100)%"));
                }
                (pct / 100.0, window_after_target.or(window_after_thresh))
            }
        };
        Ok(SloSpec {
            raw,
            percentile,
            threshold_s,
            target,
            window: window.unwrap_or(DEFAULT_WINDOW),
        })
    }

    /// Parse a `;`-separated list of specs (the CLI `--slo` form).
    pub fn parse_list(text: &str) -> Result<Vec<SloSpec>, String> {
        text.split(';')
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .map(SloSpec::parse)
            .collect()
    }

    /// Is one request within this SLO?
    pub fn is_good(&self, ev: &MetricEvent) -> bool {
        !ev.oom && ev.latency_s < self.threshold_s
    }
}

fn split_window(s: &str) -> Result<(&str, Option<u64>), String> {
    match s.split_once('/') {
        None => Ok((s, None)),
        Some((head, w)) => {
            let window: u64 =
                w.parse().map_err(|_| format!("bad window '/{w}' (want /requests)"))?;
            if window == 0 {
                return Err("window must be >= 1".to_string());
            }
            Ok((head, Some(window)))
        }
    }
}

/// Parse `5ms` / `250us` / `1.5s` / `800ns` to seconds.
pub fn parse_duration_s(s: &str) -> Result<f64, String> {
    let s = s.trim();
    let (num, scale) = if let Some(n) = s.strip_suffix("ns") {
        (n, 1e-9)
    } else if let Some(n) = s.strip_suffix("us") {
        (n, 1e-6)
    } else if let Some(n) = s.strip_suffix("ms") {
        (n, 1e-3)
    } else if let Some(n) = s.strip_suffix('s') {
        (n, 1.0)
    } else {
        return Err(format!("duration '{s}' needs a unit (ns/us/ms/s)"));
    };
    let v: f64 = num.trim().parse().map_err(|_| format!("bad duration '{s}'"))?;
    if v <= 0.0 {
        return Err(format!("duration '{s}' must be positive"));
    }
    Ok(v * scale)
}

/// Burn rate of one window: `[start, end)`, bad/total, and the budget
/// consumption speed.
#[derive(Clone, Copy, Debug)]
pub struct WindowBurn {
    pub start: u64,
    pub end: u64,
    pub total: u64,
    pub bad: u64,
    /// `bad_fraction / (1 - target)` — 1.0 burns the budget exactly at
    /// the sustainable rate.
    pub burn: f64,
}

/// Machine-readable SLO evaluation result.
#[derive(Clone, Debug)]
pub struct SloVerdict {
    pub spec: SloSpec,
    pub total: u64,
    pub good: u64,
    pub bad: u64,
    /// Good fraction over the whole run (1.0 on an empty run).
    pub compliance: f64,
    /// Allowed bad fraction, `1 - target`.
    pub budget: f64,
    /// `bad_fraction / budget`; > 1.0 means the budget is overspent.
    pub budget_consumed: f64,
    /// Worst short-window burn rate (window of `spec.window` requests).
    pub worst_short: Option<WindowBurn>,
    /// Worst long-window burn rate ([`LONG_WINDOW_FACTOR`]× wider).
    pub worst_long: Option<WindowBurn>,
    /// Short windows whose own compliance missed the target.
    pub windows_violated: usize,
    pub windows_total: usize,
    /// Fast-and-slow burn alert (see module docs) — early warning.
    pub burning: bool,
    /// Whole-run compliance below target — the hard gate.
    pub violated: bool,
}

impl SloVerdict {
    /// One human-readable verdict line for CLI output.
    pub fn line(&self) -> String {
        let state = if self.violated {
            "VIOLATED"
        } else if self.burning {
            "ok (burning)"
        } else {
            "ok"
        };
        let worst = match &self.worst_short {
            Some(w) => format!(
                ", worst window [{}, {}) burned {:.2}x",
                w.start, w.end, w.burn
            ),
            None => String::new(),
        };
        format!(
            "SLO {}: {state} — compliance {:.3}% (target {:.3}%), error budget {:.0}% consumed{worst}",
            self.spec.raw,
            self.compliance * 100.0,
            self.spec.target * 100.0,
            self.budget_consumed * 100.0,
        )
    }

    /// JSON form for the metrics snapshot (`ipumm slo-check --snapshot`
    /// reads `spec` and `violated` back out of this).
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("spec", self.spec.raw.as_str().into());
        o.set("percentile", self.spec.percentile.into());
        o.set("threshold_s", self.spec.threshold_s.into());
        o.set("target", self.spec.target.into());
        o.set("window", self.spec.window.into());
        o.set("total", self.total.into());
        o.set("good", self.good.into());
        o.set("bad", self.bad.into());
        o.set("compliance", self.compliance.into());
        o.set("budget", self.budget.into());
        o.set("budget_consumed", self.budget_consumed.into());
        o.set("windows_violated", self.windows_violated.into());
        o.set("windows_total", self.windows_total.into());
        if let Some(w) = &self.worst_short {
            let mut b = Json::obj();
            b.set("start", w.start.into());
            b.set("end", w.end.into());
            b.set("burn", w.burn.into());
            o.set("worst_short", b);
        }
        if let Some(w) = &self.worst_long {
            let mut b = Json::obj();
            b.set("start", w.start.into());
            b.set("end", w.end.into());
            b.set("burn", w.burn.into());
            o.set("worst_long", b);
        }
        o.set("burning", self.burning.into());
        o.set("violated", self.violated.into());
        o
    }
}

fn window_burns(spec: &SloSpec, events: &[MetricEvent], width: u64) -> Vec<WindowBurn> {
    let budget = (1.0 - spec.target).max(f64::EPSILON);
    let mut per: std::collections::BTreeMap<u64, (u64, u64)> = std::collections::BTreeMap::new();
    for ev in events {
        let start = ev.pos / width * width;
        let slot = per.entry(start).or_insert((0, 0));
        slot.0 += 1;
        slot.1 += !spec.is_good(ev) as u64;
    }
    per.into_iter()
        .map(|(start, (total, bad))| WindowBurn {
            start,
            end: start + width,
            total,
            bad,
            burn: (bad as f64 / total as f64) / budget,
        })
        .collect()
}

/// Evaluate one SLO over an event stream (positions are request ids).
pub fn evaluate(spec: &SloSpec, events: &[MetricEvent]) -> SloVerdict {
    let total = events.len() as u64;
    let good = events.iter().filter(|e| spec.is_good(e)).count() as u64;
    let bad = total - good;
    let compliance = if total == 0 { 1.0 } else { good as f64 / total as f64 };
    let budget = 1.0 - spec.target;
    let budget_consumed = if total == 0 {
        0.0
    } else {
        (bad as f64 / total as f64) / budget.max(f64::EPSILON)
    };

    let shorts = window_burns(spec, events, spec.window);
    let longs = window_burns(spec, events, spec.window * LONG_WINDOW_FACTOR);
    let worst = |burns: &[WindowBurn]| {
        burns
            .iter()
            .copied()
            .max_by(|a, b| a.burn.partial_cmp(&b.burn).unwrap())
    };
    let worst_short = worst(&shorts);
    let worst_long = worst(&longs);
    // fast alert in some short window, confirmed by the long window
    // containing it
    let burning = shorts.iter().any(|s| {
        s.burn >= FAST_BURN
            && longs
                .iter()
                .find(|l| l.start <= s.start && s.start < l.end)
                .is_some_and(|l| l.burn >= SLOW_BURN)
    });
    let windows_violated = shorts
        .iter()
        .filter(|w| ((w.total - w.bad) as f64 / w.total as f64) < spec.target)
        .count();

    SloVerdict {
        spec: spec.clone(),
        total,
        good,
        bad,
        compliance,
        budget,
        budget_consumed,
        worst_short,
        worst_long,
        windows_violated,
        windows_total: shorts.len(),
        burning,
        violated: compliance < spec.target,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(pos: u64, latency_s: f64) -> MetricEvent {
        MetricEvent {
            pos,
            class: "c".to_string(),
            latency_s,
            cache_lookup: true,
            cache_hit: true,
            queue_depth: 0,
            oom: false,
        }
    }

    #[test]
    fn parses_full_and_minimal_specs() {
        let s = SloSpec::parse("p99<5ms@99.5%/200").unwrap();
        assert_eq!(s.percentile, 99.0);
        assert!((s.threshold_s - 5e-3).abs() < 1e-15);
        assert!((s.target - 0.995).abs() < 1e-12);
        assert_eq!(s.window, 200);

        // target defaults to the monitored percentile, window to 100
        let s = SloSpec::parse("p95<250us").unwrap();
        assert!((s.threshold_s - 250e-6).abs() < 1e-18);
        assert!((s.target - 0.95).abs() < 1e-12);
        assert_eq!(s.window, DEFAULT_WINDOW);

        // window may follow the threshold when no target is given
        let s = SloSpec::parse("p50<1s/50").unwrap();
        assert_eq!(s.window, 50);
        assert_eq!(s.threshold_s, 1.0);

        let list = SloSpec::parse_list("p99<5ms; p50<1ms@90%").unwrap();
        assert_eq!(list.len(), 2);
    }

    #[test]
    fn rejects_malformed_specs() {
        for bad in [
            "99<5ms",      // no p
            "p99",         // no threshold
            "p99<5",       // no unit
            "p99<5ms@99",  // no %
            "p0<5ms",      // percentile out of range
            "p99<5ms/0",   // zero window
            "p99<-1ms",    // negative duration
            "p101<5ms",    // >100
        ] {
            assert!(SloSpec::parse(bad).is_err(), "'{bad}' should not parse");
        }
    }

    #[test]
    fn duration_units() {
        assert!((parse_duration_s("800ns").unwrap() - 8e-7).abs() < 1e-18);
        assert!((parse_duration_s("250us").unwrap() - 2.5e-4).abs() < 1e-15);
        assert!((parse_duration_s("5ms").unwrap() - 5e-3).abs() < 1e-12);
        assert!((parse_duration_s("1.5s").unwrap() - 1.5).abs() < 1e-12);
        assert!(parse_duration_s("5").is_err());
    }

    #[test]
    fn compliant_run_keeps_its_budget() {
        let spec = SloSpec::parse("p99<5ms@99%/10").unwrap();
        let events: Vec<MetricEvent> = (0..100).map(|i| ev(i, 1e-3)).collect();
        let v = evaluate(&spec, &events);
        assert!(!v.violated);
        assert!(!v.burning);
        assert_eq!(v.compliance, 1.0);
        assert_eq!(v.budget_consumed, 0.0);
        assert_eq!(v.windows_total, 10);
        assert_eq!(v.windows_violated, 0);
    }

    #[test]
    fn violated_run_overspends_budget() {
        let spec = SloSpec::parse("p99<5ms@99%/10").unwrap();
        // 5% of requests breach the threshold: 5x the 1% budget
        let events: Vec<MetricEvent> =
            (0..100).map(|i| ev(i, if i % 20 == 0 { 1.0 } else { 1e-3 })).collect();
        let v = evaluate(&spec, &events);
        assert!(v.violated);
        assert!((v.compliance - 0.95).abs() < 1e-12);
        assert!((v.budget_consumed - 5.0).abs() < 1e-9);
        assert!(v.windows_violated > 0);
    }

    #[test]
    fn oom_requests_always_count_against_the_slo() {
        let spec = SloSpec::parse("p99<5ms@50%").unwrap();
        let mut bad = ev(0, 1e-6); // fast, but OOM
        bad.oom = true;
        let v = evaluate(&spec, &[bad, ev(1, 1e-6), ev(2, 1e-6)]);
        assert_eq!(v.bad, 1);
        assert!(!v.violated, "2/3 good still beats a 50% target");
    }

    #[test]
    fn burn_alert_needs_fast_and_slow_windows() {
        let spec = SloSpec::parse("p99<5ms@99%/10").unwrap();
        // one saturated window of 10 bad requests in a 200-request run:
        // short burn 100x (>= FAST), long burn over 100 requests is
        // 10/100/0.01 = 10x (>= SLOW) -> burning; but overall compliance
        // 190/200 = 95% < 99% is also violated
        let events: Vec<MetricEvent> =
            (0..200).map(|i| ev(i, if (50..60).contains(&i) { 1.0 } else { 1e-3 })).collect();
        let v = evaluate(&spec, &events);
        assert!(v.burning);
        let w = v.worst_short.unwrap();
        assert_eq!((w.start, w.end), (50, 60));
        assert!((w.burn - 100.0).abs() < 1e-9);
    }

    #[test]
    fn empty_run_is_vacuously_compliant() {
        let spec = SloSpec::parse("p99<5ms").unwrap();
        let v = evaluate(&spec, &[]);
        assert!(!v.violated);
        assert_eq!(v.compliance, 1.0);
        assert_eq!(v.windows_total, 0);
    }

    #[test]
    fn verdict_json_round_trips() {
        let spec = SloSpec::parse("p99<5ms@99%/10").unwrap();
        let events: Vec<MetricEvent> = (0..30).map(|i| ev(i, 1e-3)).collect();
        let v = evaluate(&spec, &events);
        let doc = Json::parse(&v.to_json().render()).unwrap();
        assert_eq!(doc.get("spec").and_then(Json::as_str), Some("p99<5ms@99%/10"));
        assert_eq!(doc.get("violated").and_then(Json::as_f64), None, "bool, not number");
        assert!(matches!(doc.get("violated"), Some(Json::Bool(false))));
        assert_eq!(doc.get("total").and_then(Json::as_f64), Some(30.0));
    }
}
