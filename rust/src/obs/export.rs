//! Metrics exposition: Prometheus text format and a JSON snapshot.
//!
//! [`MetricsSnapshot`] is the serialization boundary of the streaming
//! metrics pipeline: the serving layer folds its per-request events
//! into one snapshot (whole-run class aggregates, the windowed
//! timeline, and SLO verdicts), and this module renders it two ways:
//!
//! * [`MetricsSnapshot::prometheus_text`] — the Prometheus text
//!   exposition format (`# HELP`/`# TYPE` preambles, counter and gauge
//!   samples, and one `summary`-typed family per latency distribution
//!   with `quantile` labels plus `_sum`/`_count`), ready for a
//!   file-based scrape (`ipumm serve --metrics-out F` writes it; a
//!   node-exporter textfile collector or a CI validator picks it up);
//! * [`MetricsSnapshot::to_json`] — a deterministic JSON document on
//!   [`crate::util::json`] carrying the full timeline (per-window
//!   p50/p99 per traffic class — the view Prometheus' whole-run
//!   families cannot express) and the machine-readable SLO verdicts
//!   (`ipumm slo-check --snapshot` consumes it).
//!
//! Everything renders deterministically: counters and gauges are
//! `BTreeMap`s, classes and windows arrive sorted from
//! [`crate::obs::window`], and [`crate::util::json::Json`] objects
//! render with sorted keys.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::util::json::Json;

use super::sketch::QuantileSketch;
use super::slo::{evaluate, SloSpec, SloVerdict};
use super::window::{windowed, MetricEvent, WindowSpec, WindowStats};

/// Whole-run aggregate for one traffic class.
#[derive(Clone, Debug)]
pub struct ClassAggregate {
    pub class: String,
    pub requests: u64,
    pub lookups: u64,
    pub hits: u64,
    pub oom: u64,
    pub latency: QuantileSketch,
}

/// One serving run's exportable metrics state.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    /// Monotone counters (full metric names, e.g.
    /// `ipumm_serve_requests_total`).
    pub counters: BTreeMap<String, u64>,
    /// Point-in-time gauges (e.g. `ipumm_serve_wall_seconds`).
    pub gauges: BTreeMap<String, f64>,
    /// Whole-run per-class aggregates, sorted by class label.
    pub classes: Vec<ClassAggregate>,
    pub window: WindowSpec,
    /// Windowed view of the same events (JSON-only; see module docs).
    pub timeline: Vec<WindowStats>,
    pub slos: Vec<SloVerdict>,
}

impl MetricsSnapshot {
    /// Fold an event stream into a snapshot: whole-run class
    /// aggregates, a tumbling/sliding timeline, and one verdict per
    /// SLO spec.
    pub fn build(
        events: &[MetricEvent],
        counters: BTreeMap<String, u64>,
        gauges: BTreeMap<String, f64>,
        window: WindowSpec,
        slos: &[SloSpec],
    ) -> MetricsSnapshot {
        let mut classes: BTreeMap<String, ClassAggregate> = BTreeMap::new();
        for ev in events {
            let agg = classes.entry(ev.class.clone()).or_insert_with(|| ClassAggregate {
                class: ev.class.clone(),
                requests: 0,
                lookups: 0,
                hits: 0,
                oom: 0,
                latency: QuantileSketch::new(),
            });
            agg.requests += 1;
            if ev.cache_lookup {
                agg.lookups += 1;
                agg.hits += ev.cache_hit as u64;
            }
            agg.oom += ev.oom as u64;
            agg.latency.observe(ev.latency_s);
        }
        MetricsSnapshot {
            counters,
            gauges,
            classes: classes.into_values().collect(),
            window,
            timeline: windowed(events, window),
            slos: slos.iter().map(|s| evaluate(s, events)).collect(),
        }
    }

    /// Any SLO verdict violated?
    pub fn any_slo_violated(&self) -> bool {
        self.slos.iter().any(|v| v.violated)
    }

    /// Prometheus text exposition (see module docs). Valid for a
    /// textfile collector: every sample line is
    /// `name{labels} value` with sanitized names and escaped labels.
    pub fn prometheus_text(&self) -> String {
        let mut out = String::new();
        for (name, value) in &self.counters {
            let name = sanitize_metric_name(name);
            let _ = writeln!(out, "# HELP {name} ipumm serve counter.");
            let _ = writeln!(out, "# TYPE {name} counter");
            let _ = writeln!(out, "{name} {value}");
        }
        for (name, value) in &self.gauges {
            let name = sanitize_metric_name(name);
            let _ = writeln!(out, "# HELP {name} ipumm serve gauge.");
            let _ = writeln!(out, "# TYPE {name} gauge");
            let _ = writeln!(out, "{name} {value}");
        }
        if !self.classes.is_empty() {
            let name = "ipumm_serve_latency_seconds";
            let _ = writeln!(
                out,
                "# HELP {name} End-to-end request latency per traffic class."
            );
            let _ = writeln!(out, "# TYPE {name} summary");
            for c in &self.classes {
                let class = escape_label_value(&c.class);
                for (q, label) in
                    [(0.5, "0.5"), (0.95, "0.95"), (0.99, "0.99"), (0.999, "0.999")]
                {
                    let _ = writeln!(
                        out,
                        "{name}{{class=\"{class}\",quantile=\"{label}\"}} {}",
                        c.latency.quantile(q)
                    );
                }
                let _ =
                    writeln!(out, "{name}_sum{{class=\"{class}\"}} {}", c.latency.sum());
                let _ = writeln!(
                    out,
                    "{name}_count{{class=\"{class}\"}} {}",
                    c.latency.count()
                );
            }
        }
        if !self.slos.is_empty() {
            for (metric, help) in [
                ("ipumm_slo_compliance", "Good-request fraction per SLO."),
                ("ipumm_slo_budget_consumed", "Error-budget consumption per SLO (1.0 = spent exactly)."),
                ("ipumm_slo_violated", "1 when the SLO's whole-run compliance missed its target."),
            ] {
                let _ = writeln!(out, "# HELP {metric} {help}");
                let _ = writeln!(out, "# TYPE {metric} gauge");
                for v in &self.slos {
                    let slo = escape_label_value(&v.spec.raw);
                    let value = match metric {
                        "ipumm_slo_compliance" => v.compliance,
                        "ipumm_slo_budget_consumed" => v.budget_consumed,
                        _ => v.violated as u64 as f64,
                    };
                    let _ = writeln!(out, "{metric}{{slo=\"{slo}\"}} {value}");
                }
            }
        }
        out
    }

    /// JSON snapshot: counters, gauges, whole-run class summaries, the
    /// per-window timeline (p50/p99 per class per window), SLO
    /// verdicts, and the sketch configuration. Parses back through
    /// [`Json::parse`] byte-stable.
    pub fn to_json(&self) -> Json {
        let mut doc = Json::obj();

        let mut counters = Json::obj();
        for (name, value) in &self.counters {
            counters.set(name, (*value).into());
        }
        doc.set("counters", counters);

        let mut gauges = Json::obj();
        for (name, value) in &self.gauges {
            gauges.set(name, (*value).into());
        }
        doc.set("gauges", gauges);

        let mut classes = Json::Arr(Vec::new());
        for c in &self.classes {
            let mut o = Json::obj();
            o.set("class", c.class.as_str().into());
            o.set("requests", c.requests.into());
            o.set("lookups", c.lookups.into());
            o.set("hits", c.hits.into());
            o.set("oom", c.oom.into());
            o.set("latency", sketch_json(&c.latency));
            classes.push(o);
        }
        doc.set("classes", classes);

        let mut window = Json::obj();
        window.set("width", self.window.width.into());
        window.set("stride", self.window.stride.into());
        doc.set("window", window);

        let mut timeline = Json::Arr(Vec::new());
        for w in &self.timeline {
            timeline.push(window_json(w));
        }
        doc.set("timeline", timeline);

        let mut slos = Json::Arr(Vec::new());
        for v in &self.slos {
            slos.push(v.to_json());
        }
        doc.set("slos", slos);

        let probe = QuantileSketch::new();
        let mut sketch = Json::obj();
        sketch.set("relative_error", probe.relative_error().into());
        sketch.set("buckets", probe.buckets().into());
        sketch.set("memory_bytes", probe.memory_bytes().into());
        doc.set("sketch", sketch);
        doc
    }
}

fn sketch_json(s: &QuantileSketch) -> Json {
    let mut o = Json::obj();
    o.set("n", s.count().into());
    if !s.is_empty() {
        let sum = s.summary();
        o.set("mean", sum.mean.into());
        o.set("min", sum.min.into());
        o.set("p50", sum.median.into());
        o.set("p95", sum.p95.into());
        o.set("p99", sum.p99.into());
        o.set("p999", sum.p999.into());
        o.set("max", sum.max.into());
    }
    o
}

fn window_json(w: &WindowStats) -> Json {
    let mut o = Json::obj();
    o.set("start", w.start.into());
    o.set("end", w.end.into());
    let mut classes = Json::Arr(Vec::new());
    for c in &w.classes {
        let mut co = Json::obj();
        co.set("class", c.class.as_str().into());
        co.set("requests", c.requests.into());
        co.set("hits", c.hits.into());
        co.set("lookups", c.lookups.into());
        co.set("oom", c.oom.into());
        co.set("hit_rate", c.hit_rate().into());
        co.set("mean_queue_depth", c.mean_queue_depth().into());
        if !c.latency.is_empty() {
            co.set("p50", c.latency.quantile(0.5).into());
            co.set("p99", c.latency.quantile(0.99).into());
            co.set("max", c.latency.max().into());
        }
        classes.push(co);
    }
    o.set("classes", classes);
    o
}

/// Prometheus metric names allow `[a-zA-Z0-9_:]`; map everything else
/// (the recorder's dotted names, dashes) to `_`.
pub fn sanitize_metric_name(name: &str) -> String {
    let mut out: String = name
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '_' || c == ':' { c } else { '_' })
        .collect();
    if out.chars().next().is_some_and(|c| c.is_ascii_digit()) {
        out.insert(0, '_');
    }
    out
}

/// Escape a label value per the exposition format: backslash, quote,
/// newline.
pub fn escape_label_value(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(pos: u64, class: &str, latency_s: f64, hit: bool) -> MetricEvent {
        MetricEvent {
            pos,
            class: class.to_string(),
            latency_s,
            cache_lookup: true,
            cache_hit: hit,
            queue_depth: 1,
            oom: false,
        }
    }

    fn snapshot() -> MetricsSnapshot {
        let events: Vec<MetricEvent> = (0..40)
            .map(|i| {
                ev(
                    i,
                    if i % 2 == 0 { "256x256x256" } else { "512x512x512" },
                    1e-3 * (1 + i % 5) as f64,
                    i % 4 != 0,
                )
            })
            .collect();
        let mut counters = BTreeMap::new();
        counters.insert("ipumm_serve_requests_total".to_string(), 40);
        counters.insert("ipumm_serve_batches_total".to_string(), 12);
        let mut gauges = BTreeMap::new();
        gauges.insert("ipumm_serve_wall_seconds".to_string(), 0.25);
        let slos = [SloSpec::parse("p99<10ms@99%/10").unwrap()];
        MetricsSnapshot::build(&events, counters, gauges, WindowSpec::tumbling(10), &slos)
    }

    #[test]
    fn prometheus_text_has_families_counters_and_slos() {
        let text = snapshot().prometheus_text();
        assert!(text.contains("# TYPE ipumm_serve_requests_total counter"));
        assert!(text.contains("ipumm_serve_requests_total 40"));
        assert!(text.contains("# TYPE ipumm_serve_latency_seconds summary"));
        assert!(text.contains("ipumm_serve_latency_seconds{class=\"256x256x256\",quantile=\"0.99\"}"));
        assert!(text.contains("ipumm_serve_latency_seconds_count{class=\"256x256x256\"} 20"));
        assert!(text.contains("ipumm_slo_violated{slo=\"p99<10ms@99%/10\"} 0"));
        // every non-comment line is `name_or_name{labels} value`
        for line in text.lines().filter(|l| !l.starts_with('#') && !l.is_empty()) {
            let (head, value) = line.rsplit_once(' ').expect("sample line has a value");
            assert!(value.parse::<f64>().is_ok(), "unparseable value in '{line}'");
            let name = head.split('{').next().unwrap();
            assert!(
                name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':'),
                "bad metric name in '{line}'"
            );
        }
    }

    #[test]
    fn snapshot_json_round_trips_and_carries_windows() {
        let doc = snapshot().to_json();
        let text = doc.render();
        let parsed = Json::parse(&text).unwrap();
        assert_eq!(parsed.render(), text, "render-stable");
        let timeline = parsed.get("timeline").and_then(Json::items).unwrap();
        assert_eq!(timeline.len(), 4, "40 requests / width 10");
        let w0 = &timeline[0];
        assert_eq!(w0.get("start").and_then(Json::as_f64), Some(0.0));
        let classes = w0.get("classes").and_then(Json::items).unwrap();
        assert_eq!(classes.len(), 2);
        // the acceptance surface: per-window p50/p99 per class
        assert!(classes[0].get("p50").and_then(Json::as_f64).is_some());
        assert!(classes[0].get("p99").and_then(Json::as_f64).is_some());
        let slos = parsed.get("slos").and_then(Json::items).unwrap();
        assert_eq!(slos.len(), 1);
        assert!(matches!(slos[0].get("violated"), Some(Json::Bool(false))));
        assert!(parsed.get("sketch").and_then(|s| s.get("buckets")).is_some());
    }

    #[test]
    fn quantiles_are_monotone_within_a_family() {
        let snap = snapshot();
        for c in &snap.classes {
            let (p50, p95, p99) = (
                c.latency.quantile(0.5),
                c.latency.quantile(0.95),
                c.latency.quantile(0.99),
            );
            assert!(p50 <= p95 && p95 <= p99, "{}: {p50} {p95} {p99}", c.class);
        }
    }

    #[test]
    fn sanitize_and_escape() {
        assert_eq!(sanitize_metric_name("queue.rejected"), "queue_rejected");
        assert_eq!(sanitize_metric_name("serve-latency"), "serve_latency");
        assert_eq!(sanitize_metric_name("9lives"), "_9lives");
        assert_eq!(escape_label_value("a\"b\\c"), "a\\\"b\\\\c");
    }

    #[test]
    fn violated_slo_is_visible() {
        let events: Vec<MetricEvent> = (0..20).map(|i| ev(i, "c", 1.0, true)).collect();
        let slos = [SloSpec::parse("p99<1ms@99%").unwrap()];
        let snap = MetricsSnapshot::build(
            &events,
            BTreeMap::new(),
            BTreeMap::new(),
            WindowSpec::tumbling(10),
            &slos,
        );
        assert!(snap.any_slo_violated());
        assert!(snap.prometheus_text().contains("ipumm_slo_violated{slo=\"p99<1ms@99%\"} 1"));
    }
}
